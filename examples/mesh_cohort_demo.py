"""Sharded cohort execution demo: the async engine's cohort axis
partitioned over a host-device mesh.

    PYTHONPATH=src python examples/mesh_cohort_demo.py --devices 8

Spawns N virtual host devices, builds a ('data','model') mesh with every
device on the data axis, and drives one federated SER workload through
the cohort engine with ``client_axis="vmap"`` (or ``"fl_step"`` for the
production per-microbatch-DP round): a full-population cohort is stacked
on a leading client axis, constrained onto the data axis, and every
member's local DP-SGD round runs on its own device.  Prints the per-leaf
shard occupancy (the proof the axis is partitioned, not replicated) and
the usual accuracy/participation summary.
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--executor", default="vmap",
                    choices=("vmap", "fl_step"))
    ap.add_argument("--updates", type=int, default=16)
    ap.add_argument("--sigma", type=float, default=1.0)
    args = ap.parse_args()

    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        # append rather than setdefault: a pre-existing XLA_FLAGS value
        # must not silently discard --devices (the partition proof would
        # then pass trivially on 1 device)
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count="
            f"{args.devices}").strip()
    import jax

    from repro.core.testbed import TestbedConfig, build_testbed, run_experiment
    from repro.data.synthetic_ser import SERDataConfig
    from repro.engine import (
        CohortRunner, EngineConfig, assert_cohort_partitioned, cohort_mesh)

    n = len(jax.devices())
    mesh = cohort_mesh()
    print(f"[mesh-cohorts] {n} host devices, mesh {dict(mesh.shape)}")

    cfg = TestbedConfig(num_clients=n, batch_size=32, sigma=args.sigma,
                        data=SERDataConfig(n_total=150 * n), seed=0)
    ec = EngineConfig(client_axis=args.executor, mesh=mesh, max_cohort=n,
                      staleness_window=1e9)
    if args.executor == "fl_step":
        from repro.core.dp import DPConfig
        from repro.core.fl_step import FLStepConfig
        ec = EngineConfig(client_axis="fl_step", mesh=mesh, max_cohort=n,
                          staleness_window=1e9,
                          fl_cfg=FLStepConfig(
                              num_clients=n, n_micro=2, local_lr=0.02,
                              dp=DPConfig(clip_norm=1.0,
                                          noise_multiplier=args.sigma,
                                          granularity="per_microbatch")))

    # 1) shard-shape proof: one full-population cohort through the runner
    clients, params, _, _ = build_testbed(cfg)
    runner = CohortRunner(clients, ec)
    key = jax.random.PRNGKey(0)
    plans = []
    for c in clients:
        key, sub = jax.random.split(key)
        plans.append(runner.dispatch(c, params, sub, 0))
    stacked = runner.run_cohort(plans)
    report = assert_cohort_partitioned(stacked, mesh)
    print(f"[mesh-cohorts] cohort of {n} partitioned: "
          f"{len(report)} leaves x {set(report.values())} member(s)/shard")

    # 2) the same config end-to-end through the run_experiment frontend
    _, log = run_experiment("fedasync", cfg, max_updates=args.updates,
                            alpha=0.4, eval_every=args.updates,
                            engine="cohort", engine_cfg=ec)
    eps = {t: round(v[-1], 2) for t, v in log.eps_trajectory.items() if v}
    print(f"[mesh-cohorts] {sum(log.update_counts.values())} updates in "
          f"cohorts of {sorted(set(log.cohort_sizes))}, "
          f"final acc {log.global_acc[-1]:.3f}, eps per tier {eps}")
    st = log.engine_stats
    print(f"[mesh-cohorts] data path: {st['data_path']} — "
          f"{st['h2d_bytes_per_cohort']:.0f} B/cohort over H2D "
          f"({st['cohorts']} cohorts; index plans only on the arena path)")


if __name__ == "__main__":
    main()
