"""Distributed FL pretraining of a small language model with DP — the
datacenter-scale face of the paper's technique (core/fl_step.py).

    PYTHONPATH=src python examples/distributed_fl_pretrain.py \
        --steps 200 --devices 8

Spawns N virtual host devices, builds a ('data','model') mesh, and runs
``fl_train_step`` (per-client DP-SGD + staleness-weighted aggregation as
ONE pjit program) on a reduced smollm-family LM over the synthetic token
pipeline.  Loss decreasing over a few hundred federated rounds shows the
whole stack — model zoo, sharding rules, DP clipping, server Adam,
checkpointing — working end to end.
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--data-axis", type=int, default=4)
    ap.add_argument("--sigma", type=float, default=0.02)
    ap.add_argument("--clip", type=float, default=10.0)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="results/fl_pretrain_ckpt")
    args = ap.parse_args()

    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.devices}")
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.checkpoint import checkpoint as ckpt
    from repro.configs import get_config
    from repro.core.dp import DPConfig
    from repro.core.fl_step import (
        FLStepConfig, make_fl_train_step, make_server_optimizer)
    from repro.data.tokens import TokenDataConfig, make_batches
    from repro.models import layers as Lyr
    from repro.models.base import get_family
    from repro.launch.shardings import batch_spec, leaf_spec, tree_shardings

    G = args.data_axis
    mesh = jax.make_mesh((G, args.devices // G), ("data", "model"))
    cfg = get_config("smollm-360m").replace(
        n_layers=args.layers, d_model=args.d_model, n_heads=4, n_kv_heads=2,
        d_head=args.d_model // 4, d_ff=2 * args.d_model, vocab=2048,
        param_dtype="float32")
    fam = get_family(cfg.family)
    Lyr.set_mesh_context(mesh, "data", "model")

    # DP granularity note: per-microbatch clipping with few microbatches
    # needs a looser clip than the paper's per-example C=1 (the clipped
    # unit is a whole-model mean gradient, not one sample's), and the
    # noise norm scales with sqrt(n_params): per step it EXCEEDS the
    # clipped signal, and training still works only because the signal
    # accumulates coherently across rounds while the noise averages out —
    # the same reason the paper needs ~60 rounds to 75%.  sigma here is
    # deliberately small for a 200-round demo; production DP-FL buys SNR
    # with client count and per-example clipping.
    fl = FLStepConfig(
        num_clients=G, n_local=1, n_micro=4, local_lr=0.5, server_lr=5e-3,
        dp=DPConfig(clip_norm=args.clip, noise_multiplier=args.sigma,
                    granularity="per_microbatch"),
        compute_dtype="float32",
    )
    key = jax.random.PRNGKey(0)
    params = fam.init_params(key, cfg)
    stacked_sds = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct((G,) + l.shape, l.dtype), params)
    client_sh = tree_shardings(stacked_sds, cfg, mesh, role="client")
    step = make_fl_train_step(lambda p, b: fam.loss(p, b, cfg), fl,
                              client_shardings=client_sh)
    sopt = make_server_optimizer(fl)
    opt_state = sopt.init(params)

    msh = tree_shardings(params, cfg, mesh, role="master")
    osh = jax.tree_util.tree_map(
        lambda l: NamedSharding(mesh, P() if l.ndim == 0
                                else leaf_spec(l.shape, cfg, mesh, "master")),
        opt_state)
    repl = NamedSharding(mesh, P())
    B = G * 8  # 8 sequences per client round (4 microbatches of 2)
    bsp = {k: NamedSharding(mesh, batch_spec(mesh, 1))
           for k in ("tokens", "labels")}

    data = make_batches(
        TokenDataConfig(vocab=cfg.vocab, seq_len=args.seq, seed=0),
        num_batches=args.steps, batch_size=B)
    weights = jnp.ones((G,)) / G

    eval_loss = jax.jit(lambda p, b: fam.loss(p, b, cfg))
    with jax.sharding.set_mesh(mesh):
        params = jax.device_put(params, msh)
        opt_state = jax.device_put(opt_state, osh)
        jitted = jax.jit(step, in_shardings=(msh, osh, bsp, repl, repl),
                         donate_argnums=(0, 1))
        first_loss = None
        for i, batch in enumerate(data):
            jb = jax.device_put(
                {k: jnp.asarray(v) for k, v in batch.items()}, bsp)
            if i % 25 == 0 or i == args.steps - 1:
                loss = float(eval_loss(params, jb))
                first_loss = first_loss if first_loss is not None else loss
                print(f"[fl-pretrain] round {i:4d} loss {loss:.4f}")
            params, opt_state, _ = jitted(
                params, opt_state, jb, weights, jax.random.PRNGKey(i))
        final_loss = float(eval_loss(params, jb))

    ckpt.save(args.ckpt_dir, args.steps, params,
              meta={"sigma": args.sigma, "final_loss": final_loss})
    print(f"[fl-pretrain] loss {first_loss:.4f} -> {final_loss:.4f} "
          f"({args.steps} federated rounds, G={G} clients, DP sigma="
          f"{args.sigma}); checkpoint in {args.ckpt_dir}")
    assert final_loss < first_loss, "training did not reduce loss"


if __name__ == "__main__":
    main()
