"""Analytic privacy sweep with the Moments Accountant: how per-client
epsilon depends on noise sigma and update frequency — the mechanism behind
the paper's Table 3, without any training.

    PYTHONPATH=src python examples/privacy_sweep.py
"""
import numpy as np

from repro.core.accountant import compute_epsilon

Q = 0.136          # paper: q = B/|D_k|
DELTA = 1e-5
SIGMAS = (0.5, 1.0, 1.5, 2.0)
# update counts emergent from the tier clocks at alpha=0.2 (c.f. Fig. 5:
# high-end 62%, mid 16%, low-end <14%) over a 300-update async run,
# x ~7 DP steps per round
TIER_UPDATES = {"HW_T1": 9, "HW_T2": 11, "HW_T3": 26, "HW_T4": 120,
                "HW_T5": 134}
STEPS_PER_UPDATE = 7


def main():
    print(f"q={Q} delta={DELTA}  (paper Sec. 4.1.4)")
    header = "tier     updates | " + " | ".join(f"sig={s:<4}" for s in SIGMAS)
    print(header)
    print("-" * len(header))
    eps_by_sigma = {}
    for tier, ups in TIER_UPDATES.items():
        row = []
        for s in SIGMAS:
            eps = compute_epsilon(Q, s, ups * STEPS_PER_UPDATE, DELTA)
            row.append(eps)
            eps_by_sigma.setdefault(s, []).append(eps)
        print(f"{tier}  {ups:7d} | " + " | ".join(f"{e:7.2f}" for e in row))
    print("\nper-sigma disparity (max eps / min eps):")
    for s, es in eps_by_sigma.items():
        print(f"  sigma={s}: {max(es)/min(es):.1f}x "
              f"(paper reports ~5-6x at alpha>=0.4)")
    # FedAvg reference: uniform participation, ~60 rounds
    print("\nFedAvg uniform reference (60 rounds x 7 steps):")
    for s in SIGMAS:
        print(f"  sigma={s}: eps={compute_epsilon(Q, s, 420, DELTA):.2f} "
              f"on every tier")


if __name__ == "__main__":
    main()
