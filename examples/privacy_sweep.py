"""Privacy sweep over the paper's noise grid, two ways:

1. **Analytic** (default, instant): the Moments Accountant table behind
   the paper's Table 3 — per-tier epsilon as a function of sigma and the
   emergent update frequencies, no training.
2. **Measured** (``--train``): a TRAINED sigma sweep through ONE
   ``repro.api.Session`` — reduced-scale FedAsync runs whose per-tier
   epsilons come out of the actual RunLogs.  The session keeps the
   dataset partitions and the compiled cohort step warm across the grid
   (the step takes the noise scale as a runtime argument), so the four
   sigma points cost ONE testbed generation and ONE XLA compile — this
   script used to be exactly the kind of per-point ``run_experiment``
   loop the Session API deletes.

    PYTHONPATH=src python examples/privacy_sweep.py            # analytic
    PYTHONPATH=src python examples/privacy_sweep.py --train    # + measured
"""
import argparse

from repro.core.accountant import compute_epsilon

Q = 0.136          # paper: q = B/|D_k|
DELTA = 1e-5
SIGMAS = (0.5, 1.0, 1.5, 2.0)
# update counts emergent from the tier clocks at alpha=0.2 (c.f. Fig. 5:
# high-end 62%, mid 16%, low-end <14%) over a 300-update async run,
# x ~7 DP steps per round
TIER_UPDATES = {"HW_T1": 9, "HW_T2": 11, "HW_T3": 26, "HW_T4": 120,
                "HW_T5": 134}
STEPS_PER_UPDATE = 7


def analytic():
    print(f"q={Q} delta={DELTA}  (paper Sec. 4.1.4)")
    header = "tier     updates | " + " | ".join(f"sig={s:<4}" for s in SIGMAS)
    print(header)
    print("-" * len(header))
    eps_by_sigma = {}
    for tier, ups in TIER_UPDATES.items():
        row = []
        for s in SIGMAS:
            eps = compute_epsilon(Q, s, ups * STEPS_PER_UPDATE, DELTA)
            row.append(eps)
            eps_by_sigma.setdefault(s, []).append(eps)
        print(f"{tier}  {ups:7d} | " + " | ".join(f"{e:7.2f}" for e in row))
    print("\nper-sigma disparity (max eps / min eps):")
    for s, es in eps_by_sigma.items():
        print(f"  sigma={s}: {max(es)/min(es):.1f}x "
              f"(paper reports ~5-6x at alpha>=0.4)")
    # FedAvg reference: uniform participation, ~60 rounds
    print("\nFedAvg uniform reference (60 rounds x 7 steps):")
    for s in SIGMAS:
        print(f"  sigma={s}: eps={compute_epsilon(Q, s, 420, DELTA):.2f} "
              f"on every tier")


def trained(max_updates: int):
    from repro.api import ExperimentSpec, RunBudget, Session, StrategySpec
    from repro.core.testbed import TestbedConfig
    from repro.data.synthetic_ser import SERDataConfig

    spec = ExperimentSpec(
        testbed=TestbedConfig(use_dp=True, sigma=SIGMAS[0], batch_size=64,
                              data=SERDataConfig(n_total=2940), seed=0),
        strategy=StrategySpec("fedasync", alpha=0.2),
        run=RunBudget(max_updates=max_updates, eval_every=20))
    session = Session()
    print(f"\nmeasured sigma sweep (FedAsync alpha=0.2, "
          f"{max_updates} updates, one warm session) ...")
    result = session.sweep(spec, axes={"testbed.sigma": list(SIGMAS)})
    for point, log, wall in zip(result.points, result.logs, result.wall_s):
        eps = {t: (v[-1] if v else 0.0)
               for t, v in log.eps_trajectory.items()}
        disp = (max(eps.values()) / max(min(eps.values()), 1e-9)
                if eps else 0.0)
        by_tier = " ".join(f"{t.split('_')[1]}={e:.1f}"
                           for t, e in sorted(eps.items()))
        print(f"  sigma={point['testbed.sigma']}: eps {by_tier} "
              f"(disparity {disp:.1f}x, acc {log.global_acc[-1]:.3f}, "
              f"{wall:.1f}s)")
    print(f"  session cache telemetry: {session.stats()}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--train", action="store_true",
                    help="also run the measured (trained) sigma sweep "
                         "through one Session")
    ap.add_argument("--max-updates", type=int, default=120)
    args = ap.parse_args()
    analytic()
    if args.train:
        trained(args.max_updates)


if __name__ == "__main__":
    main()
