"""End-to-end driver: the paper's full experiment grid on the simulated
heterogeneous testbed (Speech Emotion Recognition, DP-SGD, Moments
Accountant), driven through the declarative API.

    PYTHONPATH=src python examples/fl_ser_tradeoff.py             # reduced
    PYTHONPATH=src python examples/fl_ser_tradeoff.py --full      # paper scale
    PYTHONPATH=src python examples/fl_ser_tradeoff.py --backend legacy

One ``repro.api.Session`` owns the whole grid: the FedAvg reference run
and the FedAsync alpha sweep share the generated dataset, the device
arenas and the compiled cohort step (this script used to loop
``run_experiment`` and pay the full testbed rebuild per point).  Each
scenario is an ``ExperimentSpec``; ``session.sweep`` runs the alpha axis
and its ``SweepResult.table()`` is the efficiency/fairness/privacy
summary (paper Sec. 4.2.4).  Results land in
results/example_tradeoff.json with every run's full spec as provenance.
"""
import argparse
import json
import os

import numpy as np

from repro.api import ExperimentSpec, RunBudget, Session, StrategySpec
from repro.core.testbed import TestbedConfig
from repro.data.synthetic_ser import SERDataConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale data (5882 clips, B=128)")
    ap.add_argument("--sigma", type=float, default=1.0)
    ap.add_argument("--target", type=float, default=0.75)
    ap.add_argument("--backend", choices=("cohort", "legacy"),
                    default="cohort", help="execution path (engine)")
    ap.add_argument("--window", type=float, default=0.0,
                    help="cohort staleness window in virtual seconds")
    args = ap.parse_args()

    from repro.engine import EngineConfig
    engine = EngineConfig(
        staleness_window=args.window if args.backend == "cohort" else 0.0)

    data = SERDataConfig() if args.full else SERDataConfig(n_total=2940)
    bsz = 128 if args.full else 64
    base = ExperimentSpec(
        testbed=TestbedConfig(use_dp=True, sigma=args.sigma,
                              batch_size=bsz, data=data, seed=0),
        strategy=StrategySpec("fedavg"),
        run=RunBudget(rounds=40, max_updates=400, eval_every=1,
                      target_acc=args.target),
        engine=engine,
        backend=args.backend,
    )
    session = Session()
    out = {"sigma": args.sigma, "backend": args.backend, "runs": {},
           "spec": base.to_dict()}

    print(f"[driver] FedAvg to {args.target:.0%} ({args.backend} backend) ...")
    _, log_avg = session.run(base)
    t_avg = log_avg.time_to_accuracy(args.target)
    out["runs"]["fedavg"] = {
        "time_to_target_s": t_avg, "acc": log_avg.global_acc[-1],
        "eps": {t: v[-1] for t, v in log_avg.eps_trajectory.items()},
    }
    print(f"  time-to-target {t_avg and round(t_avg)}s "
          f"acc {log_avg.global_acc[-1]:.3f}")

    # the alpha axis, one warm sweep: the session reuses the dataset,
    # arenas and compiled step the FedAvg run just built
    alphas = (0.2, 0.4, 0.6)
    print(f"[driver] FedAsync alpha sweep {alphas} (warm session) ...")
    result = session.sweep(
        ExperimentSpec(
            testbed=base.testbed, backend=base.backend, engine=base.engine,
            strategy=StrategySpec("fedasync", alpha=0.4),
            run=RunBudget(max_updates=400, eval_every=5,
                          target_acc=args.target)),
        axes={"strategy": [StrategySpec("fedasync", alpha=a)
                           for a in alphas]})

    for alpha, (spec, log) in zip(alphas, result):
        t = log.time_to_accuracy(args.target)
        fr = log.fairness()
        out["runs"][f"fedasync_a{alpha}"] = {
            "time_to_target_s": t, "acc": log.global_acc[-1],
            "speedup_vs_fedavg": (t_avg / t) if (t and t_avg) else None,
            "participation_pct": fr["participation_pct"],
            "privacy_disparity": fr["privacy_disparity"],
            "eps": {k: (v[-1] if v else 0)
                    for k, v in log.eps_trajectory.items()},
            "staleness": {k: float(np.mean(v)) for k, v in
                          log.staleness.items() if v},
            "spec": spec.to_dict(),
        }
        print(f"  alpha={alpha}: time-to-target {t and round(t)}s "
              f"speedup {t_avg and t and round(t_avg / t, 1)}x "
              f"high-end PP "
              f"{fr['participation_pct'].get('HW_T5', 0):.0f}%+"
              f"{fr['participation_pct'].get('HW_T4', 0):.0f}% "
              f"eps-disparity {fr['privacy_disparity']:.1f}x")

    out["sweep_table"] = result.table()
    out["session_stats"] = session.stats()
    print(f"[driver] session cache telemetry: {session.stats()}")

    os.makedirs("results", exist_ok=True)
    with open("results/example_tradeoff.json", "w") as f:
        json.dump(out, f, indent=1, default=float)
    print("[driver] wrote results/example_tradeoff.json")


if __name__ == "__main__":
    main()
