"""End-to-end driver: the paper's full experiment grid on the simulated
heterogeneous testbed (Speech Emotion Recognition, DP-SGD, Moments
Accountant).

    PYTHONPATH=src python examples/fl_ser_tradeoff.py             # reduced
    PYTHONPATH=src python examples/fl_ser_tradeoff.py --full      # paper scale
    PYTHONPATH=src python examples/fl_ser_tradeoff.py --engine legacy

Runs on the cohort-batched execution engine (repro.engine) by default;
``--engine legacy`` selects the original per-client event loop and
``--window`` sets the engine's staleness-tolerance batching window
(virtual seconds; 0 = exact legacy semantics).

Trains the paper's SER CNN federated for tens of rounds x 5 clients x ~7
DP-SGD steps per round (several hundred to thousands of optimizer steps),
sweeping aggregation strategy and noise, then prints the
efficiency/fairness/privacy summary (paper Sec. 4.2.4) and writes JSON to
results/example_tradeoff.json.
"""
import argparse
import json
import os

import numpy as np

from repro.core.testbed import TestbedConfig, run_experiment
from repro.data.synthetic_ser import SERDataConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale data (5882 clips, B=128)")
    ap.add_argument("--sigma", type=float, default=1.0)
    ap.add_argument("--target", type=float, default=0.75)
    ap.add_argument("--engine", choices=("cohort", "legacy"),
                    default="cohort")
    ap.add_argument("--window", type=float, default=0.0,
                    help="cohort staleness window in virtual seconds")
    args = ap.parse_args()

    engine_cfg = None
    if args.engine == "cohort" and args.window > 0:
        from repro.engine import EngineConfig
        engine_cfg = EngineConfig(staleness_window=args.window)
    run_kw = dict(engine=args.engine, engine_cfg=engine_cfg)

    data = SERDataConfig() if args.full else SERDataConfig(n_total=2940)
    bsz = 128 if args.full else 64
    cfg = TestbedConfig(use_dp=True, sigma=args.sigma, batch_size=bsz,
                        data=data, seed=0)
    out = {"sigma": args.sigma, "engine": args.engine, "runs": {}}

    print(f"[driver] FedAvg to {args.target:.0%} ({args.engine} engine) ...")
    _, log_avg = run_experiment("fedavg", cfg, rounds=40,
                                target_acc=args.target, **run_kw)
    t_avg = log_avg.time_to_accuracy(args.target)
    out["runs"]["fedavg"] = {
        "time_to_target_s": t_avg, "acc": log_avg.global_acc[-1],
        "eps": {t: v[-1] for t, v in log_avg.eps_trajectory.items()},
    }
    print(f"  time-to-target {t_avg and round(t_avg)}s "
          f"acc {log_avg.global_acc[-1]:.3f}")

    for alpha in (0.2, 0.4, 0.6):
        print(f"[driver] FedAsync alpha={alpha} ...")
        _, log = run_experiment("fedasync", cfg, max_updates=400,
                                alpha=alpha, eval_every=5,
                                target_acc=args.target, **run_kw)
        t = log.time_to_accuracy(args.target)
        fr = log.fairness()
        out["runs"][f"fedasync_a{alpha}"] = {
            "time_to_target_s": t, "acc": log.global_acc[-1],
            "speedup_vs_fedavg": (t_avg / t) if (t and t_avg) else None,
            "participation_pct": fr["participation_pct"],
            "privacy_disparity": fr["privacy_disparity"],
            "eps": {k: (v[-1] if v else 0)
                    for k, v in log.eps_trajectory.items()},
            "staleness": {k: float(np.mean(v)) for k, v in
                          log.staleness.items() if v},
        }
        print(f"  time-to-target {t and round(t)}s "
              f"speedup {t_avg and t and round(t_avg / t, 1)}x "
              f"high-end PP "
              f"{fr['participation_pct'].get('HW_T5', 0):.0f}%+"
              f"{fr['participation_pct'].get('HW_T4', 0):.0f}% "
              f"eps-disparity {fr['privacy_disparity']:.1f}x")

    os.makedirs("results", exist_ok=True)
    with open("results/example_tradeoff.json", "w") as f:
        json.dump(out, f, indent=1, default=float)
    print("[driver] wrote results/example_tradeoff.json")


if __name__ == "__main__":
    main()
