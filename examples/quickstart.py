"""Quickstart: 5 heterogeneous clients, DP-SGD, FedAvg vs FedAsync.

    PYTHONPATH=src python examples/quickstart.py

Runs a small simulated testbed (~2 min on CPU) and prints the trade-off
triangle the paper is about: convergence time, participation share, and
per-client privacy loss.
"""
import numpy as np

from repro.core.testbed import TestbedConfig, run_experiment
from repro.data.synthetic_ser import SERDataConfig


def main():
    cfg = TestbedConfig(use_dp=True, sigma=1.0, batch_size=64,
                        data=SERDataConfig(n_total=1600), seed=0)

    print("== FedAvg (synchronous, straggler-bound) ==")
    _, log_avg = run_experiment("fedavg", cfg, rounds=6)
    print(f"  accuracy: {log_avg.global_acc[-1]:.3f}  "
          f"virtual time: {log_avg.times[-1]:.0f}s")
    eps = {t: v[-1] for t, v in log_avg.eps_trajectory.items()}
    print(f"  eps (uniform): {eps['HW_T1']:.2f} on every tier")

    print("== FedAsync (alpha=0.4, staleness-aware) ==")
    _, log_as = run_experiment("fedasync", cfg, max_updates=60, alpha=0.4,
                               eval_every=5)
    print(f"  accuracy: {log_as.global_acc[-1]:.3f}  "
          f"virtual time: {log_as.times[-1]:.0f}s")
    print(f"  updates per tier: {log_as.update_counts}")
    eps = {t: (v[-1] if v else 0) for t, v in log_as.eps_trajectory.items()}
    print("  eps per tier:", {t: round(e, 2) for t, e in eps.items()})
    fr = log_as.fairness()
    print(f"  privacy disparity (max/min eps): {fr['privacy_disparity']:.1f}x")
    print(f"  Jain participation index: {fr['jain_participation']:.2f}")


if __name__ == "__main__":
    main()
