#!/usr/bin/env bash
# Launch hygiene for accelerator runs (olmax/HomebrewNLP idiom):
#   scripts/launch.sh <entrypoint.py|-m module> [args...]
#
# - tcmalloc, when present, replaces glibc malloc (host-side arena
#   assembly and numpy batch planning allocate heavily);
# - TF_CPP_MIN_LOG_LEVEL=4 silences the TF/XLA dataset warning spam;
# - --xla_step_marker_location=1 puts the step marker on the outer while
#   loop (0 = program entry) so profiles attribute whole cohort steps —
#   TPU-only flag (CPU/GPU XLA builds abort on unknown flags), added when
#   a TPU is detected or REPRO_TPU=1 forces it;
# - REPRO_HOST_DEVICES=N forces N host platform devices (the forced-mesh
#   CI/bench topology; unset = real device count);
# - REPRO_PALLAS_INTERPRET=0/1 overrides the Pallas interpret-mode policy
#   (see src/repro/kernels/common.py) — exported through untouched.
set -euo pipefail

TCMALLOC=/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4
if [[ -z "${LD_PRELOAD:-}" && -e "$TCMALLOC" ]]; then
  export LD_PRELOAD="$TCMALLOC"                 # faster malloc
  export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=60000000000
fi
export TF_CPP_MIN_LOG_LEVEL=4                   # no dataset warnings

XLA_FLAGS="${XLA_FLAGS:-}"
if [[ -n "${REPRO_TPU:-}" || -e /dev/accel0 || -c /dev/vfio/0 ]]; then
  XLA_FLAGS="--xla_step_marker_location=1 ${XLA_FLAGS}"  # 0 = entry; 1 = outer while
fi
if [[ -n "${REPRO_HOST_DEVICES:-}" ]]; then
  XLA_FLAGS="--xla_force_host_platform_device_count=${REPRO_HOST_DEVICES} ${XLA_FLAGS}"
fi
export XLA_FLAGS

REPO_ROOT="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
export PYTHONPATH="${REPO_ROOT}/src${PYTHONPATH:+:${PYTHONPATH}}"

exec python "$@"
