"""gemma2-2b [dense] — local+global alternating attention, logit softcaps
[arXiv:2408.00118]."""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="gemma2-2b", family="dense", source="arXiv:2408.00118",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, d_head=256,
    d_ff=9216, vocab=256000,
    local_global_pattern=True, sliding_window=4096,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    tie_embeddings=True, mlp_variant="geglu", rope_theta=10000.0,
)

# long_500k variant: every layer local (global layers fall back to the
# 4096-token sliding window) -> sub-quadratic decode over a window-bounded
# KV cache.  See DESIGN.md section 5.
CONFIG_LONG = CONFIG.replace(local_global_pattern=False, sliding_window=4096)
