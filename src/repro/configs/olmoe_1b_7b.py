"""olmoe-1b-7b [moe] — 64 experts top-8 [arXiv:2409.02060]."""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="olmoe-1b-7b", family="moe", source="arXiv:2409.02060",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab=50304,
    n_experts=64, top_k=8, n_shared_experts=0, d_expert=1024,
)
