"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242]."""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="zamba2-1.2b", family="hybrid", source="arXiv:2411.15242",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000,
    ssm_state=64, ssm_head_dim=64, ssm_conv=4, ssm_expand=2, ssm_chunk=128,
    attn_every=6,   # one shared attn+MLP application per 6 mamba layers
)
