"""smollm-360m [dense] — llama-arch small [hf:HuggingFaceTB/SmolLM-135M]."""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="smollm-360m", family="dense", source="hf:HuggingFaceTB/SmolLM-135M",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
    d_ff=2560, vocab=49152, tie_embeddings=True,
)
