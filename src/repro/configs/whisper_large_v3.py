"""whisper-large-v3 [audio] — enc-dec, conv frontend stubbed
[arXiv:2212.04356]."""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="whisper-large-v3", family="audio", source="arXiv:2212.04356",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab=51866,
    n_enc_layers=32, enc_frames=1500, mlp_variant="gelu",
    max_seq=32768,   # assignment decode_32k shape (whisper native ctx is 448)
)
