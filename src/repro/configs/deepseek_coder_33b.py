"""deepseek-coder-33b [dense] — llama-arch [arXiv:2401.14196]."""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="deepseek-coder-33b", family="dense", source="arXiv:2401.14196",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=19200, vocab=32256, rope_theta=100000.0,
)
