"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP stub
[hf:microsoft/Phi-3-vision-128k-instruct]."""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="phi-3-vision-4.2b", family="vlm",
    source="hf:microsoft/Phi-3-vision-128k-instruct",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32064,
    n_patches=576,   # stub ViT/projector output length (336px/14 -> 24x24)
    rope_theta=10000.0,
)
