"""The paper's own model: lightweight 1D CNN for Speech Emotion
Recognition (paper Sec. 3.1), trained federated with DP-SGD."""
from repro.models.ser_cnn import SERConfig

CONFIG = SERConfig()
