"""xlstm-350m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517]."""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="xlstm-350m", family="ssm", source="arXiv:2405.04517",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0,        # assignment: no separate FFN; mLSTM carries up/down proj
    vocab=50304,
    slstm_every=8,  # xLSTM[7:1]: one sLSTM closes each period of 8
    ssm_chunk=128,
)
