"""Config registry: --arch <id> resolution for launcher/dry-run/tests."""
from importlib import import_module

_MODULES = {
    "gemma2-2b": "gemma2_2b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "whisper-large-v3": "whisper_large_v3",
    "zamba2-1.2b": "zamba2_1_2b",
    "xlstm-350m": "xlstm_350m",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "smollm-360m": "smollm_360m",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "llama3.2-3b": "llama3_2_3b",
    "phi-3-vision-4.2b": "phi3_vision_4_2b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str, long_variant: bool = False):
    mod = import_module(f"repro.configs.{_MODULES[arch_id]}")
    if long_variant and hasattr(mod, "CONFIG_LONG"):
        return mod.CONFIG_LONG
    return mod.CONFIG


# long_500k support per DESIGN.md section 5: SSM/hybrid always; gemma2 via
# its all-local variant; everything else skipped (full attention).
LONG_CONTEXT_ARCHS = ("gemma2-2b", "zamba2-1.2b", "xlstm-350m")
