"""Flat-npz checkpointing with step metadata and sharding-aware gather.

Layout: <dir>/step_<N>.npz holding flattened leaves keyed by joined tree
paths, plus a _meta json entry (step, strategy, per-client epsilon, etc.).
On restore, arrays are reassembled into the template pytree and cast to
the template's dtypes.  For sharded arrays the save path gathers to host
(process 0) first — fine at simulation scale; a real deployment would
swap in async per-shard writes behind the same interface.

Durability contract (the engine's crash-resume path in
:mod:`repro.engine.resilience` relies on all three):

* **atomic publish** — the npz is written to a ``.tmp`` sibling, fsynced,
  then ``os.replace``d into place and the directory entry fsynced; a
  crash mid-save leaves at most a stale ``.tmp``, never a torn
  ``step_*.npz`` (``latest_step`` only ever sees complete files);
* **bounded retention** — ``keep_last=N`` prunes the oldest steps after
  each successful publish, so a long checkpointed run cannot fill the
  disk (pruning happens strictly AFTER the new step is durable);
* **collision-free keys** — tree-path components are escaped before
  joining with ``/`` (``{"a": {"b": x}}`` and ``{"a/b": x}`` flatten to
  the distinct keys ``a/b`` and ``a\\/b``), so sibling names containing
  a slash can no longer alias another leaf's entry.
"""
from __future__ import annotations

import json
import os
import re
from typing import Optional

import jax
import numpy as np


def _escape(component: str) -> str:
    """Escape one tree-path component so ``/``-joined keys are injective
    (a literal backslash escapes first, then the separator)."""
    return component.replace("\\", "\\\\").replace("/", "\\/")


def _path_key(path) -> str:
    """The npz key for one jax tree path — escaped components joined
    with ``/``.  Shared by save/restore/load so the escaping cannot
    drift between the writer and the readers."""
    return "/".join(
        _escape(str(getattr(p, "key", getattr(p, "idx", p)))) for p in path)


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _path_key(path)
        if key in flat:
            raise ValueError(
                f"checkpoint tree flattens two leaves to key {key!r}")
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _fsync_dir(directory: str):
    fd = os.open(directory, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save(directory: str, step: int, tree, meta: Optional[dict] = None,
         keep_last: Optional[int] = None):
    """Write ``<directory>/step_<N>.npz`` atomically and durably; with
    ``keep_last=N`` prune all but the newest N steps afterwards."""
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    flat["_meta"] = np.frombuffer(
        json.dumps({"step": step, **(meta or {})}).encode(), dtype=np.uint8
    )
    path = os.path.join(directory, f"step_{step:08d}.npz")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    _fsync_dir(directory)
    if keep_last is not None:
        if keep_last < 1:
            raise ValueError(f"keep_last must be >= 1: {keep_last!r}")
        for old in _step_files(directory)[:-keep_last]:
            os.remove(os.path.join(directory, old))
    return path


def _step_files(directory: str) -> list:
    """Completed checkpoint filenames, oldest first (.tmp leftovers of a
    crashed save never match)."""
    return sorted(fn for fn in os.listdir(directory)
                  if re.match(r"step_(\d+)\.npz$", fn))


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for fn in os.listdir(directory)
        if (m := re.match(r"step_(\d+)\.npz$", fn))
    ]
    return max(steps) if steps else None


def load_flat(directory: str, step: Optional[int] = None):
    """Template-free read: returns ``(flat, meta)`` where ``flat`` maps
    escaped tree-path keys to host numpy arrays — the engine's resume
    path reassembles its heterogeneous state from this (the live run
    provides the templates)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    with np.load(os.path.join(directory, f"step_{step:08d}.npz")) as data:
        meta = json.loads(bytes(data["_meta"]).decode())
        flat = {k: data[k] for k in data.files if k != "_meta"}
    return flat, meta


def restore(directory: str, template, step: Optional[int] = None):
    """Returns (tree, meta).  ``template`` provides treedef + dtypes."""
    flat, meta = load_flat(directory, step)
    leaves, _ = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for path, leaf in leaves:
        arr = flat[_path_key(path)]
        out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out
    )
    return tree, meta
