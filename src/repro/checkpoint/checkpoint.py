"""Flat-npz checkpointing with step metadata and sharding-aware gather.

Layout: <dir>/step_<N>.npz holding flattened leaves keyed by joined tree
paths, plus a _meta json entry (step, strategy, per-client epsilon, etc.).
On restore, arrays are reassembled into the template pytree and cast to
the template's dtypes.  For sharded arrays the save path gathers to host
(process 0) first — fine at simulation scale; a real deployment would
swap in async per-shard writes behind the same interface.
"""
from __future__ import annotations

import json
import os
import re
from typing import Optional

import jax
import numpy as np


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save(directory: str, step: int, tree, meta: Optional[dict] = None):
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    flat["_meta"] = np.frombuffer(
        json.dumps({"step": step, **(meta or {})}).encode(), dtype=np.uint8
    )
    path = os.path.join(directory, f"step_{step:08d}.npz")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for fn in os.listdir(directory)
        if (m := re.match(r"step_(\d+)\.npz$", fn))
    ]
    return max(steps) if steps else None


def restore(directory: str, template, step: Optional[int] = None):
    """Returns (tree, meta).  ``template`` provides treedef + dtypes."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    data = np.load(os.path.join(directory, f"step_{step:08d}.npz"))
    meta = json.loads(bytes(data["_meta"]).decode())

    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = data[key]
        out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out
    )
    return tree, meta
