"""Durable flat-npz checkpoint store (see checkpoint.py for the atomic
publish / retention / key-escaping contract)."""
from repro.checkpoint.checkpoint import (  # noqa: F401
    latest_step, load_flat, restore, save)

__all__ = ["save", "restore", "load_flat", "latest_step"]
