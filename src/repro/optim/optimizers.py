"""Pure-JAX optimizers (no optax in this container).

Minimal, stateless-API optimizers used by both the FL simulation (client
Adam, paper Sec. 3.1: lr=1e-3) and the large-architecture SPMD training
path.  State is a pytree shaped like the params, so it shards identically.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


@dataclass(frozen=True)
class Adam:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0

    def init(self, params) -> AdamState:
        z = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return AdamState(step=jnp.zeros((), jnp.int32), mu=z, nu=jax.tree_util.tree_map(jnp.copy, z))

    def update(self, grads, state: AdamState, params):
        step = state.step + 1
        t = step.astype(jnp.float32)
        mu = jax.tree_util.tree_map(
            lambda m, g: self.b1 * m + (1 - self.b1) * g.astype(jnp.float32), state.mu, grads
        )
        nu = jax.tree_util.tree_map(
            lambda v, g: self.b2 * v + (1 - self.b2) * jnp.square(g.astype(jnp.float32)),
            state.nu,
            grads,
        )
        bc1 = 1 - self.b1 ** t
        bc2 = 1 - self.b2 ** t

        def upd(p, m, v):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - self.lr * u).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, mu, nu)
        return new_params, AdamState(step=step, mu=mu, nu=nu)


class SGDState(NamedTuple):
    mom: dict


@dataclass(frozen=True)
class SGD:
    lr: float = 0.01
    momentum: float = 0.0

    def init(self, params) -> SGDState:
        return SGDState(
            mom=jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        )

    def update(self, grads, state: SGDState, params):
        if self.momentum:
            mom = jax.tree_util.tree_map(
                lambda m, g: self.momentum * m + g.astype(jnp.float32), state.mom, grads
            )
            eff = mom
        else:
            mom, eff = state.mom, grads
        new_params = jax.tree_util.tree_map(
            lambda p, g: (p.astype(jnp.float32) - self.lr * g.astype(jnp.float32)).astype(p.dtype),
            params,
            eff,
        )
        return new_params, SGDState(mom=mom)


def make_optimizer(name: str, **kw):
    if name == "adam":
        return Adam(**kw)
    if name == "sgd":
        return SGD(**kw)
    raise ValueError(name)
