"""Shared kernel plumbing: interpret-mode selection.

Kernels TARGET TPU (pl.pallas_call + BlockSpec VMEM tiling); on this
CPU-only container they are validated in interpret=True mode, which
executes the kernel body in Python for correctness (assignment: 'VALIDATE
them in interpret=True mode').
"""
import jax

def interpret_mode() -> bool:
    return jax.default_backend() != "tpu"
