"""Shared kernel-layer policy: when do Pallas bodies run interpreted?

Pallas kernels compile to real accelerator programs on TPU and GPU
(Mosaic / Triton lowering).  On CPU-only hosts the bodies must run under
``interpret=True`` (pure-Python emulation) or ``pallas_call`` fails to
lower.  The old policy here was ``backend != "tpu"`` — which silently
ran the *interpreted* body on GPU, orders of magnitude slower than the
jnp path the kernels are meant to beat.

Resolution order for :func:`interpret_mode`:

1. process-level override set via :func:`set_interpret_override`
   (tests, benchmarks),
2. the ``REPRO_PALLAS_INTERPRET`` environment variable (``1/true/yes``
   forces interpreted, ``0/false/no`` forces compiled),
3. backend capability: compiled on TPU and GPU (``tpu``/``gpu``/
   ``cuda``/``rocm``), interpreted elsewhere (CPU).

:func:`interpret_info` reports the resolved mode *and* which of the
three sources decided it — benchmark rows and ``RunLog.engine_stats``
record this so a silent interpreted fallback on a compiled-capable
backend is visible (``summarize.py --check-engine`` fails on it).
"""
from __future__ import annotations

import os
from typing import Optional

import jax

# Backends whose Pallas lowering produces a real compiled kernel.
_COMPILED_BACKENDS = ("tpu", "gpu", "cuda", "rocm")

_ENV_VAR = "REPRO_PALLAS_INTERPRET"
_TRUE = ("1", "true", "yes", "on")
_FALSE = ("0", "false", "no", "off")

# Process-level override (None = defer to env / backend capability).
_override: Optional[bool] = None


def set_interpret_override(mode: Optional[bool]) -> Optional[bool]:
    """Force interpret mode for this process (``None`` clears the
    override).  Returns the previous override so tests can restore it."""
    global _override
    prev = _override
    _override = mode
    return prev


def _env_override() -> Optional[bool]:
    raw = os.environ.get(_ENV_VAR)
    if raw is None:
        return None
    v = raw.strip().lower()
    if v in _TRUE:
        return True
    if v in _FALSE:
        return False
    raise ValueError(
        f"{_ENV_VAR}={raw!r}: expected one of {_TRUE + _FALSE}")


def interpret_mode() -> bool:
    """True when Pallas bodies should run interpreted on this host."""
    return interpret_info()["interpret"]


def interpret_info() -> dict:
    """Resolved interpret decision with provenance.

    Returns ``{"backend": str, "interpret": bool, "source": str}`` where
    ``source`` is ``"override"``, ``"env"``, or ``"auto"`` (backend
    capability).
    """
    backend = jax.default_backend()
    if _override is not None:
        return {"backend": backend, "interpret": _override,
                "source": "override"}
    env = _env_override()
    if env is not None:
        return {"backend": backend, "interpret": env, "source": "env"}
    return {"backend": backend,
            "interpret": backend not in _COMPILED_BACKENDS,
            "source": "auto"}
