"""jit'd wrapper for the sliding-window flash decode kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.common import interpret_mode
from repro.kernels.flash_attn.flash_attn import flash_decode as _raw


@partial(jax.jit, static_argnames=("window", "softcap", "ts"))
def flash_decode(q, k, v, pos, *, window: int = 0, softcap: float = 0.0,
                 ts: int = 512):
    return _raw(q, k, v, pos, window=window, softcap=softcap, ts=ts,
                interpret=interpret_mode())
