"""Sliding-window flash-attention DECODE kernel (one query token against a
long KV cache) — the long_500k hot loop.

TPU adaptation: the KV cache is swept in (TS, Dh) VMEM tiles with the
classic online-softmax recurrence (running max m, denominator l, rescaled
accumulator in the output block).  GQA is handled in the BlockSpec index
map (kv head = q head // rep), so repeated KV heads are never materialized
— on a real TPU this kernel is HBM-bandwidth-bound and the tile sweep is
what the roofline's memory term prices.

Grid: (B, H, nS).  Per-step live VMEM at defaults (TS=512, Dh<=256):
    k/v tiles 2*512*256*4B = 1 MiB + scratch — comfortably under budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TS = 512


def _flash_decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                         *, ts: int, scale: float, window: int,
                         softcap: float):
    s_idx = pl.program_id(2)

    q = q_ref[0, 0].astype(jnp.float32)                   # (Dh,)
    k = k_ref[0, :, 0].astype(jnp.float32)                # (TS, Dh)
    v = v_ref[0, :, 0].astype(jnp.float32)                # (TS, Dh)
    pos = pos_ref[0]

    logits = jnp.sum(k * q[None, :], axis=1) * scale      # (TS,)
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    kv_pos = s_idx * ts + jax.lax.iota(jnp.int32, ts)
    eff_w = window if window > 0 else (1 << 30)
    mask = (kv_pos <= pos) & (kv_pos > pos - eff_w)
    logits = jnp.where(mask, logits, -1e30)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    m_prev, l_prev = m_ref[0], l_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(logits))
    p = jnp.exp(logits - m_new)                           # (TS,)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p)
    acc = o_ref[0, 0].astype(jnp.float32) * corr + jnp.sum(
        p[:, None] * v, axis=0)
    m_ref[0], l_ref[0] = m_new, l_new
    o_ref[0, 0] = acc.astype(o_ref.dtype)


def flash_decode(q, k, v, pos, *, window: int = 0, softcap: float = 0.0,
                 ts: int = DEFAULT_TS, interpret: bool = True):
    """q: (B, H, Dh); k/v: (B, S, Hkv, Dh); pos: (B,) -> (B, H, Dh)."""
    B, S, Hkv, Dh = k.shape
    H = q.shape[1]
    rep = H // Hkv
    ts = min(ts, S)
    grid = (B, H, pl.cdiv(S, ts))
    scale = Dh ** -0.5

    kern = functools.partial(
        _flash_decode_kernel, ts=ts, scale=scale, window=window,
        softcap=softcap,
    )
    # NOTE: pallas_call maps outputs in KERNEL-SIGNATURE order — the
    # kernel declares (..., o_ref, m_ref, l_ref), so the second output is
    # the running max m and the third is the denominator l
    acc, m, l = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, s: (b,)),                 # pos
            pl.BlockSpec((1, 1, Dh), lambda b, h, s: (b, h, 0)),      # q
            pl.BlockSpec((1, ts, 1, Dh), lambda b, h, s: (b, s, h // rep, 0)),
            pl.BlockSpec((1, ts, 1, Dh), lambda b, h, s: (b, s, h // rep, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Dh), lambda b, h, s: (b, h, 0)),      # acc
            pl.BlockSpec((1,), lambda b, h, s: (b * H + h,)),         # l
            pl.BlockSpec((1,), lambda b, h, s: (b * H + h,)),         # m
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Dh), q.dtype),
            jax.ShapeDtypeStruct((B * H,), jnp.float32),
            jax.ShapeDtypeStruct((B * H,), jnp.float32),
        ],
        interpret=interpret,
    )(pos, q, k, v)
    return (acc.astype(jnp.float32)
            / jnp.maximum(l.reshape(B, H, 1), 1e-30)).astype(q.dtype)
