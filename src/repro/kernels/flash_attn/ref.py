"""Pure-jnp oracle for the flash decode kernel."""
import jax
import jax.numpy as jnp


def flash_decode_ref(q, k, v, pos, *, window: int = 0, softcap: float = 0.0):
    """q: (B, H, Dh); k/v: (B, S, Hkv, Dh); pos: (B,).  GQA via H % Hkv == 0.
    Returns (B, H, Dh) attention output over cache entries <= pos (and
    within the sliding window when window > 0)."""
    B, S, Hkv, Dh = k.shape
    H = q.shape[1]
    rep = H // Hkv
    kk = jnp.repeat(k, rep, axis=2) if rep > 1 else k
    vv = jnp.repeat(v, rep, axis=2) if rep > 1 else v
    logits = jnp.einsum("bhd,bshd->bhs", q, kk).astype(jnp.float32) * (Dh ** -0.5)
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    idx = jnp.arange(S)[None, None, :]
    cur = pos[:, None, None]
    eff_w = window if window > 0 else S + 1
    mask = (idx <= cur) & (idx > cur - eff_w)
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", probs, vv).astype(q.dtype)
