"""jit'd wrappers around the fused DP clip(+noise) kernels."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import interpret_mode
from repro.kernels.dp_clip.dp_clip import (
    DEFAULT_TB, DEFAULT_TD, cohort_scale_mean, sqnorms)


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("clip_norm",))
def dp_clip_mean_noise_cohort(g, clip_norm: float, noise_stddev=None, z=None):
    """g: (K, B, D) stacked per-example grads for a whole cohort ->
    (means (K, D), mean_norms (K,), clip_fractions (K,)) in ONE launch
    per pass over the member-major (K*Bp, Dp) matrix.

    When ``z`` ((K, D) standard-normal draws) is given, ``noise_stddev``
    (runtime float32 scalar — NOT baked into the compiled program) scales
    it inside the kernel's final-tile epilogue: means[m] += stddev * z[m].

    Inputs are zero-padded to tile multiples: padded rows have norm 0 and
    scale 1 so they contribute nothing; the member mean divides by the
    REAL B inside the kernel (inv_b), so no post-hoc rescale is needed.
    Zero-grad mask members (engine cohort padding) likewise produce a
    harmless all-zero mean row.
    """
    K, B, D = g.shape
    interp = interpret_mode()
    tb, td = min(DEFAULT_TB, B), min(DEFAULT_TD, D)
    gp = _pad_to(_pad_to(g, tb, 1), td, 2)          # (K, Bp, Dp)
    Bp, Dp = gp.shape[1], gp.shape[2]
    flat = gp.reshape(K * Bp, Dp)
    sq = sqnorms(flat, tb=tb, td=td, interpret=interp)
    norms = jnp.sqrt(sq)                            # (K*Bp,)
    scales = 1.0 / jnp.maximum(1.0, norms / clip_norm)
    if z is not None:
        z = _pad_to(z.astype(jnp.float32), td, 1)   # (K, Dp)
        stddev = jnp.asarray(noise_stddev, jnp.float32).reshape(1, 1)
    else:
        stddev = None
    means = cohort_scale_mean(flat, scales, k=K, inv_b=1.0 / B,
                              z=z, stddev=stddev,
                              tb=tb, td=td, interpret=interp)
    norms = norms.reshape(K, Bp)[:, :B]
    return (means[:, :D], jnp.mean(norms, axis=1),
            jnp.mean((norms > clip_norm).astype(jnp.float32), axis=1))


@partial(jax.jit, static_argnames=("clip_norm",))
def dp_clip_mean_flat(flat, clip_norm: float):
    """flat: (B, D) per-example grads -> (mean_clipped (D,), mean_norm,
    clip_fraction).  Single-member (K=1) view of the cohort op."""
    means, nrms, fracs = dp_clip_mean_noise_cohort(flat[None], clip_norm)
    return means[0], nrms[0], fracs[0]
