"""jit'd wrapper around the fused DP clip kernels."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import interpret_mode
from repro.kernels.dp_clip.dp_clip import scale_mean, sqnorms


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("clip_norm",))
def dp_clip_mean_flat(flat, clip_norm: float):
    """flat: (B, D) per-example grads -> (mean_clipped (D,), mean_norm,
    clip_fraction).  Two-pass fused kernel (see dp_clip.py).

    Inputs are zero-padded to tile multiples: padded rows have norm 0 and
    scale 1 so they contribute nothing; the batch mean uses the REAL B.
    """
    B, D = flat.shape
    interp = interpret_mode()
    tb = min(128, B) if B % min(128, B) == 0 else 128
    td = min(512, D) if D % min(512, D) == 0 else 512
    fp = _pad_to(_pad_to(flat, tb, 0), td, 1)
    sq = sqnorms(fp, tb=tb, td=td, interpret=interp)
    norms = jnp.sqrt(sq)                                    # (B_pad,)
    scales = 1.0 / jnp.maximum(1.0, norms / clip_norm)
    # the kernel's inv_b must be 1/B_real: rescale the padded-B mean
    mean = scale_mean(fp, scales, tb=tb, td=td, interpret=interp)
    mean = mean[:D] * (fp.shape[0] / B)
    norms = norms[:B]
    return mean, jnp.mean(norms), jnp.mean((norms > clip_norm).astype(jnp.float32))
