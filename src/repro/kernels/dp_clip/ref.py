"""Pure-jnp oracle for the fused DP clip kernel."""
import jax.numpy as jnp


def dp_clip_mean_flat_ref(flat, clip_norm: float):
    """flat: (B, D) per-example grads.  Returns (mean_clipped (D,),
    mean_pre_norm, clip_fraction) — paper Eq. 4 then the 1/|b| average."""
    norms = jnp.sqrt(jnp.sum(jnp.square(flat.astype(jnp.float32)), axis=1))
    scales = 1.0 / jnp.maximum(1.0, norms / clip_norm)
    mean = jnp.mean(flat * scales[:, None], axis=0)
    return mean, jnp.mean(norms), jnp.mean((norms > clip_norm).astype(jnp.float32))
