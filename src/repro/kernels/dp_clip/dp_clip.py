"""Fused per-sample gradient clip + accumulate (+ noise) — the DP-SGD
hot spot (what Opacus spends its time on), as two tiled Pallas TPU
kernels, cohort-aware so the engine launches ONE program per cohort step.

TPU adaptation (DESIGN.md sec 3): instead of Opacus' hook-based per-layer
GPU pass, the flattened per-example grad matrix — (B, D) for a single
client, (K*B, D) for a whole stacked cohort — is swept twice with
MXU/VPU-aligned VMEM tiles:

  pass 1 (sqnorm):  grid (nB, nD); each step reduces a (TB, TD) tile to a
                    (TB,) partial sum accumulated into the row norms.
  pass 2 (scale+mean+noise): grid (K, nD, nB); each step loads member
                    m's i-th (TB, TD) row tile, multiplies by the
                    per-sample scale min(1, C/||g_i||) broadcast from a
                    (TB,) slice, and accumulates the batch-mean into the
                    member's (1, TD) output row.  On the LAST row tile an
                    epilogue fuses the Gaussian-mechanism noise add:
                    out += stddev * z, with the stddev a (1, 1) runtime
                    scalar — sigma stays out of the compiled program so
                    one program serves the whole sigma sweep (PR-5
                    invariant).

Cohort padding composes with the engine's pow2 cohort padding: mask
members carry zero grads, so their rows clip to scale 1 and contribute
zero to their own (discarded) output row.

Tiles default to (128, 512) f32 = 256 KiB live VMEM per step — far under
the ~16 MiB/core budget, leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TB = 128
DEFAULT_TD = 512


def _sqnorm_kernel(flat_ref, out_ref):
    """grid (nB, nD): accumulate per-sample squared norms."""
    j = pl.program_id(1)
    tile = flat_ref[...].astype(jnp.float32)          # (TB, TD)
    partial = jnp.sum(tile * tile, axis=1)            # (TB,)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial


def _scale_mean_kernel(flat_ref, scale_ref, out_ref, *, inv_b: float):
    """grid (K, nD, nB): out[m, d] += sum_b scale[m*B+b] * flat[m*B+b, d] / B."""
    i = pl.program_id(2)
    tile = flat_ref[...].astype(jnp.float32)          # (TB, TD)
    scales = scale_ref[...]                           # (TB,)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += (jnp.sum(tile * scales[:, None], axis=0) * inv_b)[None, :]


def _scale_mean_noise_kernel(flat_ref, scale_ref, z_ref, std_ref, out_ref,
                             *, inv_b: float, n_b: int):
    """_scale_mean_kernel + fused Gaussian epilogue on the last row tile:
    out[m] += std * z[m], with std a runtime (1, 1) scalar."""
    i = pl.program_id(2)
    tile = flat_ref[...].astype(jnp.float32)          # (TB, TD)
    scales = scale_ref[...]                           # (TB,)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += (jnp.sum(tile * scales[:, None], axis=0) * inv_b)[None, :]

    @pl.when(i == n_b - 1)
    def _noise_epilogue():
        out_ref[...] += std_ref[0, 0] * z_ref[...].astype(jnp.float32)


def sqnorms(flat, *, tb: int = DEFAULT_TB, td: int = DEFAULT_TD,
            interpret: bool = True):
    """Per-row squared norms of a tile-aligned (R, D) matrix."""
    B, D = flat.shape
    tb, td = min(tb, B), min(td, D)
    grid = (pl.cdiv(B, tb), pl.cdiv(D, td))
    return pl.pallas_call(
        _sqnorm_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tb, td), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((tb,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.float32),
        interpret=interpret,
    )(flat)


def cohort_scale_mean(flat, scales, *, k: int, inv_b: float,
                      z=None, stddev=None,
                      tb: int = DEFAULT_TB, td: int = DEFAULT_TD,
                      interpret: bool = True):
    """Per-member clipped batch mean over a stacked cohort, one launch.

    flat:   (K*Bp, Dp) member-major per-example grads, Bp % tb == 0.
    scales: (K*Bp,) per-sample clip scales.
    z:      optional (K, Dp) standard-normal draws; when given, ``stddev``
            (a (1, 1) float32 array, runtime-valued) scales them and the
            kernel adds the noise in the final-tile epilogue.
    inv_b:  1 / B_real — padded rows are zero so they add nothing and no
            post-hoc rescale is needed.

    Returns (K, Dp) float32 means (noised when z is given).
    """
    kb, Dp = flat.shape
    bp = kb // k
    tb, td = min(tb, bp), min(td, Dp)
    n_b = pl.cdiv(bp, tb)
    grid = (k, pl.cdiv(Dp, td), n_b)
    flat_spec = pl.BlockSpec((tb, td), lambda m, j, i: (m * n_b + i, j))
    scale_spec = pl.BlockSpec((tb,), lambda m, j, i: (m * n_b + i,))
    out_spec = pl.BlockSpec((1, td), lambda m, j, i: (m, j))
    out_shape = jax.ShapeDtypeStruct((k, Dp), jnp.float32)
    if z is None:
        kern = functools.partial(_scale_mean_kernel, inv_b=inv_b)
        return pl.pallas_call(
            kern, grid=grid,
            in_specs=[flat_spec, scale_spec],
            out_specs=out_spec, out_shape=out_shape,
            interpret=interpret,
        )(flat, scales)
    kern = functools.partial(_scale_mean_noise_kernel, inv_b=inv_b, n_b=n_b)
    return pl.pallas_call(
        kern, grid=grid,
        in_specs=[
            flat_spec, scale_spec,
            pl.BlockSpec((1, td), lambda m, j, i: (m, j)),
            pl.BlockSpec((1, 1), lambda m, j, i: (0, 0)),
        ],
        out_specs=out_spec, out_shape=out_shape,
        interpret=interpret,
    )(flat, scales, z, stddev)


def scale_mean(flat, scales, *, tb: int = DEFAULT_TB, td: int = DEFAULT_TD,
               interpret: bool = True):
    """Single-member (K=1) clipped batch mean — thin cohort wrapper kept
    for the unit-level kernel tests."""
    B, D = flat.shape
    out = cohort_scale_mean(flat, scales, k=1, inv_b=1.0 / B,
                            tb=tb, td=td, interpret=interpret)
    return out[0]
