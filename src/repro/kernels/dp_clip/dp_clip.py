"""Fused per-sample gradient clip + accumulate — the DP-SGD hot spot
(what Opacus spends its time on), as two tiled Pallas TPU kernels.

TPU adaptation (DESIGN.md sec 3): instead of Opacus' hook-based per-layer
GPU pass, the flattened per-example grad matrix (B, D) is swept twice with
MXU/VPU-aligned VMEM tiles:

  pass 1 (sqnorm):  grid (nB, nD); each step reduces a (TB, TD) tile to a
                    (TB,) partial sum accumulated into the (B,) norms.
  pass 2 (scale+mean): grid (nD, nB); each step loads a (TB, TD) tile,
                    multiplies by the per-sample scale min(1, C/||g_i||)
                    broadcast from a (TB,) slice, and accumulates the
                    batch-mean into the (TD,) output.

Tiles default to (128, 512) f32 = 256 KiB live VMEM per step — far under
the ~16 MiB/core budget, leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TB = 128
DEFAULT_TD = 512


def _sqnorm_kernel(flat_ref, out_ref):
    """grid (nB, nD): accumulate per-sample squared norms."""
    j = pl.program_id(1)
    tile = flat_ref[...].astype(jnp.float32)          # (TB, TD)
    partial = jnp.sum(tile * tile, axis=1)            # (TB,)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial


def _scale_mean_kernel(flat_ref, scale_ref, out_ref, *, inv_b: float):
    """grid (nD, nB): out[d] += sum_b scale[b] * flat[b, d] * (1/B)."""
    i = pl.program_id(1)
    tile = flat_ref[...].astype(jnp.float32)          # (TB, TD)
    scales = scale_ref[...]                           # (TB,)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.sum(tile * scales[:, None], axis=0) * inv_b


def sqnorms(flat, *, tb: int = DEFAULT_TB, td: int = DEFAULT_TD,
            interpret: bool = True):
    B, D = flat.shape
    tb, td = min(tb, B), min(td, D)
    grid = (pl.cdiv(B, tb), pl.cdiv(D, td))
    return pl.pallas_call(
        _sqnorm_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tb, td), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((tb,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.float32),
        interpret=interpret,
    )(flat)


def scale_mean(flat, scales, *, tb: int = DEFAULT_TB, td: int = DEFAULT_TD,
               interpret: bool = True):
    B, D = flat.shape
    tb, td = min(tb, B), min(td, D)
    grid = (pl.cdiv(D, td), pl.cdiv(B, tb))
    kern = functools.partial(_scale_mean_kernel, inv_b=1.0 / B)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, td), lambda j, i: (i, j)),
            pl.BlockSpec((tb,), lambda j, i: (i,)),
        ],
        out_specs=pl.BlockSpec((td,), lambda j, i: (j,)),
        out_shape=jax.ShapeDtypeStruct((D,), jnp.float32),
        interpret=interpret,
    )(flat, scales)
