"""Mamba2 SSD intra-chunk Pallas kernel (the quadratic-in-chunk hot loop).

TPU adaptation of the Triton SSD kernel (Dao & Gu 2024): one grid step
owns a (chunk q x chunk q) score tile for one (batch, chunk, head) —
computed as C @ B^T on the MXU — masks it with the causal decay matrix
L = exp(segsum(a_h)) built in-register from a cumulative sum, and applies
it to the head's (q, p) input block, again on the MXU.

VMEM per step at (q=128, n=64, p=64) f32:
  B,C tiles 2*128*64*4 = 64 KiB; x/y 2*128*64*4 = 64 KiB; scores/L
  2*128*128*4 = 128 KiB — trivially resident, fully double-bufferable.

Grid: (b, c, h).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_intra_kernel(x_ref, a_ref, b_ref, c_ref, y_ref):
    x = x_ref[0, 0, :, 0].astype(jnp.float32)          # (q, p)
    a = a_ref[0, 0, 0].astype(jnp.float32)             # (q,)
    Bm = b_ref[0, 0].astype(jnp.float32)               # (q, n)
    Cm = c_ref[0, 0].astype(jnp.float32)               # (q, n)
    q = a.shape[0]

    cs = jnp.cumsum(a)
    seg = cs[:, None] - cs[None, :]                    # (q, q)
    causal = jnp.tril(jnp.ones((q, q), jnp.bool_))
    Lm = jnp.where(causal, jnp.exp(seg), 0.0)

    scores = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)
    y = jnp.dot(scores * Lm, x, preferred_element_type=jnp.float32)
    y_ref[0, 0, :, 0] = y.astype(y_ref.dtype)


def ssd_intra_chunk(xr, ar, Br, Cr, *, interpret: bool = True):
    """xr: (b,c,q,h,p); ar: (b,h,c,q); Br/Cr: (b,c,q,n) -> (b,c,q,h,p)."""
    b, c, q, h, p = xr.shape
    n = Br.shape[-1]
    grid = (b, c, h)
    return pl.pallas_call(
        _ssd_intra_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q, 1, p), lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, 1, q), lambda bi, ci, hi: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, q, n), lambda bi, ci, hi: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda bi, ci, hi: (bi, ci, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, q, 1, p), lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
        out_shape=jax.ShapeDtypeStruct(xr.shape, xr.dtype),
        interpret=interpret,
    )(xr, ar, Br, Cr)
