"""Pure-jnp oracle for the SSD intra-chunk kernel."""
import jax.numpy as jnp

from repro.models.mamba2 import _segsum


def ssd_intra_chunk_ref(xr, ar, Br, Cr):
    """xr: (b,c,q,h,p); ar: (b,h,c,q); Br/Cr: (b,c,q,n).
    Y_diag[b,c,q,h,p] = sum_k C_q.B_k * exp(segsum(a))[q,k] * x_k  (causal)."""
    Lm = jnp.exp(_segsum(ar))                          # (b,h,c,q,k)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cr, Br)     # (b,c,q,k)
    return jnp.einsum("bcqk,bhcqk,bckhp->bcqhp", scores, Lm, xr)
