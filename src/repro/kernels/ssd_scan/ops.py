"""jit'd wrapper for the SSD intra-chunk kernel."""
from __future__ import annotations

import jax

from repro.kernels.common import interpret_mode
from repro.kernels.ssd_scan.ssd_scan import ssd_intra_chunk as _raw


@jax.jit
def ssd_intra_chunk(xr, ar, Br, Cr):
    return _raw(xr, ar, Br, Cr, interpret=interpret_mode())
