"""Moments Accountant (Abadi et al., 2016) for the subsampled Gaussian
mechanism, as used by the paper for per-client privacy tracking.

The paper (Sec. 3.2, Eq. 7-8) tracks, per client k, the cumulative log
moments ``mu(lambda) = sum_t mu_t(lambda)`` and reports

    eps = min_lambda ( mu(lambda) - log(delta) ) / lambda .

For the Gaussian mechanism with per-sample clipping norm C, noise scale
``sigma * C`` and Poisson-style subsampling ratio ``q = B / |D_k|``, the
lambda-th log moment of one step admits the classical integer-order bound
(Abadi et al. Lemma 3 / Mironov's sampled-Gaussian RDP at integer orders):

    mu_t(lambda) = log( sum_{k=0}^{lambda+1} C(lambda+1, k)
                        (1-q)^{lambda+1-k} q^k  exp( k(k-1) / (2 sigma^2) ) )

(using the identity mu_MA(lambda) = log A(alpha) with alpha = lambda + 1,
where A(alpha) = E_{z~mu}[(mu/mu0)^alpha]).  Everything is computed in
log-space in float64, so large lambda / small sigma do not overflow.

This module is pure numpy (it runs on the host, per client, per round —
never inside a jitted step).

Dispatch-time cost: the engine's cohort scheduler charges the accountant
once per client dispatch, which makes the one-step moment computation
part of the server's host-side critical path (see
``repro.engine.engine``).  :func:`log_moments_vector` therefore computes
the whole one-step log-moment vector over all orders in one vectorized
numpy pass, and :func:`cached_log_moments` memoizes it per
``(q, sigma, orders)`` — a client population with homogeneous (q, sigma)
pays the O(orders * max_order) term construction ONCE per process and
every subsequent ``MomentsAccountant.step`` is a single O(orders)
fused-multiply-add.  :class:`EpsilonSchedule` goes one step further for
the engine's fixed per-round step counts: the whole epsilon-vs-round
trajectory of a client config is a lazily extended table, so dispatch
(and ``AdaptiveAsync`` budget checks) read epsilon by index instead of
re-minimizing over orders.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

DEFAULT_ORDERS = tuple(range(1, 65)) + (80, 96, 128, 192, 256, 512)


def _log_comb(n: int, k: int) -> float:
    return math.lgamma(n + 1) - math.lgamma(k + 1) - math.lgamma(n - k + 1)


def log_moment_subsampled_gaussian(q: float, sigma: float, lam: int) -> float:
    """One-step lambda-th log moment mu_t(lambda) for sampling ratio q,
    noise multiplier sigma.  Exact at integer orders."""
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"sampling ratio q={q} outside [0, 1]")
    if sigma <= 0.0:
        return math.inf  # no noise => unbounded privacy loss
    if q == 0.0:
        return 0.0
    alpha = lam + 1
    if q == 1.0:
        # plain Gaussian mechanism: mu(lambda) = lambda (lambda+1) / (2 sigma^2)
        return lam * alpha / (2.0 * sigma * sigma)
    # log-sum-exp over k of:  logC(alpha,k) + (alpha-k)log(1-q) + k log q
    #                          + k(k-1)/(2 sigma^2)
    log_terms = np.array(
        [
            _log_comb(alpha, k)
            + (alpha - k) * math.log1p(-q)
            + k * math.log(q)
            + (k * (k - 1)) / (2.0 * sigma * sigma)
            for k in range(alpha + 1)
        ],
        dtype=np.float64,
    )
    m = log_terms.max()
    return float(m + math.log(np.exp(log_terms - m).sum()))


def log_moments_vector(q: float, sigma: float,
                       orders=DEFAULT_ORDERS) -> np.ndarray:
    """One-step log-moment VECTOR over ``orders`` in one vectorized pass.

    Produces exactly :func:`log_moment_subsampled_gaussian` evaluated at
    every order (the per-term IEEE operations and the per-order
    log-sum-exp reduction are kept in the scalar path's association, so
    the two agree bit-for-bit — the tier-1 fast-path test pins them to
    1e-12): the binomial/term matrix for all orders is built as one
    (n_orders, max_alpha + 1) numpy computation instead of n_orders
    Python list comprehensions.
    """
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"sampling ratio q={q} outside [0, 1]")
    lams = np.asarray(orders, dtype=np.int64)
    alphas = lams + 1
    if sigma <= 0.0:
        return np.full(len(lams), math.inf)
    if q == 0.0:
        return np.zeros(len(lams))
    if q == 1.0:
        # plain Gaussian mechanism: mu(lambda) = lambda (lambda+1) / (2 sigma^2)
        return (lams * alphas) / (2.0 * sigma * sigma)
    a_max = int(alphas.max())
    # lgamma table: LG[m] = lgamma(m), so _log_comb(n, k) is
    # LG[n+1] - LG[k+1] - LG[n-k+1] with the scalar path's exact values
    # index 0 is never read (all lookups are >= 1); lgamma has a pole there
    lg = np.array([0.0] + [math.lgamma(m) for m in range(1, a_max + 2)])
    k = np.arange(a_max + 1, dtype=np.int64)
    log_comb = lg[alphas[:, None] + 1] - lg[k[None, :] + 1] \
        - lg[np.maximum(alphas[:, None] - k[None, :], 0) + 1]
    log1mq = math.log1p(-q)
    logq = math.log(q)
    # same left-to-right accumulation as the scalar term expression
    terms = ((log_comb + (alphas[:, None] - k[None, :]) * log1mq)
             + k[None, :] * logq) + (k * (k - 1)) / (2.0 * sigma * sigma)
    out = np.empty(len(lams))
    for i, alpha in enumerate(alphas):
        row = terms[i, : alpha + 1]          # the k = 0..alpha terms only
        m = row.max()
        out[i] = m + math.log(np.exp(row - m).sum())
    return out


# one-step log-moment vectors are pure functions of (q, sigma, orders):
# memoize them so per-dispatch accounting is an O(orders) increment, not a
# recomputation (the cached arrays are marked read-only — accountants
# accumulate into their own _mu, never into the cache)
_ONE_STEP_CACHE: dict = {}
_FAST_ACCOUNTING = True


def use_fast_accounting(enabled: bool) -> bool:
    """Toggle the memoized-vector fast path in ``MomentsAccountant.step``
    (returns the previous setting).  The scalar path is kept ONLY so the
    benchmarks can measure the pre-memoization dispatch cost — both paths
    produce identical moments (see tests/test_accountant.py)."""
    global _FAST_ACCOUNTING
    prev = _FAST_ACCOUNTING
    _FAST_ACCOUNTING = bool(enabled)
    return prev


def fast_accounting_enabled() -> bool:
    return _FAST_ACCOUNTING


def cached_log_moments(q: float, sigma: float,
                       orders=DEFAULT_ORDERS) -> np.ndarray:
    """Memoized :func:`log_moments_vector` (read-only array)."""
    key = (float(q), float(sigma), tuple(orders))
    vec = _ONE_STEP_CACHE.get(key)
    if vec is None:
        vec = log_moments_vector(q, sigma, orders)
        vec.setflags(write=False)
        _ONE_STEP_CACHE[key] = vec
    return vec


def epsilon_from_moments(log_moments: np.ndarray, orders, delta: float) -> float:
    """eps = min_lambda (mu(lambda) - log delta) / lambda   (paper Eq. 8)."""
    if delta <= 0 or delta >= 1:
        raise ValueError(f"delta={delta} outside (0, 1)")
    orders = np.asarray(orders, dtype=np.float64)
    mu = np.asarray(log_moments, dtype=np.float64)
    finite = np.isfinite(mu)
    if not finite.any():
        return math.inf
    if (mu[finite] <= 0).all():
        return 0.0  # no privacy loss accrued (e.g. q = 0): eps -> 0 as
                    # lambda -> inf, so the exact answer is 0
    eps = (mu[finite] - math.log(delta)) / orders[finite]
    return float(eps.min())


def delta_from_moments(log_moments: np.ndarray, orders, eps: float) -> float:
    """delta = min_lambda exp(mu(lambda) - lambda eps)   (paper Sec. 2.3)."""
    orders = np.asarray(orders, dtype=np.float64)
    mu = np.asarray(log_moments, dtype=np.float64)
    finite = np.isfinite(mu)
    if not finite.any():
        return 1.0
    # exp is monotone: min over lambda of exp(.) = exp(min of the exponent);
    # a non-negative exponent means delta >= 1, which caps at 1 anyway
    expo = float((mu[finite] - orders[finite] * eps).min())
    if expo >= 0.0:
        return 1.0
    return math.exp(expo)


@dataclass
class MomentsAccountant:
    """Tracks cumulative log moments for ONE client.

    The paper fixes (q, sigma) per client and accumulates over rounds;
    we allow heterogeneous steps too (q or sigma may change round to
    round, e.g. under the beyond-paper adaptive noise calibration).
    """

    orders: tuple = DEFAULT_ORDERS
    _mu: np.ndarray = field(default=None, repr=False)
    steps: int = 0

    def __post_init__(self):
        if self._mu is None:
            self._mu = np.zeros(len(self.orders), dtype=np.float64)

    def step(self, q: float, sigma: float, num_steps: int = 1) -> None:
        """Account for ``num_steps`` subsampled-Gaussian steps.

        The one-step log-moment vector comes from the per-(q, sigma)
        memo (:func:`cached_log_moments`), so repeated steps — one per
        dispatch in the engine's event loop — cost O(orders) instead of
        recomputing the O(orders * max_order) term matrix every round.
        """
        if num_steps <= 0:
            return
        if _FAST_ACCOUNTING:
            inc = cached_log_moments(q, sigma, self.orders)
        else:
            inc = np.array(
                [log_moment_subsampled_gaussian(q, sigma, lam)
                 for lam in self.orders],
                dtype=np.float64,
            )
        self._mu = self._mu + num_steps * inc
        self.steps += num_steps

    def epsilon(self, delta: float) -> float:
        if self.steps == 0:
            return 0.0
        return epsilon_from_moments(self._mu, self.orders, delta)

    def delta(self, eps: float) -> float:
        if self.steps == 0:
            return 0.0
        return delta_from_moments(self._mu, self.orders, eps)

    def copy(self) -> "MomentsAccountant":
        acc = MomentsAccountant(orders=self.orders)
        acc._mu = self._mu.copy()
        acc.steps = self.steps
        return acc


def compute_epsilon(
    q: float, sigma: float, steps: int, delta: float, orders=DEFAULT_ORDERS
) -> float:
    """Convenience one-shot: eps after ``steps`` identical DP-SGD steps."""
    acc = MomentsAccountant(orders=orders)
    acc.step(q, sigma, steps)
    return acc.epsilon(delta)


class EpsilonSchedule:
    """Precomputed epsilon-vs-round trajectory for ONE client config.

    The engine dispatches a client with a FIXED per-round step count
    (``steps_per_round`` is a function of (n_train, B, E)), so the whole
    epsilon trajectory is known up front: entry r is the epsilon a
    :class:`MomentsAccountant` reports after r identical round charges.
    The table accumulates the memoized one-step vector round by round —
    the SAME float64 addition sequence the accountant performs — so the
    lookup is bit-identical to stepping an accountant, and the
    ``AdaptiveAsync`` budget check at dispatch time is an array index
    instead of a min-over-orders recomputation.

    The table extends lazily in :meth:`epsilon_after_rounds`; use
    :func:`cached_epsilon_schedule` to share one schedule per distinct
    ``(q, sigma, steps_per_round, delta)`` across clients.
    """

    def __init__(self, q: float, sigma: float, steps_per_round: int,
                 delta: float, orders=DEFAULT_ORDERS):
        self.q = q
        self.sigma = sigma
        self.steps_per_round = int(steps_per_round)
        self.delta = delta
        self.orders = orders
        self._round_inc = (self.steps_per_round
                           * cached_log_moments(q, sigma, orders))
        self._mu = np.zeros(len(orders), dtype=np.float64)
        self._eps = [0.0]  # eps after 0 rounds

    def epsilon_after_rounds(self, rounds: int) -> float:
        """Epsilon after ``rounds`` dispatched local rounds (table lookup,
        extending the table when the run outlives it)."""
        if rounds < 0:
            raise ValueError(f"rounds={rounds} must be >= 0")
        if self.steps_per_round == 0:
            return 0.0  # no full batch => no charged steps (steps == 0)
        while len(self._eps) <= rounds:
            self._mu = self._mu + self._round_inc
            self._eps.append(
                epsilon_from_moments(self._mu, self.orders, self.delta))
        return self._eps[rounds]


_SCHEDULE_CACHE: dict = {}


def cached_epsilon_schedule(q: float, sigma: float, steps_per_round: int,
                            delta: float,
                            orders=DEFAULT_ORDERS) -> EpsilonSchedule:
    """One shared :class:`EpsilonSchedule` per distinct client config."""
    key = (float(q), float(sigma), int(steps_per_round), float(delta),
           tuple(orders))
    sched = _SCHEDULE_CACHE.get(key)
    if sched is None:
        sched = EpsilonSchedule(q, sigma, steps_per_round, delta, orders)
        _SCHEDULE_CACHE[key] = sched
    return sched
