"""Moments Accountant (Abadi et al., 2016) for the subsampled Gaussian
mechanism, as used by the paper for per-client privacy tracking.

The paper (Sec. 3.2, Eq. 7-8) tracks, per client k, the cumulative log
moments ``mu(lambda) = sum_t mu_t(lambda)`` and reports

    eps = min_lambda ( mu(lambda) - log(delta) ) / lambda .

For the Gaussian mechanism with per-sample clipping norm C, noise scale
``sigma * C`` and Poisson-style subsampling ratio ``q = B / |D_k|``, the
lambda-th log moment of one step admits the classical integer-order bound
(Abadi et al. Lemma 3 / Mironov's sampled-Gaussian RDP at integer orders):

    mu_t(lambda) = log( sum_{k=0}^{lambda+1} C(lambda+1, k)
                        (1-q)^{lambda+1-k} q^k  exp( k(k-1) / (2 sigma^2) ) )

(using the identity mu_MA(lambda) = log A(alpha) with alpha = lambda + 1,
where A(alpha) = E_{z~mu}[(mu/mu0)^alpha]).  Everything is computed in
log-space in float64, so large lambda / small sigma do not overflow.

This module is pure numpy (it runs on the host, per client, per round —
never inside a jitted step).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

DEFAULT_ORDERS = tuple(range(1, 65)) + (80, 96, 128, 192, 256, 512)


def _log_comb(n: int, k: int) -> float:
    return math.lgamma(n + 1) - math.lgamma(k + 1) - math.lgamma(n - k + 1)


def log_moment_subsampled_gaussian(q: float, sigma: float, lam: int) -> float:
    """One-step lambda-th log moment mu_t(lambda) for sampling ratio q,
    noise multiplier sigma.  Exact at integer orders."""
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"sampling ratio q={q} outside [0, 1]")
    if sigma <= 0.0:
        return math.inf  # no noise => unbounded privacy loss
    if q == 0.0:
        return 0.0
    alpha = lam + 1
    if q == 1.0:
        # plain Gaussian mechanism: mu(lambda) = lambda (lambda+1) / (2 sigma^2)
        return lam * alpha / (2.0 * sigma * sigma)
    # log-sum-exp over k of:  logC(alpha,k) + (alpha-k)log(1-q) + k log q
    #                          + k(k-1)/(2 sigma^2)
    log_terms = np.array(
        [
            _log_comb(alpha, k)
            + (alpha - k) * math.log1p(-q)
            + k * math.log(q)
            + (k * (k - 1)) / (2.0 * sigma * sigma)
            for k in range(alpha + 1)
        ],
        dtype=np.float64,
    )
    m = log_terms.max()
    return float(m + math.log(np.exp(log_terms - m).sum()))


def epsilon_from_moments(log_moments: np.ndarray, orders, delta: float) -> float:
    """eps = min_lambda (mu(lambda) - log delta) / lambda   (paper Eq. 8)."""
    if delta <= 0 or delta >= 1:
        raise ValueError(f"delta={delta} outside (0, 1)")
    orders = np.asarray(orders, dtype=np.float64)
    mu = np.asarray(log_moments, dtype=np.float64)
    finite = np.isfinite(mu)
    if not finite.any():
        return math.inf
    if (mu[finite] <= 0).all():
        return 0.0  # no privacy loss accrued (e.g. q = 0): eps -> 0 as
                    # lambda -> inf, so the exact answer is 0
    eps = (mu[finite] - math.log(delta)) / orders[finite]
    return float(eps.min())


def delta_from_moments(log_moments: np.ndarray, orders, eps: float) -> float:
    """delta = min_lambda exp(mu(lambda) - lambda eps)   (paper Sec. 2.3)."""
    orders = np.asarray(orders, dtype=np.float64)
    mu = np.asarray(log_moments, dtype=np.float64)
    finite = np.isfinite(mu)
    if not finite.any():
        return 1.0
    # exp is monotone: min over lambda of exp(.) = exp(min of the exponent);
    # a non-negative exponent means delta >= 1, which caps at 1 anyway
    expo = float((mu[finite] - orders[finite] * eps).min())
    if expo >= 0.0:
        return 1.0
    return math.exp(expo)


@dataclass
class MomentsAccountant:
    """Tracks cumulative log moments for ONE client.

    The paper fixes (q, sigma) per client and accumulates over rounds;
    we allow heterogeneous steps too (q or sigma may change round to
    round, e.g. under the beyond-paper adaptive noise calibration).
    """

    orders: tuple = DEFAULT_ORDERS
    _mu: np.ndarray = field(default=None, repr=False)
    steps: int = 0

    def __post_init__(self):
        if self._mu is None:
            self._mu = np.zeros(len(self.orders), dtype=np.float64)

    def step(self, q: float, sigma: float, num_steps: int = 1) -> None:
        """Account for ``num_steps`` subsampled-Gaussian steps."""
        if num_steps <= 0:
            return
        inc = np.array(
            [log_moment_subsampled_gaussian(q, sigma, lam) for lam in self.orders],
            dtype=np.float64,
        )
        self._mu = self._mu + num_steps * inc
        self.steps += num_steps

    def epsilon(self, delta: float) -> float:
        if self.steps == 0:
            return 0.0
        return epsilon_from_moments(self._mu, self.orders, delta)

    def delta(self, eps: float) -> float:
        if self.steps == 0:
            return 0.0
        return delta_from_moments(self._mu, self.orders, eps)

    def copy(self) -> "MomentsAccountant":
        acc = MomentsAccountant(orders=self.orders)
        acc._mu = self._mu.copy()
        acc.steps = self.steps
        return acc


def compute_epsilon(
    q: float, sigma: float, steps: int, delta: float, orders=DEFAULT_ORDERS
) -> float:
    """Convenience one-shot: eps after ``steps`` identical DP-SGD steps."""
    acc = MomentsAccountant(orders=orders)
    acc.step(q, sigma, steps)
    return acc.epsilon(delta)
