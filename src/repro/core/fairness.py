"""Fairness metrics (paper Sec. 4.2.2).

Participation percentages (PP), per-client accuracy gaps, and Jain's
fairness index over both participation counts and local accuracies.
"""
from __future__ import annotations

import numpy as np


def participation_percentages(update_counts: dict) -> dict:
    total = float(sum(update_counts.values()))
    if total == 0:
        return {k: 0.0 for k in update_counts}
    return {k: 100.0 * v / total for k, v in update_counts.items()}


def jain_index(values) -> float:
    """Jain's fairness index: (sum x)^2 / (n * sum x^2).  1 = fair."""
    x = np.asarray(list(values), dtype=np.float64)
    if x.size == 0 or (x == 0).all():
        return 1.0
    return float((x.sum() ** 2) / (x.size * (x ** 2).sum()))


def accuracy_gap(per_client_acc: dict) -> float:
    vals = list(per_client_acc.values())
    return float(max(vals) - min(vals)) if vals else 0.0


def privacy_disparity(per_client_eps: dict) -> float:
    """max eps / min eps across clients (paper reports ~5-6x under FedAsync)."""
    vals = [v for v in per_client_eps.values() if v > 0]
    if not vals:
        return 1.0
    return float(max(vals) / max(min(vals), 1e-12))


def fairness_report(update_counts, per_client_acc, per_client_eps) -> dict:
    return {
        "participation_pct": participation_percentages(update_counts),
        "jain_participation": jain_index(update_counts.values()),
        "jain_accuracy": jain_index(per_client_acc.values()),
        "accuracy_gap": accuracy_gap(per_client_acc),
        "privacy_disparity": privacy_disparity(per_client_eps),
    }
