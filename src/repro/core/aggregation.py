"""Server-side aggregation strategies.

Paper-faithful:
  * :class:`FedAvg`    — Eq. (9): dataset-size-weighted average, barrier.
  * :class:`FedAsync`  — Eq. (10)-(11): immediate merge with staleness-aware
                         decay alpha_k = alpha / (1 + tau_k), optionally
                         staleness-UNaware (alpha_k = alpha) to reproduce the
                         paper's "without staleness control" Fig. 4 variant.

Beyond-paper (paper Sec. 5 future directions, recorded separately in
EXPERIMENTS.md):
  * :class:`FedBuff`   — buffered async aggregation (Nguyen et al. [5]).
  * :class:`AdaptiveAsync` — joint aggregation-privacy adaptation: the merge
                         weight additionally shrinks with the client's
                         cumulative privacy spend, throttling the high-end
                         devices that dominate the update stream.
  * :class:`TrimmedMeanFedAvg` / :class:`NormBoundedFedAsync` — robust
                         aggregation under corrupt updates (coordinate-wise
                         trimmed mean; norm-clamped async merge) — the
                         aggregation-side complement to the engine's
                         update screening (repro.core.screening).
"""
from __future__ import annotations

from dataclasses import dataclass, field, fields
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.pytree import tree_lin, tree_scale, tree_add, tree_zeros_like


@dataclass
class FedAvg:
    """Synchronous, dataset-size weighted (Eq. 9)."""

    name: str = "fedavg"
    is_async: bool = False

    def aggregate(self, global_params, updates):
        """``updates`` = list of (params_k, n_k).  Returns new globals."""
        total = float(sum(n for _, n in updates))
        acc = None
        for params_k, n_k in updates:
            contrib = tree_scale(params_k, n_k / total)
            acc = contrib if acc is None else tree_add(acc, contrib)
        return acc


@dataclass
class FedAsync:
    """Asynchronous with staleness-aware decay (Eq. 10-11)."""

    alpha: float = 0.4
    staleness_aware: bool = True
    name: str = "fedasync"
    is_async: bool = True

    def mixing_weight(self, staleness: int) -> float:
        if self.staleness_aware:
            return self.alpha / (1.0 + float(staleness))
        return self.alpha

    def merge(self, global_params, client_params, staleness: int):
        a_k = self.mixing_weight(staleness)
        return tree_lin(global_params, client_params, 1.0 - a_k, a_k), a_k


@dataclass
class TrimmedMeanFedAvg(FedAvg):
    """Robust synchronous aggregation: coordinate-wise trimmed mean.

    Sorts the K client payloads per coordinate, drops the
    ``floor(trim_frac * K)`` largest and smallest values (capped so at
    least one value survives) and averages the rest — the classic
    Byzantine-robust estimator (Yin et al.; PAPERS.md).  Intentionally
    UNWEIGHTED: a dataset-size weight would let a large corrupt client
    dominate the very statistic meant to exclude it.  Deliberately NOT
    fused (``_fused_ok`` routes it per-member): a per-coordinate sort is
    not a weights-vector reduction.
    """

    trim_frac: float = 0.2
    name: str = "fedavg_trimmed"

    def __post_init__(self):
        if not 0.0 <= self.trim_frac < 0.5:
            raise ValueError(
                f"trim_frac must be in [0, 0.5): {self.trim_frac} "
                "(trimming half or more from each end leaves nothing)")

    def aggregate(self, global_params, updates):
        k = len(updates)
        cut = min(int(self.trim_frac * k), (k - 1) // 2)
        payloads = [u for u, _ in updates]

        def leaf(*vals):
            v = jnp.sort(jnp.stack(vals, axis=0), axis=0)
            return jnp.mean(v[cut: k - cut], axis=0)

        return jax.tree_util.tree_map(leaf, *payloads)


@dataclass
class NormBoundedFedAsync(FedAsync):
    """Robust async merge: the client delta is norm-clamped before the
    staleness-weighted Eq. 11 merge.  The merge moves by
    ``min(1, norm_bound / ||p_k - g||)`` of the delta direction — an
    oversized update contributes at most a ``norm_bound``-long step, a
    nonfinite one contributes nothing (scale 0), while in-bound updates
    merge EXACTLY like plain FedAsync.  The reported influence weight is
    the host-known nominal ``alpha/(1+tau)`` (the clamp is a device-side
    projection, not a re-weighting — bookkeeping stays sync-free)."""

    norm_bound: float = 10.0
    name: str = "fedasync_normbound"

    def __post_init__(self):
        if not self.norm_bound > 0:
            raise ValueError(f"norm_bound must be > 0: {self.norm_bound}")

    def merge(self, global_params, client_params, staleness: int):
        sq = jnp.float32(0.0)
        for g, p in zip(jax.tree_util.tree_leaves(global_params),
                        jax.tree_util.tree_leaves(client_params)):
            d = p.astype(jnp.float32) - g.astype(jnp.float32)
            sq = sq + jnp.sum(d * d)
        norm = jnp.sqrt(sq)
        clamp = jnp.where(
            jnp.isfinite(norm),
            jnp.minimum(jnp.float32(1.0),
                        jnp.float32(self.norm_bound)
                        / jnp.maximum(norm, jnp.float32(1e-12))),
            jnp.float32(0.0))

        def leaf(g, p):
            gf, pf = g.astype(jnp.float32), p.astype(jnp.float32)
            # a nonfinite payload must contribute EXACTLY nothing:
            # clamp is 0 there, but 0 * NaN = NaN would re-poison the
            # projection, so nonfinite entries fall back to the globals
            # (a no-op whenever the norm — and hence every entry — is
            # finite, preserving the in-bound bit-identity below)
            pf = jnp.where(jnp.isfinite(pf), pf, gf)
            proj = (gf + clamp * (pf - gf)).astype(p.dtype)
            # clamp == 1.0 selects the payload VERBATIM: an in-bound
            # update then merges bit-identically to plain FedAsync
            return jnp.where(clamp == 1.0, p, proj)

        bounded = jax.tree_util.tree_map(leaf, global_params, client_params)
        return super().merge(global_params, bounded, staleness)


@dataclass
class FedBuff:
    """Buffered asynchronous aggregation (beyond-paper; Nguyen et al. [5]).

    Buffers ``buffer_size`` staleness-weighted deltas, then applies their
    weighted mean in one server step — a middle point between FedAvg's
    barrier and FedAsync's immediate merge.
    """

    alpha: float = 0.4
    buffer_size: int = 3
    staleness_aware: bool = True
    name: str = "fedbuff"
    is_async: bool = True

    _buffer: list = field(default_factory=list, repr=False)

    def mixing_weight(self, staleness: int) -> float:
        if self.staleness_aware:
            return self.alpha / (1.0 + float(staleness))
        return self.alpha

    def offer(self, global_params, client_params, staleness: int):
        """Returns (new_globals | None, applied: bool, weight)."""
        w = self.mixing_weight(staleness)
        self._buffer.append((client_params, w))
        if len(self._buffer) < self.buffer_size:
            return None, False, w
        wsum = sum(w_ for _, w_ in self._buffer)
        mix = None
        for p, w_ in self._buffer:
            c = tree_scale(p, w_ / wsum)
            mix = c if mix is None else tree_add(mix, c)
        # effective server step: move by the mean weight toward the mix
        a = wsum / len(self._buffer)
        new_globals = tree_lin(global_params, mix, 1.0 - a, a)
        self._buffer = []
        return new_globals, True, w


@dataclass
class AdaptiveAsync(FedAsync):
    """Beyond-paper: joint aggregation-privacy adaptation (paper Sec. 5,
    'Joint Aggregation-Privacy Adaptation').

    The merge weight is additionally scaled by how much privacy budget the
    client has left: w = alpha/(1+tau) * max(eps_floor, 1 - eps_k/eps_target).
    High-end devices that have already spent most of their target budget
    get throttled, flattening both the participation-influence skew and the
    privacy-loss skew at a modest convergence cost (see EXPERIMENTS §Beyond).
    """

    eps_target: float = 8.0
    eps_floor: float = 0.1
    name: str = "adaptive_async"

    def mixing_weight(self, staleness: int, eps_spent: float = 0.0) -> float:
        base = super().mixing_weight(staleness)
        budget_frac = max(self.eps_floor, 1.0 - eps_spent / self.eps_target)
        return base * budget_frac

    def merge(self, global_params, client_params, staleness: int, eps_spent: float = 0.0):
        a_k = self.mixing_weight(staleness, eps_spent)
        return tree_lin(global_params, client_params, 1.0 - a_k, a_k), a_k


def apply_update(strategy, global_params, params_k, tau: int,
                 eps_spent: float = 0.0):
    """Route one client update through ``strategy`` (the single switch the
    legacy loop and the cohort engine both use, so their merge semantics
    cannot drift).

    Returns ``(new_globals, version_inc, weight)`` where ``version_inc`` is
    how much the server version advances (0 while FedBuff is buffering).
    """
    if isinstance(strategy, FedBuff):
        new_g, applied, w = strategy.offer(global_params, params_k, tau)
        if applied:
            return new_g, 1, w
        return global_params, 0, w
    if isinstance(strategy, AdaptiveAsync):
        new_g, w = strategy.merge(global_params, params_k, tau,
                                  eps_spent=eps_spent)
        return new_g, 1, w
    # FedAsync (staleness-aware or not)
    new_g, w = strategy.merge(global_params, params_k, tau)
    return new_g, 1, w


# ---------------------------------------------------------------------------
# strategy registry: the ONE name -> (constructor, tunable params) table.
# repro.api.StrategySpec validates against it at construction time and
# make_strategy resolves through it, so a name/param can't be accepted by
# one layer and rejected deep inside the other.
# ---------------------------------------------------------------------------

def _tunable_params(cls, exclude=()) -> tuple:
    """The constructor params a user may set: init-able dataclass fields
    minus the identity fields (name/is_async) and private state."""
    skip = {"name", "is_async"} | set(exclude)
    return tuple(f.name for f in fields(cls)
                 if f.init and f.name not in skip
                 and not f.name.startswith("_"))


# name -> (zero-arg-or-kw constructor, allowed keyword params).
# fedasync_nostale pins staleness_aware=False (the paper's Fig. 4
# "without staleness control" variant), so that knob is not tunable there.
STRATEGIES = {
    "fedavg": (FedAvg, ()),
    "fedavg_trimmed": (TrimmedMeanFedAvg,
                       _tunable_params(TrimmedMeanFedAvg)),
    "fedasync": (FedAsync, _tunable_params(FedAsync)),
    "fedasync_nostale": (
        partial(FedAsync, staleness_aware=False),
        _tunable_params(FedAsync, exclude=("staleness_aware",))),
    "fedasync_normbound": (NormBoundedFedAsync,
                           _tunable_params(NormBoundedFedAsync)),
    "fedbuff": (FedBuff, _tunable_params(FedBuff)),
    "adaptive_async": (AdaptiveAsync, _tunable_params(AdaptiveAsync)),
}

STRATEGY_NAMES = tuple(STRATEGIES)


def strategy_params(name: str) -> tuple:
    """Valid keyword params for ``name`` (raises on unknown names, listing
    the registry)."""
    try:
        return STRATEGIES[name.lower()][1]
    except KeyError:
        raise ValueError(
            f"unknown aggregation strategy: {name!r} "
            f"(valid: {', '.join(sorted(STRATEGIES))})") from None


def validate_strategy_params(name: str, kw: dict) -> str:
    """Check ``kw`` against the registry (raising with the valid options
    listed) and return the normalized name — the ONE validation shared by
    :func:`make_strategy` and ``repro.api.StrategySpec``, so a spec can
    never accept what the constructor would reject (or vice versa)."""
    name = str(name).lower()
    allowed = strategy_params(name)
    unknown = sorted(set(kw) - set(allowed))
    if unknown:
        raise ValueError(
            f"unknown param(s) {', '.join(unknown)} for strategy "
            f"{name!r} (valid: {', '.join(allowed) or 'none'})")
    # value validation too: constructing the (cheap, pure) dataclass runs
    # its __post_init__ checks, so a spec can no more carry trim_frac=0.7
    # than an unknown param name
    STRATEGIES[name][0](**kw)
    return name


def make_strategy(name: str, **kw):
    name = str(name).lower()
    if name == "fedasync_nostale":
        kw.pop("staleness_aware", None)  # historical frontend tolerance
    name = validate_strategy_params(name, kw)
    return STRATEGIES[name][0](**kw)
