"""FL client: local DP-SGD training (paper Algorithm 1, client side).

A client owns: a local dataset partition, a hardware tier (VirtualClock),
an optimizer state, and a MomentsAccountant.  ``local_train`` runs E local
epochs of per-example DP-SGD from the received global weights and returns
the new local weights plus bookkeeping (virtual duration, privacy step
count, train metrics).

The jitted update step is shared across clients (same treedef/shapes), so
simulation cost is 1 trace + K*steps executions.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accountant import MomentsAccountant
from repro.core.dp import DPConfig, dp_mean_gradient
from repro.core.heterogeneity import DeviceProfile, VirtualClock
from repro.optim.optimizers import Adam


@partial(jax.jit, static_argnames=("loss_fn", "dp_cfg", "opt", "dp_path"))
def _dp_sgd_step(params, opt_state, batch, key, *, loss_fn, dp_cfg, opt, dp_path="jnp"):
    """One DP-SGD mini-batch step (Eq. 4-6 + Adam)."""
    grad, aux = dp_mean_gradient(loss_fn, params, batch, key, dp_cfg, dp_path=dp_path)
    new_params, new_opt_state = opt.update(grad, opt_state, params)
    return new_params, new_opt_state, aux


@partial(jax.jit, static_argnames=("loss_fn", "opt"))
def _sgd_step(params, opt_state, batch, *, loss_fn, opt):
    """Non-private baseline step (sigma=0, no clipping)."""
    loss, grad = jax.value_and_grad(
        lambda p: jnp.mean(jax.vmap(lambda ex: loss_fn(p, ex))(batch))
    )(params)
    new_params, new_opt_state = opt.update(grad, opt_state, params)
    return new_params, new_opt_state, loss


@dataclass
class Client:
    cid: int
    tier: str
    profile: DeviceProfile
    data: dict                      # {"x": (N,...), "y": (N,)} train split
    test_data: dict                 # local test split
    loss_fn: Callable               # loss_fn(params, example) -> scalar
    dp_cfg: DPConfig
    opt: Adam
    batch_size: int = 128
    local_epochs: int = 1
    seed: int = 0
    use_dp: bool = True
    dp_path: str = "jnp"            # "jnp" | "pallas" (fused clip+noise kernel)
    # personalized FL (beyond-paper; paper Sec. 5 'Personalized FL with
    # Privacy Guarantees'): these TOP-LEVEL param subtrees stay on-device —
    # they are restored over the received globals before local training and
    # are never sent back (the server's copy stays frozen), so low-end
    # clients keep a usable local head even under strong noise/staleness
    personal_keys: tuple = ()

    clock: VirtualClock = field(init=False)
    accountant: MomentsAccountant = field(init=False)
    rng: np.random.Generator = field(init=False)
    opt_state: object = field(init=False, default=None)
    model_version: int = 0          # global version this client last pulled
    update_count: int = 0
    staleness_history: list = field(default_factory=list)
    _personal: dict = field(init=False, default=None)

    def __post_init__(self):
        self.clock = VirtualClock(self.profile, seed=self.seed * 977 + self.cid)
        self.accountant = MomentsAccountant()
        self.rng = np.random.default_rng(self.seed * 131 + self.cid)

    def reset(self):
        """Restore construction-time state (clock/accountant/RNG chain,
        optimizer state, version bookkeeping) so a long-lived testbed can
        be reused across runs: ``repro.api.Session`` resets every client
        between scenario runs, and a reset run is bit-identical to one on
        a freshly built testbed (the session parity tests assert it).  The
        dataset partition and training config are untouched."""
        self.__post_init__()
        self.opt_state = None
        self.model_version = 0
        self.update_count = 0
        self.staleness_history = []
        self._personal = None

    @property
    def n_train(self) -> int:
        return int(self.data["y"].shape[0])

    @property
    def q(self) -> float:
        """Sampling ratio for the accountant (paper: q = B/|D_k| ~ 0.136)."""
        return min(1.0, self.batch_size / self.n_train)

    def local_train(self, global_params, key: jax.Array):
        """Run E epochs of DP-SGD from ``global_params``.

        Returns (new_params, info) with virtual ``duration`` drawn from the
        hardware tier's clock and the number of accounted DP steps.
        """
        params = global_params
        if self.personal_keys:
            if self._personal is None:  # first round: adopt global init
                self._personal = {k: global_params[k]
                                  for k in self.personal_keys}
            params = dict(global_params)
            params.update(self._personal)
        if self.opt_state is None:
            self.opt_state = self.opt.init(params)
        opt_state = self.opt_state

        n = self.n_train
        steps = 0
        losses = []
        for _ in range(self.local_epochs):
            perm = self.rng.permutation(n)
            for s in range(0, n - self.batch_size + 1, self.batch_size):
                idx = perm[s : s + self.batch_size]
                batch = {k: v[idx] for k, v in self.data.items()}
                key, sub = jax.random.split(key)
                if self.use_dp:
                    params, opt_state, aux = _dp_sgd_step(
                        params, opt_state, batch, sub,
                        loss_fn=self.loss_fn, dp_cfg=self.dp_cfg, opt=self.opt,
                        dp_path=self.dp_path,
                    )
                else:
                    params, opt_state, loss = _sgd_step(
                        params, opt_state, batch, loss_fn=self.loss_fn, opt=self.opt
                    )
                    losses.append(float(loss))
                steps += 1

        self.opt_state = opt_state
        if self.use_dp and steps > 0:
            self.accountant.step(self.q, self.dp_cfg.noise_multiplier, steps)
        duration = self.clock.round_duration()
        self.update_count += 1
        info = {
            "duration": duration,
            "dp_steps": steps,
            "epsilon": self.accountant.epsilon(1e-5) if self.use_dp else 0.0,
        }
        if self.personal_keys:
            # keep the trained personal subtrees on-device; the uploaded
            # model carries the UNTOUCHED global values for those keys
            self._personal = {k: params[k] for k in self.personal_keys}
            upload = dict(params)
            for k in self.personal_keys:
                upload[k] = global_params[k]
            return upload, info
        return params, info

    def evaluate(self, params, accuracy_fn) -> float:
        if self.personal_keys and self._personal is not None:
            params = dict(params)
            params.update(self._personal)
        return float(accuracy_fn(params, self.test_data))
