"""Wires the full paper testbed: synthetic CREMA-D + 5 heterogeneous
clients (HW_T1..T5) + SER CNN + DP-SGD + server loops.

This is the entry point the benchmarks and examples use; every paper
figure/table is a function of (strategy, alpha, sigma, rounds, seed).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from functools import lru_cache, partial
from typing import Optional

import jax
import numpy as np

from repro.core.aggregation import make_strategy
from repro.core.client import Client
from repro.core.dp import DPConfig
from repro.core.heterogeneity import PROFILES, TIERS
from repro.core.server import run_async, run_fedavg
from repro.data.partition import dirichlet_partition, iid_partition
from repro.data.synthetic_ser import SERDataConfig, generate, train_test_split
from repro.models import ser_cnn
from repro.optim.optimizers import Adam


@dataclass(frozen=True)
class TestbedConfig:
    __test__ = False               # keep pytest from collecting this class

    num_clients: int = 5           # >5 cycles the hardware tiers T1..T5
    batch_size: int = 128          # paper: B = 128
    local_epochs: int = 1          # paper: E = 1
    lr: float = 1e-3               # paper: Adam 1e-3
    clip_norm: float = 1.0         # paper: C = 1
    sigma: float = 1.0             # paper sweeps {0.5, 1, 1.5, 2}
    use_dp: bool = True
    use_kernel: bool = False       # route clipping through the Pallas kernel
    personalized: bool = False     # per-client local output head (beyond-paper)
    partition: str = "iid"         # iid (paper) | dirichlet (beyond-paper)
    dirichlet_alpha: float = 0.5
    seed: int = 0
    data: SERDataConfig = SERDataConfig()
    model: ser_cnn.SERConfig = ser_cnn.SERConfig()


@lru_cache(maxsize=None)
def _shared_loss_fn(model_cfg):
    """One loss closure per model config: jitted steps key on the loss
    object (static arg / engine step cache), so sharing it across
    testbeds lets repeated runs reuse compiled programs instead of
    re-tracing per build_testbed call."""
    return partial(ser_cnn.loss_fn, cfg=model_cfg)


@lru_cache(maxsize=None)
def _shared_accuracy_fn(model_cfg):
    return ser_cnn.make_accuracy_fn(model_cfg)


def build_testbed(cfg: TestbedConfig):
    """Returns (clients, global_params, accuracy_fn, pooled_test)."""
    raw = generate(cfg.data)
    if cfg.partition == "dirichlet":
        parts = dirichlet_partition(raw, cfg.num_clients,
                                    alpha=cfg.dirichlet_alpha, seed=cfg.seed)
    else:
        parts = iid_partition(raw, cfg.num_clients, seed=cfg.seed)

    loss = _shared_loss_fn(cfg.model)
    acc_fn = _shared_accuracy_fn(cfg.model)
    opt = Adam(lr=cfg.lr)
    dp_cfg = DPConfig(
        clip_norm=cfg.clip_norm,
        noise_multiplier=cfg.sigma if cfg.use_dp else 0.0,
        granularity="per_example",
    )

    clients, test_pool = [], []
    for cid, part in enumerate(parts):
        tier = TIERS[cid % len(TIERS)]  # >5 clients: cycle the tiers
        tr, te = train_test_split(part, test_frac=0.2, seed=cfg.seed + cid)
        tr = {k: v for k, v in tr.items() if k != "speaker"}
        te = {k: v for k, v in te.items() if k != "speaker"}
        clients.append(
            Client(
                cid=cid,
                tier=tier,
                profile=PROFILES[tier],
                data=tr,
                test_data=te,
                loss_fn=loss,
                dp_cfg=dp_cfg,
                opt=opt,
                batch_size=cfg.batch_size,
                local_epochs=cfg.local_epochs,
                seed=cfg.seed,
                use_dp=cfg.use_dp,
                use_kernel=cfg.use_kernel,
                personal_keys=("out",) if cfg.personalized else (),
            )
        )
        test_pool.append(te)

    pooled_test = {
        k: np.concatenate([t[k] for t in test_pool]) for k in test_pool[0]
    }
    params = ser_cnn.init(jax.random.PRNGKey(cfg.seed), cfg.model)
    return clients, params, acc_fn, pooled_test


def run_experiment(
    strategy_name: str,
    cfg: TestbedConfig = TestbedConfig(),
    rounds: int = 60,
    max_updates: int = 300,
    alpha: float = 0.4,
    staleness_aware: bool = True,
    target_acc: Optional[float] = None,
    eval_every: int = 1,
    engine: str = "cohort",
    engine_cfg=None,
    mesh=None,
    **strategy_kw,
):
    """One full FL run; returns (params, RunLog).

    ``engine`` selects the execution path: "cohort" (the batched engine in
    repro.engine, default) or "legacy" (the per-client reference loop).
    ``mesh`` (cohort engine only) partitions the cohort client axis over
    the mesh's data axes — pair it with
    ``engine_cfg=EngineConfig(client_axis="vmap" or "fl_step", ...)``.
    The cohort engine runs the device-resident arena data path by default
    (datasets upload once, cohorts assemble on device from int32 index
    plans, padded so they always partition on a mesh);
    ``EngineConfig(device_arena=False)`` selects the host-fed baseline.
    """
    clients, params, acc_fn, pooled_test = build_testbed(cfg)
    if strategy_name == "fedavg":
        return run_fedavg(
            clients, params, acc_fn, pooled_test,
            rounds=rounds, seed=cfg.seed, target_acc=target_acc,
            eval_every=eval_every, engine=engine, engine_cfg=engine_cfg,
            mesh=mesh,
        )
    if strategy_name in ("fedasync", "fedasync_nostale", "fedbuff", "adaptive_async"):
        kw = dict(alpha=alpha)
        if strategy_name == "fedasync":
            kw["staleness_aware"] = staleness_aware
        kw.update(strategy_kw)
        strat = make_strategy(strategy_name, **kw)
        return run_async(
            clients, params, acc_fn, pooled_test, strat,
            max_updates=max_updates, seed=cfg.seed, target_acc=target_acc,
            eval_every=max(1, eval_every), engine=engine,
            engine_cfg=engine_cfg, mesh=mesh,
        )
    raise ValueError(strategy_name)
