"""Wires the full paper testbed: synthetic CREMA-D + heterogeneous
clients (HW_T1..T5) + a registry-selected workload model + DP-SGD +
server loops.

Every paper figure/table is a function of (strategy, alpha, sigma,
rounds, seed).  The preferred frontend is the declarative API in
:mod:`repro.api` (``ExperimentSpec`` + ``Session`` — scenario sweeps
reuse datasets, device arenas and compiled steps across runs);
:func:`run_experiment` remains as a thin shim over it with its exact
historical signature.

The build is split into cache-friendly layers the Session keys on:

  * :func:`build_partitions` — generate + partition + split the dataset
    (pure numpy, the expensive host work; keyed by
    :func:`partition_key`);
  * :func:`build_clients`    — wrap partitions in ``Client`` objects
    (cheap; depends on the full config: DP, optimizer, batch size);
  * :func:`build_testbed`    — both plus the workload's initial params
    and eval closure (the historical one-shot entry point).

The model family is pluggable: ``TestbedConfig.workload`` names an entry
in :mod:`repro.api.workloads` (``"ser_cnn"`` — the paper's CNN — by
default), whose memoized loss/accuracy closures keep jitted steps shared
across repeated builds.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import numpy as np

from repro.core.client import Client
from repro.core.dp import DPConfig
from repro.core.faults import FaultModel
from repro.core.screening import ScreeningConfig
from repro.core.heterogeneity import PROFILES, TIERS
from repro.data.partition import dirichlet_partition, iid_partition
from repro.data.synthetic_ser import SERDataConfig, generate, train_test_split
from repro.models import ser_cnn
from repro.optim.optimizers import Adam


@dataclass(frozen=True)
class TestbedConfig:
    __test__ = False               # keep pytest from collecting this class

    num_clients: int = 5           # >5 cycles the hardware tiers T1..T5
    batch_size: int = 128          # paper: B = 128
    local_epochs: int = 1          # paper: E = 1
    lr: float = 1e-3               # paper: Adam 1e-3
    clip_norm: float = 1.0         # paper: C = 1
    sigma: float = 1.0             # paper sweeps {0.5, 1, 1.5, 2}
    use_dp: bool = True
    dp_path: str = "jnp"           # "jnp" | "pallas": per-example clip+noise
                                   # via the fused Pallas kernel hot path
    personalized: bool = False     # per-client local output head (beyond-paper)
    partition: str = "iid"         # iid (paper) | dirichlet (beyond-paper)
    dirichlet_alpha: float = 0.5
    seed: int = 0
    data: SERDataConfig = SERDataConfig()
    model: ser_cnn.SERConfig = ser_cnn.SERConfig()
    workload: str = "ser_cnn"      # repro.api.workloads registry entry
    faults: Optional[FaultModel] = None  # deterministic fault injection
                                   # (core.faults; None = fault-free run)
    screening: Optional[ScreeningConfig] = None  # update screening /
                                   # quarantine (core.screening; None =
                                   # every delivered upload merges)


def partition_key(cfg: TestbedConfig) -> tuple:
    """The fields :func:`build_partitions` actually depends on — sweeps
    that only touch anything else (sigma, strategy, engine, batch size,
    workload) reuse the generated partitions."""
    return (cfg.data, cfg.partition, cfg.dirichlet_alpha,
            cfg.num_clients, cfg.seed)


def build_partitions(cfg: TestbedConfig):
    """Generate the synthetic corpus, partition it across clients and
    train/test-split each share.  Returns ``(splits, pooled_test)`` where
    ``splits[cid] = (train, test)`` dicts (speaker column dropped)."""
    raw = generate(cfg.data)
    if cfg.partition == "dirichlet":
        parts = dirichlet_partition(raw, cfg.num_clients,
                                    alpha=cfg.dirichlet_alpha, seed=cfg.seed)
    else:
        parts = iid_partition(raw, cfg.num_clients, seed=cfg.seed)
    splits, test_pool = [], []
    for cid, part in enumerate(parts):
        tr, te = train_test_split(part, test_frac=0.2, seed=cfg.seed + cid)
        tr = {k: v for k, v in tr.items() if k != "speaker"}
        te = {k: v for k, v in te.items() if k != "speaker"}
        splits.append((tr, te))
        test_pool.append(te)
    pooled_test = {
        k: np.concatenate([t[k] for t in test_pool]) for k in test_pool[0]
    }
    return splits, pooled_test


def build_clients(cfg: TestbedConfig, splits) -> list:
    """Wrap pre-built partitions in Client objects (tier cycling for >5
    clients; the workload's shared loss closure keeps jitted steps
    common across builds)."""
    from repro.api.workloads import get_workload
    from repro.core.dp import validate_dp_path
    validate_dp_path(cfg.dp_path)
    wl = get_workload(cfg.workload)
    loss = wl.shared_loss(cfg.model)
    opt = Adam(lr=cfg.lr)
    dp_cfg = DPConfig(
        clip_norm=cfg.clip_norm,
        noise_multiplier=cfg.sigma if cfg.use_dp else 0.0,
        granularity="per_example",
    )
    clients = []
    for cid, (tr, te) in enumerate(splits):
        tier = TIERS[cid % len(TIERS)]  # >5 clients: cycle the tiers
        clients.append(
            Client(
                cid=cid,
                tier=tier,
                profile=PROFILES[tier],
                data=tr,
                test_data=te,
                loss_fn=loss,
                dp_cfg=dp_cfg,
                opt=opt,
                batch_size=cfg.batch_size,
                local_epochs=cfg.local_epochs,
                seed=cfg.seed,
                use_dp=cfg.use_dp,
                dp_path=cfg.dp_path,
                personal_keys=("out",) if cfg.personalized else (),
            )
        )
    return clients


def build_testbed(cfg: TestbedConfig):
    """Returns (clients, global_params, accuracy_fn, pooled_test)."""
    from repro.api.workloads import get_workload
    wl = get_workload(cfg.workload)
    splits, pooled_test = build_partitions(cfg)
    clients = build_clients(cfg, splits)
    acc_fn = wl.shared_accuracy(cfg.model)
    params = wl.init(jax.random.PRNGKey(cfg.seed), cfg.model)
    return clients, params, acc_fn, pooled_test


def run_experiment(
    strategy_name: str,
    cfg: TestbedConfig = TestbedConfig(),
    rounds: int = 60,
    max_updates: int = 300,
    alpha: float = 0.4,
    staleness_aware: bool = True,
    target_acc: Optional[float] = None,
    eval_every: int = 1,
    engine: str = "cohort",
    engine_cfg=None,
    mesh=None,
    **strategy_kw,
):
    """One full FL run; returns (params, RunLog).

    Thin shim over the declarative API: the arguments are folded into an
    :class:`repro.api.ExperimentSpec` (strategy name/params validated at
    construction) and executed by a fresh one-run
    :class:`repro.api.Session` — bit-identical to calling the API
    directly (the shim-parity tests assert it).  For scenario SWEEPS use
    a shared Session, which keeps datasets, device arenas and compiled
    steps warm across the points instead of rebuilding per call.

    ``engine`` selects the execution path: "cohort" (the batched engine
    in repro.engine, default) or "legacy" (the per-client reference
    loop).  ``mesh`` (cohort engine only) partitions the cohort client
    axis over the mesh's data axes — pair it with
    ``engine_cfg=EngineConfig(client_axis="vmap" or "fl_step", ...)``.
    """
    from repro.api import ExperimentSpec, Session
    spec = ExperimentSpec.from_legacy(
        strategy_name, cfg, rounds=rounds, max_updates=max_updates,
        alpha=alpha, staleness_aware=staleness_aware, target_acc=target_acc,
        eval_every=eval_every, engine=engine, engine_cfg=engine_cfg,
        mesh=mesh, **strategy_kw)
    return Session().run(spec)
