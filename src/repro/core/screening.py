"""Update screening and quarantine for corrupt-update defense.

The paper's low-tier devices ship infrequent, stale, noise-perturbed
updates whose outlier geometry degrades the global model — and async DP
schemes amplify the damage because every arriving update merges
immediately, with no cross-client cross-check (van Dijk et al.
2007.09208; Xu et al. 2402.10991 weight async contributions by quality
for exactly this reason).  PR 8 made the *control plane* robust (loss,
crash, churn — RESILIENCE.md); this module defends the *data plane*:

* :class:`ScreeningConfig` — a frozen, spec-serializable screening
  policy on ``TestbedConfig.screening`` (registered in the
  :mod:`repro.api.spec` codec).  ``None`` disables screening entirely.
* per-member screen verdicts — the compiled cohort step ALWAYS computes
  a ``(finite, update_norm)`` pair per stacked member over the
  float32 update delta (see ``make_cohort_step``); threshold comparison
  happens on the HOST, so one compiled program serves screening on/off
  and every threshold (the PR-5 one-program sweep invariant:
  ``step_builds`` delta 0).  :func:`screen_update` is the host-side
  mirror the legacy loops use, and :func:`corrupt_update` the host-side
  mirror of the in-step transit corruption.
* :class:`ScreeningState` — the deterministic host-side runtime:
  rejection verdicts, per-client strike counters, quarantine suspension
  after ``quarantine_after`` strikes, re-admission after
  ``readmit_delay_s`` virtual seconds.  A rejected or quarantined
  member is NOT ejected from its compiled cohort — it keeps its padded
  slot and its merge coefficient becomes exactly ``0.0``, the same PR-3
  mask machinery that absorbs lost updates.

Determinism contract
--------------------
Screening draws no randomness at all: verdicts are pure functions of
the (deterministic) update payloads and the delivery times already
fixed by the virtual clock + :class:`~repro.core.faults.FaultInjector`.
Both execution backends invoke :meth:`ScreeningState.screen` at the
same logical points in the same ``(time, cid)`` delivery order, so the
same seed + same configs replay the identical rejection/quarantine
event sequence on the legacy loop and the cohort engine, across
``pipeline_depth`` settings, and across a checkpoint/resume boundary
(:meth:`ScreeningState.state_dict` rides in the snapshot meta).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

# The screening counters appended to repro.core.runlog.ENGINE_STATS_KEYS
# (all zero when screening is off, so the stats schema stays
# unconditional).  Ledger law, enforced by
# repro.analysis.audits.audit_engine_stats:
#
#     screen_rejections == screen_nonfinite + screen_norm_rejects
#
# Quarantine drops are counted separately (a suspended client's delivery
# is dropped BEFORE its verdict is consulted, so it is not a rejection).
SCREEN_STATS_KEYS = (
    "screen_rejections",        # in-step verdict rejections (sum of next two)
    "screen_nonfinite",         # rejected: NaN/Inf anywhere in the update
    "screen_norm_rejects",      # rejected: update norm above max_update_norm
    "screen_quarantined",       # suspension events (quarantine_after strikes)
    "screen_quarantine_drops",  # deliveries dropped while suspended
    "screen_verdict_syncs",     # sanctioned device->host verdict fetches
)


def zero_screen_stats() -> dict:
    """The screening counters of a screening-off run."""
    return {k: 0 for k in SCREEN_STATS_KEYS}


@dataclass(frozen=True)
class ScreeningConfig:
    """Spec-serializable update-screening policy (see module docstring).

    All fields are JSON scalars; validation happens at construction so a
    bad policy never reaches a run.  The finite check is unconditional
    once screening is on; ``max_update_norm=None`` disables only the
    norm threshold, ``quarantine_after=0`` disables quarantine."""

    max_update_norm: Optional[float] = None  # L2 reject threshold on the
                                             # float32 update delta; None
                                             # = finite-check only
    quarantine_after: int = 0                # strikes before suspension;
                                             # 0 = quarantine off
    readmit_delay_s: float = 600.0           # virtual-time suspension length

    def __post_init__(self):
        if self.max_update_norm is not None and not self.max_update_norm > 0:
            raise ValueError(
                f"ScreeningConfig.max_update_norm must be > 0 or None: "
                f"{self.max_update_norm!r}")
        if (self.quarantine_after < 0
                or self.quarantine_after != int(self.quarantine_after)):
            raise ValueError(
                f"ScreeningConfig.quarantine_after must be an int >= 0: "
                f"{self.quarantine_after!r}")
        if self.quarantine_after > 0 and not self.readmit_delay_s > 0:
            raise ValueError(
                f"ScreeningConfig.readmit_delay_s must be > 0 when "
                f"quarantine is on: {self.readmit_delay_s!r}")


def screen_update(params_ref, params_k) -> tuple:
    """Host-side mirror of the compiled per-member screen pass: the
    ``(finite, norm)`` verdict inputs for ONE update, computed over the
    float32 delta ``params_k - params_ref`` with the same leaf-order
    accumulation the stacked in-step pass uses.  The legacy loops call
    this; the cohort engine reads the same quantities out of the
    compiled step's screen outputs."""
    sq = jnp.float32(0.0)
    for p0, p in zip(jax.tree_util.tree_leaves(params_ref),
                     jax.tree_util.tree_leaves(params_k)):
        d = jnp.asarray(p, jnp.float32) - jnp.asarray(p0, jnp.float32)
        sq = sq + jnp.sum(d * d)
    norm = jnp.sqrt(sq)
    return bool(jnp.isfinite(norm)), float(norm)


def corrupt_update(params_ref, params_k, scale: float):
    """Host-side mirror of the in-step transit corruption: the payload
    delivered to the server becomes ``p0 + scale * (p - p0)`` (float32,
    elementwise — bitwise identical to the compiled step's
    ``where(scale == 1.0, p, p0 + scale * (p - p0))`` branch).  The
    client's own local state keeps the honestly-trained params; only
    the uploaded copy is corrupted.  ``scale == 1.0`` is the clean
    sentinel and returns ``params_k`` unchanged (bit-identity)."""
    if scale == 1.0:
        return params_k
    s = jnp.float32(scale)
    return jax.tree_util.tree_map(
        lambda p0, p: jnp.asarray(p0, jnp.float32)
        + s * (jnp.asarray(p, jnp.float32) - jnp.asarray(p0, jnp.float32)),
        params_ref, params_k)


class ScreeningState:
    """Deterministic host-side screening runtime shared by both
    execution backends.  The loops call exactly one entry point per
    delivered update — :meth:`screen` — in ``(time, cid)`` delivery
    order; the state owns the strike/suspension bookkeeping, the
    counters behind :data:`SCREEN_STATS_KEYS` (minus the runner-owned
    ``screen_verdict_syncs``) and an ordered ``events`` ledger appended
    to ``RunLog.fault_events``.  Serializes via :meth:`state_dict` so a
    checkpointed run resumes mid-quarantine bit-identically."""

    def __init__(self, cfg: ScreeningConfig, num_clients: int):
        self.cfg = cfg
        self._strikes = [0] * num_clients
        self._suspended_until = [None] * num_clients
        self.counters = {k: 0 for k in SCREEN_STATS_KEYS
                         if k != "screen_verdict_syncs"}
        self.events = []    # ordered (kind, cid, t) tuples

    def _record(self, kind: str, counter: Optional[str], cid: int, t: float):
        if counter is not None:
            self.counters[counter] += 1
        self.events.append((kind, cid, float(t)))

    def screen(self, cid: int, t: float, finite, norm) -> bool:
        """Resolve one delivered update at virtual time ``t``; returns
        True when the update may merge.  Order: quarantine gate first
        (a suspended client's delivery drops WITHOUT consulting the
        verdict), then the finite/norm verdict, then strike/quarantine
        bookkeeping on a rejection."""
        su = self._suspended_until[cid]
        if su is not None:
            if t < su:
                self._record("quarantine_drop", "screen_quarantine_drops",
                             cid, t)
                return False
            self._suspended_until[cid] = None
            self._record("readmit", None, cid, t)
        finite, norm = bool(finite), float(norm)
        ok = finite and (self.cfg.max_update_norm is None
                         or norm <= float(self.cfg.max_update_norm))
        if ok:
            return True
        self.counters["screen_rejections"] += 1
        self._record("screen_nonfinite" if not finite else "screen_norm",
                     "screen_nonfinite" if not finite else
                     "screen_norm_rejects", cid, t)
        if self.cfg.quarantine_after > 0:
            self._strikes[cid] += 1
            if self._strikes[cid] >= self.cfg.quarantine_after:
                self._strikes[cid] = 0
                self._suspended_until[cid] = t + float(self.cfg.readmit_delay_s)
                self._record("quarantine", "screen_quarantined", cid, t)
        return False

    def stats(self) -> dict:
        return dict(self.counters)

    # -- checkpoint serialization -------------------------------------------
    def state_dict(self) -> dict:
        """JSON-able snapshot of strikes, suspensions, counters and the
        event ledger — restoring it resumes quarantine bookkeeping
        exactly where the checkpoint left it."""
        return {
            "strikes": list(self._strikes),
            "suspended_until": [None if s is None else float(s)
                                for s in self._suspended_until],
            "counters": dict(self.counters),
            "events": [list(e) for e in self.events],
        }

    def load_state_dict(self, state: dict):
        self._strikes = [int(s) for s in state["strikes"]]
        self._suspended_until = [None if s is None else float(s)
                                 for s in state["suspended_until"]]
        self.counters = {k: 0 for k in SCREEN_STATS_KEYS
                         if k != "screen_verdict_syncs"}
        self.counters.update(state["counters"])
        self.events = [(str(k), int(cid), float(t))
                       for k, cid, t in state["events"]]


__all__ = ["SCREEN_STATS_KEYS", "zero_screen_stats", "ScreeningConfig",
           "ScreeningState", "screen_update", "corrupt_update"]
