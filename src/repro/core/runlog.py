"""Run bookkeeping shared by the legacy per-client loop and the cohort
engine: everything the paper's figures/tables need (accuracy-vs-virtual-
time, per-client participation, staleness, epsilon trajectories, resource
samples), plus engine-side cohort statistics.

Lives in its own module so both ``repro.core.server`` (legacy loops) and
``repro.engine`` (cohort-batched loops) can import it without a cycle.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.core.fairness import fairness_report
from repro.core.faults import FAULT_STATS_KEYS
from repro.core.screening import SCREEN_STATS_KEYS

# Tiered client-state store counters (see STORE.md).  Defined HERE, not
# in ``repro.engine.statestore``, for the same no-cycle reason this
# module exists at all: ``repro.core`` must not import ``repro.engine``,
# while the store (engine-side) imports the schema from here so the
# producer and the frozen schema cannot drift apart.
STORE_STATS_KEYS = (
    "store_fetches",         # slot acquisitions demanded by staged cohorts
    "store_hot_hits",        # already device-resident, not via prefetch
    "store_prefetch_hits",   # resident because the lookahead staged it
    "store_stall_waits",     # cohort had to block on a demand load
    "store_evictions",       # hot slots surrendered to LRU pressure
    "store_spill_bytes",     # device->host bytes of dirty-row spills
    "store_sync_reads",      # _host_fetch-funnelled reads tagged _in_store
)

# THE schema for ``RunLog.engine_stats`` — the exact keys
# ``CohortRunner.stats()`` produces.  Frozen here (not derived at a use
# site) so every consumer of engine provenance pulls from one place:
# ``repro.analysis.audits.audit_engine_stats`` validates recorded logs
# against it, ``benchmarks/summarize.py --check-engine`` validates bench
# rows against it, and ``tests/test_engine_stats_schema.py`` pins
# ``CohortRunner.stats()`` itself to it.  Adding a counter to the engine
# without extending this tuple (and the docs below) fails CI instead of
# silently drifting the bench/analysis contract.
ENGINE_STATS_KEYS = (
    "data_path",                 # "arena" | "host"
    "dp_path",                   # "jnp" | "pallas"
    "pallas_interpret",          # interpret_info() dict, or None off-pallas
    "cohorts",                   # cohorts merged this run
    "h2d_bytes_total",           # host->device staging traffic (bytes)
    "h2d_bytes_per_cohort",      # h2d_bytes_total / cohorts
    "pipeline_depth",            # EngineConfig.pipeline_depth
    "host_syncs_at_eval",        # sanctioned _host_fetch blocking points
    "host_syncs_between_evals",  # MUST be 0 on the pipelined path
    "blocking_submits",          # serial path's donation-chained submits
    "drain_waits",               # pipelined backpressure waits
    # fault/retry/degraded-round counters (repro.core.faults; all zero on
    # a fault-free run — the schema is unconditional so --check-engine
    # and the audits validate every row the same way)
) + FAULT_STATS_KEYS + (
    # update-screening / quarantine counters (repro.core.screening; all
    # zero when TestbedConfig.screening is None, same unconditional-
    # schema rationale; ledger law enforced by the audits:
    # screen_rejections == screen_nonfinite + screen_norm_rejects)
) + SCREEN_STATS_KEYS + (
    # tiered client-state store counters (repro.engine.statestore; all
    # zero on an all-resident run — StoreConfig.hot_slots is None — same
    # unconditional-schema rationale; ledger law enforced by the audits:
    # store_fetches == store_hot_hits + store_prefetch_hits
    #                  + store_stall_waits)
) + STORE_STATS_KEYS


def validate_engine_stats(stats: dict, context: str = "engine_stats"):
    """Assert ``stats`` carries exactly :data:`ENGINE_STATS_KEYS`.

    Called by the engine loops when they record ``RunLog.engine_stats``
    and by the analysis/bench consumers when they read it back, so a
    renamed or dropped counter fails at the producer AND the consumer.
    """
    if not isinstance(stats, dict):
        raise TypeError(f"{context} must be a dict: {stats!r}")
    got = set(stats)
    want = set(ENGINE_STATS_KEYS)
    missing, extra = sorted(want - got), sorted(got - want)
    if missing or extra:
        raise ValueError(
            f"{context} keys drifted from RunLog.ENGINE_STATS_KEYS — "
            f"missing: {missing or 'none'}, unexpected: {extra or 'none'}")
    return stats


@dataclass
class RunLog:
    strategy: str
    # time series (one entry per server event / round)
    times: list = field(default_factory=list)
    global_acc: list = field(default_factory=list)
    server_version: list = field(default_factory=list)
    # per client
    update_counts: dict = field(default_factory=dict)
    influence: dict = field(default_factory=dict)   # sum of applied merge weights
    staleness: dict = field(default_factory=dict)
    eps_trajectory: dict = field(default_factory=dict)
    local_acc: dict = field(default_factory=dict)
    resources: dict = field(default_factory=dict)
    dropouts: dict = field(default_factory=dict)
    # engine-only: size of each merged cohort (legacy loops leave it empty)
    cohort_sizes: list = field(default_factory=list)
    # engine-only: data-path + scheduler counters from
    # CohortRunner.stats() — which path ran ("arena" | "host"), the
    # per-cohort H2D byte traffic, and the pipelined-scheduler sync
    # accounting (pipeline_depth, host_syncs_between_evals — 0 on the
    # pipelined path, blocking_submits — the serial path's per-cohort
    # donation syncs, drain_waits — overlapped backpressure waits)
    engine_stats: dict = field(default_factory=dict)
    # ordered (kind, cid, virtual_time) fault events from the
    # FaultInjector (empty without a FaultModel) — recorded by BOTH
    # backends, so same-seed fault replay is asserted by list equality
    fault_events: list = field(default_factory=list)

    def time_to_accuracy(self, target: float) -> Optional[float]:
        for t, a in zip(self.times, self.global_acc):
            if a >= target:
                return t
        return None

    def fairness(self) -> dict:
        final_acc = {k: (v[-1] if v else 0.0) for k, v in self.local_acc.items()}
        final_eps = {k: (v[-1] if v else 0.0) for k, v in self.eps_trajectory.items()}
        rep = fairness_report(self.update_counts, final_acc, final_eps)
        total_w = sum(self.influence.values())
        if total_w > 0:
            rep["influence_pct"] = {
                k: 100.0 * v / total_w for k, v in self.influence.items()}
        return rep


def eval_all(clients, params, accuracy_fn, log: RunLog):
    """Append every client's local-test accuracy to the log."""
    for c in clients:
        log.local_acc.setdefault(c.tier, []).append(c.evaluate(params, accuracy_fn))
