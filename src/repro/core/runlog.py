"""Run bookkeeping shared by the legacy per-client loop and the cohort
engine: everything the paper's figures/tables need (accuracy-vs-virtual-
time, per-client participation, staleness, epsilon trajectories, resource
samples), plus engine-side cohort statistics.

Lives in its own module so both ``repro.core.server`` (legacy loops) and
``repro.engine`` (cohort-batched loops) can import it without a cycle.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.core.fairness import fairness_report


@dataclass
class RunLog:
    strategy: str
    # time series (one entry per server event / round)
    times: list = field(default_factory=list)
    global_acc: list = field(default_factory=list)
    server_version: list = field(default_factory=list)
    # per client
    update_counts: dict = field(default_factory=dict)
    influence: dict = field(default_factory=dict)   # sum of applied merge weights
    staleness: dict = field(default_factory=dict)
    eps_trajectory: dict = field(default_factory=dict)
    local_acc: dict = field(default_factory=dict)
    resources: dict = field(default_factory=dict)
    dropouts: dict = field(default_factory=dict)
    # engine-only: size of each merged cohort (legacy loops leave it empty)
    cohort_sizes: list = field(default_factory=list)
    # engine-only: data-path + scheduler counters from
    # CohortRunner.stats() — which path ran ("arena" | "host"), the
    # per-cohort H2D byte traffic, and the pipelined-scheduler sync
    # accounting (pipeline_depth, host_syncs_between_evals — 0 on the
    # pipelined path, blocking_submits — the serial path's per-cohort
    # donation syncs, drain_waits — overlapped backpressure waits)
    engine_stats: dict = field(default_factory=dict)

    def time_to_accuracy(self, target: float) -> Optional[float]:
        for t, a in zip(self.times, self.global_acc):
            if a >= target:
                return t
        return None

    def fairness(self) -> dict:
        final_acc = {k: (v[-1] if v else 0.0) for k, v in self.local_acc.items()}
        final_eps = {k: (v[-1] if v else 0.0) for k, v in self.eps_trajectory.items()}
        rep = fairness_report(self.update_counts, final_acc, final_eps)
        total_w = sum(self.influence.values())
        if total_w > 0:
            rep["influence_pct"] = {
                k: 100.0 * v / total_w for k, v in self.influence.items()}
        return rep


def eval_all(clients, params, accuracy_fn, log: RunLog):
    """Append every client's local-test accuracy to the log."""
    for c in clients:
        log.local_acc.setdefault(c.tier, []).append(c.evaluate(params, accuracy_fn))
