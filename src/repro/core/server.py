"""FL server loops (paper Algorithm 1, server side) over a virtual clock.

* :func:`run_fedavg`  — synchronous barrier rounds; round time is the MAX
  over clients (the straggler effect emerges from the tier clocks).
* :func:`run_async`   — event-driven loop: a priority queue of client
  completion events; each completion is merged immediately (FedAsync) or
  buffered (FedBuff).  Staleness tau_k = server_version - client_version.

Both are now thin frontends over two interchangeable execution paths:

* ``engine="cohort"`` (default) — the cohort-batched engine in
  :mod:`repro.engine`: completions within a staleness-tolerance window run
  as ONE jitted scan+vmap program with a fused weights-vector merge.
* ``engine="legacy"``           — the original per-client Python event
  loop below (one jitted step per client per minibatch), kept as the
  reference implementation for the parity tests.

Both return a :class:`RunLog` with everything the paper's figures/tables
need: accuracy-vs-virtual-time, per-client participation, staleness,
epsilon trajectories, and resource samples.
"""
from __future__ import annotations

import heapq
from typing import Callable, Optional

import jax

from repro.core.aggregation import AdaptiveAsync, apply_update
from repro.core.runlog import RunLog, eval_all

# back-compat alias: RunLog used to live here
_eval_all = eval_all


def _normalize_eval_every(eval_every: int) -> int:
    """Route the eval cadence through the ONE validation point
    (``repro.api.spec.RunBudget``): ``eval_every=0`` used to reach the
    fedavg loop raw and die on ``rnd % 0`` while the async frontend
    clamped it — both frontends now share the RunBudget normalization.
    Imported lazily: repro.api sits above this module."""
    from repro.api.spec import RunBudget
    return RunBudget(eval_every=eval_every).eval_every


def run_fedavg(
    clients: list,
    global_params,
    accuracy_fn: Callable,
    test_data: dict,
    rounds: int = 60,
    seed: int = 0,
    eval_every: int = 1,
    target_acc: Optional[float] = None,
    engine: str = "cohort",
    engine_cfg=None,
    mesh=None,
    faults=None,
    checkpoint=None,
    resume_from=None,
    strategy=None,
    screening=None,
) -> tuple:
    """Synchronous FedAvg (Eq. 9).  Returns (final_params, RunLog).

    ``mesh`` (a ``launch.mesh`` mesh) partitions the cohort engine's
    client axis over the mesh's data axes — cohort-engine only.
    ``faults`` (a :class:`repro.core.faults.FaultModel`) injects the same
    deterministic fault sequence on either execution path;
    ``checkpoint``/``resume_from`` (cohort-engine only) snapshot and
    resume the run — see :mod:`repro.engine.resilience`.
    ``strategy`` selects the synchronous aggregator (default plain
    FedAvg; ``TrimmedMeanFedAvg`` is the robust variant); ``screening``
    (a :class:`repro.core.screening.ScreeningConfig`) rejects
    nonfinite/oversized uploads identically on both paths."""
    eval_every = _normalize_eval_every(eval_every)
    if engine == "cohort":
        from repro.engine import run_fedavg_engine
        return run_fedavg_engine(
            clients, global_params, accuracy_fn, test_data, rounds=rounds,
            seed=seed, eval_every=eval_every, target_acc=target_acc,
            engine_cfg=engine_cfg, mesh=mesh, faults=faults,
            checkpoint=checkpoint, resume_from=resume_from,
            strategy=strategy, screening=screening)
    if engine != "legacy":
        raise ValueError(f"unknown execution engine: {engine!r}")
    if mesh is not None:
        raise ValueError("mesh execution requires engine='cohort'")
    if checkpoint is not None or resume_from is not None:
        raise ValueError("checkpoint/resume requires engine='cohort' — the "
                         "legacy reference loop has no snapshot support")
    return _run_fedavg_legacy(
        clients, global_params, accuracy_fn, test_data, rounds=rounds,
        seed=seed, eval_every=eval_every, target_acc=target_acc,
        faults=faults, strategy=strategy, screening=screening)


def run_async(
    clients: list,
    global_params,
    accuracy_fn: Callable,
    test_data: dict,
    strategy,                      # FedAsync / FedBuff / AdaptiveAsync
    max_updates: int = 300,
    max_time: Optional[float] = None,
    seed: int = 0,
    eval_every: int = 5,
    target_acc: Optional[float] = None,
    engine: str = "cohort",
    engine_cfg=None,
    mesh=None,
    faults=None,
    checkpoint=None,
    resume_from=None,
    screening=None,
) -> tuple:
    """Event-driven asynchronous FL (Eq. 10-11).

    Every client trains continuously: as soon as its update is merged it
    pulls the fresh globals and starts the next local round.  Completion
    times come from each client's VirtualClock, so fast tiers complete
    many rounds while slow tiers finish one (the paper's participation
    skew emerges, it is not scripted).

    ``mesh`` partitions the cohort engine's client axis over the mesh's
    data axes — cohort-engine only.  ``faults`` injects the same
    deterministic fault sequence on either execution path;
    ``checkpoint``/``resume_from`` (cohort-engine only) snapshot and
    resume the run — see :mod:`repro.engine.resilience`.
    """
    eval_every = _normalize_eval_every(eval_every)
    if engine == "cohort":
        from repro.engine import run_async_engine
        return run_async_engine(
            clients, global_params, accuracy_fn, test_data, strategy,
            max_updates=max_updates, max_time=max_time, seed=seed,
            eval_every=eval_every, target_acc=target_acc,
            engine_cfg=engine_cfg, mesh=mesh, faults=faults,
            checkpoint=checkpoint, resume_from=resume_from,
            screening=screening)
    if engine != "legacy":
        raise ValueError(f"unknown execution engine: {engine!r}")
    if mesh is not None:
        raise ValueError("mesh execution requires engine='cohort'")
    if checkpoint is not None or resume_from is not None:
        raise ValueError("checkpoint/resume requires engine='cohort' — the "
                         "legacy reference loop has no snapshot support")
    return _run_async_legacy(
        clients, global_params, accuracy_fn, test_data, strategy,
        max_updates=max_updates, max_time=max_time, seed=seed,
        eval_every=eval_every, target_acc=target_acc, faults=faults,
        screening=screening)


# ---------------------------------------------------------------------------
# Legacy per-client reference path (parity baseline for the cohort engine)
# ---------------------------------------------------------------------------

def _run_fedavg_legacy(
    clients, global_params, accuracy_fn, test_data,
    rounds=60, seed=0, eval_every=1, target_acc=None, faults=None,
    strategy=None, screening=None,
) -> tuple:
    from repro.core.aggregation import FedAvg
    from repro.core.faults import FaultInjector, apply_deadline
    from repro.core import screening as _scr
    strat = strategy if strategy is not None else FedAvg()
    injector = (FaultInjector(faults, len(clients))
                if faults is not None else None)
    screener = (_scr.ScreeningState(screening, len(clients))
                if screening is not None else None)
    log = RunLog(strategy=strat.name)
    key = jax.random.PRNGKey(seed)
    t_virtual = 0.0
    for c in clients:
        log.update_counts[c.tier] = 0
        log.staleness.setdefault(c.tier, [])
        log.eps_trajectory.setdefault(c.tier, [])

    for rnd in range(1, rounds + 1):
        payloads, durations, infos = [], [], []
        # the round's dispatch globals — the corruption/screening
        # reference (the same snapshot the cohort engine's params0 is)
        g_round = global_params
        for c in clients:
            key, sub = jax.random.split(key)
            params_k, info = c.local_train(global_params, sub)
            if injector is not None and rnd > 1:
                # leave/rejoin churn stretches the member's round (same
                # draw point as the cohort engine's dispatch loop)
                info["duration"] += injector.redispatch_delay(
                    c.cid, t_virtual)
            payloads.append(params_k)
            durations.append(info["duration"])
            infos.append(info)
        t_round0 = t_virtual
        offsets = list(durations)
        if injector is not None:
            offsets = [injector.fedavg_fate(c.cid, t_virtual, d)[0]
                       for c, d in zip(clients, durations)]
            keep, round_time = apply_deadline(injector.model, offsets)
            for i, (c, off, kept) in enumerate(zip(clients, offsets, keep)):
                if off is not None:
                    # transit corruption hits every DELIVERED payload
                    # (even a deadline-dropped one — the scale was drawn,
                    # the payload just never merges)
                    payloads[i] = _scr.corrupt_update(
                        g_round, payloads[i],
                        injector.take_corruption(c.cid))
                    if not kept:
                        injector.note_deadline_drop(c.cid, t_round0 + off)
            if not all(keep):
                injector.note_degraded()
            t_virtual += (round_time if round_time is not None
                          else max(durations))
        else:
            keep = [True] * len(clients)
            # straggler effect: the barrier waits for the slowest client
            t_virtual += max(durations)
        if screener is not None:
            keep = list(keep)
            for i, (c, off) in enumerate(zip(clients, offsets)):
                if not keep[i] or off is None:
                    continue
                fin, nrm = _scr.screen_update(g_round, payloads[i])
                if not screener.screen(c.cid, t_round0 + off, fin, nrm):
                    keep[i] = False
        for c, info, kept in zip(clients, infos, keep):
            if not kept:
                continue
            log.update_counts[c.tier] += 1
            log.staleness[c.tier].append(0)  # barrier => no staleness
            log.eps_trajectory[c.tier].append(info["epsilon"])
        updates = [(p, c.n_train)
                   for c, p, kept in zip(clients, payloads, keep) if kept]
        if updates:
            global_params = strat.aggregate(global_params, updates)

        if rnd % eval_every == 0 or rnd == rounds:
            acc = float(accuracy_fn(global_params, test_data))
            log.times.append(t_virtual)
            log.global_acc.append(acc)
            log.server_version.append(rnd)
            eval_all(clients, global_params, accuracy_fn, log)
            if target_acc is not None and acc >= target_acc:
                break

    for c in clients:
        log.resources[c.tier] = c.clock.resource_sample()
        log.dropouts[c.tier] = c.clock.dropouts
    if injector is not None or screener is not None:
        ev = list(injector.events) if injector is not None else []
        if screener is not None:
            ev += list(screener.events)
        log.fault_events = ev
    return global_params, log


def _run_async_legacy(
    clients, global_params, accuracy_fn, test_data, strategy,
    max_updates=300, max_time=None, seed=0, eval_every=5, target_acc=None,
    faults=None, screening=None,
) -> tuple:
    from repro.core.faults import FaultInjector
    from repro.core import screening as _scr
    injector = (FaultInjector(faults, len(clients))
                if faults is not None else None)
    screener = (_scr.ScreeningState(screening, len(clients))
                if screening is not None else None)
    log = RunLog(strategy=strategy.name)
    key = jax.random.PRNGKey(seed)
    for c in clients:
        log.update_counts[c.tier] = 0
        log.influence.setdefault(c.tier, 0.0)
        log.staleness.setdefault(c.tier, [])
        log.eps_trajectory.setdefault(c.tier, [])

    # Seed the event queue: every client starts training version 0 at t=0.
    # Pending entries carry the DISPATCH-time globals alongside the
    # trained payload: transit corruption and screening both measure the
    # upload against the snapshot the client pulled (params0 in the
    # cohort engine), not the globals at delivery.
    heap = []
    pending = {}
    for c in clients:
        key, sub = jax.random.split(key)
        params_k, info = c.local_train(global_params, sub)
        c.model_version = 0
        pending[c.cid] = (params_k, info, global_params)
        heapq.heappush(heap, (info["duration"], c.cid))

    server_version = 0
    t_virtual = 0.0
    done = False
    while heap and not done:
        t, cid = heapq.heappop(heap)
        c = clients[cid]
        dropped = False
        if injector is not None:
            # resolve the delivery attempt exactly like the cohort engine
            # (same per-client RNG stream, same draw order): ghosts are
            # deduped, retried/late uploads re-enter the heap, lost
            # updates consume the pending round without merging
            verdict, aux = injector.on_completion(cid, t)
            if verdict == "duplicate":
                continue
            if verdict == "requeue":
                heapq.heappush(heap, (aux, cid))
                continue
            if verdict == "drop":
                dropped = True
                injector.note_degraded()
            elif aux is not None:           # deliver + a scheduled dup copy
                heapq.heappush(heap, (aux, cid))
        t_virtual = t
        params_k, info, g_ref = pending.pop(cid)
        if not dropped and injector is not None:
            params_k = _scr.corrupt_update(
                g_ref, params_k, injector.take_corruption(cid))
        if not dropped and screener is not None:
            fin, nrm = _scr.screen_update(g_ref, params_k)
            if not screener.screen(cid, t, fin, nrm):
                dropped = True  # zero-influence reject, same as the engine
        if not dropped:
            tau = server_version - c.model_version
            log.staleness[c.tier].append(tau)
            log.update_counts[c.tier] += 1
            log.eps_trajectory[c.tier].append(info["epsilon"])

            global_params, inc, _w = apply_update(
                strategy, global_params, params_k, tau,
                eps_spent=info["epsilon"])
            server_version += inc
            log.influence[c.tier] += float(_w)

        total_updates = sum(log.update_counts.values())
        if not dropped and total_updates % eval_every == 0:
            acc = float(accuracy_fn(global_params, test_data))
            log.times.append(t_virtual)
            log.global_acc.append(acc)
            log.server_version.append(server_version)
            eval_all(clients, global_params, accuracy_fn, log)
            if target_acc is not None and acc >= target_acc:
                done = True

        if total_updates >= max_updates or (max_time and t_virtual >= max_time):
            done = True

        # joint aggregation-privacy adaptation (beyond-paper, paper Sec. 5):
        # a client that has exhausted its privacy budget STOPS training —
        # down-weighting alone does not cap eps, it only slows convergence
        # while exposure keeps accruing (see EXPERIMENTS.md §Beyond)
        budget_exhausted = (
            isinstance(strategy, AdaptiveAsync)
            and info["epsilon"] >= strategy.eps_target
        )
        if not done and not budget_exhausted:
            # client immediately pulls fresh globals and trains again
            key, sub = jax.random.split(key)
            new_params_k, new_info = c.local_train(global_params, sub)
            c.model_version = server_version
            pending[cid] = (new_params_k, new_info, global_params)
            t_next = t_virtual + new_info["duration"]
            if injector is not None:
                # leave/rejoin churn delays the next local round
                t_next += injector.redispatch_delay(cid, t_virtual)
            heapq.heappush(heap, (t_next, cid))

    for c in clients:
        log.resources[c.tier] = c.clock.resource_sample()
        log.dropouts[c.tier] = c.clock.dropouts
    if injector is not None or screener is not None:
        ev = list(injector.events) if injector is not None else []
        if screener is not None:
            ev += list(screener.events)
        log.fault_events = ev
    return global_params, log
