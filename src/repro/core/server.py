"""FL server loops (paper Algorithm 1, server side) over a virtual clock.

* :func:`run_fedavg`  — synchronous barrier rounds; round time is the MAX
  over clients (the straggler effect emerges from the tier clocks).
* :func:`run_async`   — event-driven loop: a priority queue of client
  completion events; each completion is merged immediately (FedAsync) or
  buffered (FedBuff).  Staleness tau_k = server_version - client_version.

Both are now thin frontends over two interchangeable execution paths:

* ``engine="cohort"`` (default) — the cohort-batched engine in
  :mod:`repro.engine`: completions within a staleness-tolerance window run
  as ONE jitted scan+vmap program with a fused weights-vector merge.
* ``engine="legacy"``           — the original per-client Python event
  loop below (one jitted step per client per minibatch), kept as the
  reference implementation for the parity tests.

Both return a :class:`RunLog` with everything the paper's figures/tables
need: accuracy-vs-virtual-time, per-client participation, staleness,
epsilon trajectories, and resource samples.
"""
from __future__ import annotations

import heapq
from typing import Callable, Optional

import jax

from repro.core.aggregation import AdaptiveAsync, apply_update
from repro.core.runlog import RunLog, eval_all

# back-compat alias: RunLog used to live here
_eval_all = eval_all


def _normalize_eval_every(eval_every: int) -> int:
    """Route the eval cadence through the ONE validation point
    (``repro.api.spec.RunBudget``): ``eval_every=0`` used to reach the
    fedavg loop raw and die on ``rnd % 0`` while the async frontend
    clamped it — both frontends now share the RunBudget normalization.
    Imported lazily: repro.api sits above this module."""
    from repro.api.spec import RunBudget
    return RunBudget(eval_every=eval_every).eval_every


def run_fedavg(
    clients: list,
    global_params,
    accuracy_fn: Callable,
    test_data: dict,
    rounds: int = 60,
    seed: int = 0,
    eval_every: int = 1,
    target_acc: Optional[float] = None,
    engine: str = "cohort",
    engine_cfg=None,
    mesh=None,
) -> tuple:
    """Synchronous FedAvg (Eq. 9).  Returns (final_params, RunLog).

    ``mesh`` (a ``launch.mesh`` mesh) partitions the cohort engine's
    client axis over the mesh's data axes — cohort-engine only."""
    eval_every = _normalize_eval_every(eval_every)
    if engine == "cohort":
        from repro.engine import run_fedavg_engine
        return run_fedavg_engine(
            clients, global_params, accuracy_fn, test_data, rounds=rounds,
            seed=seed, eval_every=eval_every, target_acc=target_acc,
            engine_cfg=engine_cfg, mesh=mesh)
    if engine != "legacy":
        raise ValueError(f"unknown execution engine: {engine!r}")
    if mesh is not None:
        raise ValueError("mesh execution requires engine='cohort'")
    return _run_fedavg_legacy(
        clients, global_params, accuracy_fn, test_data, rounds=rounds,
        seed=seed, eval_every=eval_every, target_acc=target_acc)


def run_async(
    clients: list,
    global_params,
    accuracy_fn: Callable,
    test_data: dict,
    strategy,                      # FedAsync / FedBuff / AdaptiveAsync
    max_updates: int = 300,
    max_time: Optional[float] = None,
    seed: int = 0,
    eval_every: int = 5,
    target_acc: Optional[float] = None,
    engine: str = "cohort",
    engine_cfg=None,
    mesh=None,
) -> tuple:
    """Event-driven asynchronous FL (Eq. 10-11).

    Every client trains continuously: as soon as its update is merged it
    pulls the fresh globals and starts the next local round.  Completion
    times come from each client's VirtualClock, so fast tiers complete
    many rounds while slow tiers finish one (the paper's participation
    skew emerges, it is not scripted).

    ``mesh`` partitions the cohort engine's client axis over the mesh's
    data axes — cohort-engine only.
    """
    eval_every = _normalize_eval_every(eval_every)
    if engine == "cohort":
        from repro.engine import run_async_engine
        return run_async_engine(
            clients, global_params, accuracy_fn, test_data, strategy,
            max_updates=max_updates, max_time=max_time, seed=seed,
            eval_every=eval_every, target_acc=target_acc,
            engine_cfg=engine_cfg, mesh=mesh)
    if engine != "legacy":
        raise ValueError(f"unknown execution engine: {engine!r}")
    if mesh is not None:
        raise ValueError("mesh execution requires engine='cohort'")
    return _run_async_legacy(
        clients, global_params, accuracy_fn, test_data, strategy,
        max_updates=max_updates, max_time=max_time, seed=seed,
        eval_every=eval_every, target_acc=target_acc)


# ---------------------------------------------------------------------------
# Legacy per-client reference path (parity baseline for the cohort engine)
# ---------------------------------------------------------------------------

def _run_fedavg_legacy(
    clients, global_params, accuracy_fn, test_data,
    rounds=60, seed=0, eval_every=1, target_acc=None,
) -> tuple:
    from repro.core.aggregation import FedAvg
    strat = FedAvg()
    log = RunLog(strategy="fedavg")
    key = jax.random.PRNGKey(seed)
    t_virtual = 0.0
    for c in clients:
        log.update_counts[c.tier] = 0
        log.staleness.setdefault(c.tier, [])
        log.eps_trajectory.setdefault(c.tier, [])

    for rnd in range(1, rounds + 1):
        updates, durations = [], []
        for c in clients:
            key, sub = jax.random.split(key)
            params_k, info = c.local_train(global_params, sub)
            updates.append((params_k, c.n_train))
            durations.append(info["duration"])
            log.update_counts[c.tier] += 1
            log.staleness[c.tier].append(0)  # barrier => no staleness
            log.eps_trajectory[c.tier].append(info["epsilon"])
        # straggler effect: the barrier waits for the slowest client
        t_virtual += max(durations)
        global_params = strat.aggregate(global_params, updates)

        if rnd % eval_every == 0 or rnd == rounds:
            acc = float(accuracy_fn(global_params, test_data))
            log.times.append(t_virtual)
            log.global_acc.append(acc)
            log.server_version.append(rnd)
            eval_all(clients, global_params, accuracy_fn, log)
            if target_acc is not None and acc >= target_acc:
                break

    for c in clients:
        log.resources[c.tier] = c.clock.resource_sample()
        log.dropouts[c.tier] = c.clock.dropouts
    return global_params, log


def _run_async_legacy(
    clients, global_params, accuracy_fn, test_data, strategy,
    max_updates=300, max_time=None, seed=0, eval_every=5, target_acc=None,
) -> tuple:
    log = RunLog(strategy=strategy.name)
    key = jax.random.PRNGKey(seed)
    for c in clients:
        log.update_counts[c.tier] = 0
        log.influence.setdefault(c.tier, 0.0)
        log.staleness.setdefault(c.tier, [])
        log.eps_trajectory.setdefault(c.tier, [])

    # Seed the event queue: every client starts training version 0 at t=0.
    heap = []
    pending = {}
    for c in clients:
        key, sub = jax.random.split(key)
        params_k, info = c.local_train(global_params, sub)
        c.model_version = 0
        pending[c.cid] = (params_k, info)
        heapq.heappush(heap, (info["duration"], c.cid))

    server_version = 0
    t_virtual = 0.0
    done = False
    while heap and not done:
        t_virtual, cid = heapq.heappop(heap)
        c = clients[cid]
        params_k, info = pending.pop(cid)
        tau = server_version - c.model_version
        log.staleness[c.tier].append(tau)
        log.update_counts[c.tier] += 1
        log.eps_trajectory[c.tier].append(info["epsilon"])

        global_params, inc, _w = apply_update(
            strategy, global_params, params_k, tau,
            eps_spent=info["epsilon"])
        server_version += inc
        log.influence[c.tier] += float(_w)

        total_updates = sum(log.update_counts.values())
        if total_updates % eval_every == 0:
            acc = float(accuracy_fn(global_params, test_data))
            log.times.append(t_virtual)
            log.global_acc.append(acc)
            log.server_version.append(server_version)
            eval_all(clients, global_params, accuracy_fn, log)
            if target_acc is not None and acc >= target_acc:
                done = True

        if total_updates >= max_updates or (max_time and t_virtual >= max_time):
            done = True

        # joint aggregation-privacy adaptation (beyond-paper, paper Sec. 5):
        # a client that has exhausted its privacy budget STOPS training —
        # down-weighting alone does not cap eps, it only slows convergence
        # while exposure keeps accruing (see EXPERIMENTS.md §Beyond)
        budget_exhausted = (
            isinstance(strategy, AdaptiveAsync)
            and info["epsilon"] >= strategy.eps_target
        )
        if not done and not budget_exhausted:
            # client immediately pulls fresh globals and trains again
            key, sub = jax.random.split(key)
            new_params_k, new_info = c.local_train(global_params, sub)
            c.model_version = server_version
            pending[cid] = (new_params_k, new_info)
            heapq.heappush(heap, (t_virtual + new_info["duration"], cid))

    for c in clients:
        log.resources[c.tier] = c.clock.resource_sample()
        log.dropouts[c.tier] = c.clock.dropouts
    return global_params, log
