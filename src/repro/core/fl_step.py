"""Distributed FL train step: the paper's technique (local DP-SGD + noisy
weighted aggregation) as a single pjit-able SPMD program over the
production mesh (DESIGN.md sec 6).

One ``fl_train_step`` = one federated round:

  1. **broadcast**: f32 ZeRO-sharded master params -> G bf16 per-client
     replicas stacked on a leading client dim (sharded over the data/pod
     axes -> all-gather of the model-sharded master);
  2. **local phase**: each client group runs ``n_local`` local SGD steps;
     each step scans ``n_micro`` gradient-accumulation microbatches and
     clips each microbatch gradient to C (per-microbatch LDP granularity,
     paper Eq. 4) before accumulating, then adds N(0, (sigma C / n_micro)^2)
     once (Eq. 5) and applies the local update (Eq. 6);
  3. **(optional) client-level DP**: the round delta is clipped + noised
     instead (DP-FedAvg granularity, Geyer et al. [17]);
  4. **aggregate**: staleness/fedavg weights w_g (an input vector, so the
     same compiled step serves FedAvg, FedAsync and FedBuff semantics)
     produce Delta = sum_g w_g delta_g / sum_g w_g — a weighted
     reduce over the client axis lowering to reduce-scatter/all-reduce
     into the master sharding;
  5. **server update**: FedAdam (or SGD) on the f32 master.

The per-client accountant step (paper Alg. 1 line 14-17) happens on the
host: every client spent n_local * n_micro subsampled-Gaussian steps.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.dp import DPConfig, clip_tree
from repro.optim.optimizers import Adam


@dataclass(frozen=True)
class FLStepConfig:
    num_clients: int                  # G = product of data axes
    n_local: int = 1                  # local SGD steps per round
    n_micro: int = 2                  # grad-accum microbatches per local step
    local_lr: float = 0.02
    server_lr: float = 1e-3
    dp: DPConfig = DPConfig(clip_norm=1.0, noise_multiplier=1.0,
                            granularity="per_microbatch")
    server_opt: str = "adam"          # adam (FedAdam) | sgd
    compute_dtype: str = "bfloat16"


def make_server_optimizer(fl: FLStepConfig):
    if fl.server_opt == "adam":
        return Adam(lr=fl.server_lr)
    from repro.optim.optimizers import SGD
    return SGD(lr=fl.server_lr)


def split_batch(x, G: int, n_local: int, n_micro: int):
    """Reshape one global-batch array to ``(G, n_local, n_micro, per_micro,
    ...)`` — the stacked per-client microbatch layout the local phase scans.

    Divisibility is validated up front: the old inline reshape surfaced an
    inscrutable XLA "cannot reshape" error that named neither the batch
    shape nor the config values that made it impossible.
    """
    b = int(x.shape[0])
    if b % G:
        raise ValueError(
            f"global batch dim {b} (leading dim of shape {tuple(x.shape)}) "
            f"is not divisible by num_clients G={G}")
    per_client = b // G
    if per_client % (n_local * n_micro):
        raise ValueError(
            f"per-client batch {per_client} (global batch {b} over G={G} "
            f"clients) is not divisible by n_local*n_micro = "
            f"{n_local}*{n_micro} = {n_local * n_micro}; use a global batch "
            f"that is a multiple of G*n_local*n_micro = "
            f"{G * n_local * n_micro}")
    per_micro = per_client // (n_local * n_micro)
    return x.reshape((G, n_local, n_micro, per_micro) + x.shape[1:])


def make_local_phase(loss_fn: Callable, fl: FLStepConfig):
    """One client's local phase (paper Eq. 4-6): a scan of local SGD steps,
    each accumulating ``n_micro`` clipped microbatch gradients before one
    noise draw and the ``local_lr`` update.

    Factored out of :func:`make_fl_train_step` so the cohort engine can
    drive the IDENTICAL production round from its event loop
    (``repro.engine.cohort_step`` with ``client_axis="fl_step"``).

    Returns ``local_phase(client_params, client_batch, key, n_steps=None)``
    where ``client_batch`` leaves are ``(n_local, n_micro, per_micro, ...)``
    (the step count is taken from the batch's leading dim, so callers may
    run more or fewer steps than ``fl.n_local``) and ``n_steps`` optionally
    masks trailing steps — a masked step leaves params untouched, which is
    how the engine pads every cohort member to a common step count.
    """

    def local_phase(client_params, client_batch, key, n_steps=None):
        n_local = jax.tree_util.tree_leaves(client_batch)[0].shape[0]

        def one_local_step(params, inp):
            step_i, step_key, micro_batch = inp
            # scan over microbatches: clip each microbatch grad (Eq. 4).
            # The microbatch count comes from the BATCH, not fl.n_micro: the
            # scan below already iterates the batch's actual microbatch dim,
            # so the accumulator mean and the noise stddev must divide by
            # the same count — the old static fl.n_micro silently mis-scaled
            # both whenever the batch layout disagreed with the config.
            n_micro = jax.tree_util.tree_leaves(micro_batch)[0].shape[0]

            def micro(acc, mb):
                g = jax.grad(lambda p: loss_fn(p, mb))(params)
                if fl.dp.granularity == "per_microbatch":
                    g, _ = clip_tree(g, fl.dp.clip_norm)
                return jax.tree_util.tree_map(jnp.add, acc, g), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            acc, _ = jax.lax.scan(micro, zeros, micro_batch)
            mean_g = jax.tree_util.tree_map(lambda a: a / n_micro, acc)
            if (fl.dp.granularity == "per_microbatch"
                    and fl.dp.noise_multiplier > 0):
                stddev = fl.dp.noise_multiplier * fl.dp.clip_norm / n_micro
                leaves, treedef = jax.tree_util.tree_flatten(mean_g)
                keys = jax.random.split(step_key, len(leaves))
                mean_g = jax.tree_util.tree_unflatten(
                    treedef,
                    [g + stddev * jax.random.normal(k, g.shape, jnp.float32)
                     for k, g in zip(keys, leaves)],
                )
            new = jax.tree_util.tree_map(
                lambda p, g: (p.astype(jnp.float32)
                              - fl.local_lr * g).astype(p.dtype),
                params, mean_g,
            )
            if n_steps is not None:
                live = step_i < n_steps
                new = jax.tree_util.tree_map(
                    lambda a, b: jnp.where(live, a, b), new, params)
            return new, None

        step_keys = jax.random.split(key, n_local)
        params, _ = jax.lax.scan(
            one_local_step, client_params,
            (jnp.arange(n_local), step_keys, client_batch))
        return params

    return local_phase


def make_fl_train_step(loss_fn: Callable, fl: FLStepConfig,
                       client_shardings=None, master_shardings=None):
    """loss_fn(params, batch) -> scalar mean loss, where every array in
    ``batch`` has a leading per-client batch dim.

    ``client_shardings``: optional pytree of NamedShardings for the
    G-STACKED param tree (leading client dim over the data axes, tensor
    dims over model).  Without it XLA keeps the broadcast-from-ZeRO-master
    stacked params replicated over the client axis — i.e. every device
    would redo all G clients' work.  The constraint is what turns the
    broadcast into the intended all-gather + client partition.

    Returns fl_train_step(master, opt_state, batch, weights, key)
      master:    f32 param pytree (ZeRO-sharded under pjit)
      batch:     global batch; leading dim = G * per_client_batch
      weights:   (G,) aggregation weights (uniform p_k = FedAvg Eq. 9;
                 staleness alpha/(1+tau) = FedAsync Eq. 10)
      key:       PRNG key for the DP noise
    """
    G = fl.num_clients
    server_opt = make_server_optimizer(fl)
    cdtype = jnp.dtype(fl.compute_dtype)

    def constrain_clients(tree):
        if client_shardings is None:
            return tree
        return jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, tree, client_shardings)

    local_phase = make_local_phase(loss_fn, fl)

    def fl_train_step(master, opt_state, batch, weights, key):
        # 1. broadcast master -> stacked per-client replicas.  Convert to
        # bf16 BEFORE the gather (pin the converted copy to the master's
        # ZeRO sharding) so the data-axis all-gather moves half the bytes
        # (EXPERIMENTS.md §Perf iteration 2a).
        def to_compute(m, sh=None):
            mc = m.astype(cdtype)
            if sh is not None:
                mc = jax.lax.with_sharding_constraint(mc, sh)
            return mc

        if master_shardings is not None:
            master_c = jax.tree_util.tree_map(
                to_compute, master, master_shardings)
        else:
            master_c = jax.tree_util.tree_map(to_compute, master)

        def bcast(m):
            return jnp.broadcast_to(m[None], (G,) + m.shape)

        stacked = constrain_clients(jax.tree_util.tree_map(bcast, master_c))

        # reshape global batch to (G, n_local, n_micro, per_micro, ...)
        cbatch = jax.tree_util.tree_map(
            lambda x: split_batch(x, G, fl.n_local, fl.n_micro), batch)
        keys = jax.random.split(key, G + 1)
        client_keys, delta_key = keys[:G], keys[G]

        # 2. per-client local phase (vmapped over the stacked client dim)
        new_stacked = constrain_clients(
            jax.vmap(local_phase)(stacked, cbatch, client_keys))

        # 3. deltas (+ optional client-level DP)
        deltas = jax.tree_util.tree_map(
            lambda ns, s: (ns.astype(jnp.float32) - s.astype(jnp.float32)),
            new_stacked, stacked,
        )
        if fl.dp.granularity == "client_level":
            def clip_client(d):
                # per-client global norms across ALL leaves
                return d  # handled below jointly
            sq = sum(
                jnp.sum(jnp.square(l), axis=tuple(range(1, l.ndim)))
                for l in jax.tree_util.tree_leaves(deltas)
            )
            norms = jnp.sqrt(sq)                               # (G,)
            scales = 1.0 / jnp.maximum(1.0, norms / fl.dp.clip_norm)
            deltas = jax.tree_util.tree_map(
                lambda d: d * scales.reshape((G,) + (1,) * (d.ndim - 1)), deltas
            )
            if fl.dp.noise_multiplier > 0:
                leaves, treedef = jax.tree_util.tree_flatten(deltas)
                nkeys = jax.random.split(delta_key, len(leaves))
                stddev = fl.dp.noise_multiplier * fl.dp.clip_norm
                deltas = jax.tree_util.tree_unflatten(
                    treedef,
                    [d + stddev * jax.random.normal(k, d.shape, jnp.float32)
                     for k, d in zip(nkeys, leaves)],
                )

        # 4. weighted aggregation over the client axis (paper Eq. 9 / 10-11)
        wsum = jnp.sum(weights)
        wn = (weights / wsum).astype(jnp.float32)
        agg = jax.tree_util.tree_map(
            lambda d: jnp.tensordot(wn, d, axes=(0, 0)), deltas
        )

        # 5. server update: FedAdam treats -Delta as the gradient
        neg = jax.tree_util.tree_map(jnp.negative, agg)
        new_master, new_opt_state = server_opt.update(neg, opt_state, master)

        metrics = {
            "delta_norm": jnp.sqrt(sum(
                jnp.sum(jnp.square(l)) for l in jax.tree_util.tree_leaves(agg)
            )),
            "weight_sum": wsum,
        }
        return new_master, new_opt_state, metrics

    return fl_train_step
