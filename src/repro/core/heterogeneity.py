"""Device-heterogeneity simulation layer (replaces the paper's physical
testbed — DESIGN.md sec 2).

Five hardware tiers HW_T1..HW_T5 (paper Table 1/2, Fig. 3), calibrated so
that the *emergent* behaviour matches the paper's measurements:

  * per-round local-training time: high-end 65-75 s, low-end 6-9x longer;
  * exchange latency ~25 ms high-end, ~7x higher low-end;
  * dropout/rejoin events on T1 (3 observed), T2 (2 observed) over 60 rounds;
  * under FedAsync the emergent staleness is tau ~ {7, 6, 4, 0, 0}.

The virtual clock is deterministic given a seed; nothing here touches real
wall time.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DeviceProfile:
    tier: str                 # "HW_T1".."HW_T5"
    device: str               # human-readable hardware name
    compute_time_s: float     # mean local-training time per round (seconds)
    compute_jitter: float     # lognormal sigma of compute time
    exchange_latency_s: float # model up+download latency per round
    ram_gb: float
    ram_usage_pct: float      # paper Table 2 (reported by resource monitor)
    cpu_user_s: float         # paper Table 2, cumulative over 60 rounds
    cpu_sys_s: float
    dropout_per_round: float  # P(drop this round); rejoin after penalty
    dropout_penalty_s: float  # extra delay when a dropout occurs
    application: str


# Calibration: paper Fig. 3b gives high-end ~65-75 s and low-end 6-9x longer
# (~420-600 s); T3 is ~3-4x faster than low-end, ~3-4x slower than high-end.
# Fig. 3c: exchange latency ~25 ms high-end, ~7x low-end (~175 ms).
# Dropout rates chosen so E[#dropouts over 60 rounds] = 3 / 2 / 0 (Table 2).
PROFILES = {
    "HW_T1": DeviceProfile(
        tier="HW_T1", device="Raspberry Pi 3 Model B",
        compute_time_s=540.0, compute_jitter=0.22, exchange_latency_s=0.175,
        ram_gb=1.0, ram_usage_pct=78.7, cpu_user_s=2268.2, cpu_sys_s=311.0,
        dropout_per_round=0.05, dropout_penalty_s=180.0,
        application="Smart Homes (low-end)",
    ),
    "HW_T2": DeviceProfile(
        tier="HW_T2", device="Raspberry Pi 3 Model B+",
        compute_time_s=470.0, compute_jitter=0.20, exchange_latency_s=0.16,
        ram_gb=1.0, ram_usage_pct=77.1, cpu_user_s=2087.9, cpu_sys_s=275.2,
        dropout_per_round=0.033, dropout_penalty_s=150.0,
        application="Entertainment (low-mid)",
    ),
    "HW_T3": DeviceProfile(
        tier="HW_T3", device="NXP HummingBoard",
        compute_time_s=230.0, compute_jitter=0.12, exchange_latency_s=0.09,
        ram_gb=1.0, ram_usage_pct=77.0, cpu_user_s=1117.3, cpu_sys_s=93.7,
        dropout_per_round=0.0, dropout_penalty_s=0.0,
        application="Healthcare (moderate)",
    ),
    "HW_T4": DeviceProfile(
        tier="HW_T4", device="Raspberry Pi 4 Model B (4GB)",
        compute_time_s=72.0, compute_jitter=0.06, exchange_latency_s=0.027,
        ram_gb=4.0, ram_usage_pct=49.6, cpu_user_s=1122.0, cpu_sys_s=83.3,
        dropout_per_round=0.0, dropout_penalty_s=0.0,
        application="Automotive (high-mid)",
    ),
    "HW_T5": DeviceProfile(
        tier="HW_T5", device="Raspberry Pi 4 Model B (8GB)",
        compute_time_s=66.0, compute_jitter=0.05, exchange_latency_s=0.025,
        ram_gb=8.0, ram_usage_pct=30.5, cpu_user_s=1036.4, cpu_sys_s=80.9,
        dropout_per_round=0.0, dropout_penalty_s=0.0,
        application="Education (high-end)",
    ),
}

TIERS = tuple(PROFILES)  # ordered T1..T5


class VirtualClock:
    """Deterministic event-time sampler for one client."""

    def __init__(self, profile: DeviceProfile, seed: int):
        self.profile = profile
        self.rng = np.random.default_rng(seed)
        self.dropouts = 0

    def round_duration(self) -> float:
        """Sample one round's wall time: compute + exchange (+ dropout)."""
        p = self.profile
        t = p.compute_time_s * float(
            self.rng.lognormal(mean=0.0, sigma=p.compute_jitter)
        )
        t += p.exchange_latency_s
        if p.dropout_per_round > 0 and self.rng.random() < p.dropout_per_round:
            self.dropouts += 1
            t += p.dropout_penalty_s
        return t

    def resource_sample(self):
        """RAM%/CPU-time sample consistent with paper Table 2 noise levels."""
        p = self.profile
        return {
            "ram_pct": p.ram_usage_pct + float(self.rng.normal(0, 1.5)),
            "cpu_user_s": p.cpu_user_s + float(self.rng.normal(0, p.cpu_user_s * 0.04)),
            "cpu_sys_s": p.cpu_sys_s + float(self.rng.normal(0, p.cpu_sys_s * 0.08)),
        }
