"""DP-SGD primitives (paper Sec. 3.2, Eq. 4-6), in pure JAX.

Three clipping granularities (DESIGN.md sec 3):

  * ``per_example``    — exact per-sample gradients via jax.vmap(jax.grad),
                         clipped individually, then averaged + noised.
                         This is what Opacus does and what the paper's 1M
                         parameter SER CNN uses.
  * ``per_microbatch`` — each gradient-accumulation microbatch is clipped
                         as a unit (virtual-batch clipping).  Used by the
                         large assigned architectures where exact
                         per-example grads are infeasible.
  * ``client_level``   — the whole client model delta is clipped + noised
                         once per round (DP-FedAvg, Geyer et al. [17]).

All return the noised mean gradient exactly as Eq. (5):

    g~ = (1/|b|) sum_i clip(g_i) + N(0, sigma^2 C^2 / |b|^2 * I-ish)

NOTE on noise scaling: Eq. (5) in the paper adds N(0, sigma^2 C^2 I) to the
*sum* before the 1/|b| factor is applied to the sum only; the standard
DP-SGD mechanism (Abadi et al.) noises the sum and then divides everything
by |b|.  We follow Abadi et al. (noise stddev sigma*C on the sum, i.e.
sigma*C/|b| on the mean) — this is also what Opacus implements, so it is
what the paper actually ran.

The per-example mechanism ships two interchangeable implementations,
selected by ``dp_path``:

  * ``"jnp"``    — reference: per-leaf norms/scales + ``noise_tree``.
  * ``"pallas"`` — the fused ``repro.kernels.dp_clip`` two-pass kernel
                   (clip + mean + Gaussian noise in the final-tile
                   epilogue), the cohort engine's production hot path.
                   Noise draws replay ``noise_tree``'s exact per-leaf
                   split order (``tree_gaussian_vector_like``) so both
                   paths agree to float tolerance; the noise stddev stays
                   a RUNTIME scalar, preserving the one-program-per-sigma-
                   sweep invariant.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.pytree import (
    tree_gaussian_like,
    tree_global_norm,
    tree_scale,
)


DP_PATHS = ("jnp", "pallas")


def validate_dp_path(dp_path: str) -> str:
    if dp_path not in DP_PATHS:
        raise ValueError(
            f"dp_path must be one of {DP_PATHS}, got {dp_path!r}")
    return dp_path


@dataclass(frozen=True)
class DPConfig:
    clip_norm: float = 1.0        # C  (paper: C = 1)
    noise_multiplier: float = 0.0  # sigma (paper: {0.5, 1, 1.5, 2}); 0 = off
    granularity: str = "per_example"  # per_example | per_microbatch | client_level

    @property
    def enabled(self) -> bool:
        return self.noise_multiplier > 0.0 or self.clip_norm > 0.0


def clip_tree(grads, clip_norm: float):
    """Eq. (4): g <- g / max(1, ||g||_2 / C).  Returns (clipped, pre_norm)."""
    nrm = tree_global_norm(grads)
    scale = 1.0 / jnp.maximum(1.0, nrm / clip_norm)
    return tree_scale(grads, scale), nrm


def noise_tree(key, grads, stddev):
    """Add iid Gaussian noise of the given stddev to every leaf.

    ``stddev`` may be a traced scalar (the cohort engine passes the noise
    scale as a runtime argument so one compiled program serves a whole
    sigma sweep); the zero short-circuit only applies to concrete floats."""
    if isinstance(stddev, (int, float)) and stddev == 0.0:
        return grads
    noise = tree_gaussian_like(key, grads, stddev)
    return jax.tree_util.tree_map(jnp.add, grads, noise)


def per_example_grads(loss_fn: Callable, params, batch):
    """vmap(grad) over the leading batch axis of every array in ``batch``.

    ``loss_fn(params, example) -> scalar`` where ``example`` is one sample
    (no batch dim).  Returns a pytree with a leading batch axis on every
    leaf.
    """
    gfn = jax.grad(loss_fn)
    return jax.vmap(gfn, in_axes=(None, 0))(params, batch)


def dp_mean_gradient(
    loss_fn: Callable,
    params,
    batch,
    key: jax.Array,
    cfg: DPConfig,
    dp_path: str = "jnp",
    noise_stddev=None,
):
    """Per-example DP-SGD gradient (Eq. 4-6): clip each sample's grad to C,
    average, add N(0, (sigma*C/B)^2) to the mean.

    ``dp_path`` selects the implementation: ``"jnp"`` (reference) or
    ``"pallas"`` (fused clip+mean+noise kernel, see module docstring).

    ``noise_stddev`` overrides the statically derived
    ``sigma * C / B`` with a (possibly traced) runtime scalar: the cohort
    engine computes the stddev on the host once per runner and feeds it as
    a program ARGUMENT, so one compiled step serves every noise multiplier
    of a sigma sweep instead of re-tracing per sigma.

    Returns (noised_mean_grad, aux) where aux carries the mean pre-clip
    norm (useful for calibrating C) and the fraction of clipped samples.
    """
    validate_dp_path(dp_path)
    g_per = per_example_grads(loss_fn, params, batch)
    bsz = jax.tree_util.tree_leaves(g_per)[0].shape[0]
    stddev = (cfg.noise_multiplier * cfg.clip_norm / bsz
              if noise_stddev is None else noise_stddev)
    # mirror noise_tree's short-circuit: a CONCRETE zero stddev means no
    # noise; a traced scalar always takes the noised program.
    add_noise = not (isinstance(stddev, (int, float)) and stddev == 0.0)

    if dp_path == "pallas":
        # fused Pallas path: flatten per-example grads to (1, B, D) and run
        # the two-pass cohort clip+mean(+noise) kernel with K=1 (see
        # repro.kernels.dp_clip).  Noise draws replay noise_tree's split
        # order so both paths agree to float tolerance.
        from repro.kernels.dp_clip.ops import dp_clip_mean_noise_cohort
        from repro.pytree import (
            tree_gaussian_vector_like, tree_unflatten_from_vector)

        leaves = jax.tree_util.tree_leaves(g_per)
        flat = jnp.concatenate(
            [l.reshape(bsz, -1).astype(jnp.float32) for l in leaves], axis=1
        )
        template = jax.tree_util.tree_map(lambda l: l[0], g_per)
        if add_noise:
            z = tree_gaussian_vector_like(key, template)
            mean_flat, nrm, frac = dp_clip_mean_noise_cohort(
                flat[None], cfg.clip_norm,
                jnp.asarray(stddev, jnp.float32), z[None])
        else:
            mean_flat, nrm, frac = dp_clip_mean_noise_cohort(
                flat[None], cfg.clip_norm)
        noised = tree_unflatten_from_vector(mean_flat[0], template)
        return noised, {"mean_grad_norm": nrm[0], "clip_fraction": frac[0]}
    else:
        # per-sample norms over ALL leaves (flatten the non-batch dims)
        sq = sum(
            jnp.sum(jnp.square(l.astype(jnp.float32)).reshape(bsz, -1), axis=1)
            for l in jax.tree_util.tree_leaves(g_per)
        )
        norms = jnp.sqrt(sq)                                   # (B,)
        scales = 1.0 / jnp.maximum(1.0, norms / cfg.clip_norm)  # (B,)
        mean = jax.tree_util.tree_map(
            lambda l: jnp.mean(
                l * scales.reshape((bsz,) + (1,) * (l.ndim - 1)), axis=0
            ),
            g_per,
        )
        nrm = jnp.mean(norms)
        frac = jnp.mean((norms > cfg.clip_norm).astype(jnp.float32))

    noised = noise_tree(key, mean, stddev) if add_noise else mean
    return noised, {"mean_grad_norm": nrm, "clip_fraction": frac}


def dp_microbatch_gradient(grads, key, cfg: DPConfig, num_microbatches: int):
    """Per-microbatch granularity: ``grads`` is the (already-averaged)
    gradient of ONE microbatch; clip it as a unit.  Noise is added once by
    the caller after accumulation via :func:`dp_accumulate_noise`."""
    clipped, nrm = clip_tree(grads, cfg.clip_norm)
    return clipped, nrm


def dp_accumulate_noise(summed_clipped, key, cfg: DPConfig, num_units: int):
    """Finish a per-microbatch / client-level accumulation: average the
    ``num_units`` clipped units and add N(0, (sigma*C/num_units)^2)."""
    mean = tree_scale(summed_clipped, 1.0 / num_units)
    stddev = cfg.noise_multiplier * cfg.clip_norm / num_units
    return noise_tree(key, mean, stddev)


def dp_client_delta(delta, key, cfg: DPConfig):
    """Client-level DP (DP-FedAvg): clip the round's model delta to C and
    noise it before it leaves the (virtual) device."""
    clipped, nrm = clip_tree(delta, cfg.clip_norm)
    noised = noise_tree(key, clipped, cfg.noise_multiplier * cfg.clip_norm)
    return noised, nrm
