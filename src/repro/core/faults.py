"""Deterministic fault injection for the FL testbed (see RESILIENCE.md).

The paper's physical testbed is defined by failure — Table 2 records 3
dropout/rejoin events on HW_T1 and 2 on HW_T2 over 60 rounds — yet the
heterogeneity layer only models dropout as a passive DELAY
(:mod:`repro.core.heterogeneity` adds a penalty to the round duration and
counts it).  This module makes updates actually *lossy*:

* :class:`FaultModel` — a frozen, spec-serializable description of the
  failure distribution, carried on ``TestbedConfig.faults`` and
  registered in the :mod:`repro.api.spec` codec, so a faulty scenario is
  reproducible from its JSON provenance alone.
* :class:`FaultInjector` — the seeded runtime that draws fault outcomes.
  All faults are expressed as *events in virtual time* (re-entries into
  the existing event heap, zero-weight mask slots in the cohort merge),
  so the compiled hot path is untouched and a faulty run compiles
  nothing a fault-free run didn't.

Determinism contract
--------------------
Every client owns an independent ``np.random.Generator`` seeded from
``(model.seed, cid)``; draws happen in a FIXED per-delivery order
(failure -> upload loss/retry -> late -> duplicate -> corruption, then a
leave draw at each re-dispatch).  Because the streams are per-client and the loops
invoke the injector at the same logical points, the SAME seed + SAME
FaultModel replays the identical fault event sequence on both execution
backends (legacy per-client loop and cohort engine at
``staleness_window=0``) and across ``pipeline_depth`` settings — the
tier-1 fault-parity tests assert ``RunLog.fault_events`` equality.

Fault semantics (one delivery attempt, at virtual time ``t``):

1. **duplicate arrival** — a ghost event scheduled by an earlier
   delivery; dropped at the server (counted, never merged).
2. **mid-round failure** (``failure_prob``, first attempt only) — the
   device finished its local steps but crashed at the upload boundary:
   the update is discarded (the member becomes a zero-weight mask slot
   in its cohort), privacy was already charged at dispatch (the
   computation DID run), and the client re-dispatches afterwards.
3. **upload loss** (``upload_loss_prob``, drawn per attempt) — the
   upload vanishes in transit; up to ``max_retries`` re-entries at
   ``t + retry_backoff_s`` (the retried event re-enters the heap at the
   backoff-delayed virtual time), after which the update is lost like a
   failure.
4. **late delivery** (``late_prob``, once per update) — the upload
   arrives ``late_delay_s`` later than the completion event (extra
   staleness under async merging).
5. **duplicate delivery** (``duplicate_prob``) — the network delivers a
   second copy ``duplicate_delay_s`` after the first; the server
   dedupes it (see 1).
6. **leave/rejoin churn** (``leave_prob``, drawn at each re-dispatch) —
   the client goes away for ``rejoin_delay_s`` before starting its next
   local round.
7. **transit corruption** (``corrupt_prob``, drawn LAST and only on
   deliveries that reach the server) — the payload arrives as all-NaN
   (with probability ``corrupt_nan_frac``) or with its update delta
   scaled by ``corrupt_scale``.  The server still receives it; the
   screening layer (:mod:`repro.core.screening`) is the defense.

FedAvg rounds additionally honor ``round_deadline_s`` + ``min_quorum``:
the barrier stops waiting at the deadline (stretched just enough to
collect ``min_quorum`` surviving updates) and aggregates the partial
cohort with survivor-renormalized weights.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

# The fault counters appended to repro.core.runlog.ENGINE_STATS_KEYS —
# defined here (next to the code that increments them) and imported by
# the runlog schema so the two cannot drift independently.
FAULT_STATS_KEYS = (
    "fault_failures",            # mid-round crashes (update discarded)
    "fault_upload_losses",       # upload attempts lost in transit
    "fault_retries",             # backoff re-entries into the event heap
    "fault_lost_updates",        # updates dropped after exhausting retries
    "fault_duplicates_dropped",  # duplicate arrivals deduped at the server
    "fault_late_deliveries",     # deliveries delayed past completion
    "fault_churn_leaves",        # leave/rejoin cycles at re-dispatch
    "fault_corruptions",         # delivered payloads corrupted in transit
    "degraded_cohorts",          # cohorts/rounds merged below full strength
    "deadline_drops",            # fedavg members dropped at the deadline
)


def zero_fault_stats() -> dict:
    """The fault counters of a fault-free run (every engine run reports
    them so the stats schema is unconditional)."""
    return {k: 0 for k in FAULT_STATS_KEYS}


@dataclass(frozen=True)
class FaultModel:
    """Spec-serializable failure distribution (see module docstring for
    the per-fault semantics).  All fields are JSON scalars; validation
    happens at construction so a bad model never reaches a run."""

    seed: int = 0                  # fault RNG seed (independent of the
                                   # testbed seed: the same scenario can
                                   # replay under different fault draws)
    failure_prob: float = 0.0      # P(mid-round crash) per update
    upload_loss_prob: float = 0.0  # P(upload lost) per delivery attempt
    max_retries: int = 2           # bounded retries after an upload loss
    retry_backoff_s: float = 5.0   # virtual-time backoff between retries
    duplicate_prob: float = 0.0    # P(second copy delivered) per update
    duplicate_delay_s: float = 1.0
    late_prob: float = 0.0         # P(delivery arrives late) per update
    late_delay_s: float = 30.0
    leave_prob: float = 0.0        # P(leave) drawn at each re-dispatch
    rejoin_delay_s: float = 120.0
    # transit corruption of DELIVERED payloads (drawn last, only on
    # updates that actually reach the server): with probability
    # ``corrupt_nan_frac`` the payload arrives as all-NaN, otherwise the
    # update delta is blown up by ``corrupt_scale`` (a gradient-scaling
    # attack / bit-rot model).  The screening layer
    # (repro.core.screening) is the defense.
    corrupt_prob: float = 0.0      # P(payload corrupted) per delivery
    corrupt_nan_frac: float = 0.5  # NaN payload vs scale blowup split
    corrupt_scale: float = 1e6     # delta multiplier for blowup corruption
    # fedavg-only graceful degradation: stop waiting for dead/slow
    # members at the deadline, but never aggregate below the quorum
    round_deadline_s: Optional[float] = None
    min_quorum: int = 1

    def __post_init__(self):
        for name in ("failure_prob", "upload_loss_prob", "duplicate_prob",
                     "late_prob", "leave_prob", "corrupt_prob",
                     "corrupt_nan_frac"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"FaultModel.{name} must be in [0, 1]: {v!r}")
        if self.seed < 0 or self.seed != int(self.seed):
            raise ValueError(
                f"FaultModel.seed must be a non-negative int: {self.seed!r}")
        if self.max_retries < 0 or self.max_retries != int(self.max_retries):
            raise ValueError(
                f"FaultModel.max_retries must be an int >= 0: "
                f"{self.max_retries!r}")
        for name in ("retry_backoff_s", "duplicate_delay_s", "late_delay_s",
                     "rejoin_delay_s"):
            if getattr(self, name) < 0:
                raise ValueError(
                    f"FaultModel.{name} must be >= 0: "
                    f"{getattr(self, name)!r}")
        # a zero re-entry delay would re-pop the same virtual instant
        # forever — virtual time must strictly advance per re-entry.
        # Exception: retry_backoff_s == 0 is legal when max_retries == 0
        # (a lost upload is dropped immediately, nothing ever re-enters
        # the heap, so virtual time cannot freeze).
        for prob, delay in (("duplicate_prob", "duplicate_delay_s"),
                            ("late_prob", "late_delay_s")):
            if getattr(self, prob) > 0 and getattr(self, delay) <= 0:
                raise ValueError(
                    f"FaultModel.{delay} must be > 0 when {prob} > 0 "
                    "(virtual time must advance between re-entries)")
        if (self.upload_loss_prob > 0 and self.retry_backoff_s <= 0
                and self.max_retries > 0):
            raise ValueError(
                "FaultModel.retry_backoff_s must be > 0 when "
                "upload_loss_prob > 0 and max_retries > 0 "
                "(virtual time must advance between re-entries)")
        if not (0 < self.corrupt_scale < float("inf")):
            raise ValueError(
                f"FaultModel.corrupt_scale must be a finite positive "
                f"float: {self.corrupt_scale!r}")
        if self.corrupt_prob > 0 and self.corrupt_scale == 1.0:
            raise ValueError(
                "FaultModel.corrupt_scale must differ from 1.0 when "
                "corrupt_prob > 0 (1.0 is the clean-payload sentinel — "
                "the corruption would be a silent no-op)")
        if self.round_deadline_s is not None and self.round_deadline_s <= 0:
            raise ValueError(
                f"FaultModel.round_deadline_s must be > 0 or None: "
                f"{self.round_deadline_s!r}")
        if self.min_quorum < 1 or self.min_quorum != int(self.min_quorum):
            raise ValueError(
                f"FaultModel.min_quorum must be an int >= 1: "
                f"{self.min_quorum!r}")


def apply_deadline(model: FaultModel, offsets) -> tuple:
    """FedAvg partial aggregation: given each member's delivery offset
    from the round start (``None`` = update already lost to a fault),
    decide who the barrier keeps.

    The server stops waiting at ``round_deadline_s``, stretched to the
    ``min_quorum``-th smallest surviving delivery time when the plain
    deadline would collect fewer than the quorum.  Returns
    ``(keep, round_time)`` — ``keep[i]`` is True for aggregated members,
    ``round_time`` is how long the round occupied the server (the
    effective deadline when it cut anyone off, else the slowest kept
    delivery; ``None`` when no update survived, in which case the caller
    falls back to the full barrier wait).

    A quorum larger than the round's LIVE client count (``len(offsets)``
    — everyone who dispatched, survivors and casualties alike) is a
    configuration error, not a degraded round: the deadline would
    stretch unboundedly waiting for a quorum that can never assemble.
    Rejected here and at :class:`FaultInjector` construction.  A quorum
    larger than the SURVIVOR count but within the live count is the
    legitimate degraded case — the clamp below keeps every survivor."""
    if int(model.min_quorum) > len(offsets):
        raise ValueError(
            f"FaultModel.min_quorum={int(model.min_quorum)} exceeds the "
            f"round's live client count ({len(offsets)}) — the deadline "
            "would stretch unboundedly waiting for a quorum that can "
            "never assemble")
    times = sorted(o for o in offsets if o is not None)
    if not times:
        return [False] * len(offsets), None
    if model.round_deadline_s is None:
        return [o is not None for o in offsets], times[-1]
    k = min(int(model.min_quorum), len(times))
    eff = max(float(model.round_deadline_s), times[k - 1])
    keep = [o is not None and o <= eff for o in offsets]
    if any(o is not None and o > eff for o in offsets):
        return keep, eff
    return keep, times[-1]


class FaultInjector:
    """Seeded runtime fault oracle shared by both execution backends.

    The loops call exactly four entry points — :meth:`on_completion`
    (async delivery attempt), :meth:`redispatch_delay` (leave/rejoin
    churn), :meth:`fedavg_fate` (a whole barrier-round delivery
    simulated inline) and :meth:`note_deadline_drop` /
    :meth:`note_degraded` (server-side bookkeeping) — and record the
    returned outcomes; the injector owns every random draw and the
    ordered ``events`` log that ``RunLog.fault_events`` exposes.  Its
    state (per-client RNG streams, retry bookkeeping, in-flight
    duplicates, counters, events) serializes via :meth:`state_dict` so a
    checkpointed run resumes mid-fault-sequence bit-identically."""

    def __init__(self, model: FaultModel, num_clients: int):
        if int(model.min_quorum) > int(num_clients):
            raise ValueError(
                f"FaultModel.min_quorum={int(model.min_quorum)} exceeds "
                f"the testbed's live client count ({int(num_clients)}) — "
                "the round deadline would stretch unboundedly waiting for "
                "a quorum that can never assemble")
        self.model = model
        self._rngs = [np.random.default_rng((int(model.seed), 0x5EED, cid))
                      for cid in range(num_clients)]
        self._attempts = [0] * num_clients   # retries used, current update
        self._late = [False] * num_clients   # late draw used, current update
        self._dups = {}                      # (t, cid) -> pending copies
        self._corrupt = {}                   # cid -> pending delivery scale
        self.counters = zero_fault_stats()
        self.events = []                     # ordered (kind, cid, t) tuples

    # -- shared draw helpers ----------------------------------------------
    def _record(self, kind: str, counter: Optional[str], cid: int, t: float):
        if counter is not None:
            self.counters[counter] += 1
        self.events.append((kind, cid, float(t)))

    def _reset_update(self, cid: int):
        self._attempts[cid] = 0
        self._late[cid] = False

    def _draw_corruption(self, cid: int, t: float):
        """Transit-corruption draw, LAST in the per-delivery order and
        only on updates that actually reach the server.  The resulting
        payload scale (NaN = all-NaN payload, ``corrupt_scale`` = delta
        blowup, 1.0 = clean) parks in a per-client pending slot until
        the loop collects it via :meth:`take_corruption`."""
        m, rng = self.model, self._rngs[cid]
        if m.corrupt_prob <= 0:
            return
        if rng.random() >= m.corrupt_prob:
            return
        if rng.random() < m.corrupt_nan_frac:
            self._corrupt[cid] = float("nan")
            self._record("corrupt_nan", "fault_corruptions", cid, t)
        else:
            self._corrupt[cid] = float(m.corrupt_scale)
            self._record("corrupt_scale", "fault_corruptions", cid, t)

    def take_corruption(self, cid: int) -> float:
        """Collect (and clear) the pending delivery's payload scale for
        ``cid`` — 1.0 when the delivery is clean.  Called exactly once
        per delivered update by both backends."""
        return self._corrupt.pop(cid, 1.0)

    # -- async loops --------------------------------------------------------
    def on_completion(self, cid: int, t: float) -> tuple:
        """Resolve one delivery attempt popped from the event heap at
        virtual time ``t``.  Returns ``(verdict, aux)``:

        * ``("duplicate", None)`` — ghost copy of an already-merged
          update; skip it (no pending plan is consumed).
        * ``("requeue", t_new)`` — not delivered yet (upload retry or
          late arrival); push ``(t_new, cid)`` back on the heap, the
          pending plan stays pending.
        * ``("drop", reason)`` — the update is lost ("failure" |
          "retries_exhausted"): consume the pending plan as a
          zero-weight member and re-dispatch the client.
        * ``("deliver", dup_t)`` — merge now; when ``dup_t`` is not
          None, push the ghost duplicate ``(dup_t, cid)`` on the heap.
        """
        key = (float(t), cid)
        pending = self._dups.get(key, 0)
        if pending:
            if pending == 1:
                del self._dups[key]
            else:
                self._dups[key] = pending - 1
            self._record("duplicate_dropped", "fault_duplicates_dropped",
                         cid, t)
            return ("duplicate", None)
        m, rng = self.model, self._rngs[cid]
        first_attempt = self._attempts[cid] == 0 and not self._late[cid]
        if (first_attempt and m.failure_prob > 0
                and rng.random() < m.failure_prob):
            self._record("failure", "fault_failures", cid, t)
            self._reset_update(cid)
            return ("drop", "failure")
        if m.upload_loss_prob > 0 and rng.random() < m.upload_loss_prob:
            self._record("upload_loss", "fault_upload_losses", cid, t)
            if self._attempts[cid] < m.max_retries:
                self._attempts[cid] += 1
                t_new = t + m.retry_backoff_s
                self._record("retry", "fault_retries", cid, t_new)
                return ("requeue", t_new)
            self._record("lost", "fault_lost_updates", cid, t)
            self._reset_update(cid)
            return ("drop", "retries_exhausted")
        if (not self._late[cid] and m.late_prob > 0
                and rng.random() < m.late_prob):
            self._late[cid] = True
            t_new = t + m.late_delay_s
            self._record("late", "fault_late_deliveries", cid, t_new)
            return ("requeue", t_new)
        dup_t = None
        if m.duplicate_prob > 0 and rng.random() < m.duplicate_prob:
            dup_t = t + m.duplicate_delay_s
            dk = (float(dup_t), cid)
            self._dups[dk] = self._dups.get(dk, 0) + 1
            self._record("duplicate_scheduled", None, cid, dup_t)
        self._draw_corruption(cid, t)
        self._reset_update(cid)
        return ("deliver", dup_t)

    def redispatch_delay(self, cid: int, t: float) -> float:
        """Leave/rejoin churn, drawn once per RE-dispatch (the initial
        t=0 dispatch never draws): the client's next local round starts
        ``rejoin_delay_s`` late when it leaves."""
        m = self.model
        if m.leave_prob > 0 and self._rngs[cid].random() < m.leave_prob:
            self._record("leave", "fault_churn_leaves", cid, t)
            return float(m.rejoin_delay_s)
        return 0.0

    # -- fedavg barrier rounds ----------------------------------------------
    def fedavg_fate(self, cid: int, t0: float, duration: float) -> tuple:
        """Simulate one barrier-round delivery inline (same draw order
        as the async path: failure -> loss/retry loop -> late ->
        duplicate).  ``t0`` is the round's start time (event timestamps
        only).  Returns ``(delivery_offset, reason)`` — the offset from
        the round start at which the update reaches the server, or
        ``(None, reason)`` when it is lost."""
        m, rng = self.model, self._rngs[cid]
        if m.failure_prob > 0 and rng.random() < m.failure_prob:
            self._record("failure", "fault_failures", cid, t0 + duration)
            return None, "failure"
        off = float(duration)
        attempts = 0
        while m.upload_loss_prob > 0 and rng.random() < m.upload_loss_prob:
            self._record("upload_loss", "fault_upload_losses", cid, t0 + off)
            if attempts < m.max_retries:
                attempts += 1
                off += m.retry_backoff_s
                self._record("retry", "fault_retries", cid, t0 + off)
                continue
            self._record("lost", "fault_lost_updates", cid, t0 + off)
            return None, "retries_exhausted"
        if m.late_prob > 0 and rng.random() < m.late_prob:
            off += m.late_delay_s
            self._record("late", "fault_late_deliveries", cid, t0 + off)
        if m.duplicate_prob > 0 and rng.random() < m.duplicate_prob:
            # the barrier dedupes instantly — both halves recorded so the
            # scheduled/dropped ledger stays balanced across modes
            dup_t = t0 + off + m.duplicate_delay_s
            self._record("duplicate_scheduled", None, cid, dup_t)
            self._record("duplicate_dropped", "fault_duplicates_dropped",
                         cid, dup_t)
        self._draw_corruption(cid, t0 + off)
        return off, None

    # -- server-side bookkeeping --------------------------------------------
    def note_deadline_drop(self, cid: int, t: float):
        self._record("deadline_drop", "deadline_drops", cid, t)

    def note_degraded(self):
        self.counters["degraded_cohorts"] += 1

    def stats(self) -> dict:
        return dict(self.counters)

    # -- checkpoint serialization -------------------------------------------
    def state_dict(self) -> dict:
        """JSON-able snapshot of the full injector state (RNG streams
        included) — restoring it resumes the fault sequence exactly
        where the checkpoint left it."""
        return {
            "rng": [r.bit_generator.state for r in self._rngs],
            "attempts": list(self._attempts),
            "late": list(self._late),
            "dups": [[t, cid, n] for (t, cid), n in self._dups.items()],
            # NaN round-trips through JSON repr as the string "nan" —
            # store scales as repr strings so the payload kind survives
            "corrupt": [[cid, repr(s)] for cid, s in self._corrupt.items()],
            "counters": dict(self.counters),
            "events": [list(e) for e in self.events],
        }

    def load_state_dict(self, state: dict):
        for r, s in zip(self._rngs, state["rng"]):
            r.bit_generator.state = s
        self._attempts = [int(a) for a in state["attempts"]]
        self._late = [bool(b) for b in state["late"]]
        self._dups = {(float(t), int(cid)): int(n)
                      for t, cid, n in state["dups"]}
        self._corrupt = {int(cid): float(s)
                         for cid, s in state.get("corrupt", [])}
        self.counters = zero_fault_stats()
        self.counters.update(state["counters"])
        self.events = [(str(k), int(cid), float(t))
                       for k, cid, t in state["events"]]


__all__ = ["FAULT_STATS_KEYS", "zero_fault_stats", "FaultModel",
           "FaultInjector", "apply_deadline"]
