"""Client partitioners: IID (the paper's setting) and Dirichlet non-IID
(beyond-paper ablation)."""
from __future__ import annotations

import numpy as np


def iid_partition(data: dict, num_clients: int, seed: int = 0):
    """Shuffle and split evenly — the paper's 5-way IID split with balanced
    classes (we shuffle within class to keep balance exact)."""
    rng = np.random.default_rng(seed)
    y = data["y"]
    idx_by_class = [np.where(y == c)[0] for c in np.unique(y)]
    shards = [[] for _ in range(num_clients)]
    for idx in idx_by_class:
        idx = rng.permutation(idx)
        for i, chunk in enumerate(np.array_split(idx, num_clients)):
            shards[i].append(chunk)
    out = []
    for parts in shards:
        sel = rng.permutation(np.concatenate(parts))
        out.append({k: v[sel] for k, v in data.items()})
    return out


def dirichlet_partition(data: dict, num_clients: int, alpha: float = 0.5, seed: int = 0):
    """Label-skew non-IID split (beyond-paper heterogeneity ablation)."""
    rng = np.random.default_rng(seed)
    y = data["y"]
    classes = np.unique(y)
    client_idx = [[] for _ in range(num_clients)]
    for c in classes:
        idx = rng.permutation(np.where(y == c)[0])
        props = rng.dirichlet([alpha] * num_clients)
        cuts = (np.cumsum(props)[:-1] * len(idx)).astype(int)
        for i, chunk in enumerate(np.split(idx, cuts)):
            client_idx[i].append(chunk)
    out = []
    for parts in client_idx:
        sel = np.concatenate(parts) if parts else np.array([], dtype=int)
        sel = rng.permutation(sel)
        out.append({k: v[sel] for k, v in data.items()})
    return out
