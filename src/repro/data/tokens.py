"""Synthetic LM token pipeline: deterministic, seeded, learnable.

Sequences follow a noisy affine recurrence over a vocabulary subset
(t_{i+1} = (a * t_i + c) mod V' with probability 1-p, uniform otherwise),
so a language model can visibly reduce loss in a few hundred steps — used
by the smoke tests and the distributed FL pretraining example.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class TokenDataConfig:
    vocab: int
    seq_len: int
    effective_vocab: int = 0     # 0 -> min(vocab, 4096)
    noise: float = 0.15
    seed: int = 0


def make_batches(cfg: TokenDataConfig, num_batches: int, batch_size: int):
    """Yields dicts {tokens (B,S), labels (B,S)} of int32."""
    rng = np.random.default_rng(cfg.seed)
    V = cfg.effective_vocab or min(cfg.vocab, 4096)
    a, c = 31, 17
    for _ in range(num_batches):
        t0 = rng.integers(0, V, size=(batch_size, 1))
        toks = [t0]
        for _ in range(cfg.seq_len):
            nxt = (a * toks[-1] + c) % V
            flip = rng.random((batch_size, 1)) < cfg.noise
            rand = rng.integers(0, V, size=(batch_size, 1))
            toks.append(np.where(flip, rand, nxt))
        seq = np.concatenate(toks, axis=1)
        yield {
            "tokens": seq[:, : cfg.seq_len].astype(np.int32),
            "labels": seq[:, 1 : cfg.seq_len + 1].astype(np.int32),
        }
