"""Synthetic CREMA-D-shaped SER dataset (DESIGN.md sec 2).

CREMA-D is not available offline, so we synthesize mel-spectrogram-like
patches with the same cardinality the paper uses after filtering:
5,882 clips, 4 classes (Neutral/Happy/Angry/Sad), 91 speakers, balanced
classes; 5 IID client partitions with 80/20 train/test (~941 train / ~234
test per client).

Generation model (shared-basis low-rank time-frequency fields):

    x = sum_r  a_r(class, sample) * u_r(t) v_r(f)   (SHARED basis; classes
                                                     differ only in their
                                                     coefficient vectors)
      + sum_s  b_s(speaker) * p_s(t) q_s(f)         (speaker nuisance)
      + noise * N(0,1)

plus label noise (a fraction of labels flipped uniformly).  Classes
sharing one smooth basis and differing only in mixing coefficients makes
the task genuinely hard for a small CNN (it must learn coefficient
geometry, not template matching), and label noise caps attainable accuracy
— giving the paper's 75 %-after-60-rounds convergence dynamics room to
appear under DP-SGD.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.models.ser_cnn import SERConfig

CLASSES = ("neutral", "happy", "angry", "sad")


@dataclass(frozen=True)
class SERDataConfig:
    n_total: int = 5882
    n_classes: int = 4
    n_speakers: int = 91
    time_frames: int = 64
    n_mels: int = 40
    rank: int = 6           # shared basis rank
    speaker_rank: int = 2
    class_gain: float = 0.8     # scale of class coefficient separation
    speaker_gain: float = 1.0
    noise: float = 1.6
    coeff_jitter: float = 0.55  # per-sample jitter on class coefficients
    label_noise: float = 0.12   # fraction of labels flipped uniformly
    seed: int = 1234


def _smooth_field(rng, n, length, smooth=6):
    """(n, length) smooth random curves via moving-average of white noise."""
    z = rng.standard_normal((n, length + smooth))
    k = np.ones(smooth) / smooth
    out = np.stack([np.convolve(z[i], k, mode="valid")[:length] for i in range(n)])
    return out / (out.std(axis=1, keepdims=True) + 1e-8)


def generate(cfg: SERDataConfig = SERDataConfig()):
    """Returns dict with x: (N, T, M) float32, y: (N,) int32, speaker: (N,)."""
    rng = np.random.default_rng(cfg.seed)
    T, M, R = cfg.time_frames, cfg.n_mels, cfg.rank

    # ONE shared smooth basis; classes differ only in coefficient vectors
    basis_u = _smooth_field(rng, R, T)                     # (R, T)
    basis_v = _smooth_field(rng, R, M)                     # (R, M)
    cls_a = rng.standard_normal((cfg.n_classes, R)) * cfg.class_gain

    spk_u = _smooth_field(rng, cfg.n_speakers * cfg.speaker_rank, T).reshape(
        cfg.n_speakers, cfg.speaker_rank, T
    )
    spk_v = _smooth_field(rng, cfg.n_speakers * cfg.speaker_rank, M).reshape(
        cfg.n_speakers, cfg.speaker_rank, M
    )
    spk_b = rng.standard_normal((cfg.n_speakers, cfg.speaker_rank)) * cfg.speaker_gain

    n = cfg.n_total
    y_true = rng.integers(0, cfg.n_classes, size=n)
    spk = rng.integers(0, cfg.n_speakers, size=n)
    # per-sample coefficient jitter (prosody / utterance variability)
    coeffs = cls_a[y_true] + cfg.coeff_jitter * rng.standard_normal((n, R))

    x = np.einsum("nr,rt,rm->ntm", coeffs, basis_u, basis_v)
    x += np.einsum("ns,nst,nsm->ntm", spk_b[spk], spk_u[spk], spk_v[spk])
    x += cfg.noise * rng.standard_normal((n, T, M))
    x = (x - x.mean()) / (x.std() + 1e-8)

    # label noise: flip a fraction of labels uniformly at random
    y = y_true.copy()
    if cfg.label_noise > 0:
        flip = rng.random(n) < cfg.label_noise
        y[flip] = rng.integers(0, cfg.n_classes, size=int(flip.sum()))

    return {
        "x": x.astype(np.float32),
        "y": y.astype(np.int32),
        "speaker": spk.astype(np.int32),
    }


def train_test_split(data, test_frac=0.2, seed=0):
    rng = np.random.default_rng(seed)
    n = data["y"].shape[0]
    perm = rng.permutation(n)
    n_test = int(n * test_frac)
    te, tr = perm[:n_test], perm[n_test:]
    take = lambda idx: {k: v[idx] for k, v in data.items()}
    return take(tr), take(te)
