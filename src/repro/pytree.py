"""Small pytree utilities shared across the framework.

Pure functions over parameter pytrees: global norms, scaling, linear
combinations, flattening for the DP clip kernel, and deterministic
per-leaf RNG splitting for noise injection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_global_norm(tree) -> jax.Array:
    """L2 norm over every leaf of a pytree (float32 accumulation)."""
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros((), jnp.float32)
    sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    return jnp.sqrt(sq)


def tree_scale(tree, s):
    return jax.tree_util.tree_map(lambda l: l * s, tree)


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def tree_lin(a, b, wa, wb):
    """wa*a + wb*b leafwise (used by FedAsync merge, Eq. 11)."""
    return jax.tree_util.tree_map(lambda x, y: wa * x + wb * y, a, b)


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_gaussian_like(key, tree, stddev):
    """Add iid N(0, stddev^2) noise of each leaf's shape; deterministic split."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    noised = [
        jax.random.normal(k, l.shape, jnp.float32).astype(l.dtype) * stddev
        for k, l in zip(keys, leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, noised)


def tree_gaussian_vector_like(key, tree) -> jax.Array:
    """Standard-normal draws matching :func:`tree_gaussian_like`'s exact
    per-leaf split/sample order, flattened to one f32 vector (the fused
    DP kernel's noise input: kernel adds ``stddev * z`` so the noised
    result matches the jnp path's ``noise_tree`` draw for draw)."""
    leaves = jax.tree_util.tree_leaves(tree)
    keys = jax.random.split(key, len(leaves))
    return jnp.concatenate([
        jax.random.normal(k, l.shape, jnp.float32).reshape(-1)
        for k, l in zip(keys, leaves)
    ])


def tree_size(tree) -> int:
    return sum(l.size for l in jax.tree_util.tree_leaves(tree))


def tree_flatten_to_vector(tree) -> jax.Array:
    """Concatenate all leaves into one flat f32 vector (kernel interface)."""
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])


def tree_unflatten_from_vector(vec, tree):
    """Inverse of tree_flatten_to_vector given a template tree."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    out, off = [], 0
    for l in leaves:
        out.append(vec[off : off + l.size].reshape(l.shape).astype(l.dtype))
        off += l.size
    return jax.tree_util.tree_unflatten(treedef, out)
