"""Crash-resilient engine runs: snapshot / restore for the cohort loops.

The engine loops (:func:`repro.engine.engine.run_fedavg_engine` /
``run_async_engine``) call :func:`save_fedavg` / :func:`save_async` at
loop-consistent points — the end of a barrier round, the end of one
event-loop body after re-dispatch — and :func:`restore_fedavg` /
:func:`restore_async` on ``resume_from``.  A snapshot captures EVERY
input the remaining iterations read:

* the server globals and the jax PRNG key chain;
* the device-resident client arena (params + optimizer state, with the
  queued dispatch writes flushed first — flushing early is a bitwise
  no-op, the scatters write the same values either way);
* on tiered runs (``StoreConfig.hot_slots``), the complete
  :class:`~repro.engine.statestore.TieredStateStore` state: residency
  maps, LRU ticks, dirty/prefetched sets, the host cold rows and the
  pending dispatch-params trees (deduped by identity so clients that
  pulled the same globals version restore sharing one tree, keeping
  the deferred-write flush batching identical);
* every pending :class:`~repro.engine.cohort.LocalRoundPlan` (batch
  index plan, dispatch key, duration, epsilon, pulled version) and the
  serialized event heap, ghost duplicate entries included;
* per-client host state: the numpy RNG streams (batch permutations and
  the virtual clock), dropout counters, update counts, accountant log
  moments, personal subtrees;
* the :class:`RunLog` so far, the
  :class:`~repro.core.faults.FaultInjector` state (its RNG streams
  resume mid-fault-sequence) and the runner's scheduler counters.

Restoring replays the rest of the run **bit-identically** to the
uninterrupted one — the abort/resume tier-1 tests assert RunLog equality
down to the float.  Deliberately NOT captured: the dataset arena and the
compiled steps (pure functions of the config — rebuilt), the
``EpsilonSchedule`` memo (pure), and the pipelined driver's in-flight
window (futures cannot be serialized; it refills within
``pipeline_depth`` cohorts, so only the wall-clock overlap — never a
logged value — differs on resume.  ``drain_waits`` is therefore exact on
the serial driver and approximate across a resume of a pipelined run).

Storage is the durable flat-npz store in :mod:`repro.checkpoint`
(atomic publish, ``keep_last`` retention, escaped tree-path keys);
arrays land in the npz, scalars/lists ride the JSON ``_meta`` entry
(floats round-trip exactly through JSON repr).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Optional

import jax
import numpy as np

from repro.checkpoint import checkpoint as _ckpt
from repro.checkpoint.checkpoint import _escape, _path_key
from repro.engine.cohort import LocalRoundPlan

_RUNNER_COUNTERS = ("cohorts_run", "h2d_bytes_total", "host_syncs_at_eval",
                    "host_syncs_between_evals", "blocking_submits",
                    "drain_waits", "screen_verdict_syncs")
_RUNLOG_FIELDS = ("times", "global_acc", "server_version", "update_counts",
                  "influence", "staleness", "eps_trajectory", "local_acc",
                  "cohort_sizes")


class SimulatedCrash(RuntimeError):
    """Raised by :class:`CheckpointPolicy` after ``crash_after_saves``
    snapshots — the fault-smoke benchmark and the abort/resume tests
    kill a run at a published checkpoint without killing the process."""


@dataclass
class CheckpointPolicy:
    """When and where the engine loops snapshot.

    ``every`` counts the loop's progress unit — barrier rounds for
    fedavg, merged updates for async.  ``keep_last`` bounds on-disk
    retention (see :mod:`repro.checkpoint`).  ``crash_after_saves=N``
    raises :class:`SimulatedCrash` right after the N-th successful save
    of this policy object — deterministic mid-flight aborts for tests.
    """

    directory: str
    every: int = 10
    keep_last: int = 3
    crash_after_saves: Optional[int] = None
    saves: int = field(default=0, init=False)
    _next: int = field(default=0, init=False, repr=False)

    def __post_init__(self):
        if self.every < 1 or self.every != int(self.every):
            raise ValueError(
                f"CheckpointPolicy.every must be an int >= 1: {self.every!r}")
        if self.keep_last < 1:
            raise ValueError(
                f"CheckpointPolicy.keep_last must be >= 1: {self.keep_last!r}")
        self._next = self.every

    def due(self, step: int) -> bool:
        return step >= self._next

    def mark(self, step: int):
        """Advance the cadence past ``step`` (called after a save, and on
        resume so the first post-resume snapshot lands on the next
        multiple instead of re-saving the restored step)."""
        self._next = (int(step) // self.every + 1) * self.every

    def _publish(self, step: int, tree: dict, meta: dict) -> str:
        path = _ckpt.save(self.directory, step, tree, meta,
                          keep_last=self.keep_last)
        self.mark(step)
        self.saves += 1
        if (self.crash_after_saves is not None
                and self.saves >= self.crash_after_saves):
            raise SimulatedCrash(
                f"simulated crash after checkpoint #{self.saves} "
                f"(step {step}, {path})")
        return path


# ---------------------------------------------------------------------------
# flat-tree helpers (escaped keys shared with repro.checkpoint)
# ---------------------------------------------------------------------------

def _add_tree(flat: dict, prefix: str, tree):
    """Flatten ``tree`` into ``flat`` under ``prefix`` with the store's
    escaped path keys (collision within a snapshot is a bug)."""
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = f"{prefix}/{_path_key(path)}" if path else prefix
        if key in flat:
            raise ValueError(f"snapshot key collision: {key!r}")
        flat[key] = np.asarray(jax.device_get(leaf))


def _fetch(flat: dict, key: str):
    """Read one snapshot array back.  The snapshot hands ``_ckpt.save`` an
    ALREADY-flat dict, so the store escapes each joined key once more as a
    single path component — reads must apply the same (injective) escape."""
    return flat[_escape(key)]


def _get_tree(flat: dict, prefix: str, template):
    """Rebuild a pytree from snapshot arrays using the LIVE template for
    structure and device placement.  Leaves whose template sharding spans
    several devices (the state arenas on a mesh) go back under that exact
    sharding; everything else returns as a host array — uncommitted, so
    downstream jitted computations place it exactly like the fresh-run
    path does (a ``device_put`` onto the template's single device would
    COMMIT the restored globals there and fight the mesh-constrained
    arena init)."""
    leaves, _ = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for path, leaf in leaves:
        key = f"{prefix}/{_path_key(path)}" if path else prefix
        arr = _fetch(flat, key)
        if isinstance(leaf, jax.Array) and len(leaf.sharding.device_set) > 1:
            out.append(jax.device_put(arr, leaf.sharding))
        else:
            out.append(np.asarray(arr))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out)


# ---------------------------------------------------------------------------
# snapshot assembly (shared by the fedavg / async save paths)
# ---------------------------------------------------------------------------

def _require_arena(runner):
    if not runner.use_arena:
        raise ValueError(
            "checkpoint/resume requires the device-arena data path "
            "(EngineConfig.device_arena=True with pytree-rule shardings) — "
            "the host path keeps per-client optimizer trees outside the "
            "snapshot's reach")


def _snapshot_common(runner, clients, log, injector, global_params, key,
                     pending: dict):
    """Build the (arrays, meta) pair every loop kind shares."""
    _require_arena(runner)
    runner._flush_writes()      # queued dispatch writes land in the arena
    flat = {"prng_key": np.asarray(jax.device_get(key))}
    _add_tree(flat, "globals", global_params)
    if runner._arena_params is not None:
        _add_tree(flat, "arena_params", runner._arena_params)
        _add_tree(flat, "arena_opt", runner._arena_opt)
    cmeta = {}
    for c in clients:
        cmeta[str(c.cid)] = {
            "rng": c.rng.bit_generator.state,
            "clock_rng": c.clock.rng.bit_generator.state,
            "clock_dropouts": int(c.clock.dropouts),
            "update_count": int(c.update_count),
            "model_version": int(c.model_version),
            "acct_steps": int(c.accountant.steps),
            "has_personal": c._personal is not None,
        }
        flat[f"acct_mu/{c.cid}"] = np.asarray(c.accountant._mu)
        if c._personal is not None:
            _add_tree(flat, f"personal/{c.cid}", c._personal)
    pmeta = {}
    for cid, p in pending.items():
        pmeta[str(cid)] = {
            "n_steps": int(p.n_steps),
            "duration": float(p.duration),
            "epsilon": float(p.epsilon),
            "model_version": int(p.model_version),
            "has_personal": p.personal_snapshot is not None,
            # lazy dispatch defers the permutation draws to staging; an
            # unmaterialized plan snapshots WITHOUT indices — the saved
            # client RNG stream still owes those draws, so resume re-derives
            # the identical plan at staging time
            "materialized": p.batch_idx is not None,
        }
        if p.batch_idx is not None:
            flat[f"plan_batch_idx/{cid}"] = np.asarray(p.batch_idx)
        flat[f"plan_key/{cid}"] = np.asarray(jax.device_get(p.key))
        if p.personal_snapshot is not None:
            _add_tree(flat, f"plan_personal/{cid}", p.personal_snapshot)
    meta = {
        "strategy": log.strategy,
        "num_clients": len(clients),
        "has_arena": runner._arena_params is not None,
        "clients": cmeta,
        "pending": pmeta,
        "runlog": {f: getattr(log, f) for f in _RUNLOG_FIELDS},
        "fault_events": [list(e) for e in log.fault_events],
        "injector": injector.state_dict() if injector is not None else None,
        "screening": (runner.screening.state_dict()
                      if runner.screening is not None else None),
        "runner": {k: int(getattr(runner, k)) for k in _RUNNER_COUNTERS},
        "store": {"hot_slots": runner.cfg.store.hot_slots,
                  "lookahead": int(runner.cfg.store.lookahead)},
    }
    if runner.store is not None:
        store = runner.store
        ss = store.state_meta()
        # Pending dispatch params are globals-tree REFERENCES; dedupe by
        # identity so the restored store shares one tree per pulled version
        # exactly like the live one (flush batching, memory).  Only pending
        # cids' entries matter — stale map entries are never read again.
        pp_map, tree_ids = {}, {}
        for cid in pending:
            tree = store.pending_params[cid]
            idx = tree_ids.get(id(tree))
            if idx is None:
                idx = len(tree_ids)
                tree_ids[id(tree)] = idx
                _add_tree(flat, f"store_params/{idx}", tree)
            pp_map[str(cid)] = idx
        ss["pp_map"] = pp_map
        ss["n_param_trees"] = len(tree_ids)
        for cid in sorted(store.cold):
            _add_tree(flat, f"store_cold/{cid}", store.cold[cid])
        meta["store_state"] = ss
        meta["store_cold"] = sorted(int(c) for c in store.cold)
    return flat, meta


def _restore_common(flat, meta, runner, clients, log, injector,
                    global_params):
    """Inverse of :func:`_snapshot_common`; returns (globals, key)."""
    _require_arena(runner)
    if meta["strategy"] != log.strategy:
        raise ValueError(
            f"checkpoint was taken under strategy {meta['strategy']!r}, "
            f"cannot resume a {log.strategy!r} run from it")
    if meta["num_clients"] != len(clients):
        raise ValueError(
            f"checkpoint has {meta['num_clients']} clients, the resuming "
            f"testbed has {len(clients)}")
    cur_store = {"hot_slots": runner.cfg.store.hot_slots,
                 "lookahead": int(runner.cfg.store.lookahead)}
    saved_store = meta.get("store")
    if saved_store is None:
        # pre-store checkpoints are all-resident by construction; lookahead
        # is inert without hot_slots, so inherit the current value
        saved_store = {"hot_slots": None,
                       "lookahead": cur_store["lookahead"]}
    if saved_store != cur_store:
        raise ValueError(
            f"StoreConfig mismatch: the checkpoint was taken with "
            f"{saved_store}, the resuming run has {cur_store} — hot-slot "
            "count and lookahead fix the arena shapes and the "
            "prefetch/eviction schedule, so resuming across them cannot "
            "replay bit-identically; rerun with the original StoreConfig")
    if (injector is None) != (meta["injector"] is None):
        raise ValueError(
            "fault configuration mismatch: the checkpointed run and the "
            "resuming run must both carry the same FaultModel (or neither)")
    saved_screening = meta.get("screening")
    if (runner.screening is None) != (saved_screening is None):
        raise ValueError(
            "screening configuration mismatch: the checkpointed run and "
            "the resuming run must both carry a ScreeningConfig (or "
            "neither) — quarantine strike/suspension state cannot be "
            "invented or discarded mid-run")
    globals_ = _get_tree(flat, "globals", global_params)
    key = jax.numpy.asarray(_fetch(flat, "prng_key"))
    if meta["has_arena"]:
        runner._ensure_state_arenas(globals_)
        runner._arena_params = _get_tree(
            flat, "arena_params", runner._arena_params)
        runner._arena_opt = _get_tree(flat, "arena_opt", runner._arena_opt)
    if runner.store is not None:
        ss = meta["store_state"]
        runner.store.load_state_meta(ss)
        # cold rows share the arena-opt TREE STRUCTURE (not shapes); an
        # int-leaf template keeps _get_tree on the host-array branch
        row_tmpl = jax.tree_util.tree_map(lambda _: 0, runner._arena_opt)
        runner.store.cold = {
            int(c): _get_tree(flat, f"store_cold/{c}", row_tmpl)
            for c in meta["store_cold"]}
        ptrees = [_get_tree(flat, f"store_params/{i}", globals_)
                  for i in range(int(ss["n_param_trees"]))]
        runner.store.pending_params = {
            int(c): ptrees[i] for c, i in ss["pp_map"].items()}
    for c in clients:
        cm = meta["clients"][str(c.cid)]
        c.rng.bit_generator.state = cm["rng"]
        c.clock.rng.bit_generator.state = cm["clock_rng"]
        c.clock.dropouts = int(cm["clock_dropouts"])
        c.update_count = int(cm["update_count"])
        c.model_version = int(cm["model_version"])
        c.accountant.steps = int(cm["acct_steps"])
        c.accountant._mu = np.array(
            _fetch(flat, f"acct_mu/{c.cid}"), np.float64)
        if cm["has_personal"]:
            tmpl = {k: globals_[k] for k in c.personal_keys}
            c._personal = _get_tree(flat, f"personal/{c.cid}", tmpl)
        else:
            c._personal = None
    for f in _RUNLOG_FIELDS:
        setattr(log, f, meta["runlog"][f])
    log.fault_events = [(str(k), int(cid), float(t))
                        for k, cid, t in meta["fault_events"]]
    if injector is not None:
        injector.load_state_dict(meta["injector"])
    if runner.screening is not None:
        runner.screening.load_state_dict(saved_screening)
    for k in _RUNNER_COUNTERS:
        setattr(runner, k, int(meta["runner"].get(k, 0)))
    return globals_, key


def _restore_pending(flat, meta, clients, globals_) -> dict:
    pending = {}
    for cid_s, pm in meta["pending"].items():
        cid = int(cid_s)
        snapshot = None
        if pm["has_personal"]:
            tmpl = {k: globals_[k] for k in clients[cid].personal_keys}
            snapshot = _get_tree(flat, f"plan_personal/{cid}", tmpl)
        plan = LocalRoundPlan(
            cid=cid, params0=None, opt_state=None,
            batch_idx=(np.asarray(
                _fetch(flat, f"plan_batch_idx/{cid}"), np.int32)
                if pm.get("materialized", True) else None),
            key=jax.numpy.asarray(_fetch(flat, f"plan_key/{cid}")),
            n_steps=int(pm["n_steps"]), duration=float(pm["duration"]),
            epsilon=float(pm["epsilon"]),
            model_version=int(pm["model_version"]))
        plan.personal_snapshot = snapshot
        pending[cid] = plan
    return pending


# ---------------------------------------------------------------------------
# loop-facing entry points
# ---------------------------------------------------------------------------

def save_async(policy: CheckpointPolicy, runner, clients, log, injector,
               global_params, key, heap, pending, t_virtual: float,
               server_version: int, total_updates: int) -> str:
    """Snapshot an async run at the end of one event-loop body (after
    re-dispatch: ``pending``/``heap`` describe the NEXT events)."""
    flat, meta = _snapshot_common(
        runner, clients, log, injector, global_params, key, pending)
    meta.update(kind="async", t_virtual=float(t_virtual),
                engine_version=int(server_version),
                heap=[[float(t), int(cid)] for t, cid in heap])
    return policy._publish(int(total_updates), flat, meta)


def restore_async(directory: str, runner, clients, log, injector,
                  global_params, heap, pending) -> tuple:
    """Rebuild async loop state in place (``heap``/``pending`` are filled);
    returns ``(global_params, key, t_virtual, server_version)``."""
    flat, meta = _ckpt.load_flat(directory)
    if meta.get("kind") != "async":
        raise ValueError(
            f"checkpoint in {directory!r} is kind={meta.get('kind')!r}, "
            "expected an async-engine snapshot")
    globals_, key = _restore_common(
        flat, meta, runner, clients, log, injector, global_params)
    pending.update(_restore_pending(flat, meta, clients, globals_))
    heap[:] = [(float(t), int(cid)) for t, cid in meta["heap"]]
    heapq.heapify(heap)     # saved in heap order already — belt and braces
    return globals_, key, float(meta["t_virtual"]), int(
        meta["engine_version"])


def save_fedavg(policy: CheckpointPolicy, runner, clients, log, injector,
                global_params, key, t_virtual: float, rnd: int) -> str:
    """Snapshot a fedavg run at the end of barrier round ``rnd`` (the
    round's merge, logging and eval are already in ``log``)."""
    flat, meta = _snapshot_common(
        runner, clients, log, injector, global_params, key, pending={})
    meta.update(kind="fedavg", t_virtual=float(t_virtual), round=int(rnd))
    return policy._publish(int(rnd), flat, meta)


def restore_fedavg(directory: str, runner, clients, log, injector,
                   global_params) -> tuple:
    """Returns ``(global_params, key, t_virtual, completed_round)``."""
    flat, meta = _ckpt.load_flat(directory)
    if meta.get("kind") != "fedavg":
        raise ValueError(
            f"checkpoint in {directory!r} is kind={meta.get('kind')!r}, "
            "expected a fedavg-engine snapshot")
    globals_, key = _restore_common(
        flat, meta, runner, clients, log, injector, global_params)
    return globals_, key, float(meta["t_virtual"]), int(meta["round"])


__all__ = ["SimulatedCrash", "CheckpointPolicy",
           "save_async", "restore_async", "save_fedavg", "restore_fedavg"]
