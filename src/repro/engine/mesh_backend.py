"""Mesh backend for the cohort engine: client-axis shardings + helpers.

The compiled cohort step (:mod:`repro.engine.cohort_step`) stacks every
cohort member on a leading client axis — the same layout
``core/fl_step.py``'s ``fl_train_step`` uses for its G client groups.  On
a mesh, constraining that leading axis onto the ``data``/``pod`` axes is
what turns the vmapped local phase into genuinely parallel per-member
execution; without the constraint XLA keeps the stacked program fully
replicated and every device redoes the whole cohort's work.

:class:`CohortSharding` is the piece the engine plumbs end-to-end: a
hashable ``leaf -> NamedSharding`` rule built from a
``launch.mesh``-style mesh, applied per stacked leaf at trace time
(params, optimizer state and batches all carry the leading cohort dim, so
one rank-generic rule covers them), and usable as a compiled-step cache
key so scenario sweeps over the same mesh reuse compiled programs
(``cohort_step.cached_cohort_step`` caches per (step-key, mesh);
``cohort_step.invalidate_step_cache(mesh=...)`` drops a mesh's entries).

Executor-choice guidance (measured on this repo's surfaces):

* single CPU device — ``client_axis="unroll"`` (flat program; vmap turns
  the SER convolutions into batched-filter convs off XLA CPU's fast path);
* mesh (forced host devices or real accelerators) — ``"vmap"``
  (simulation math) or ``"fl_step"`` (production per-microbatch-DP round
  via ``core/fl_step.make_local_phase``) with a :class:`CohortSharding`.

Partitioning note: GSPMD silently REPLICATES a leading-dim constraint
whose size does not divide evenly over the named axes (verified on CPU:
a (2, ...) or (4, ...) array constrained to an 8-way axis comes back
replicated).  :func:`cohort_spec` is therefore shape-aware — it emits the
partitioned spec only when the leading dim is a multiple of the data-axis
product and falls back to replication otherwise.  On the engine's default
arena data path this fallback no longer fires for cohorts: every cohort
pads to the bucket size from ``cohort.padded_cohort_size`` (a multiple of
the data-axis product; pad members are zero-step masked with merge
coefficient 0), so the stacked cohort ALWAYS partitions regardless of how
many completions the staleness window popped.  The replication fallback
still covers the arenas themselves and the PR-2 host path
(``EngineConfig(device_arena=False)``).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes, make_host_mesh
# the cohort axis partitions over the same pod x data product that
# fl_train_step calls its client-group axis — one definition, not two
from repro.launch.mesh import num_client_groups as _data_axis_size


def cohort_mesh(max_cohort: Optional[int] = None):
    """Host mesh for sharded cohorts: every available device on the data
    axis (clamped to a divisor of the device count, and to ``max_cohort``
    when given so a full cohort maps one-member-per-device-group)."""
    n = len(jax.devices())
    return make_host_mesh(data=n if max_cohort is None else min(n, max_cohort))


def cohort_spec(mesh, shape) -> P:
    """PartitionSpec for one cohort-stacked leaf: the leading client dim
    over the ``pod``/``data`` axes when the cohort size divides their
    product evenly, fully replicated otherwise (GSPMD would silently
    replicate an uneven leading-dim partition anyway — see module
    docstring)."""
    shape = tuple(shape)
    if not shape or shape[0] % _data_axis_size(mesh):
        return P()
    daxes = data_axes(mesh)
    return P(daxes if len(daxes) > 1 else daxes[0],
             *([None] * (len(shape) - 1)))


class CohortSharding:
    """Hashable ``leaf -> NamedSharding`` rule for cohort-stacked pytrees.

    Passed as ``client_shardings`` to the cohort step, which applies it to
    every stacked leaf inside the traced program (so it sees the concrete
    cohort size K of the shape being compiled; each K is its own XLA
    program, so the rule may partition one K and replicate another).

    With ``arch_cfg`` (a model-zoo architecture config) tensor dims are
    additionally sharded over ``model`` via
    ``launch.shardings.leaf_spec``'s ``role="client"`` rules — exactly
    ``fl_train_step``'s stacked layout.  Without it only the leading
    client dim is partitioned, which is the right call for the small SER
    CNN: zero tensor-parallel collectives inside the local phase.

    Equality/hash key on ``(mesh, arch_cfg)`` so
    ``cached_cohort_step`` memoizes one compiled step per mesh.
    """

    def __init__(self, mesh, arch_cfg=None):
        self.mesh = mesh
        self.arch_cfg = arch_cfg

    def spec(self, shape) -> P:
        shape = tuple(shape)
        base = cohort_spec(self.mesh, shape)
        if self.arch_cfg is None or len(shape) < 2:
            return base
        from repro.launch.shardings import leaf_spec
        tensor = leaf_spec(shape, self.arch_cfg, self.mesh, role="client")
        # keep cohort_spec's shape-aware leading dim (leaf_spec assumes the
        # leading dim always partitions) and graft the tensor dims onto it
        lead = base[0] if len(base) else None
        return P(lead, *tuple(tensor)[1:])

    def __call__(self, leaf) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(leaf.shape))

    def __eq__(self, other):
        return (type(other) is CohortSharding and self.mesh == other.mesh
                and self.arch_cfg == other.arch_cfg)

    def __hash__(self):
        return hash((CohortSharding, self.mesh, self.arch_cfg))

    def __repr__(self):
        return (f"CohortSharding(mesh={dict(self.mesh.shape)}, "
                f"arch_cfg={'set' if self.arch_cfg is not None else None})")


def assert_cohort_partitioned(tree, mesh) -> dict:
    """Assert every leaf of a cohort-stacked tree is GENUINELY partitioned
    on its leading axis: each addressable shard holds exactly
    ``K / data_axis_product`` members (not a padded or replicated copy).

    Returns ``{leaf_path: members_per_shard}`` for smoke-test output.
    Raises ``AssertionError`` naming the first offending leaf — the
    regression this guards is GSPMD quietly replicating the cohort axis,
    which keeps results correct while silently destroying the parallelism.
    """
    n_data = _data_axis_size(mesh)
    report = {}
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves:
        name = jax.tree_util.keystr(path)
        k = leaf.shape[0]
        if k % n_data:
            raise AssertionError(
                f"{name}: cohort size {k} is not a multiple of the "
                f"data-axis product {n_data} — this shape cannot partition")
        expect = (k // n_data,) + tuple(leaf.shape[1:])
        shard_shapes = {s.data.shape for s in leaf.addressable_shards}
        if shard_shapes != {expect}:
            raise AssertionError(
                f"{name}: expected every shard to hold {expect} of global "
                f"{tuple(leaf.shape)}, got shards {sorted(shard_shapes)} — "
                f"the cohort axis is replicated, not partitioned")
        report[name] = k // n_data
    return report
