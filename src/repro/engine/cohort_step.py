"""The compiled cohort step: one jitted program that runs every cohort
member's whole local round (all DP-SGD minibatch steps) plus the fused
weighted aggregation — the simulation-side sibling of
``repro.core.fl_step``'s ``fl_train_step`` (same structure: stacked client
axis -> mapped local phase -> weights-vector reduction over the client
axis).

Two data paths feed it (``make_cohort_step(..., arena=...)``):

* **arena** (the engine's default) — the cohort assembles ON DEVICE from
  persistent arenas: all clients' params/opt state live in one stacked
  (A, ...) pytree (slot per client) and every client's dataset is
  uploaded once; the step gathers the cohort with ``jnp.take`` over a
  (K,) slot vector, gathers minibatches from the resident data via the
  (K, S_max, B) int32 ``batch_idx`` plan, and scatters the new optimizer
  state back into the opt arena.  Per-cohort H2D is a few KB of
  indices.
* **host** (PR-2 baseline, ``arena=False``) — params/opt state stack in
  Python per cohort and fully materialized batch tensors cross H2D every
  step; kept for the benchmark comparison and for raw-pytree shardings.

Numerical parity with the legacy per-client loop is load-bearing (the
tier-1 parity tests assert it): the per-step math is literally the same
``dp_mean_gradient`` / ``opt.update`` composition as ``Client.local_train``
uses, including the per-step ``key, sub = split(key)`` chain, executed
inside one compiled program instead of one jit call per minibatch.
Members whose local round is shorter than the cohort's padded step count
are masked with ``jnp.where`` (a masked step leaves params/opt state/key
untouched).

Four client-axis executors (``client_axis``), chosen from CPU
measurements on the SER testbed (B=32, 5 local steps, 317k params; legacy
per-step dispatch = 377 ms per local round):

* ``"unroll"`` (default) — flat program: Python loop over the K members
  AND the local steps inside one jit.  ~250 ms per client warm (the
  whole-round fusion is where the engine's measured speedup comes from),
  but XLA compile time scales with K * S — keep ``max_cohort`` small and
  let the cross-run step cache amortize it.  The right choice on a single
  CPU device.
* ``"map"``  — ``lax.map`` over the stacked axis: compile cost is
  K-independent (body compiled once) but XLA CPU optimizes while-loop
  bodies poorly (~2x slower warm than the flat program).  Use for large
  cohorts / one-off runs.
* ``"vmap"`` — ``jax.vmap`` over the stacked axis, composing with
  ``client_shardings`` exactly like ``fl_train_step``'s broadcast/stack
  layout: on a mesh the cohort partitions over the data axes and members
  genuinely run in parallel (build the shardings with
  ``engine.mesh_backend.CohortSharding``).  On a single CPU device it
  turns every convolution into a batched-filter conv that XLA lowers off
  the fast path — do not use it there.
* ``"fl_step"`` — the PRODUCTION local round: each member runs
  ``core/fl_step.make_local_phase`` (per-microbatch DP clipping, one
  noise draw per local step, plain ``local_lr`` SGD — the client
  optimizer state passes through untouched), vmapped over the stacked
  axis and composing with ``client_shardings`` the same way.  Requires an
  ``FLStepConfig`` (``fl_cfg``); with DP off and ``n_micro=1`` it
  computes exactly the simulation math (the tier-1 parity test asserts
  it), with DP on it is the per-microbatch granularity the large
  architectures train under rather than the paper's per-example Eq. 4.

DP implementation (``dp_path``): with ``dp_path="pallas"`` (and DP on)
the member-major executors above are replaced by a STEP-MAJOR fused
executor — all K members advance one local step together, so each DP-SGD
step launches the fused ``repro.kernels.dp_clip`` clip+mean+noise kernel
ONCE over the whole cohort's stacked (K*B, D) per-example grad matrix
(not vmap-of-pallas_call per member), with the Gaussian noise added in
the kernel's final-tile epilogue.  The noise stddev stays the runtime
scalar argument and the noise draws replay ``noise_tree``'s per-leaf
split order, so the pallas path keeps both the one-program-per-sigma-
sweep invariant and float-tolerance parity with ``dp_path="jnp"`` and
the legacy loop (asserted by tests/test_dp_path_engine.py).  Padded
mask members ride along exactly like every other masked step: their
kernel row is computed and discarded (``n_steps=0`` masks the update,
the merge gives them coefficient 0).
"""
from __future__ import annotations

import functools
from dataclasses import replace
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.dp import (
    DPConfig, dp_mean_gradient, per_example_grads, validate_dp_path)

# flat-unroll the local-step loop up to this length; beyond it, fall back
# to a rolled scan to keep compile times bounded
_MAX_FULL_UNROLL = 16

# programs built since process start (make_cohort_step invocations): the
# observable cache-miss counter behind the Session sweep-amortization
# acceptance test/bench — a warm sigma sweep must NOT grow it per point
_STEP_BUILDS = 0


def step_builds() -> int:
    """How many cohort-step programs have been BUILT (cache misses at the
    make_cohort_step level; each build implies a fresh XLA trace+compile
    on first call).  ``benchmarks.fl_benchmarks.bench_sweep_amortization``
    reports the cold-vs-warm delta of this counter."""
    return _STEP_BUILDS

# the one place the executor set is defined: make_cohort_step and
# EngineConfig both validate against it (they used to disagree on the
# default too — "map" vs "unroll" — which handed direct callers the
# executor the docstring calls ~2x slower on CPU)
CLIENT_AXES = ("unroll", "map", "vmap", "fl_step")


def validate_client_axis(client_axis: str) -> str:
    if client_axis not in CLIENT_AXES:
        raise ValueError(
            f"client_axis must be one of {CLIENT_AXES}: {client_axis!r}")
    return client_axis


def _tree_where(mask, new, old):
    return jax.tree_util.tree_map(
        lambda n, o: jnp.where(mask, n, o), new, old)


def _corrupt_members(ref, new, scale):
    """In-step transit corruption of the stacked upload payloads:
    member k's delivered params become ``p0 + scale[k] * (p - p0)``
    (float32, elementwise).  ``scale[k] == 1.0`` is the CLEAN sentinel
    and selects ``p`` verbatim through ``jnp.where`` — clean members'
    payloads stay bitwise identical to an uncorrupted run, which is
    what makes screening-off a true no-op (and keeps the one-program
    invariant: the scale vector is a runtime argument, corruption rate
    sweeps never retrace).  A NaN scale poisons the whole payload (the
    all-NaN corruption mode)."""
    def leaf(p0, p):
        shape = (-1,) + (1,) * (p.ndim - 1)
        s = scale.reshape(shape).astype(jnp.float32)
        clean = (scale == 1.0).reshape(shape)
        p0f, pf = p0.astype(jnp.float32), p.astype(jnp.float32)
        return jnp.where(clean, pf, p0f + s * (pf - p0f)).astype(p.dtype)

    return jax.tree_util.tree_map(leaf, ref, new)


def _screen_members(ref, new):
    """Per-member screen pass over the stacked (K, D) update matrix:
    ``(finite, norm)`` with ``norm[k]`` the float32 L2 norm of member
    k's update delta and ``finite[k]`` its finiteness verdict
    (``isfinite(norm)`` — NaN/Inf anywhere in the delta, or a square
    sum overflowing float32, both trip it).  Threshold comparison is
    deliberately NOT here: it happens on the host against runtime
    config scalars, so one compiled program serves screening on/off and
    every threshold.  ``repro.core.screening.screen_update`` is the
    legacy loops' host-side mirror (same leaf-order accumulation)."""
    sq = None
    for p0, p in zip(jax.tree_util.tree_leaves(ref),
                     jax.tree_util.tree_leaves(new)):
        d = p.astype(jnp.float32) - p0.astype(jnp.float32)
        part = jnp.sum(d * d, axis=tuple(range(1, d.ndim)))    # (K,)
        sq = part if sq is None else sq + part
    norm = jnp.sqrt(sq)
    return jnp.isfinite(norm), norm


def _tree_where_members(live, new, old):
    """Per-member select over stacked (K, ...) trees: ``live`` is (K,)."""
    return jax.tree_util.tree_map(
        lambda n, o: jnp.where(live.reshape((-1,) + (1,) * (n.ndim - 1)),
                               n, o), new, old)


def _unflatten_members(mat, template):
    """(K, D) flat member vectors -> stacked tree with leaves (K, ...)
    shaped/typed like ``template`` (a single member's tree)."""
    leaves, treedef = jax.tree_util.tree_flatten(template)
    out, off = [], 0
    for l in leaves:
        out.append(mat[:, off:off + l.size]
                   .reshape((mat.shape[0],) + l.shape).astype(l.dtype))
        off += l.size
    return jax.tree_util.tree_unflatten(treedef, out)


def constrain_tree(tree, client_shardings):
    """Apply the shardings to every leaf: a callable rule (CohortSharding)
    maps each leaf's shape to its sharding; a raw pytree of shardings is
    zipped leaf-wise; None is a no-op.  The ONE place constraint
    application lives — the cohort step and the arena helpers both call
    it."""
    if client_shardings is None:
        return tree
    if callable(client_shardings):
        return jax.tree_util.tree_map(
            lambda l: jax.lax.with_sharding_constraint(
                l, client_shardings(l)), tree)
    return jax.tree_util.tree_map(
        jax.lax.with_sharding_constraint, tree, client_shardings)


def make_cohort_step(loss_fn: Callable, dp_cfg: DPConfig, opt,
                     use_dp: bool = True, dp_path: str = "jnp",
                     client_axis: str = "unroll", client_shardings=None,
                     fl_cfg=None, arena: bool = False,
                     donate_globals: bool = False, donate: bool = True,
                     add_noise: bool = True):
    """Build the jitted cohort program.

    Returns ``(cohort_step, merge_cohort)``.  With ``arena=False`` (the
    host-fed data path, kept as the PR-2 comparison baseline):

    ``cohort_step(stacked_params, stacked_opt, batches, keys, n_steps)``
    where every input has a leading cohort axis K:

      stacked_params: pytree, leaves (K, ...)
      stacked_opt:    pytree of optimizer state, leaves (K, ...)
      batches:        pytree, leaves (K, S_max, B, ...)
      keys:           (K, 2) uint32 dispatch keys
      n_steps:        (K,) int32 — member i executes its first n_steps[i]
                      loop iterations; the rest are masked no-ops

    With ``arena=True`` (the device-resident data path, the engine's
    default) the per-cohort inputs are a few KB of int32 indices instead
    of stacked state and batch tensors:

    ``cohort_step(arena_params, arena_opt, arena_data, slots, data_slots,
    batch_idx, keys, n_steps)`` where the arenas hold the resident
    clients' state/data on a leading slot axis (a slot per RESIDENT
    client plus a spare pad slot — all N clients on the all-resident
    layout, the ``StoreConfig.hot_slots`` hot set under the tiered
    store):

      arena_params: pytree, leaves (A, ...) — per-slot dispatch params
      arena_opt:    pytree, leaves (A, ...) — per-slot optimizer state
                    (DONATED: scatter-updated in place each cohort)
      arena_data:   pytree, leaves (A_d, n_max, ...) — every DISTINCT
                    dataset, uploaded once at runner construction and
                    keyed separately from client state (A_d never
                    shrinks to the hot set; see ``statestore.DataArena``)
      slots:        (K,) int32 — STATE arena slot of each cohort member
                    (padded mask members point at the spare slot)
      data_slots:   (K,) int32 — DATA arena row of each member (equal to
                    ``slots`` values on the legacy all-resident layout)
      batch_idx:    (K, S_max, B) int32 minibatch plan, gathered from
                    ``arena_data`` INSIDE the compiled program

    Cohort assembly is then one fused ``jnp.take`` over the slot axis and
    write-back one scatter — no per-member Python stacking, no batch
    tensors over H2D.

    ``merge_cohort(global_params, stacked_uploads, coeffs, g_coeff)``
    computes ``g_coeff * g + sum_i coeffs[i] * upload_i`` as one weighted
    reduction over the client axis (the ``weights``-vector aggregation of
    ``fl_train_step``, here carrying alpha/(1+tau) staleness weights or
    FedAvg's n_k / sum n).  With ``donate_globals`` its ``global_params``
    argument is donated — the async inner loop re-merges every cohort and
    never reuses the old globals.  Only safe when nothing else aliases the
    globals buffer: the CohortRunner enables it on the arena path (plans
    carry slot ids, not params0 snapshots) for populations without
    personalized clients (whose ``_personal`` / ``personal_snapshot``
    subtrees alias received globals across merges).

    ``client_shardings`` may be a pytree of NamedShardings congruent with
    the stacked params (legacy form, host path only) or a callable
    ``leaf -> sharding`` applied to EVERY stacked input — params,
    optimizer state and batches — at trace time
    (``engine.mesh_backend.CohortSharding``; being shape-aware it can
    partition the divisible leading dims and replicate the rest).
    ``fl_cfg`` (an ``FLStepConfig``) is required by the ``"fl_step"``
    executor and ignored by the others.

    ``dp_path`` selects the per-example DP implementation: ``"jnp"``
    (reference) or ``"pallas"`` — the fused clip+mean+noise kernel run
    STEP-MAJOR, one launch over the whole cohort's stacked (K*B, D)
    per-example grad matrix per local step (see the module docstring).
    Incompatible with ``client_axis="fl_step"`` (per-microbatch
    mechanism); ignored when ``use_dp=False``.

    Both data-path variants additionally take a trailing ``corrupt_scale``
    argument — a (K_pad,) float32 runtime vector of per-member transit-
    corruption scales (1.0 = clean sentinel, NaN = all-NaN payload, any
    other value = update-delta blowup; see ``repro.core.faults``) — and
    return a third output ``screen = (finite, norm)``: the per-member
    finite verdict and float32 update-delta L2 norm of the (possibly
    corrupted) upload payload, computed INSIDE the compiled program over
    the stacked (K, D) update matrix.  Threshold comparison against
    ``ScreeningConfig`` happens on the host, so screening on/off, every
    threshold and every corruption rate replay ONE compiled program
    (``step_builds`` delta 0 — the same invariant the runtime sigma
    preserves).

    Both data-path variants take a trailing ``noise_stddev`` argument — a
    runtime float32 scalar carrying the DP noise scale ``sigma * C / B``
    (computed ON THE HOST by the runner so it rounds to the same float32
    the statically-folded legacy constant does).  ``dp_cfg``'s own
    ``noise_multiplier`` is therefore IGNORED by the built program, and
    :func:`cached_cohort_step` strips it from the cache key: every point
    of a sigma sweep replays ONE compiled program instead of re-tracing
    per sigma (the Session sweep-amortization win).  The ``"fl_step"``
    executor is the exception — its noise is baked into ``fl_cfg.dp``
    (the production mechanism), so ``fl_cfg`` stays in the key unstripped.
    ``add_noise=False`` builds the STATICALLY noise-free variant for
    sigma == 0 runs (clipping still applies): a traced zero scale would
    defeat ``noise_tree``'s short-circuit and sample a full Gaussian tree
    per step just to multiply it away — the runner picks the variant from
    the clients' sigma, so only the noisy points of a sweep share the
    runtime-scale program.

    The cohort step itself is ALWAYS donation-free (see the arena-path
    comment: on jax 0.4.37 the XLA:CPU thunk runtime recycles donated
    step inputs while the PR-9 screen/corrupt epilogue still reads
    pre-scatter state, corrupting the returned uploads).  ``donate``
    now gates only the remaining donations outside the step —
    ``donate_globals`` on the fused merge and the arena write helpers;
    ``donate=False`` disables those too.  Donation is a throughput win
    on the strictly serial driver, but a donated-input dispatch BLOCKS
    the host until the computation finishes (measured on jax 0.4 CPU: a
    donation-chained loop runs fully synchronously while the identical
    non-donated chain dispatches asynchronously) — the engine's
    pipelined scheduler (``EngineConfig.pipeline_depth >= 2``)
    therefore runs every donation off so host planning can overlap
    device compute.
    """
    global _STEP_BUILDS
    _STEP_BUILDS += 1
    # the built program NEVER reads the static sigma (noise is the
    # runtime argument, or statically off with add_noise=False) — strip
    # it here too so direct callers get the same program the cache hands
    # out for every sigma
    dp_cfg = replace(dp_cfg, noise_multiplier=0.0)
    validate_client_axis(client_axis)
    validate_dp_path(dp_path)
    if client_axis == "fl_step" and fl_cfg is None:
        raise ValueError(
            "client_axis='fl_step' drives the production local round and "
            "needs an FLStepConfig (EngineConfig.fl_cfg / fl_cfg=)")
    if client_axis == "fl_step" and dp_path == "pallas":
        raise ValueError(
            "dp_path='pallas' fuses the PER-EXAMPLE clip+noise mechanism "
            "(paper Eq. 4-6); client_axis='fl_step' runs the per-microbatch "
            "production mechanism from fl_cfg.dp — use dp_path='jnp' there")
    fused_dp = bool(use_dp and dp_path == "pallas")
    if arena and client_shardings is not None and not callable(client_shardings):
        raise ValueError(
            "the arena data path needs a shape-aware callable shardings "
            "rule (engine.mesh_backend.CohortSharding) or None — a raw "
            "pytree of NamedShardings is congruent with one cohort stack, "
            "not with the (A, ...) arenas")

    def constrain(tree):
        return constrain_tree(tree, client_shardings)

    def one_step(params, opt_state, batch, key, noise_stddev):
        """Identical math to the legacy ``_dp_sgd_step`` / ``_sgd_step``
        (``noise_stddev`` carries the host-rounded sigma*C/B scalar)."""
        if use_dp:
            # add_noise=False: fall back to the (sigma-stripped) static
            # config — a concrete 0.0 stddev short-circuits noise_tree
            grad, _aux = dp_mean_gradient(
                loss_fn, params, batch, key, dp_cfg,
                noise_stddev=noise_stddev if add_noise else None)
        else:
            grad = jax.grad(
                lambda p: jnp.mean(
                    jax.vmap(lambda ex: loss_fn(p, ex))(batch)))(params)
        return opt.update(grad, opt_state, params)

    def local_phase(params, opt_state, key, batches, n_steps, noise_stddev):
        """One member's whole local round, fused across minibatch steps."""
        s_max = jax.tree_util.tree_leaves(batches)[0].shape[0]

        def apply_masked(p, o, k, step_i, batch):
            live = step_i < n_steps
            k_next, sub = jax.random.split(k)
            p_new, o_new = one_step(p, o, batch, sub, noise_stddev)
            return (_tree_where(live, p_new, p),
                    _tree_where(live, o_new, o),
                    jnp.where(live, k_next, k))

        if s_max <= _MAX_FULL_UNROLL:
            # flat step loop: measured ~1.5x faster than the same body
            # under a lax.scan/lax.map while loop on XLA CPU
            p, o, k = params, opt_state, key
            for s in range(s_max):
                batch = jax.tree_util.tree_map(lambda l: l[s], batches)
                p, o, k = apply_masked(p, o, k, s, batch)
            return p, o

        def body(carry, inp):
            step_i, batch = inp
            return apply_masked(*carry, step_i, batch), None

        (p, o, _), _ = jax.lax.scan(
            body, (params, opt_state, key), (jnp.arange(s_max), batches))
        return p, o

    if client_axis == "fl_step":
        from repro.core.fl_step import make_local_phase

        def batch_mean_loss(p, mb):
            # the engine's loss is per-example; fl_step's local phase
            # consumes a batch-mean loss (production loss signature)
            return jnp.mean(jax.vmap(lambda ex: loss_fn(p, ex))(mb))

        fl_local = make_local_phase(batch_mean_loss, fl_cfg)

        def fl_member_phase(params, opt_state, key, member_batches, steps):
            def to_micro(l):
                s, b = l.shape[0], l.shape[1]
                if b % fl_cfg.n_micro:
                    raise ValueError(
                        f"cohort batch size {b} is not divisible by "
                        f"fl_cfg.n_micro={fl_cfg.n_micro}")
                return l.reshape((s, fl_cfg.n_micro, b // fl_cfg.n_micro)
                                 + l.shape[2:])

            micro = jax.tree_util.tree_map(to_micro, member_batches)
            # production semantics: plain local_lr SGD inside the round —
            # the client optimizer state passes through untouched (the
            # server-side merge is the engine's weights-vector reduction)
            return fl_local(params, micro, key, n_steps=steps), opt_state

    if fused_dp:
        from repro.kernels.dp_clip.ops import dp_clip_mean_noise_cohort
        from repro.pytree import tree_gaussian_vector_like

        def fused_one_step(stacked_params, stacked_opt, ks, batch_s,
                           step_i, n_steps, noise_stddev):
            """All K members' DP-SGD step s, ONE fused kernel launch over
            the stacked (K*B, D) per-example grad matrix.  Per-member math
            (clip scales, mean, noise draws keyed off the same
            ``split(key)`` chain) is identical to ``one_step``'s
            ``dp_mean_gradient`` — only the launch granularity changes."""
            live = step_i < n_steps                       # (K,)
            splits = jax.vmap(jax.random.split)(ks)       # (K, 2, key)
            k_next, subs = splits[:, 0], splits[:, 1]
            g_per = jax.vmap(
                lambda p, b: per_example_grads(loss_fn, p, b))(
                    stacked_params, batch_s)              # leaves (K, B, ...)
            leaves = jax.tree_util.tree_leaves(g_per)
            K, bsz = leaves[0].shape[0], leaves[0].shape[1]
            flat = jnp.concatenate(
                [l.reshape(K, bsz, -1).astype(jnp.float32) for l in leaves],
                axis=2)                                   # (K, B, D)
            template = jax.tree_util.tree_map(lambda l: l[0, 0], g_per)
            if add_noise:
                z = jax.vmap(
                    lambda k: tree_gaussian_vector_like(k, template))(subs)
                means, _, _ = dp_clip_mean_noise_cohort(
                    flat, dp_cfg.clip_norm, noise_stddev, z)
            else:
                means, _, _ = dp_clip_mean_noise_cohort(flat, dp_cfg.clip_norm)
            grads = _unflatten_members(means, template)
            p_new, o_new = jax.vmap(opt.update)(
                grads, stacked_opt, stacked_params)
            return (_tree_where_members(live, p_new, stacked_params),
                    _tree_where_members(live, o_new, stacked_opt),
                    jnp.where(live[:, None], k_next, ks))

        def run_members_fused(stacked_params, stacked_opt, keys, batches,
                              n_steps, noise_stddev):
            """Step-major executor for the pallas DP path: the local-step
            loop is OUTSIDE the member axis so every iteration is one
            cohort-wide kernel launch (batches leaves are (K, S_max, B,
            ...))."""
            s_max = jax.tree_util.tree_leaves(batches)[0].shape[1]
            if s_max <= _MAX_FULL_UNROLL:
                p, o, k = stacked_params, stacked_opt, keys
                for s in range(s_max):
                    batch_s = jax.tree_util.tree_map(
                        lambda l: l[:, s], batches)
                    p, o, k = fused_one_step(
                        p, o, k, batch_s, s, n_steps, noise_stddev)
                return p, o

            step_major = jax.tree_util.tree_map(
                lambda l: jnp.moveaxis(l, 1, 0), batches)  # (S_max, K, B, ..)

            def body(carry, inp):
                step_i, batch_s = inp
                p, o, k = carry
                return fused_one_step(p, o, k, batch_s, step_i, n_steps,
                                      noise_stddev), None

            (p, o, _), _ = jax.lax.scan(
                body, (stacked_params, stacked_opt, keys),
                (jnp.arange(s_max), step_major))
            return p, o

    def run_members(stacked_params, stacked_opt, keys, batches, n_steps,
                    noise_stddev):
        """The client-axis executor switch over one stacked cohort
        (``noise_stddev`` is shared across members — broadcast, never
        stacked; the fl_step executor ignores it, its noise lives in
        ``fl_cfg.dp``).  ``dp_path="pallas"`` overrides the member-major
        executors with the step-major fused-kernel executor above."""
        if fused_dp:
            return run_members_fused(stacked_params, stacked_opt, keys,
                                     batches, n_steps, noise_stddev)
        if client_axis == "vmap":
            return jax.vmap(local_phase,
                            in_axes=(0, 0, 0, 0, 0, None))(
                stacked_params, stacked_opt, keys, batches, n_steps,
                noise_stddev)
        if client_axis == "fl_step":
            return jax.vmap(fl_member_phase)(
                stacked_params, stacked_opt, keys, batches, n_steps)
        if client_axis == "map":
            return jax.lax.map(
                lambda t: local_phase(*t, noise_stddev),
                (stacked_params, stacked_opt, keys, batches, n_steps))
        # unroll: flat program over the K members
        K = keys.shape[0]
        outs = [
            local_phase(unstack_tree(stacked_params, i),
                        unstack_tree(stacked_opt, i),
                        keys[i],
                        unstack_tree(batches, i),
                        n_steps[i],
                        noise_stddev)
            for i in range(K)
        ]
        return (stack_trees([p for p, _ in outs]),
                stack_trees([o for _, o in outs]))

    if arena:
        # the opt arena used to be donated and scatter-updated in place
        # (input/output leaves share shape/dtype and sharding rule, so
        # the alias materialized even on a mesh) — but with the PR-9
        # screen/corrupt epilogue adding reduction outputs to the step,
        # XLA:CPU's thunk runtime recycles the donated buffers while the
        # epilogue still reads pre-scatter state, and the step returns
        # garbage (observed on jax 0.4.37: NaN uploads in a fault-free
        # run; data-dependency fences don't help because the bug is in
        # buffer liveness, not op ordering).  The step is therefore
        # donation-free on every path — the same program shape the
        # pipelined scheduler always required (see audit_donation) — at
        # the cost of one opt-arena copy per serial-path cohort.
        @jax.jit
        def cohort_step(arena_params, arena_opt, arena_data, slots,
                        data_slots, batch_idx, keys, n_steps, noise_stddev,
                        corrupt_scale):
            def take(tree):
                return jax.tree_util.tree_map(
                    lambda l: jnp.take(l, slots, axis=0), tree)

            stacked_params = constrain(take(arena_params))
            stacked_opt = constrain(take(arena_opt))
            # in-step batch gather: (A_d, n_max, ...)[dslot, idx] -> the
            # (K, S_max, B, ...) batch stack, computed on device from the
            # resident datasets (only the index plan crossed H2D).  The
            # dataset arena has its OWN slot map: state slots are hot-set
            # rows under the tiered store while data rows stay resident
            # per distinct dataset (deduped), so the two index spaces
            # only coincide on the legacy all-resident layout.
            batches = constrain(jax.tree_util.tree_map(
                lambda l: l[data_slots[:, None, None], batch_idx],
                arena_data))
            new_params, new_opt = run_members(
                stacked_params, stacked_opt, keys, batches, n_steps,
                noise_stddev)
            # write-back scatter: pad members target the spare slot with
            # their (masked, unchanged) gathered state, so duplicate
            # indices only ever carry identical values.  The scatter
            # takes the HONEST post-training state: transit corruption
            # below touches only the returned upload payload, never the
            # client's own arena slot.
            new_arena_opt = constrain(jax.tree_util.tree_map(
                lambda a, n: a.at[slots].set(n), arena_opt, new_opt))
            upload = constrain(_corrupt_members(
                stacked_params, new_params, corrupt_scale))
            screen = constrain(_screen_members(stacked_params, upload))
            return upload, new_arena_opt, screen
    else:
        # donation-free like the arena path (the cohort-stack inputs
        # never aliased under mesh shardings anyway — replicated in,
        # partitioned out — and the single-device alias trips the same
        # XLA:CPU thunk-runtime buffer recycling described above)
        @jax.jit
        def cohort_step(stacked_params, stacked_opt, batches, keys, n_steps,
                        noise_stddev, corrupt_scale):
            stacked_params = constrain(stacked_params)
            if callable(client_shardings):
                stacked_opt = constrain(stacked_opt)
                batches = constrain(batches)
            new_params, new_opt = run_members(
                stacked_params, stacked_opt, keys, batches, n_steps,
                noise_stddev)
            upload = constrain(_corrupt_members(
                stacked_params, new_params, corrupt_scale))
            screen = constrain(_screen_members(stacked_params, upload))
            return upload, new_opt, screen

    # every merge replaces the globals, so donating kills the one
    # full-model re-allocation in the async inner loop — but only when the
    # runner proved nothing aliases the buffer (see docstring)
    merge_kw = {"donate_argnums": (0,)} if donate_globals and donate else {}

    @functools.partial(jax.jit, **merge_kw)
    def merge_cohort(global_params, stacked_uploads, coeffs, g_coeff):
        coeffs = coeffs.astype(jnp.float32)

        def leaf(g, s):
            sf = s.astype(jnp.float32)
            # a zero-coefficient member must contribute EXACTLY nothing
            # even when its payload is nonfinite (a screened-out corrupt
            # upload, PR 9): 0 * NaN = NaN would poison the reduction
            mask = (coeffs != 0.0).reshape((-1,) + (1,) * (sf.ndim - 1))
            sf = jnp.where(mask, sf, 0.0)
            return (g_coeff * g.astype(jnp.float32)
                    + jnp.tensordot(coeffs, sf, axes=(0, 0))).astype(g.dtype)

        return jax.tree_util.tree_map(leaf, global_params, stacked_uploads)

    return cohort_step, merge_cohort


# ---------------------------------------------------------------------------
# cross-run compile cache: repeated runs over the same testbed (benchmark
# sweeps, parity tests) reuse the compiled programs instead of re-tracing
# ---------------------------------------------------------------------------

_STEP_CACHE: dict = {}


def _hashable_loss(loss_fn):
    """Normalize functools.partial losses so two testbeds built from the
    same model config share one compiled step."""
    if isinstance(loss_fn, functools.partial):
        try:
            key = (loss_fn.func, loss_fn.args,
                   tuple(sorted(loss_fn.keywords.items())))
            hash(key)
            return key
        except TypeError:
            pass
    return loss_fn


_UNCACHEABLE = object()  # sentinel: shardings we cannot turn into a key


def _shardings_key(client_shardings):
    """Hashable cache key for the shardings argument.  ``CohortSharding``
    hashes by (mesh, arch_cfg); a raw pytree of NamedShardings flattens to
    (treedef, leaves); anything unhashable disables caching for that call
    only (returns the _UNCACHEABLE sentinel, never None — None means "no
    shardings" and is a perfectly cacheable key)."""
    if client_shardings is None:
        return None
    try:
        hash(client_shardings)
        return client_shardings
    except TypeError:
        pass
    try:
        leaves, treedef = jax.tree_util.tree_flatten(client_shardings)
        key = (treedef, tuple(leaves))
        hash(key)
        return key
    except TypeError:
        return _UNCACHEABLE


def cached_cohort_step(loss_fn, dp_cfg, opt, use_dp=True, dp_path="jnp",
                       client_axis="unroll", client_shardings=None,
                       fl_cfg=None, arena=False, donate_globals=False,
                       donate=True, add_noise=True):
    """Memoized :func:`make_cohort_step`, keyed per (training config,
    executor, data path, shardings/mesh): scenario sweeps over the same
    testbed AND mesh reuse the compiled programs instead of re-tracing
    every run.  Supplying shardings no longer bypasses the cache —
    mesh-lifetime entries are dropped explicitly with
    :func:`invalidate_step_cache`.  The cache only ever holds the compiled
    step FUNCTIONS; arenas are per-runner arguments, never closed over, so
    dropping a runner frees its device buffers regardless of the cache.

    ``dp_cfg.noise_multiplier`` is STRIPPED from both the key and the
    built program: the noise scale is a runtime argument of the compiled
    step, so every sigma of a noise sweep shares one entry (the
    ``"fl_step"`` executor's noise lives in ``fl_cfg``, which stays in
    the key)."""
    dp_cfg = replace(dp_cfg, noise_multiplier=0.0)

    def build():
        return make_cohort_step(
            loss_fn, dp_cfg, opt, use_dp=use_dp, dp_path=dp_path,
            client_axis=client_axis, client_shardings=client_shardings,
            fl_cfg=fl_cfg, arena=arena, donate_globals=donate_globals,
            donate=donate, add_noise=add_noise)

    sh_key = _shardings_key(client_shardings)
    if sh_key is _UNCACHEABLE:
        return build()
    key = (_hashable_loss(loss_fn), dp_cfg, opt, use_dp, dp_path,
           client_axis, fl_cfg, sh_key, arena, donate_globals, donate,
           add_noise)
    try:
        hash(key)
    except TypeError:
        return build()
    if key not in _STEP_CACHE:
        _STEP_CACHE[key] = build()
    return _STEP_CACHE[key]


def cached_arena_helpers(arena_slots: int, opt, client_shardings,
                         donate: bool = True):
    """Compiled arena plumbing — ``(init, write, gather, write_rows,
    init_opt)`` over the (A, ...) client-state arenas — shared across
    CohortRunners and stored in the SAME cache as the compiled steps, so
    :func:`invalidate_step_cache` drops a mesh's helper entries alongside
    its step entries (the documented mesh-lifetime cleanup covers both).
    The arenas themselves are call arguments, never closed over: the
    cache holds compiled functions only, no device buffers.
    ``donate=False`` keeps the writers out-of-place (the pipelined
    scheduler needs async dispatch; donated inputs block it — see
    :func:`make_cohort_step`).

    ``write_rows``/``init_opt`` serve the tiered store's hot-set churn:
    ``write_rows(arena, rows, slots)`` scatters pre-stacked per-slot
    rows (cold-store reloads — leaves (k, ...)); ``init_opt(arena_opt,
    p, slots)`` re-initializes slots' optimizer rows in place from a
    params tree (``opt.init`` is value-independent, so a re-initialized
    slot is bitwise the row a fresh all-resident arena would hold)."""

    def build():
        def constrain(tree):
            return constrain_tree(tree, client_shardings)

        @jax.jit
        def init(p):
            stacked = jax.tree_util.tree_map(
                lambda l: jnp.broadcast_to(
                    l[None], (arena_slots,) + l.shape), p)
            return constrain(stacked), constrain(jax.vmap(opt.init)(stacked))

        @functools.partial(
            jax.jit, **({"donate_argnums": (0,)} if donate else {}))
        def write(arena, p, slots):
            return constrain(jax.tree_util.tree_map(
                lambda a, l: a.at[slots].set(
                    jnp.broadcast_to(l[None].astype(a.dtype),
                                     (slots.shape[0],) + l.shape)),
                arena, p))

        @jax.jit
        def gather(arena, slots):
            return jax.tree_util.tree_map(
                lambda l: jnp.take(l, slots, axis=0), arena)

        @functools.partial(
            jax.jit, **({"donate_argnums": (0,)} if donate else {}))
        def write_rows(arena, rows, slots):
            return constrain(jax.tree_util.tree_map(
                lambda a, r: a.at[slots].set(r.astype(a.dtype)),
                arena, rows))

        @functools.partial(
            jax.jit, **({"donate_argnums": (0,)} if donate else {}))
        def init_opt(arena_opt, p, slots):
            fresh = opt.init(p)
            return constrain(jax.tree_util.tree_map(
                lambda a, l: a.at[slots].set(
                    jnp.broadcast_to(l[None].astype(a.dtype),
                                     (slots.shape[0],) + l.shape)),
                arena_opt, fresh))

        return init, write, gather, write_rows, init_opt

    sh_key = _shardings_key(client_shardings)
    if sh_key is _UNCACHEABLE:
        return build()
    key = ("arena_helpers", arena_slots, opt, sh_key, donate)
    try:
        hash(key)
    except TypeError:
        return build()
    if key not in _STEP_CACHE:
        _STEP_CACHE[key] = build()
    return _STEP_CACHE[key]


def _mentions_mesh(obj, mesh) -> bool:
    if isinstance(obj, tuple):
        return any(_mentions_mesh(o, mesh) for o in obj)
    return getattr(obj, "mesh", None) == mesh


def invalidate_step_cache(mesh=None) -> int:
    """Explicitly drop cached compiled cohort steps.

    With ``mesh``, drop only entries whose shardings were built for that
    mesh (call it when a mesh's devices go away, or between sweeps that
    rebuild meshes); with no argument, clear everything.  Returns the
    number of entries dropped.
    """
    if mesh is None:
        n = len(_STEP_CACHE)
        _STEP_CACHE.clear()
        return n
    drop = [k for k in _STEP_CACHE if _mentions_mesh(k, mesh)]
    for k in drop:
        del _STEP_CACHE[k]
    return len(drop)


def stack_trees(trees):
    """Stack a list of identically-shaped pytrees on a new leading axis."""
    return jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *trees)


def unstack_tree(tree, i: int):
    """Member ``i``'s slice of a stacked pytree."""
    return jax.tree_util.tree_map(lambda l: l[i], tree)
