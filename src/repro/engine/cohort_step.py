"""The compiled cohort step: one jitted program that runs every cohort
member's whole local round (all DP-SGD minibatch steps) plus the fused
weighted aggregation — the simulation-side sibling of
``repro.core.fl_step``'s ``fl_train_step`` (same structure: stacked client
axis -> mapped local phase -> weights-vector reduction over the client
axis).

Numerical parity with the legacy per-client loop is load-bearing (the
tier-1 parity tests assert it): the per-step math is literally the same
``dp_mean_gradient`` / ``opt.update`` composition as ``Client.local_train``
uses, including the per-step ``key, sub = split(key)`` chain, executed
inside one compiled program instead of one jit call per minibatch.
Members whose local round is shorter than the cohort's padded step count
are masked with ``jnp.where`` (a masked step leaves params/opt state/key
untouched).

Three client-axis executors (``client_axis``), chosen from CPU
measurements on the SER testbed (B=32, 5 local steps, 317k params; legacy
per-step dispatch = 377 ms per local round):

* ``"unroll"`` (default) — flat program: Python loop over the K members
  AND the local steps inside one jit.  ~250 ms per client warm (the
  whole-round fusion is where the engine's measured speedup comes from),
  but XLA compile time scales with K * S — keep ``max_cohort`` small and
  let the cross-run step cache amortize it.
* ``"map"``  — ``lax.map`` over the stacked axis: compile cost is
  K-independent (body compiled once) but XLA CPU optimizes while-loop
  bodies poorly (~2x slower warm than the flat program).  Use for large
  cohorts / one-off runs.
* ``"vmap"`` — ``jax.vmap`` over the stacked axis, composing with
  ``client_shardings`` exactly like ``fl_train_step``'s broadcast/stack
  layout: on a mesh the cohort partitions over the data axes and members
  genuinely run in parallel.  (On CPU it turns every convolution into a
  batched-filter conv that XLA lowers off the fast path — do not use it
  single-device.)
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.dp import DPConfig, dp_mean_gradient

# flat-unroll the local-step loop up to this length; beyond it, fall back
# to a rolled scan to keep compile times bounded
_MAX_FULL_UNROLL = 16


def _tree_where(mask, new, old):
    return jax.tree_util.tree_map(
        lambda n, o: jnp.where(mask, n, o), new, old)


def make_cohort_step(loss_fn: Callable, dp_cfg: DPConfig, opt,
                     use_dp: bool = True, use_kernel: bool = False,
                     client_axis: str = "map", client_shardings=None):
    """Build the jitted cohort program.

    Returns ``(cohort_step, merge_cohort)``:

    ``cohort_step(stacked_params, stacked_opt, batches, keys, n_steps)``
    where every input has a leading cohort axis K:

      stacked_params: pytree, leaves (K, ...)
      stacked_opt:    pytree of optimizer state, leaves (K, ...)
      batches:        pytree, leaves (K, S_max, B, ...)
      keys:           (K, 2) uint32 dispatch keys
      n_steps:        (K,) int32 — member i executes its first n_steps[i]
                      loop iterations; the rest are masked no-ops

    ``merge_cohort(global_params, stacked_uploads, coeffs, g_coeff)``
    computes ``g_coeff * g + sum_i coeffs[i] * upload_i`` as one weighted
    reduction over the client axis (the ``weights``-vector aggregation of
    ``fl_train_step``, here carrying alpha/(1+tau) staleness weights or
    FedAvg's n_k / sum n).
    """
    if client_axis not in ("unroll", "map", "vmap"):
        raise ValueError(
            f"client_axis must be 'unroll', 'map' or 'vmap': {client_axis!r}")

    def constrain(tree):
        if client_shardings is None:
            return tree
        return jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, tree, client_shardings)

    def one_step(params, opt_state, batch, key):
        """Identical math to the legacy ``_dp_sgd_step`` / ``_sgd_step``."""
        if use_dp:
            grad, _aux = dp_mean_gradient(
                loss_fn, params, batch, key, dp_cfg, use_kernel=use_kernel)
        else:
            grad = jax.grad(
                lambda p: jnp.mean(
                    jax.vmap(lambda ex: loss_fn(p, ex))(batch)))(params)
        return opt.update(grad, opt_state, params)

    def local_phase(params, opt_state, key, batches, n_steps):
        """One member's whole local round, fused across minibatch steps."""
        s_max = jax.tree_util.tree_leaves(batches)[0].shape[0]

        def apply_masked(p, o, k, step_i, batch):
            live = step_i < n_steps
            k_next, sub = jax.random.split(k)
            p_new, o_new = one_step(p, o, batch, sub)
            return (_tree_where(live, p_new, p),
                    _tree_where(live, o_new, o),
                    jnp.where(live, k_next, k))

        if s_max <= _MAX_FULL_UNROLL:
            # flat step loop: measured ~1.5x faster than the same body
            # under a lax.scan/lax.map while loop on XLA CPU
            p, o, k = params, opt_state, key
            for s in range(s_max):
                batch = jax.tree_util.tree_map(lambda l: l[s], batches)
                p, o, k = apply_masked(p, o, k, s, batch)
            return p, o

        def body(carry, inp):
            step_i, batch = inp
            return apply_masked(*carry, step_i, batch), None

        (p, o, _), _ = jax.lax.scan(
            body, (params, opt_state, key), (jnp.arange(s_max), batches))
        return p, o

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def cohort_step(stacked_params, stacked_opt, batches, keys, n_steps):
        stacked_params = constrain(stacked_params)
        if client_axis == "vmap":
            new_params, new_opt = jax.vmap(local_phase)(
                stacked_params, stacked_opt, keys, batches, n_steps)
        elif client_axis == "map":
            new_params, new_opt = jax.lax.map(
                lambda t: local_phase(*t),
                (stacked_params, stacked_opt, keys, batches, n_steps))
        else:  # unroll: flat program over the K members
            K = keys.shape[0]
            outs = [
                local_phase(unstack_tree(stacked_params, i),
                            unstack_tree(stacked_opt, i),
                            keys[i],
                            unstack_tree(batches, i),
                            n_steps[i])
                for i in range(K)
            ]
            new_params = stack_trees([p for p, _ in outs])
            new_opt = stack_trees([o for _, o in outs])
        return constrain(new_params), new_opt

    @jax.jit
    def merge_cohort(global_params, stacked_uploads, coeffs, g_coeff):
        coeffs = coeffs.astype(jnp.float32)
        return jax.tree_util.tree_map(
            lambda g, s: (g_coeff * g.astype(jnp.float32)
                          + jnp.tensordot(coeffs, s.astype(jnp.float32),
                                          axes=(0, 0))).astype(g.dtype),
            global_params, stacked_uploads)

    return cohort_step, merge_cohort


# ---------------------------------------------------------------------------
# cross-run compile cache: repeated runs over the same testbed (benchmark
# sweeps, parity tests) reuse the compiled programs instead of re-tracing
# ---------------------------------------------------------------------------

_STEP_CACHE: dict = {}


def _hashable_loss(loss_fn):
    """Normalize functools.partial losses so two testbeds built from the
    same model config share one compiled step."""
    if isinstance(loss_fn, functools.partial):
        try:
            key = (loss_fn.func, loss_fn.args,
                   tuple(sorted(loss_fn.keywords.items())))
            hash(key)
            return key
        except TypeError:
            pass
    return loss_fn


def cached_cohort_step(loss_fn, dp_cfg, opt, use_dp=True, use_kernel=False,
                       client_axis="map", client_shardings=None):
    """Memoized :func:`make_cohort_step` (no caching when shardings are
    given — NamedShardings are mesh-lifetime objects)."""
    if client_shardings is not None:
        return make_cohort_step(loss_fn, dp_cfg, opt, use_dp=use_dp,
                                use_kernel=use_kernel,
                                client_axis=client_axis,
                                client_shardings=client_shardings)
    key = (_hashable_loss(loss_fn), dp_cfg, opt, use_dp, use_kernel,
           client_axis)
    try:
        hash(key)
    except TypeError:
        return make_cohort_step(loss_fn, dp_cfg, opt, use_dp=use_dp,
                                use_kernel=use_kernel, client_axis=client_axis)
    if key not in _STEP_CACHE:
        _STEP_CACHE[key] = make_cohort_step(
            loss_fn, dp_cfg, opt, use_dp=use_dp, use_kernel=use_kernel,
            client_axis=client_axis)
    return _STEP_CACHE[key]


def stack_trees(trees):
    """Stack a list of identically-shaped pytrees on a new leading axis."""
    return jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *trees)


def unstack_tree(tree, i: int):
    """Member ``i``'s slice of a stacked pytree."""
    return jax.tree_util.tree_map(lambda l: l[i], tree)
