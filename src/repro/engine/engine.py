"""Cohort-batched async execution engine (event-queue driver).

Drives the compiled cohort step (``repro.engine.cohort_step``) from the
virtual-clock priority queue that the legacy per-client loop in
``repro.core.server`` uses:

  1. **dispatch**: a client pulls the current globals, its minibatch
     schedule / PRNG chain / tier-clock duration / accountant step are
     planned on the host (``LocalRoundPlan``) and its completion event is
     pushed on the heap — exactly the bookkeeping ``Client.local_train``
     does, but WITHOUT running the training yet;
  2. **cohort pop**: all completions within ``staleness_window`` virtual
     seconds of the earliest pending event come off the heap as one cohort;
  3. **compiled local phase**: the cohort runs as ONE jitted program.
     On the default device-resident data path the members' dispatch-time
     params / optimizer states are GATHERED inside the program from a
     per-client arena (dispatch wrote the pulled globals into the
     member's slot), minibatches are gathered from the once-uploaded
     per-client datasets by a (K, S_max, B) int32 index plan — the only
     per-cohort H2D traffic — and cohorts pad to bucket sizes that always
     partition on a mesh (pad members are zero-step masked and merge with
     coefficient 0);
  4. **merge**: FedAvg/FedAsync weights (n_k / sum n, alpha/(1+tau_i))
     are folded into a single weights-vector reduction over the client
     axis (``fold_cohort_weights`` makes the fused merge exactly equal to
     the legacy sequential merges); FedBuff / AdaptiveAsync / personalized
     clients route per-member through ``aggregation.apply_update`` — the
     same switch the legacy loop uses;
  5. **bookkeeping**: staleness, per-tier update counts, epsilon
     trajectories and influence land in the same ``RunLog`` the legacy
     loops produce, so every benchmark/figure works unchanged.

With ``staleness_window=0`` cohorts have size 1 and the engine reproduces
the legacy event loop update-for-update (the tier-1 parity tests assert
params allclose and identical update-count/epsilon bookkeeping).  A
positive window trades a bounded amount of merge reordering for wide
cohorts and is where the throughput win comes from (see
``benchmarks/fl_benchmarks.py::bench_engine_throughput``).
"""
from __future__ import annotations

import functools
import heapq
from dataclasses import dataclass, replace
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import (
    AdaptiveAsync, FedAsync, FedAvg, FedBuff, apply_update)
from repro.core.runlog import RunLog, eval_all
from repro.engine.cohort import (
    LocalRoundPlan, fedavg_weights, fold_cohort_weights, padded_cohort_size,
    plan_batches, pop_cohort, steps_per_round)
from repro.engine.cohort_step import (
    cached_arena_helpers, cached_cohort_step, stack_trees, unstack_tree,
    validate_client_axis)
from repro.engine.mesh_backend import CohortSharding


@dataclass(frozen=True)
class EngineConfig:
    staleness_window: float = 0.0  # virtual seconds of completions per cohort
    max_cohort: int = 2            # cap on POPPED cohort size ("unroll" compile
                                   # time scales with it; see cohort_step) — on
                                   # a mesh the arena path pads the compiled
                                   # leading dim up to the next bucket that
                                   # divides the data-axis product
    fused_merge: bool = True       # fold FedAvg/FedAsync into the weights vector
    delta: float = 1e-5            # accountant delta (matches legacy loop)
    client_axis: str = "unroll"    # unroll (single CPU) | map | vmap (mesh,
                                   # sim math) | fl_step (mesh, production
                                   # per-microbatch-DP round) — see cohort_step
    pow2_cohorts: bool = True      # bucket cohort sizes to bound recompiles
    mesh: Optional[object] = None  # jax Mesh: partition the cohort axis over
                                   # its data axes (engine.mesh_backend builds
                                   # the CohortSharding); None = replicated
    fl_cfg: Optional[object] = None  # FLStepConfig for client_axis="fl_step"
    device_arena: bool = True      # device-resident data path: client
                                   # params/opt state live in a stacked arena,
                                   # datasets upload once, cohorts assemble as
                                   # a compiled gather fed by index plans only
                                   # (False = PR-2 host-fed baseline)

    def __post_init__(self):
        validate_client_axis(self.client_axis)


def _resolve_mesh_cfg(cfg: EngineConfig, mesh) -> EngineConfig:
    """Fold a frontend-supplied mesh into the engine config (an explicit
    EngineConfig.mesh wins over the run_experiment/run_* keyword)."""
    if mesh is not None and cfg.mesh is None:
        cfg = replace(cfg, mesh=mesh)
    return cfg




class CohortRunner:
    """Owns the compiled cohort program and the host-side plan/IO glue.

    When ``cfg.mesh`` is set (or ``client_shardings`` is passed
    explicitly), the compiled step constrains every stacked input's
    leading cohort dim onto the mesh's data axes — the members of a
    full-size cohort then genuinely run on different devices (see
    :mod:`repro.engine.mesh_backend`).

    With ``cfg.device_arena`` (the default) the per-cohort hot path is
    device-resident end to end: every client's params and optimizer state
    live in one stacked arena (slot per client, sharded by the same
    shape-aware rule), every client's dataset uploads to device ONCE at
    construction, and a cohort is assembled inside the compiled step by a
    ``jnp.take`` over slots plus an in-step batch gather driven by the
    (K, S_max, B) int32 index plan — the only per-cohort H2D traffic.
    Cohorts additionally pad to the bucket size from
    :func:`repro.engine.cohort.padded_cohort_size`, so on a mesh the
    compiled leading dim always divides the data-axis product and the
    cohort ALWAYS partitions (pad members gather a spare slot, run zero
    masked steps and merge with coefficient zero).
    """

    def __init__(self, clients, cfg: EngineConfig,
                 client_shardings=None):
        c0 = clients[0]
        for c in clients:
            if (c.dp_cfg != c0.dp_cfg or c.use_dp != c0.use_dp
                    or c.use_kernel != c0.use_kernel or c.opt != c0.opt
                    or c.batch_size != c0.batch_size
                    or not (c.loss_fn is c0.loss_fn
                            or c.loss_fn == c0.loss_fn)):
                raise ValueError(
                    "cohort engine requires homogeneous client training "
                    "configs (heterogeneity lives in the virtual clocks)")
        self.clients = clients
        self.cfg = cfg
        # run-level padded step count: every client's local round length is
        # fixed by (n_train, B, E), so padding all cohorts to the global max
        # keeps the compiled step's shapes constant across the whole run
        self.s_max = max(
            steps_per_round(c.n_train, c.batch_size, c.local_epochs)
            for c in clients)
        if cfg.client_axis == "fl_step" and c0.use_dp:
            # the host-side accountant (dispatch) charges the clients'
            # dp_cfg mechanism: eps depends on (q, sigma, steps) — the
            # sampling rate and step count are the same either way and
            # eps is clip-norm-independent, so the bound transfers to the
            # executed per-microbatch mechanism ONLY when the noise
            # multipliers agree and noise is actually added per step
            fl_dp = cfg.fl_cfg.dp if cfg.fl_cfg is not None else None
            if (fl_dp is None or fl_dp.granularity != "per_microbatch"
                    or fl_dp.noise_multiplier != c0.dp_cfg.noise_multiplier):
                raise ValueError(
                    "client_axis='fl_step' with DP clients requires "
                    "fl_cfg.dp to use granularity='per_microbatch' with the "
                    "same noise_multiplier as the clients' dp_cfg "
                    f"(got {fl_dp!r} vs sigma={c0.dp_cfg.noise_multiplier}) "
                    "— otherwise the reported epsilon does not describe "
                    "the executed mechanism")
        if client_shardings is None and cfg.mesh is not None:
            client_shardings = CohortSharding(cfg.mesh)
        self.client_shardings = client_shardings
        # a raw pytree of per-leaf shardings is congruent with one cohort
        # stack, not with the arenas — fall back to the host data path
        self.use_arena = bool(cfg.device_arena) and (
            client_shardings is None or callable(client_shardings))
        # donate the globals into the fused merge only when nothing can
        # alias their buffer across merges: the host path keeps params0
        # snapshots in pending plans, and personalized clients keep
        # _personal / personal_snapshot refs to received globals.  The
        # engine loops read this flag and defensively copy the CALLER's
        # initial globals once per run (donation would otherwise delete
        # the caller's buffers at the first merge).
        self.donates_globals = self.use_arena and not any(
            c.personal_keys for c in clients)
        self.cohort_step, self.merge_cohort = cached_cohort_step(
            c0.loss_fn, c0.dp_cfg, c0.opt, use_dp=c0.use_dp,
            use_kernel=c0.use_kernel, client_axis=cfg.client_axis,
            client_shardings=client_shardings, fl_cfg=cfg.fl_cfg,
            arena=self.use_arena, donate_globals=self.donates_globals)
        # data-axis product: arena cohorts pad to a multiple of it so the
        # compiled leading dim always partitions on the mesh (resolved
        # from cfg.mesh when set, else from the CohortSharding's mesh; a
        # custom callable rule without cfg.mesh cannot be introspected,
        # so such cohorts keep their natural size)
        self._n_data = 1
        mesh = cfg.mesh
        if mesh is None and isinstance(client_shardings, CohortSharding):
            mesh = client_shardings.mesh
        if self.use_arena and mesh is not None:
            from repro.launch.mesh import num_client_groups
            self._n_data = num_client_groups(mesh)
        self._arena_params = None
        self._arena_opt = None
        self._writeq = []
        self.cohorts_run = 0
        self.h2d_bytes_total = 0
        if self.use_arena:
            self._build_data_arena()

    # -- device-resident arenas -------------------------------------------
    def _build_data_arena(self):
        """Upload every client's dataset once: pytree leaves
        (A, n_max, ...) with slot = cid, short datasets zero-padded (the
        pad rows are never indexed by a real batch plan), plus spare
        slots so A is a multiple of the data-axis product (the arena
        itself then shards under the shape-aware rule)."""
        clients = self.clients
        n = len(clients)
        self.pad_slot = n                       # gathered by padded members
        slots = n + 1
        if self._n_data > 1:
            slots = -(-slots // self._n_data) * self._n_data
        self.arena_slots = slots
        n_max = max(c.n_train for c in clients)
        cs = self.client_shardings
        put = ((lambda a: jax.device_put(a, cs(a))) if callable(cs)
               else jnp.asarray)
        arena = {}
        for k, v0 in clients[0].data.items():
            buf = np.zeros((slots, n_max) + v0.shape[1:], v0.dtype)
            for c in clients:
                buf[c.cid, : c.data[k].shape[0]] = c.data[k]
            arena[k] = put(buf)
        self._arena_data = arena

    def _ensure_state_arenas(self, params):
        """Lazy-init the params/opt arenas from the first dispatched
        globals (shapes only — every slot is overwritten at dispatch
        before the compiled step reads it).  The compiled helpers come
        from the cross-runner cache in
        :func:`repro.engine.cohort_step.cached_arena_helpers` (dropped by
        ``invalidate_step_cache`` together with the step entries)."""
        if self._arena_params is not None:
            return
        init, self._write, self._gather = cached_arena_helpers(
            self.arena_slots, self.clients[0].opt, self.client_shardings)
        self._arena_params, self._arena_opt = init(params)

    def _queue_write(self, slot: int, params_tree):
        """Record 'slot trains from this params tree'; the device scatter
        is deferred so consecutive dispatches sharing one globals object
        (a whole FedAvg round, every post-merge re-dispatch) collapse
        into ONE compiled broadcast-write."""
        self._ensure_state_arenas(params_tree)
        self._writeq.append((slot, params_tree))

    def _flush_writes(self):
        q, self._writeq = self._writeq, []
        i = 0
        while i < len(q):
            tree = q[i][1]
            slots = [q[i][0]]
            j = i + 1
            while j < len(q) and q[j][1] is tree:
                slots.append(q[j][0])
                j += 1
            self._arena_params = self._write(
                self._arena_params, tree, jnp.asarray(slots, jnp.int32))
            i = j

    def stats(self) -> dict:
        """Data-path counters for RunLog.engine_stats / the benchmarks."""
        return {
            "data_path": "arena" if self.use_arena else "host",
            "cohorts": self.cohorts_run,
            "h2d_bytes_total": int(self.h2d_bytes_total),
            "h2d_bytes_per_cohort": (
                self.h2d_bytes_total / self.cohorts_run
                if self.cohorts_run else 0.0),
        }

    # -- dispatch ----------------------------------------------------------
    def dispatch(self, c, global_params, key, server_version: int
                 ) -> LocalRoundPlan:
        """Plan one local round: same host bookkeeping (RNG permutations,
        accountant step, clock draw, version pull) as Client.local_train,
        deferring the actual training to the compiled cohort step."""
        params0 = global_params
        personal_snapshot = None
        if c.personal_keys:
            if c._personal is None:  # first round: adopt global init
                c._personal = {k: global_params[k] for k in c.personal_keys}
            params0 = dict(global_params)
            params0.update(c._personal)
            personal_snapshot = {k: global_params[k] for k in c.personal_keys}
        if self.use_arena:
            # arena path: the dispatch-time params snapshot is a deferred
            # device-side slot write; optimizer state already lives in the
            # arena (initialized for every slot at first dispatch)
            self._queue_write(c.cid, params0)
        elif c.opt_state is None:
            c.opt_state = c.opt.init(params0)
        idx = plan_batches(c.rng, c.n_train, c.batch_size, c.local_epochs)
        steps = int(idx.shape[0])
        if c.use_dp and steps > 0:
            c.accountant.step(c.q, c.dp_cfg.noise_multiplier, steps)
        duration = c.clock.round_duration()
        c.update_count += 1
        c.model_version = server_version
        plan = LocalRoundPlan(
            cid=c.cid,
            params0=None if self.use_arena else params0,
            opt_state=None if self.use_arena else c.opt_state,
            batch_idx=idx, key=key, n_steps=steps, duration=duration,
            epsilon=c.accountant.epsilon(self.cfg.delta) if c.use_dp else 0.0,
            model_version=server_version)
        plan.personal_snapshot = personal_snapshot
        return plan

    # -- compiled local phase ---------------------------------------------
    def _pad_idx(self, idx, batch_size: int):
        """Pad one member's (S, B) batch plan to (s_max, B) with masked
        tail rows (repeat the first row; all-zeros when S == 0)."""
        if idx.shape[0] >= self.s_max:
            return idx
        pad_row = idx[:1] if idx.shape[0] else np.zeros(
            (1, batch_size), np.int32)
        return np.concatenate(
            [idx, np.broadcast_to(
                pad_row, (self.s_max - idx.shape[0],) + pad_row.shape[1:])])

    def run_cohort(self, plans):
        """Run every member's local round in one compiled call; returns the
        stacked new params (leading dim K, or the padded bucket size on
        the arena path) and persists the members' new optimizer states
        (arena scatter, or per-client write-back on the host path)."""
        if self.use_arena:
            return self._run_cohort_arena(plans)
        s_max = self.s_max
        if s_max == 0:  # degenerate: no client has a full batch
            return stack_trees([p.params0 for p in plans])
        stacked_params = stack_trees([p.params0 for p in plans])
        stacked_opt = stack_trees([p.opt_state for p in plans])
        member_batches = []
        for p in plans:
            c = self.clients[p.cid]
            idx = self._pad_idx(p.batch_idx, c.batch_size)
            member_batches.append({k: v[idx] for k, v in c.data.items()})
        batches_np = {
            k: np.stack([mb[k] for mb in member_batches])
            for k in member_batches[0]
        }
        self.cohorts_run += 1
        self.h2d_bytes_total += (
            sum(a.nbytes for a in batches_np.values()) + 4 * len(plans))
        batches = {k: jnp.asarray(v) for k, v in batches_np.items()}
        keys = jnp.stack([p.key for p in plans])
        n_steps = jnp.asarray([p.n_steps for p in plans], jnp.int32)
        new_stacked, new_opt = self.cohort_step(
            stacked_params, stacked_opt, batches, keys, n_steps)
        for i, p in enumerate(plans):
            self.clients[p.cid].opt_state = unstack_tree(new_opt, i)
        return new_stacked

    def _run_cohort_arena(self, plans):
        """Arena data path: flush the queued dispatch writes, then run the
        cohort as ONE compiled gather->train->scatter whose only H2D
        inputs are int32 index plans (slots, batch_idx, n_steps)."""
        self._flush_writes()
        k = len(plans)
        k_pad = (padded_cohort_size(k, self._n_data, self.cfg.pow2_cohorts)
                 if self._n_data > 1 else k)
        slots = np.full((k_pad,), self.pad_slot, np.int32)
        slots[:k] = [p.cid for p in plans]
        slots_j = jnp.asarray(slots)
        if self.s_max == 0:  # degenerate: no client has a full batch
            return self._gather(self._arena_params, slots_j)
        batch_size = self.clients[0].batch_size
        batch_idx = np.zeros((k_pad, self.s_max, batch_size), np.int32)
        for i, p in enumerate(plans):
            batch_idx[i] = self._pad_idx(p.batch_idx, batch_size)
        n_steps = np.zeros((k_pad,), np.int32)
        n_steps[:k] = [p.n_steps for p in plans]
        keys = jnp.stack(
            [p.key for p in plans]
            + [jnp.zeros_like(plans[0].key)] * (k_pad - k))
        self.cohorts_run += 1
        self.h2d_bytes_total += batch_idx.nbytes + slots.nbytes + n_steps.nbytes
        new_stacked, self._arena_opt = self.cohort_step(
            self._arena_params, self._arena_opt, self._arena_data,
            slots_j, jnp.asarray(batch_idx), keys, jnp.asarray(n_steps))
        return new_stacked

    # -- upload ------------------------------------------------------------
    def upload(self, plan: LocalRoundPlan, new_params):
        """Turn a member's trained params into its uploaded model (personal
        subtrees stay on-device; the upload carries the received globals
        for those keys, exactly like Client.local_train)."""
        c = self.clients[plan.cid]
        if not c.personal_keys:
            return new_params
        c._personal = {k: new_params[k] for k in c.personal_keys}
        up = dict(new_params)
        up.update(plan.personal_snapshot)
        return up


def _pad_coeffs(coeffs, stacked):
    """Zero-extend the cohort's merge coefficients to the compiled stack's
    (possibly padded) leading dim — pad members contribute exactly 0."""
    k_pad = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    out = np.zeros((k_pad,), np.float64)
    out[: len(coeffs)] = coeffs
    return jnp.asarray(out)


def _fused_ok(strategy, clients, plans, cfg: EngineConfig) -> bool:
    """The weights-vector merge is exact only for plain FedAsync (Eq. 11
    folding) and FedAvg; FedBuff keeps cross-cohort buffer state and
    AdaptiveAsync mixes in the privacy budget, so they go per-member
    through aggregation.apply_update (as do personalized clients)."""
    if not cfg.fused_merge:
        return False
    if type(strategy) not in (FedAvg, FedAsync):
        return False
    return not any(clients[p.cid].personal_keys for p in plans)


def run_fedavg_engine(
    clients: list,
    global_params,
    accuracy_fn: Callable,
    test_data: dict,
    rounds: int = 60,
    seed: int = 0,
    eval_every: int = 1,
    target_acc: Optional[float] = None,
    engine_cfg: Optional[EngineConfig] = None,
    mesh=None,
) -> tuple:
    """Synchronous FedAvg (Eq. 9): each round is one full-population
    barrier, executed as ceil(N / max_cohort) compiled cohort chunks whose
    dataset-size-weighted partial sums accumulate into the new globals.
    ``mesh`` partitions the cohort axis (see CohortRunner)."""
    cfg = _resolve_mesh_cfg(engine_cfg or EngineConfig(), mesh)
    runner = CohortRunner(clients, cfg)
    log = RunLog(strategy="fedavg")
    key = jax.random.PRNGKey(seed)
    t_virtual = 0.0
    for c in clients:
        log.update_counts[c.tier] = 0
        log.staleness.setdefault(c.tier, [])
        log.eps_trajectory.setdefault(c.tier, [])

    for rnd in range(1, rounds + 1):
        plans = []
        for c in clients:
            key, sub = jax.random.split(key)
            plans.append(runner.dispatch(c, global_params, sub, rnd - 1))
        chunks = [plans[i:i + cfg.max_cohort]
                  for i in range(0, len(plans), cfg.max_cohort)]
        stacked_chunks = [runner.run_cohort(ch) for ch in chunks]
        log.cohort_sizes.extend(len(ch) for ch in chunks)
        t_virtual += max(p.duration for p in plans)

        if _fused_ok(FedAvg(), clients, plans, cfg):
            # Eq. 9 as chunked weights-vector reductions: the new globals
            # accumulate sum_k (n_k / sum n) p_k across the chunks.
            # (`merged`, not `acc`: the eval scalar below is `acc` — the
            # accumulator pytree must never share its name)
            _, coeffs = fedavg_weights([clients[p.cid].n_train for p in plans])
            merged = jax.tree_util.tree_map(jnp.zeros_like, global_params)
            off = 0
            for ch, st in zip(chunks, stacked_chunks):
                merged = runner.merge_cohort(
                    merged, st, _pad_coeffs(coeffs[off:off + len(ch)], st),
                    1.0)
                off += len(ch)
            global_params = merged
        else:
            updates = []
            for ch, st in zip(chunks, stacked_chunks):
                updates.extend(
                    (runner.upload(p, unstack_tree(st, i)),
                     clients[p.cid].n_train)
                    for i, p in enumerate(ch))
            global_params = FedAvg().aggregate(global_params, updates)

        for p in plans:
            c = clients[p.cid]
            log.update_counts[c.tier] += 1
            log.staleness[c.tier].append(0)  # barrier => no staleness
            log.eps_trajectory[c.tier].append(p.epsilon)

        if rnd % eval_every == 0 or rnd == rounds:
            acc = float(accuracy_fn(global_params, test_data))
            log.times.append(t_virtual)
            log.global_acc.append(acc)
            log.server_version.append(rnd)
            eval_all(clients, global_params, accuracy_fn, log)
            if target_acc is not None and acc >= target_acc:
                break

    for c in clients:
        log.resources[c.tier] = c.clock.resource_sample()
        log.dropouts[c.tier] = c.clock.dropouts
    log.engine_stats = runner.stats()
    return global_params, log


def run_async_engine(
    clients: list,
    global_params,
    accuracy_fn: Callable,
    test_data: dict,
    strategy,                      # FedAsync / FedBuff / AdaptiveAsync
    max_updates: int = 300,
    max_time: Optional[float] = None,
    seed: int = 0,
    eval_every: int = 5,
    target_acc: Optional[float] = None,
    engine_cfg: Optional[EngineConfig] = None,
    mesh=None,
) -> tuple:
    """Event-driven async FL (Eq. 10-11) over cohorts popped from the
    virtual-clock heap.  ``staleness_window=0`` reproduces the legacy loop
    update-for-update; a positive window batches near-simultaneous
    completions into one compiled step.  ``mesh`` partitions the cohort
    axis (see CohortRunner)."""
    cfg = _resolve_mesh_cfg(engine_cfg or EngineConfig(), mesh)
    runner = CohortRunner(clients, cfg)
    if runner.donates_globals:
        # the fused merge donates its globals argument; copy ONCE so the
        # first merge consumes our copy, not the caller's buffers (which
        # the caller may still read — e.g. a baseline eval or a second
        # run from the same initial params)
        global_params = jax.tree_util.tree_map(jnp.copy, global_params)
    log = RunLog(strategy=strategy.name)
    key = jax.random.PRNGKey(seed)
    for c in clients:
        log.update_counts[c.tier] = 0
        log.influence.setdefault(c.tier, 0.0)
        log.staleness.setdefault(c.tier, [])
        log.eps_trajectory.setdefault(c.tier, [])

    # Seed the event queue: every client starts training version 0 at t=0.
    heap, pending = [], {}
    server_version = 0
    for c in clients:
        key, sub = jax.random.split(key)
        plan = runner.dispatch(c, global_params, sub, server_version)
        pending[c.cid] = plan
        heapq.heappush(heap, (plan.duration, c.cid))

    t_virtual = 0.0
    done = False
    while heap and not done:
        events = pop_cohort(heap, cfg.staleness_window, cfg.max_cohort,
                            bucket_pow2=cfg.pow2_cohorts)
        plans = []
        for t, cid in events:
            p = pending.pop(cid)
            p.t_complete = t
            plans.append(p)
        t_virtual = plans[-1].t_complete
        new_stacked = runner.run_cohort(plans)
        log.cohort_sizes.append(len(plans))

        if _fused_ok(strategy, clients, plans, cfg):
            # staleness weights alpha/(1+tau_i), folded so the single
            # weights-vector reduction equals the sequential merges; member
            # i's tau accounts for the i earlier merges in this cohort
            taus = [(server_version + i) - p.model_version
                    for i, p in enumerate(plans)]
            weights = [strategy.mixing_weight(tau) for tau in taus]
            g_coeff, coeffs = fold_cohort_weights(weights)
            global_params = runner.merge_cohort(
                global_params, new_stacked, _pad_coeffs(coeffs, new_stacked),
                g_coeff)
            server_version += len(plans)
        else:
            taus, weights = [], []
            for i, p in enumerate(plans):
                up = runner.upload(p, unstack_tree(new_stacked, i))
                tau = server_version - p.model_version
                global_params, inc, w = apply_update(
                    strategy, global_params, up, tau, eps_spent=p.epsilon)
                server_version += inc
                taus.append(tau)
                weights.append(w)

        for p, tau, w in zip(plans, taus, weights):
            c = clients[p.cid]
            log.staleness[c.tier].append(tau)
            log.update_counts[c.tier] += 1
            log.eps_trajectory[c.tier].append(p.epsilon)
            log.influence[c.tier] += float(w)

        total_updates = sum(log.update_counts.values())
        crossed = any((total_updates - j) % eval_every == 0
                      for j in range(len(plans)))
        if crossed:
            acc = float(accuracy_fn(global_params, test_data))
            log.times.append(t_virtual)
            log.global_acc.append(acc)
            log.server_version.append(server_version)
            eval_all(clients, global_params, accuracy_fn, log)
            if target_acc is not None and acc >= target_acc:
                done = True
        if total_updates >= max_updates or (max_time and t_virtual >= max_time):
            done = True

        if not done:
            for p in plans:
                c = clients[p.cid]
                # joint aggregation-privacy adaptation: a client that has
                # exhausted its budget STOPS training (see legacy loop)
                if (isinstance(strategy, AdaptiveAsync)
                        and p.epsilon >= strategy.eps_target):
                    continue
                key, sub = jax.random.split(key)
                plan = runner.dispatch(c, global_params, sub, server_version)
                pending[c.cid] = plan
                heapq.heappush(heap, (p.t_complete + plan.duration, c.cid))

    for c in clients:
        log.resources[c.tier] = c.clock.resource_sample()
        log.dropouts[c.tier] = c.clock.dropouts
    log.engine_stats = runner.stats()
    return global_params, log
