"""Cohort-batched async execution engine (event-queue driver).

Drives the compiled cohort step (``repro.engine.cohort_step``) from the
virtual-clock priority queue that the legacy per-client loop in
``repro.core.server`` uses:

  1. **dispatch**: a client pulls the current globals, its minibatch
     schedule / PRNG chain / tier-clock duration / accountant step are
     planned on the host (``LocalRoundPlan``) and its completion event is
     pushed on the heap — exactly the bookkeeping ``Client.local_train``
     does, but WITHOUT running the training yet;
  2. **cohort pop**: all completions within ``staleness_window`` virtual
     seconds of the earliest pending event come off the heap as one cohort;
  3. **compiled local phase**: the cohort runs as ONE jitted program.
     On the default device-resident data path the members' dispatch-time
     params / optimizer states are GATHERED inside the program from a
     per-client arena (dispatch wrote the pulled globals into the
     member's slot), minibatches are gathered from the once-uploaded
     per-client datasets by a (K, S_max, B) int32 index plan — the only
     per-cohort H2D traffic — and cohorts pad to bucket sizes that always
     partition on a mesh (pad members are zero-step masked and merge with
     coefficient 0);
  4. **merge**: FedAvg/FedAsync weights (n_k / sum n, alpha/(1+tau_i))
     are folded into a single weights-vector reduction over the client
     axis (``fold_cohort_weights`` makes the fused merge exactly equal to
     the legacy sequential merges); FedBuff / AdaptiveAsync / personalized
     clients route per-member through ``aggregation.apply_update`` — the
     same switch the legacy loop uses;
  5. **bookkeeping**: staleness, per-tier update counts, epsilon
     trajectories and influence land in the same ``RunLog`` the legacy
     loops produce, so every benchmark/figure works unchanged.

With ``staleness_window=0`` cohorts have size 1 and the engine reproduces
the legacy event loop update-for-update (the tier-1 parity tests assert
params allclose and identical update-count/epsilon bookkeeping).  A
positive window trades a bounded amount of merge reordering for wide
cohorts and is where the throughput win comes from (see
``benchmarks/fl_benchmarks.py::bench_engine_throughput``).

Pipelined scheduling (``EngineConfig.pipeline_depth``):

  Every quantity a cohort needs is deterministic at dispatch time (the
  virtual clock, the minibatch permutations and the PRNG chain are host
  state), so the host can assemble cohort *t+1* while cohort *t* still
  executes on device.  What breaks that overlap on the serial driver is
  buffer donation: a donated-input dispatch blocks the host until the
  computation finishes (measured: a donation-chained loop on jax CPU
  runs fully synchronously), and the PR-3 data path donates the opt
  arena, the params-arena writes and the merged globals — every cohort
  is a full host<->device sync.  With ``pipeline_depth >= 2`` the runner
  builds donation-free programs and the loops split into submit/drain:

      host   │ plan t   plan t+1   plan t+2        drain/eval
             │ stage t  stage t+1  stage t+2  ...  (the ONLY host
             │ submit t submit t+1 submit t+2       blocks)
      ───────┼────────────────────────────────────────────────────
      device │          step t ──► step t+1 ──► step t+2
             │           merge t ──► merge t+1 ──► ...

  *plan* (pop_cohort, batch plans, memoized accountant, clock/heap) and
  *stage* (the few-KB int32/key uploads via async device_put) run ahead
  of the device; *submit* enqueues the compiled step + merge without
  waiting.  At most ``pipeline_depth`` cohorts are in flight — beyond
  that the loop drains the OLDEST cohort's outputs (backpressure, no
  device->host transfer).  The host genuinely blocks only at eval
  boundaries, ``target_acc`` checks and end of run; ``RunLog`` is
  bit-identical to the serial path because every bookkeeping scalar
  (merge weight, staleness tau, epsilon, influence increment) is packed
  per cohort from host-deterministic plan state, never fetched from
  device.  ``RunLog.engine_stats`` reports the sync counters
  (``host_syncs_between_evals`` is 0 on the pipelined path;
  ``blocking_submits`` counts the serial path's donation syncs).
"""
from __future__ import annotations

import functools
import heapq
from collections import deque
from dataclasses import dataclass, replace
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import accountant as _accountant
from repro.core.aggregation import (
    AdaptiveAsync, FedAsync, FedAvg, FedBuff, apply_update)
from repro.core.faults import FaultInjector, apply_deadline, zero_fault_stats
from repro.core.runlog import RunLog, eval_all, validate_engine_stats
from repro.core.screening import ScreeningState, zero_screen_stats
from repro.engine.cohort import (
    LocalRoundPlan, fedavg_weights, fold_cohort_weights, padded_cohort_size,
    plan_batches, pop_cohort, steps_per_round)
from repro.engine.cohort_step import (
    cached_arena_helpers, cached_cohort_step, stack_trees, unstack_tree,
    validate_client_axis)
from repro.engine.mesh_backend import CohortSharding
from repro.engine.statestore import (
    DataArena, StoreConfig, TieredStateStore, zero_store_stats)


@dataclass(frozen=True)
class EngineConfig:
    staleness_window: float = 0.0  # virtual seconds of completions per cohort
    max_cohort: int = 2            # cap on POPPED cohort size ("unroll" compile
                                   # time scales with it; see cohort_step) — on
                                   # a mesh the arena path pads the compiled
                                   # leading dim up to the next bucket that
                                   # divides the data-axis product
    fused_merge: bool = True       # fold FedAvg/FedAsync into the weights vector
    delta: float = 1e-5            # accountant delta (matches legacy loop)
    client_axis: str = "unroll"    # unroll (single CPU) | map | vmap (mesh,
                                   # sim math) | fl_step (mesh, production
                                   # per-microbatch-DP round) — see cohort_step
    pow2_cohorts: bool = True      # bucket cohort sizes to bound recompiles
    mesh: Optional[object] = None  # jax Mesh: partition the cohort axis over
                                   # its data axes (engine.mesh_backend builds
                                   # the CohortSharding); None = replicated
    fl_cfg: Optional[object] = None  # FLStepConfig for client_axis="fl_step"
    device_arena: bool = True      # device-resident data path: client
                                   # params/opt state live in a stacked arena,
                                   # datasets upload once, cohorts assemble as
                                   # a compiled gather fed by index plans only
                                   # (False = PR-2 host-fed baseline)
    pipeline_depth: int = 1        # cohorts in flight: 1 = the serial driver
                                   # (donation-chained, each submit blocks);
                                   # >= 2 = pipelined submit/drain — host
                                   # planning/staging overlaps device compute,
                                   # donation off so dispatch is async (see
                                   # module docstring pipeline diagram)
    store: StoreConfig = StoreConfig()  # tiered client-state store (see
                                   # repro.engine.statestore / STORE.md):
                                   # hot_slots=None keeps the all-resident
                                   # arena; a positive hot_slots bounds the
                                   # device arena to that many client rows
                                   # backed by a host cold store with
                                   # event-heap lookahead prefetch

    def __post_init__(self):
        validate_client_axis(self.client_axis)
        if int(self.pipeline_depth) < 1 or self.pipeline_depth != int(
                self.pipeline_depth):
            raise ValueError(
                f"pipeline_depth must be an integer >= 1: "
                f"{self.pipeline_depth!r}")
        if self.store.hot_slots is not None:
            if not self.device_arena:
                raise ValueError(
                    "StoreConfig.hot_slots requires device_arena=True — "
                    "the host data path has no device arena to bound")
            if self.store.hot_slots < self.max_cohort:
                raise ValueError(
                    f"StoreConfig.hot_slots={self.store.hot_slots} must be "
                    f">= max_cohort={self.max_cohort}: a staged cohort pins "
                    "one hot slot per member, so a smaller hot set "
                    "deadlocks slot acquisition")


def _resolve_mesh_cfg(cfg: EngineConfig, mesh) -> EngineConfig:
    """Fold a frontend-supplied mesh into the engine config (an explicit
    EngineConfig.mesh wins over the run_experiment/run_* keyword)."""
    if mesh is not None and cfg.mesh is None:
        cfg = replace(cfg, mesh=mesh)
    return cfg


def _host_fetch(runner, value) -> float:
    """The funnel for the engine loops' direct device->host scalar reads
    (the global-accuracy eval; ``eval_all``'s per-client fetches happen
    inside the same eval boundary but route through the shared
    ``Client.evaluate``).  Fetches — and the serial driver's
    donation-blocked submits, counted at the submit site — feed the
    runner's sync counters, and the pipelined-path acceptance criterion
    is that the between-evals count stays ZERO (the sync-count parity
    test monkeypatches this function to prove the fetch side)."""
    out = float(value)
    runner.note_host_sync()
    return out


def _host_fetch_array(runner, value):
    """The :func:`_host_fetch` sibling for the engine's ARRAY reads —
    today that is exactly one site, the per-cohort screen-verdict fetch
    (``CohortRunner.fetch_screen``).  Routing the ``device_get`` through
    this funnel keeps the sync accounting honest: the runner buckets the
    fetch into ``screen_verdict_syncs`` (the screening path's sanctioned
    blocking point), so ``host_syncs_between_evals`` stays 0 on the
    pipelined path whether screening is on or off."""
    out = jax.device_get(value)
    runner.note_host_sync()
    return out


@functools.lru_cache(maxsize=None)
def _key_chain_fn(n: int):
    def chain(key):
        def body(k, _):
            ks = jax.random.split(k)
            return ks[0], ks[1]
        return jax.lax.scan(body, key, None, length=n)
    return jax.jit(chain)


def split_key_chain(key, n: int):
    """``n`` sequential ``jax.random.split`` draws as ONE compiled scan.

    Bitwise identical to the Python loop ``key, sub = jax.random.split
    (key)`` repeated ``n`` times (the scan body IS that loop body), but
    O(1) dispatches instead of O(N) — the startup schedule at N=100k was
    dominated by per-client split dispatch overhead.  Returns ``(key,
    subs)`` with ``subs`` a host-side (n, 2) uint32 array so handing
    ``subs[i]`` to each plan costs no per-row device slicing."""
    key, subs = _key_chain_fn(n)(key)
    return key, np.asarray(subs)


@dataclass
class StagedCohort:
    """One cohort's device-ready inputs, assembled (and uploaded) ahead
    of submission: on the arena path a few KB of int32 index plans plus
    the stacked PRNG keys; on the host path the stacked state/batch
    tensors.  Staging cohort t+1 while cohort t executes is the
    'dispatch queue' of the pipelined scheduler — the H2D device_puts
    are async, so building one of these never waits on the device."""

    plans: list
    k: int
    degenerate: bool = False       # s_max == 0: no client has a full batch
    arena: bool = True
    slots: Optional[object] = None       # (K_pad,) int32 on device
    data_slots: Optional[object] = None  # (K_pad,) int32 dataset-arena rows
                                         # (the very `slots` object on the
                                         # all-resident identity layout)
    batch_idx: Optional[object] = None   # (K_pad, S_max, B) int32 on device
    keys: Optional[object] = None        # (K_pad, 2) uint32 on device
    n_steps: Optional[object] = None     # (K_pad,) int32 on device
    corrupt: Optional[object] = None     # (K_pad,) float32 transit-corruption
                                         # scales (1.0 = clean, incl. pads)
    stacked_params: Optional[object] = None  # host path only
    stacked_opt: Optional[object] = None
    batches: Optional[object] = None


class CohortRunner:
    """Owns the compiled cohort program and the host-side plan/IO glue.

    When ``cfg.mesh`` is set (or ``client_shardings`` is passed
    explicitly), the compiled step constrains every stacked input's
    leading cohort dim onto the mesh's data axes — the members of a
    full-size cohort then genuinely run on different devices (see
    :mod:`repro.engine.mesh_backend`).

    With ``cfg.device_arena`` (the default) the per-cohort hot path is
    device-resident end to end: every client's params and optimizer state
    live in one stacked arena (slot per client, sharded by the same
    shape-aware rule), every client's dataset uploads to device ONCE at
    construction, and a cohort is assembled inside the compiled step by a
    ``jnp.take`` over slots plus an in-step batch gather driven by the
    (K, S_max, B) int32 index plan — the only per-cohort H2D traffic.
    Cohorts additionally pad to the bucket size from
    :func:`repro.engine.cohort.padded_cohort_size`, so on a mesh the
    compiled leading dim always divides the data-axis product and the
    cohort ALWAYS partitions (pad members gather a spare slot, run zero
    masked steps and merge with coefficient zero).
    """

    def __init__(self, clients, cfg: EngineConfig,
                 client_shardings=None, data_arena=None):
        c0 = clients[0]
        for c in clients:
            if (c.dp_cfg != c0.dp_cfg or c.use_dp != c0.use_dp
                    or c.dp_path != c0.dp_path or c.opt != c0.opt
                    or c.batch_size != c0.batch_size
                    or not (c.loss_fn is c0.loss_fn
                            or c.loss_fn == c0.loss_fn)):
                raise ValueError(
                    "cohort engine requires homogeneous client training "
                    "configs (heterogeneity lives in the virtual clocks)")
        self.clients = clients
        self.cfg = cfg
        # run-level padded step count: every client's local round length is
        # fixed by (n_train, B, E), so padding all cohorts to the global max
        # keeps the compiled step's shapes constant across the whole run
        self.s_max = max(
            steps_per_round(c.n_train, c.batch_size, c.local_epochs)
            for c in clients)
        if cfg.client_axis == "fl_step" and c0.use_dp:
            # the host-side accountant (dispatch) charges the clients'
            # dp_cfg mechanism: eps depends on (q, sigma, steps) — the
            # sampling rate and step count are the same either way and
            # eps is clip-norm-independent, so the bound transfers to the
            # executed per-microbatch mechanism ONLY when the noise
            # multipliers agree and noise is actually added per step
            fl_dp = cfg.fl_cfg.dp if cfg.fl_cfg is not None else None
            if (fl_dp is None or fl_dp.granularity != "per_microbatch"
                    or fl_dp.noise_multiplier != c0.dp_cfg.noise_multiplier):
                raise ValueError(
                    "client_axis='fl_step' with DP clients requires "
                    "fl_cfg.dp to use granularity='per_microbatch' with the "
                    "same noise_multiplier as the clients' dp_cfg "
                    f"(got {fl_dp!r} vs sigma={c0.dp_cfg.noise_multiplier}) "
                    "— otherwise the reported epsilon does not describe "
                    "the executed mechanism")
        if client_shardings is None and cfg.mesh is not None:
            client_shardings = CohortSharding(cfg.mesh)
        self.client_shardings = client_shardings
        # a raw pytree of per-leaf shardings is congruent with one cohort
        # stack, not with the arenas — fall back to the host data path
        self.use_arena = bool(cfg.device_arena) and (
            client_shardings is None or callable(client_shardings))
        # tiered client-state store (repro.engine.statestore): bound the
        # device arena to cfg.store.hot_slots rows backed by a host cold
        # store.  Requires the arena path — EngineConfig.__post_init__
        # rejects hot_slots without device_arena, and a raw shardings
        # pytree silently falling back to the host path must fail loudly
        # rather than silently going all-resident
        self.tiered = self.use_arena and cfg.store.hot_slots is not None
        if cfg.store.hot_slots is not None and not self.use_arena:
            raise ValueError(
                "StoreConfig.hot_slots requires the device-arena data "
                "path, but these client_shardings force the host path "
                "(pass a callable shape-aware rule like CohortSharding)")
        # pipelined mode (pipeline_depth >= 2) submits cohorts without
        # waiting — donation must be OFF throughout the hot loop because
        # a donated-input dispatch blocks the host until the computation
        # finishes (the very sync the pipeline deletes)
        self.pipelined = cfg.pipeline_depth > 1
        # donate the globals into the fused merge only when nothing can
        # alias their buffer across merges: the host path keeps params0
        # snapshots in pending plans, and personalized clients keep
        # _personal / personal_snapshot refs to received globals.  The
        # engine loops read this flag and defensively copy the CALLER's
        # initial globals once per run (donation would otherwise delete
        # the caller's buffers at the first merge).
        self.donates_globals = (self.use_arena and not self.pipelined
                                and not self.tiered
                                and not any(
                                    c.personal_keys for c in clients))
        add_noise = bool(c0.use_dp and c0.dp_cfg.noise_multiplier > 0)
        self.dp_path = c0.dp_path if c0.use_dp else "jnp"
        # record the resolved Pallas interpret decision (backend + mode +
        # source) whenever the kernel path is in play: a silent
        # interpreted fallback on a compiled-capable backend must be
        # visible in RunLog.engine_stats and the bench rows
        from repro.kernels.common import interpret_info
        self.interpret_info = (interpret_info()
                               if self.dp_path == "pallas" else None)
        self.cohort_step, self.merge_cohort = cached_cohort_step(
            c0.loss_fn, c0.dp_cfg, c0.opt, use_dp=c0.use_dp,
            dp_path=self.dp_path, client_axis=cfg.client_axis,
            client_shardings=client_shardings, fl_cfg=cfg.fl_cfg,
            arena=self.use_arena, donate_globals=self.donates_globals,
            donate=not self.pipelined and not self.tiered,
            add_noise=add_noise)
        # the compiled step's runtime noise scale: sigma * C / B computed
        # on the HOST (float64) then rounded once to float32 — the same
        # constant the statically-folded legacy path multiplies by, so
        # sharing one program across a sigma sweep costs zero ulps
        self._noise_std = jnp.float32(
            c0.dp_cfg.noise_multiplier * c0.dp_cfg.clip_norm
            / c0.batch_size if c0.use_dp else 0.0)
        # data-axis product: arena cohorts pad to a multiple of it so the
        # compiled leading dim always partitions on the mesh (resolved
        # from cfg.mesh when set, else from the CohortSharding's mesh; a
        # custom callable rule without cfg.mesh cannot be introspected,
        # so such cohorts keep their natural size)
        self._n_data = 1
        mesh = cfg.mesh
        if mesh is None and isinstance(client_shardings, CohortSharding):
            mesh = client_shardings.mesh
        if self.use_arena and mesh is not None:
            from repro.launch.mesh import num_client_groups
            self._n_data = num_client_groups(mesh)
        self._arena_params = None
        self._arena_opt = None
        self._writeq = []
        self.cohorts_run = 0
        self.h2d_bytes_total = 0
        # host-sync accounting (RunLog.engine_stats): _host_fetch calls
        # split by whether the loop was inside an eval boundary, plus the
        # serial path's donation-blocked submits and the pipelined
        # path's backpressure drains
        self._in_eval = False
        self.host_syncs_at_eval = 0
        self.host_syncs_between_evals = 0
        self.drain_waits = 0
        self.blocking_submits = 0
        # fault oracle for the current run — set by the engine loops when
        # the spec carries a FaultModel; stats() folds its counters into
        # the ENGINE_STATS_KEYS schema (zeros on a fault-free run)
        self.fault_injector = None
        # update-screening oracle (core.screening.ScreeningState) — set by
        # the engine loops when the spec carries a ScreeningConfig; the
        # verdict fetches it forces are the pipelined path's third
        # sanctioned sync bucket (screen_verdict_syncs), so the
        # host_syncs_between_evals == 0 invariant survives screening
        self.screening = None
        self._in_screen = False
        self.screen_verdict_syncs = 0
        self._last_screen = None
        # tiered-store spills route device->host reads through the
        # _host_fetch funnel tagged _in_store (bucketed store_sync_reads)
        self._in_store = False
        # the serial driver consumes every submit's results before
        # planning the next cohort (and its donating merge/arena-write
        # helpers block dispatch anyway — see cohort_step): every
        # serial-path submit is therefore a per-cohort host sync,
        # counted at the submit site so the serial rows report a NONZERO
        # between-evals sync count that the pipelined path demonstrably
        # drops to 0
        self._submits_block = (not self.pipelined) and (
            self.use_arena or client_shardings is None)
        # epsilon-vs-round table per client (lazy; see dispatch)
        self._eps_sched = {}
        self.store = None
        if self.use_arena:
            self._adopt_data_arena(data_arena)
            if self.tiered:
                self.store = TieredStateStore(
                    cfg.store, len(clients), self)

    # -- cross-run reuse ---------------------------------------------------
    def reset_for_run(self):
        """Restore the runner to a fresh-construction state WITHOUT paying
        construction again: the once-uploaded dataset arena, the compiled
        step/merge/helper functions and the per-client epsilon schedules
        (all pure functions of the config) survive; the per-run state —
        params/opt arenas (stale trained state from the previous run),
        queued writes and the RunLog counters — is dropped.  The state
        arenas lazily re-init at the next dispatch exactly like a fresh
        runner's would.  ``repro.api.Session`` calls this between runs of
        a sweep so consecutive scenarios skip the testbed upload."""
        self._arena_params = None
        self._arena_opt = None
        self._writeq = []
        self.cohorts_run = 0
        self.h2d_bytes_total = 0
        self._in_eval = False
        self.host_syncs_at_eval = 0
        self.host_syncs_between_evals = 0
        self.drain_waits = 0
        self.blocking_submits = 0
        self.fault_injector = None
        self.screening = None
        self._in_screen = False
        self.screen_verdict_syncs = 0
        self._last_screen = None
        self._in_store = False
        if self.store is not None:
            # residency/LRU/cold state is per-run (the arenas re-init);
            # the dataset arena and compiled helpers stay warm
            self.store = TieredStateStore(
                self.cfg.store, len(self.clients), self)

    # -- host-sync accounting ---------------------------------------------
    def note_host_sync(self):
        if self._in_store:
            self.store.sync_reads += 1
        elif self._in_screen:
            self.screen_verdict_syncs += 1
        elif self._in_eval:
            self.host_syncs_at_eval += 1
        else:
            self.host_syncs_between_evals += 1

    def eval_boundary(self, inside: bool):
        """Mark the loop's eval sections: device->host fetches inside them
        are the sanctioned blocking points of the pipelined schedule."""
        self._in_eval = inside

    # -- device-resident arenas -------------------------------------------
    def _adopt_data_arena(self, data_arena):
        """Size the CLIENT-STATE arena and adopt (or build) the dataset
        arena — two separately-keyed residencies since the tiered store:

        * state arena — ``hot_slots`` rows under the tiered store (all N
          on the resident layout) plus the pad slot, rounded up to the
          data-axis product so it shards; rows churn with residency.
        * dataset arena — one row per DISTINCT dataset, uploaded once
          (:class:`repro.engine.statestore.DataArena`) and addressed by
          its own cid->row map; NEVER bounded by ``hot_slots`` and
          reusable across runners whose partition/mesh match (the
          Session passes a cached one in so sigma-only sweeps skip the
          re-upload).

        On the legacy all-resident layout both index spaces coincide
        (slot == cid == data row), recorded as
        ``_data_slots_identical`` so staging uploads ONE slot vector."""
        clients = self.clients
        n = len(clients)
        cs = self.client_shardings
        put = ((lambda a: jax.device_put(a, cs(a))) if callable(cs)
               else jnp.asarray)
        if data_arena is None:
            data_arena = DataArena.build(clients, self._n_data, put)
        self.data_arena = data_arena
        self._arena_data = data_arena.leaves
        self._data_slot_of = data_arena.slot_of_cid
        hot = self.cfg.store.hot_slots if self.tiered else n
        self.pad_slot = hot                     # gathered by padded members
        slots = hot + 1
        if self._n_data > 1:
            slots = -(-slots // self._n_data) * self._n_data
        self.arena_slots = slots
        self._data_slots_identical = (
            not self.tiered and data_arena.pad_slot == self.pad_slot
            and np.array_equal(self._data_slot_of, np.arange(n)))

    def _ensure_state_arenas(self, params):
        """Lazy-init the params/opt arenas from the first dispatched
        globals (shapes only — every slot is overwritten at dispatch
        before the compiled step reads it).  The compiled helpers come
        from the cross-runner cache in
        :func:`repro.engine.cohort_step.cached_arena_helpers` (dropped by
        ``invalidate_step_cache`` together with the step entries)."""
        if self._arena_params is not None:
            return
        (init, self._write, self._gather, self._write_rows,
         self._init_opt) = cached_arena_helpers(
            self.arena_slots, self.clients[0].opt, self.client_shardings,
            donate=not self.pipelined and not self.tiered)
        self._arena_params, self._arena_opt = init(params)

    def _queue_write(self, slot: int, params_tree):
        """Record 'slot trains from this params tree'; the device scatter
        is deferred so consecutive dispatches sharing one globals object
        (a whole FedAvg round, every post-merge re-dispatch) collapse
        into ONE compiled broadcast-write."""
        self._ensure_state_arenas(params_tree)
        self._writeq.append((slot, params_tree))

    def _cancel_writes(self, slot: int):
        """Drop queued params writes against ``slot`` — the tiered store
        calls this when it evicts the slot's occupant (the write belonged
        to the evicted cid; its replacement queues its own)."""
        self._writeq = [(s, t) for s, t in self._writeq if s != slot]

    # -- tiered-store device plumbing (see repro.engine.statestore) --------
    def spill_opt_slot(self, slot: int):
        """Fetch one hot opt row to the host for the cold store.  The
        read routes through the ``_host_fetch_array`` funnel tagged
        ``_in_store`` (counted ``store_sync_reads``), keeping the
        pipelined path's ``host_syncs_between_evals == 0`` proof honest."""
        row = self._gather(self._arena_opt, jnp.asarray([slot], jnp.int32))
        self._in_store = True
        try:
            host = _host_fetch_array(self, row)
        finally:
            self._in_store = False
        return jax.tree_util.tree_map(lambda l: l[0], host)

    def load_opt_rows(self, rows, slots):
        """Re-upload cold opt rows into freshly-assigned hot slots as ONE
        stacked scatter (async device_put under jit — the prefetcher's
        H2D overlaps device compute like every other staging upload)."""
        stacked = jax.tree_util.tree_map(
            lambda *ls: np.stack(ls), *rows)
        self.h2d_bytes_total += sum(
            l.nbytes for l in jax.tree_util.tree_leaves(stacked))
        self._arena_opt = self._write_rows(
            self._arena_opt, stacked, jnp.asarray(slots, jnp.int32))

    def init_opt_rows(self, params_tree, slots):
        """Re-initialize never-spilled slots' opt rows on device
        (``opt.init`` is value-independent — bitwise the state the
        all-resident arena holds for a not-yet-trained client)."""
        self._ensure_state_arenas(params_tree)
        self._arena_opt = self._init_opt(
            self._arena_opt, params_tree, jnp.asarray(slots, jnp.int32))

    def prefetch_upcoming(self, heap, pending):
        """Lookahead prefetch for the async loop: peek the next
        ``StoreConfig.lookahead`` completions of the virtual clock's
        event heap — O(k log N), pop k then push back, never a full
        sort — and stage their members' hot slots ahead of the cohort
        that will pop them.  ``pending`` filters ghosts (fault
        duplicates whose plan already delivered): prefetching a stale
        cid would stage stale params."""
        if self.store is None or not heap:
            return
        k = min(self.store.lookahead, len(heap))
        if k <= 0:
            return
        head = [heapq.heappop(heap) for _ in range(k)]
        for entry in head:
            heapq.heappush(heap, entry)
        self.store.prefetch_cids(
            [cid for _, cid in head if cid in pending])

    def prefetch_plans(self, plans):
        """Lookahead prefetch for the fedavg barrier: stage the NEXT
        chunk's members while the current chunk's step executes.  Only
        same-round plans may be passed — a cross-round prefetch would
        stage the previous round's globals."""
        if self.store is not None:
            self.store.prefetch_cids([p.cid for p in plans])

    def _flush_writes(self):
        q, self._writeq = self._writeq, []
        i = 0
        while i < len(q):
            tree = q[i][1]
            slots = [q[i][0]]
            j = i + 1
            while j < len(q) and q[j][1] is tree:
                slots.append(q[j][0])
                j += 1
            self._arena_params = self._write(
                self._arena_params, tree, jnp.asarray(slots, jnp.int32))
            i = j

    def stats(self) -> dict:
        """Data-path + scheduler counters for RunLog.engine_stats / the
        benchmarks.  ``host_syncs_between_evals`` is the pipelined-path
        acceptance number (0: the loop never pulls a device value to the
        host outside an eval boundary); ``blocking_submits`` counts the
        serial path's donation-chained submits (each one stalls the host
        for the cohort's full device time); ``drain_waits`` counts the
        pipelined path's backpressure waits on OLDER cohorts (overlapped,
        no device->host transfer)."""
        out = {
            "data_path": "arena" if self.use_arena else "host",
            "dp_path": self.dp_path,
            "pallas_interpret": self.interpret_info,
            "cohorts": self.cohorts_run,
            "h2d_bytes_total": int(self.h2d_bytes_total),
            "h2d_bytes_per_cohort": (
                self.h2d_bytes_total / self.cohorts_run
                if self.cohorts_run else 0.0),
            "pipeline_depth": int(self.cfg.pipeline_depth),
            "host_syncs_at_eval": self.host_syncs_at_eval,
            "host_syncs_between_evals": self.host_syncs_between_evals,
            "blocking_submits": self.blocking_submits,
            "drain_waits": self.drain_waits,
        }
        inj = self.fault_injector
        out.update(inj.stats() if inj is not None else zero_fault_stats())
        scr = zero_screen_stats()
        if self.screening is not None:
            scr.update(self.screening.counters)
        scr["screen_verdict_syncs"] = self.screen_verdict_syncs
        out.update(scr)
        st = zero_store_stats()
        if self.store is not None:
            st.update(self.store.stats())
        out.update(st)
        return out

    # -- dispatch ----------------------------------------------------------
    def dispatch(self, c, global_params, key, server_version: int
                 ) -> LocalRoundPlan:
        """Plan one local round: same host bookkeeping (RNG permutations,
        accountant step, clock draw, version pull) as Client.local_train,
        deferring the actual training to the compiled cohort step."""
        params0 = global_params
        personal_snapshot = None
        if c.personal_keys:
            if c._personal is None:  # first round: adopt global init
                c._personal = {k: global_params[k] for k in c.personal_keys}
            params0 = dict(global_params)
            params0.update(c._personal)
            personal_snapshot = {k: global_params[k] for k in c.personal_keys}
        if self.store is not None:
            # tiered path: the client may have no hot slot yet — remember
            # WHICH globals tree it pulled; the deferred write happens at
            # acquire/prefetch time against the slot it then holds
            self.store.note_dispatch(c.cid, params0)
        elif self.use_arena:
            # arena path: the dispatch-time params snapshot is a deferred
            # device-side slot write; optimizer state already lives in the
            # arena (initialized for every slot at first dispatch)
            self._queue_write(c.cid, params0)
        elif c.opt_state is None:
            c.opt_state = c.opt.init(params0)
        # the batch plan materializes LAZILY at staging (satellite of the
        # tiered-store PR: dispatch must be O(1) per client so the N-wide
        # startup/barrier schedules never do O(N) permutation work up
        # front); the step COUNT is a closed form of (n, B, E), and the
        # accountant charge needs only the count
        steps = steps_per_round(c.n_train, c.batch_size, c.local_epochs)
        if c.use_dp and steps > 0:
            c.accountant.step(c.q, c.dp_cfg.noise_multiplier, steps)
        duration = c.clock.round_duration()
        c.update_count += 1
        c.model_version = server_version
        plan = LocalRoundPlan(
            cid=c.cid,
            params0=None if self.use_arena else params0,
            opt_state=None if self.use_arena else c.opt_state,
            batch_idx=None, key=key, n_steps=steps, duration=duration,
            epsilon=self._client_epsilon(c, steps) if c.use_dp else 0.0,
            model_version=server_version)
        plan.personal_snapshot = personal_snapshot
        return plan

    def _materialize_plans(self, plans):
        """Draw the deferred minibatch permutations for the plans being
        staged (in plan order — each client's RNG advances exactly as the
        eager per-dispatch draws did, because a client's next dispatch
        can only follow the staging of its current plan)."""
        for p in plans:
            if p.batch_idx is None:
                c = self.clients[p.cid]
                p.batch_idx = plan_batches(
                    c.rng, c.n_train, c.batch_size, c.local_epochs)

    def _client_epsilon(self, c, steps: int) -> float:
        """Dispatch-time epsilon: a per-round table lookup on the shared
        :class:`repro.core.accountant.EpsilonSchedule` (bit-identical to
        ``c.accountant.epsilon`` — the schedule replays the accountant's
        exact float64 accumulation of the memoized one-step vector, so
        the per-dispatch min-over-orders recomputation leaves the host
        critical path).  With the fast path toggled off (the benchmark's
        pre-memoization baseline) fall back to the accountant itself."""
        if not _accountant.fast_accounting_enabled():
            return c.accountant.epsilon(self.cfg.delta)
        sched = self._eps_sched.get(c.cid)
        if sched is None:
            sched = _accountant.cached_epsilon_schedule(
                c.q, c.dp_cfg.noise_multiplier, steps, self.cfg.delta,
                orders=c.accountant.orders)
            self._eps_sched[c.cid] = sched
        # update_count was just incremented: the client has been charged
        # for exactly update_count rounds of `steps` DP-SGD steps
        return sched.epsilon_after_rounds(c.update_count)

    # -- compiled local phase ---------------------------------------------
    def _pad_idx(self, idx, batch_size: int):
        """Pad one member's (S, B) batch plan to (s_max, B) with masked
        tail rows (repeat the first row; all-zeros when S == 0)."""
        if idx.shape[0] >= self.s_max:
            return idx
        pad_row = idx[:1] if idx.shape[0] else np.zeros(
            (1, batch_size), np.int32)
        return np.concatenate(
            [idx, np.broadcast_to(
                pad_row, (self.s_max - idx.shape[0],) + pad_row.shape[1:])])

    def run_cohort(self, plans):
        """Run every member's local round in one compiled call; returns the
        stacked new params (leading dim K, or the padded bucket size on
        the arena path) and persists the members' new optimizer states
        (arena scatter, or per-client write-back on the host path).
        Stage + submit in one call — the serial driver's entry point; the
        pipelined loops call the two halves separately."""
        return self.submit_cohort(self.stage_cohort(plans))

    def stage_cohort(self, plans) -> StagedCohort:
        """Assemble one cohort's device inputs AHEAD of submission: flush
        the queued dispatch writes, build the host-side index plans and
        upload them (async device_put — a few KB on the arena path).
        Pure w.r.t. the compiled step: staging cohort t+1 while cohort t
        executes is safe because every input is host-deterministic plan
        state (the pipelined scheduler's lookahead relies on it)."""
        self._materialize_plans(plans)
        k = len(plans)
        if not self.use_arena:
            if self.s_max == 0:  # degenerate: no client has a full batch
                return StagedCohort(plans=plans, k=k, degenerate=True,
                                    arena=False)
            member_batches = []
            for p in plans:
                c = self.clients[p.cid]
                idx = self._pad_idx(p.batch_idx, c.batch_size)
                member_batches.append({kk: v[idx] for kk, v in c.data.items()})
            batches_np = {
                kk: np.stack([mb[kk] for mb in member_batches])
                for kk in member_batches[0]
            }
            self.cohorts_run += 1
            self.h2d_bytes_total += (
                sum(a.nbytes for a in batches_np.values()) + 4 * k + 4 * k)
            return StagedCohort(
                plans=plans, k=k, arena=False,
                stacked_params=stack_trees([p.params0 for p in plans]),
                stacked_opt=stack_trees([p.opt_state for p in plans]),
                batches={kk: jnp.asarray(v) for kk, v in batches_np.items()},
                keys=jnp.stack([p.key for p in plans]),
                n_steps=jnp.asarray([p.n_steps for p in plans], jnp.int32),
                corrupt=jnp.asarray(
                    [p.corrupt_scale for p in plans], jnp.float32))
        # slot resolution precedes the flush: the tiered store's acquire
        # queues params writes for faulted-in members, and those must ride
        # THIS cohort's flush (the all-resident path queues nothing here,
        # so the flush point is unchanged for it)
        if self.store is not None:
            member_slots = self.store.acquire_cohort([p.cid for p in plans])
        else:
            member_slots = [p.cid for p in plans]
        self._flush_writes()
        k_pad = (padded_cohort_size(k, self._n_data, self.cfg.pow2_cohorts)
                 if self._n_data > 1 else k)
        slots = np.full((k_pad,), self.pad_slot, np.int32)
        slots[:k] = member_slots
        slots_j = jnp.asarray(slots)
        data_slots_j = slots_j
        dslots = None
        if not self._data_slots_identical:
            dslots = np.full((k_pad,), self.data_arena.pad_slot, np.int32)
            dslots[:k] = self._data_slot_of[[p.cid for p in plans]]
            data_slots_j = jnp.asarray(dslots)
        if self.s_max == 0:  # degenerate: no client has a full batch
            return StagedCohort(plans=plans, k=k, degenerate=True,
                                slots=slots_j, data_slots=data_slots_j)
        batch_size = self.clients[0].batch_size
        batch_idx = np.zeros((k_pad, self.s_max, batch_size), np.int32)
        for i, p in enumerate(plans):
            batch_idx[i] = self._pad_idx(p.batch_idx, batch_size)
        n_steps = np.zeros((k_pad,), np.int32)
        n_steps[:k] = [p.n_steps for p in plans]
        keys = jnp.stack(
            [p.key for p in plans]
            + [jnp.zeros_like(plans[0].key)] * (k_pad - k))
        scales = np.ones((k_pad,), np.float32)  # pad members stay clean
        scales[:k] = [p.corrupt_scale for p in plans]
        self.cohorts_run += 1
        self.h2d_bytes_total += (batch_idx.nbytes + slots.nbytes
                                 + n_steps.nbytes + scales.nbytes
                                 + (dslots.nbytes if dslots is not None
                                    else 0))
        return StagedCohort(
            plans=plans, k=k, slots=slots_j, data_slots=data_slots_j,
            batch_idx=jnp.asarray(batch_idx), keys=keys,
            n_steps=jnp.asarray(n_steps), corrupt=jnp.asarray(scales))

    def submit_cohort(self, staged: StagedCohort):
        """Enqueue the compiled local phase for a staged cohort.  On the
        pipelined (donation-free) path this returns without waiting for
        the device; on the serial path the donated state blocks the call
        until the cohort finishes — each such submit is counted as a
        ``blocking_submits`` host sync (between evals, where the hot
        loop lives)."""
        plans = staged.plans
        if not staged.degenerate and self._submits_block:
            self.blocking_submits += 1
            self.note_host_sync()
        if not staged.arena:
            if staged.degenerate:
                self._last_screen = None
                return stack_trees([p.params0 for p in plans])
            new_stacked, new_opt, screen = self.cohort_step(
                staged.stacked_params, staged.stacked_opt, staged.batches,
                staged.keys, staged.n_steps, self._noise_std, staged.corrupt)
            for i, p in enumerate(plans):
                self.clients[p.cid].opt_state = unstack_tree(new_opt, i)
            self._last_screen = screen
            return new_stacked
        if staged.degenerate:
            self._last_screen = None
            return self._gather(self._arena_params, staged.slots)
        new_stacked, self._arena_opt, screen = self.cohort_step(
            self._arena_params, self._arena_opt, self._arena_data,
            staged.slots, staged.data_slots, staged.batch_idx, staged.keys,
            staged.n_steps, self._noise_std, staged.corrupt)
        if self.store is not None:
            # every real member's arena opt row was just scatter-updated
            # (dropped/screened members trained too — only their upload
            # was discarded), so eviction must spill before reuse
            self.store.note_trained([p.cid for p in staged.plans])
        self._last_screen = screen
        return new_stacked

    def take_screen_handle(self):
        """Return-and-clear the device handle for the LAST submitted
        cohort's screen outputs ((K_pad,) finite-mask + update norms).
        The handle is a future on the pipelined path — nothing syncs
        until :meth:`fetch_screen` pulls it."""
        screen, self._last_screen = self._last_screen, None
        return screen

    def fetch_screen(self, handle, k: int):
        """Materialize one cohort's screen verdict inputs on the host:
        ONE device->host fetch of the (finite, norm) pair, bucketed as a
        ``screen_verdict_syncs`` sanctioned sync (the pipelined clean
        path keeps ``host_syncs_between_evals == 0``).  Degenerate
        cohorts (``handle is None``) never trained, so every member is
        trivially finite with a zero-delta norm."""
        if handle is None:
            return np.ones((k,), bool), np.zeros((k,), np.float32)
        self._in_screen = True
        try:
            fin, nrm = _host_fetch_array(self, handle)
        finally:
            self._in_screen = False
        return np.asarray(fin[:k]), np.asarray(nrm[:k])

    # -- upload ------------------------------------------------------------
    def upload(self, plan: LocalRoundPlan, new_params):
        """Turn a member's trained params into its uploaded model (personal
        subtrees stay on-device; the upload carries the received globals
        for those keys, exactly like Client.local_train)."""
        c = self.clients[plan.cid]
        if not c.personal_keys:
            return new_params
        c._personal = {k: new_params[k] for k in c.personal_keys}
        up = dict(new_params)
        up.update(plan.personal_snapshot)
        return up


_MERGE_COEFF_DTYPE = np.float32  # the dtype merge_cohort reduces in


def _pad_coeffs(coeffs, stacked):
    """Zero-extend the cohort's merge coefficients to the compiled stack's
    (possibly padded) leading dim — pad members contribute exactly 0.

    Built AT the merge dtype: the float64 fold from
    ``fold_cohort_weights`` rounds to float32 on assignment (the same
    values ``jnp.asarray`` used to produce by silently downcasting a
    float64 buffer under jax's default x64-disabled config, minus the
    double-width round-trip and the 8-bytes-per-member H2D)."""
    k_pad = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    out = np.zeros((k_pad,), _MERGE_COEFF_DTYPE)
    out[: len(coeffs)] = coeffs
    out_j = jnp.asarray(out)
    assert out_j.dtype == _MERGE_COEFF_DTYPE, (
        f"merge coefficients must stay {_MERGE_COEFF_DTYPE}: {out_j.dtype}")
    return out_j


def _fused_ok(strategy, clients, plans, cfg: EngineConfig) -> bool:
    """The weights-vector merge is exact only for plain FedAsync (Eq. 11
    folding) and FedAvg; FedBuff keeps cross-cohort buffer state and
    AdaptiveAsync mixes in the privacy budget, so they go per-member
    through aggregation.apply_update (as do personalized clients)."""
    if not cfg.fused_merge:
        return False
    if type(strategy) not in (FedAvg, FedAsync):
        return False
    return not any(clients[p.cid].personal_keys for p in plans)


def run_fedavg_engine(
    clients: list,
    global_params,
    accuracy_fn: Callable,
    test_data: dict,
    rounds: int = 60,
    seed: int = 0,
    eval_every: int = 1,
    target_acc: Optional[float] = None,
    engine_cfg: Optional[EngineConfig] = None,
    mesh=None,
    runner: Optional[CohortRunner] = None,
    faults=None,
    checkpoint=None,
    resume_from: Optional[str] = None,
    strategy=None,
    screening=None,
) -> tuple:
    """Synchronous FedAvg (Eq. 9): each round is one full-population
    barrier, executed as ceil(N / max_cohort) compiled cohort chunks whose
    dataset-size-weighted partial sums accumulate into the new globals.
    ``mesh`` partitions the cohort axis (see CohortRunner).  ``runner``
    injects a prebuilt (and already reset) CohortRunner — the Session
    sweep path, which keeps the dataset arena uploaded across runs; its
    config wins over ``engine_cfg``/``mesh``.

    ``faults`` (a :class:`repro.core.faults.FaultModel`) makes updates
    lossy: members whose upload is lost stay in the compiled cohort as
    zero-weight mask slots (no recompile), the barrier honors
    ``round_deadline_s``/``min_quorum`` partial aggregation with
    survivor-renormalized Eq. 9 weights, and leave/rejoin churn stretches
    the member's round.  ``checkpoint`` (a
    :class:`repro.engine.resilience.CheckpointPolicy`) snapshots the full
    run state every ``checkpoint.every`` rounds; ``resume_from`` (a
    checkpoint directory) resumes an aborted run bit-identically.

    ``strategy`` selects the synchronous aggregator (default plain
    :class:`~repro.core.aggregation.FedAvg`); robust variants like
    ``TrimmedMeanFedAvg`` route per-member through ``aggregate`` exactly
    like the legacy loop.  ``screening`` (a
    :class:`repro.core.screening.ScreeningConfig`) screens every
    delivered upload against the compiled step's always-computed
    finite-mask/update-norm outputs: a rejected member keeps its compiled
    slot and merges with coefficient exactly 0.0 — same degradation rule
    as a lost update, same program, ``step_builds`` delta 0."""
    if runner is None:
        cfg = _resolve_mesh_cfg(engine_cfg or EngineConfig(), mesh)
        runner = CohortRunner(clients, cfg)
    else:
        cfg = runner.cfg
    if strategy is None:
        strategy = FedAvg()
    if strategy.is_async:
        raise ValueError(
            f"run_fedavg_engine requires a synchronous strategy, got "
            f"{strategy.name!r} — use run_async_engine")
    injector = (FaultInjector(faults, len(clients))
                if faults is not None else None)
    runner.fault_injector = injector
    screener = (ScreeningState(screening, len(clients))
                if screening is not None else None)
    runner.screening = screener
    log = RunLog(strategy=strategy.name)
    key = jax.random.PRNGKey(seed)
    t_virtual = 0.0
    for c in clients:
        log.update_counts[c.tier] = 0
        log.staleness.setdefault(c.tier, [])
        log.eps_trajectory.setdefault(c.tier, [])

    start_rnd = 1
    if resume_from is not None:
        from repro.engine import resilience as _rez
        global_params, key, t_virtual, rnd0 = _rez.restore_fedavg(
            resume_from, runner, clients, log, injector, global_params)
        start_rnd = rnd0 + 1
        if checkpoint is not None:
            checkpoint.mark(rnd0)

    # pipelined submit/drain across rounds: the barrier is algorithmic
    # (round r+1 trains from round r's merged globals) but not a host
    # sync — the merge output is a device future the next round's
    # dispatch writes reference, so up to cfg.pipeline_depth rounds of
    # compiled work stay in flight between eval boundaries
    inflight = deque()
    for rnd in range(start_rnd, rounds + 1):
        plans = []
        # one compiled scan for the round's whole PRNG chain (bitwise the
        # old per-client split loop; O(1) dispatches instead of O(N))
        key, subs = split_key_chain(key, len(clients))
        for c, sub in zip(clients, subs):
            p = runner.dispatch(c, global_params, sub, rnd - 1)
            if injector is not None and rnd > 1:
                # leave/rejoin churn: the member rejoins late, stretching
                # its whole barrier round (the initial round never draws)
                p.duration += injector.redispatch_delay(c.cid, t_virtual)
            plans.append(p)
        # per-plan delivery times for the screening ledger (None = the
        # upload never arrived, so there is nothing to screen)
        t_round0 = t_virtual
        t_deliver = [t_round0 + p.duration for p in plans]
        if injector is not None:
            # fates resolve BEFORE staging so a delivered member's
            # transit-corruption scale rides into the compiled step's
            # runtime corrupt vector (the draws are host-only state, so
            # the event sequence is identical to the submit-first order
            # earlier revisions used)
            fates = [injector.fedavg_fate(p.cid, t_virtual, p.duration)
                     for p in plans]
            offsets = [off for off, _ in fates]
            keep, round_time = apply_deadline(injector.model, offsets)
            for i, (p, off, kept) in enumerate(zip(plans, offsets, keep)):
                p.dropped = not kept
                t_deliver[i] = None if off is None else t_round0 + off
                if off is not None:
                    p.corrupt_scale = injector.take_corruption(p.cid)
                    if not kept:
                        injector.note_deadline_drop(p.cid, t_round0 + off)
            if any(p.dropped for p in plans):
                injector.note_degraded()
            # the barrier waits for the effective deadline when it cut
            # anyone off, else the slowest surviving delivery; a round
            # that lost EVERY update still burns the full barrier wait
            t_virtual += (round_time if round_time is not None
                          else max(p.duration for p in plans))
        else:
            t_virtual += max(p.duration for p in plans)
        chunks = [plans[i:i + cfg.max_cohort]
                  for i in range(0, len(plans), cfg.max_cohort)]
        stacked_chunks, screen_handles = [], []
        for ci, ch in enumerate(chunks):
            stacked_chunks.append(
                runner.submit_cohort(runner.stage_cohort(ch)))
            screen_handles.append(runner.take_screen_handle())
            if ci + 1 < len(chunks):
                # tiered store: stage the NEXT chunk's hot slots while
                # this chunk's compiled step executes (same-round plans
                # only — their dispatch-time globals are current)
                runner.prefetch_plans(chunks[ci + 1])
        log.cohort_sizes.extend(len(ch) for ch in chunks)
        if screener is not None:
            # judge every DELIVERED member against the compiled step's
            # finite-mask/update-norm outputs (one fetch per chunk, the
            # screen_verdict_syncs bucket); a reject keeps its compiled
            # slot and merges with coefficient exactly 0.0 below
            i0 = 0
            for ch, handle in zip(chunks, screen_handles):
                fin, nrm = runner.fetch_screen(handle, len(ch))
                for j, p in enumerate(ch):
                    if not p.dropped and not screener.screen(
                            p.cid, t_deliver[i0 + j], fin[j], nrm[j]):
                        p.dropped = True
                i0 += len(ch)

        if _fused_ok(strategy, clients, plans, cfg):
            # Eq. 9 as chunked weights-vector reductions: the new globals
            # accumulate sum_k (n_k / sum n) p_k across the chunks, the
            # sum running over SURVIVING members only (dropped members
            # keep their compiled slot with coefficient exactly 0, so a
            # degraded round re-uses the very same program).
            # (`merged`, not `acc`: the eval scalar below is `acc` — the
            # accumulator pytree must never share its name)
            if any(not p.dropped for p in plans):
                _, kept_coeffs = fedavg_weights(
                    [clients[p.cid].n_train for p in plans if not p.dropped])
                it = iter(kept_coeffs)
                coeffs = [0.0 if p.dropped else next(it) for p in plans]
                merged = jax.tree_util.tree_map(jnp.zeros_like, global_params)
                off = 0
                for ch, st in zip(chunks, stacked_chunks):
                    merged = runner.merge_cohort(
                        merged, st,
                        _pad_coeffs(coeffs[off:off + len(ch)], st), 1.0)
                    off += len(ch)
                global_params = merged
        else:
            updates = []
            for ch, st in zip(chunks, stacked_chunks):
                updates.extend(
                    (runner.upload(p, unstack_tree(st, i)),
                     clients[p.cid].n_train)
                    for i, p in enumerate(ch) if not p.dropped)
            if updates:
                global_params = strategy.aggregate(global_params, updates)

        for p in plans:
            if p.dropped:
                continue
            c = clients[p.cid]
            log.update_counts[c.tier] += 1
            log.staleness[c.tier].append(0)  # barrier => no staleness
            log.eps_trajectory[c.tier].append(p.epsilon)

        if rnd % eval_every == 0 or rnd == rounds:
            runner.eval_boundary(True)
            acc = _host_fetch(runner, accuracy_fn(global_params, test_data))
            log.times.append(t_virtual)
            log.global_acc.append(acc)
            log.server_version.append(rnd)
            eval_all(clients, global_params, accuracy_fn, log)
            runner.eval_boundary(False)
            inflight.clear()
            if target_acc is not None and acc >= target_acc:
                break
        elif runner.pipelined:
            inflight.append(jax.tree_util.tree_leaves(global_params))
            while len(inflight) > cfg.pipeline_depth:
                runner.drain_waits += 1
                jax.block_until_ready(inflight.popleft())

        if checkpoint is not None and rnd < rounds and checkpoint.due(rnd):
            from repro.engine import resilience as _rez
            _rez.save_fedavg(checkpoint, runner, clients, log, injector,
                             global_params, key, t_virtual, rnd)

    for c in clients:
        log.resources[c.tier] = c.clock.resource_sample()
        log.dropouts[c.tier] = c.clock.dropouts
    if injector is not None or screener is not None:
        ev = list(injector.events) if injector is not None else []
        if screener is not None:
            ev += list(screener.events)
        log.fault_events = ev
    log.engine_stats = validate_engine_stats(runner.stats())
    return global_params, log


def run_async_engine(
    clients: list,
    global_params,
    accuracy_fn: Callable,
    test_data: dict,
    strategy,                      # FedAsync / FedBuff / AdaptiveAsync
    max_updates: int = 300,
    max_time: Optional[float] = None,
    seed: int = 0,
    eval_every: int = 5,
    target_acc: Optional[float] = None,
    engine_cfg: Optional[EngineConfig] = None,
    mesh=None,
    runner: Optional[CohortRunner] = None,
    faults=None,
    checkpoint=None,
    resume_from: Optional[str] = None,
    screening=None,
) -> tuple:
    """Event-driven async FL (Eq. 10-11) over cohorts popped from the
    virtual-clock heap.  ``staleness_window=0`` reproduces the legacy loop
    update-for-update; a positive window batches near-simultaneous
    completions into one compiled step.  ``mesh`` partitions the cohort
    axis (see CohortRunner).  ``runner`` injects a prebuilt (and already
    reset) CohortRunner — the Session sweep path; its config wins over
    ``engine_cfg``/``mesh``.

    ``faults`` (a :class:`repro.core.faults.FaultModel`) resolves every
    popped completion event through the seeded
    :class:`~repro.core.faults.FaultInjector`: retried/late deliveries
    re-enter the heap at backoff-delayed virtual times, duplicates are
    deduped, and lost updates keep their compiled cohort slot as a
    zero-weight mask member (no recompile).  ``checkpoint`` (a
    :class:`repro.engine.resilience.CheckpointPolicy`) snapshots the run
    — server params, arenas, RNG streams, the serialized event heap —
    every ``checkpoint.every`` merged updates; ``resume_from`` resumes an
    aborted run bit-identically.

    ``screening`` (a :class:`repro.core.screening.ScreeningConfig`)
    screens every delivered upload against the compiled step's
    always-computed finite-mask/update-norm outputs — rejects (and
    quarantine drops) become zero-coefficient mask slots like lost
    updates, thresholds are host-side runtime scalars so the one
    compiled program is shared across every screening setting."""
    if runner is None:
        cfg = _resolve_mesh_cfg(engine_cfg or EngineConfig(), mesh)
        runner = CohortRunner(clients, cfg)
    else:
        cfg = runner.cfg
    if ((checkpoint is not None or resume_from is not None)
            and isinstance(strategy, FedBuff)):
        raise ValueError(
            "checkpoint/resume does not support FedBuff — its cross-cohort "
            "buffer holds live device trees the snapshot cannot capture")
    injector = (FaultInjector(faults, len(clients))
                if faults is not None else None)
    runner.fault_injector = injector
    screener = (ScreeningState(screening, len(clients))
                if screening is not None else None)
    runner.screening = screener
    if runner.donates_globals:
        # the fused merge donates its globals argument; copy ONCE so the
        # first merge consumes our copy, not the caller's buffers (which
        # the caller may still read — e.g. a baseline eval or a second
        # run from the same initial params)
        global_params = jax.tree_util.tree_map(jnp.copy, global_params)
    log = RunLog(strategy=strategy.name)
    key = jax.random.PRNGKey(seed)
    for c in clients:
        log.update_counts[c.tier] = 0
        log.influence.setdefault(c.tier, 0.0)
        log.staleness.setdefault(c.tier, [])
        log.eps_trajectory.setdefault(c.tier, [])

    # Seed the event queue: every client starts training version 0 at t=0.
    heap, pending = [], {}
    server_version = 0
    t_virtual = 0.0
    if resume_from is not None:
        from repro.engine import resilience as _rez
        global_params, key, t_virtual, server_version = _rez.restore_async(
            resume_from, runner, clients, log, injector, global_params,
            heap, pending)
        if checkpoint is not None:
            checkpoint.mark(sum(log.update_counts.values()))
    else:
        # startup schedule: one compiled scan for the N-wide PRNG chain
        # (bitwise the old per-client split loop), O(1)-per-client
        # dispatches (the batch permutations materialize lazily at
        # staging), and a single O(N) heapify instead of N pushes —
        # pop-order-identical since every (duration, cid) is distinct
        key, subs = split_key_chain(key, len(clients))
        entries = []
        for c, sub in zip(clients, subs):
            plan = runner.dispatch(c, global_params, sub, server_version)
            pending[c.cid] = plan
            entries.append((plan.duration, c.cid))
        heap.extend(entries)
        heapq.heapify(heap)
        # tiered store: warm the hot set for the first cohorts (restore
        # skips this — the snapshot already reflects it)
        runner.prefetch_upcoming(heap, pending)

    done = False
    # pipelined submit/drain: cohorts in flight are capped at
    # cfg.pipeline_depth — past that the loop blocks on the OLDEST
    # cohort's outputs (backpressure; the device keeps executing newer
    # cohorts while the host waits).  Serial runs (depth 1) never enter
    # the queue: their donation-chained submits already block per cohort.
    inflight = deque()
    while heap and not done:
        events = pop_cohort(heap, cfg.staleness_window, cfg.max_cohort,
                            bucket_pow2=cfg.pow2_cohorts)
        plans = []
        if injector is None:
            for t, cid in events:
                p = pending.pop(cid)
                p.t_complete = t
                plans.append(p)
        else:
            # every popped completion is a delivery ATTEMPT the injector
            # resolves: duplicates are deduped, retried/late uploads
            # re-enter the heap at a later virtual time (the pending plan
            # stays pending), lost updates consume their plan as a
            # zero-weight mask member (dropped=True)
            for t, cid in events:
                verdict, aux = injector.on_completion(cid, t)
                if verdict == "duplicate":
                    continue
                if verdict == "requeue":
                    heapq.heappush(heap, (aux, cid))
                    continue
                p = pending.pop(cid)
                p.t_complete = t
                if verdict == "drop":
                    p.dropped = True
                else:
                    p.corrupt_scale = injector.take_corruption(cid)
                    if aux is not None:     # deliver + a scheduled dup copy
                        heapq.heappush(heap, (aux, cid))
                plans.append(p)
            if not plans:                   # the whole pop was ghosts/retries
                continue
        t_virtual = plans[-1].t_complete
        new_stacked = runner.submit_cohort(runner.stage_cohort(plans))
        screen_handle = runner.take_screen_handle()
        log.cohort_sizes.append(len(plans))
        n_dropped = sum(1 for p in plans if p.dropped)
        if n_dropped:
            injector.note_degraded()
        if screener is not None:
            # screen every DELIVERED member at its completion time (one
            # fetch per cohort — the screen_verdict_syncs bucket); a
            # reject becomes a zero-coefficient mask slot exactly like a
            # lost update, so the merge below re-uses the same program
            fin, nrm = runner.fetch_screen(screen_handle, len(plans))
            for j, p in enumerate(plans):
                if not p.dropped and not screener.screen(
                        p.cid, p.t_complete, fin[j], nrm[j]):
                    p.dropped = True
            n_dropped = sum(1 for p in plans if p.dropped)

        if _fused_ok(strategy, clients, plans, cfg):
            # staleness weights alpha/(1+tau_i), folded so the single
            # weights-vector reduction equals the sequential merges; member
            # i's tau accounts for the i earlier DELIVERED merges in this
            # cohort (dropped members merge with weight 0 — the fold gives
            # them coefficient exactly 0 and leaves the survivors' terms
            # bit-identical to a cohort they were never part of)
            taus, weights = [], []
            n_del = 0
            for p in plans:
                if p.dropped:
                    taus.append(0)
                    weights.append(0.0)
                else:
                    tau = (server_version + n_del) - p.model_version
                    taus.append(tau)
                    weights.append(strategy.mixing_weight(tau))
                    n_del += 1
            g_coeff, coeffs = fold_cohort_weights(weights)
            global_params = runner.merge_cohort(
                global_params, new_stacked, _pad_coeffs(coeffs, new_stacked),
                g_coeff)
            server_version += n_del
        else:
            taus, weights = [], []
            for i, p in enumerate(plans):
                if p.dropped:
                    taus.append(0)
                    weights.append(0.0)
                    continue
                up = runner.upload(p, unstack_tree(new_stacked, i))
                tau = server_version - p.model_version
                global_params, inc, w = apply_update(
                    strategy, global_params, up, tau, eps_spent=p.epsilon)
                server_version += inc
                taus.append(tau)
                weights.append(w)

        for p, tau, w in zip(plans, taus, weights):
            if p.dropped:
                continue
            c = clients[p.cid]
            log.staleness[c.tier].append(tau)
            log.update_counts[c.tier] += 1
            log.eps_trajectory[c.tier].append(p.epsilon)
            log.influence[c.tier] += float(w)

        total_updates = sum(log.update_counts.values())
        crossed = any((total_updates - j) % eval_every == 0
                      for j in range(len(plans) - n_dropped))
        if crossed:
            # eval boundary — the pipelined schedule's ONLY sanctioned
            # host block between start and end of run: fetching the
            # global accuracy synchronizes every older cohort too
            runner.eval_boundary(True)
            acc = _host_fetch(runner, accuracy_fn(global_params, test_data))
            log.times.append(t_virtual)
            log.global_acc.append(acc)
            log.server_version.append(server_version)
            eval_all(clients, global_params, accuracy_fn, log)
            runner.eval_boundary(False)
            inflight.clear()
            if target_acc is not None and acc >= target_acc:
                done = True
        if total_updates >= max_updates or (max_time and t_virtual >= max_time):
            done = True

        if not done:
            for p in plans:
                c = clients[p.cid]
                # joint aggregation-privacy adaptation: a client that has
                # exhausted its budget STOPS training (see legacy loop) —
                # dropped members DO re-dispatch (their device crashed at
                # upload, the budget was still spent)
                if (isinstance(strategy, AdaptiveAsync)
                        and p.epsilon >= strategy.eps_target):
                    continue
                key, sub = jax.random.split(key)
                plan = runner.dispatch(c, global_params, sub, server_version)
                pending[c.cid] = plan
                t_next = p.t_complete + plan.duration
                if injector is not None:
                    # leave/rejoin churn delays the next local round
                    t_next += injector.redispatch_delay(c.cid, p.t_complete)
                heapq.heappush(heap, (t_next, c.cid))
            # tiered store: stage the heap head's members while the
            # submitted cohort executes (O(lookahead * log N) peek)
            runner.prefetch_upcoming(heap, pending)
            if runner.pipelined:
                inflight.append(jax.tree_util.tree_leaves(new_stacked)
                                + jax.tree_util.tree_leaves(global_params))
                while len(inflight) > cfg.pipeline_depth:
                    runner.drain_waits += 1
                    jax.block_until_ready(inflight.popleft())
            if checkpoint is not None and checkpoint.due(total_updates):
                from repro.engine import resilience as _rez
                _rez.save_async(checkpoint, runner, clients, log, injector,
                                global_params, key, heap, pending, t_virtual,
                                server_version, total_updates)

    for c in clients:
        log.resources[c.tier] = c.clock.resource_sample()
        log.dropouts[c.tier] = c.clock.dropouts
    if injector is not None or screener is not None:
        ev = list(injector.events) if injector is not None else []
        if screener is not None:
            ev += list(screener.events)
        log.fault_events = ev
    log.engine_stats = validate_engine_stats(runner.stats())
    return global_params, log
