"""Cohort formation and weight algebra for the async execution engine.

A *cohort* is the set of client completion events popped from the virtual-
clock priority queue whose completion times fall within a staleness-
tolerance window of the earliest pending event (FedBuff-style batching,
Nguyen et al.; PAPERS.md).  The whole cohort runs through ONE compiled
vmapped local-phase step instead of one Python-level step per client per
minibatch.

``fold_cohort_weights`` turns the strategy's per-member mixing weights
(e.g. FedAsync's alpha/(1+tau_i), paper Eq. 10-11) into an exactly
equivalent single linear combination

    g' = g_coeff * g + sum_i coeffs[i] * p_i

of the old globals and the cohort members' uploads, so a K-member cohort
merge is ONE fused weighted reduction over the stacked client axis yet
produces the same result as K sequential ``tree_lin`` merges.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class LocalRoundPlan:
    """Everything needed to replay one client's local round inside the
    compiled cohort step, captured at dispatch time (the client trains on
    the globals it pulled, not the globals at completion — that gap IS the
    staleness the paper measures)."""

    cid: int
    params0: object          # globals (+ personal overlay) pulled at dispatch
                             # (None on the arena path: the snapshot lives in
                             # the client's device-resident arena slot)
    opt_state: object        # client optimizer state at dispatch (None on
                             # the arena path — state never leaves the arena)
    batch_idx: np.ndarray    # (S, B) int32 minibatch indices into c.data
                             # (None until staging: dispatch defers the
                             # permutation draws — O(1) per client — and
                             # CohortRunner._materialize_plans fills it)
    key: object              # dispatch PRNG key (the legacy local_train sub)
    n_steps: int             # S actually executed (== legacy DP-SGD steps)
    duration: float          # virtual round duration from the tier clock
    epsilon: float           # accountant epsilon AFTER this round's steps
    model_version: int       # server version the client pulled from
    t_complete: float = 0.0
    personal_snapshot: Optional[dict] = None  # received globals at personal keys
    dropped: bool = False    # update lost to a fault (core.faults): the member
                             # stays in the compiled cohort as a zero-weight
                             # mask slot and is never logged as an update
    corrupt_scale: float = 1.0  # transit-corruption payload scale drawn by the
                                # FaultInjector at delivery (1.0 = clean
                                # sentinel, NaN = all-NaN payload, else delta
                                # blowup) — folded into the compiled step's
                                # (K_pad,) runtime corrupt_scale vector


def steps_per_round(n: int, batch_size: int, local_epochs: int) -> int:
    """Number of full minibatch steps one local round executes — the
    single source of truth shared by :func:`plan_batches` and the
    engine's padded step count (they must agree or cohort stacking
    produces mismatched shapes)."""
    per_epoch = ((n - batch_size) // batch_size + 1) if n >= batch_size else 0
    return local_epochs * max(0, per_epoch)


def plan_batches(rng: np.random.Generator, n: int, batch_size: int,
                 local_epochs: int) -> np.ndarray:
    """Replicate the legacy minibatch schedule exactly: per epoch, one
    permutation consumed in contiguous ``batch_size`` slices, dropping the
    ragged tail (``range(0, n - B + 1, B)``).  Returns (S, B) indices."""
    per_epoch = []
    steps = steps_per_round(n, batch_size, 1)
    for _ in range(local_epochs):
        perm = rng.permutation(n)
        if steps:
            per_epoch.append(
                perm[: steps * batch_size].reshape(steps, batch_size))
    if not per_epoch:
        return np.zeros((0, batch_size), np.int32)
    return np.concatenate(per_epoch, axis=0).astype(np.int32)


def pop_cohort(heap: list, window: float, max_size: int,
               bucket_pow2: bool = False):
    """Pop the earliest event plus every event within ``window`` virtual
    seconds of it (up to ``max_size``), in stable ``(time, cid)`` order.

    Tie-breaking is a GUARANTEE, not an accident of heap layout: events
    completing at the same virtual time come off in ascending cid, so a
    cohort's membership and member order — and therefore the pipelined
    scheduler's lookahead plans, the fold of the merge weights and every
    downstream RunLog row — are reproducible across runs and across
    ``pipeline_depth`` settings.  (Entries are ``(time, cid)`` tuples, so
    the heap already yields that order; the explicit sort pins the
    contract against any future entry shape that compares differently.)

    With ``bucket_pow2`` the cohort is truncated to the largest power of
    two <= its natural size (the tail goes back on the heap): the compiled
    cohort step then only ever sees K in {1, 2, 4, ...}, bounding XLA
    recompiles without wasting compute on padded dummy members."""
    events = [heapq.heappop(heap)]
    t0 = events[0][0]
    while heap and len(events) < max_size and heap[0][0] <= t0 + window:
        events.append(heapq.heappop(heap))
    events.sort()  # deterministic (time, cid) order even on time ties
    if bucket_pow2:
        keep = 1 << (len(events).bit_length() - 1)
        for ev in events[keep:]:
            heapq.heappush(heap, ev)
        events = events[:keep]
    return events


def padded_cohort_size(k: int, n_data: int = 1, pow2: bool = True) -> int:
    """Leading dim of the compiled step for a K-member cohort: the pow2
    bucket >= K, rounded up to a multiple of the mesh data-axis product
    ``n_data`` so the stacked cohort ALWAYS partitions under GSPMD (which
    silently replicates uneven leading-dim constraints).  Pad members are
    zero-weight masked — ``n_steps=0`` in the compiled step, coefficient 0
    in ``merge_cohort`` — so the result is bit-identical to the unpadded
    cohort while the recompile set collapses to the bucket sizes.

    ``pow2`` mirrors ``EngineConfig.pow2_cohorts``: with bucketing off the
    pad goes straight to the MINIMAL multiple of ``n_data`` — pad members
    still execute the masked local phase, so rounding 5 up through 8 to a
    12 on a 6-way axis would double the device work the user asked to
    avoid."""
    kp = (1 << max(0, k - 1).bit_length()) if pow2 else k
    if n_data > 1:
        kp = -(-kp // n_data) * n_data
    return kp


def fold_cohort_weights(ws) -> tuple:
    """Fold sequential async merges into one linear combination.

    K sequential merges g <- (1 - w_i) g + w_i p_i (paper Eq. 11) equal

        g' = prod_i (1 - w_i) * g  +  sum_i [ w_i * prod_{j>i} (1 - w_j) ] p_i

    Returns ``(g_coeff, coeffs)`` with ``coeffs`` a float64 (K,) vector.
    ``g_coeff + coeffs.sum() == 1`` (convexity) whenever all w_i in [0, 1].
    """
    ws = np.asarray(ws, dtype=np.float64)
    coeffs = np.empty_like(ws)
    rest = 1.0
    for i in range(len(ws) - 1, -1, -1):
        coeffs[i] = ws[i] * rest
        rest *= 1.0 - ws[i]
    return float(rest), coeffs


def fedavg_weights(sizes) -> tuple:
    """FedAvg (paper Eq. 9): dataset-size weights, globals fully replaced."""
    sizes = np.asarray(sizes, dtype=np.float64)
    return 0.0, sizes / sizes.sum()
