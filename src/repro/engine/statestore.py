"""Tiered client-state store: bounded hot device arena + host cold store.

The PR-3 arena stacks ALL N clients' params + optimizer state in one
device pytree — perfect for the paper's 32-client testbed, impossible
for the ROADMAP's million-client populations.  This module splits client
state into two tiers (see STORE.md for the full contract):

* **hot set** — the existing mesh-sharded device arena, now bounded to
  ``StoreConfig.hot_slots`` rows (+1 pad row).  A staged cohort gathers
  its members from hot slots exactly as before; the compiled cohort step
  is unchanged except that dataset rows are gathered through their own
  slot map (``DataArena``).
* **cold store** — host-side numpy rows, one optimizer-state tree per
  evicted client.  Params never spill: a client's dispatch-time params
  are a reference to the globals tree it pulled (``pending_params``), so
  re-residency re-stages them as the same deferred broadcast write the
  all-resident path uses — a few KB of H2D, not a device round-trip.
* **lookahead prefetcher** — the engine loops peek the virtual clock's
  event heap (O(k log N): pop k, push back) and stage upcoming members'
  slots ahead of their cohort, riding the PR-4 submit/drain overlap so a
  demand stall (``store_stall_waits``) is the exception, not the rule.

Residency policy: LRU over a monotonic touch tick, with the cohort (and
prefetch batch) being staged pinned via a keep-set; free slots assign in
ascending order.  Every decision is a pure function of the acquire /
prefetch call sequence — host-deterministic plan state — so a tiered
run's RunLog and params are bit-identical to the all-resident arena
(parity-tested), and checkpoint resume replays residency exactly
(``state_meta``/``load_state_meta`` round-trip the whole store through
:mod:`repro.engine.resilience`).

Spills route through the runner's ``_host_fetch_array`` funnel tagged
``_in_store`` (counted as ``store_sync_reads``), so a pipelined tiered
run still proves ``host_syncs_between_evals == 0``.  The counters land
in ``RunLog.engine_stats`` under :data:`repro.core.runlog.
STORE_STATS_KEYS` with the ledger law checked by ``audit_engine_stats``:
``store_fetches == store_hot_hits + store_prefetch_hits +
store_stall_waits``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import numpy as np

from repro.core.runlog import STORE_STATS_KEYS


def zero_store_stats() -> dict:
    """All-resident runs report every store counter as 0 (the schema in
    ``ENGINE_STATS_KEYS`` is unconditional, like the fault/screen keys)."""
    return {k: 0 for k in STORE_STATS_KEYS}


def _tree_nbytes(tree) -> int:
    return int(sum(l.nbytes for l in jax.tree_util.tree_leaves(tree)))


@dataclass(frozen=True)
class StoreConfig:
    """Spec-serializable knobs for the tiered client-state store.

    ``hot_slots=None`` (the default) is the all-resident arena — every
    client keeps a device slot and no store machinery runs, so existing
    specs/checkpoints decode and replay unchanged.  A positive
    ``hot_slots`` bounds the device arena to that many client rows;
    ``lookahead`` is how many upcoming event-heap completions the
    prefetcher stages ahead of their cohort (0 disables prefetch — every
    miss becomes a counted demand stall)."""

    hot_slots: Optional[int] = None
    lookahead: int = 8

    def __post_init__(self):
        if self.hot_slots is not None and (
                self.hot_slots != int(self.hot_slots) or self.hot_slots < 1):
            raise ValueError(
                f"StoreConfig.hot_slots must be None (all-resident) or an "
                f"integer >= 1: {self.hot_slots!r}")
        if self.lookahead != int(self.lookahead) or self.lookahead < 0:
            raise ValueError(
                f"StoreConfig.lookahead must be an integer >= 0: "
                f"{self.lookahead!r}")


@dataclass
class DataArena:
    """The once-uploaded device dataset arena, keyed SEPARATELY from
    client state: rows are deduped by dataset identity (``id(c.data)``)
    and addressed through ``slot_of_cid``, so (a) shared-dataset
    populations upload one row however many clients reference it — the
    100k-client scale bench fits on CPU because of exactly this — and
    (b) a :class:`repro.api.Session` sweep whose axes only touch
    client-state config (sigma, strategy, store) re-uses the arena
    across runners and skips the re-upload entirely."""

    leaves: dict              # data key -> (n_slots, n_max, ...) device array
    slot_of_cid: np.ndarray   # (N,) int32: cid -> data slot
    pad_slot: int             # row gathered by cohort pad members (zeros)
    n_slots: int              # pad_slot + 1 rounded up to the data-axis product
    n_max: int                # longest client dataset (short rows zero-pad)
    nbytes: int               # host-side bytes uploaded (bench provenance)

    @classmethod
    def build(cls, clients, n_data: int, put) -> "DataArena":
        """Upload every DISTINCT dataset once (slot = order of first
        encounter; identical to the legacy slot-per-cid layout when no
        clients share data, so all-resident arenas stay bit-identical),
        zero-pad short datasets, and round the slot count up to a
        multiple of ``n_data`` so the arena itself shards under the
        shape-aware mesh rule.  ``put`` is the runner's H2D placement
        closure (sharded ``device_put`` on a mesh, ``jnp.asarray``
        otherwise)."""
        reps = []
        rep_slot = {}
        slot_of_cid = np.empty((len(clients),), np.int32)
        for c in clients:
            s = rep_slot.get(id(c.data))
            if s is None:
                s = len(reps)
                rep_slot[id(c.data)] = s
                reps.append(c.data)
            slot_of_cid[c.cid] = s
        pad_slot = len(reps)
        n_slots = pad_slot + 1
        if n_data > 1:
            n_slots = -(-n_slots // n_data) * n_data
        n_max = max(c.n_train for c in clients)
        leaves = {}
        nbytes = 0
        for k, v0 in clients[0].data.items():
            buf = np.zeros((n_slots, n_max) + v0.shape[1:], v0.dtype)
            for s, data in enumerate(reps):
                buf[s, : data[k].shape[0]] = data[k]
            nbytes += buf.nbytes
            leaves[k] = put(buf)
        return cls(leaves=leaves, slot_of_cid=slot_of_cid, pad_slot=pad_slot,
                   n_slots=n_slots, n_max=n_max, nbytes=int(nbytes))


@dataclass
class TieredStateStore:
    """Residency manager for the bounded hot arena (see module docstring).

    The store owns the cid<->slot maps, the LRU clock, the dirty set and
    the host cold rows; the device work (slot writes, opt-row loads and
    spills) goes through the owning :class:`repro.engine.engine.
    CohortRunner`'s compiled helpers so every byte lands in the runner's
    H2D/sync accounting.  All methods are host-only bookkeeping — no
    raw device fetches (REP005/REP006-lintable) and no per-client O(N)
    loops: every loop below walks a cohort, a prefetch batch or the
    lookahead head."""

    cfg: StoreConfig
    n_clients: int
    runner: object

    def __post_init__(self):
        self.hot_slots = int(self.cfg.hot_slots)
        self.lookahead = int(self.cfg.lookahead)
        self.slot_of = {}         # cid -> hot slot (resident clients)
        self.cid_of = {}          # hot slot -> cid
        # free slots pop() in ascending order — deterministic assignment
        self.free = list(range(self.hot_slots - 1, -1, -1))
        self.seq = {}             # cid -> last-touch tick (LRU order)
        self.tick = 0
        self.dirty = set()        # resident cids whose hot opt row was trained
        self.prefetched = set()   # resident via prefetch, not yet acquired
        self.cold = {}            # cid -> host opt-row tree (numpy leaves)
        self.pending_params = {}  # cid -> dispatch-time globals tree (by ref)
        self.fetches = 0
        self.hot_hits = 0
        self.prefetch_hits = 0
        self.stall_waits = 0
        self.evictions = 0
        self.spill_bytes = 0
        self.sync_reads = 0

    # -- dispatch/train bookkeeping ---------------------------------------
    def note_dispatch(self, cid: int, params_tree):
        """The tiered twin of the all-resident path's dispatch-time slot
        write: remember WHICH params tree the client pulled (a reference,
        not a copy) so the deferred broadcast write happens at acquire /
        prefetch time, against whatever slot the client then holds."""
        self.pending_params[cid] = params_tree

    def note_trained(self, cids):
        """Mark a submitted cohort's members dirty: their hot opt rows
        now differ from any cold copy, so eviction must spill them (a
        dropped/screened member still trained — its budget was spent and
        its arena row was written — so it is dirty too)."""
        self.dirty.update(cids)

    # -- residency ---------------------------------------------------------
    def acquire_cohort(self, cids) -> list:
        """Return the hot slot for every member of the cohort being
        staged, faulting in misses (counted ``store_stall_waits``) and
        classifying hits by whether the prefetcher staged them.  The
        whole cohort is pinned while slots are grabbed — a cohort larger
        than the hot set is a config error surfaced as a deadlock."""
        keep = set(cids)
        loads, slots = [], []
        for cid in cids:
            self.tick += 1
            self.fetches += 1
            slot = self.slot_of.get(cid)
            if slot is not None:
                if cid in self.prefetched:
                    self.prefetched.discard(cid)
                    self.prefetch_hits += 1
                    # the prefetch already queued this cid's params write
                else:
                    self.hot_hits += 1
                    self.runner._queue_write(slot, self.pending_params[cid])
            else:
                self.stall_waits += 1
                slot = self._grab_slot(keep)
                self._assign(cid, slot)
                self.runner._queue_write(slot, self.pending_params[cid])
                loads.append((cid, slot))
            self.seq[cid] = self.tick
            slots.append(slot)
        self._load_slots(loads)
        return slots

    def prefetch_cids(self, cids):
        """Stage upcoming members' slots ahead of their cohort.  Callers
        pass only cids whose CURRENT dispatch is still pending (the
        engine loops filter against the pending map / the live round's
        plans) — prefetching a stale cid would write stale params.  Slot
        pressure degrades gracefully: a soft grab that finds every
        resident row pinned stops prefetching instead of deadlocking."""
        targets = [c for c in cids
                   if c not in self.slot_of and c in self.pending_params]
        if not targets:
            return
        keep = set(cids) | self.prefetched
        loads = []
        for cid in targets:
            slot = self._grab_slot(keep, soft=True)
            if slot is None:
                break
            self.tick += 1
            self._assign(cid, slot)
            self.seq[cid] = self.tick
            self.prefetched.add(cid)
            keep.add(cid)
            self.runner._queue_write(slot, self.pending_params[cid])
            loads.append((cid, slot))
        self._load_slots(loads)

    def _assign(self, cid: int, slot: int):
        self.slot_of[cid] = slot
        self.cid_of[slot] = cid

    def _grab_slot(self, keep, soft: bool = False):
        """One free (ascending) or LRU-evicted hot slot; ``keep`` pins
        the cohort/prefetch batch being staged.  The LRU victim is the
        strict minimum of the per-cid touch ticks (unique by
        construction), so eviction order is deterministic regardless of
        dict iteration details."""
        if self.free:
            return self.free.pop()
        victim, vseq = None, None
        for cid in self.slot_of:
            if cid in keep:
                continue
            sq = self.seq[cid]
            if vseq is None or sq < vseq:
                victim, vseq = cid, sq
        if victim is None:
            if soft:
                return None
            raise RuntimeError(
                f"TieredStateStore deadlock: all {self.hot_slots} hot slots "
                f"are pinned by the cohort being staged ({len(keep)} "
                "members) — raise StoreConfig.hot_slots above "
                "EngineConfig.max_cohort")
        return self._evict(victim)

    def _evict(self, cid: int) -> int:
        """Surrender ``cid``'s hot slot.  Dirty rows spill device->host
        through the runner (the ``_in_store`` sanctioned sync); clean
        rows free instantly — their cold copy (or, for never-trained
        rows, the value-independent fresh ``opt.init``) already
        reproduces them bit-for-bit."""
        slot = self.slot_of.pop(cid)
        del self.cid_of[slot]
        self.seq.pop(cid, None)
        self.prefetched.discard(cid)
        self.runner._cancel_writes(slot)
        self.evictions += 1
        if cid in self.dirty:
            self.dirty.discard(cid)
            row = self.runner.spill_opt_slot(slot)
            self.cold[cid] = row
            self.spill_bytes += _tree_nbytes(row)
        return slot

    def _load_slots(self, loads):
        """Materialize freshly-assigned slots' optimizer rows: cold rows
        re-upload as ONE stacked scatter; never-spilled rows re-init
        in-place on device (``opt.init`` is value-independent — zeros —
        so a fresh init is bitwise the state the all-resident arena
        would hold)."""
        if not loads:
            return
        cold_pairs = [(c, s) for c, s in loads if c in self.cold]
        fresh_pairs = [(c, s) for c, s in loads if c not in self.cold]
        if fresh_pairs:
            self.runner.init_opt_rows(
                self.pending_params[fresh_pairs[0][0]],
                [s for _, s in fresh_pairs])
        if cold_pairs:
            self.runner.load_opt_rows(
                [self.cold[c] for c, _ in cold_pairs],
                [s for _, s in cold_pairs])

    # -- stats / checkpoint state -----------------------------------------
    def stats(self) -> dict:
        return {
            "store_fetches": int(self.fetches),
            "store_hot_hits": int(self.hot_hits),
            "store_prefetch_hits": int(self.prefetch_hits),
            "store_stall_waits": int(self.stall_waits),
            "store_evictions": int(self.evictions),
            "store_spill_bytes": int(self.spill_bytes),
            "store_sync_reads": int(self.sync_reads),
        }

    def state_meta(self) -> dict:
        """The store's residency/LRU/counter state as a JSON-able dict
        (the cold rows and pending params trees travel separately as
        checkpoint arrays — see resilience._snapshot_common)."""
        return {
            "slot_of": {str(c): int(s) for c, s in self.slot_of.items()},
            "free": [int(s) for s in self.free],
            "seq": {str(c): int(t) for c, t in self.seq.items()},
            "tick": int(self.tick),
            "dirty": sorted(int(c) for c in self.dirty),
            "prefetched": sorted(int(c) for c in self.prefetched),
            "counters": self.stats(),
        }

    def load_state_meta(self, meta: dict):
        self.slot_of = {int(c): int(s) for c, s in meta["slot_of"].items()}
        self.cid_of = {s: c for c, s in self.slot_of.items()}
        self.free = [int(s) for s in meta["free"]]
        self.seq = {int(c): int(t) for c, t in meta["seq"].items()}
        self.tick = int(meta["tick"])
        self.dirty = set(int(c) for c in meta["dirty"])
        self.prefetched = set(int(c) for c in meta["prefetched"])
        c = meta["counters"]
        self.fetches = int(c["store_fetches"])
        self.hot_hits = int(c["store_hot_hits"])
        self.prefetch_hits = int(c["store_prefetch_hits"])
        self.stall_waits = int(c["store_stall_waits"])
        self.evictions = int(c["store_evictions"])
        self.spill_bytes = int(c["store_spill_bytes"])
        self.sync_reads = int(c["store_sync_reads"])


__all__ = ["DataArena", "StoreConfig", "TieredStateStore",
           "zero_store_stats", "STORE_STATS_KEYS"]
