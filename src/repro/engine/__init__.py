"""Cohort-batched asynchronous FL execution engine.

Architecture (one PR-level view; details in each module's docstring):

    virtual-clock event heap ──► cohort.pop_cohort (staleness window)
            │                               │
    engine.CohortRunner.dispatch      stacked client axis
    (host: RNG schedule, accountant,        │
     tier clock, version pull)        cohort_step.make_cohort_step
            │                         (ONE jitted scan+vmap local phase)
            ▼                               │
    LocalRoundPlan pending map        fused weights-vector merge
                                      (fold_cohort_weights: exactly the
                                       sequential Eq. 11 merges) or
                                      per-member aggregation.apply_update
                                            │
                                      RunLog (same schema as legacy)

Frontends: ``repro.core.server.run_fedavg`` / ``run_async`` take
``engine="cohort"`` (this package) or ``engine="legacy"`` (the original
per-client Python event loop, kept for parity testing — see
tests/test_engine_parity.py).  With ``EngineConfig.staleness_window=0``
the cohort path reproduces the legacy loop update-for-update; positive
windows batch near-simultaneous completions for throughput
(benchmarks/fl_benchmarks.py::bench_engine_throughput).

Mesh execution (``repro.engine.mesh_backend``): pass ``mesh=`` to the
frontends (or set ``EngineConfig.mesh``) and the stacked client axis is
partitioned over the mesh's data axes; with the default device-resident
arena path every cohort pads to a bucket that divides the data axes, so
EVERY cohort — not just full-size ones — genuinely runs one member chunk
per device group.  Executor choice: single CPU device —
``client_axis="unroll"``; mesh — ``"vmap"`` (simulation math) or
``"fl_step"`` (the production per-microbatch-DP round from
``core/fl_step.py``, driven by the same event loop).

Data path (``EngineConfig.device_arena``, default on): all clients'
params/opt state live in one stacked device arena and datasets upload
once at runner construction; per-cohort traffic is a few KB of int32
index plans (``RunLog.engine_stats`` reports the measured bytes).
``device_arena=False`` keeps the PR-2 host-fed path for comparison
(``benchmarks/fl_benchmarks.py::bench_engine_throughput`` times both and
writes ``BENCH_engine.json``).

Client-state tiering (``EngineConfig.store``): ``StoreConfig.hot_slots``
bounds the device arena to a hot set backed by a host cold store, with a
lookahead prefetcher reading the virtual clock's event heap
(``repro.engine.statestore``; contract in STORE.md).  Datasets live in
their own identity-deduped :class:`~repro.engine.statestore.DataArena`.
Tiered runs are bit-identical to the all-resident arena while scaling
the same engine to 100k+-client populations on bounded device memory.

Scheduling (``EngineConfig.pipeline_depth``): the default depth 1 is the
serial driver (donation-chained — every submit blocks the host for the
cohort's device time); depth >= 2 is the pipelined submit/drain
scheduler — donation-free compiled steps dispatch asynchronously, host
planning and the few-KB staging uploads for cohort t+1 overlap cohort
t's device execution, and the host blocks only at eval boundaries (see
the pipeline diagram in :mod:`repro.engine.engine`; dispatch-time
privacy accounting is O(orders) via the memoized vectors and epsilon
schedules in :mod:`repro.core.accountant`).  ``RunLog`` is bit-identical
across depths — the parity suite in tests/test_engine_pipeline.py holds
the pipelined path to the serial engine AND the legacy loop.
"""
from repro.engine.cohort import (
    LocalRoundPlan,
    fedavg_weights,
    fold_cohort_weights,
    padded_cohort_size,
    plan_batches,
    pop_cohort,
)
from repro.engine.cohort_step import (
    CLIENT_AXES,
    cached_cohort_step,
    invalidate_step_cache,
    make_cohort_step,
    stack_trees,
    unstack_tree,
)
from repro.engine.engine import (
    CohortRunner,
    EngineConfig,
    run_async_engine,
    run_fedavg_engine,
)
from repro.engine.mesh_backend import (
    CohortSharding,
    assert_cohort_partitioned,
    cohort_mesh,
    cohort_spec,
)
from repro.engine.resilience import CheckpointPolicy, SimulatedCrash
from repro.engine.statestore import (
    DataArena,
    StoreConfig,
    TieredStateStore,
)

__all__ = [
    "CLIENT_AXES",
    "CheckpointPolicy",
    "CohortRunner",
    "CohortSharding",
    "DataArena",
    "EngineConfig",
    "LocalRoundPlan",
    "SimulatedCrash",
    "StoreConfig",
    "TieredStateStore",
    "assert_cohort_partitioned",
    "cached_cohort_step",
    "cohort_mesh",
    "cohort_spec",
    "fedavg_weights",
    "fold_cohort_weights",
    "invalidate_step_cache",
    "make_cohort_step",
    "padded_cohort_size",
    "plan_batches",
    "pop_cohort",
    "run_async_engine",
    "run_fedavg_engine",
    "stack_trees",
    "unstack_tree",
]
