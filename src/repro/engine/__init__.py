"""Cohort-batched asynchronous FL execution engine.

Architecture (one PR-level view; details in each module's docstring):

    virtual-clock event heap ──► cohort.pop_cohort (staleness window)
            │                               │
    engine.CohortRunner.dispatch      stacked client axis
    (host: RNG schedule, accountant,        │
     tier clock, version pull)        cohort_step.make_cohort_step
            │                         (ONE jitted scan+vmap local phase)
            ▼                               │
    LocalRoundPlan pending map        fused weights-vector merge
                                      (fold_cohort_weights: exactly the
                                       sequential Eq. 11 merges) or
                                      per-member aggregation.apply_update
                                            │
                                      RunLog (same schema as legacy)

Frontends: ``repro.core.server.run_fedavg`` / ``run_async`` take
``engine="cohort"`` (this package) or ``engine="legacy"`` (the original
per-client Python event loop, kept for parity testing — see
tests/test_engine_parity.py).  With ``EngineConfig.staleness_window=0``
the cohort path reproduces the legacy loop update-for-update; positive
windows batch near-simultaneous completions for throughput
(benchmarks/fl_benchmarks.py::bench_engine_throughput).
"""
from repro.engine.cohort import (
    LocalRoundPlan,
    fedavg_weights,
    fold_cohort_weights,
    plan_batches,
    pop_cohort,
)
from repro.engine.cohort_step import (
    cached_cohort_step,
    make_cohort_step,
    stack_trees,
    unstack_tree,
)
from repro.engine.engine import (
    CohortRunner,
    EngineConfig,
    run_async_engine,
    run_fedavg_engine,
)

__all__ = [
    "CohortRunner",
    "EngineConfig",
    "LocalRoundPlan",
    "cached_cohort_step",
    "fedavg_weights",
    "fold_cohort_weights",
    "make_cohort_step",
    "plan_batches",
    "pop_cohort",
    "run_async_engine",
    "run_fedavg_engine",
    "stack_trees",
    "unstack_tree",
]
