"""Compiled-program audits for the cohort engine.

Each checker here machine-verifies an invariant that was once a hand-won
debugging session:

* :func:`audit_sharding` — PR 2: GSPMD silently REPLICATES an uneven
  stacked-client axis instead of partitioning it (no error, just 8x the
  memory and compute per device).  The audit inspects the loaded
  executable's ``output_shardings`` and fails if any leaf carrying the
  client axis has a full-size shard on a multi-device mesh.
* :func:`audit_donation` — PR 4: ``donate_argnums`` is a *request*; XLA
  silently degrades it to a copy when it can't alias (sharding/dtype
  mismatch, buffer still live).  The audit parses the compiled module's
  ``input_output_alias`` header table — the ground truth for whether
  donation materialized.
* :func:`audit_collectives` — the cohort step legitimately gathers the
  sharded arena (all-gathers ARE expected); what must not drift is the
  *budget*.  The audit fails on forbidden collective kinds or counts
  above an explicit per-kind budget.
* :func:`audit_engine_stats` — PR 6: bench provenance (which DP path, did
  pallas interpret, did the pipeline sync) must not drift silently.  The
  audit pins recorded ``RunLog.engine_stats`` to the frozen schema in
  :data:`repro.core.runlog.ENGINE_STATS_KEYS`.

All audits raise :class:`AuditFailure` with an actionable message; CI
runs them against the REAL compiled cohort step on the forced-8-device
mesh (``tests/test_analysis_audits.py``) next to seeded-violation
fixtures that must each fire.
"""
from __future__ import annotations

from repro.analysis.hlo import analyze, donation_aliases
from repro.core.runlog import ENGINE_STATS_KEYS, validate_engine_stats


class AuditFailure(AssertionError):
    """A compiled-program invariant did not hold."""


# ---------------------------------------------------------------------------
# sharding
# ---------------------------------------------------------------------------

def _leaf_shardings(compiled):
    """Flatten a loaded executable's output shapes + shardings into
    parallel leaf lists (shapes via the executable's own out_avals when
    available, else the caller passes them explicitly)."""
    import jax
    shardings = jax.tree_util.tree_leaves(
        compiled.output_shardings,
        is_leaf=lambda x: hasattr(x, "shard_shape"))
    return shardings


def audit_sharding(compiled, out_shapes=None, *, client_dim,
                   min_partition=2, label="cohort_step"):
    """Fail if any output leaf carrying the stacked-client axis is
    replicated instead of partitioned.

    ``compiled`` is a lowered-and-compiled jax executable (``jax.jit(f)
    .lower(...).compile()``); ``out_shapes`` is the matching flat list of
    output shapes (e.g. ``[s.shape for s in jax.tree_util.tree_leaves(
    jax.eval_shape(f, ...))]``) — if omitted it is read from the
    executable's output avals.  A leaf participates in the audit when its
    leading dim equals ``client_dim`` (the padded stacked-cohort size);
    such a leaf must shard to at most ``client_dim // min_partition``
    rows per device.  GSPMD replicating the axis (shard == full size) is
    exactly the PR-2 silent failure this exists to catch.
    """
    import jax
    shardings = _leaf_shardings(compiled)
    if out_shapes is None:
        out_shapes = [tuple(a.shape) for a in jax.tree_util.tree_leaves(
            compiled.out_avals)]
    if len(out_shapes) != len(shardings):
        raise ValueError(
            f"audit_sharding: {len(out_shapes)} shapes vs "
            f"{len(shardings)} shardings — pass the flat eval_shape list "
            "matching the compiled outputs")
    audited = 0
    for i, (shape, sh) in enumerate(zip(out_shapes, shardings)):
        if not shape or shape[0] != client_dim:
            continue
        audited += 1
        shard = sh.shard_shape(tuple(shape))
        if shard[0] * min_partition > shape[0]:
            raise AuditFailure(
                f"{label}: output leaf {i} shape={tuple(shape)} carries "
                f"the client axis (dim0={client_dim}) but shards to "
                f"{shard} — replicated/under-partitioned (expected "
                f"<= {shape[0] // min_partition} rows per device). "
                "GSPMD silently replicates uneven leading dims; pad the "
                "cohort to a bucket that divides the data-axis product.")
    if audited == 0:
        raise AuditFailure(
            f"{label}: no output leaf has leading dim {client_dim} — "
            "the audit checked nothing (wrong client_dim?)")
    return audited


# ---------------------------------------------------------------------------
# donation
# ---------------------------------------------------------------------------

def audit_donation(hlo_text: str, *, expect: bool, min_aliases: int = 1,
                   label="cohort_step"):
    """Verify the ``input_output_alias`` table matches the donation intent.

    ``expect=True``: at least ``min_aliases`` aliased buffers must appear
    (a ``donate=True`` build whose aliases vanished is the silent
    donation-dropped regression).  ``expect=False``: the table must be
    EMPTY — the pipelined scheduler builds donation-free programs
    precisely so dispatch never blocks; an alias sneaking back in would
    reintroduce the PR-4 stall.
    """
    aliases = donation_aliases(hlo_text)
    if expect and len(aliases) < min_aliases:
        raise AuditFailure(
            f"{label}: donate=True but only {len(aliases)} input/output "
            f"aliases materialized (expected >= {min_aliases}). XLA "
            "silently copies when it cannot alias — check for sharding/"
            "dtype mismatches between the donated input and any output.")
    if not expect and aliases:
        raise AuditFailure(
            f"{label}: donation expected OFF (pipelined path) but "
            f"{len(aliases)} input/output aliases present: {aliases[:4]}"
            " — a donated-input dispatch blocks the host and breaks the "
            "submit/drain overlap.")
    return len(aliases)


# ---------------------------------------------------------------------------
# collectives
# ---------------------------------------------------------------------------

def audit_collectives(hlo_text: str, *, forbid=(), max_counts=None,
                      entry_hint="", label="cohort_step"):
    """Budget-check the compiled program's collectives.

    The sharded-arena cohort step has a legitimate collective footprint
    (the in-program cohort gather all-gathers arena rows), so "zero
    all-gathers" is not the invariant — the *budget* is.  ``forbid``
    names kinds that must not appear at all; ``max_counts`` maps kind ->
    max trip-count-weighted occurrences.  Returns the analyzed counts
    dict for reporting.
    """
    counts = analyze(hlo_text, entry_hint=entry_hint)["collective_counts"]
    for kind in forbid:
        if counts.get(kind, 0) > 0:
            raise AuditFailure(
                f"{label}: forbidden collective {kind!r} appears "
                f"{counts[kind]}x (counts: {dict(counts)}). An unexpected "
                f"{kind} on the client axis usually means a sharding "
                "constraint was dropped and GSPMD is re-materializing "
                "the full array per device.")
    for kind, budget in (max_counts or {}).items():
        if counts.get(kind, 0) > budget:
            raise AuditFailure(
                f"{label}: {kind} count {counts[kind]} exceeds budget "
                f"{budget} (counts: {dict(counts)}) — the program's "
                "collective footprint drifted; re-derive the budget or "
                "fix the regression.")
    return dict(counts)


# ---------------------------------------------------------------------------
# engine-stats provenance
# ---------------------------------------------------------------------------

def audit_engine_stats(stats: dict, *, label="engine_stats"):
    """Pin a recorded ``RunLog.engine_stats`` dict to the frozen schema
    (:data:`repro.core.runlog.ENGINE_STATS_KEYS`) and the cross-field
    invariants the bench contract relies on."""
    try:
        validate_engine_stats(stats, context=label)
    except (TypeError, ValueError) as e:
        raise AuditFailure(str(e)) from e
    if stats["pipeline_depth"] > 1 and stats["host_syncs_between_evals"]:
        raise AuditFailure(
            f"{label}: pipelined run (depth="
            f"{stats['pipeline_depth']}) recorded "
            f"{stats['host_syncs_between_evals']} host syncs between "
            "evals — the submit/drain overlap is broken (a device value "
            "is being fetched outside _host_fetch's eval boundary).")
    if stats["dp_path"] == "pallas" and stats["pallas_interpret"] is None:
        raise AuditFailure(
            f"{label}: dp_path='pallas' but no interpret provenance was "
            "recorded — interpret_info() must be captured so a silently "
            "interpreting kernel on a compiled backend is visible.")
    # fault-ledger conservation (repro.core.faults): every lost upload
    # either re-entered the heap as a retry or exhausted its budget and
    # became a lost update — an imbalance means a loop dropped or
    # double-counted a delivery attempt
    if stats["fault_upload_losses"] != (
            stats["fault_retries"] + stats["fault_lost_updates"]):
        raise AuditFailure(
            f"{label}: fault ledger imbalance — fault_upload_losses="
            f"{stats['fault_upload_losses']} must equal fault_retries="
            f"{stats['fault_retries']} + fault_lost_updates="
            f"{stats['fault_lost_updates']}.")
    # screening-ledger conservation (repro.core.screening): every
    # rejection is classified as exactly one of nonfinite / norm-reject
    # — an imbalance means a verdict was double-counted or a classifier
    # branch was skipped
    if stats["screen_rejections"] != (
            stats["screen_nonfinite"] + stats["screen_norm_rejects"]):
        raise AuditFailure(
            f"{label}: screening ledger imbalance — screen_rejections="
            f"{stats['screen_rejections']} must equal screen_nonfinite="
            f"{stats['screen_nonfinite']} + screen_norm_rejects="
            f"{stats['screen_norm_rejects']}.")
    # store-ledger conservation (repro.engine.statestore): every slot
    # acquisition is classified as exactly one of hot-hit / prefetch-hit
    # / stall — an imbalance means a fetch was double-counted or a
    # classification branch was skipped (all-resident runs report 0 == 0)
    if stats["store_fetches"] != (
            stats["store_hot_hits"] + stats["store_prefetch_hits"]
            + stats["store_stall_waits"]):
        raise AuditFailure(
            f"{label}: store ledger imbalance — store_fetches="
            f"{stats['store_fetches']} must equal store_hot_hits="
            f"{stats['store_hot_hits']} + store_prefetch_hits="
            f"{stats['store_prefetch_hits']} + store_stall_waits="
            f"{stats['store_stall_waits']}.")
    return stats


__all__ = ["AuditFailure", "audit_sharding", "audit_donation",
           "audit_collectives", "audit_engine_stats", "ENGINE_STATS_KEYS"]
