"""Compile-budget guard: the "one compiled program per sigma sweep"
invariant as a structural guarantee.

PR 5 made sigma a RUNTIME argument of the compiled cohort step so a
noise sweep replays one program; PR 6 extended that to the fused Pallas
DP path.  Until now the invariant was enforced after the fact — a
per-test assertion plus ``summarize.py --check-engine`` failing a bench
row whose warm ``step_builds`` delta grew.  :func:`compile_guard` moves
the check to the execution site: ``Session.sweep`` wraps its grid loop
in a guard whose budget is derived from the grid itself
(:func:`sweep_max_builds`), so an accidental recompile-per-point — a new
config field that leaks into the step cache key, a sharding object that
stops hashing, sigma read statically again — fails the sweep THERE, with
the offending budget in the message, not a bench run later.
"""
from __future__ import annotations

import contextlib
import dataclasses

from repro.analysis.audits import AuditFailure


class CompileBudgetExceeded(AuditFailure):
    """More cohort-step programs were built than the region's budget."""


def step_signature(spec):
    """The compile identity of a spec: two specs with equal signatures
    share one cached cohort-step build (``cohort_step.cached_cohort_step``
    keys on testbed-derived training config + engine config; sigma is a
    runtime argument, so only the noise on/off distinction survives).
    Returns ``None`` for specs that never touch the step cache (legacy
    backend)."""
    if spec.backend != "cohort":
        return None
    tb = spec.testbed
    # the built program only distinguishes add_noise = use_dp and
    # sigma > 0; the magnitude is a runtime arg (PR 5).  Fault and
    # screening models never reach the program at all: corruption
    # scales are a runtime (K,) step argument and screening thresholds
    # compare on the host (PR 9), so a (fault × screening) grid shares
    # ONE build with the clean point.
    tb = dataclasses.replace(
        tb, sigma=1.0 if (tb.use_dp and tb.sigma > 0) else 0.0,
        faults=None, screening=None)
    return (tb, spec.engine)


def sweep_max_builds(specs) -> int:
    """Upper bound on cohort-step builds for running ``specs`` cold: the
    number of DISTINCT compile signatures in the grid.  A warm session
    builds fewer (possibly zero); building MORE means a recompile leak."""
    return len({sig for sig in map(step_signature, specs)
                if sig is not None})


@dataclasses.dataclass
class GuardReport:
    """Live view of a :func:`compile_guard` region (also returned from
    it): ``delta`` is the number of cohort-step builds since entry."""

    start: int
    max_builds: int
    label: str = "compile_guard"

    @property
    def delta(self) -> int:
        from repro.engine.cohort_step import step_builds
        return step_builds() - self.start


@contextlib.contextmanager
def compile_guard(max_builds: int, label: str = "compile_guard"):
    """Fail if more than ``max_builds`` cohort-step programs are built
    inside the ``with`` block.

    Checks on clean exit only — an exception already propagating out of
    the region is the real error and is never masked by the budget
    check.  Yields a :class:`GuardReport` whose ``delta`` can be read
    inside or after the region (``summarize.py`` reports it in the sweep
    bench rows).
    """
    from repro.engine.cohort_step import step_builds
    if max_builds < 0:
        raise ValueError(f"max_builds must be >= 0: {max_builds}")
    report = GuardReport(start=step_builds(), max_builds=max_builds,
                         label=label)
    yield report
    delta = report.delta
    if delta > max_builds:
        raise CompileBudgetExceeded(
            f"{label}: {delta} cohort-step programs built in a region "
            f"budgeted for {max_builds}. A recompile is leaking — most "
            "likely a config value that should be a runtime argument "
            "(sigma was one) is being traced statically, or a new field "
            "entered the cached_cohort_step key so consecutive grid "
            "points stopped sharing a program.")


__all__ = ["CompileBudgetExceeded", "GuardReport", "compile_guard",
           "step_signature", "sweep_max_builds"]
