"""``python -m repro.analysis.lint`` — AST lint pass for the repo's own
bug classes.

Generic linters catch generic bugs; every expensive failure this repo
has actually hit was a REPO-SPECIFIC invariant violation (a config field
missing from a compile-cache key, a dataclass half-registered in the
spec codec, a static divisor where a batch-derived one was meant, a
donated buffer reused, a host sync in the pipelined hot loop).  The REP
rules in :mod:`repro.analysis.rules` codify those classes; this module
is the engine: file loading, project-wide context (dataclass registry,
spec-type registries, donation registry), suppression handling, and the
CLI.

Usage::

    PYTHONPATH=src python -m repro.analysis.lint src/          # CI gate
    PYTHONPATH=src python -m repro.analysis.lint path/to/a.py  # one file

Suppression: append ``# rep-noqa: REP003 -- <why this is safe>`` to the
flagged line.  The justification is REQUIRED — a bare ``rep-noqa``
produces REP000.  Multiple rules: ``# rep-noqa: REP004, REP005 -- ...``.

Exit status: 0 when no findings, 1 when any finding survives
suppression, 2 on usage/parse errors.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import os
import re
import sys

_SUPPRESS_RE = re.compile(
    r"#\s*rep-noqa:\s*(REP\d{3}(?:\s*,\s*REP\d{3})*)(\s*--\s*(\S.*))?")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


class SourceFile:
    """One parsed file: tree, parent links, and suppression table."""

    def __init__(self, path: str, text: str):
        self.path = path
        self.text = text
        self.tree = ast.parse(text, filename=path)
        self.lines = text.splitlines()
        self.parents: dict = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self.parents[child] = parent
        # line -> set of suppressed rule codes; lines with a rep-noqa
        # comment lacking the "-- reason" tail get REP000 instead
        self.suppressions: dict = {}
        self.bare_suppressions: list = []
        for i, raw in enumerate(self.lines, 1):
            m = _SUPPRESS_RE.search(raw)
            if m is None:
                continue
            codes = {c.strip() for c in m.group(1).split(",")}
            if m.group(3) is None:
                self.bare_suppressions.append((i, sorted(codes)))
            else:
                self.suppressions[i] = codes

    def ancestors(self, node):
        cur = self.parents.get(node)
        while cur is not None:
            yield cur
            cur = self.parents.get(cur)

    def enclosing_function(self, node):
        for anc in self.ancestors(node):
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return anc
        return None


# ---------------------------------------------------------------------------
# project context: cross-file registries the rules consult
# ---------------------------------------------------------------------------

def _is_dataclass_decorator(dec) -> bool:
    if isinstance(dec, ast.Call):
        dec = dec.func
    if isinstance(dec, ast.Name):
        return dec.id == "dataclass"
    if isinstance(dec, ast.Attribute):
        return dec.attr == "dataclass"
    return False


@dataclasses.dataclass
class DataclassInfo:
    name: str
    path: str
    line: int
    fields: list            # annotated field names, in order
    refs: set               # identifiers referenced by annotations/defaults


@dataclasses.dataclass
class SpecRegistry:
    """An ``_SPEC_TYPES``-style codec registry assignment."""
    path: str
    line: int
    names: list             # registered class names


@dataclasses.dataclass
class Donator:
    """A function compiled with ``donate_argnums``."""
    name: str
    path: str
    line: int
    positions: tuple        # donated argument indices


class ProjectContext:
    """Registries built over ALL linted files before per-file rules run.

    The context is scoped to the lint invocation: linting ``src/`` sees
    the whole package; linting one fixture file sees only that file, so
    seeded-violation fixtures are self-contained.
    """

    def __init__(self, files):
        self.files = files
        self.dataclasses: dict = {}
        self.spec_registries: list = []
        self.donators: dict = {}        # normalized name -> Donator
        for f in files:
            self._scan(f)

    # -- dataclass + codec registries -----------------------------------
    def _scan(self, f: SourceFile):
        for node in ast.walk(f.tree):
            if isinstance(node, ast.ClassDef) and any(
                    _is_dataclass_decorator(d) for d in node.decorator_list):
                fields, refs = [], set()
                for stmt in node.body:
                    if not isinstance(stmt, ast.AnnAssign):
                        continue
                    if isinstance(stmt.target, ast.Name):
                        fields.append(stmt.target.id)
                    for sub in ast.walk(stmt):
                        if isinstance(sub, ast.Name):
                            refs.add(sub.id)
                self.dataclasses[node.name] = DataclassInfo(
                    node.name, f.path, node.lineno, fields, refs)
            elif isinstance(node, ast.Assign):
                self._scan_spec_registry(f, node)
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._scan_donator(f, node)

    def _scan_spec_registry(self, f: SourceFile, node: ast.Assign):
        if len(node.targets) != 1 or not isinstance(node.targets[0], ast.Name):
            return
        if not node.targets[0].id.endswith("_SPEC_TYPES"):
            return
        names = []
        v = node.value
        if isinstance(v, ast.DictComp) and v.generators:
            it = v.generators[0].iter
            if isinstance(it, (ast.Tuple, ast.List)):
                names = [e.id for e in it.elts if isinstance(e, ast.Name)]
        elif isinstance(v, ast.Dict):
            names = [val.id for val in v.values if isinstance(val, ast.Name)]
        if names:
            self.spec_registries.append(
                SpecRegistry(f.path, node.lineno, names))

    # -- donation registry ----------------------------------------------
    def _scan_donator(self, f: SourceFile, fn):
        positions = set()
        # local dict assigns visible to a **name in the decorator — the
        # `jit_kw = {...} if cond else {...}` idiom
        local_dicts: dict = {}
        scope = f.enclosing_function(fn)
        search = scope if scope is not None else f.tree
        for node in ast.walk(search):
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)):
                local_dicts[node.targets[0].id] = node.value
        for dec in fn.decorator_list:
            positions |= _donated_positions(dec, local_dicts)
        if positions:
            self.donators[_norm(fn.name)] = Donator(
                fn.name, f.path, fn.lineno, tuple(sorted(positions)))


def _norm(name: str) -> str:
    return name.lstrip("_")


def _donated_positions(dec, local_dicts) -> set:
    """Donated arg indices requested by a decorator expression.

    Handles ``@functools.partial(jax.jit, donate_argnums=(0, 1))``, the
    conditional ``**({"donate_argnums": (1,)} if flag else {})`` form,
    and one level of ``**name`` indirection to a local dict literal.
    Conditional donation unions both branches (conservative: the rule
    checks the donating configuration).
    """
    if not isinstance(dec, ast.Call):
        return set()
    exprs = [kw.value for kw in dec.keywords]
    out = set()
    for expr in exprs:
        if isinstance(expr, ast.Name):
            expr = local_dicts.get(expr.id, expr)
        for node in ast.walk(expr):
            if isinstance(node, ast.Dict):
                for k, v in zip(node.keys, node.values):
                    if (isinstance(k, ast.Constant)
                            and k.value == "donate_argnums"):
                        out |= {c.value for c in ast.walk(v)
                                if isinstance(c, ast.Constant)
                                and isinstance(c.value, int)}
    for kw in dec.keywords:
        if kw.arg == "donate_argnums":
            out |= {c.value for c in ast.walk(kw.value)
                    if isinstance(c, ast.Constant)
                    and isinstance(c.value, int)}
    return out


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def collect_files(paths) -> list:
    out = []
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, names in os.walk(p):
                for n in sorted(names):
                    if n.endswith(".py"):
                        out.append(os.path.join(root, n))
        elif p.endswith(".py"):
            out.append(p)
        else:
            raise ValueError(f"not a python file or directory: {p}")
    return out


def run(paths, select=None) -> list:
    """Lint ``paths`` (files and/or directories); returns surviving
    :class:`Finding`\\ s.  ``select`` restricts to the given rule codes
    (suppression hygiene REP000 always runs)."""
    from repro.analysis.rules import RULES
    files = []
    for path in collect_files(paths):
        with open(path, encoding="utf-8") as fh:
            files.append(SourceFile(path, fh.read()))
    ctx = ProjectContext(files)
    findings = []
    for f in files:
        for line, codes in f.bare_suppressions:
            findings.append(Finding(
                "REP000", f.path, line, 0,
                f"rep-noqa for {', '.join(codes)} has no justification — "
                "write `# rep-noqa: CODE -- why this is safe`"))
        for code, rule in RULES.items():
            if select and code not in select:
                continue
            for finding in rule.check(f, ctx):
                if finding.rule in f.suppressions.get(finding.line, ()):
                    continue
                findings.append(finding)
    return sorted(findings, key=lambda x: (x.path, x.line, x.rule))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="repo-specific AST lint (REP rules); see ANALYSIS.md")
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument("--select", action="append", default=None,
                    metavar="REPNNN", help="run only these rule codes")
    args = ap.parse_args(argv)
    try:
        findings = run(args.paths, select=args.select)
    except (SyntaxError, ValueError) as e:
        print(f"lint error: {e}", file=sys.stderr)
        return 2
    for f in findings:
        print(f.format())
    if findings:
        print(f"{len(findings)} finding(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
