"""Static analyzer for compiled (scheduled) HLO text.

XLA's HloCostAnalysis counts `while` bodies ONCE (trip counts are treated
as unknown), which under-reports both FLOPs and collective bytes for
scan-over-layers programs by ~n_layers x.  This walker fixes that:

  * splits the module into computations,
  * extracts while-loop trip counts from their condition computations
    (JAX scans lower to `compare(iter, constant(N)), direction=LT`),
  * walks the call graph from ENTRY multiplying per-computation totals by
    the enclosing trip counts,
  * per computation, accumulates
      - dot FLOPs (2 x output elems x contraction size; >99% of model
        FLOPs for transformer/SSM programs — elementwise ops are ignored
        and noted in EXPERIMENTS.md),
      - collective bytes by kind with replica-group size, converted to
        per-chip link traffic with the standard ring multipliers:
          all-gather        (g-1)/g * out_bytes
          reduce-scatter    (g-1)/g * in_bytes
          all-reduce        2 (g-1)/g * bytes
          all-to-all        (g-1)/g * bytes
          collective-permute  bytes

Pure text parsing — no XLA internals — so it works on any backend's
scheduled HLO dump.

Promoted from ``benchmarks/hlo_analysis.py`` (which remains as a
re-export shim) so the program-audit layer in
:mod:`repro.analysis.audits` can build compiled-program checkers on top
of it: :func:`donation_aliases` parses the module header's
``input_output_alias`` table (the ground truth for whether a
``donate_argnums`` request actually materialized), and :func:`analyze`'s
collective counts/bytes feed the collective audit.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_CALLED_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")


def _shape_info(text):
    """First array shape in text -> (elems, bytes) summed over all arrays."""
    elems, nbytes = 0, 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


def _dims_of_first_shape(text):
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Computation:
    name: str
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0          # operand+output bytes at fusion boundary
    collectives: dict = field(default_factory=lambda: defaultdict(float))
    coll_counts: dict = field(default_factory=lambda: defaultdict(int))
    whiles: list = field(default_factory=list)      # (body, cond)
    calls: list = field(default_factory=list)       # fusion/call targets
    fusion_targets: set = field(default_factory=set)
    trip_const: int = 1                              # if used as a while cond


# ops that don't move HBM bytes themselves
_NO_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "partition-id", "replica-id",
               "while", "conditional", "call"}


def parse_module(hlo: str) -> dict:
    comps: dict = {}
    cur = None
    shapes: dict = {}          # op name -> shape text (per computation scope is
                               # fine to flatten: names are unique module-wide)
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        # computation header: "%name (params...) -> type {"  or "ENTRY ..."
        if (s.endswith("{") and ("(" in s) and ("=" not in s.split("(")[0])):
            header = s
            name = header.split("(")[0].replace("ENTRY", "").strip().lstrip("%")
            cur = Computation(name=name)
            comps[name] = cur
            # parameter shapes from the signature
            sig = header[header.find("(") + 1: header.rfind("->")]
            for pm in re.finditer(r"%?([\w.\-]+):\s*([\w\[\],\s()]+)", sig):
                shapes[pm.group(1)] = pm.group(2)
            continue
        if s == "}" or cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        op_name, out_text, kind, rest = m.groups()
        shapes[op_name] = out_text

        # HBM traffic at fusion boundary: output + operand bytes.  Ops
        # inside fusion computations are NOT counted (they live in VMEM) —
        # fusion targets are excluded from the call-graph byte walk below.
        if kind not in _NO_TRAFFIC:
            _, ob = _shape_info(out_text)
            ib = 0
            for opn in _OPERAND_RE.findall(rest.split("),")[0] + ")"):
                _, b = _shape_info(shapes.get(opn, ""))
                ib += b
            cur.hbm_bytes += ob + ib

        if kind == "constant" and "s32[]" in out_text:
            cm = re.search(r"constant\((\d+)\)", s)
            if cm:
                cur.trip_const = max(cur.trip_const, int(cm.group(1)))

        if kind == "dot":
            out_elems, _ = _shape_info(out_text)
            ops = _OPERAND_RE.findall(rest)
            cdims = _CONTRACT_RE.search(s)
            contract = 1
            if ops and cdims is not None:
                lhs_shape = _dims_of_first_shape(shapes.get(ops[0], ""))
                if lhs_shape:
                    for d in cdims.group(1).split(","):
                        if d and int(d) < len(lhs_shape):
                            contract *= lhs_shape[int(d)]
            cur.dot_flops += 2.0 * out_elems * contract
        elif kind == "while":
            tgt = dict(
                (k, v) for k, v in re.findall(
                    r"(body|condition)=%?([\w.\-]+)", s))
            if "body" in tgt:
                cur.whiles.append((tgt["body"], tgt.get("condition")))
        elif kind in ("fusion", "call", "conditional", "async-start"):
            tgts = _CALLED_RE.findall(s)
            cur.calls.extend(tgts)
            if kind == "fusion":
                cur.fusion_targets.update(tgts)
        else:
            base = kind[:-6] if kind.endswith("-start") else kind
            if base in COLLECTIVE_KINDS and not kind.endswith("-done"):
                _, out_bytes = _shape_info(out_text)
                # operand bytes for reduce-scatter traffic
                in_bytes = 0
                for opn in _OPERAND_RE.findall(rest):
                    _, b = _shape_info(shapes.get(opn, ""))
                    in_bytes += b
                gm = _GROUPS_RE.search(s)
                if gm:
                    g = int(gm.group(2))
                else:
                    ge = _GROUPS_EXPL_RE.search(s)
                    g = len(ge.group(1).split(",")) if ge else 2
                g = max(g, 2)
                ring = (g - 1) / g
                if base == "all-gather":
                    traffic = ring * out_bytes
                elif base == "all-reduce":
                    traffic = 2 * ring * out_bytes
                elif base == "reduce-scatter":
                    traffic = ring * in_bytes
                elif base == "all-to-all":
                    traffic = ring * out_bytes
                else:  # collective-permute
                    traffic = out_bytes
                cur.collectives[base] += traffic
                cur.coll_counts[base] += 1
    return comps


_ALIAS_HEADER = "input_output_alias={"
_ALIAS_PAIR_RE = re.compile(r"\{([\d,\s]*)\}:\s*\((\d+)")


def donation_aliases(hlo: str) -> list:
    """Parse the module header's ``input_output_alias`` table.

    Returns ``[(output_index_path, parameter_number), ...]`` — one entry
    per buffer the compiled program aliases between an input and an
    output.  An empty list means NO donation materialized: a
    ``donate_argnums`` request that XLA could not honor (sharding
    mismatch, dtype change, buffer still live) silently degrades to a
    copy, which is exactly the regression :func:`repro.analysis.audits.
    audit_donation` exists to catch.
    """
    start = hlo.find(_ALIAS_HEADER)
    if start < 0:
        return []
    # balanced-brace scan over the alias table (entries nest one level:
    # "{ {0}: (23, {}, may-alias), ... }")
    i = start + len(_ALIAS_HEADER) - 1
    depth = 0
    for j in range(i, len(hlo)):
        if hlo[j] == "{":
            depth += 1
        elif hlo[j] == "}":
            depth -= 1
            if depth == 0:
                break
    else:
        return []
    table = hlo[i: j + 1]
    pairs = []
    for m in _ALIAS_PAIR_RE.finditer(table):
        out_path = tuple(int(d) for d in m.group(1).split(",") if d.strip())
        pairs.append((out_path, int(m.group(2))))
    return pairs


def analyze(hlo: str, entry_hint: str = "") -> dict:
    """Walk from ENTRY, multiplying by while trip counts."""
    comps = parse_module(hlo)
    entry = None
    for name in comps:
        if entry_hint and entry_hint in name:
            entry = name
    if entry is None:
        # ENTRY is usually the computation named like the jit'd fn or 'main'
        first_line = hlo.find("ENTRY")
        if first_line >= 0:
            m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo[first_line:])
            entry = m.group(1) if m else None
    if entry is None or entry not in comps:
        raise ValueError("could not locate ENTRY computation")

    memo = {}

    def walk(name, depth=0):
        if name in memo:
            return memo[name]
        if name not in comps or depth > 64:
            return 0.0, 0.0, defaultdict(float), defaultdict(int)
        c = comps[name]
        flops = c.dot_flops
        hbm = c.hbm_bytes
        coll = defaultdict(float, c.collectives)
        cnt = defaultdict(int, c.coll_counts)
        for tgt in c.calls:
            f, h, co, ct = walk(tgt, depth + 1)
            flops += f
            if tgt not in c.fusion_targets:
                hbm += h          # fusion internals live in VMEM
            for k, v in co.items():
                coll[k] += v
            for k, v in ct.items():
                cnt[k] += v
        for body, cond in c.whiles:
            trips = comps[cond].trip_const if cond in comps else 1
            fb, hb, cb, nb = walk(body, depth + 1)
            fc, hc, cc, nc = (walk(cond, depth + 1) if cond in comps
                              else (0, 0, {}, {}))
            flops += trips * (fb + fc)
            hbm += trips * (hb + hc)
            for k, v in cb.items():
                coll[k] += trips * v
            for k, v in nb.items():
                cnt[k] += trips * v
        memo[name] = (flops, hbm, coll, cnt)
        return memo[name]

    flops, hbm, coll, cnt = walk(entry)
    return {
        "dot_flops": flops,
        "hbm_bytes": hbm,
        "collective_traffic_bytes": dict(coll),
        "collective_counts": dict(cnt),
        "total_collective_bytes": float(sum(coll.values())),
    }
