"""The REP rule registry.  Each rule codifies one bug class this repo
has actually shipped and hand-debugged; ``ANALYSIS.md`` documents the
history.  Rules are AST-only (no imports of the linted code) so they run
on fixtures and broken trees alike.

Adding a rule: subclass :class:`Rule`, set ``code``/``title``, implement
``check(file, ctx) -> list[Finding]``, and add it to :data:`RULES`.
"""
from __future__ import annotations

import ast
import re

from repro.analysis.lint import Finding, ProjectContext, SourceFile, _norm


class Rule:
    code = "REP000"
    title = ""

    def check(self, file: SourceFile, ctx: ProjectContext) -> list:
        raise NotImplementedError

    def finding(self, file, node, message) -> Finding:
        return Finding(self.code, file.path, node.lineno,
                       getattr(node, "col_offset", 0), message)


def _functions(tree):
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _params(fn) -> list:
    a = fn.args
    names = [p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs)]
    return [n for n in names if n not in ("self", "cls")]


def _names_in(node) -> set:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


# ---------------------------------------------------------------------------
# REP001 — cache-key completeness
# ---------------------------------------------------------------------------

class CacheKeyCompleteness(Rule):
    """A tuple used as a memo/cache key must cover every parameter of the
    caching function.

    History: PR 6 plumbed ``dp_path`` into the engine but the first cut
    left it out of the ``cached_cohort_step`` key tuple — two testbeds
    differing only in DP implementation silently shared one compiled
    program.  The rule finds ``key = (...)`` tuples used in membership
    tests / subscripts / ``.get`` lookups and reports any function
    parameter not reachable from the tuple (directly, or through one
    level of local dataflow such as ``sh_key = _shardings_key(
    client_shardings)``).
    """

    code = "REP001"
    title = "cache-key tuple omits a function parameter"

    def check(self, file, ctx):
        findings = []
        for fn in _functions(file.tree):
            assigns = {}
            for node in ast.walk(fn):
                if (isinstance(node, ast.Assign) and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)):
                    assigns.setdefault(
                        node.targets[0].id, []).append(node.value)
            for node in ast.walk(fn):
                if not (isinstance(node, ast.Assign)
                        and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)
                        and isinstance(node.value, ast.Tuple)):
                    continue
                key_name = node.targets[0].id
                if not self._used_as_cache_key(fn, key_name):
                    continue
                covered = _names_in(node.value)
                for name in list(covered):
                    for value in assigns.get(name, []):
                        covered |= _names_in(value)
                missing = [p for p in _params(fn) if p not in covered]
                if missing:
                    findings.append(self.finding(
                        file, node,
                        f"cache key `{key_name}` in `{fn.name}` omits "
                        f"parameter(s) {', '.join(missing)} — every input "
                        "that changes the cached value must be in the key "
                        "(or be derived into it), else two configs share "
                        "one entry"))
        return findings

    @staticmethod
    def _used_as_cache_key(fn, name) -> bool:
        for node in ast.walk(fn):
            if isinstance(node, ast.Compare) and any(
                    isinstance(op, (ast.In, ast.NotIn)) for op in node.ops):
                operands = [node.left] + list(node.comparators)
                if any(isinstance(o, ast.Name) and o.id == name
                       for o in operands[:-1]):
                    return True
            elif (isinstance(node, ast.Subscript)
                    and isinstance(node.slice, ast.Name)
                    and node.slice.id == name):
                return True
            elif (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in ("get", "setdefault", "pop")
                    and node.args
                    and isinstance(node.args[0], ast.Name)
                    and node.args[0].id == name):
                return True
        return False


# ---------------------------------------------------------------------------
# REP002 — spec-codec completeness
# ---------------------------------------------------------------------------

class SpecCodecCompleteness(Rule):
    """Every config dataclass reachable from the spec types must be
    registered in ``_SPEC_TYPES``.

    History: the PR-6 ``dp_path`` migration kept a ``use_kernel`` bool
    alive in archived JSON; more generally a dataclass nested into
    ``TestbedConfig``/``EngineConfig`` but missing from the codec
    registry makes ``encode`` raise (best case) or drop the sub-config
    (worst case) when a spec round-trips through ``BENCH_engine.json``.
    The rule walks field annotations/defaults from the registered set
    and reports reachable-but-unregistered dataclasses.
    """

    code = "REP002"
    title = "config dataclass reachable from the spec but not in _SPEC_TYPES"

    def check(self, file, ctx):
        findings = []
        for reg in ctx.spec_registries:
            if reg.path != file.path:
                continue
            registered = set(reg.names)
            reachable, stack = set(), [
                n for n in registered if n in ctx.dataclasses]
            while stack:
                cur = stack.pop()
                if cur in reachable:
                    continue
                reachable.add(cur)
                stack.extend(r for r in ctx.dataclasses[cur].refs
                             if r in ctx.dataclasses)
            for name in sorted(reachable - registered):
                info = ctx.dataclasses[name]
                findings.append(Finding(
                    self.code, file.path, reg.line, 0,
                    f"dataclass `{name}` ({info.path}:{info.line}) is "
                    "reachable from the registered spec types but absent "
                    "from _SPEC_TYPES — encode/decode will fail or drop "
                    "it when the spec round-trips through JSON"))
        return findings


# ---------------------------------------------------------------------------
# REP003 — static divisor in a traced body
# ---------------------------------------------------------------------------

_COUNT_ATTR = re.compile(r"^(n_\w+|num_\w+|batch_size)$")
_CFG_NAME = re.compile(r"(^|_)(cfg|config|fl|dp)$")


class StaticDivisor(Rule):
    """Dividing by a static config count inside a traced body that has a
    batch-derived dimension available.

    History: ``fl_step``'s local phase divided the microbatch-grad mean
    and the noise stddev by the STATIC ``fl.n_micro`` while the actual
    number of microbatches came from the batch shape — correct only when
    the two agreed, silently wrong scaling otherwise (fixed in PR 6).
    The rule flags ``x / cfg.n_*``-shaped divisions in functions that
    trace (use ``jnp``/``lax``) and read a ``.shape`` — the signal that
    a runtime-derived count exists.  Two legitimate uses are exempt:
    shape arithmetic feeding a ``.reshape(...)`` (splitting a static
    factor out of a dimension), and pure config-on-config arithmetic
    (``d_model // cfg.n_heads`` — the left side must be DATA-derived
    for the static/runtime mismatch to exist, so the rule tracks which
    locals are static config values and only fires when a non-static
    name is being divided).  ``%``-divisibility *checks* against the
    static count are the correct defensive pattern and never flag.
    """

    code = "REP003"
    title = "static config count used as divisor in a traced body"

    def check(self, file, ctx):
        findings = []
        for fn in _functions(file.tree):
            if not self._is_traced_body(fn):
                continue
            cfg_names = self._config_names(fn)
            if not cfg_names:
                continue
            static = self._static_names(fn, cfg_names)
            for node in ast.walk(fn):
                if not (isinstance(node, ast.BinOp) and isinstance(
                        node.op, (ast.Div, ast.FloorDiv))):
                    continue
                r = node.right
                if not (isinstance(r, ast.Attribute)
                        and _COUNT_ATTR.match(r.attr)
                        and isinstance(r.value, ast.Name)
                        and r.value.id in cfg_names):
                    continue
                if all(n in static for n in _names_in(node.left)):
                    continue          # config-on-config arithmetic
                if self._in_reshape(file, node):
                    continue
                findings.append(self.finding(
                    file, node,
                    f"`{fn.name}` divides by static "
                    f"`{r.value.id}.{r.attr}` in a traced body that "
                    "reads a batch shape — derive the count from the "
                    "actual batch dim (static-vs-runtime mismatch scales "
                    "results silently)"))
        return findings

    @staticmethod
    def _is_traced_body(fn) -> bool:
        uses_jnp = reads_shape = False
        for node in ast.walk(fn):
            if isinstance(node, ast.Attribute):
                if node.attr == "shape":
                    reads_shape = True
                if (isinstance(node.value, ast.Name)
                        and node.value.id in ("jnp", "lax")):
                    uses_jnp = True
        return uses_jnp and reads_shape

    @staticmethod
    def _static_names(fn, cfg_names) -> set:
        """Names that only ever derive from config values: the config
        params themselves plus locals assigned from expressions whose
        every Name is already static (fixpoint over the function's
        assignments).  Everything else — data params, shape reads,
        module globals — is non-static, conservatively."""
        static = set(cfg_names)
        assigns = [n for n in ast.walk(fn) if isinstance(n, ast.Assign)]
        changed = True
        while changed:
            changed = False
            for a in assigns:
                if not all(n in static for n in _names_in(a.value)):
                    continue
                for t in a.targets:
                    for tn in ast.walk(t):
                        if (isinstance(tn, ast.Name)
                                and tn.id not in static):
                            static.add(tn.id)
                            changed = True
        return static

    @staticmethod
    def _config_names(fn) -> set:
        names = set()
        for p in fn.args.posonlyargs + fn.args.args + fn.args.kwonlyargs:
            ann = p.annotation
            ann_cfg = (isinstance(ann, ast.Name)
                       and ann.id.endswith("Config")) or (
                isinstance(ann, ast.Constant)
                and str(ann.value).endswith("Config"))
            if _CFG_NAME.search(p.arg) or ann_cfg:
                names.add(p.arg)
        return names

    @staticmethod
    def _in_reshape(file, node) -> bool:
        for anc in file.ancestors(node):
            if (isinstance(anc, ast.Call)
                    and isinstance(anc.func, ast.Attribute)
                    and anc.func.attr == "reshape"):
                return True
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return False
        return False


# ---------------------------------------------------------------------------
# REP004 — donated-buffer reuse after donation
# ---------------------------------------------------------------------------

class DonatedReuse(Rule):
    """Passing a buffer to a ``donate_argnums`` position invalidates it;
    reading the same reference afterwards is a use-after-free XLA only
    sometimes reports.

    History: the PR-3/PR-4 arena work donates the params/opt arenas and
    the merged globals into each compiled step; every call site must
    rebind the donated reference from the step's outputs in the same
    statement.  The rule resolves ``donate_argnums`` decorators
    (including the conditional ``**({"donate_argnums": ...} if ...)``
    idiom), then checks each call site: a donated ``name``/dotted-name
    argument must be rebound by the consuming statement or never loaded
    again in the function.  Bare-name callees match donators in the same
    file; ``self.X(...)`` callees match project-wide (leading
    underscores ignored, so ``self._write`` matches the compiled
    ``write`` helper).
    """

    code = "REP004"
    title = "donated buffer used after donation"

    def check(self, file, ctx):
        findings = []
        for fn in _functions(file.tree):
            body_stmts = list(fn.body)
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                donator = self._match(file, ctx, node.func)
                if donator is None:
                    continue
                stmt = self._enclosing_stmt(file, fn, node)
                for pos in donator.positions:
                    if pos >= len(node.args):
                        continue
                    dotted = self._dotted(node.args[pos])
                    if dotted is None:
                        continue
                    if dotted in self._stmt_targets(stmt):
                        continue
                    use = self._first_use_after(fn, stmt, dotted)
                    if use is not None:
                        findings.append(self.finding(
                            file, use,
                            f"`{dotted}` was donated to `{donator.name}` "
                            f"(arg {pos}, line {node.lineno}) and read "
                            "again without rebinding — donated buffers "
                            "are invalidated; rebind from the call's "
                            "outputs"))
            del body_stmts
        return findings

    @staticmethod
    def _match(file, ctx, func):
        if isinstance(func, ast.Name):
            d = ctx.donators.get(_norm(func.id))
            return d if d is not None and d.path == file.path else None
        if (isinstance(func, ast.Attribute)
                and isinstance(func.value, ast.Name)
                and func.value.id == "self"):
            return ctx.donators.get(_norm(func.attr))
        return None

    @staticmethod
    def _dotted(node):
        parts = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if isinstance(node, ast.Name):
            parts.append(node.id)
            return ".".join(reversed(parts))
        return None

    def _enclosing_stmt(self, file, fn, node):
        stmt = node
        for anc in file.ancestors(node):
            if anc is fn:
                break
            if isinstance(anc, ast.stmt):
                stmt = anc
        return stmt

    def _stmt_targets(self, stmt) -> set:
        targets = set()
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                targets |= self._target_names(t)
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            targets |= self._target_names(stmt.target)
        return targets

    def _target_names(self, t) -> set:
        if isinstance(t, (ast.Tuple, ast.List)):
            out = set()
            for e in t.elts:
                out |= self._target_names(e)
            return out
        d = self._dotted(t)
        return {d} if d else set()

    def _first_use_after(self, fn, stmt, dotted):
        """First Load of ``dotted`` strictly after ``stmt`` (linear
        lineno order) that is not preceded by a Store of it."""
        after_line = stmt.end_lineno if stmt.end_lineno else stmt.lineno
        events = []
        for node in ast.walk(fn):
            d = self._dotted(node) if isinstance(
                node, (ast.Name, ast.Attribute)) else None
            if d != dotted:
                continue
            ctx_kind = getattr(node, "ctx", None)
            if isinstance(ctx_kind, ast.Store):
                events.append((node.lineno, "store", node))
            elif isinstance(ctx_kind, ast.Load):
                events.append((node.lineno, "load", node))
        for line, kind, node in sorted(events, key=lambda e: e[0]):
            if line <= after_line:
                continue
            return node if kind == "load" else None
        return None


# ---------------------------------------------------------------------------
# REP005 — host sync in an engine hot region
# ---------------------------------------------------------------------------

_REGION_RE = re.compile(
    r"^(run_\w+_engine|submit_\w+|stage_\w+|drain\w*|run_cohort\w*)$")
_HOST_SYNC_ATTRS = ("device_get", "item")


class HostSyncInHotRegion(Rule):
    """Device->host fetches inside the engine's submit/drain regions
    must go through the ``_host_fetch`` funnel.

    History: the PR-4 pipelined scheduler's whole win is that the host
    never blocks between eval boundaries; one stray ``float(...)``/
    ``np.asarray``/``device_get`` on a device value re-serializes the
    loop and is invisible until someone profiles.  The rule flags raw
    sync calls (``jax.device_get``, ``np.asarray``, ``.item()``,
    ``float(<call>)``) inside functions named like engine hot regions
    (``run_*_engine``, ``submit_*``, ``stage_*``, ``drain*``,
    ``run_cohort*``).  ``_host_fetch`` itself and
    ``jax.block_until_ready`` (a scheduling barrier, not a transfer into
    Python) are the sanctioned exceptions.
    """

    code = "REP005"
    title = "raw host sync inside an engine submit/drain region"

    def check(self, file, ctx):
        findings = []
        for fn in _functions(file.tree):
            if not _REGION_RE.match(fn.name):
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                msg = self._sync_kind(node)
                if msg:
                    findings.append(self.finding(
                        file, node,
                        f"`{msg}` in hot region `{fn.name}` blocks the "
                        "host on device state — route it through the "
                        "_host_fetch funnel (counted, eval-boundary-"
                        "gated) or move it out of the submit/drain path"))
        return findings

    @staticmethod
    def _sync_kind(node):
        f = node.func
        if isinstance(f, ast.Attribute):
            if f.attr in _HOST_SYNC_ATTRS:
                return f"…{'.' + f.attr}()"
            if (f.attr == "asarray" and isinstance(f.value, ast.Name)
                    and f.value.id in ("np", "numpy")):
                return "np.asarray()"
        if (isinstance(f, ast.Name) and f.id == "float" and node.args
                and isinstance(node.args[0], ast.Call)):
            return "float(<device value>)"
        return None


# ---------------------------------------------------------------------------
# REP006 — per-client loop in a store residency hot region
# ---------------------------------------------------------------------------

_STORE_REGION_RE = re.compile(
    r"^_?(prefetch|spill|evict|acquire|materialize)\w*$")


class PerClientLoopInStoreRegion(Rule):
    """Residency-management hot paths must walk cohorts/batches, never
    the whole client population.

    History: the tiered-store PR exists because the engine loops used to
    touch all N clients per round (the O(N) dispatch scan the lazy-plan
    fix removed); the store's prefetch/spill/acquire paths run once per
    cohort, so a Python loop over ``clients`` (or ``self.clients``)
    inside them reintroduces exactly the O(N)-per-cohort wall the
    100k-client scale bench guards against — invisible at the 32-client
    paper testbed, fatal at scale.  The rule flags ``for``-loop and
    comprehension iterables that reference a ``clients`` name/attribute
    inside functions named like store residency regions (``prefetch*``,
    ``spill*``, ``evict*``, ``acquire*``, ``materialize*``,
    underscore-prefixed included).  Walk the cohort's plans or the
    prefetch batch, or index one client (``clients[cid]``), instead.
    """

    code = "REP006"
    title = "per-client loop inside a store residency region"

    def check(self, file, ctx):
        findings = []
        for fn in _functions(file.tree):
            if not _STORE_REGION_RE.match(fn.name):
                continue
            for node in ast.walk(fn):
                if isinstance(node, (ast.For, ast.AsyncFor)):
                    iters = [node.iter]
                elif isinstance(node, (ast.ListComp, ast.SetComp,
                                       ast.DictComp, ast.GeneratorExp)):
                    iters = [g.iter for g in node.generators]
                else:
                    continue
                if any(self._mentions_clients(it) for it in iters):
                    findings.append(self.finding(
                        file, node,
                        f"loop over the client population in store region "
                        f"`{fn.name}` — residency paths run per cohort: "
                        "walk the cohort/prefetch batch (or index "
                        "clients[cid]) instead"))
        return findings

    @staticmethod
    def _mentions_clients(node) -> bool:
        for n in ast.walk(node):
            if isinstance(n, ast.Name) and n.id == "clients":
                return True
            if isinstance(n, ast.Attribute) and n.attr == "clients":
                return True
        return False


RULES = {
    r.code: r for r in (
        CacheKeyCompleteness(), SpecCodecCompleteness(), StaticDivisor(),
        DonatedReuse(), HostSyncInHotRegion(), PerClientLoopInStoreRegion())
}

__all__ = ["RULES", "Rule", "CacheKeyCompleteness", "SpecCodecCompleteness",
           "StaticDivisor", "DonatedReuse", "HostSyncInHotRegion",
           "PerClientLoopInStoreRegion"]
