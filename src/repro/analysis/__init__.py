"""repro.analysis — static analysis & program audits for the engine.

Two layers, one goal: the invariants that used to be found by hand
(replicated cohort axes, dropped donations, half-plumbed config fields,
static-vs-runtime divisors, stray host syncs, recompile leaks) are
checked mechanically.

* **Lint time** — :mod:`repro.analysis.lint` + :mod:`repro.analysis.
  rules`: ``python -m repro.analysis.lint src/`` runs the REP rule set
  over the source (CI gates on it; see ``ANALYSIS.md``).
* **Compile time** — :mod:`repro.analysis.audits` checks REAL compiled
  programs (sharding, donation aliases, collective budgets, engine-stats
  schema) on top of the HLO walker in :mod:`repro.analysis.hlo`, and
  :mod:`repro.analysis.guard` makes the sweep compile-budget structural
  (``Session.sweep`` runs under :func:`compile_guard`).
"""
from repro.analysis.audits import (
    AuditFailure, audit_collectives, audit_donation, audit_engine_stats,
    audit_sharding)
from repro.analysis.guard import (
    CompileBudgetExceeded, compile_guard, step_signature, sweep_max_builds)
from repro.analysis.hlo import analyze, donation_aliases, parse_module

__all__ = [
    "AuditFailure", "CompileBudgetExceeded",
    "analyze", "donation_aliases", "parse_module",
    "audit_collectives", "audit_donation", "audit_engine_stats",
    "audit_sharding",
    "compile_guard", "step_signature", "sweep_max_builds",
]
