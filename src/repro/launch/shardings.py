"""Parameter / activation sharding rules, by dimension-size matching.

Rather than brittle path-name matching, each leaf's PartitionSpec is
derived from its SHAPE against the architecture config:

  * the last dim (searching right-to-left) whose size matches a
    "model-parallel candidate" (experts, vocab, d_ff, d_expert, d_inner,
    heads, kv-heads, head_dim) AND divides evenly by the model-axis size
    is sharded over ``model``;
  * for the f32 master params / server-optimizer state (role="master"),
    the first remaining dim matching d_model that divides by the data-axis
    product is sharded over the data axes (ZeRO-style — every assigned
    arch has d_model divisible by 32);
  * for G-stacked per-client tensors (role="client"), the LEADING client
    dim is sharded over the data axes (each client group holds only its
    own replica) and d_model dims stay unsharded.

Evenly-divisible dims are strictly preferred; uneven (padded) sharding is
never chosen implicitly.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes as _data_axes, num_client_groups


# global sharding options (hillclimb knobs; see EXPERIMENTS.md §Perf)
#   attn_shard: "even"         — only evenly-divisible dims are sharded
#               "heads_padded" — head-count dims are sharded FIRST, with
#                                GSPMD padding when uneven (e.g. 56 -> 64);
#                                avoids the Dh-contraction score psums
_OPTS = {"attn_shard": "even"}


def set_sharding_options(**kw):
    _OPTS.update(kw)


def _model_candidates(cfg) -> list:
    cand = []
    if getattr(cfg, "n_experts", 0):
        cand.append(cfg.n_experts + getattr(cfg, "expert_pad", 0))
        cand.append(cfg.n_experts)
    if _OPTS["attn_shard"] == "heads_padded":
        cand += [cfg.n_heads, cfg.n_kv_heads]
    cand.append(cfg.vocab)
    if cfg.d_ff:
        cand += [cfg.d_ff, 2 * cfg.d_ff]
    if getattr(cfg, "d_expert", 0):
        cand.append(cfg.d_expert)
    # ssm / xlstm inner dims
    if getattr(cfg, "ssm_state", 0) or cfg.family in ("ssm", "hybrid"):
        di = 2 * cfg.d_model
        cand += [di, 2 * di]  # d_inner, mlstm up-proj
        if cfg.family == "hybrid":
            from repro.models.mamba2 import conv_channels, d_inner as dih
            cand.append(conv_channels(cfg))
            cand.append(2 * dih(cfg) + 2 * cfg.ssm_state + (dih(cfg) // cfg.ssm_head_dim))
    if cfg.family == "ssm":
        from repro.models.xlstm import slstm_ff
        cand.append(slstm_ff(cfg))
    cand += [cfg.n_heads, cfg.n_kv_heads, cfg.head_dim]
    # dedupe preserving priority order
    seen, out = set(), []
    for c in cand:
        if c and c not in seen:
            seen.add(c)
            out.append(c)
    return out


def leaf_spec(shape, cfg, mesh, role: str = "master", skip_leading: int = 0):
    """PartitionSpec for one leaf of the given shape."""
    model_size = mesh.shape["model"]
    daxes = _data_axes(mesh)
    data_size = int(np.prod([mesh.shape[a] for a in daxes]))
    cand = _model_candidates(cfg)

    spec = [None] * len(shape)
    if role == "client_all_axes" and len(shape) > 0:
        # pure-DP placement (§Perf iteration 5): the client dim spans
        # data AND model axes; tensor dims stay replicated -> zero TP
        # collectives inside the local phase (right for small archs)
        spec[0] = tuple(daxes) + ("model",)
        return P(*spec)
    if role == "client" and len(shape) > 0:
        spec[0] = daxes if len(daxes) > 1 else daxes[0]
        skip_leading = max(skip_leading, 1)

    model_dim = None
    if _OPTS["attn_shard"] == "heads_padded":
        # candidate-priority search; head-count dims shard with GSPMD
        # padding when uneven (56 heads -> pad 64): avoids Dh-contraction
        # score psums at <=14% padded-FLOP cost (EXPERIMENTS.md §Perf)
        uneven_ok = {cfg.n_heads, cfg.n_kv_heads}
        for c in cand:
            for i in range(len(shape) - 1, skip_leading - 1, -1):
                if spec[i] is not None or shape[i] != c:
                    continue
                if shape[i] % model_size == 0 or (c in uneven_ok and shape[i] > 1):
                    model_dim = i
                    break
            if model_dim is not None:
                break
    else:
        # baseline: right-to-left, first evenly-divisible candidate match
        for i in range(len(shape) - 1, skip_leading - 1, -1):
            if spec[i] is None and shape[i] % model_size == 0:
                for c in cand:
                    if shape[i] == c:
                        model_dim = i
                        break
                if model_dim is not None:
                    break
    if model_dim is not None:
        spec[model_dim] = "model"

    # role="serve": model-parallel only (single bf16 replica, no ZeRO)
    # ZeRO data dim for master-role tensors
    if role == "master":
        for i in range(skip_leading, len(shape)):
            if spec[i] is None and shape[i] == cfg.d_model and shape[i] % data_size == 0:
                spec[i] = daxes if len(daxes) > 1 else daxes[0]
                break
    return P(*spec)


def tree_shardings(tree, cfg, mesh, role: str = "master", skip_leading: int = 0):
    """NamedSharding pytree matching ``tree`` (of arrays or SDS)."""
    return jax.tree_util.tree_map(
        lambda l: NamedSharding(
            mesh, leaf_spec(l.shape, cfg, mesh, role, skip_leading)
        ),
        tree,
    )


def batch_spec(mesh, extra_dims: int = 1):
    """Global-batch tensors: batch dim over all data axes."""
    daxes = _data_axes(mesh)
    return P(daxes if len(daxes) > 1 else daxes[0], *([None] * extra_dims))


def cache_shardings(cache_tree, cfg, mesh, batch_size: int):
    """KV/SSM caches — the batch dim (identified by size) goes over the
    data axes when evenly divisible; one head/state/channel dim goes over
    ``model`` when even.  batch=1 (long_500k) stays replicated over data."""
    model_size = mesh.shape["model"]
    daxes = _data_axes(mesh)
    d_ax = daxes if len(daxes) > 1 else daxes[0]
    data_size = int(np.prod([mesh.shape[a] for a in daxes]))

    cand = [cfg.n_kv_heads, cfg.n_heads, cfg.head_dim]
    if cfg.family in ("ssm", "hybrid"):
        from repro.models.mamba2 import conv_channels, d_inner, n_heads_ssm
        cand = [n_heads_ssm(cfg), conv_channels(cfg), d_inner(cfg),
                2 * cfg.d_model] + cand
    if cfg.family == "ssm":
        from repro.models.xlstm import d_inner as xdi, mlstm_heads
        cand = [mlstm_heads(cfg), xdi(cfg) // mlstm_heads(cfg)] + cand

    def spec_for(l):
        shape = l.shape
        spec = [None] * len(shape)
        # batch dim: prefer dim 1 (convention: [stack, B, ...]), else first
        # match; only shard when evenly divisible by the data-axis product
        batch_dim = None
        if batch_size % data_size == 0:
            if len(shape) > 1 and shape[1] == batch_size:
                batch_dim = 1
            else:
                for i, s in enumerate(shape):
                    if s == batch_size:
                        batch_dim = i
                        break
        if batch_dim is not None:
            spec[batch_dim] = d_ax
        # model dim: search from the right
        for i in range(len(shape) - 1, (batch_dim if batch_dim is not None else 0), -1):
            if spec[i] is None and shape[i] % model_size == 0 and shape[i] in cand:
                spec[i] = "model"
                break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map(spec_for, cache_tree)
