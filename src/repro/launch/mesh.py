"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the real single CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (2 pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def largest_divisor(n: int, k: int) -> int:
    """Largest divisor of ``n`` that is <= ``k`` (always >= 1)."""
    k = max(1, min(k, n))
    while n % k:
        k -= 1
    return k


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (examples / integration tests).

    Requested axis sizes are clamped to DIVISORS of the device count
    (largest divisor <= the request), never just ``min``-clamped: e.g.
    ``data=4`` on 6 devices used to build a ``(4, 1)`` mesh — invalid on
    jax versions that require the product to cover the device list, and
    silently stranding two devices on versions that truncate — and
    ``data=0`` divided by zero.  Now ``data=4`` on 6 devices gives
    ``(3, ...)`` and the model axis is clamped to a divisor of what
    remains, so ``data * model`` always divides the device count.
    """
    n = len(jax.devices())
    data = largest_divisor(n, data)
    model = largest_divisor(n // data, model)
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[: data * model])


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"


def num_client_groups(mesh) -> int:
    """The FL client-group axis is pod x data."""
    import numpy as np
    return int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))
