"""Training launcher — the two faces of the framework behind one CLI.

Simulation mode (the paper's experiment):
    PYTHONPATH=src python -m repro.launch.train sim \
        --strategy fedasync --alpha 0.4 --sigma 1.0 --rounds 40 \
        --ckpt-dir results/ckpt_sim

Distributed SPMD mode (fl_train_step on a host-device mesh; the same
program the dry-run lowers for the production meshes):
    PYTHONPATH=src python -m repro.launch.train spmd \
        --arch smollm-360m --devices 8 --data-axis 4 --steps 100 \
        --reduce d_model=256,n_layers=4,vocab=2048
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def run_sim(args):
    from repro.checkpoint import checkpoint as ckpt
    from repro.core.testbed import TestbedConfig, run_experiment
    from repro.data.synthetic_ser import SERDataConfig

    cfg = TestbedConfig(
        use_dp=args.sigma > 0, sigma=args.sigma, batch_size=args.batch,
        data=SERDataConfig(n_total=args.n_total), seed=args.seed,
    )
    kw = {}
    if args.strategy != "fedavg":
        kw.update(alpha=args.alpha, max_updates=args.max_updates)
    params, log = run_experiment(
        args.strategy, cfg, rounds=args.rounds, eval_every=args.eval_every,
        target_acc=args.target_acc, **kw)
    print(f"[train:sim] {args.strategy}: acc={log.global_acc[-1]:.3f} "
          f"virtual_time={log.times[-1]:.0f}s "
          f"updates={log.update_counts}")
    fr = log.fairness()
    eps = {k: round(v[-1], 2) for k, v in log.eps_trajectory.items() if v}
    print(f"[train:sim] eps={eps} disparity={fr['privacy_disparity']:.1f}x "
          f"jain={fr['jain_participation']:.2f}")
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, len(log.times), params,
                  meta={"strategy": args.strategy, "sigma": args.sigma,
                        "acc": log.global_acc[-1]})
        print(f"[train:sim] checkpoint -> {args.ckpt_dir}")
    if args.log_json:
        with open(args.log_json, "w") as f:
            json.dump({"acc": log.global_acc, "times": log.times,
                       "eps": {k: v for k, v in log.eps_trajectory.items()},
                       "updates": log.update_counts}, f, default=float)
    return 0


def run_spmd(args):
    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={args.devices}")
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.checkpoint import checkpoint as ckpt
    from repro.configs import get_config
    from repro.core.dp import DPConfig
    from repro.core.fl_step import (
        FLStepConfig, make_fl_train_step, make_server_optimizer)
    from repro.data.tokens import TokenDataConfig, make_batches
    from repro.models import layers as Lyr
    from repro.models.base import get_family
    from repro.launch.shardings import batch_spec, leaf_spec, tree_shardings

    cfg = get_config(args.arch)
    if args.reduce:
        overrides = {}
        for kv in args.reduce.split(","):
            k, v = kv.split("=")
            overrides[k] = int(v) if v.isdigit() else v
        cfg = cfg.replace(param_dtype="float32", **overrides)
    fam = get_family(cfg.family)

    G = args.data_axis
    mesh = jax.make_mesh((G, args.devices // G), ("data", "model"))
    Lyr.set_mesh_context(mesh, None, "model")  # no batch constraints (§Perf)

    fl = FLStepConfig(
        num_clients=G, n_local=args.n_local, n_micro=args.n_micro,
        local_lr=args.local_lr, server_lr=args.server_lr,
        dp=DPConfig(clip_norm=args.clip, noise_multiplier=args.sigma,
                    granularity="per_microbatch"),
        compute_dtype=cfg.param_dtype,
    )
    key = jax.random.PRNGKey(args.seed)
    params = fam.init_params(key, cfg)
    stacked_sds = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct((G,) + l.shape, l.dtype), params)
    client_sh = tree_shardings(stacked_sds, cfg, mesh, role="client")
    master_sh = tree_shardings(params, cfg, mesh, role="master")
    step = make_fl_train_step(
        lambda p, b: fam.loss(p, b, cfg), fl,
        client_shardings=client_sh, master_shardings=master_sh)
    sopt = make_server_optimizer(fl)
    opt_state = sopt.init(params)
    osh = jax.tree_util.tree_map(
        lambda l: NamedSharding(mesh, P() if l.ndim == 0
                                else leaf_spec(l.shape, cfg, mesh, "master")),
        opt_state)
    repl = NamedSharding(mesh, P())
    B = G * args.n_local * args.n_micro * args.per_micro
    bsp = {k: NamedSharding(mesh, batch_spec(mesh, 1))
           for k in ("tokens", "labels")}
    data = make_batches(
        TokenDataConfig(vocab=cfg.vocab, seq_len=args.seq, seed=args.seed),
        num_batches=args.steps, batch_size=B)
    weights = jnp.ones((G,)) / G
    eval_loss = jax.jit(lambda p, b: fam.loss(p, b, cfg))

    with jax.sharding.set_mesh(mesh):
        params = jax.device_put(params, master_sh)
        opt_state = jax.device_put(opt_state, osh)
        jitted = jax.jit(step, in_shardings=(master_sh, osh, bsp, repl, repl),
                         donate_argnums=(0, 1))
        for i, batch in enumerate(data):
            jb = jax.device_put(
                {k: jnp.asarray(v) for k, v in batch.items()}, bsp)
            if i % args.log_every == 0:
                print(f"[train:spmd] round {i:5d} "
                      f"loss {float(eval_loss(params, jb)):.4f}", flush=True)
            params, opt_state, _ = jitted(
                params, opt_state, jb, weights, jax.random.PRNGKey(i))
        final = float(eval_loss(params, jb))
    print(f"[train:spmd] final loss {final:.4f}")
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, params,
                  meta={"arch": args.arch, "loss": final})
        print(f"[train:spmd] checkpoint -> {args.ckpt_dir}")
    return 0


def main():
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="mode", required=True)

    sim = sub.add_parser("sim", help="paper testbed simulation")
    sim.add_argument("--strategy", default="fedasync",
                     choices=("fedavg", "fedasync", "fedasync_nostale",
                              "fedbuff", "adaptive_async"))
    sim.add_argument("--alpha", type=float, default=0.4)
    sim.add_argument("--sigma", type=float, default=1.0)
    sim.add_argument("--rounds", type=int, default=40)
    sim.add_argument("--max-updates", type=int, default=300)
    sim.add_argument("--batch", type=int, default=64)
    sim.add_argument("--n-total", type=int, default=2940)
    sim.add_argument("--eval-every", type=int, default=5)
    sim.add_argument("--target-acc", type=float, default=None)
    sim.add_argument("--seed", type=int, default=0)
    sim.add_argument("--ckpt-dir", default="")
    sim.add_argument("--log-json", default="")

    spmd = sub.add_parser("spmd", help="distributed fl_train_step")
    spmd.add_argument("--arch", default="smollm-360m")
    spmd.add_argument("--devices", type=int, default=8)
    spmd.add_argument("--data-axis", type=int, default=4)
    spmd.add_argument("--steps", type=int, default=100)
    spmd.add_argument("--seq", type=int, default=128)
    spmd.add_argument("--n-local", type=int, default=1)
    spmd.add_argument("--n-micro", type=int, default=4)
    spmd.add_argument("--per-micro", type=int, default=2)
    spmd.add_argument("--local-lr", type=float, default=0.5)
    spmd.add_argument("--server-lr", type=float, default=5e-3)
    spmd.add_argument("--clip", type=float, default=10.0)
    spmd.add_argument("--sigma", type=float, default=0.02)
    spmd.add_argument("--seed", type=int, default=0)
    spmd.add_argument("--log-every", type=int, default=25)
    spmd.add_argument("--ckpt-dir", default="")
    spmd.add_argument("--reduce", default="",
                      help="comma list of cfg overrides, e.g. d_model=256")

    args = ap.parse_args()
    sys.exit(run_sim(args) if args.mode == "sim" else run_spmd(args))


if __name__ == "__main__":
    main()
