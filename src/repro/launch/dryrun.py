import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination with ShapeDtypeStruct inputs (no allocation), and extract the
roofline terms from the compiled artifact.

  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b \
      --shape train_4k --mesh single            # one combination
  PYTHONPATH=src python -m repro.launch.dryrun --all  # the full matrix

Results land in results/dryrun/<arch>__<shape>__<mesh>.json:
  memory_analysis (bytes/device), cost_analysis (flops/bytes),
  per-collective byte totals parsed from the optimized HLO.

NOTE the two lines at the very top: they MUST run before any jax import
(jax locks the device count at first init), and must NOT leak into
conftest/pyproject — smoke tests and benches see the real single device.
"""
import argparse
import gzip
import json
import re
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo import analyze as hlo_analyze
from repro.configs import ARCH_IDS, LONG_CONTEXT_ARCHS, get_config
from repro.core.dp import DPConfig
from repro.core.fl_step import FLStepConfig, make_fl_train_step, make_server_optimizer
from repro.models import layers as Lyr
from repro.models.base import INPUT_SHAPES, get_family, input_specs
from repro.launch.mesh import (
    data_axes, make_production_mesh, num_client_groups,
)
from repro.launch.shardings import (
    batch_spec, cache_shardings, tree_shardings,
)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../results/dryrun")

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _op_output_bytes(line: str) -> int:
    """Sum the byte size of the op's output shape(s) (before the '=')."""
    lhs = line.split("=", 1)[0]
    total = 0
    for m in _SHAPE_RE.finditer(lhs):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind byte totals (output-shape bytes, summed over
    static op occurrences; ops inside while loops are counted once per
    occurrence — a conservative per-step lower bound)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        for kind in _COLLECTIVES:
            # match op name: "%all-gather.3 = ..." or "all-gather(" form
            if re.search(rf"= {kind}", s) or re.search(rf"= \S*{kind}", s):
                if f"{kind}-start" in s or f"{kind}-done" in s:
                    # async pair: count the start only
                    if f"{kind}-done" in s:
                        break
                out[kind] += _op_output_bytes(s)
                counts[kind] += 1
                break
    return {"bytes": out, "counts": counts}


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def _sds_tree(f, *args):
    return jax.eval_shape(f, *args)


def build_train(arch_id, cfg, shape, mesh, n_micro=4,
                dp_granularity="per_microbatch", client_placement="tp"):
    fam = get_family(cfg.family)
    G = num_client_groups(mesh)
    if client_placement == "dp":
        # pure-DP: one client per chip; no tensor parallelism inside the
        # local phase (params replicated per client) — §Perf iteration 5
        import numpy as _np
        G = int(_np.prod(list(mesh.shape.values())))
    fl = FLStepConfig(
        num_clients=G, n_local=1, n_micro=n_micro,
        dp=DPConfig(clip_norm=1.0, noise_multiplier=1.0,
                    granularity=dp_granularity),
    )
    loss_fn = lambda p, b: fam.loss(p, b, cfg)
    server_opt = make_server_optimizer(fl)

    key = jax.random.PRNGKey(0)
    params_sds = _sds_tree(lambda: fam.init_params(key, cfg))
    stacked_sds = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct((G,) + l.shape, l.dtype), params_sds)
    crole = "client_all_axes" if client_placement == "dp" else "client"
    client_sh = tree_shardings(stacked_sds, cfg, mesh, role=crole)
    master_sh_c = tree_shardings(params_sds, cfg, mesh, role="master")
    step = make_fl_train_step(loss_fn, fl, client_shardings=client_sh,
                              master_shardings=master_sh_c)
    # master params are f32
    params_sds = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32), params_sds)
    opt_sds = _sds_tree(lambda: server_opt.init(params_sds))
    batch_sds = input_specs(cfg, shape)
    weights_sds = jax.ShapeDtypeStruct((G,), jnp.float32)
    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)

    master_sh = tree_shardings(params_sds, cfg, mesh, role="master")
    opt_sh = _sds_tree(lambda: server_opt.init(params_sds))
    opt_sh = jax.tree_util.tree_map(
        lambda l: (NamedSharding(mesh, P()) if l.ndim == 0
                   else tree_shardings(l, cfg, mesh, role="master")),
        opt_sds,
        is_leaf=lambda l: hasattr(l, "shape"),
    )
    if client_placement == "dp":
        from repro.launch.mesh import data_axes as _da
        all_ax = tuple(_da(mesh)) + ("model",)
        bspec = {k: NamedSharding(mesh, P(all_ax, *([None] * (v.ndim - 1))))
                 for k, v in batch_sds.items()}
    else:
        bspec = {k: NamedSharding(mesh, batch_spec(mesh, v.ndim - 1))
                 for k, v in batch_sds.items()}
    repl = NamedSharding(mesh, P())

    jitted = jax.jit(
        step,
        in_shardings=(master_sh, opt_sh, bspec, repl, repl),
        donate_argnums=(0, 1),
    )
    return jitted, (params_sds, opt_sds, batch_sds, weights_sds, key_sds)


def build_prefill(arch_id, cfg, shape, mesh):
    fam = get_family(cfg.family)
    B, S = shape.global_batch, shape.seq_len

    def step(params, batch):
        cache = fam.init_cache(cfg, B, S)
        return fam.prefill(params, batch, cfg, cache)

    key = jax.random.PRNGKey(0)
    params_sds = _sds_tree(lambda: fam.init_params(key, cfg))
    batch_sds = input_specs(cfg, shape)
    params_sh = tree_shardings(params_sds, cfg, mesh, role="serve")
    bspec = {k: NamedSharding(mesh, batch_spec(mesh, v.ndim - 1))
             for k, v in batch_sds.items()}
    jitted = jax.jit(step, in_shardings=(params_sh, bspec))
    return jitted, (params_sds, batch_sds)


def build_decode(arch_id, cfg, shape, mesh):
    fam = get_family(cfg.family)
    B, S = shape.global_batch, shape.seq_len

    def step(params, cache, token, pos):
        return fam.decode_step(params, cache, token, pos, cfg)

    key = jax.random.PRNGKey(0)
    params_sds = _sds_tree(lambda: fam.init_params(key, cfg))
    cache_sds = _sds_tree(lambda: fam.init_cache(cfg, B, S))
    specs = input_specs(cfg, shape)
    token_sds, pos_sds = specs["token"], specs["pos"]

    params_sh = tree_shardings(params_sds, cfg, mesh, role="serve")
    cache_sh = cache_shardings(cache_sds, cfg, mesh, batch_size=B)
    daxes = data_axes(mesh)
    d_ax = daxes if len(daxes) > 1 else daxes[0]
    data_size = int(np.prod([mesh.shape[a] for a in daxes]))
    tok_sh = NamedSharding(mesh, P(d_ax, None) if B % data_size == 0 else P())
    pos_sh = NamedSharding(mesh, P(d_ax) if B % data_size == 0 else P())
    jitted = jax.jit(
        step, in_shardings=(params_sh, cache_sh, tok_sh, pos_sh),
        donate_argnums=(1,),
    )
    return jitted, (params_sds, cache_sds, token_sds, pos_sds)


def applicable(arch_id: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch_id in LONG_CONTEXT_ARCHS
    return True


def run_one(arch_id: str, shape_name: str, mesh_kind: str,
            n_micro: int = 4, tag: str = "", attn_shard: str = "even",
            expert_pad: int = 0, remat_policy: str = "",
            train_batch_constraints: bool = True,
            client_placement: str = "tp") -> dict:
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    cfg = get_config(arch_id, long_variant=(shape_name == "long_500k"))
    # hillclimb knobs (EXPERIMENTS.md §Perf)
    from repro.launch.shardings import set_sharding_options
    from repro.models.transformer import set_remat_policy
    set_sharding_options(attn_shard=attn_shard)
    set_remat_policy(remat_policy or None)
    if expert_pad and cfg.n_experts:
        cfg = cfg.replace(expert_pad=expert_pad)
    d_ax = (data_axes(mesh) if len(data_axes(mesh)) > 1
            else data_axes(mesh)[0])
    if shape.kind == "train" and not train_batch_constraints:
        # inside the per-client vmap a batch constraint pins the tiny
        # per-client microbatch dim to the data axes -> forced replication
        d_ax = None
    # NOTE (§Perf iteration 2b, REFUTED): constraining q/k/v on the
    # head_dim axis to "match" Dh-sharded params makes the scores einsum
    # contract over a sharded dim -> an all-reduce of the (B,H,S,S)
    # score tensor per layer (457s collective on deepseek).  The padded
    # HEADS constraint is strictly better; keep it unconditionally.
    Lyr.set_mesh_context(
        mesh, d_ax, "model",
        attn_axis=("none" if (shape.kind == "train"
                              and client_placement == "dp") else "heads"))
    t0 = time.time()
    try:
        with jax.sharding.set_mesh(mesh):
            if shape.kind == "train":
                jitted, sds = build_train(arch_id, cfg, shape, mesh,
                                          n_micro=n_micro,
                                          client_placement=client_placement)
            elif shape.kind == "prefill":
                jitted, sds = build_prefill(arch_id, cfg, shape, mesh)
            else:
                jitted, sds = build_decode(arch_id, cfg, shape, mesh)
            lowered = jitted.lower(*sds)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
            # trip-count-aware walker (collectives + dot flops); the naive
            # line parser stays as a cross-check column
            try:
                walk = hlo_analyze(hlo)
            except Exception as e:  # noqa: BLE001
                walk = {"error": str(e)[:500]}
            coll = collective_bytes(hlo)
            hlo_dir = os.path.join(RESULTS_DIR, "../hlo")
            os.makedirs(hlo_dir, exist_ok=True)
            with gzip.open(os.path.join(
                    hlo_dir, f"{arch_id}__{shape_name}__{mesh_kind}"
                    f"{'__' + tag if tag else ''}.txt.gz"), "wt") as f:
                f.write(hlo)
        result = {
            "arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
            "status": "ok", "tag": tag,
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "memory": {
                "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_size_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None),
            },
            "cost": {k: cost.get(k) for k in
                     ("flops", "bytes accessed", "bytes accessed0{}",
                      "bytes accessed1{}", "bytes accessedout{}")
                     if k in cost} if isinstance(cost, dict) else str(cost),
            "collectives": coll,
            "walk": walk,
            "hlo_ops": len(hlo.splitlines()),
        }
    except Exception as e:  # noqa: BLE001 — record the failure and move on
        result = {
            "arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
            "status": "error", "tag": tag,
            "error": f"{type(e).__name__}: {str(e)[:2000]}",
            "elapsed_s": round(time.time() - t0, 1),
        }
    finally:
        Lyr.clear_mesh_context()
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(INPUT_SHAPES))
    ap.add_argument("--mesh", choices=("single", "multipod"), default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--resume", action="store_true",
                    help="skip combos with an existing ok result")
    ap.add_argument("--n-micro", type=int, default=4)
    ap.add_argument("--tag", default="")
    ap.add_argument("--attn-shard", choices=("even", "heads_padded"),
                    default="even")
    ap.add_argument("--expert-pad", type=int, default=0)
    ap.add_argument("--remat-policy", choices=("", "dots"), default="")
    ap.add_argument("--no-batch-constraints", action="store_true")
    ap.add_argument("--client-placement", choices=("tp", "dp"), default="tp")
    args = ap.parse_args()

    os.makedirs(RESULTS_DIR, exist_ok=True)
    combos = []
    if args.all:
        for arch in ARCH_IDS:
            for shp in INPUT_SHAPES:
                if applicable(arch, shp):
                    combos.append((arch, shp, "single"))
                    combos.append((arch, shp, "multipod"))
    else:
        combos = [(args.arch, args.shape, args.mesh)]

    for arch, shp, mk in combos:
        suffix = f"__{args.tag}" if args.tag else ""
        fn = os.path.join(RESULTS_DIR, f"{arch}__{shp}__{mk}{suffix}.json")
        if args.resume and os.path.exists(fn):
            with open(fn) as f:
                prev = json.load(f)
            if prev.get("status") == "ok" and "walk" in prev:
                print(f"[dryrun] {arch} x {shp} x {mk}: skip (done)", flush=True)
                continue
        res = run_one(arch, shp, mk, n_micro=args.n_micro, tag=args.tag,
                      attn_shard=args.attn_shard, expert_pad=args.expert_pad,
                      remat_policy=args.remat_policy,
                      train_batch_constraints=not args.no_batch_constraints,
                      client_placement=args.client_placement)
        with open(fn, "w") as f:
            json.dump(res, f, indent=1)
        status = res["status"]
        extra = (f"compile={res.get('compile_s')}s" if status == "ok"
                 else res["error"][:120])
        print(f"[dryrun] {arch} x {shp} x {mk}: {status} {extra}", flush=True)
    sys.exit(0)


if __name__ == "__main__":
    main()
