"""Declarative experiment API: ``ExperimentSpec`` + ``Session``.

The paper's contribution is a GRID of scenarios — {FedAvg, FedAsync ±
staleness, FedBuff, AdaptiveAsync} x sigma {0.5, 1, 1.5, 2} x device
tiers.  This package is the experiment-facing entry point for driving
that grid: a fully-typed, serializable spec per scenario, and a session
that keeps the expensive state (datasets, device arenas, compiled steps)
warm across the runs of a sweep.

    from repro.api import ExperimentSpec, RunBudget, Session, StrategySpec
    from repro.core.testbed import TestbedConfig

    spec = ExperimentSpec(
        testbed=TestbedConfig(sigma=1.0, batch_size=64),
        strategy=StrategySpec("fedasync", alpha=0.4),
        run=RunBudget(max_updates=300, eval_every=5, target_acc=0.75),
    )
    session = Session()
    params, log = session.run(spec)
    result = session.sweep(spec, axes={"testbed.sigma": [0.5, 1, 1.5, 2]})
    for row in result.table():
        print(row)

Migration from the legacy keyword frontends (which remain as thin shims
with their exact historical signatures and bit-identical results):

    old (still works)                       new
    ------------------------------------   ---------------------------------
    run_experiment("fedasync", cfg,         Session().run(ExperimentSpec(
        max_updates=300, alpha=0.4,             testbed=cfg,
        staleness_aware=True,                   strategy=StrategySpec(
        eval_every=5,                               "fedasync", alpha=0.4,
        engine_cfg=ec, mesh=m)                      staleness_aware=True),
                                                run=RunBudget(
                                                    max_updates=300,
                                                    eval_every=5),
                                                engine=replace(ec, mesh=m)))
    run_experiment("fedavg", cfg,           ... strategy=StrategySpec(
        rounds=60)                              "fedavg"),
                                                run=RunBudget(rounds=60) ...
    engine="legacy"                         ExperimentSpec(...,
                                                backend="legacy")
    for s in sigmas:                        Session().sweep(spec, axes={
        run_experiment(..., TestbedConfig(      "testbed.sigma": sigmas})
            sigma=s, ...))                  # datasets + compiled steps warm

Strategy params are validated at SPEC construction against the registry
in :mod:`repro.core.aggregation` (unknown names/params raise listing the
valid options), the eval cadence is normalized once in ``RunBudget``, and
``spec.to_dict()`` / ``ExperimentSpec.from_dict`` round-trip the whole
configuration through JSON for benchmark/CI provenance.  The model family
behind a testbed is pluggable through ``TestbedConfig.workload`` and
:func:`repro.api.workloads.register_workload`.
"""
from repro.api.session import Session, SweepResult
from repro.api.spec import (
    ExperimentSpec,
    RunBudget,
    StrategySpec,
    replace_path,
)
from repro.api.workloads import (
    Workload,
    get_workload,
    register_workload,
    workload_names,
)

__all__ = [
    "ExperimentSpec",
    "RunBudget",
    "Session",
    "StrategySpec",
    "SweepResult",
    "Workload",
    "get_workload",
    "register_workload",
    "replace_path",
    "workload_names",
]
