"""Typed, frozen, serializable experiment specifications.

An :class:`ExperimentSpec` is the complete description of one FL run —
the paper's every figure/table point is one spec:

    spec = ExperimentSpec(
        testbed=TestbedConfig(sigma=1.0, batch_size=64),
        strategy=StrategySpec("fedasync", alpha=0.4),
        run=RunBudget(max_updates=300, eval_every=5, target_acc=0.75),
        engine=EngineConfig(staleness_window=45.0),
    )

Specs are value objects: frozen, hashable, comparable, and round-trip
through plain JSON-able dicts (``spec.to_dict()`` /
``ExperimentSpec.from_dict(d)``), so benchmark provenance rows, CI
artifacts and ``BENCH_engine.json`` can carry the FULL configuration a
number was produced under and reproduce it from the JSON alone.

Validation happens at CONSTRUCTION, not deep inside a run:
:class:`StrategySpec` checks its name and params against the registry in
:mod:`repro.core.aggregation` (unknown names/params raise immediately,
listing the valid options), and :class:`RunBudget` normalizes the eval
cadence once (``eval_every=0`` used to reach the fedavg loop raw and
die on ``rnd % 0``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

from repro.core.aggregation import make_strategy, validate_strategy_params
from repro.core.dp import DPConfig
from repro.core.faults import FaultModel
from repro.core.fl_step import FLStepConfig
from repro.core.screening import ScreeningConfig
from repro.core.testbed import TestbedConfig
from repro.data.synthetic_ser import SERDataConfig
from repro.engine import EngineConfig, StoreConfig
from repro.models.ser_cnn import SERConfig


@dataclass(frozen=True, init=False)
class StrategySpec:
    """Registry-validated aggregation strategy: ``name`` plus keyword
    params, canonicalized to a sorted tuple so specs hash/compare by
    value.  Replaces the old ``strategy_name``/``alpha``/
    ``staleness_aware``/``**strategy_kw`` keyword pile — a typo'd or
    misplaced param now fails HERE with the valid options listed, not
    deep inside ``make_strategy`` mid-run."""

    name: str
    params: tuple                   # sorted ((key, value), ...)

    def __init__(self, name: str, /, **params):
        name = validate_strategy_params(name, params)
        object.__setattr__(self, "name", name)
        object.__setattr__(self, "params", tuple(sorted(params.items())))

    @property
    def kwargs(self) -> dict:
        return dict(self.params)

    def make(self):
        """Instantiate the aggregation strategy (fresh per run — FedBuff
        carries cross-update buffer state)."""
        return make_strategy(self.name, **self.kwargs)

    def replace(self, **params) -> "StrategySpec":
        """A copy with the given params overriding the current ones."""
        merged = {**self.kwargs, **params}
        return StrategySpec(self.name, **merged)


@dataclass(frozen=True)
class RunBudget:
    """How long a run goes and how often it evaluates.  FedAvg consumes
    ``rounds``; the async strategies consume ``max_updates``/``max_time``
    — carrying both keeps one spec valid across a strategy sweep."""

    rounds: int = 60               # fedavg barrier rounds
    max_updates: int = 300         # async: total merged updates
    max_time: Optional[float] = None   # async: virtual-seconds cap
    eval_every: int = 1            # rounds (fedavg) / updates (async)
    target_acc: Optional[float] = None  # early-stop accuracy

    def __post_init__(self):
        if self.rounds < 0 or self.max_updates < 0:
            raise ValueError(
                f"rounds/max_updates must be >= 0: "
                f"{self.rounds}/{self.max_updates}")
        # THE eval-cadence validation point: every frontend routes its
        # eval_every through here, so a 0 can no longer reach the fedavg
        # loop raw and die on `rnd % 0` (it used to — only the async
        # path clamped)
        object.__setattr__(self, "eval_every", max(1, int(self.eval_every)))


@dataclass(frozen=True)
class ExperimentSpec:
    """One fully-specified experiment.  ``backend`` selects the execution
    path ("cohort" — the batched engine, default — or "legacy", the
    per-client reference loop); everything else is typed sub-config."""

    testbed: TestbedConfig = TestbedConfig()
    strategy: StrategySpec = StrategySpec("fedasync", alpha=0.4)
    run: RunBudget = RunBudget()
    engine: EngineConfig = EngineConfig()
    backend: str = "cohort"

    def __post_init__(self):
        if self.backend not in ("cohort", "legacy"):
            raise ValueError(
                f"backend must be 'cohort' or 'legacy': {self.backend!r}")
        for fld, typ in (("testbed", TestbedConfig),
                         ("strategy", StrategySpec),
                         ("run", RunBudget),
                         ("engine", EngineConfig)):
            if not isinstance(getattr(self, fld), typ):
                raise TypeError(
                    f"ExperimentSpec.{fld} must be a {typ.__name__}: "
                    f"{getattr(self, fld)!r}")

    # -- legacy-frontend bridge -------------------------------------------
    @classmethod
    def from_legacy(cls, strategy_name: str, cfg: TestbedConfig = None,
                    rounds: int = 60, max_updates: int = 300,
                    alpha: float = 0.4, staleness_aware: bool = True,
                    target_acc: Optional[float] = None, eval_every: int = 1,
                    engine: str = "cohort", engine_cfg: EngineConfig = None,
                    mesh=None, **strategy_kw) -> "ExperimentSpec":
        """Build a spec from ``run_experiment``'s historical signature
        (the shim calls this, so old call sites keep working verbatim)."""
        name = str(strategy_name).lower()
        if name == "fedavg":
            kw = dict(strategy_kw)
        else:
            kw = dict(alpha=alpha)
            if name == "fedasync":
                kw["staleness_aware"] = staleness_aware
            kw.update(strategy_kw)
            if name == "fedasync_nostale":
                # historical tolerance: the old frontend silently dropped
                # this (the variant pins it False)
                kw.pop("staleness_aware", None)
        ecfg = engine_cfg or EngineConfig()
        if mesh is not None and ecfg.mesh is None:
            ecfg = dataclasses.replace(ecfg, mesh=mesh)
        return cls(
            testbed=cfg if cfg is not None else TestbedConfig(),
            strategy=StrategySpec(name, **kw),
            run=RunBudget(rounds=rounds, max_updates=max_updates,
                          eval_every=eval_every, target_acc=target_acc),
            engine=ecfg,
            backend=engine,
        )

    # -- serialization -----------------------------------------------------
    def to_dict(self) -> dict:
        """Plain JSON-able dict (nested configs become tagged dicts; a
        live mesh is recorded by its axis sizes — see :func:`encode`)."""
        return encode(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ExperimentSpec":
        spec = decode(d)
        if not isinstance(spec, cls):
            raise ValueError(f"not an ExperimentSpec dict: {d!r}")
        return spec


def replace_path(spec: ExperimentSpec, path: str, value) -> ExperimentSpec:
    """Functional update through a dotted field path — the sweep-axis
    primitive: ``replace_path(spec, "testbed.sigma", 2.0)`` or a whole
    sub-config at once (``replace_path(spec, "strategy", StrategySpec(
    "fedbuff", alpha=0.4))``)."""
    head, _, rest = path.partition(".")
    if not hasattr(spec, head):
        raise ValueError(
            f"{type(spec).__name__} has no field {head!r} (path {path!r})")
    if not rest:
        return dataclasses.replace(spec, **{head: value})
    return dataclasses.replace(
        spec, **{head: replace_path(getattr(spec, head), rest, value)})


# ---------------------------------------------------------------------------
# dict codec: tagged encoding for the closed set of spec-carrying types
# ---------------------------------------------------------------------------

_SPEC_TYPES = {cls.__name__: cls for cls in (
    ExperimentSpec, StrategySpec, RunBudget, TestbedConfig, SERDataConfig,
    SERConfig, EngineConfig, StoreConfig, DPConfig, FLStepConfig, FaultModel,
    ScreeningConfig)}


def _is_mesh(obj) -> bool:
    return (obj.__class__.__module__.startswith("jax")
            and obj.__class__.__name__ == "Mesh")


def encode(obj):
    """Recursively encode a spec object to JSON-able data.  Dataclasses
    from the closed spec-type set become ``{"__type__": name, ...}``
    dicts; a jax mesh is recorded as its axis sizes (``{"__mesh__":
    {"data": 8, "model": 1}}`` — :func:`decode` rebuilds a host mesh of
    that shape over the CURRENT process's devices, the only meaningful
    cross-process reading of a device handle)."""
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if isinstance(obj, StrategySpec):
        return {"__type__": "StrategySpec", "name": obj.name,
                "params": {k: encode(v) for k, v in obj.params}}
    if dataclasses.is_dataclass(obj) and type(obj).__name__ in _SPEC_TYPES:
        out = {"__type__": type(obj).__name__}
        for f in dataclasses.fields(obj):
            out[f.name] = encode(getattr(obj, f.name))
        return out
    if _is_mesh(obj):
        return {"__mesh__": {str(a): int(s)
                             for a, s in dict(obj.shape).items()}}
    if isinstance(obj, (tuple, list)):
        return [encode(v) for v in obj]
    if hasattr(obj, "item") and getattr(obj, "shape", None) == ():
        return obj.item()          # numpy/jax scalar
    raise ValueError(
        f"cannot encode {type(obj).__name__!r} into a spec dict: {obj!r}")


def decode(d):
    """Inverse of :func:`encode`."""
    if isinstance(d, list):
        return [decode(v) for v in d]
    if not isinstance(d, dict):
        return d
    if "__mesh__" in d:
        from repro.launch.mesh import make_host_mesh
        axes = d["__mesh__"]
        extra = set(axes) - {"data", "model"}
        if extra:
            raise ValueError(
                f"cannot rebuild a mesh with axes {sorted(extra)} — only "
                "host meshes over (data, model) round-trip")
        return make_host_mesh(data=int(axes.get("data", 1)),
                              model=int(axes.get("model", 1)))
    tag = d.get("__type__")
    if tag is None:
        return {k: decode(v) for k, v in d.items()}
    if tag == "StrategySpec":
        return StrategySpec(d["name"], **{k: decode(v)
                                          for k, v in d["params"].items()})
    cls = _SPEC_TYPES.get(tag)
    if cls is None:
        raise ValueError(f"unknown spec type tag {tag!r}")
    if tag == "TestbedConfig" and "use_kernel" in d and "dp_path" not in d:
        # pre-dp_path specs carried a `use_kernel` bool; map it onto the
        # selector so archived JSON keeps meaning what it meant
        d = dict(d)
        d["dp_path"] = "pallas" if d.pop("use_kernel") else "jnp"
    kw = {}
    for f in dataclasses.fields(cls):
        if f.name in d:
            v = decode(d[f.name])
            # JSON turns tuples into lists; restore for tuple-typed fields
            if isinstance(v, list) and isinstance(
                    getattr(cls, f.name, None), tuple):
                v = tuple(v)
            kw[f.name] = v
    return cls(**kw)
