"""Workload registry: the pluggable model family behind a testbed.

``build_testbed`` used to hard-wire the paper's SER CNN; a *workload*
packages everything the testbed needs from a model family —

  * ``init(key, model_cfg) -> params``
  * ``loss(model_cfg) -> loss_fn(params, example) -> scalar``
    (per-example, vmap-able: both the legacy per-client loop and the
    compiled cohort step drive it through ``jax.vmap(jax.grad(...))``)
  * ``accuracy(model_cfg) -> accuracy_fn(params, data) -> scalar``

— keyed by ``TestbedConfig.workload``.  Registering a new name is all it
takes for arch-zoo models (``repro.configs``) or ad-hoc baselines to run
through the same ``ExperimentSpec``/``Session`` machinery as the paper's
CNN.

The loss and accuracy closures are memoized per (workload, model_cfg):
jitted steps key on the loss OBJECT (static arg / engine step cache), so
handing every testbed built from the same config the same closure is what
lets repeated runs and sweeps reuse compiled programs instead of
re-tracing per ``build_testbed`` call.

Built-ins:

  * ``"ser_cnn"``    — the paper's 1D-CNN speech-emotion model
    (:mod:`repro.models.ser_cnn`); the default.
  * ``"ser_linear"`` — multinomial logistic regression over the same
    mel-spectrogram patches: a deliberately tiny convex baseline whose
    per-step cost is negligible, used by the sweep smoke tests/CI to
    exercise the Session machinery without paying CNN compiles.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache, partial
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Workload:
    name: str
    init: Callable                 # (key, model_cfg) -> params
    loss: Callable                 # (model_cfg) -> loss_fn
    accuracy: Callable             # (model_cfg) -> accuracy_fn

    # memoized closure accessors — identity-stable per model_cfg
    def shared_loss(self, model_cfg):
        return _shared_closure(self.name, "loss", model_cfg)

    def shared_accuracy(self, model_cfg):
        return _shared_closure(self.name, "accuracy", model_cfg)


_REGISTRY: dict = {}


@lru_cache(maxsize=None)
def _shared_closure(workload: str, kind: str, model_cfg):
    wl = get_workload(workload)
    return (wl.loss if kind == "loss" else wl.accuracy)(model_cfg)


def register_workload(name: str, *, init: Callable, loss: Callable,
                      accuracy: Callable, overwrite: bool = False) -> Workload:
    """Register a model family under ``name`` (see module docstring for
    the three factory signatures).  Re-registering an existing name is an
    error unless ``overwrite=True`` — silent replacement would detach the
    memoized closures live testbeds already hold."""
    if name in _REGISTRY and not overwrite:
        raise ValueError(
            f"workload {name!r} is already registered "
            "(pass overwrite=True to replace it)")
    wl = Workload(name=name, init=init, loss=loss, accuracy=accuracy)
    _REGISTRY[name] = wl
    if overwrite:
        _shared_closure.cache_clear()
    return wl


def get_workload(name: str) -> Workload:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown workload: {name!r} "
            f"(registered: {', '.join(sorted(_REGISTRY))})") from None


def workload_names() -> tuple:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# built-in: the paper's SER CNN
# ---------------------------------------------------------------------------

def _register_builtins():
    from repro.models import ser_cnn

    register_workload(
        "ser_cnn",
        init=ser_cnn.init,
        loss=lambda cfg: partial(ser_cnn.loss_fn, cfg=cfg),
        accuracy=ser_cnn.make_accuracy_fn,
    )

    # tiny convex baseline over the same (time_frames, n_mels) patches
    def _linear_init(key, cfg):
        d = cfg.time_frames * cfg.n_mels
        scale = 1.0 / jnp.sqrt(d)
        return {
            "w": jax.random.uniform(key, (d, cfg.num_classes), jnp.float32,
                                    -scale, scale),
            "b": jnp.zeros((cfg.num_classes,), jnp.float32),
        }

    def _linear_logits(params, x):
        return x.reshape(-1) @ params["w"] + params["b"]

    def _linear_loss(cfg):
        def loss_fn(params, example):
            logits = _linear_logits(params, example["x"])
            return -jax.nn.log_softmax(logits)[example["y"]]
        return loss_fn

    def _linear_accuracy(cfg):
        @jax.jit
        def _acc(params, data):
            logits = jax.vmap(lambda x: _linear_logits(params, x))(data["x"])
            return jnp.mean(
                (jnp.argmax(logits, -1) == data["y"]).astype(jnp.float32))
        return _acc

    register_workload(
        "ser_linear",
        init=_linear_init,
        loss=_linear_loss,
        accuracy=_linear_accuracy,
    )


_register_builtins()
