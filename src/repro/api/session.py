"""The Session: long-lived execution state behind declarative experiments.

``run_experiment`` rebuilt the world per call: regenerate + repartition
the synthetic dataset, reconstruct every client, re-upload the device
dataset arena, rebuild the cohort runner.  A :class:`Session` owns all of
that as KEYED state and, between consecutive :class:`ExperimentSpec`\\ s,
rebuilds only what the spec diff actually invalidates:

    what changed             what is rebuilt          what stays warm
    ---------------------    ----------------------   -------------------
    nothing (re-run)         client state reset       everything
    strategy / run budget    client state reset       testbed, runner +
                                                      device arenas,
                                                      compiled steps
    testbed.sigma (DP)       clients (cheap), runner  dataset partitions,
                                                      compiled steps (the
                                                      noise scale is a
                                                      runtime arg of the
                                                      step — PR 5)
    testbed.data/partition   everything below the
                             step cache               compiled steps (per
                                                      step-config, global)

``session.sweep(spec, axes={...})`` runs the cartesian grid of a spec
with dotted-path axes —

    Session().sweep(spec, axes={"testbed.sigma": [0.5, 1.0, 1.5, 2.0],
                                "strategy": [StrategySpec("fedavg"),
                                             StrategySpec("fedasync",
                                                          alpha=0.4)]})

— ordering the points so consecutive runs share the longest cache prefix
(the LAST axis varies fastest), and returns a :class:`SweepResult`: the
per-scenario ``RunLog``\\ s plus a tidy comparison table feeding the
paper's efficiency/fairness/privacy figures.  Runs inside one session are
bit-identical to fresh-process runs — every client resets to its
construction-time RNG/clock/accountant chain between runs (asserted by
the session parity tests).
"""
from __future__ import annotations

import itertools
import time
from collections import Counter
from dataclasses import dataclass, field
from typing import Optional

from repro.api.spec import ExperimentSpec, StrategySpec, replace_path
from repro.core.testbed import (
    TestbedConfig, build_clients, build_partitions, partition_key)


def _axis_label(value) -> object:
    """Human-readable cell for a sweep-axis value."""
    if isinstance(value, StrategySpec):
        kw = ", ".join(f"{k}={v}" for k, v in value.params)
        return f"{value.name}({kw})" if kw else value.name
    if hasattr(value, "__dataclass_fields__"):
        return type(value).__name__
    return value


@dataclass
class SweepResult:
    """Outcome of :meth:`Session.sweep`: parallel lists over the grid
    points (``specs[i]`` produced ``logs[i]`` in ``wall_s[i]`` seconds;
    ``points[i]`` maps each axis path to the value it took)."""

    base: ExperimentSpec
    axes: dict
    points: list = field(default_factory=list)
    specs: list = field(default_factory=list)
    logs: list = field(default_factory=list)
    wall_s: list = field(default_factory=list)

    def __len__(self):
        return len(self.logs)

    def __iter__(self):
        return iter(zip(self.specs, self.logs))

    def table(self) -> list:
        """One row per scenario: the axis values plus the summary metrics
        the paper's figures are built from (efficiency: final acc /
        time-to-target; fairness: Jain + participation skew; privacy:
        max-eps + disparity)."""
        rows = []
        for point, spec, log, wall in zip(
                self.points, self.specs, self.logs, self.wall_s):
            fr = log.fairness()
            eps_final = [v[-1] for v in log.eps_trajectory.values() if v]
            target = spec.run.target_acc
            row = {
                "strategy": spec.strategy.name,
                "sigma": spec.testbed.sigma,
                "final_acc": (round(log.global_acc[-1], 4)
                              if log.global_acc else None),
                "time_to_target_s": (log.time_to_accuracy(target)
                                     if target is not None else None),
                "updates": sum(log.update_counts.values()),
                "jain_participation": round(fr["jain_participation"], 4),
                "accuracy_gap": round(fr["accuracy_gap"], 4),
                "privacy_disparity": round(fr["privacy_disparity"], 2),
                "max_eps": (round(max(eps_final), 3) if eps_final else 0.0),
                "wall_s": round(wall, 3),
            }
            # axis columns LAST so they win any name collision: a
            # StrategySpec axis point must show "fedasync(alpha=0.2)",
            # not be clobbered down to the bare name shared by every row
            row.update({p: _axis_label(v) for p, v in point.items()})
            rows.append(row)
        return rows


class Session:
    """Owns testbed + engine state across runs (see module docstring).

    One live testbed and one live cohort runner at a time (CLIENT-state
    arenas are big — a sweep should not accumulate one per scenario);
    dataset partitions are kept per distinct data-config so alternating
    testbeds still skip regeneration, and the uploaded device dataset
    arena (:class:`repro.engine.DataArena`) is kept per
    ``(partition_key, mesh)`` — it is immutable and keyed separately from
    client state, so a sweep whose axes only touch client-state config
    (sigma, strategy, store) hands the SAME device buffers to every
    rebuilt runner and skips the re-upload entirely.  The compiled-step
    cache itself is process-global (:mod:`repro.engine.cohort_step`) —
    the session adds the layers above it."""

    def __init__(self):
        self._partitions = {}          # partition_key -> (splits, pooled)
        self._data_arenas = {}         # (partition_key, mesh) -> DataArena
        self._testbed_cfg: Optional[TestbedConfig] = None
        self._clients = None
        self._params0 = None
        self._acc_fn = None
        self._pooled = None
        self._runner = None
        self._runner_key = None
        self.events = Counter()        # cache telemetry (tests/bench)

    # -- cache layers ------------------------------------------------------
    def _materialize(self, tb: TestbedConfig):
        """Clients + initial params + eval closures for ``tb``, reusing
        cached partitions / the live testbed where the config allows."""
        if self._testbed_cfg == tb:
            for c in self._clients:
                c.reset()
            self.events["testbed_reuses"] += 1
            return
        pk = partition_key(tb)
        cached = self._partitions.get(pk)
        if cached is None:
            cached = build_partitions(tb)
            self._partitions[pk] = cached
            self.events["partition_builds"] += 1
        else:
            self.events["partition_reuses"] += 1
        splits, pooled = cached
        from repro.api.workloads import get_workload
        import jax
        wl = get_workload(tb.workload)
        self._clients = build_clients(tb, splits)
        self._params0 = wl.init(jax.random.PRNGKey(tb.seed), tb.model)
        self._acc_fn = wl.shared_accuracy(tb.model)
        self._pooled = pooled
        self._testbed_cfg = tb
        self._runner = None            # built over the OLD clients
        self._runner_key = None
        self.events["testbed_builds"] += 1

    def _get_runner(self, tb: TestbedConfig, engine_cfg):
        from repro.engine import CohortRunner
        key = (tb, engine_cfg)
        if self._runner_key == key:
            self._runner.reset_for_run()
            self.events["runner_reuses"] += 1
        else:
            arena_key = (partition_key(tb), engine_cfg.mesh)
            arena = self._data_arenas.get(arena_key)
            self._runner = CohortRunner(self._clients, engine_cfg,
                                        data_arena=arena)
            if getattr(self._runner, "use_arena", False):
                if arena is None:
                    self._data_arenas[arena_key] = self._runner.data_arena
                    self.events["data_arena_builds"] += 1
                else:
                    self.events["data_arena_reuses"] += 1
            self._runner_key = key
            self.events["runner_builds"] += 1
        return self._runner

    # -- execution ---------------------------------------------------------
    def run(self, spec: ExperimentSpec, *,
            checkpoint_every: Optional[int] = None,
            checkpoint_dir: Optional[str] = None,
            keep_last: int = 3,
            crash_after_saves: Optional[int] = None,
            resume_from: Optional[str] = None) -> tuple:
        """Execute one spec; returns ``(final_params, RunLog)`` — exactly
        what ``run_experiment`` returns (the legacy frontends are shims
        over this).

        ``spec.testbed.faults`` (a :class:`repro.core.faults.FaultModel`)
        flows to either backend's loop.  ``checkpoint_every=N`` with
        ``checkpoint_dir`` snapshots the run every N rounds (fedavg) /
        merged updates (async) into the durable store, keeping the newest
        ``keep_last`` steps; ``resume_from=<dir>`` resumes an aborted run
        from its latest checkpoint bit-identically.  ``crash_after_saves``
        raises :class:`repro.engine.resilience.SimulatedCrash` after that
        many snapshots (deterministic mid-flight aborts for tests).
        Checkpoint/resume is cohort-engine only."""
        if not isinstance(spec, ExperimentSpec):
            raise TypeError(f"Session.run takes an ExperimentSpec: {spec!r}")
        tb, b = spec.testbed, spec.run
        checkpoint = None
        if checkpoint_every is not None:
            if checkpoint_dir is None:
                raise ValueError(
                    "checkpoint_every requires checkpoint_dir (where the "
                    "step_*.npz snapshots go)")
            from repro.engine.resilience import CheckpointPolicy
            checkpoint = CheckpointPolicy(
                directory=checkpoint_dir, every=checkpoint_every,
                keep_last=keep_last, crash_after_saves=crash_after_saves)
        self._materialize(tb)
        clients, params0 = self._clients, self._params0
        acc_fn, pooled = self._acc_fn, self._pooled
        self.events["runs"] += 1
        # route by the strategy's sync/async nature, not its name: the
        # robust fedavg variants (fedavg_trimmed) are barrier loops too
        strategy = spec.strategy.make()
        if spec.backend == "legacy":
            if spec.engine.mesh is not None:
                raise ValueError("mesh execution requires backend='cohort'")
            if checkpoint is not None or resume_from is not None:
                raise ValueError(
                    "checkpoint/resume requires backend='cohort' — the "
                    "legacy reference loop has no snapshot support")
            from repro.core.server import run_async, run_fedavg
            if not strategy.is_async:
                return run_fedavg(
                    clients, params0, acc_fn, pooled, rounds=b.rounds,
                    seed=tb.seed, eval_every=b.eval_every,
                    target_acc=b.target_acc, engine="legacy",
                    faults=tb.faults, strategy=strategy,
                    screening=tb.screening)
            return run_async(
                clients, params0, acc_fn, pooled, strategy,
                max_updates=b.max_updates, max_time=b.max_time, seed=tb.seed,
                eval_every=b.eval_every, target_acc=b.target_acc,
                engine="legacy", faults=tb.faults, screening=tb.screening)
        from repro.engine import run_async_engine, run_fedavg_engine
        runner = self._get_runner(tb, spec.engine)
        if not strategy.is_async:
            return run_fedavg_engine(
                clients, params0, acc_fn, pooled, rounds=b.rounds,
                seed=tb.seed, eval_every=b.eval_every,
                target_acc=b.target_acc, runner=runner, faults=tb.faults,
                checkpoint=checkpoint, resume_from=resume_from,
                strategy=strategy, screening=tb.screening)
        return run_async_engine(
            clients, params0, acc_fn, pooled, strategy,
            max_updates=b.max_updates, max_time=b.max_time, seed=tb.seed,
            eval_every=b.eval_every, target_acc=b.target_acc, runner=runner,
            faults=tb.faults, checkpoint=checkpoint, resume_from=resume_from,
            screening=tb.screening)

    def sweep(self, spec: ExperimentSpec, axes: dict) -> SweepResult:
        """Run the cartesian grid of ``spec`` with ``axes`` mapping dotted
        field paths to value lists (see module docstring).  Axis order is
        significant: the LAST axis varies fastest, so putting the
        expensive-to-change axis first (e.g. ``testbed.data``) maximizes
        consecutive-run reuse."""
        if not axes:
            raise ValueError("sweep needs at least one axis")
        paths = list(axes)
        values = [list(axes[p]) for p in paths]
        for p, vs in zip(paths, values):
            if not vs:
                raise ValueError(f"sweep axis {p!r} has no values")
            replace_path(spec, p, vs[0])   # fail fast on bad paths/values
        result = SweepResult(base=spec, axes={p: list(v) for p, v in
                                              zip(paths, values)})
        grid = []
        for combo in itertools.product(*values):
            point = dict(zip(paths, combo))
            s = spec
            for p, v in point.items():
                s = replace_path(s, p, v)
            grid.append((point, s))
        # the whole grid is materialized up front so the sweep runs under
        # a compile budget derived from it: at most one cohort-step build
        # per DISTINCT compile signature (sigma is a runtime arg, so a
        # sigma grid contributes ONE).  A recompile leaking per point —
        # the regression PR 5/6 guarded with after-the-fact assertions —
        # now fails structurally, inside the sweep itself.
        from repro.analysis.guard import compile_guard, sweep_max_builds
        budget = sweep_max_builds(s for _, s in grid)
        with compile_guard(budget, label="Session.sweep") as guard:
            for point, s in grid:
                t0 = time.perf_counter()
                _, log = self.run(s)
                result.points.append(point)
                result.specs.append(s)
                result.logs.append(log)
                result.wall_s.append(time.perf_counter() - t0)
        self.events["sweep_step_builds"] += guard.delta
        return result

    def stats(self) -> dict:
        """Cache telemetry: builds vs reuses per layer (partitions /
        testbed / runner) plus the run count."""
        return dict(self.events)
