"""phi-3-vision family: the phi3-mini language backbone consuming stubbed
vision embeddings.

Per the assignment carve-out, the ViT/CLIP encoder + projector is a STUB:
``input_specs`` provides pre-projected patch embeddings
(B, n_patches, d_model).  The LM backbone — attention, RoPE, SwiGLU MLP,
the cross-modal token interleave (patch prefix + text) — is fully
implemented and reuses the dense transformer trunk.

Sequence layout: [patch_0 .. patch_{P-1}, tok_0 .. tok_{S-1}], positions
are global (0..P+S-1); training loss is computed on text positions only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T
from repro.models.base import Family, register_family

init_params = T.init_params  # backbone only; patches arrive pre-projected


def _prefix_embed(params, batch, cfg):
    tokens, patches = batch["tokens"], batch["patches"]
    B, S = tokens.shape
    P = patches.shape[1]
    tok_emb = L.embed(tokens, params["embedding"])
    x = jnp.concatenate([patches.astype(tok_emb.dtype), tok_emb], axis=1)
    positions = jnp.broadcast_to(jnp.arange(P + S), (B, P + S))
    return L.shard(x, "batch", None, None), positions, P


def forward_hidden(params, batch, cfg):
    x, positions, P = _prefix_embed(params, batch, cfg)
    h = T.trunk(params, x, cfg, positions)
    return h, P


def logits_fn(params, batch, cfg):
    h, P = forward_hidden(params, batch, cfg)
    return L.unembed(h[:, P:], T._lm_matrix(params))


def loss(params, batch, cfg, *, loss_chunk: int = 512):
    """CE over TEXT positions only (patch positions carry no labels)."""
    h, P = forward_hidden(params, batch, cfg)
    h = h[:, P:]
    labels = batch["labels"]
    B, S, D = h.shape
    W = T._lm_matrix(params)
    chunk = min(loss_chunk, S)
    n_chunks = max(1, S // chunk)
    hc = h[:, : n_chunks * chunk].reshape(B, n_chunks, chunk, D).transpose(1, 0, 2, 3)
    lc = labels[:, : n_chunks * chunk].reshape(B, n_chunks, chunk).transpose(1, 0, 2)

    def chunk_loss(args):
        hx, lx = args
        logits = L.unembed(hx, W)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lx[..., None], axis=-1)[..., 0]
        return jnp.mean(logz - gold)

    return jnp.mean(jax.lax.map(jax.checkpoint(chunk_loss), (hc, lc)))


def init_cache(cfg, batch_size, max_len, dtype=None):
    # cache must also hold the patch-prefix KV
    return T.init_cache(cfg, batch_size, max_len + cfg.n_patches, dtype)


def prefill(params, batch, cfg, cache):
    x, positions, P = _prefix_embed(params, batch, cfg)
    windows = T.layer_windows(cfg)
    S_tot = x.shape[1]

    def body(carry, scanned):
        x = carry
        blk, window = scanned
        h = L.rms_norm(x, blk["ln_attn"], cfg.norm_eps)
        _, k, v = L._qkv(h, blk["attn"], cfg, positions)
        attn_out = L.attention(
            h, blk["attn"], cfg, positions, window=window, causal=True,
            kv_override=(k, v, positions),
        )
        x = x + attn_out
        h2 = L.rms_norm(x, blk["ln_mlp"], cfg.norm_eps)
        return x + L.mlp(h2, blk["mlp"], cfg.mlp_variant), (k, v)

    x, (ks, vs) = jax.lax.scan(jax.checkpoint(body), x, (params["blocks"], windows))
    cache = {
        "k": jax.lax.dynamic_update_slice(cache["k"], ks, (0, 0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], vs, (0, 0, 0, 0, 0)),
    }
    h = L.rms_norm(x, params["ln_final"], cfg.norm_eps)
    logits = L.unembed(h[:, -1:], T._lm_matrix(params))
    return logits[:, 0], cache


def decode_step(params, cache, token, pos, cfg):
    """pos is the GLOBAL position (patch prefix included by the caller)."""
    return T.decode_step(params, cache, token, pos, cfg)


register_family(
    Family(
        name="vlm",
        init_params=init_params,
        forward=logits_fn,
        loss=loss,
        init_cache=init_cache,
        prefill=prefill,
        decode_step=decode_step,
    )
)
