"""Shared model building blocks: norms, RoPE, GQA attention (sliding
window + logit softcap), gated MLPs, embeddings, and chunked
(memory-efficient) attention used for long sequences.

Conventions
-----------
* Parameter layouts keep head / ff dims explicit so sharding rules can
  target them:  wq (D, H, Dh), wk/wv (D, Hkv, Dh), wo (H, Dh, D),
  mlp w_gate/w_up (D, F), w_down (F, D), embedding (V, D).
* Activations are bf16 (or the config's param dtype); normalization and
  softmax accumulate in f32.
* ``shard(x, ...)`` applies a with_sharding_constraint only when a mesh
  context has been installed by the launcher (see set_mesh_rules) — smoke
  tests on a single CPU device run the identical code without constraints.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# activation-sharding context (installed by repro.launch.shardings)
# ---------------------------------------------------------------------------

_MESH_CTX = {"mesh": None, "data_axes": None, "model_axis": None,
             "attn_axis": "heads"}


def set_mesh_context(mesh, data_axes, model_axis, attn_axis="heads"):
    _MESH_CTX.update(mesh=mesh, data_axes=data_axes, model_axis=model_axis,
                     attn_axis=attn_axis)


def clear_mesh_context():
    _MESH_CTX.update(mesh=None, data_axes=None, model_axis=None,
                     attn_axis="heads")


def shard(x, *logical):
    """Constrain activation sharding. ``logical`` entries: 'batch' (data
    axes), 'model' (tensor axis), None (replicated).

    NOTE: when ``data_axes`` is None in the mesh context (the FL train
    step), 'batch' resolves to None.  Inside the per-client vmap the
    visible batch dim is the tiny per-client microbatch — constraining it
    to the data axes is unsatisfiable and forces XLA to REPLICATE the
    whole activation across data, dragging the client dim with it
    (EXPERIMENTS.md §Perf iteration 1).  The client dim's sharding comes
    from input/param propagation instead.
    """
    mesh = _MESH_CTX["mesh"]
    if mesh is None:
        return x
    spec = []
    for ax in logical:
        if ax == "batch":
            spec.append(_MESH_CTX["data_axes"])   # may be None
        elif ax == "model":
            spec.append(_MESH_CTX["model_axis"])
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, P(*spec))
    )


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in or shape[0]
    scale = (1.0 / fan_in) ** 0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = x32.var(-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale + bias).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(d_head: int, theta: float):
    return theta ** (-jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, Dh); positions: (..., S)."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)                       # (Dh/2,)
    angles = positions[..., :, None].astype(jnp.float32)[..., None, :] * freqs
    # angles: (..., S, 1, Dh/2) broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def init_attention(key, cfg, dtype=None):
    dtype = dtype or cfg.pdtype
    D, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, (D, H, Dh), dtype, fan_in=D),
        "wk": dense_init(k2, (D, Hkv, Dh), dtype, fan_in=D),
        "wv": dense_init(k3, (D, Hkv, Dh), dtype, fan_in=D),
        "wo": dense_init(k4, (H, Dh, D), dtype, fan_in=H * Dh),
    }


def _softcap(x, cap: float):
    return jnp.tanh(x / cap) * cap if cap > 0 else x


def _qkv(x, p, cfg, positions, rope=True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention(
    x,
    p,
    cfg,
    positions=None,
    *,
    window: int = 0,
    causal: bool = True,
    q_chunk: int = 1024,
    rope: bool = True,
    kv_override=None,          # (k, v, kv_positions) for cross-attention
):
    """Exact attention, q-chunked for memory (scan over query chunks).

    x: (B, S, D) -> (B, S, D).  ``window`` > 0 masks keys older than
    ``window`` positions (sliding-window attention).
    """
    B, S, D = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    if kv_override is None:
        q, k, v = _qkv(x, p, cfg, positions, rope=rope)
        kv_pos = positions
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        if rope:
            q = apply_rope(q, positions, cfg.rope_theta)
        k, v, kv_pos = kv_override
    # constrain q/k/v on the axis that MATCHES the param sharding rule
    # (heads when evenly divisible, else head_dim) — a mismatched
    # constraint forces a reshard collective per layer per direction
    # (EXPERIMENTS.md §Perf iteration 2b)
    if _MESH_CTX["attn_axis"] == "dh":
        q = shard(q, "batch", None, None, "model")
        k = shard(k, "batch", None, None, "model")
        v = shard(v, "batch", None, None, "model")
    elif _MESH_CTX["attn_axis"] == "heads":
        q = shard(q, "batch", None, "model", None)
        k = shard(k, "batch", None, "model", None)
        v = shard(v, "batch", None, "model", None)

    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    rep = H // Hkv
    scale = Dh ** -0.5

    def attend_chunk(q_c, qpos_c):
        # q_c: (B, Cq, H, Dh)
        kk = jnp.repeat(k, rep, axis=2) if rep > 1 else k
        vv = jnp.repeat(v, rep, axis=2) if rep > 1 else v
        logits = jnp.einsum("bqhk,bshk->bhqs", q_c, kk).astype(jnp.float32) * scale
        logits = _softcap(logits, cfg.attn_logit_softcap)
        dq = qpos_c[:, :, None]           # (B, Cq, 1)
        dk = kv_pos[:, None, :]           # (B, 1, Skv)
        # branchless window: 0 (or any non-positive) means "no window";
        # window may be a traced per-layer value (gemma2 local/global scan).
        eff_w = jnp.where(jnp.asarray(window) > 0, jnp.asarray(window), 1 << 30)
        mask = dk > dq - eff_w
        if causal:
            mask = mask & (dk <= dq)
        logits = jnp.where(mask[:, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        return jnp.einsum("bhqs,bshk->bqhk", probs, vv)

    # pick the largest divisor of S that fits the target chunk (S=1500
    # whisper frames, S=4672 vlm patch+text, ... are not 1024-divisible)
    eff_chunk = q_chunk
    while eff_chunk > 1 and S % eff_chunk:
        eff_chunk -= 1
    if S <= q_chunk or eff_chunk < 64:
        o = attend_chunk(q, positions)
    else:
        n_chunks = S // eff_chunk
        qr = q.reshape(B, n_chunks, eff_chunk, H, Dh).transpose(1, 0, 2, 3, 4)
        pr = positions.reshape(B, n_chunks, eff_chunk).transpose(1, 0, 2)
        o = jax.lax.map(lambda qc: attend_chunk(qc[0], qc[1]), (qr, pr))
        o = o.transpose(1, 0, 2, 3, 4).reshape(B, S, H, Dh)

    if _MESH_CTX["attn_axis"] == "dh":
        o = shard(o, "batch", None, None, "model")
    elif _MESH_CTX["attn_axis"] == "heads":
        o = shard(o, "batch", None, "model", None)
    return jnp.einsum("bqhk,hkd->bqd", o, p["wo"])


def decode_attention(q, p, cache_k, cache_v, pos, cfg, *, window: int = 0):
    """One-token decode: q (B, 1, H, Dh) against cache (B, L, Hkv, Dh).

    ``pos`` (B,) is the current position; cache positions are 0..L-1 and
    entries >= pos (or outside the window) are masked.
    """
    B, L = cache_k.shape[0], cache_k.shape[1]
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    rep = H // Hkv
    kk = jnp.repeat(cache_k, rep, axis=2) if rep > 1 else cache_k
    vv = jnp.repeat(cache_v, rep, axis=2) if rep > 1 else cache_v
    logits = jnp.einsum("bqhk,bshk->bhqs", q, kk).astype(jnp.float32) * (Dh ** -0.5)
    logits = _softcap(logits, cfg.attn_logit_softcap)
    kv_idx = jnp.arange(L)[None, None, None, :]               # (1,1,1,L)
    cur = pos[:, None, None, None]
    eff_w = jnp.where(jnp.asarray(window) > 0, jnp.asarray(window), 1 << 30)
    mask = (kv_idx <= cur) & (kv_idx > cur - eff_w)
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhqs,bshk->bqhk", probs, vv)
    return jnp.einsum("bqhk,hkd->bqd", o, p["wo"])


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d_model, d_ff, dtype, variant="swiglu"):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(k1, (d_model, d_ff), dtype),
        "w_down": dense_init(k2, (d_ff, d_model), dtype, fan_in=d_ff),
    }
    if variant in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(k3, (d_model, d_ff), dtype)
    return p


def mlp(x, p, variant="swiglu"):
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    if variant == "swiglu":
        gate = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
        h = gate * up
    elif variant == "geglu":
        gate = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
        h = gate * up
    else:
        h = jax.nn.gelu(up)
    h = shard(h, "batch", None, "model")
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------


def init_embedding(key, vocab, d_model, dtype):
    return dense_init(key, (vocab, d_model), dtype, fan_in=d_model)


def embed(tokens, emb):
    return jnp.take(emb, tokens, axis=0)


def unembed(x, emb_or_head, softcap: float = 0.0):
    logits = jnp.einsum("bsd,vd->bsv", x, emb_or_head).astype(jnp.float32)
    return _softcap(logits, softcap)


def cross_entropy_loss(logits, labels, vocab: int):
    """Mean next-token CE.  logits (B,S,V) f32, labels (B,S)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
