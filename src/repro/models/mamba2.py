"""Mamba2 (SSD — state-space duality) block, chunkwise-parallel in pure
JAX (the intra-chunk matmul is also available as a Pallas kernel, see
repro/kernels/ssd_scan/).

Follows the minimal SSD formulation (Dao & Gu, 2024):

    h_t = exp(a_t) * h_{t-1} + dt_t * B_t x_t^T        (per head)
    y_t = C_t h_t + D * x_t

with a_t = -exp(A_log) * dt_t (scalar per head), B/C shared across heads
(n_groups = 1), chunked into blocks of ``cfg.ssm_chunk``:
  * intra-chunk: quadratic attention-like term with decay mask L,
  * inter-chunk: a short lax.scan over per-chunk states (B, H, P, N).

Decode is the recurrent form on a persistent (B, H, P, N) state plus a
(width-1) causal-conv state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


def d_inner(cfg) -> int:
    return cfg.ssm_expand * cfg.d_model


def n_heads_ssm(cfg) -> int:
    return d_inner(cfg) // cfg.ssm_head_dim


def conv_channels(cfg) -> int:
    return d_inner(cfg) + 2 * cfg.ssm_state  # x ++ B ++ C (one group)


def init_mamba2(key, cfg):
    dtype = cfg.pdtype
    D = cfg.d_model
    di, H, N = d_inner(cfg), n_heads_ssm(cfg), cfg.ssm_state
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d_in_proj = 2 * di + 2 * N + H  # z, x, B, C, dt
    return {
        "in_proj": L.dense_init(k1, (D, d_in_proj), dtype, fan_in=D),
        "conv_w": L.dense_init(k2, (cfg.ssm_conv, conv_channels(cfg)), dtype,
                               fan_in=cfg.ssm_conv),
        "conv_b": jnp.zeros((conv_channels(cfg),), dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm": jnp.zeros((di,), dtype),
        "out_proj": L.dense_init(k3, (di, D), dtype, fan_in=di),
    }


def _segsum(a):
    """a: (..., Q) -> (..., Q, Q) with out[i,j] = sum_{j<k<=i} a_k (causal)."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, -1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x, a, Bm, Cm, chunk: int, init_state=None, use_kernel: bool = False):
    """Chunked SSD scan.

    x:  (b, s, h, p)   head inputs (already * dt)
    a:  (b, s, h)      log decay per step (<= 0)
    Bm: (b, s, n)      input projection (shared across heads)
    Cm: (b, s, n)      output projection
    Returns (y (b,s,h,p), final_state (b,h,p,n)).
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    c = s // chunk
    q = chunk
    xr = x.reshape(b, c, q, h, p)
    ar = a.reshape(b, c, q, h).transpose(0, 3, 1, 2)       # (b,h,c,q)
    Br = Bm.reshape(b, c, q, n)
    Cr = Cm.reshape(b, c, q, n)

    a_cs = jnp.cumsum(ar, axis=-1)                         # (b,h,c,q)

    if use_kernel:
        from repro.kernels.ssd_scan.ops import ssd_intra_chunk
        Y_diag = ssd_intra_chunk(xr, ar, Br, Cr)
    else:
        Lm = jnp.exp(_segsum(ar))                          # (b,h,c,q,k)
        scores = jnp.einsum("bcqn,bckn->bcqk", Cr, Br)     # (b,c,q,k)
        Y_diag = jnp.einsum("bcqk,bhcqk,bckhp->bcqhp", scores, Lm, xr)

    # states at chunk ends: sum_k exp(a_end - a_k) B_k x_k
    decay_states = jnp.exp(a_cs[..., -1:] - a_cs)          # (b,h,c,q)
    states = jnp.einsum("bckn,bhck,bckhp->bchpn", Br, decay_states, xr)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(a_cs[..., -1])                   # (b,h,c)
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)

    def scan_fn(hprev, inp):
        st, dec = inp                                      # (b,h,p,n), (b,h)
        hnew = hprev * dec[..., None, None] + st
        return hnew, hprev

    states_c = states.transpose(1, 0, 2, 3, 4).astype(jnp.float32)  # (c,b,h,p,n)
    decays_c = chunk_decay.transpose(2, 0, 1)              # (c,b,h)
    final, prev_states = jax.lax.scan(scan_fn, init_state, (states_c, decays_c))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)     # (b,c,h,p,n)

    # inter-chunk contribution
    state_decay = jnp.exp(a_cs)                            # (b,h,c,q)
    Y_off = jnp.einsum(
        "bcqn,bchpn,bhcq->bcqhp", Cr, prev_states.astype(x.dtype), state_decay
    )
    y = (Y_diag + Y_off).reshape(b, s, h, p)
    return y, final


def _causal_conv(xBC, w, bias):
    """Depthwise causal conv over time. xBC: (B, S, C), w: (K, C)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xBC.shape[1]] * w[i] for i in range(K))
    return out + bias


def mamba2_forward(x, p, cfg, state=None, use_kernel: bool = False):
    """Full mamba2 mixer on (B, S, D). Returns (out, (conv_state, ssm_state))."""
    Bsz, S, D = x.shape
    di, H, N, P = d_inner(cfg), n_heads_ssm(cfg), cfg.ssm_state, cfg.ssm_head_dim

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xs, Bm, Cm, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], -1)

    xBC_raw = jnp.concatenate([xs, Bm, Cm], -1)
    if state is not None:
        conv_in = jnp.concatenate([state[0], xBC_raw], axis=1)
        xBC = _causal_conv(conv_in, p["conv_w"], p["conv_b"])[:, state[0].shape[1]:]
    else:
        xBC = _causal_conv(xBC_raw, p["conv_w"], p["conv_b"])
    xBC = jax.nn.silu(xBC)
    xs, Bm, Cm = jnp.split(xBC, [di, di + N], -1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])     # (B,S,H)
    a = -jnp.exp(p["A_log"]) * dt                                   # (B,S,H)
    xh = xs.reshape(Bsz, S, H, P)
    x_dt = (xh.astype(jnp.float32) * dt[..., None]).astype(x.dtype)

    init_ssm = state[1] if state is not None else None
    y, ssm_final = ssd_chunked(
        x_dt, a, Bm, Cm, min(cfg.ssm_chunk, S), init_state=init_ssm,
        use_kernel=use_kernel,
    )
    y = y + xh * p["D"][None, None, :, None].astype(x.dtype)
    # SSD mixes f32 decay factors in; pin back to the residual dtype
    y = y.reshape(Bsz, S, di).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    K = cfg.ssm_conv
    if state is not None:
        tail = jnp.concatenate([state[0], xBC_raw], axis=1)[:, -(K - 1):]
    else:
        tail = xBC_raw[:, S - (K - 1):]
    return out, (tail, ssm_final)


def mamba2_decode(x, p, cfg, state):
    """One-step recurrent decode. x: (B, 1, D); state=(conv_state, ssm_state)."""
    conv_state, ssm_state = state
    Bsz = x.shape[0]
    di, H, N, P = d_inner(cfg), n_heads_ssm(cfg), cfg.ssm_state, cfg.ssm_head_dim

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xs, Bm, Cm, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], -1)
    xBC_new = jnp.concatenate([xs, Bm, Cm], -1)            # (B,1,C)

    conv_in = jnp.concatenate([conv_state, xBC_new], axis=1)   # (B,K,C)
    K = cfg.ssm_conv
    xBC = (conv_in * p["conv_w"][None]).sum(axis=1, keepdims=True) + p["conv_b"]
    xBC = jax.nn.silu(xBC)
    new_conv_state = conv_in[:, 1:]

    xs, Bm, Cm = jnp.split(xBC, [di, di + N], -1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])    # (B,1,H)
    a = jnp.exp(-jnp.exp(p["A_log"]) * dt)[:, 0]                   # (B,H)
    xs_h = xs.reshape(Bsz, H, P).astype(jnp.float32)
    x_dt = xs_h * dt[:, 0, :, None]                                # discretized

    # h <- a h + (dt x) B^T ; y = h C + D x
    new_ssm = ssm_state * a[..., None, None] + jnp.einsum(
        "bhp,bn->bhpn", x_dt, Bm[:, 0].astype(jnp.float32)
    )
    y = jnp.einsum("bhpn,bn->bhp", new_ssm, Cm[:, 0].astype(jnp.float32))
    y = y + xs_h * p["D"][None, :, None]
    y = y.reshape(Bsz, 1, di).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, (new_conv_state, new_ssm)


def init_decode_state(cfg, batch_size):
    di, H, N, P = d_inner(cfg), n_heads_ssm(cfg), cfg.ssm_state, cfg.ssm_head_dim
    conv = jnp.zeros((batch_size, cfg.ssm_conv - 1, conv_channels(cfg)), cfg.pdtype)
    ssm = jnp.zeros((batch_size, H, P, N), jnp.float32)
    return (conv, ssm)
