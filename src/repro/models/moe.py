"""Mixture-of-Experts decoder family (qwen2-moe / olmoe).

GShard/Switch-style capacity-based top-k routing, TPU-native:

  * tokens are reshaped into GROUPS of ~``group_size`` so the dispatch /
    combine einsums stay ~O(S * capacity * D) per group (<10 % of expert
    FLOPs) instead of the naive O(S^2) formulation;
  * experts live on the ``model`` mesh axis (expert parallelism) — the
    dispatch einsum lowers to an all-to-all over that axis;
  * qwen2-moe additionally has ``n_shared_experts`` always-on experts
    (fused into one dense MLP of width n_shared * d_expert) and top-4 of
    60 routed experts; olmoe is pure top-8 of 64;
  * load-balance auxiliary loss (Switch style): E * sum_e f_e * p_e.

Capacity overflow drops tokens (standard); the capacity factor is a
config knob (default 1.25).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T
from repro.models.base import Family, register_family


def init_moe_mlp(key, cfg):
    dtype = cfg.pdtype
    D, E, F = cfg.d_model, cfg.n_experts, cfg.d_expert or cfg.d_ff
    Ep = E + cfg.expert_pad      # padded expert count for even sharding
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "router": L.dense_init(k1, (D, E), jnp.float32),
        "w_gate": L.dense_init(k2, (Ep, D, F), dtype, fan_in=D),
        "w_up": L.dense_init(k3, (Ep, D, F), dtype, fan_in=D),
        "w_down": L.dense_init(k4, (Ep, F, D), dtype, fan_in=F),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.init_mlp(
            k5, D, cfg.n_shared_experts * F, dtype, variant="swiglu"
        )
    return p


def moe_mlp(x, p, cfg, *, group_size: int = 2048):
    """x: (B, S, D) -> (B, S, D); returns (out, aux_loss)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    xt = x.reshape(B * S, D)
    n_tok = B * S
    g = max(1, n_tok // group_size)
    s = n_tok // g                                   # tokens per group
    xg = xt.reshape(g, s, D)

    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)          # (g, s, E)
    top_p, top_e = jax.lax.top_k(probs, K)           # (g, s, K)
    top_p = top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)  # renorm

    # load-balance aux loss (Switch): E * mean_e( frac_tokens_e * mean_prob_e )
    frac = jnp.mean(
        jax.nn.one_hot(top_e[..., 0], E, dtype=jnp.float32), axis=(0, 1)
    )
    aux = E * jnp.sum(frac * jnp.mean(probs, axis=(0, 1)))

    cap = int(max(4, (s * K / E) * cfg.capacity_factor))

    # position of each (token, k) among the tokens routed to the same expert
    onehot = jax.nn.one_hot(top_e, E, dtype=jnp.int32)          # (g, s, K, E)
    flat = onehot.reshape(g, s * K, E)
    pos_in_e = jnp.cumsum(flat, axis=1) - flat                  # (g, s*K, E)
    pos = (pos_in_e * flat).sum(-1).reshape(g, s, K)            # (g, s, K)
    keep = pos < cap
    gate = top_p * keep.astype(top_p.dtype)

    # one-hot factors; an out-of-range index (dropped token) yields a zero row
    # (padded experts, if any, simply never receive tokens)
    oh_e = jax.nn.one_hot(top_e, E + cfg.expert_pad, dtype=x.dtype)    # (g,s,K,Ep)
    oh_c = jax.nn.one_hot(jnp.where(keep, pos, cap), cap, dtype=x.dtype)  # (g,s,K,cap)
    # contract over K without materializing the (g,s,K,E,cap) product
    disp = jnp.einsum("gske,gskc->gsec", oh_e, oh_c)                   # (g,s,E,cap)
    comb = jnp.einsum("gske,gskc->gsec", oh_e * gate.astype(x.dtype)[..., None], oh_c)

    xe = jnp.einsum("gsec,gsd->gecd", disp, xg)                 # (g, E, cap, D)
    xe = L.shard(xe, None, "model", None, None)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])) * jnp.einsum(
        "gecd,edf->gecf", xe, p["w_up"]
    )
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])           # (g, E, cap, D)
    ye = L.shard(ye, None, "model", None, None)
    out = jnp.einsum("gsec,gecd->gsd", comb, ye)

    out = out.reshape(B, S, D)
    if "shared" in p:
        out = out + L.mlp(x, p["shared"], "swiglu")
    return out, aux


def init_params(key, cfg):
    dtype = cfg.pdtype
    n = cfg.n_layers
    k0, k1, k2, k3 = jax.random.split(key, 4)

    def stack(init_fn, k):
        ks = jax.random.split(k, n)
        return jax.vmap(init_fn)(ks)

    params = {
        "embedding": L.init_embedding(k2, cfg.vocab, cfg.d_model, dtype),
        "blocks": {
            "attn": stack(lambda k: L.init_attention(k, cfg), k0),
            "moe": stack(lambda k: init_moe_mlp(k, cfg), k1),
            "ln_attn": jnp.zeros((n, cfg.d_model), dtype),
            "ln_mlp": jnp.zeros((n, cfg.d_model), dtype),
        },
        "ln_final": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_embedding(k3, cfg.vocab, cfg.d_model, dtype)
    return params


def _moe_block(x, blk, cfg, positions):
    h = L.rms_norm(x, blk["ln_attn"], cfg.norm_eps)
    x = x + L.attention(h, blk["attn"], cfg, positions, causal=True)
    h = L.rms_norm(x, blk["ln_mlp"], cfg.norm_eps)
    out, aux = moe_mlp(h, blk["moe"], cfg)
    return x + out, aux


def trunk(params, x, cfg, positions):
    def body(carry, blk):
        x, aux_sum = carry
        x, aux = _moe_block(x, blk, cfg, positions)
        return (x, aux_sum + aux), None

    (x, aux), _ = jax.lax.scan(
        jax.checkpoint(body), (x, jnp.zeros((), jnp.float32)), params["blocks"]
    )
    return L.rms_norm(x, params["ln_final"], cfg.norm_eps), aux / cfg.n_layers


def forward_hidden(params, batch, cfg):
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = L.shard(L.embed(tokens, params["embedding"]), "batch", None, None)
    return trunk(params, x, cfg, positions)


def logits_fn(params, batch, cfg):
    h, _aux = forward_hidden(params, batch, cfg)
    return L.unembed(h, params.get("lm_head", params["embedding"]))


def loss(params, batch, cfg, *, loss_chunk: int = 512):
    h, aux = forward_hidden(params, batch, cfg)
    labels = batch["labels"]
    B, S, D = h.shape
    W = params.get("lm_head", params["embedding"])
    n_chunks = max(1, S // loss_chunk)
    hc = h.reshape(B, n_chunks, S // n_chunks, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n_chunks, S // n_chunks).transpose(1, 0, 2)

    def chunk_loss(args):
        hx, lx = args
        logits = L.unembed(hx, W)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lx[..., None], axis=-1)[..., 0]
        return jnp.mean(logz - gold)

    ce = jnp.mean(jax.lax.map(jax.checkpoint(chunk_loss), (hc, lc)))
    return ce + cfg.router_aux_coef * aux


# ---------------------------------------------------------------------------
# decode path (same attention cache as dense; MoE ffn on 1-token groups)
# ---------------------------------------------------------------------------

init_cache = T.init_cache


def prefill(params, batch, cfg, cache):
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = L.shard(L.embed(tokens, params["embedding"]), "batch", None, None)

    def body(carry, blk):
        x, aux_sum = carry
        h = L.rms_norm(x, blk["ln_attn"], cfg.norm_eps)
        _, k, v = L._qkv(h, blk["attn"], cfg, positions)
        attn_out = L.attention(
            h, blk["attn"], cfg, positions, causal=True, kv_override=(k, v, positions)
        )
        x = x + attn_out
        h2 = L.rms_norm(x, blk["ln_mlp"], cfg.norm_eps)
        out, aux = moe_mlp(h2, blk["moe"], cfg)
        return (x + out, aux_sum + aux), (k, v)

    (x, _aux), (ks, vs) = jax.lax.scan(
        jax.checkpoint(body), (x, jnp.zeros((), jnp.float32)), params["blocks"]
    )
    cache = {
        "k": jax.lax.dynamic_update_slice(cache["k"], ks, (0, 0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], vs, (0, 0, 0, 0, 0)),
    }
    h = L.rms_norm(x, params["ln_final"], cfg.norm_eps)
    logits = L.unembed(h[:, -1:], params.get("lm_head", params["embedding"]))
    return logits[:, 0], cache


def decode_step(params, cache, token, pos, cfg):
    B = token.shape[0]
    x = L.embed(token, params["embedding"])
    positions = pos[:, None]
    batch_idx = jnp.arange(B)

    def body(x, scanned):
        blk, ck, cv = scanned
        h = L.rms_norm(x, blk["ln_attn"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, blk["attn"]["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, blk["attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, blk["attn"]["wv"])
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        ck = ck.at[batch_idx, pos].set(k[:, 0])
        cv = cv.at[batch_idx, pos].set(v[:, 0])
        x = x + L.decode_attention(q, blk["attn"], ck, cv, pos, cfg)
        h2 = L.rms_norm(x, blk["ln_mlp"], cfg.norm_eps)
        out, _aux = moe_mlp(h2, blk["moe"], cfg, group_size=B)
        return x + out, (ck, cv)

    x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], cache["k"], cache["v"]))
    cache = {"k": ks, "v": vs}
    h = L.rms_norm(x, params["ln_final"], cfg.norm_eps)
    logits = L.unembed(h, params.get("lm_head", params["embedding"]))
    return logits[:, 0], cache


register_family(
    Family(
        name="moe",
        init_params=init_params,
        forward=logits_fn,
        loss=loss,
        init_cache=init_cache,
        prefill=prefill,
        decode_step=decode_step,
    )
)
