"""The paper's SER model (Sec. 3.1): a lightweight 1D CNN over
mel-spectrogram features.

  * two 1D conv blocks (64 / 128 filters, kernel 5) + GroupNorm + ReLU,
  * 1D max-pool (2) after each block,
  * dropout 0.3 / 0.4 after the conv blocks, 0.5 after the FC layer,
  * FC-128 + output layer (4 emotions).

Input: (time_frames, n_mels) mel-spectrogram patch; n_mels acts as the
channel dim of the 1D convolution over time (standard light-SER layout).

Implemented as explicit pure functions over a param dict so that
``jax.vmap(jax.grad(...))`` per-example DP-SGD (core/dp.py) works without
any framework magic.  Dropout is exposed behind ``train=True, rng=...``;
the FL simulation trains in deterministic mode (DP noise already
regularizes; per-sample dropout RNG plumbing through vmap is intentionally
avoided — see DESIGN.md §8).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SERConfig:
    time_frames: int = 64
    n_mels: int = 40
    channels1: int = 64
    channels2: int = 128
    kernel: int = 5
    gn_groups: int = 8
    fc_dim: int = 128
    num_classes: int = 4
    drop1: float = 0.3
    drop2: float = 0.4
    drop_fc: float = 0.5


def init(key: jax.Array, cfg: SERConfig = SERConfig()):
    k1, k2, k3, k4 = jax.random.split(key, 4)

    def conv_init(k, cin, cout, ksz):
        scale = 1.0 / jnp.sqrt(cin * ksz)
        return {
            "w": jax.random.uniform(k, (ksz, cin, cout), jnp.float32, -scale, scale),
            "b": jnp.zeros((cout,), jnp.float32),
        }

    def dense_init(k, din, dout):
        scale = 1.0 / jnp.sqrt(din)
        return {
            "w": jax.random.uniform(k, (din, dout), jnp.float32, -scale, scale),
            "b": jnp.zeros((dout,), jnp.float32),
        }

    t_after = cfg.time_frames // 4  # two maxpools of 2
    return {
        "conv1": conv_init(k1, cfg.n_mels, cfg.channels1, cfg.kernel),
        "gn1": {"scale": jnp.ones((cfg.channels1,)), "bias": jnp.zeros((cfg.channels1,))},
        "conv2": conv_init(k2, cfg.channels1, cfg.channels2, cfg.kernel),
        "gn2": {"scale": jnp.ones((cfg.channels2,)), "bias": jnp.zeros((cfg.channels2,))},
        "fc1": dense_init(k3, t_after * cfg.channels2, cfg.fc_dim),
        "out": dense_init(k4, cfg.fc_dim, cfg.num_classes),
    }


def _conv1d(x, p):
    """x: (T, Cin) -> (T, Cout), SAME padding."""
    y = jax.lax.conv_general_dilated(
        x[None],                       # (1, T, Cin)
        p["w"],                        # (K, Cin, Cout)
        window_strides=(1,),
        padding="SAME",
        dimension_numbers=("NWC", "WIO", "NWC"),
    )[0]
    return y + p["b"]


def _group_norm(x, p, groups):
    """x: (T, C) grouped over channels."""
    t, c = x.shape
    xg = x.reshape(t, groups, c // groups)
    mean = xg.mean(axis=(0, 2), keepdims=True)
    var = xg.var(axis=(0, 2), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + 1e-5)
    return xg.reshape(t, c) * p["scale"] + p["bias"]


def _maxpool2(x):
    t, c = x.shape
    return x.reshape(t // 2, 2, c).max(axis=1)


def _dropout(x, rate, rng, train):
    if not train or rate == 0.0 or rng is None:
        return x
    keep = jax.random.bernoulli(rng, 1.0 - rate, x.shape)
    return jnp.where(keep, x / (1.0 - rate), 0.0)


def apply(params, x, cfg: SERConfig = SERConfig(), train: bool = False, rng=None):
    """x: (time_frames, n_mels) -> logits (num_classes,)."""
    rngs = jax.random.split(rng, 3) if (train and rng is not None) else (None,) * 3
    h = _conv1d(x, params["conv1"])
    h = _group_norm(h, params["gn1"], cfg.gn_groups)
    h = jax.nn.relu(h)
    h = _maxpool2(h)
    h = _dropout(h, cfg.drop1, rngs[0], train)

    h = _conv1d(h, params["conv2"])
    h = _group_norm(h, params["gn2"], cfg.gn_groups)
    h = jax.nn.relu(h)
    h = _maxpool2(h)
    h = _dropout(h, cfg.drop2, rngs[1], train)

    h = h.reshape(-1)
    h = jax.nn.relu(h @ params["fc1"]["w"] + params["fc1"]["b"])
    h = _dropout(h, cfg.drop_fc, rngs[2], train)
    return h @ params["out"]["w"] + params["out"]["b"]


def loss_fn(params, example, cfg: SERConfig = SERConfig()):
    """Cross-entropy loss for ONE example (paper Eq. 2); vmap-able."""
    logits = apply(params, example["x"], cfg)
    return -jax.nn.log_softmax(logits)[example["y"]]


def make_accuracy_fn(cfg: SERConfig = SERConfig(), batch: int = 512):
    @jax.jit
    def _acc(params, data):
        logits = jax.vmap(lambda x: apply(params, x, cfg))(data["x"])
        return jnp.mean((jnp.argmax(logits, -1) == data["y"]).astype(jnp.float32))

    return _acc


def param_count(params) -> int:
    return sum(l.size for l in jax.tree_util.tree_leaves(params))
