"""Dense decoder-only transformer family (llama / gemma2 / phi3 / smollm /
deepseek-coder).

Implementation notes
--------------------
* **Scan over layers** with stacked params (leading L dim) keeps the HLO
  size independent of depth — essential for the 62-layer deepseek dry-run.
* **gemma2 options**: alternating local(window)/global attention driven by
  a per-layer window array scanned alongside the params; attention-logit
  softcap; final-logit softcap; post-norms (sandwich); embedding scaled by
  sqrt(d_model); tied embeddings.
* **Chunked CE loss**: the (B, S, V) logits tensor is never materialized;
  we scan over sequence chunks (vocab up to 256 000).
* ``prefill`` returns (last-position logits, filled KV cache); ``decode``
  consumes one token and updates the cache in place (functional).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.base import Family, register_family

# remat-policy knob (hillclimb: save dot outputs to trade memory for the
# recompute FLOPs the baseline full-remat pays; EXPERIMENTS.md §Perf)
_REMAT = {"policy": None}


def set_remat_policy(name):
    _REMAT["policy"] = (
        jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        if name == "dots" else None)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def init_params(key, cfg):
    dtype = cfg.pdtype
    n = cfg.n_layers
    keys = jax.random.split(key, 8)

    def stack(init_fn, k):
        ks = jax.random.split(k, n)
        return jax.vmap(init_fn)(ks)

    blocks = {
        "attn": stack(lambda k: L.init_attention(k, cfg), keys[0]),
        "mlp": stack(
            lambda k: L.init_mlp(k, cfg.d_model, cfg.d_ff, dtype, cfg.mlp_variant),
            keys[1],
        ),
        "ln_attn": jnp.zeros((n, cfg.d_model), dtype),
        "ln_mlp": jnp.zeros((n, cfg.d_model), dtype),
    }
    if cfg.local_global_pattern:  # gemma2 sandwich norms
        blocks["ln_attn_post"] = jnp.zeros((n, cfg.d_model), dtype)
        blocks["ln_mlp_post"] = jnp.zeros((n, cfg.d_model), dtype)
    params = {
        "embedding": L.init_embedding(keys[2], cfg.vocab, cfg.d_model, dtype),
        "blocks": blocks,
        "ln_final": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_embedding(keys[3], cfg.vocab, cfg.d_model, dtype)
    return params


def layer_windows(cfg):
    """Per-layer sliding window sizes. 0 = full attention.

    gemma2: even layers local (sliding_window), odd layers global — unless
    the config forces all-local (``sliding_window`` with no pattern), which
    is the long_500k variant.
    """
    if cfg.local_global_pattern:
        return jnp.array(
            [cfg.sliding_window if (i % 2 == 0) else 0 for i in range(cfg.n_layers)],
            jnp.int32,
        )
    return jnp.full((cfg.n_layers,), cfg.sliding_window, jnp.int32)


# ---------------------------------------------------------------------------
# trunk
# ---------------------------------------------------------------------------


def _block(x, blk, window, cfg, positions):
    h = L.rms_norm(x, blk["ln_attn"], cfg.norm_eps)
    attn_out = L.attention(
        h, blk["attn"], cfg, positions, window=window, causal=True
    )
    if "ln_attn_post" in blk:
        attn_out = L.rms_norm(attn_out, blk["ln_attn_post"], cfg.norm_eps)
    x = x + attn_out
    h = L.rms_norm(x, blk["ln_mlp"], cfg.norm_eps)
    mlp_out = L.mlp(h, blk["mlp"], cfg.mlp_variant)
    if "ln_mlp_post" in blk:
        mlp_out = L.rms_norm(mlp_out, blk["ln_mlp_post"], cfg.norm_eps)
    return x + mlp_out


def trunk(params, x, cfg, positions):
    """x: (B, S, D) embedded input -> final hidden states."""
    windows = layer_windows(cfg)

    def body(carry, scanned):
        blk, window = scanned
        return _block(carry, blk, window, cfg, positions), None

    body = jax.checkpoint(body, policy=_REMAT["policy"])
    x, _ = jax.lax.scan(body, x, (params["blocks"], windows))
    return L.rms_norm(x, params["ln_final"], cfg.norm_eps)


def embed_tokens(params, tokens, cfg):
    x = L.embed(tokens, params["embedding"])
    if cfg.local_global_pattern:  # gemma scales embeddings
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return L.shard(x, "batch", None, None)


def _lm_matrix(params):
    return params.get("lm_head", params["embedding"])


def forward_hidden(params, batch, cfg):
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = embed_tokens(params, tokens, cfg)
    return trunk(params, x, cfg, positions)


def logits_fn(params, batch, cfg):
    h = forward_hidden(params, batch, cfg)
    return L.unembed(h, _lm_matrix(params), cfg.final_logit_softcap)


def loss(params, batch, cfg, *, loss_chunk: int = 512):
    """Mean next-token CE with sequence-chunked logits."""
    h = forward_hidden(params, batch, cfg)                  # (B, S, D)
    labels = batch["labels"]
    B, S, D = h.shape
    W = _lm_matrix(params)
    n_chunks = max(1, S // loss_chunk)
    hc = h.reshape(B, n_chunks, S // n_chunks, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n_chunks, S // n_chunks).transpose(1, 0, 2)

    def chunk_loss(args):
        hx, lx = args
        logits = L.unembed(hx, W, cfg.final_logit_softcap)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lx[..., None], axis=-1)[..., 0]
        return jnp.mean(logz - gold)

    losses = jax.lax.map(jax.checkpoint(chunk_loss), (hc, lc))
    return jnp.mean(losses)


# ---------------------------------------------------------------------------
# KV cache / prefill / decode
# ---------------------------------------------------------------------------


def init_cache(cfg, batch_size, max_len, dtype=None):
    dtype = dtype or cfg.pdtype
    shape = (cfg.n_layers, batch_size, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def prefill(params, batch, cfg, cache):
    """Fill the cache for tokens (B, S); return (last logits, cache)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = embed_tokens(params, tokens, cfg)
    windows = layer_windows(cfg)

    def body(carry, scanned):
        x = carry
        blk, window = scanned
        h = L.rms_norm(x, blk["ln_attn"], cfg.norm_eps)
        _, k, v = L._qkv(h, blk["attn"], cfg, positions)
        attn_out = L.attention(
            h, blk["attn"], cfg, positions, window=window, causal=True,
            kv_override=(k, v, positions),
        )
        if "ln_attn_post" in blk:
            attn_out = L.rms_norm(attn_out, blk["ln_attn_post"], cfg.norm_eps)
        x = x + attn_out
        h2 = L.rms_norm(x, blk["ln_mlp"], cfg.norm_eps)
        mlp_out = L.mlp(h2, blk["mlp"], cfg.mlp_variant)
        if "ln_mlp_post" in blk:
            mlp_out = L.rms_norm(mlp_out, blk["ln_mlp_post"], cfg.norm_eps)
        return x + mlp_out, (k, v)

    x, (ks, vs) = jax.lax.scan(jax.checkpoint(body), x, (params["blocks"], windows))
    Lmax = cache["k"].shape[2]
    cache = {
        "k": jax.lax.dynamic_update_slice(cache["k"], ks, (0, 0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], vs, (0, 0, 0, 0, 0)),
    }
    h = L.rms_norm(x, params["ln_final"], cfg.norm_eps)
    logits = L.unembed(h[:, -1:], _lm_matrix(params), cfg.final_logit_softcap)
    return logits[:, 0], cache


def decode_step(params, cache, token, pos, cfg):
    """One decode step: token (B, 1), pos (B,).  Returns (logits, cache)."""
    B = token.shape[0]
    x = embed_tokens(params, token, cfg)                    # (B, 1, D)
    positions = pos[:, None]
    windows = layer_windows(cfg)
    batch_idx = jnp.arange(B)

    def body(x, scanned):
        blk, window, ck, cv = scanned
        h = L.rms_norm(x, blk["ln_attn"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, blk["attn"]["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, blk["attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, blk["attn"]["wv"])
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        ck = ck.at[batch_idx, pos].set(k[:, 0])
        cv = cv.at[batch_idx, pos].set(v[:, 0])
        attn_out = L.decode_attention(q, blk["attn"], ck, cv, pos, cfg, window=window)
        if "ln_attn_post" in blk:
            attn_out = L.rms_norm(attn_out, blk["ln_attn_post"], cfg.norm_eps)
        x = x + attn_out
        h2 = L.rms_norm(x, blk["ln_mlp"], cfg.norm_eps)
        mlp_out = L.mlp(h2, blk["mlp"], cfg.mlp_variant)
        if "ln_mlp_post" in blk:
            mlp_out = L.rms_norm(mlp_out, blk["ln_mlp_post"], cfg.norm_eps)
        return x + mlp_out, (ck, cv)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["blocks"], windows, cache["k"], cache["v"])
    )
    cache = {"k": ks, "v": vs}
    h = L.rms_norm(x, params["ln_final"], cfg.norm_eps)
    logits = L.unembed(h, _lm_matrix(params), cfg.final_logit_softcap)
    return logits[:, 0], cache


register_family(
    Family(
        name="dense",
        init_params=init_params,
        forward=logits_fn,
        loss=loss,
        init_cache=init_cache,
        prefill=prefill,
        decode_step=decode_step,
    )
)
