"""zamba2-style hybrid family: Mamba2 backbone with a SHARED attention+MLP
block applied every ``cfg.attn_every`` layers (one parameter set, reused —
the zamba2 weight-sharing trick, arXiv:2411.15242).

Layer layout for n_layers=38, attn_every=6:
  6 segments of [6 x mamba2, shared_attn], then 2 trailing mamba2 layers.
Segments run as a nested scan (outer over segments, inner over the
segment's mamba layers) so HLO stays small; the shared block appears once
per segment application but with the SAME weights.

Decode carries per-layer (conv_state, ssm_state) plus a KV cache for the
shared attention block applications (one cache slot per application).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models.base import Family, register_family


def segment_plan(cfg):
    """(n_segments, seg_len, n_trailing)."""
    if cfg.attn_every <= 0:
        return 0, 0, cfg.n_layers
    n_seg = cfg.n_layers // cfg.attn_every
    trailing = cfg.n_layers - n_seg * cfg.attn_every
    return n_seg, cfg.attn_every, trailing


def init_params(key, cfg):
    dtype = cfg.pdtype
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    n_seg, seg_len, trailing = segment_plan(cfg)

    def stack(init_fn, k, n):
        ks = jax.random.split(k, max(n, 1))
        return jax.vmap(init_fn)(ks)

    params = {
        "embedding": L.init_embedding(k1, cfg.vocab, cfg.d_model, dtype),
        "mamba_seg": {
            "mix": jax.vmap(lambda k: jax.vmap(lambda kk: M.init_mamba2(kk, cfg))(
                jax.random.split(k, seg_len)))(jax.random.split(k2, n_seg))
            if n_seg else None,
            "ln": jnp.zeros((n_seg, seg_len, cfg.d_model), dtype) if n_seg else None,
        },
        # ONE shared attention+MLP block (zamba2 weight sharing)
        "shared_attn": {
            "attn": L.init_attention(k3, cfg),
            "mlp": L.init_mlp(k4, cfg.d_model, cfg.d_ff, dtype, cfg.mlp_variant),
            "ln_attn": jnp.zeros((cfg.d_model,), dtype),
            "ln_mlp": jnp.zeros((cfg.d_model,), dtype),
        },
        "mamba_tail": {
            "mix": stack(lambda k: M.init_mamba2(k, cfg), k5, trailing)
            if trailing else None,
            "ln": jnp.zeros((trailing, cfg.d_model), dtype) if trailing else None,
        },
        "ln_final": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_embedding(k6, cfg.vocab, cfg.d_model, dtype)
    return params


def _mamba_layer(x, mix, ln, cfg, state=None, use_kernel=False):
    h = L.rms_norm(x, ln, cfg.norm_eps)
    if state is None:
        out, new_state = M.mamba2_forward(h, mix, cfg, use_kernel=use_kernel)
    else:
        out, new_state = M.mamba2_decode(h, mix, cfg, state)
    return x + out, new_state


def _shared_block(x, p, cfg, positions):
    sp = p["shared_attn"]
    h = L.rms_norm(x, sp["ln_attn"], cfg.norm_eps)
    x = x + L.attention(h, sp["attn"], cfg, positions, causal=True)
    h = L.rms_norm(x, sp["ln_mlp"], cfg.norm_eps)
    return x + L.mlp(h, sp["mlp"], cfg.mlp_variant)


def forward_hidden(params, batch, cfg):
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = L.shard(L.embed(tokens, params["embedding"]), "batch", None, None)
    n_seg, seg_len, trailing = segment_plan(cfg)

    if n_seg:
        def seg_body(x, seg):
            def inner(x, lyr):
                x, _ = _mamba_layer(x, lyr["mix"], lyr["ln"], cfg)
                return x, None
            x, _ = jax.lax.scan(inner, x, seg)
            x = _shared_block(x, params, cfg, positions)
            return x, None

        x, _ = jax.lax.scan(
            jax.checkpoint(seg_body), x, params["mamba_seg"]
        )
    if trailing:
        def inner(x, lyr):
            x, _ = _mamba_layer(x, lyr["mix"], lyr["ln"], cfg)
            return x, None
        x, _ = jax.lax.scan(jax.checkpoint(inner), x, params["mamba_tail"])
    return L.rms_norm(x, params["ln_final"], cfg.norm_eps)


def logits_fn(params, batch, cfg):
    h = forward_hidden(params, batch, cfg)
    return L.unembed(h, params.get("lm_head", params["embedding"]))


def loss(params, batch, cfg, *, loss_chunk: int = 512):
    h = forward_hidden(params, batch, cfg)
    labels = batch["labels"]
    B, S, D = h.shape
    W = params.get("lm_head", params["embedding"])
    n_chunks = max(1, S // loss_chunk)
    hc = h.reshape(B, n_chunks, S // n_chunks, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n_chunks, S // n_chunks).transpose(1, 0, 2)

    def chunk_loss(args):
        hx, lx = args
        logits = L.unembed(hx, W)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lx[..., None], axis=-1)[..., 0]
        return jnp.mean(logz - gold)

    return jnp.mean(jax.lax.map(jax.checkpoint(chunk_loss), (hc, lc)))


# ---------------------------------------------------------------------------
# decode: per-layer SSM states + KV cache per shared-attn application
# ---------------------------------------------------------------------------


def init_cache(cfg, batch_size, max_len, dtype=None):
    n_seg, seg_len, trailing = segment_plan(cfg)
    conv, ssm = M.init_decode_state(cfg, batch_size)

    def rep(x, n):
        return jnp.broadcast_to(x[None], (n,) + x.shape) * 0 if n else None

    cache = {
        "seg_conv": rep(conv, n_seg * seg_len).reshape(
            (n_seg, seg_len) + conv.shape) if n_seg else None,
        "seg_ssm": rep(ssm, n_seg * seg_len).reshape(
            (n_seg, seg_len) + ssm.shape) if n_seg else None,
        "tail_conv": rep(conv, trailing) if trailing else None,
        "tail_ssm": rep(ssm, trailing) if trailing else None,
        # KV cache: one slot per shared-attention application
        "attn_k": jnp.zeros(
            (n_seg, batch_size, max_len, cfg.n_kv_heads, cfg.head_dim),
            dtype or cfg.pdtype,
        ) if n_seg else None,
        "attn_v": jnp.zeros(
            (n_seg, batch_size, max_len, cfg.n_kv_heads, cfg.head_dim),
            dtype or cfg.pdtype,
        ) if n_seg else None,
    }
    return cache


def prefill(params, batch, cfg, cache):
    """Prefill via the parallel path, then capture states for decode.

    For SSM layers the final ssm/conv states come from the chunked scan;
    for the shared attention block we store K/V of the full prefix.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = L.shard(L.embed(tokens, params["embedding"]), "batch", None, None)
    n_seg, seg_len, trailing = segment_plan(cfg)

    if n_seg:
        def seg_body(x, seg):
            def inner(x, lyr):
                h = L.rms_norm(x, lyr["ln"], cfg.norm_eps)
                out, st = M.mamba2_forward(h, lyr["mix"], cfg)
                return x + out, st
            x, states = jax.lax.scan(inner, x, seg)
            sp = params["shared_attn"]
            h = L.rms_norm(x, sp["ln_attn"], cfg.norm_eps)
            _, k, v = L._qkv(h, sp["attn"], cfg, positions)
            x = x + L.attention(
                h, sp["attn"], cfg, positions, causal=True,
                kv_override=(k, v, positions),
            )
            h = L.rms_norm(x, sp["ln_mlp"], cfg.norm_eps)
            x = x + L.mlp(h, sp["mlp"], cfg.mlp_variant)
            return x, (states, k, v)

        x, (seg_states, ks, vs) = jax.lax.scan(
            jax.checkpoint(seg_body), x, params["mamba_seg"]
        )
        cache = dict(cache)
        cache["seg_conv"], cache["seg_ssm"] = seg_states
        cache["attn_k"] = jax.lax.dynamic_update_slice(
            cache["attn_k"], ks, (0, 0, 0, 0, 0))
        cache["attn_v"] = jax.lax.dynamic_update_slice(
            cache["attn_v"], vs, (0, 0, 0, 0, 0))
    if trailing:
        def inner(x, lyr):
            h = L.rms_norm(x, lyr["ln"], cfg.norm_eps)
            out, st = M.mamba2_forward(h, lyr["mix"], cfg)
            return x + out, st
        x, tail_states = jax.lax.scan(jax.checkpoint(inner), x, params["mamba_tail"])
        cache = dict(cache)
        cache["tail_conv"], cache["tail_ssm"] = tail_states

    h = L.rms_norm(x, params["ln_final"], cfg.norm_eps)
    logits = L.unembed(h[:, -1:], params.get("lm_head", params["embedding"]))
    return logits[:, 0], cache


def decode_step(params, cache, token, pos, cfg):
    B = token.shape[0]
    x = L.embed(token, params["embedding"])
    positions = pos[:, None]
    batch_idx = jnp.arange(B)
    n_seg, seg_len, trailing = segment_plan(cfg)

    cache = dict(cache)
    if n_seg:
        def seg_body(x, seg):
            lyrs, conv_sts, ssm_sts, ck, cv = seg

            def inner(x, inp):
                lyr, cst, sst = inp
                h = L.rms_norm(x, lyr["ln"], cfg.norm_eps)
                out, (ncst, nsst) = M.mamba2_decode(h, lyr["mix"], cfg, (cst, sst))
                return x + out, (ncst, nsst)

            x, (nconv, nssm) = jax.lax.scan(inner, x, (lyrs, conv_sts, ssm_sts))
            sp = params["shared_attn"]
            h = L.rms_norm(x, sp["ln_attn"], cfg.norm_eps)
            q = jnp.einsum("bsd,dhk->bshk", h, sp["attn"]["wq"])
            k = jnp.einsum("bsd,dhk->bshk", h, sp["attn"]["wk"])
            v = jnp.einsum("bsd,dhk->bshk", h, sp["attn"]["wv"])
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
            ck = ck.at[batch_idx, pos].set(k[:, 0])
            cv = cv.at[batch_idx, pos].set(v[:, 0])
            x = x + L.decode_attention(q, sp["attn"], ck, cv, pos, cfg)
            h = L.rms_norm(x, sp["ln_mlp"], cfg.norm_eps)
            x = x + L.mlp(h, sp["mlp"], cfg.mlp_variant)
            return x, (nconv, nssm, ck, cv)

        x, (nconv, nssm, ks, vs) = jax.lax.scan(
            seg_body, x,
            (params["mamba_seg"], cache["seg_conv"], cache["seg_ssm"],
             cache["attn_k"], cache["attn_v"]),
        )
        cache.update(seg_conv=nconv, seg_ssm=nssm, attn_k=ks, attn_v=vs)
    if trailing:
        def inner(x, inp):
            lyr, cst, sst = inp
            h = L.rms_norm(x, lyr["ln"], cfg.norm_eps)
            out, (ncst, nsst) = M.mamba2_decode(h, lyr["mix"], cfg, (cst, sst))
            return x + out, (ncst, nsst)
        x, (nc, ns) = jax.lax.scan(
            inner, x, (params["mamba_tail"], cache["tail_conv"], cache["tail_ssm"])
        )
        cache.update(tail_conv=nc, tail_ssm=ns)

    h = L.rms_norm(x, params["ln_final"], cfg.norm_eps)
    logits = L.unembed(h, params.get("lm_head", params["embedding"]))
    return logits[:, 0], cache


register_family(
    Family(
        name="hybrid",
        init_params=init_params,
        forward=logits_fn,
        loss=loss,
        init_cache=init_cache,
        prefill=prefill,
        decode_step=decode_step,
    )
)
