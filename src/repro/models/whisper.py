"""Whisper-style encoder-decoder family (audio).

Per the assignment carve-out, the mel-spectrogram + conv feature extractor
is a STUB: ``input_specs`` provides precomputed frame embeddings
(B, enc_frames, d_model).  Everything downstream — the 32-layer
bidirectional encoder, the 32-layer causal decoder with cross-attention,
sinusoidal/learned positions — is implemented fully.

Differences vs the original (noted in DESIGN.md): RMSNorm without biases
instead of LayerNorm+bias (keeps the block uniform with the rest of the
zoo; dry-run cost is identical to first order), and the decoder position
table is sized by ``cfg.max_seq`` to honour the assignment's decode_32k
shape rather than whisper's 448-token context.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.base import Family, register_family


def _sinusoidal(length: int, d: int):
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], -1)


def init_params(key, cfg):
    dtype = cfg.pdtype
    ks = jax.random.split(key, 8)
    n_enc, n_dec = cfg.n_enc_layers, cfg.n_layers

    def stack(init_fn, k, n):
        return jax.vmap(init_fn)(jax.random.split(k, n))

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "attn": L.init_attention(k1, cfg),
            "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, dtype, "gelu"),
            "ln_attn": jnp.zeros((cfg.d_model,), dtype),
            "ln_mlp": jnp.zeros((cfg.d_model,), dtype),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "attn": L.init_attention(k1, cfg),
            "xattn": L.init_attention(k2, cfg),
            "mlp": L.init_mlp(k3, cfg.d_model, cfg.d_ff, dtype, "gelu"),
            "ln_attn": jnp.zeros((cfg.d_model,), dtype),
            "ln_xattn": jnp.zeros((cfg.d_model,), dtype),
            "ln_mlp": jnp.zeros((cfg.d_model,), dtype),
        }

    return {
        "embedding": L.init_embedding(ks[0], cfg.vocab, cfg.d_model, dtype),
        "pos_dec": L.dense_init(ks[1], (cfg.max_seq, cfg.d_model), dtype,
                                fan_in=cfg.d_model),
        "enc": stack(enc_layer, ks[2], n_enc),
        "dec": stack(dec_layer, ks[3], n_dec),
        "ln_enc_final": jnp.zeros((cfg.d_model,), dtype),
        "ln_final": jnp.zeros((cfg.d_model,), dtype),
    }


def encode(params, frames, cfg):
    """frames: (B, F, D) stub embeddings -> encoder states."""
    B, F, D = frames.shape
    x = frames + _sinusoidal(F, D).astype(frames.dtype)
    x = L.shard(x, "batch", None, None)
    positions = jnp.broadcast_to(jnp.arange(F), (B, F))

    def body(x, blk):
        h = L.rms_norm(x, blk["ln_attn"], cfg.norm_eps)
        x = x + L.attention(h, blk["attn"], cfg, positions, causal=False, rope=False)
        h = L.rms_norm(x, blk["ln_mlp"], cfg.norm_eps)
        return x + L.mlp(h, blk["mlp"], "gelu"), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc"])
    return L.rms_norm(x, params["ln_enc_final"], cfg.norm_eps)


def _dec_trunk(params, x, cfg, positions, enc_out, enc_pos, collect_kv=False):
    def body(x, blk):
        h = L.rms_norm(x, blk["ln_attn"], cfg.norm_eps)
        _, k, v = L._qkv(h, blk["attn"], cfg, positions, rope=False)
        x = x + L.attention(
            h, blk["attn"], cfg, positions, causal=True, rope=False,
            kv_override=(k, v, positions),
        )
        h = L.rms_norm(x, blk["ln_xattn"], cfg.norm_eps)
        xk = jnp.einsum("bsd,dhk->bshk", enc_out, blk["xattn"]["wk"])
        xv = jnp.einsum("bsd,dhk->bshk", enc_out, blk["xattn"]["wv"])
        x = x + L.attention(
            h, blk["xattn"], cfg, positions, causal=False, rope=False,
            kv_override=(xk, xv, enc_pos),
        )
        h = L.rms_norm(x, blk["ln_mlp"], cfg.norm_eps)
        ys = (k, v, xk, xv) if collect_kv else None
        return x + L.mlp(h, blk["mlp"], "gelu"), ys

    x, kvs = jax.lax.scan(jax.checkpoint(body), x, params["dec"])
    return L.rms_norm(x, params["ln_final"], cfg.norm_eps), kvs


def forward_hidden(params, batch, cfg, collect_kv=False):
    tokens, frames = batch["tokens"], batch["frames"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    enc_out = encode(params, frames, cfg)
    enc_pos = jnp.broadcast_to(jnp.arange(enc_out.shape[1]), (B, enc_out.shape[1]))
    x = L.embed(tokens, params["embedding"]) + params["pos_dec"][:S]
    x = L.shard(x, "batch", None, None)
    return _dec_trunk(params, x, cfg, positions, enc_out, enc_pos,
                      collect_kv=collect_kv)


def logits_fn(params, batch, cfg):
    h, _ = forward_hidden(params, batch, cfg)
    return L.unembed(h, params["embedding"])


def loss(params, batch, cfg, *, loss_chunk: int = 512):
    h, _ = forward_hidden(params, batch, cfg)
    labels = batch["labels"]
    B, S, D = h.shape
    chunk = min(loss_chunk, S)
    n_chunks = max(1, S // chunk)
    hc = h.reshape(B, n_chunks, S // n_chunks, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n_chunks, S // n_chunks).transpose(1, 0, 2)

    def chunk_loss(args):
        hx, lx = args
        logits = L.unembed(hx, params["embedding"])
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lx[..., None], axis=-1)[..., 0]
        return jnp.mean(logz - gold)

    return jnp.mean(jax.lax.map(jax.checkpoint(chunk_loss), (hc, lc)))


def init_cache(cfg, batch_size, max_len, dtype=None):
    dtype = dtype or cfg.pdtype
    n = cfg.n_layers
    H, Dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((n, batch_size, max_len, H, Dh), dtype),
        "v": jnp.zeros((n, batch_size, max_len, H, Dh), dtype),
        "xk": jnp.zeros((n, batch_size, cfg.enc_frames, H, Dh), dtype),
        "xv": jnp.zeros((n, batch_size, cfg.enc_frames, H, Dh), dtype),
    }


def prefill(params, batch, cfg, cache):
    h, kvs = forward_hidden(params, batch, cfg, collect_kv=True)
    ks, vs, xks, xvs = kvs
    cache = {
        "k": jax.lax.dynamic_update_slice(cache["k"], ks, (0, 0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], vs, (0, 0, 0, 0, 0)),
        "xk": xks,
        "xv": xvs,
    }
    logits = L.unembed(h[:, -1:], params["embedding"])
    return logits[:, 0], cache


def decode_step(params, cache, token, pos, cfg):
    """Decoder-only step; cross-attention reads the cached encoder KV."""
    B = token.shape[0]
    x = L.embed(token, params["embedding"]) + params["pos_dec"][pos][:, None]
    batch_idx = jnp.arange(B)
    F = cache["xk"].shape[2]
    enc_pos = jnp.broadcast_to(jnp.arange(F), (B, F))

    def body(x, scanned):
        blk, ck, cv, xk, xv = scanned
        h = L.rms_norm(x, blk["ln_attn"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, blk["attn"]["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, blk["attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, blk["attn"]["wv"])
        ck = ck.at[batch_idx, pos].set(k[:, 0])
        cv = cv.at[batch_idx, pos].set(v[:, 0])
        x = x + L.decode_attention(q, blk["attn"], ck, cv, pos, cfg)
        h = L.rms_norm(x, blk["ln_xattn"], cfg.norm_eps)
        xq = jnp.einsum("bsd,dhk->bshk", h, blk["xattn"]["wq"])
        # cross attention: all encoder frames visible
        x = x + L.decode_attention(
            xq, blk["xattn"], xk, xv, jnp.full((B,), F - 1), cfg
        )
        h = L.rms_norm(x, blk["ln_mlp"], cfg.norm_eps)
        return x + L.mlp(h, blk["mlp"], "gelu"), (ck, cv)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec"], cache["k"], cache["v"], cache["xk"], cache["xv"])
    )
    cache = dict(cache, k=ks, v=vs)
    h = L.rms_norm(x, params["ln_final"], cfg.norm_eps)
    return L.unembed(h, params["embedding"])[:, 0], cache


register_family(
    Family(
        name="audio",
        init_params=init_params,
        forward=logits_fn,
        loss=loss,
        init_cache=init_cache,
        prefill=prefill,
        decode_step=decode_step,
    )
)
