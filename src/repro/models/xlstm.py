"""xLSTM family (arXiv:2405.04517): mLSTM (matrix-memory, chunkwise-
parallel) and sLSTM (scalar-memory, strictly recurrent) blocks.

Layout: sLSTM every ``cfg.slstm_every`` layers (xLSTM[a:b] notation), the
rest mLSTM — xlstm-350m uses 24 blocks with 3 sLSTM.  d_ff=0 in the
assignment: mLSTM blocks carry their own up/down projection (factor 2);
sLSTM blocks carry a small gated FFN (factor 4/3) per the paper.

mLSTM chunkwise form (per head, exponential-decay linear attention):
    C_t = f_t C_{t-1} + i_t k_t v_t^T ,  n_t = f_t n_{t-1} + i_t k_t
    y_t = (q_t C_t) / max(|q_t . n_t|, 1)
implemented with the same intra/inter-chunk split as Mamba2's SSD; the
normalizer n is carried as an extra value column.

sLSTM: stabilized exponential gating with per-head block-diagonal
recurrent matrices, as a lax.scan over time (sequential by construction —
this is the architecture's documented trade-off, not an implementation
shortcut).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.base import Family, register_family
from repro.models.mamba2 import _segsum


def d_inner(cfg) -> int:
    return 2 * cfg.d_model


def mlstm_heads(cfg) -> int:
    return cfg.n_heads


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg):
    dtype = cfg.pdtype
    D, di, H = cfg.d_model, d_inner(cfg), mlstm_heads(cfg)
    ks = jax.random.split(key, 8)
    return {
        "ln": jnp.zeros((D,), dtype),
        "w_up": L.dense_init(ks[0], (D, 2 * di), dtype, fan_in=D),
        "wq": L.dense_init(ks[1], (di, di), dtype),
        "wk": L.dense_init(ks[2], (di, di), dtype),
        "wv": L.dense_init(ks[3], (di, di), dtype),
        "w_i": L.dense_init(ks[4], (di, H), jnp.float32),
        "w_f": L.dense_init(ks[5], (di, H), jnp.float32),
        "b_i": jnp.zeros((H,), jnp.float32),
        "b_f": jnp.full((H,), 3.0, jnp.float32),  # init: remember
        "ln_inner": jnp.zeros((di,), dtype),
        "w_down": L.dense_init(ks[6], (di, D), dtype, fan_in=di),
    }


def mlstm_chunked(q, k, v, logf, logi, chunk: int, init_state=None):
    """q,k,v: (b,s,h,d); logf,logi: (b,s,h).  Returns (y, final_C).

    The normalizer is appended as an extra column of v, so state C is
    (b, h, dk, dv+1).
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    ones = jnp.ones(v.shape[:-1] + (1,), v.dtype)
    v_ext = jnp.concatenate([v, ones], -1)                 # (b,s,h,dv+1)
    # fold the input gate into the value contribution
    v_ext = v_ext * jnp.exp(logi)[..., None].astype(v.dtype)

    c = s // chunk
    qr = q.reshape(b, c, chunk, h, dk)
    kr = k.reshape(b, c, chunk, h, dk)
    vr = v_ext.reshape(b, c, chunk, h, dv + 1)
    ar = logf.reshape(b, c, chunk, h).transpose(0, 3, 1, 2)  # (b,h,c,q)
    a_cs = jnp.cumsum(ar, -1)

    Lm = jnp.exp(_segsum(ar))                              # (b,h,c,q,kq)
    scores = jnp.einsum("bcqhd,bckhd->bhcqk", qr, kr)
    Y_diag = jnp.einsum("bhcqk,bhcqk,bckhe->bcqhe", scores, Lm, vr)

    decay_states = jnp.exp(a_cs[..., -1:] - a_cs)
    states = jnp.einsum("bckhd,bhck,bckhe->bchde", kr, decay_states, vr)
    chunk_decay = jnp.exp(a_cs[..., -1])
    if init_state is None:
        init_state = jnp.zeros((b, h, dk, dv + 1), jnp.float32)

    def scan_fn(cprev, inp):
        st, dec = inp
        return cprev * dec[..., None, None] + st, cprev

    final, prev = jax.lax.scan(
        scan_fn, init_state,
        (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_decay.transpose(2, 0, 1)),
    )
    prev = prev.transpose(1, 0, 2, 3, 4)                   # (b,c,h,dk,dv+1)
    state_decay = jnp.exp(a_cs)
    Y_off = jnp.einsum(
        "bcqhd,bchde,bhcq->bcqhe", qr, prev.astype(q.dtype), state_decay
    )
    y_ext = (Y_diag + Y_off).reshape(b, s, h, dv + 1)
    y, norm = y_ext[..., :dv], y_ext[..., dv:]
    y = y / jnp.maximum(jnp.abs(norm), 1.0)
    return y, final


def mlstm_block(x, p, cfg, state=None):
    """x: (B,S,D).  state: C (B,H,dk,dv+1) for decode, or None."""
    B, S, D = x.shape
    di, H = d_inner(cfg), mlstm_heads(cfg)
    dh = di // H
    h_in = L.rms_norm(x, p["ln"], cfg.norm_eps)
    up = jnp.einsum("bsd,de->bse", h_in, p["w_up"])
    z, m = jnp.split(up, 2, -1)

    q = jnp.einsum("bse,ef->bsf", m, p["wq"]).reshape(B, S, H, dh)
    k = jnp.einsum("bse,ef->bsf", m, p["wk"]).reshape(B, S, H, dh) * (dh ** -0.5)
    v = jnp.einsum("bse,ef->bsf", m, p["wv"]).reshape(B, S, H, dh)
    logi = jnp.einsum("bse,eh->bsh", m.astype(jnp.float32), p["w_i"]) + p["b_i"]
    logf = jax.nn.log_sigmoid(
        jnp.einsum("bse,eh->bsh", m.astype(jnp.float32), p["w_f"]) + p["b_f"]
    )
    # stabilize the input gate (exp can overflow): subtract a running cap
    logi = jnp.minimum(logi, 10.0)

    if state is None or S > 1:
        y, final = mlstm_chunked(q, k, v, logf, logi,
                                 min(cfg.ssm_chunk or 64, S), init_state=state)
    else:
        # one-step recurrent decode
        C = state                                           # (B,H,dk,dv+1)
        ones = jnp.ones(v.shape[:-1] + (1,), v.dtype)
        v_ext = (jnp.concatenate([v, ones], -1)
                 * jnp.exp(logi)[..., None].astype(v.dtype))[:, 0]
        f = jnp.exp(logf)[:, 0]                             # (B,H)
        C = C * f[..., None, None] + jnp.einsum(
            "bhd,bhe->bhde", k[:, 0].astype(jnp.float32),
            v_ext.astype(jnp.float32))
        y_ext = jnp.einsum("bhd,bhde->bhe", q[:, 0].astype(jnp.float32), C)
        yv, norm = y_ext[..., :-1], y_ext[..., -1:]
        y = (yv / jnp.maximum(jnp.abs(norm), 1.0))[:, None].astype(x.dtype)
        final = C

    # chunked path mixes f32 decay factors in; pin back to residual dtype
    y = y.reshape(B, S, di).astype(x.dtype)
    y = L.rms_norm(y, p["ln_inner"], cfg.norm_eps) * jax.nn.silu(z)
    return x + jnp.einsum("bse,ed->bsd", y, p["w_down"]), final


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_ff(cfg) -> int:
    return max(64, (4 * cfg.d_model // 3 + 63) // 64 * 64)


def init_slstm(key, cfg):
    dtype = cfg.pdtype
    D, H = cfg.d_model, cfg.n_heads
    dh = D // H
    ks = jax.random.split(key, 12)
    p = {"ln": jnp.zeros((D,), dtype)}
    for i, g in enumerate(("z", "i", "f", "o")):
        p[f"W{g}"] = L.dense_init(ks[i], (D, D), jnp.float32)
        p[f"R{g}"] = L.dense_init(ks[4 + i], (H, dh, dh), jnp.float32, fan_in=dh)
        p[f"b{g}"] = (jnp.full((D,), 3.0, jnp.float32) if g == "f"
                      else jnp.zeros((D,), jnp.float32))
    ff = slstm_ff(cfg)
    p["ffn"] = L.init_mlp(ks[8], D, ff, dtype, "swiglu")
    p["ln_ffn"] = jnp.zeros((D,), dtype)
    return p


def slstm_scan(x, p, cfg, init=None):
    """x: (B,S,D) -> (B,S,D); stabilized exponential-gating recurrence."""
    B, S, D = x.shape
    H = cfg.n_heads
    dh = D // H
    xf = x.astype(jnp.float32)
    pre = {g: jnp.einsum("bsd,de->bse", xf, p[f"W{g}"]) + p[f"b{g}"]
           for g in ("z", "i", "f", "o")}

    if init is None:
        zeros = jnp.zeros((B, H, dh), jnp.float32)
        init = {"c": zeros, "n": zeros, "h": zeros, "m": zeros - 1e30 * 0}

    def step(carry, inp):
        c, n, h, m = carry["c"], carry["n"], carry["h"], carry["m"]
        pz, pi, pf, po = inp
        rec = {g: jnp.einsum("bhd,hde->bhe", h, p[f"R{g}"]) for g in "zifo"}
        z = jnp.tanh(pz.reshape(B, H, dh) + rec["z"])
        i_log = pi.reshape(B, H, dh) + rec["i"]
        f_log = jax.nn.log_sigmoid(pf.reshape(B, H, dh) + rec["f"])
        o = jax.nn.sigmoid(po.reshape(B, H, dh) + rec["o"])
        m_new = jnp.maximum(f_log + m, i_log)
        i_p = jnp.exp(i_log - m_new)
        f_p = jnp.exp(f_log + m - m_new)
        c_new = f_p * c + i_p * z
        n_new = f_p * n + i_p
        h_new = o * c_new / jnp.maximum(n_new, 1.0)
        return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}, h_new

    xs = tuple(pre[g].transpose(1, 0, 2) for g in "zifo")
    final, hs = jax.lax.scan(step, init, xs)              # hs: (S, B, H, dh)
    return hs.transpose(1, 0, 2, 3).reshape(B, S, D).astype(x.dtype), final


def slstm_block(x, p, cfg, state=None):
    h_in = L.rms_norm(x, p["ln"], cfg.norm_eps)
    y, final = slstm_scan(h_in, p, cfg, init=state)
    x = x + y
    h2 = L.rms_norm(x, p["ln_ffn"], cfg.norm_eps)
    return x + L.mlp(h2, p["ffn"], "swiglu"), final


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------


def layer_plan(cfg):
    """(n_periods, mlstm_per_period, n_trailing_mlstm).  One sLSTM closes
    each period."""
    if cfg.slstm_every <= 0:
        return 0, 0, cfg.n_layers
    n_per = cfg.n_layers // cfg.slstm_every
    trailing = cfg.n_layers - n_per * cfg.slstm_every
    return n_per, cfg.slstm_every - 1, trailing


def init_params(key, cfg):
    dtype = cfg.pdtype
    n_per, m_per, trailing = layer_plan(cfg)
    ks = jax.random.split(key, 6)

    def stack2(init_fn, k, n0, n1):
        return jax.vmap(
            lambda kk: jax.vmap(lambda k3: init_fn(k3))(jax.random.split(kk, n1))
        )(jax.random.split(k, n0))

    params = {
        "embedding": L.init_embedding(ks[0], cfg.vocab, cfg.d_model, dtype),
        "mlstm_seg": stack2(lambda k: init_mlstm(k, cfg), ks[1], n_per, m_per)
        if n_per and m_per else None,
        "slstm": jax.vmap(lambda k: init_slstm(k, cfg))(jax.random.split(ks[2], n_per))
        if n_per else None,
        "mlstm_tail": jax.vmap(lambda k: init_mlstm(k, cfg))(
            jax.random.split(ks[3], trailing)) if trailing else None,
        "ln_final": jnp.zeros((cfg.d_model,), dtype),
        "lm_head": L.init_embedding(ks[4], cfg.vocab, cfg.d_model, dtype),
    }
    return params


def forward_hidden(params, batch, cfg, cache=None):
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.shard(L.embed(tokens, params["embedding"]), "batch", None, None)
    n_per, m_per, trailing = layer_plan(cfg)
    new_cache = {} if cache is not None else None

    if n_per:
        def period(x, seg):
            mls, sls = seg

            def inner(x, lyr):
                x, st = mlstm_block(x, lyr, cfg)
                return x, st

            if m_per:
                x, mstates = jax.lax.scan(inner, x, mls)
            else:
                mstates = None
            x, sstate = slstm_block(x, sls, cfg)
            return x, (mstates, sstate)

        x, states = jax.lax.scan(
            jax.checkpoint(period), x, (params["mlstm_seg"], params["slstm"])
        )
        if new_cache is not None:
            new_cache["m_seg"], new_cache["s_seg"] = states
    if trailing:
        def inner(x, lyr):
            x, st = mlstm_block(x, lyr, cfg)
            return x, st
        x, tstates = jax.lax.scan(jax.checkpoint(inner), x, params["mlstm_tail"])
        if new_cache is not None:
            new_cache["m_tail"] = tstates
    return L.rms_norm(x, params["ln_final"], cfg.norm_eps), new_cache


def logits_fn(params, batch, cfg):
    h, _ = forward_hidden(params, batch, cfg)
    return L.unembed(h, params["lm_head"])


def loss(params, batch, cfg, *, loss_chunk: int = 512):
    h, _ = forward_hidden(params, batch, cfg)
    labels = batch["labels"]
    B, S, D = h.shape
    n_chunks = max(1, S // loss_chunk)
    hc = h.reshape(B, n_chunks, S // n_chunks, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n_chunks, S // n_chunks).transpose(1, 0, 2)

    def chunk_loss(args):
        hx, lx = args
        logits = L.unembed(hx, params["lm_head"])
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lx[..., None], axis=-1)[..., 0]
        return jnp.mean(logz - gold)

    return jnp.mean(jax.lax.map(jax.checkpoint(chunk_loss), (hc, lc)))


def init_cache(cfg, batch_size, max_len, dtype=None):
    n_per, m_per, trailing = layer_plan(cfg)
    di, H = d_inner(cfg), mlstm_heads(cfg)
    dh = di // H
    D = cfg.d_model
    dhs = D // cfg.n_heads
    mstate = jnp.zeros((batch_size, H, dh, dh + 1), jnp.float32)
    zeros = jnp.zeros((batch_size, cfg.n_heads, dhs), jnp.float32)
    sstate = {"c": zeros, "n": zeros, "h": zeros, "m": zeros}

    def rep(x, *dims):
        out = x
        for d in reversed(dims):
            out = jax.tree_util.tree_map(
                lambda l: jnp.broadcast_to(l[None], (d,) + l.shape), out)
        return out

    return {
        "m_seg": rep(mstate, n_per, m_per) if n_per and m_per else None,
        "s_seg": rep(sstate, n_per) if n_per else None,
        "m_tail": rep(mstate, trailing) if trailing else None,
    }


def prefill(params, batch, cfg, cache):
    h, new_cache = forward_hidden(params, batch, cfg, cache=cache)
    logits = L.unembed(h[:, -1:], params["lm_head"])
    return logits[:, 0], new_cache


def decode_step(params, cache, token, pos, cfg):
    B = token.shape[0]
    x = L.embed(token, params["embedding"])
    n_per, m_per, trailing = layer_plan(cfg)
    cache = dict(cache)

    if n_per:
        def period(x, seg):
            mls, sls, mst, sst = seg

            def inner(x, inp):
                lyr, st = inp
                x, nst = mlstm_block(x, lyr, cfg, state=st)
                return x, nst

            if m_per:
                x, nm = jax.lax.scan(inner, x, (mls, mst))
            else:
                nm = mst
            x, ns = slstm_block(x, sls, cfg, state=sst)
            return x, (nm, ns)

        x, (nm, ns) = jax.lax.scan(
            period, x,
            (params["mlstm_seg"], params["slstm"], cache["m_seg"], cache["s_seg"]),
        )
        cache.update(m_seg=nm, s_seg=ns)
    if trailing:
        def inner(x, inp):
            lyr, st = inp
            x, nst = mlstm_block(x, lyr, cfg, state=st)
            return x, nst
        x, nt = jax.lax.scan(inner, x, (params["mlstm_tail"], cache["m_tail"]))
        cache.update(m_tail=nt)

    h = L.rms_norm(x, params["ln_final"], cfg.norm_eps)
    return L.unembed(h, params["lm_head"])[:, 0], cache


register_family(
    Family(
        name="ssm",
        init_params=init_params,
        forward=logits_fn,
        loss=loss,
        init_cache=init_cache,
        prefill=prefill,
        decode_step=decode_step,
    )
)
