"""Architecture config + model registry + ShapeDtypeStruct input specs.

Every assigned architecture is an :class:`ArchConfig` instance in
``repro/configs/<id>.py``; families register a :class:`Family`
implementation here.  The launcher and dry-run only talk to this
interface.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    source: str                  # citation bracket from the assignment
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0              # 0 -> d_model // n_heads
    # --- attention options -------------------------------------------------
    rope_theta: float = 10000.0
    sliding_window: int = 0      # 0 = full attention
    local_global_pattern: bool = False   # gemma2: alternate local/global
    attn_logit_softcap: float = 0.0      # gemma2: 50.0
    final_logit_softcap: float = 0.0     # gemma2: 30.0
    tie_embeddings: bool = False
    mlp_variant: str = "swiglu"  # swiglu | geglu | gelu
    norm_eps: float = 1e-6
    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_expert: int = 0            # per-expert hidden dim (qwen2-moe: 1408)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    expert_pad: int = 0          # pad expert weight arrays for even sharding
    # --- SSM / hybrid ------------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 128
    attn_every: int = 0          # zamba2: shared attn block period
    slstm_every: int = 0         # xlstm: sLSTM block period (else mLSTM)
    # --- enc-dec / multimodal stubs -----------------------------------------
    n_enc_layers: int = 0        # whisper encoder depth
    enc_frames: int = 1500       # whisper: stub conv-frontend output length
    n_patches: int = 0           # vlm: stub vision-encoder output length
    max_seq: int = 8192
    # --- numerics ----------------------------------------------------------
    param_dtype: str = "bfloat16"

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: <=2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, 256)
        heads = min(self.n_heads, 4)
        kv = max(1, min(self.n_kv_heads, heads))
        while heads % kv:
            kv -= 1
        return self.replace(
            n_layers=2,
            d_model=d,
            n_heads=heads,
            n_kv_heads=kv,
            d_head=max(32, d // heads),
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            d_expert=min(self.d_expert, 256) if self.d_expert else 0,
            n_enc_layers=2 if self.n_enc_layers else 0,
            enc_frames=64,
            n_patches=16 if self.n_patches else 0,
            attn_every=2 if self.attn_every else 0,
            slstm_every=2 if self.slstm_every else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_chunk=32,
            max_seq=512,
        )


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Family:
    """Callable bundle implemented by each model family module."""

    name: str
    init_params: Callable        # (key, cfg) -> params
    forward: Callable            # (params, inputs, cfg) -> per-token loss or logits
    loss: Callable               # (params, batch, cfg) -> scalar mean loss
    init_cache: Callable         # (cfg, batch, max_len) -> cache pytree
    prefill: Callable            # (params, inputs, cfg, cache) -> (logits_last, cache)
    decode_step: Callable        # (params, cache, token, pos, cfg) -> (logits, cache)


_FAMILIES: dict = {}


def register_family(fam: Family):
    _FAMILIES[fam.name] = fam


def get_family(name: str) -> Family:
    if name not in _FAMILIES:
        # lazy import of family modules
        import repro.models.transformer  # noqa: F401
        import repro.models.moe  # noqa: F401
        import repro.models.hybrid  # noqa: F401
        import repro.models.xlstm  # noqa: F401
        import repro.models.whisper  # noqa: F401
        import repro.models.vlm  # noqa: F401
    return _FAMILIES[name]


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def input_specs(cfg: ArchConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this arch/shape.

    train:   tokens+labels (B, S)   [+ modality stub embeddings]
    prefill: tokens (B, S)
    decode:  token (B, 1) + positions; the KV cache itself is created by
             ``init_cache`` (also shape-only under jax.eval_shape).
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    out = {}
    if shape.kind == "train":
        out["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        out["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    elif shape.kind == "prefill":
        out["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    else:  # decode
        out["token"] = jax.ShapeDtypeStruct((B, 1), i32)
        out["pos"] = jax.ShapeDtypeStruct((B,), i32)
    if cfg.family == "audio":
        # stub conv/mel frontend: precomputed encoder frame embeddings
        out["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.enc_frames, cfg.d_model), cfg.pdtype
        )
    if cfg.family == "vlm":
        # stub vision encoder + projector: precomputed patch embeddings
        out["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.n_patches, cfg.d_model), cfg.pdtype
        )
    return out
