"""Sharding-rule unit tests: leaf_spec decisions on realistic shapes.

Runs on the single CPU device (NamedSharding construction only touches
metadata, never allocates on the 256-chip mesh)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config


@pytest.fixture(scope="module")
def mesh():
    # AbstractMesh: shape metadata without devices.  The constructor
    # signature changed across jax releases: >=0.5 takes (sizes, names),
    # 0.4.x takes a tuple of (name, size) pairs.
    try:
        return jax.sharding.AbstractMesh((16, 16), ("data", "model"))
    except TypeError:
        return jax.sharding.AbstractMesh((("data", 16), ("model", 16)))


def _spec(shape, cfg, mesh, role="master"):
    from repro.launch.shardings import leaf_spec
    return leaf_spec(shape, cfg, mesh, role)


def test_dense_master_rules(mesh):
    cfg = get_config("llama3.2-3b")
    D, H, Hkv, Dh, F, V = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                           cfg.head_dim, cfg.d_ff, cfg.vocab)
    L = cfg.n_layers
    # embedding: vocab over model, d_model ZeRO over data
    assert _spec((V, D), cfg, mesh) == P("model", "data")
    # mlp: ff over model, d_model over data
    assert _spec((L, D, F), cfg, mesh) == P(None, "data", "model")
    assert _spec((L, F, D), cfg, mesh) == P(None, "model", "data")
    # attention: 24 heads % 16 != 0 -> head_dim sharded (even rule)
    assert _spec((L, D, H, Dh), cfg, mesh) == P(None, "data", None, "model")
    # norm scales: d_model over data only
    assert _spec((L, D), cfg, mesh) == P(None, "data")


def test_moe_expert_sharding(mesh):
    cfg = get_config("olmoe-1b-7b")        # 64 experts, d_expert 1024
    L, E, D, F = cfg.n_layers, cfg.n_experts, cfg.d_model, cfg.d_expert
    spec = _spec((L, E, D, F), cfg, mesh)
    # one of experts / d_expert lands on model; d_model gets ZeRO data
    assert "model" in tuple(spec)
    assert spec[2] in ("data", None) or spec[1] in ("data",)


def test_client_role_leading_dim(mesh):
    cfg = get_config("smollm-360m")
    spec = _spec((16, cfg.n_layers, cfg.d_model, cfg.d_ff), cfg, mesh,
                 role="client")
    assert spec[0] == "data"
    assert "model" in tuple(spec)
    # d_model NOT ZeRO-sharded in client role (per-client copies)
    assert spec[2] is None


def test_client_all_axes_role(mesh):
    cfg = get_config("smollm-360m")
    spec = _spec((256, cfg.d_model, cfg.d_ff), cfg, mesh,
                 role="client_all_axes")
    assert spec[0] == ("data", "model")
    assert all(s is None for s in tuple(spec)[1:])


def test_serve_role_no_zero(mesh):
    cfg = get_config("gemma2-2b")
    spec = _spec((cfg.n_layers, cfg.d_model, cfg.d_ff), cfg, mesh,
                 role="serve")
    assert spec == P(None, None, "model")   # no data-axis ZeRO for serving


def test_all_archs_have_model_dim_on_big_leaves(mesh):
    """Every arch's ff-like matrices must shard over model (memory!)."""
    from repro.configs import ARCH_IDS
    from repro.launch.shardings import leaf_spec
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        if cfg.d_ff:
            spec = leaf_spec((cfg.n_layers, cfg.d_model, cfg.d_ff), cfg,
                             mesh, "master")
            assert "model" in tuple(spec), arch
