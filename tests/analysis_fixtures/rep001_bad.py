"""Seeded REP001 violation: the cache key omits a parameter that changes
the built value (the PR-6 ``dp_path`` plumbing gap, reduced)."""

_CACHE = {}


def cached_build(alpha, beta, gamma):
    key = (alpha, beta)                 # gamma missing from the key
    if key not in _CACHE:
        _CACHE[key] = alpha + beta + gamma
    return _CACHE[key]
