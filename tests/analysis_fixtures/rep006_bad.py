"""Seeded REP006 violation: per-client Python loops inside store
residency hot regions (prefetch/spill/acquire run once per cohort — an
O(N) population walk there is the scale wall the tiered store removes)."""


def prefetch_cids(store, cids):
    for c in store.clients:                 # walks ALL N clients
        if c.cid in cids:
            store.stage(c.cid)


def _evict_lru(store, keep):
    ticks = {c.cid: store.seq[c.cid]
             for c in store.clients}        # population comprehension
    victim = min(ticks, key=ticks.get)
    return store.spill(victim)


def acquire_cohort(store, clients, cids):
    return [store.slot_of[c.cid]
            for c in clients if c.cid in cids]   # filters N to find K
