"""Clean twin of rep004_bad: donated references are rebound by the
consuming statement (including the conditional-donation and
``self._write`` attribute-call idioms the engine uses)."""
import functools

import jax


@functools.partial(jax.jit, donate_argnums=(0,))
def step(arena, delta):
    return arena + delta


def make_write(donate):
    jit_kw = {"donate_argnums": (0,)} if donate else {}

    @functools.partial(jax.jit, **jit_kw)
    def write(buf, value):
        return buf.at[0].set(value)

    return write


def run_round(arena, delta):
    arena = step(arena, delta)          # rebound in the same statement
    return arena, arena.sum()


class Runner:
    def __init__(self, buf, donate):
        self._buf = buf
        self._write = make_write(donate)

    def submit_cohort(self, value):
        self._buf = self._write(self._buf, value)
        return self._buf
