"""Seeded REP002 violation: ``InnerConfig`` is reachable from the
registered spec root but absent from ``_SPEC_TYPES`` — encode/decode
would fail or silently drop the sub-config (the PR-6 ``use_kernel``
gap, reduced)."""
from dataclasses import dataclass, field


@dataclass(frozen=True)
class InnerConfig:
    depth: int = 1
    width: int = 8


@dataclass(frozen=True)
class OuterSpec:
    name: str = "run"
    inner: InnerConfig = field(default_factory=InnerConfig)


_SPEC_TYPES = {cls.__name__: cls for cls in (OuterSpec,)}
