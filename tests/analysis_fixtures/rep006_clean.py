"""Clean twin of rep006_bad: residency regions walk the cohort or the
prefetch batch — O(K) — and touch at most one INDEXED client; population
walks live outside the store regions (startup, roster building)."""


def prefetch_cids(store, cids):
    for cid in cids:                        # the prefetch batch, O(K)
        store.stage(cid)


def _materialize_plans(store, plans):
    for p in plans:                         # the cohort's plans, O(K)
        c = store.clients[p.cid]            # one indexed client is fine
        p.batch_idx = store.draw(c)


def build_roster(clients):
    # not a residency region: startup may walk the population freely
    return {c.cid: c for c in clients}
