"""Seeded REP004 violation: a buffer passed to a ``donate_argnums``
position is read again afterwards (the arena-donation use-after-free
class the PR-3/PR-4 call sites must avoid)."""
import functools

import jax


@functools.partial(jax.jit, donate_argnums=(0,))
def step(arena, delta):
    return arena + delta


def run_round(arena, delta):
    out = step(arena, delta)
    total = arena.sum()                 # arena was donated to step()
    return out, total
