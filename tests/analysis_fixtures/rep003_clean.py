"""Clean twin of rep003_bad: the mean uses the batch-derived count, and
the three legitimate static-count uses don't flag — a divisibility
guard, reshape-splitting arithmetic, and pure config-on-config math."""
import jax.numpy as jnp


def local_phase(batch, fl_cfg):
    if batch.shape[0] % fl_cfg.n_micro:
        raise ValueError("batch not divisible into microbatches")
    n_actual = batch.shape[0]
    mean = jnp.sum(batch, axis=0) / n_actual
    micro = batch.reshape(
        (fl_cfg.n_micro, batch.shape[0] // fl_cfg.n_micro) + batch.shape[1:])
    head_dim = fl_cfg.d_model // fl_cfg.n_heads
    return mean, micro, head_dim


def suppressed_phase(batch, fl_cfg):
    scale = batch.shape[0]
    return jnp.sum(batch) / fl_cfg.n_micro + scale  # rep-noqa: REP003 -- exercising the justified-suppression path
