"""Clean twin of rep002_bad: every dataclass reachable from the spec
root is registered.  ``Unrelated`` is a dataclass too, but nothing in
the registered set references it — unreachable types need no entry."""
from dataclasses import dataclass, field


@dataclass(frozen=True)
class InnerConfig:
    depth: int = 1
    width: int = 8


@dataclass(frozen=True)
class OuterSpec:
    name: str = "run"
    inner: InnerConfig = field(default_factory=InnerConfig)


@dataclass
class Unrelated:
    note: str = ""


_SPEC_TYPES = {cls.__name__: cls for cls in (OuterSpec, InnerConfig)}
