"""Clean twin of rep001_bad: every parameter reaches the key, either
directly or through one level of local derivation (the
``sh_key = _shardings_key(client_shardings)`` idiom)."""

_CACHE = {}


def _normalize(gamma):
    return tuple(sorted(gamma))


def cached_build(alpha, beta, gamma):
    g_key = _normalize(gamma)
    key = (alpha, beta, g_key)
    if key not in _CACHE:
        _CACHE[key] = (alpha, beta, sum(gamma))
    return _CACHE[key]


def not_a_cache_key(alpha, beta):
    # a tuple that is merely compared/returned is NOT a cache key:
    # omitting beta from it is fine
    marker = (alpha, "tag")
    return marker == ("x", "tag")
