"""Clean twin of rep005_bad: hot regions block only through the
``_host_fetch`` funnel (or the scheduling-only ``block_until_ready``);
``float`` on an already-host value is fine, and helpers outside the hot
regions may sync freely."""
import jax
import numpy as np


def run_async_engine(runner, cohorts):
    acc = 0.0
    for cohort in cohorts:
        out = runner.step(cohort)
        jax.block_until_ready(out)          # barrier, not a transfer
        acc += _host_fetch(runner, out)
        weight = runner.plan_weight(cohort)
        acc += float(weight)                # float on a host value
    return acc


def _host_fetch(runner, value):
    runner.note_host_sync()
    return float(value)


def summarize_run(outputs):
    # not a hot region: eval-side helpers may pull to host directly
    return np.asarray(jax.device_get(outputs)).mean().item()
