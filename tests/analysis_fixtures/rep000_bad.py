"""Seeded REP000 violation: a ``rep-noqa`` with no justification.  The
suppression does NOT take effect (REP003 still fires) and the bare
comment itself is a finding."""
import jax.numpy as jnp


def local_phase(batch, fl_cfg):
    n_actual = batch.shape[0]
    return jnp.sum(batch) / fl_cfg.n_micro + n_actual  # rep-noqa: REP003
