"""Seeded REP003 violation: a traced body with a batch-derived dimension
in hand divides data by the STATIC config count (the PR-6 ``fl.n_micro``
grad-mean/noise-stddev scaling bug, reduced)."""
import jax.numpy as jnp


def local_phase(batch, fl_cfg):
    n_actual = batch.shape[0]
    grads = jnp.sum(batch, axis=0)
    mean = grads / fl_cfg.n_micro       # wrong when n_actual != n_micro
    return mean, n_actual
