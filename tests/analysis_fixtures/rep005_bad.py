"""Seeded REP005 violation: raw device->host syncs inside an engine hot
region (the pipelined submit/drain loop must only block through the
``_host_fetch`` funnel at eval boundaries)."""
import jax
import numpy as np


def run_async_engine(runner, cohorts):
    acc = 0.0
    for cohort in cohorts:
        out = runner.step(cohort)
        acc += float(runner.fetch(out))     # float(<call>) blocks the host
        snapshot = np.asarray(out)          # so does np.asarray
        runner.record(snapshot)
    return acc


def submit_cohort(runner, staged):
    runner.inflight.append(runner.step(staged))
    return jax.device_get(runner.inflight[-1])
