"""Moments Accountant: unit + property tests (hypothesis when installed,
fixed parametrized cases otherwise — see tests/_hypothesis_compat.py).

Anchors: Abadi et al. report eps ~= 1.26 for q=0.01, sigma=4, T=1e4,
delta=1e-5 with the moments accountant — we must land within a few percent.
"""
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.accountant import (
    DEFAULT_ORDERS,
    MomentsAccountant,
    cached_epsilon_schedule,
    cached_log_moments,
    compute_epsilon,
    delta_from_moments,
    epsilon_from_moments,
    log_moment_subsampled_gaussian,
    log_moments_vector,
    use_fast_accounting,
)


def test_abadi_anchor():
    eps = compute_epsilon(q=0.01, sigma=4.0, steps=10_000, delta=1e-5)
    assert 1.15 < eps < 1.35, eps


def test_strong_composition_beats_naive():
    """MA must beat naive eps*T composition by a wide margin."""
    eps1 = compute_epsilon(q=0.01, sigma=4.0, steps=1, delta=1e-5)
    epsT = compute_epsilon(q=0.01, sigma=4.0, steps=10_000, delta=1e-5)
    assert epsT < 0.05 * eps1 * 10_000


def test_zero_noise_is_infinite():
    assert math.isinf(compute_epsilon(0.1, 0.0, 10, 1e-5))


def test_q_zero_is_free():
    assert compute_epsilon(0.0, 1.0, 1000, 1e-5) == pytest.approx(0.0)


@settings(max_examples=60, deadline=None)
@given(
    q=st.floats(0.001, 0.5),
    sigma=st.floats(0.3, 4.0),
    lam=st.integers(1, 32),
)
def test_log_moment_nonnegative_finite(q, sigma, lam):
    mu = log_moment_subsampled_gaussian(q, sigma, lam)
    assert mu >= -1e-9
    assert math.isfinite(mu)


@settings(max_examples=40, deadline=None)
@given(
    q=st.floats(0.01, 0.3),
    sigma=st.floats(0.5, 3.0),
    t1=st.integers(1, 200),
    t2=st.integers(1, 200),
)
def test_epsilon_monotone_in_steps(q, sigma, t1, t2):
    """More steps => more privacy loss (composability, paper Sec 2.3)."""
    lo, hi = sorted((t1, t2))
    e_lo = compute_epsilon(q, sigma, lo, 1e-5)
    e_hi = compute_epsilon(q, sigma, hi, 1e-5)
    assert e_hi >= e_lo - 1e-12


@settings(max_examples=40, deadline=None)
@given(
    q=st.floats(0.01, 0.3),
    s1=st.floats(0.4, 3.0),
    s2=st.floats(0.4, 3.0),
    steps=st.integers(1, 300),
)
def test_epsilon_monotone_in_sigma(q, s1, s2, steps):
    """More noise => less privacy loss (paper Sec 4.2.3 observation)."""
    lo, hi = sorted((s1, s2))
    e_weak = compute_epsilon(q, lo, steps, 1e-5)
    e_strong = compute_epsilon(q, hi, steps, 1e-5)
    assert e_strong <= e_weak + 1e-9


@settings(max_examples=30, deadline=None)
@given(
    q=st.floats(0.01, 0.3),
    sigma=st.floats(0.5, 3.0),
    steps=st.integers(1, 100),
)
def test_additivity_of_moments(q, sigma, steps):
    """mu(lambda) of k steps == k * mu(lambda) of one step (paper Eq. 8)."""
    a = MomentsAccountant()
    a.step(q, sigma, steps)
    b = MomentsAccountant()
    for _ in range(min(steps, 10)):
        b.step(q, sigma, 1)
    if steps <= 10:
        np.testing.assert_allclose(a._mu, b._mu, rtol=1e-12)


def test_eps_delta_roundtrip():
    acc = MomentsAccountant()
    acc.step(0.1, 1.0, 50)
    eps = acc.epsilon(1e-5)
    # delta at that eps should be <= 1e-5 (tightness of min over lambda)
    assert acc.delta(eps) <= 1e-5 * (1 + 1e-6)


# ---------------------------------------------------------------------------
# dispatch-time fast path: vectorized + memoized one-step moments
# ---------------------------------------------------------------------------

def test_vectorized_moments_match_scalar_on_paper_grid():
    """The one-pass vector must equal the scalar loop to 1e-12 across the
    paper's sigma grid and representative sampling ratios, including the
    q=0 / q=1 / sigma=0 edge cases."""
    for sigma in (0.5, 1.0, 1.5, 2.0):
        for q in (0.0, 1e-4, 0.01, 0.136, 0.5, 0.9, 1.0):
            vec = log_moments_vector(q, sigma, DEFAULT_ORDERS)
            ref = np.array([log_moment_subsampled_gaussian(q, sigma, lam)
                            for lam in DEFAULT_ORDERS])
            np.testing.assert_allclose(vec, ref, rtol=0, atol=1e-12)
    # sigma = 0: unbounded privacy loss at every order
    assert np.isinf(log_moments_vector(0.136, 0.0, DEFAULT_ORDERS)).all()
    with pytest.raises(ValueError, match="outside"):
        log_moments_vector(1.5, 1.0, DEFAULT_ORDERS)


def test_cached_vector_is_shared_and_readonly():
    a = cached_log_moments(0.136, 1.0)
    b = cached_log_moments(0.136, 1.0)
    assert a is b                       # memoized per (q, sigma, orders)
    with pytest.raises(ValueError):
        a[0] = 1.0                      # accountants must not mutate it


def test_fast_and_scalar_accounting_agree():
    """MomentsAccountant.step with the memoized fast path must reproduce
    the scalar recomputation path exactly (the engine's dispatch-time
    bookkeeping is compared verbatim against the legacy loop's)."""
    prev = use_fast_accounting(False)
    try:
        scalar = MomentsAccountant()
        scalar.step(0.136, 0.5, 3)
        scalar.step(0.136, 0.5, 3)
    finally:
        use_fast_accounting(prev)
    fast = MomentsAccountant()
    fast.step(0.136, 0.5, 3)
    fast.step(0.136, 0.5, 3)
    np.testing.assert_allclose(fast._mu, scalar._mu, rtol=0, atol=1e-12)
    assert fast.epsilon(1e-5) == pytest.approx(scalar.epsilon(1e-5),
                                               abs=1e-12)


def test_epsilon_schedule_matches_stepped_accountant():
    """The precomputed eps-vs-round table must replay the accountant's
    exact accumulation: entry r == an accountant charged r rounds."""
    q, sigma, steps, delta = 0.136, 0.5, 3, 1e-5
    sched = cached_epsilon_schedule(q, sigma, steps, delta)
    acc = MomentsAccountant()
    assert sched.epsilon_after_rounds(0) == 0.0
    for r in range(1, 15):
        acc.step(q, sigma, steps)
        assert sched.epsilon_after_rounds(r) == acc.epsilon(delta), r
    # random access after the sequential fill is a pure lookup
    assert sched.epsilon_after_rounds(7) == sched._eps[7]
    # degenerate config: no full batch => no charged steps, eps stays 0
    empty = cached_epsilon_schedule(0.5, 1.0, 0, delta)
    assert empty.epsilon_after_rounds(10) == 0.0
    with pytest.raises(ValueError, match="rounds"):
        sched.epsilon_after_rounds(-1)
    assert cached_epsilon_schedule(q, sigma, steps, delta) is sched


def test_heterogeneous_clients_disparity():
    """A client updating 6x more often accrues much larger eps — the
    paper's central privacy-disparity mechanism (Table 3).  Note eps is
    sublinear in steps (composition is sqrt-ish), so 6x updates yields
    ~2.6x eps at sigma=0.5 — the paper's 5x gap corresponds to its larger
    observed participation ratios."""
    slow, fast = MomentsAccountant(), MomentsAccountant()
    slow.step(0.136, 0.5, 8)          # HW_T1-ish: few rounds
    fast.step(0.136, 0.5, 48)         # HW_T5-ish: 6x the rounds
    e_slow, e_fast = slow.epsilon(1e-5), fast.epsilon(1e-5)
    assert e_fast > 2.0 * e_slow
