"""Optional-``hypothesis`` shim for the property-based test modules.

When hypothesis is installed (CI installs it — see requirements.txt) the
real ``given``/``settings``/``strategies`` are re-exported and nothing
changes.  On a bare install the shim degrades each ``@given`` into a
``pytest.mark.parametrize`` over a deterministic set of fixed cases
(strategy endpoints plus seeded interior draws), so the tier-1 suite
collects and runs everywhere.
"""
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare installs
    HAVE_HYPOTHESIS = False

    import random

    import pytest

    _N_INTERIOR = 4  # seeded draws per @given, on top of the two endpoints

    class _Strategy:
        def __init__(self, lo, hi, draw):
            self.lo, self.hi, self._draw = lo, hi, draw

        def draw(self, rng):
            return self._draw(rng)

    class _StrategiesShim:
        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(min_value, max_value,
                             lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(min_value, max_value,
                             lambda r: r.randint(min_value, max_value))

    st = _StrategiesShim()

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    def given(**strats):
        names = sorted(strats)
        rng = random.Random(0xC0FFEE)
        cases = [tuple(strats[n].lo for n in names),
                 tuple(strats[n].hi for n in names)]
        cases += [tuple(strats[n].draw(rng) for n in names)
                  for _ in range(_N_INTERIOR)]

        def deco(fn):
            return pytest.mark.parametrize(",".join(names), cases)(fn)

        return deco
