"""dp_path engine parity: the fused Pallas clip+noise hot path must be a
pure implementation swap — params allclose vs the jnp path and the legacy
per-client loop with IDENTICAL privacy/update bookkeeping, on both the
single-device unroll executor and the forced-8-device sharded mesh, and
one compiled program across the paper's whole sigma grid (the runtime
noise-stddev argument)."""
from dataclasses import replace

import jax
import numpy as np
import pytest

from repro.core.testbed import TestbedConfig, run_experiment
from repro.data.synthetic_ser import SERDataConfig
from repro.engine import EngineConfig, cohort_step
from repro.models.ser_cnn import SERConfig

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs multiple devices (CI: XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")

# Tiny model on purpose: interpret-mode pallas unrolls the whole kernel
# grid into the traced program, so compile time scales with param count —
# the small CNN keeps the grid a handful of tiles while exercising the
# identical multi-leaf conv/dense tree structure.
_DIMS = dict(time_frames=12, n_mels=12)


def _dp_cfg(dp_path, num_clients=5, seed=3):
    return TestbedConfig(
        use_dp=True, sigma=1.0, batch_size=16, num_clients=num_clients,
        data=SERDataConfig(n_total=72 * num_clients, **_DIMS),
        model=SERConfig(channels1=8, channels2=16, fc_dim=32, **_DIMS),
        seed=seed, dp_path=dp_path)


def _assert_params_close(a, b, rtol=1e-4, atol=1e-5):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


def _assert_books_match(log_a, log_b):
    assert log_a.update_counts == log_b.update_counts
    assert log_a.eps_trajectory == log_b.eps_trajectory
    assert log_a.staleness == log_b.staleness
    assert log_a.times == log_b.times


# ---------------------------------------------------------------------------
# unroll executor: pallas vs jnp vs legacy (the tentpole acceptance)
# ---------------------------------------------------------------------------

def test_engine_pallas_matches_jnp_and_legacy():
    """Three executions of the same DP FedAsync run — engine/jnp,
    engine/pallas (ONE fused kernel launch per cohort step), and the
    legacy loop routed through the pallas dp_path — must agree: the noise
    epilogue replays ``noise_tree``'s exact Gaussian draws, so this is a
    tight comparison, not a statistical one."""
    kw = dict(max_updates=10, eval_every=5, alpha=0.4)
    cfg_j = _dp_cfg("jnp")
    cfg_p = _dp_cfg("pallas")
    p_j, log_j = run_experiment("fedasync", cfg_j, engine="cohort", **kw)
    p_p, log_p = run_experiment("fedasync", cfg_p, engine="cohort", **kw)
    p_l, log_l = run_experiment("fedasync", cfg_p, engine="legacy", **kw)
    _assert_params_close(p_j, p_p)
    _assert_params_close(p_l, p_p)
    _assert_books_match(log_j, log_p)
    _assert_books_match(log_l, log_p)
    # provenance: the run must record which DP path executed and, for the
    # kernel path, the resolved interpret decision + its source
    assert log_j.engine_stats["dp_path"] == "jnp"
    assert log_j.engine_stats["pallas_interpret"] is None
    assert log_p.engine_stats["dp_path"] == "pallas"
    info = log_p.engine_stats["pallas_interpret"]
    assert info["backend"] == jax.default_backend()
    assert info["source"] in ("override", "env", "auto")


def test_engine_pallas_windowed_cohorts_match_jnp():
    """Multi-member cohorts through the step-major fused executor: one
    kernel launch per local step over the stacked (K*B, D) matrix."""
    kw = dict(max_updates=8, eval_every=4, alpha=0.4, engine="cohort")
    ec = EngineConfig(staleness_window=1e9, max_cohort=4)
    p_j, log_j = run_experiment("fedasync", _dp_cfg("jnp"),
                                engine_cfg=ec, **kw)
    p_p, log_p = run_experiment("fedasync", _dp_cfg("pallas"),
                                engine_cfg=ec, **kw)
    _assert_params_close(p_j, p_p)
    _assert_books_match(log_j, log_p)
    assert log_j.cohort_sizes == log_p.cohort_sizes
    assert max(log_p.cohort_sizes) > 1     # the window actually batched


def test_engine_rejects_pallas_with_fl_step_axis():
    """client_axis='fl_step' runs the production per-microbatch DP
    mechanism — the per-example kernel cannot substitute for it."""
    with pytest.raises(ValueError, match="fl_step"):
        run_experiment(
            "fedasync", _dp_cfg("pallas"),
            max_updates=2, eval_every=2, alpha=0.4, engine="cohort",
            engine_cfg=EngineConfig(client_axis="fl_step"))


def test_engine_rejects_unknown_dp_path():
    with pytest.raises(ValueError, match="dp_path"):
        run_experiment("fedasync", _dp_cfg("triton"),
                       max_updates=2, eval_every=2, alpha=0.4,
                       engine="cohort")


# ---------------------------------------------------------------------------
# sigma grid: one compiled program (the PR-5 runtime-noise invariant)
# ---------------------------------------------------------------------------

def test_pallas_sigma_sweep_shares_one_compiled_step():
    """The fused kernel takes noise_stddev as a RUNTIME scalar: after the
    first sigma compiles, the rest of the paper's grid must replay the
    same program (step_builds delta == 0), each agreeing with the jnp
    path at its own sigma."""
    sigmas = (0.5, 1.0, 1.5, 2.0)
    kw = dict(max_updates=6, eval_every=6, alpha=0.4, engine="cohort")

    def run(path, sigma):
        return run_experiment(
            "fedasync", replace(_dp_cfg(path), sigma=sigma), **kw)

    run("pallas", sigmas[0])               # compile both paths once
    run("jnp", sigmas[0])
    b0 = cohort_step.step_builds()
    for sg in sigmas:
        p_p, log_p = run("pallas", sg)
        p_j, log_j = run("jnp", sg)
        _assert_params_close(p_j, p_p)
        _assert_books_match(log_j, log_p)
    assert cohort_step.step_builds() == b0


# ---------------------------------------------------------------------------
# sharded mesh: padded uneven cohorts through the fused kernel
# ---------------------------------------------------------------------------

def _mesh_dp_cfg(dp_path):
    return _dp_cfg(dp_path, num_clients=len(jax.devices()), seed=0)


@multi_device
def test_sharded_padded_cohorts_pallas_matches_jnp():
    """UNEVEN cohorts (max_cohort not dividing the data axis) on the
    forced-8-device mesh: the arena path pads them to the bucket size —
    padded members must contribute nothing through the kernel (their
    zero gradients clip to zero and their updates are masked out)."""
    from repro.engine import cohort_mesh
    mesh = cohort_mesh()
    n = mesh.shape["data"]
    k = max(2, (3 * n) // 4)
    if k % n == 0:
        pytest.skip(f"{n} devices admit no uneven max_cohort")
    ec = EngineConfig(staleness_window=1e9, max_cohort=k,
                      client_axis="vmap", mesh=mesh, pow2_cohorts=False)
    kw = dict(max_updates=2 * k, eval_every=k, alpha=0.4, engine="cohort")
    p_j, log_j = run_experiment("fedasync", _mesh_dp_cfg("jnp"),
                                engine_cfg=ec, **kw)
    p_p, log_p = run_experiment("fedasync", _mesh_dp_cfg("pallas"),
                                engine_cfg=ec, **kw)
    _assert_params_close(p_j, p_p)
    _assert_books_match(log_j, log_p)
    assert log_j.cohort_sizes == log_p.cohort_sizes
    assert log_p.engine_stats["dp_path"] == "pallas"
    for leaf in jax.tree_util.tree_leaves(p_p):
        assert bool(np.isfinite(np.asarray(leaf)).all())
