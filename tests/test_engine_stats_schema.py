"""The frozen engine-stats schema: ``CohortRunner.stats()`` emits exactly
``ENGINE_STATS_KEYS`` (order included), ``validate_engine_stats`` is the
single drift detector shared by the engine, the analysis audits and
``summarize.py --check-engine``, and a real runner's stats pass the
cross-field audit."""
import pytest

from repro.core.runlog import ENGINE_STATS_KEYS, validate_engine_stats
from repro.core.testbed import build_testbed
from repro.engine import CohortRunner, EngineConfig


@pytest.fixture(scope="module")
def runner_stats(micro_cfg):
    clients, params, _, _ = build_testbed(micro_cfg)
    return CohortRunner(clients, EngineConfig()).stats()


def test_runner_stats_match_frozen_schema(runner_stats):
    assert tuple(runner_stats.keys()) == ENGINE_STATS_KEYS


def test_validate_returns_the_same_dict(runner_stats):
    assert validate_engine_stats(runner_stats) is runner_stats


def test_missing_key_is_named():
    stats = {k: 0 for k in ENGINE_STATS_KEYS}
    del stats["drain_waits"]
    with pytest.raises(ValueError, match="drain_waits"):
        validate_engine_stats(stats)


def test_extra_key_is_named():
    stats = {k: 0 for k in ENGINE_STATS_KEYS}
    stats["surprise_counter"] = 9
    with pytest.raises(ValueError, match="surprise_counter"):
        validate_engine_stats(stats, context="test stats")


def test_real_stats_pass_the_audit(runner_stats):
    from repro.analysis.audits import audit_engine_stats
    audit_engine_stats(runner_stats)
