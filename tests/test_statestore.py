"""Tiered client-state store tests (engine/statestore.py + integration).

The contract under test (STORE.md): a tiered run — device hot set bounded
to ``StoreConfig.hot_slots``, host cold store, event-heap lookahead
prefetch — produces params and a RunLog **bit-identical** to the
all-resident arena, on the serial and pipelined drivers, across a
crash/resume, and on a forced multi-device mesh; the store's counters
satisfy the ledger law ``store_fetches == store_hot_hits +
store_prefetch_hits + store_stall_waits``; and the lazy-dispatch fix
keeps per-round work O(cohort), not O(population) (regression-counted at
N=10k).  Dataset rows live in their own identity-deduped
:class:`~repro.engine.statestore.DataArena`, which the Session keeps
warm across client-state-only sweep axes (sigma) so the re-upload is
skipped entirely.
"""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import jax.random as jr
import numpy as np
import pytest

from repro.core.aggregation import FedAsync
from repro.core.testbed import TestbedConfig, build_clients, build_partitions
from repro.data.synthetic_ser import SERDataConfig
from repro.engine import (
    CohortRunner, EngineConfig, StoreConfig,
    run_async_engine, run_fedavg_engine)
from repro.engine.statestore import DataArena
from repro.models.ser_cnn import SERConfig

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices (CI: XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")

_DIMS = dict(time_frames=12, n_mels=12)


@pytest.fixture(scope="module")
def store_tb():
    """16 tiny clients — small enough that the all-resident reference
    arena is cheap, big enough that hot_slots=6 forces real evictions."""
    n = 16
    return TestbedConfig(
        use_dp=True, sigma=0.5, batch_size=16, num_clients=n,
        data=SERDataConfig(n_total=36 * n, **_DIMS),
        model=SERConfig(channels1=8, channels2=16, fc_dim=32, **_DIMS))


@pytest.fixture(scope="module")
def store_world(store_tb):
    from repro.api.workloads import get_workload
    splits, pooled = build_partitions(store_tb)
    wl = get_workload(store_tb.workload)
    params0 = wl.init(jr.PRNGKey(store_tb.seed), store_tb.model)
    acc_fn = wl.shared_accuracy(store_tb.model)
    return splits, pooled, params0, acc_fn


def _runner(tb, splits, store, mesh=None, **kw):
    clients = build_clients(tb, splits)
    kw = {"staleness_window": 30.0, "max_cohort": 4,
          "pipeline_depth": 2, **kw}
    cfg = EngineConfig(store=store, mesh=mesh, **kw)
    return clients, CohortRunner(clients, cfg)


def _leaves_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


def _store_stats(log):
    return {k: v for k, v in log.engine_stats.items()
            if k.startswith("store_")}


def _assert_ledger(stats):
    assert stats["store_fetches"] == (
        stats["store_hot_hits"] + stats["store_prefetch_hits"]
        + stats["store_stall_waits"]), stats


def _logs_equal_ex_stats(a, b):
    """RunLog equality excluding engine_stats (H2D/store counters
    legitimately differ between tiered and all-resident)."""
    assert a.times == b.times
    assert a.global_acc == b.global_acc
    assert a.staleness == b.staleness
    assert a.influence == b.influence
    assert a.update_counts == b.update_counts
    assert a.eps_trajectory == b.eps_trajectory
    assert a.cohort_sizes == b.cohort_sizes


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------

def test_storeconfig_validation():
    with pytest.raises(ValueError, match="hot_slots"):
        StoreConfig(hot_slots=0)
    with pytest.raises(ValueError, match="hot_slots"):
        StoreConfig(hot_slots=2.5)
    with pytest.raises(ValueError, match="lookahead"):
        StoreConfig(lookahead=-1)
    assert StoreConfig().hot_slots is None          # all-resident default


def test_engineconfig_guards_tiering():
    with pytest.raises(ValueError, match="max_cohort"):
        EngineConfig(max_cohort=8, store=StoreConfig(hot_slots=4))
    with pytest.raises(ValueError, match="device_arena"):
        EngineConfig(device_arena=False, store=StoreConfig(hot_slots=8))


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def test_split_key_chain_bitwise():
    from repro.engine.engine import split_key_chain
    key = jr.PRNGKey(7)
    k_ref, subs_ref = key, []
    for _ in range(9):
        k_ref, sub = jr.split(k_ref)
        subs_ref.append(np.asarray(sub))
    k_new, subs = split_key_chain(jr.PRNGKey(7), 9)
    assert np.array_equal(np.asarray(k_new), np.asarray(k_ref))
    assert np.array_equal(subs, np.stack(subs_ref))


def test_data_arena_dedupes_shared_rows(store_tb, store_world):
    splits, _, _, _ = store_world
    clients = build_clients(store_tb, splits)
    put = lambda b: jnp.asarray(b)
    distinct = DataArena.build(clients, 1, put)
    assert distinct.pad_slot == len(clients)
    assert np.array_equal(distinct.slot_of_cid,
                          np.arange(len(clients), dtype=np.int32))
    # every client referencing ONE dict uploads ONE row (+ the pad row)
    shared = build_clients(store_tb, [splits[0]] * len(clients))
    arena = DataArena.build(shared, 1, put)
    assert arena.pad_slot == 1 and arena.n_slots == 2
    assert set(arena.slot_of_cid.tolist()) == {0}
    assert arena.nbytes < distinct.nbytes / 4


# ---------------------------------------------------------------------------
# tiered vs all-resident: bit-identical
# ---------------------------------------------------------------------------

def test_tiered_async_parity(store_tb, store_world):
    splits, pooled, params0, acc_fn = store_world

    def go(store):
        clients, runner = _runner(store_tb, splits, store)
        return run_async_engine(
            clients, params0, acc_fn, pooled, FedAsync(alpha=0.5),
            max_updates=40, seed=0, eval_every=10, runner=runner)

    p_res, log_res = go(StoreConfig())
    p_tier, log_tier = go(StoreConfig(hot_slots=6, lookahead=4))
    assert _leaves_equal(p_res, p_tier)
    _logs_equal_ex_stats(log_res, log_tier)
    st = _store_stats(log_tier)
    _assert_ledger(st)
    assert st["store_fetches"] > 0
    assert st["store_prefetch_hits"] > 0       # the prefetcher is live
    assert st["store_evictions"] > 0           # hot 6 < 16 forces churn
    assert st["store_spill_bytes"] > 0         # dirty rows really spill
    # every device->host read went through the _in_store funnel: the
    # pipelined scheduler still never blocks between eval boundaries
    assert log_tier.engine_stats["host_syncs_between_evals"] == 0
    assert log_tier.engine_stats["store_sync_reads"] > 0
    assert all(v == 0 for v in _store_stats(log_res).values())


def test_tiered_fedavg_parity(store_tb, store_world):
    splits, pooled, params0, acc_fn = store_world

    def go(store):
        clients, runner = _runner(store_tb, splits, store)
        return run_fedavg_engine(
            clients, params0, acc_fn, pooled, rounds=3,
            seed=0, eval_every=3, runner=runner)

    p_res, log_res = go(StoreConfig())
    p_tier, log_tier = go(StoreConfig(hot_slots=6, lookahead=4))
    assert _leaves_equal(p_res, p_tier)
    _logs_equal_ex_stats(log_res, log_tier)
    st = _store_stats(log_tier)
    _assert_ledger(st)
    # a 16-client barrier round over 6 hot slots cycles every chunk
    assert st["store_fetches"] >= 3 * 16
    assert st["store_evictions"] > 0
    assert st["store_prefetch_hits"] > 0       # next-chunk prefetch
    assert log_tier.engine_stats["host_syncs_between_evals"] == 0


def test_lookahead_zero_is_all_demand_misses(store_tb, store_world):
    """Prefetch off: every non-resident member is a counted demand
    stall, and the result is STILL bit-identical (the prefetcher is a
    latency optimization, never a semantics change)."""
    splits, pooled, params0, acc_fn = store_world

    def go(store):
        clients, runner = _runner(store_tb, splits, store)
        return run_async_engine(
            clients, params0, acc_fn, pooled, FedAsync(alpha=0.5),
            max_updates=20, seed=0, eval_every=10, runner=runner)

    p_res, log_res = go(StoreConfig())
    p_tier, log_tier = go(StoreConfig(hot_slots=6, lookahead=0))
    assert _leaves_equal(p_res, p_tier)
    _logs_equal_ex_stats(log_res, log_tier)
    st = _store_stats(log_tier)
    _assert_ledger(st)
    assert st["store_prefetch_hits"] == 0
    assert st["store_stall_waits"] > 0


# ---------------------------------------------------------------------------
# O(N) dispatch regression (satellite: lazy/batched startup)
# ---------------------------------------------------------------------------

def test_dispatch_stays_o_cohort_at_10k_clients(store_tb, store_world,
                                                monkeypatch):
    """At N=10k (every client sharing ONE dataset row), a short tiered
    run must draw batch permutations only for STAGED cohort members —
    the old eager dispatch drew all N at startup (O(N * S) host work per
    run, the wall this PR's lazy plans removed)."""
    import repro.engine.engine as eng
    splits, pooled, params0, acc_fn = store_world
    n = 10_000
    clients = build_clients(store_tb, [splits[0]] * n)
    calls = {"n": 0}
    real = eng.plan_batches

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(eng, "plan_batches", counting)
    cfg = EngineConfig(staleness_window=30.0, max_cohort=4,
                       pipeline_depth=2,
                       store=StoreConfig(hot_slots=64, lookahead=8))
    runner = CohortRunner(clients, cfg)
    _, log = run_async_engine(
        clients, params0, acc_fn, pooled, FedAsync(alpha=0.5),
        max_updates=12, seed=0, eval_every=12, runner=runner)
    assert sum(log.update_counts.values()) >= 12
    # staged members only: bounded by updates + in-flight slack, never N
    assert 0 < calls["n"] < 100, calls["n"]
    st = _store_stats(log)
    _assert_ledger(st)
    assert st["store_fetches"] > 0


# ---------------------------------------------------------------------------
# checkpoint / resume
# ---------------------------------------------------------------------------

def _crash_resume(tb, splits, pooled, params0, acc_fn, store, mesh=None):
    from repro.engine.resilience import CheckpointPolicy, SimulatedCrash

    def go(**kw):
        clients, runner = _runner(tb, splits, store, mesh=mesh)
        return run_async_engine(
            clients, params0, acc_fn, pooled, FedAsync(alpha=0.5),
            max_updates=40, seed=0, eval_every=10, runner=runner, **kw)

    p_ref, log_ref = go()
    with tempfile.TemporaryDirectory() as d:
        pol = CheckpointPolicy(d, every=8, crash_after_saves=2)
        with pytest.raises(SimulatedCrash):
            go(checkpoint=pol)
        p_res, log_res = go(resume_from=d)
        assert _leaves_equal(p_ref, p_res)
        _logs_equal_ex_stats(log_ref, log_res)
        # the resumed run replays the SAME residency/prefetch schedule:
        # even the store counters land identical to the uninterrupted run
        assert _store_stats(log_res) == _store_stats(log_ref)
        _assert_ledger(_store_stats(log_res))
        assert _store_stats(log_ref)["store_evictions"] > 0
        # refusing a mismatched tier layout beats silently diverging
        clients, runner = _runner(
            tb, splits,
            dataclasses.replace(store, hot_slots=store.hot_slots + 2),
            mesh=mesh)
        with pytest.raises(ValueError, match="StoreConfig mismatch"):
            run_async_engine(
                clients, params0, acc_fn, pooled, FedAsync(alpha=0.5),
                max_updates=40, seed=0, eval_every=10, runner=runner,
                resume_from=d)


def test_tiered_crash_resume_bit_identical(store_tb, store_world):
    splits, pooled, params0, acc_fn = store_world
    _crash_resume(store_tb, splits, pooled, params0, acc_fn,
                  StoreConfig(hot_slots=6, lookahead=4))


# ---------------------------------------------------------------------------
# forced-8-device mesh (CI engine-mesh job)
# ---------------------------------------------------------------------------

@multi_device
def test_tiered_mesh_parity(store_tb, store_world):
    from repro.engine import cohort_mesh
    splits, pooled, params0, acc_fn = store_world
    mesh = cohort_mesh(8)

    def go(store):
        clients, runner = _runner(store_tb, splits, store, mesh=mesh,
                                  max_cohort=8)
        return run_async_engine(
            clients, params0, acc_fn, pooled, FedAsync(alpha=0.5),
            max_updates=24, seed=0, eval_every=8, runner=runner)

    p_res, log_res = go(StoreConfig())
    p_tier, log_tier = go(StoreConfig(hot_slots=8, lookahead=6))
    assert _leaves_equal(p_res, p_tier)
    _logs_equal_ex_stats(log_res, log_tier)
    st = _store_stats(log_tier)
    _assert_ledger(st)
    assert st["store_evictions"] > 0
    assert log_tier.engine_stats["host_syncs_between_evals"] == 0


@multi_device
def test_tiered_mesh_crash_resume(store_tb, store_world):
    from repro.engine import cohort_mesh
    splits, pooled, params0, acc_fn = store_world
    _crash_resume(store_tb, splits, pooled, params0, acc_fn,
                  StoreConfig(hot_slots=8, lookahead=6),
                  mesh=cohort_mesh(8))


# ---------------------------------------------------------------------------
# Session keeps the dataset arena warm (satellite: sigma-only sweeps
# skip the re-upload)
# ---------------------------------------------------------------------------

def test_session_sweep_reuses_data_arena(store_tb):
    from repro.api import ExperimentSpec, RunBudget, Session, StrategySpec
    sess = Session()
    spec = ExperimentSpec(
        testbed=store_tb,
        strategy=StrategySpec("fedasync", alpha=0.5),
        run=RunBudget(max_updates=10, eval_every=10),
        engine=EngineConfig(staleness_window=30.0, max_cohort=4))
    sess.run(spec)
    arena0 = sess._runner.data_arena
    leaves0 = {k: id(v) for k, v in arena0.leaves.items()}
    sigma2 = dataclasses.replace(store_tb, sigma=1.5)
    sess.run(dataclasses.replace(spec, testbed=sigma2))
    assert sess.events["data_arena_builds"] == 1
    assert sess.events["data_arena_reuses"] == 1
    # the second scenario's runner holds the SAME device buffers — the
    # dataset bytes crossed the H2D link exactly once
    assert sess._runner.data_arena is arena0
    assert {k: id(v) for k, v in sess._runner.data_arena.leaves.items()} \
        == leaves0
    assert len(sess._data_arenas) == 1


# ---------------------------------------------------------------------------
# audit: the ledger law is enforced
# ---------------------------------------------------------------------------

def test_store_ledger_audit_fires(store_tb, store_world):
    from repro.analysis.audits import AuditFailure, audit_engine_stats
    splits, pooled, params0, acc_fn = store_world
    clients, runner = _runner(store_tb, splits,
                              StoreConfig(hot_slots=6, lookahead=4))
    _, log = run_async_engine(
        clients, params0, acc_fn, pooled, FedAsync(alpha=0.5),
        max_updates=20, seed=0, eval_every=10, runner=runner)
    audit_engine_stats(log.engine_stats)       # the real run balances
    bad = dict(log.engine_stats)
    bad["store_hot_hits"] += 1                 # cook the books
    with pytest.raises(AuditFailure, match="store ledger"):
        audit_engine_stats(bad)
