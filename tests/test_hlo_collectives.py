"""HLO-walker collective accounting: verify traffic conventions on
programs with KNOWN collective content (requires >1 device => spawn a
subprocess with forced host devices so the main test session keeps its
single CPU device)."""
import json
import subprocess
import sys

import pytest

_PROBE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, "src"); sys.path.insert(0, ".")
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from benchmarks.hlo_analysis import analyze

mesh = jax.make_mesh((8,), ("x",))
sh = NamedSharding(mesh, P("x"))
repl = NamedSharding(mesh, P())

# psum over sharded contraction: y = sum over the sharded dim
def f(a, b):
    return a @ b     # (64, 128@x) @ (128@x, 32): contraction sharded -> AR

a_sh = NamedSharding(mesh, P(None, "x"))
b_sh = NamedSharding(mesh, P("x", None))
jitted = jax.jit(f, in_shardings=(a_sh, b_sh), out_shardings=repl)
txt = jitted.lower(
    jax.ShapeDtypeStruct((64, 128), jnp.float32),
    jax.ShapeDtypeStruct((128, 32), jnp.float32),
).compile().as_text()
res = analyze(txt)
out = {"ar_traffic": res["collective_traffic_bytes"].get("all-reduce", 0.0),
       "counts": res["collective_counts"],
       "flops": res["dot_flops"]}
print(json.dumps(out))
"""


@pytest.mark.parametrize("probe", [_PROBE])
def test_allreduce_convention(probe):
    r = subprocess.run([sys.executable, "-c", probe], capture_output=True,
                       text=True, timeout=300, cwd=".")
    assert r.returncode == 0, r.stderr[-800:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    # one all-reduce of the (64, 32) f32 output: ring traffic
    # 2*(g-1)/g * bytes = 2*(7/8)*8192 = 14336
    assert out["counts"].get("all-reduce", 0) >= 1
    expected = 2 * (7 / 8) * 64 * 32 * 4
    assert abs(out["ar_traffic"] - expected) / expected < 0.5, out
    # per-device dot flops: full output x sharded contraction
    # = 2 * 64*32 * (128/8) = 65536
    assert out["flops"] == pytest.approx(2 * 64 * 32 * 16, rel=0.01), out
