"""Mesh backend tests: cohort sharding rules, host-mesh clamping, and —
when multiple devices exist — genuinely partitioned cohort execution.

Sharding-spec construction is device-free (AbstractMesh).  The
partitioned-execution and acceptance tests need multiple host devices, so
they skip on a single device and run in CI's multi-device job
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``).  The
``make_host_mesh`` regression runs in a subprocess with its own forced
device count (the flag only takes effect before the first jax import).
"""
import os
import subprocess
import sys
from dataclasses import replace

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import largest_divisor

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs multiple devices (CI: XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")


@pytest.fixture(scope="module")
def amesh():
    # AbstractMesh: shape metadata without devices (ctor changed across
    # jax releases — see tests/test_shardings.py)
    try:
        return jax.sharding.AbstractMesh((8, 1), ("data", "model"))
    except TypeError:
        return jax.sharding.AbstractMesh((("data", 8), ("model", 1)))


# ---------------------------------------------------------------------------
# sharding rule (device-free)
# ---------------------------------------------------------------------------

def test_cohort_spec_partitions_divisible_cohorts(amesh):
    from repro.engine.mesh_backend import cohort_spec
    assert cohort_spec(amesh, (8, 5, 40, 64)) == P("data", None, None, None)
    assert cohort_spec(amesh, (16,)) == P("data")


def test_cohort_spec_replicates_uneven_cohorts(amesh):
    """GSPMD silently replicates uneven leading-dim partitions, so the
    rule must fall back to explicit replication (not emit a spec that
    looks partitioned but isn't)."""
    from repro.engine.mesh_backend import cohort_spec
    assert cohort_spec(amesh, (4, 5)) == P()
    assert cohort_spec(amesh, (2, 3, 3)) == P()
    assert cohort_spec(amesh, ()) == P()


def test_cohort_sharding_hashable_per_mesh(amesh):
    """cached_cohort_step keys compiled programs on the sharding object:
    two CohortShardings over the same mesh must collide."""
    from repro.engine.mesh_backend import CohortSharding
    a, b = CohortSharding(amesh), CohortSharding(amesh)
    assert a == b and hash(a) == hash(b) and len({a, b}) == 1
    assert a.spec((8, 3)) == P("data", None)


def test_step_cache_keyed_per_mesh_with_invalidation(amesh):
    """Supplying shardings must NOT bypass the compiled-step cache (every
    sweep run used to re-trace); entries are dropped per mesh."""
    from repro.core.dp import DPConfig
    from repro.engine.cohort_step import cached_cohort_step, invalidate_step_cache
    from repro.engine.mesh_backend import CohortSharding
    from repro.optim.optimizers import Adam

    def loss(p, ex):
        return ((p["w"] - ex["x"]) ** 2).sum()

    args = (loss, DPConfig(clip_norm=1.0, noise_multiplier=0.0), Adam(lr=0.1))
    sh = CohortSharding(amesh)
    invalidate_step_cache(amesh)
    s1 = cached_cohort_step(*args, client_axis="vmap", client_shardings=sh)
    s2 = cached_cohort_step(*args, client_axis="vmap",
                            client_shardings=CohortSharding(amesh))
    assert s1 is s2                       # same mesh -> same compiled step
    s3 = cached_cohort_step(*args, client_axis="vmap")
    assert s3 is not s1                   # unsharded is a different entry
    assert invalidate_step_cache(amesh) == 1
    s4 = cached_cohort_step(*args, client_axis="vmap", client_shardings=sh)
    assert s4 is not s1                   # invalidation dropped the entry
    assert cached_cohort_step(*args, client_axis="vmap") is s3  # untouched
    invalidate_step_cache(amesh)


# ---------------------------------------------------------------------------
# make_host_mesh clamping (satellite regression)
# ---------------------------------------------------------------------------

def test_largest_divisor():
    assert largest_divisor(6, 4) == 3
    assert largest_divisor(8, 6) == 4
    assert largest_divisor(8, 8) == 8
    assert largest_divisor(7, 3) == 1
    assert largest_divisor(6, 0) == 1      # used to divide by zero downstream
    assert largest_divisor(6, 100) == 6


def test_make_host_mesh_clamps_on_forced_six_devices():
    """Regression (ISSUE 2): ``data=4`` on 6 devices built a ``(4, 1)``
    mesh — invalid where jax requires the product to cover the devices,
    silently stranding two of them where it truncates.  Axis sizes now
    clamp to divisors of the device count."""
    code = """
import jax
from repro.launch.mesh import make_host_mesh
assert len(jax.devices()) == 6, len(jax.devices())
m = make_host_mesh(data=4)
assert dict(m.shape) == {"data": 3, "model": 1}, dict(m.shape)
assert m.devices.size == 3
m = make_host_mesh(data=6, model=4)
assert dict(m.shape) == {"data": 6, "model": 1}, dict(m.shape)
m = make_host_mesh(data=2, model=3)
assert dict(m.shape) == {"data": 2, "model": 3}, dict(m.shape)
m = make_host_mesh(data=2, model=2)      # 2 does not divide 6 // 2 = 3
assert dict(m.shape) == {"data": 2, "model": 1}, dict(m.shape)
m = make_host_mesh(data=0)          # used to ZeroDivisionError
assert dict(m.shape) == {"data": 1, "model": 1}, dict(m.shape)
print("host-mesh-clamp-ok")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr
    assert "host-mesh-clamp-ok" in out.stdout


# ---------------------------------------------------------------------------
# partitioned execution (multi-device job)
# ---------------------------------------------------------------------------

def _mesh_cfg():
    from repro.core.testbed import TestbedConfig
    from repro.data.synthetic_ser import SERDataConfig
    n = len(jax.devices())
    return TestbedConfig(num_clients=n, batch_size=32,
                         data=SERDataConfig(n_total=120 * n), seed=0)


def _assert_close(a, b, rtol=1e-4, atol=1e-5):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


@multi_device
def test_cohort_step_partitions_cohort_axis():
    """Smoke: one full-population cohort through the vmap executor on a
    mesh — every stacked leaf must hold K / n_data members per shard."""
    from repro.core.testbed import build_testbed
    from repro.engine import (CohortRunner, EngineConfig,
                              assert_cohort_partitioned, cohort_mesh)
    mesh = cohort_mesh()
    n = len(jax.devices())
    clients, params, _, _ = build_testbed(_mesh_cfg())
    runner = CohortRunner(clients, EngineConfig(
        client_axis="vmap", mesh=mesh, max_cohort=n))
    key = jax.random.PRNGKey(0)
    plans = []
    for c in clients:
        key, sub = jax.random.split(key)
        plans.append(runner.dispatch(c, params, sub, 0))
    stacked = runner.run_cohort(plans)
    report = assert_cohort_partitioned(stacked, mesh)
    assert report and set(report.values()) == {n // mesh.shape["data"]}


@multi_device
def test_padded_uneven_cohort_partitions_and_matches():
    """Tentpole acceptance: an UNEVEN cohort — the case PR 2's GSPMD rule
    could only run replicated — pads to its bucket size, GENUINELY
    partitions (addressable-shard shapes), and its first K rows equal the
    unpadded host-path result (pad members are zero-step masked)."""
    from repro.core.testbed import build_testbed
    from repro.engine import (CohortRunner, EngineConfig,
                              assert_cohort_partitioned, cohort_mesh,
                              padded_cohort_size)
    mesh = cohort_mesh()
    n = len(jax.devices())
    n_data = mesh.shape["data"]
    k = max(2, (3 * n_data) // 4)
    assert k % n_data, "need a cohort size that does not divide the axis"
    cfg = replace(_mesh_cfg(), use_dp=False)

    def one_cohort(ec):
        clients, params, _, _ = build_testbed(cfg)
        runner = CohortRunner(clients, ec)
        key = jax.random.PRNGKey(0)
        plans = []
        for c in clients[:k]:
            key, sub = jax.random.split(key)
            plans.append(runner.dispatch(c, params, sub, 0))
        return runner.run_cohort(plans)

    stacked = one_cohort(
        EngineConfig(client_axis="vmap", mesh=mesh, max_cohort=n))
    k_pad = padded_cohort_size(k, n_data)
    assert jax.tree_util.tree_leaves(stacked)[0].shape[0] == k_pad
    report = assert_cohort_partitioned(stacked, mesh)
    assert report and set(report.values()) == {k_pad // n_data}
    ref = one_cohort(EngineConfig(device_arena=False))  # host path, no mesh
    for a, b in zip(jax.tree_util.tree_leaves(stacked),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_allclose(np.asarray(a)[:k], np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_invalidate_step_cache_frees_arena_buffers(micro_cfg):
    """The compiled-step cache holds step FUNCTIONS only — arenas are
    per-runner call arguments, never closed over — so dropping a mesh's
    cache entries plus the runner must free every device-resident arena
    buffer (params, opt state and dataset)."""
    import gc
    import weakref

    from repro.core.testbed import build_testbed
    from repro.engine import CohortRunner, EngineConfig, cohort_mesh
    from repro.engine.cohort_step import invalidate_step_cache

    mesh = cohort_mesh()
    clients, params, _, _ = build_testbed(micro_cfg)
    runner = CohortRunner(clients, EngineConfig(mesh=mesh, max_cohort=2))
    key = jax.random.PRNGKey(0)
    plans = []
    for c in clients[:2]:
        key, sub = jax.random.split(key)
        plans.append(runner.dispatch(c, params, sub, 0))
    stacked = runner.run_cohort(plans)
    jax.block_until_ready(jax.tree_util.tree_leaves(stacked)[0])
    refs = [weakref.ref(leaf) for leaf in (
        jax.tree_util.tree_leaves(runner._arena_data)
        + jax.tree_util.tree_leaves(runner._arena_params)
        + jax.tree_util.tree_leaves(runner._arena_opt))]
    # at least the runner's compiled step AND its arena helpers entry
    # (cached_arena_helpers shares the step cache) must drop
    assert invalidate_step_cache(mesh) >= 2
    del runner, plans, stacked
    gc.collect()
    alive = [r for r in refs if r() is not None]
    assert not alive, f"{len(alive)}/{len(refs)} arena buffers leaked"


@multi_device
def test_run_experiment_vmap_sharded_matches_unroll():
    """The acceptance criterion: run_experiment(..., engine="cohort",
    engine_cfg=EngineConfig(client_axis="vmap"), mesh=...) end-to-end on a
    multi-host-device mesh, params allclose vs the unroll executor with
    identical RunLog bookkeeping.

    DP off for the tight comparison: with DP on, noise-dominated
    gradients near zero get sign-flipped by ~1e-7 lowering differences
    between the batched and unbatched conv programs, and Adam's
    normalized first step turns each flip into a ±lr difference (the DP
    case is covered at that documented looser tolerance below)."""
    from repro.core.testbed import run_experiment
    from repro.engine import EngineConfig, cohort_mesh
    mesh = cohort_mesh()
    n = len(jax.devices())
    cfg = replace(_mesh_cfg(), use_dp=False)
    kw = dict(rounds=2, eval_every=2, engine="cohort")
    p_u, log_u = run_experiment("fedavg", cfg,
                                engine_cfg=EngineConfig(max_cohort=n), **kw)
    p_v, log_v = run_experiment("fedavg", cfg, mesh=mesh,
                                engine_cfg=EngineConfig(client_axis="vmap",
                                                        max_cohort=n), **kw)
    _assert_close(p_u, p_v)
    assert log_u.update_counts == log_v.update_counts
    assert log_u.staleness == log_v.staleness
    assert log_u.eps_trajectory == log_v.eps_trajectory
    assert log_u.times == log_v.times
    np.testing.assert_allclose(log_u.global_acc, log_v.global_acc, atol=1e-5)
    assert log_v.cohort_sizes == [n, n]    # full-population compiled cohorts


@multi_device
def test_sharded_async_dp_run_trains():
    """FedAsync with DP over sharded cohorts: bookkeeping exact vs the
    unroll executor, params allclose at the Adam-sign-amplified tolerance
    (see test_run_experiment_vmap_sharded_matches_unroll)."""
    from repro.core.testbed import run_experiment
    from repro.engine import EngineConfig, cohort_mesh
    mesh = cohort_mesh()
    n = len(jax.devices())
    kw = dict(max_updates=2 * n, eval_every=n, alpha=0.4, engine="cohort")
    ec = EngineConfig(staleness_window=1e9, max_cohort=n)
    _, log_u = run_experiment("fedasync", _mesh_cfg(), engine_cfg=ec, **kw)
    p_v, log_v = run_experiment(
        "fedasync", _mesh_cfg(), mesh=mesh,
        engine_cfg=replace(ec, client_axis="vmap"), **kw)
    assert log_u.update_counts == log_v.update_counts
    assert log_u.eps_trajectory == log_v.eps_trajectory
    assert sum(log_v.cohort_sizes) == 2 * n
    assert max(log_v.cohort_sizes) == n    # the window batched full cohorts
    for leaf in jax.tree_util.tree_leaves(p_v):
        assert bool(np.isfinite(np.asarray(leaf)).all())
