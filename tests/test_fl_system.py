"""End-to-end FL system behaviour (reduced scale): the paper's qualitative
claims must EMERGE from the simulation, not be scripted.

The multi-round training runs are marked ``slow`` (deselected by default;
``-m slow`` runs them) — the ``tiny_cfg`` fixture lives in conftest.py.
"""
import numpy as np
import pytest

from repro.core.heterogeneity import PROFILES, TIERS, VirtualClock
from repro.core.testbed import TestbedConfig, run_experiment
from repro.data.synthetic_ser import SERDataConfig, generate
from repro.data.partition import dirichlet_partition, iid_partition


def test_virtual_clock_ordering():
    """Low tiers must be consistently slower (paper Fig. 3b)."""
    means = {}
    for tier in TIERS:
        clk = VirtualClock(PROFILES[tier], seed=0)
        means[tier] = np.mean([clk.round_duration() for _ in range(50)])
    assert means["HW_T1"] > means["HW_T2"] > means["HW_T3"] > means["HW_T4"]
    assert means["HW_T1"] > 6 * means["HW_T5"]   # paper: 6-9x


def test_partitions_balanced():
    data = generate(SERDataConfig(n_total=1000))
    parts = iid_partition(data, 5, seed=0)
    sizes = [p["y"].shape[0] for p in parts]
    assert max(sizes) - min(sizes) <= 5
    # classes balanced within each client
    for p in parts:
        counts = np.bincount(p["y"], minlength=4)
        assert counts.min() > 0.15 * counts.sum()


def test_dirichlet_partition_skews():
    data = generate(SERDataConfig(n_total=2000))
    parts = dirichlet_partition(data, 5, alpha=0.1, seed=0)
    # strong label skew: some client has a dominant class
    doms = [np.bincount(p["y"], minlength=4).max() / max(1, p["y"].shape[0])
            for p in parts if p["y"].shape[0] > 10]
    assert max(doms) > 0.5


@pytest.mark.slow
def test_fedavg_trains_and_tracks_privacy(tiny_cfg):
    params, log = run_experiment("fedavg", tiny_cfg, rounds=6)
    assert log.global_acc[-1] > 0.4          # better than 4-class chance
    # synchronous => uniform update counts and (nearly) uniform epsilon
    counts = set(log.update_counts.values())
    assert len(counts) == 1
    eps = [v[-1] for v in log.eps_trajectory.values()]
    # near-uniform: partition sizes differ by <=5 samples; a client whose
    # N_k crosses a batch-size multiple does one FEWER full DP step per
    # round (floor(N/B)), which moves eps by up to ~(1/steps) relatively
    assert (max(eps) - min(eps)) / max(eps) < 0.30
    assert eps[0] > 0
    # straggler effect: round time ~ slowest device
    assert log.times[0] > PROFILES["HW_T1"].compute_time_s * 0.7


@pytest.mark.slow
def test_fedasync_participation_skew_and_privacy_disparity(tiny_cfg):
    params, log = run_experiment(
        "fedasync", tiny_cfg, max_updates=40, alpha=0.4, eval_every=10)
    # high-end devices contribute many more updates (paper Fig. 5)
    assert log.update_counts["HW_T5"] >= 5 * max(1, log.update_counts["HW_T1"])
    # and accrue more privacy loss (paper Table 3)
    eps5 = log.eps_trajectory["HW_T5"][-1]
    eps1 = log.eps_trajectory["HW_T1"][-1]
    assert eps5 > 1.5 * eps1
    # staleness higher on slow tiers (paper Sec 4.2.1)
    mean_tau = {k: np.mean(v) for k, v in log.staleness.items() if v}
    assert mean_tau["HW_T1"] > mean_tau["HW_T5"]
    fr = log.fairness()
    assert fr["jain_participation"] < 0.9    # skewed
    assert fr["privacy_disparity"] > 1.5


@pytest.mark.slow
def test_fedasync_faster_than_fedavg_to_target(tiny_cfg):
    """The headline efficiency claim, at reduced scale (paper Fig. 4)."""
    target = 0.5
    _, log_avg = run_experiment("fedavg", tiny_cfg, rounds=6,
                                target_acc=target)
    _, log_async = run_experiment("fedasync", tiny_cfg, max_updates=60,
                                  alpha=0.4, eval_every=3, target_acc=target)
    t_avg = log_avg.time_to_accuracy(target)
    t_async = log_async.time_to_accuracy(target)
    assert t_avg is not None and t_async is not None
    assert t_async < t_avg / 2, (t_async, t_avg)


@pytest.mark.slow
def test_fedbuff_and_adaptive_run(tiny_cfg):
    _, log_b = run_experiment("fedbuff", tiny_cfg, max_updates=20,
                              alpha=0.4, eval_every=10, buffer_size=3)
    assert sum(log_b.update_counts.values()) >= 20
    _, log_a = run_experiment("adaptive_async", tiny_cfg, max_updates=20,
                              alpha=0.4, eval_every=10, eps_target=50.0)
    assert sum(log_a.update_counts.values()) >= 20
    # with a tight budget, clients must STOP training once eps_target is
    # exhausted (joint aggregation-privacy adaptation, beyond-paper)
    _, log_t = run_experiment("adaptive_async", tiny_cfg, max_updates=200,
                              alpha=0.4, eval_every=50, eps_target=5.0)
    final_eps = [v[-1] for v in log_t.eps_trajectory.values() if v]
    assert max(final_eps) < 5.0 * 1.6   # one overshoot round at most


def test_checkpoint_roundtrip(tmp_path, tiny_cfg):
    import jax
    from repro.checkpoint import checkpoint as ckpt
    from repro.models import ser_cnn
    params = ser_cnn.init(jax.random.PRNGKey(0))
    path = ckpt.save(str(tmp_path), 7, params, meta={"sigma": 1.0})
    restored, meta = ckpt.restore(str(tmp_path), params)
    assert meta["step"] == 7 and meta["sigma"] == 1.0
    a = jax.tree_util.tree_leaves(params)
    b = jax.tree_util.tree_leaves(restored)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.slow
def test_personalized_heads_stay_local(tiny_cfg):
    """Beyond-paper (paper Sec. 5 direction 3): personal output heads are
    trained locally, never uploaded, and diverge per client."""
    from dataclasses import replace
    import jax
    cfg = replace(tiny_cfg, personalized=True)
    params, log = run_experiment("fedasync", cfg, max_updates=15,
                                 alpha=0.4, eval_every=15)
    from repro.core.testbed import build_testbed
    # rebuild to inspect clients directly (same seed => same wiring)
    clients, init_params, acc_fn, pooled = build_testbed(cfg)
    # run a couple of rounds manually
    key = jax.random.PRNGKey(0)
    for c in clients[:2]:
        key, sub = jax.random.split(key)
        up, _ = c.local_train(init_params, sub)
        # uploaded 'out' equals the received global 'out' (never leaves)
        for leaf_up, leaf_g in zip(
                jax.tree_util.tree_leaves(up["out"]),
                jax.tree_util.tree_leaves(init_params["out"])):
            np.testing.assert_array_equal(np.asarray(leaf_up),
                                          np.asarray(leaf_g))
        # but the on-device personal head has trained away from init
        moved = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
                    for a, b in zip(
                        jax.tree_util.tree_leaves(c._personal["out"]),
                        jax.tree_util.tree_leaves(init_params["out"])))
        assert moved > 0
    # personal heads differ across clients (trained on different shards)
    d = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
            for a, b in zip(
                jax.tree_util.tree_leaves(clients[0]._personal["out"]),
                jax.tree_util.tree_leaves(clients[1]._personal["out"])))
    assert d > 0
