"""Introspective spec-codec completeness: EVERY dataclass in
``_SPEC_TYPES`` round-trips an instance whose every field holds a
NON-default value.  A field the codec drops (not encoded, not decoded,
or decoded back to the default) is reported BY NAME — this is the test
shape that would have caught the PR-6 ``use_kernel``/``dp_path``
half-plumbing, and it fails automatically for fields added in future
PRs without touching this file."""
import dataclasses

import pytest

from repro.api.spec import _SPEC_TYPES, decode, encode

# Fields whose values are constrained (validated enums, registry names,
# live meshes) get explicit non-default values; everything else is
# derived from the field's default by type.
_SPECIAL = {
    ("ExperimentSpec", "backend"): "legacy",
    ("TestbedConfig", "dp_path"): "pallas",
    ("TestbedConfig", "partition"): "dirichlet",
    ("TestbedConfig", "workload"): "ser_linear",
    ("TestbedConfig", "faults"): "__faults__",    # Optional[FaultModel]
    ("TestbedConfig", "screening"): "__screening__",  # Optional[ScreeningConfig]
    ("EngineConfig", "client_axis"): "vmap",
    ("EngineConfig", "mesh"): "__mesh__",          # built lazily (devices)
    ("EngineConfig", "store"): "__store__",        # see _bump
    ("StoreConfig", "hot_slots"): 12,     # Optional[int], validated >= 1
    ("DPConfig", "granularity"): "per_microbatch",
    ("FLStepConfig", "server_opt"): "sgd",
    ("FLStepConfig", "compute_dtype"): "float32",
}
# granularity default differs between a bare DPConfig ("per_example")
# and FLStepConfig's nested default ("per_microbatch") — flip per parent
_SPECIAL_NESTED_DP = {"granularity": "per_example"}


def _mesh():
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh(data=1)


def _bump(cls_name, field, value):
    """A value for ``field`` guaranteed to differ from ``value``."""
    special = _SPECIAL.get((cls_name, field.name))
    if special == "__mesh__":
        return _mesh()
    if special == "__faults__":
        from repro.core.faults import FaultModel
        return _nondefault_instance(FaultModel)
    if special == "__screening__":
        from repro.core.screening import ScreeningConfig
        return _nondefault_instance(ScreeningConfig)
    if special == "__store__":
        # the generator flips device_arena False, and EngineConfig rejects
        # a BOUNDED store on the host data path — bump lookahead only;
        # hot_slots round-trips via the standalone StoreConfig case
        from repro.engine import StoreConfig
        return StoreConfig(lookahead=11)
    if special is not None:
        assert special != value, (cls_name, field.name)
        return special
    if isinstance(value, bool):
        return not value
    if isinstance(value, int):
        return value + 3
    if isinstance(value, float):
        return value + 0.25
    if isinstance(value, str):
        return value + "_x"
    if value is None:                    # Optional[float] budget caps
        return 123.5
    if dataclasses.is_dataclass(value):
        return _nondefault_instance(type(value), base=value)
    raise AssertionError(
        f"no bump strategy for {cls_name}.{field.name} = {value!r} — "
        "teach this test about the new field type")


def _nondefault_instance(cls, base=None):
    """Instance of ``cls`` with every field changed from its default."""
    name = cls.__name__
    if name == "StrategySpec":
        return cls("fedasync", alpha=0.7, staleness_aware=False)
    if name == "FLStepConfig":
        kw = {}
        for f in dataclasses.fields(cls):
            if f.name == "num_clients":          # required, no default
                kw[f.name] = 7
            elif f.name == "dp":
                kw[f.name] = dataclasses.replace(
                    f.default_factory()
                    if f.default is dataclasses.MISSING else f.default,
                    clip_norm=2.5, noise_multiplier=0.75,
                    **_SPECIAL_NESTED_DP)
            else:
                kw[f.name] = _bump(name, f, _default_of(f))
        return cls(**kw)
    kw = {}
    for f in dataclasses.fields(cls):
        kw[f.name] = _bump(name, f, _default_of(f))
    return cls(**kw)


def _default_of(f):
    if f.default is not dataclasses.MISSING:
        return f.default
    if f.default_factory is not dataclasses.MISSING:
        return f.default_factory()
    return None


def _diff(cls, a, b):
    """Field names where two instances differ (mesh compared by axes)."""
    out = []
    for f in dataclasses.fields(cls):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if f.name == "mesh" and va is not None and vb is not None:
            if dict(va.shape) != dict(vb.shape):
                out.append(f.name)
            continue
        if va != vb:
            out.append(f.name)
    return out


@pytest.mark.parametrize("name", sorted(_SPEC_TYPES))
def test_roundtrip_preserves_every_field(name):
    cls = _SPEC_TYPES[name]
    inst = _nondefault_instance(cls)
    if name == "EngineConfig":
        inst = dataclasses.replace(inst, fl_cfg=_nondefault_instance(
            _SPEC_TYPES["FLStepConfig"]))
    decoded = decode(encode(inst))
    assert type(decoded) is cls
    dropped = _diff(cls, inst, decoded)
    assert not dropped, (
        f"{name} fields dropped/mutated by the spec codec: {dropped} — "
        "register the field's type in _SPEC_TYPES / extend encode()")


@pytest.mark.parametrize("name", sorted(_SPEC_TYPES))
def test_instance_really_is_nondefault(name):
    """Guard the generator itself: if a field comes out equal to its
    default, the round-trip above can't detect the codec dropping it."""
    cls = _SPEC_TYPES[name]
    inst = _nondefault_instance(cls)
    for f in dataclasses.fields(cls):
        default = _default_of(f)
        if default is None and f.name in ("mesh", "fl_cfg"):
            # fl_cfg is exercised via the EngineConfig round-trip above
            if f.name == "fl_cfg":
                continue
        got = getattr(inst, f.name)
        if f.name == "mesh":
            assert got is not None
            continue
        assert got != default, (
            f"generator produced the DEFAULT for {name}.{f.name}; "
            "add a _SPECIAL entry for it")


def test_json_roundtrip_is_plain_data():
    import json
    spec = _nondefault_instance(_SPEC_TYPES["ExperimentSpec"])
    d = encode(spec)
    restored = decode(json.loads(json.dumps(d)))
    assert _diff(type(spec), spec, restored) == []
