"""Cohort engine vs legacy per-client loop: the engine must reproduce the
legacy event loop update-for-update (params allclose, IDENTICAL per-tier
update counts / epsilon trajectories / staleness), plus executor parity
(vmap / fl_step vs unroll) and unit tests for the cohort weights vector
and cohort formation."""
import heapq
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import FedAsync
from repro.core.testbed import build_testbed, run_experiment
from repro.engine import EngineConfig, fedavg_weights, fold_cohort_weights
from repro.engine.cohort import plan_batches, pop_cohort
from repro.pytree import tree_lin


def _assert_params_close(a, b, rtol=1e-4, atol=1e-5):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


def _assert_logs_match(log_leg, log_eng):
    assert log_leg.update_counts == log_eng.update_counts
    assert log_leg.eps_trajectory == log_eng.eps_trajectory
    assert log_leg.staleness == log_eng.staleness
    assert log_leg.times == log_eng.times
    np.testing.assert_allclose(log_leg.global_acc, log_eng.global_acc,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# end-to-end parity (the tentpole acceptance criterion)
# ---------------------------------------------------------------------------

def test_fedavg_engine_matches_legacy(micro_cfg):
    p_leg, log_leg = run_experiment("fedavg", micro_cfg, rounds=2,
                                    engine="legacy")
    p_eng, log_eng = run_experiment("fedavg", micro_cfg, rounds=2,
                                    engine="cohort")
    _assert_params_close(p_leg, p_eng)
    _assert_logs_match(log_leg, log_eng)
    # engine ran the barrier in compiled cohort chunks
    assert sum(log_eng.cohort_sizes) == 2 * micro_cfg.num_clients
    assert not log_leg.cohort_sizes  # legacy loop never forms cohorts


def test_fedasync_engine_matches_legacy(micro_cfg):
    kw = dict(max_updates=12, eval_every=4, alpha=0.4)
    p_leg, log_leg = run_experiment("fedasync", micro_cfg, engine="legacy",
                                    **kw)
    p_eng, log_eng = run_experiment("fedasync", micro_cfg, engine="cohort",
                                    **kw)
    _assert_params_close(p_leg, p_eng)
    _assert_logs_match(log_leg, log_eng)
    assert log_leg.influence == pytest.approx(log_eng.influence)
    # the default window is 0 => the engine replays the exact event order
    assert log_eng.cohort_sizes == [1] * sum(log_eng.update_counts.values())


def test_fedasync_windowed_cohorts_still_train(micro_cfg):
    """A positive staleness window batches completions; bookkeeping totals
    must be preserved even though merge order coarsens."""
    ec = EngineConfig(staleness_window=1e9, max_cohort=2)
    _, log = run_experiment("fedasync", micro_cfg, max_updates=8,
                            eval_every=4, alpha=0.4, engine="cohort",
                            engine_cfg=ec)
    assert sum(log.update_counts.values()) == sum(log.cohort_sizes) == 8
    assert max(log.cohort_sizes) == 2        # the window actually batched
    assert all(len(v) == n for v, n in
               zip(log.eps_trajectory.values(), log.update_counts.values()))


def test_fedbuff_and_adaptive_route_through_engine(micro_cfg):
    _, log_b = run_experiment("fedbuff", micro_cfg, max_updates=6,
                              eval_every=6, alpha=0.4, buffer_size=2,
                              engine="cohort")
    assert sum(log_b.update_counts.values()) == 6
    _, log_a = run_experiment("adaptive_async", micro_cfg, max_updates=6,
                              eval_every=6, alpha=0.4, eps_target=50.0,
                              engine="cohort")
    assert sum(log_a.update_counts.values()) == 6


def test_arena_data_path_matches_host_path(micro_cfg):
    """The device-resident arena path (the default) must reproduce the
    PR-2 host-fed path: identical bookkeeping, params allclose — while
    shrinking per-cohort H2D from stacked batch tensors to index-only
    traffic (the RunLog.engine_stats counters prove which path ran)."""
    for strat, kw in (("fedavg", dict(rounds=2)),
                      ("fedasync", dict(max_updates=8, eval_every=4,
                                        alpha=0.4))):
        p_a, log_a = run_experiment(strat, micro_cfg, engine="cohort", **kw)
        p_h, log_h = run_experiment(
            strat, micro_cfg, engine="cohort",
            engine_cfg=EngineConfig(device_arena=False), **kw)
        _assert_params_close(p_a, p_h)
        _assert_logs_match(log_h, log_a)
        assert log_a.engine_stats["data_path"] == "arena"
        assert log_h.engine_stats["data_path"] == "host"
        # the arena path ships a (K, S_max, B) int32 plan; the host path
        # ships the full gathered batch tensors
        assert (log_a.engine_stats["h2d_bytes_per_cohort"] * 100
                < log_h.engine_stats["h2d_bytes_per_cohort"])


def test_dropout_counters_match_across_backends(micro_cfg):
    """RunLog.dropouts (the passive delay-dropouts of the heterogeneity
    layer, paper Table 2) must agree between the legacy loop and the
    cohort engine — both drive the SAME per-client VirtualClock stream,
    so the per-tier counters are identical, not just close."""
    from repro.core.server import run_fedavg

    def boosted():
        clients, params, acc_fn, test = build_testbed(micro_cfg)
        for c in clients[:2]:      # make dropouts certain in 4 rounds
            c.profile = replace(c.profile, dropout_per_round=0.7,
                                dropout_penalty_s=60.0)
            c.reset()              # rebuild the clock over the new profile
        return clients, params, acc_fn, test

    logs = {}
    for engine in ("legacy", "cohort"):
        clients, params, acc_fn, test = boosted()
        _, logs[engine] = run_fedavg(
            clients, params, acc_fn, test, rounds=4,
            seed=micro_cfg.seed, eval_every=2, engine=engine)
    assert logs["legacy"].dropouts == logs["cohort"].dropouts
    assert sum(logs["legacy"].dropouts.values()) > 0


def test_async_engine_preserves_callers_initial_params(micro_cfg):
    """The arena path's fused merge donates its globals argument; the
    engine must consume a COPY of the caller's initial params so they
    stay readable after the run (reading a donated jax buffer raises)."""
    from repro.core.aggregation import FedAsync as FA
    from repro.engine import run_async_engine

    clients, params, acc_fn, test = build_testbed(micro_cfg)
    run_async_engine(clients, params, acc_fn, test, FA(alpha=0.4),
                     max_updates=4, eval_every=4, seed=micro_cfg.seed)
    for leaf in jax.tree_util.tree_leaves(params):
        assert np.isfinite(np.asarray(leaf)).all()  # still alive


# ---------------------------------------------------------------------------
# executor parity: vmap / fl_step vs unroll (single device, unsharded —
# the sharded variants run in the multi-device job, tests/test_mesh_backend)
# ---------------------------------------------------------------------------

def test_vmap_executor_matches_unroll(micro_cfg):
    """client_axis="vmap" must match the unroll executor's params allclose
    with identical RunLog bookkeeping for FedAvg and FedAsync.  DP off for
    the tight tolerance: under DP the noise-dominated near-zero gradients
    pick up sign flips from the batched-vs-unbatched conv lowering and
    Adam's normalized first step amplifies each to ±lr."""
    nodp = replace(micro_cfg, use_dp=False)
    for strat, kw in (("fedavg", dict(rounds=2)),
                      ("fedasync", dict(max_updates=8, eval_every=4,
                                        alpha=0.4))):
        p_u, log_u = run_experiment(strat, nodp, engine="cohort", **kw)
        p_v, log_v = run_experiment(
            strat, nodp, engine="cohort",
            engine_cfg=EngineConfig(client_axis="vmap"), **kw)
        _assert_params_close(p_u, p_v)
        _assert_logs_match(log_u, log_v)


def test_vmap_executor_dp_bookkeeping_matches(micro_cfg):
    """With DP on the executors agree at the Adam-sign-amplified tolerance
    (see above) and the privacy/participation bookkeeping stays exact."""
    kw = dict(max_updates=6, eval_every=6, alpha=0.4, engine="cohort")
    p_u, log_u = run_experiment("fedasync", micro_cfg, **kw)
    p_v, log_v = run_experiment(
        "fedasync", micro_cfg,
        engine_cfg=EngineConfig(client_axis="vmap"), **kw)
    _assert_params_close(p_u, p_v, rtol=1e-2, atol=5e-3)
    assert log_u.update_counts == log_v.update_counts
    assert log_u.eps_trajectory == log_v.eps_trajectory
    assert log_u.staleness == log_v.staleness


def test_fl_step_executor_matches_simulation(micro_cfg):
    """client_axis="fl_step" drives the production per-microbatch local
    round (core/fl_step.make_local_phase) from the engine event loop.
    With DP off, n_micro=1 and a plain-SGD client optimizer the production
    math IS the simulation math, so at staleness_window=0 it must match
    the unroll executor allclose with identical bookkeeping."""
    from repro.core.aggregation import FedAsync as FA
    from repro.core.dp import DPConfig
    from repro.core.fl_step import FLStepConfig
    from repro.engine import run_async_engine
    from repro.optim.optimizers import SGD

    fl = FLStepConfig(
        num_clients=1, n_local=1, n_micro=1, local_lr=0.05,
        dp=DPConfig(clip_norm=1e9, noise_multiplier=0.0,
                    granularity="per_microbatch"))

    def run(ec):
        clients, params, acc_fn, test = build_testbed(
            replace(micro_cfg, use_dp=False))
        for c in clients:  # production local phase = plain local_lr SGD
            c.opt = SGD(lr=fl.local_lr)
        return run_async_engine(
            clients, params, acc_fn, test, FA(alpha=0.4), max_updates=8,
            eval_every=4, seed=micro_cfg.seed, engine_cfg=ec)

    p_u, log_u = run(EngineConfig())
    p_f, log_f = run(EngineConfig(client_axis="fl_step", fl_cfg=fl))
    _assert_params_close(p_u, p_f)
    _assert_logs_match(log_u, log_f)


def test_fl_step_executor_rejects_incoherent_dp_accounting(micro_cfg):
    """With DP clients, the accountant charges the clients' dp_cfg; the
    fl_step executor executes fl_cfg.dp — the runner must refuse configs
    where the reported epsilon would not describe the executed mechanism
    (e.g. noiseless fl_cfg under use_dp=True clients)."""
    from repro.core.dp import DPConfig
    from repro.core.fl_step import FLStepConfig
    from repro.engine import CohortRunner

    clients, _, _, _ = build_testbed(micro_cfg)   # use_dp=True, sigma=1.0
    noiseless = FLStepConfig(
        num_clients=1, n_micro=1,
        dp=DPConfig(clip_norm=1.0, noise_multiplier=0.0,
                    granularity="per_microbatch"))
    with pytest.raises(ValueError, match="executed mechanism"):
        CohortRunner(clients, EngineConfig(client_axis="fl_step",
                                           fl_cfg=noiseless))
    # matching noise at per-microbatch granularity is accepted
    coherent = FLStepConfig(
        num_clients=1, n_micro=1,
        dp=DPConfig(clip_norm=1.0, noise_multiplier=micro_cfg.sigma,
                    granularity="per_microbatch"))
    CohortRunner(clients, EngineConfig(client_axis="fl_step",
                                       fl_cfg=coherent))


def test_fl_step_executor_requires_fl_cfg():
    from repro.engine.cohort_step import make_cohort_step
    from repro.core.dp import DPConfig
    from repro.optim.optimizers import Adam
    with pytest.raises(ValueError, match="FLStepConfig"):
        make_cohort_step(lambda p, ex: 0.0, DPConfig(), Adam(),
                         client_axis="fl_step")


def test_client_axis_validated_in_one_place():
    """EngineConfig and make_cohort_step share one executor set (their
    defaults used to disagree: "unroll" vs "map")."""
    import inspect
    from repro.engine import CLIENT_AXES
    from repro.engine.cohort_step import cached_cohort_step, make_cohort_step
    assert EngineConfig().client_axis == "unroll"
    for fn in (make_cohort_step, cached_cohort_step):
        assert inspect.signature(fn).parameters["client_axis"].default == \
            "unroll"
    with pytest.raises(ValueError, match="client_axis"):
        EngineConfig(client_axis="bogus")
    assert set(CLIENT_AXES) == {"unroll", "map", "vmap", "fl_step"}


# ---------------------------------------------------------------------------
# the cohort weights vector (staleness weights alpha/(1+tau), folded)
# ---------------------------------------------------------------------------

def test_staleness_weights_vector():
    """The folded cohort weights carry FedAsync's alpha/(1+tau) (Eq. 10):
    member i's coefficient is w_i * prod_{j>i} (1 - w_j)."""
    strat = FedAsync(alpha=0.6)
    taus = [0, 2, 5]
    ws = [strat.mixing_weight(t) for t in taus]
    assert ws == pytest.approx([0.6, 0.2, 0.1])
    g_coeff, coeffs = fold_cohort_weights(ws)
    assert coeffs[0] == pytest.approx(0.6 * (1 - 0.2) * (1 - 0.1))
    assert coeffs[1] == pytest.approx(0.2 * (1 - 0.1))
    assert coeffs[2] == pytest.approx(0.1)
    assert g_coeff == pytest.approx((1 - 0.6) * (1 - 0.2) * (1 - 0.1))
    # convexity: the merged model stays in the hull of {g, p_1..p_K}
    assert g_coeff + coeffs.sum() == pytest.approx(1.0)


def test_fold_equals_sequential_merges():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    g = {"w": jax.random.normal(ks[0], (8,)), "b": jax.random.normal(ks[1], (3,))}
    ps = [{"w": jax.random.normal(k, (8,)), "b": jax.random.normal(k, (3,))}
          for k in (ks[2], ks[3])]
    ws = [0.4, 0.25]
    seq = g
    for p, w in zip(ps, ws):
        seq = tree_lin(seq, p, 1.0 - w, w)
    g_coeff, coeffs = fold_cohort_weights(ws)
    fused = jax.tree_util.tree_map(
        lambda gl, p0, p1: g_coeff * gl + coeffs[0] * p0 + coeffs[1] * p1,
        g, ps[0], ps[1])
    _assert_params_close(seq, fused, rtol=1e-6, atol=1e-7)


def test_fedavg_weights_normalized():
    g_coeff, coeffs = fedavg_weights([100, 300])
    assert g_coeff == 0.0
    np.testing.assert_allclose(coeffs, [0.25, 0.75])


# ---------------------------------------------------------------------------
# cohort formation & batch planning
# ---------------------------------------------------------------------------

def test_pop_cohort_window_and_pow2():
    heap = [(1.0, 0), (1.5, 1), (1.9, 2), (2.1, 3), (9.0, 4)]
    heapq.heapify(heap)
    events = pop_cohort(heap, window=1.5, max_size=8)
    assert [cid for _, cid in events] == [0, 1, 2, 3]
    assert heap[0] == (9.0, 4)

    heap = [(1.0, 0), (1.1, 1), (1.2, 2), (9.0, 3)]
    heapq.heapify(heap)
    events = pop_cohort(heap, window=1.0, max_size=8, bucket_pow2=True)
    assert [cid for _, cid in events] == [0, 1]   # 3 -> largest pow2 = 2
    assert heap[0] == (1.2, 2)                    # tail went back

    heap = [(5.0, 7)]
    heapq.heapify(heap)
    assert pop_cohort(heap, window=0.0, max_size=4) == [(5.0, 7)]


def test_padded_cohort_size_buckets():
    """Arena cohorts pad to the pow2 bucket rounded up to a multiple of
    the mesh data-axis product, so the compiled leading dim always
    partitions and the recompile set collapses to the bucket sizes."""
    from repro.engine import padded_cohort_size
    assert [padded_cohort_size(k) for k in (1, 2, 3, 5, 8)] == [1, 2, 4, 8, 8]
    assert [padded_cohort_size(k, 8) for k in (1, 3, 6, 8, 9)] == \
        [8, 8, 8, 8, 16]
    # non-pow2 data axes round up to the next multiple
    assert padded_cohort_size(4, 6) == 6
    assert padded_cohort_size(8, 6) == 12
    # pow2 bucketing off (EngineConfig.pow2_cohorts=False): pad straight
    # to the MINIMAL multiple — pad members still burn masked compute
    assert padded_cohort_size(5, 6, pow2=False) == 6   # not 12
    assert padded_cohort_size(5, 1, pow2=False) == 5   # no padding at all
    assert padded_cohort_size(9, 8, pow2=False) == 16
    # every result divides evenly over the data axis
    for n_data in (1, 2, 4, 6, 8):
        for k in range(1, 33):
            for pow2 in (True, False):
                kp = padded_cohort_size(k, n_data, pow2)
                assert kp % n_data == 0 and kp >= k


def test_plan_batches_matches_legacy_slicing():
    """Same schedule as Client.local_train: per epoch one permutation cut
    into contiguous B-slices, ragged tail dropped."""
    rng_a = np.random.default_rng(42)
    rng_b = np.random.default_rng(42)
    n, B, E = 37, 8, 2
    idx = plan_batches(rng_a, n, B, E)
    expect = []
    for _ in range(E):
        perm = rng_b.permutation(n)
        for s in range(0, n - B + 1, B):
            expect.append(perm[s:s + B])
    np.testing.assert_array_equal(idx, np.stack(expect))
    assert idx.shape == (2 * 4, B)

    assert plan_batches(np.random.default_rng(0), 5, 8, 1).shape == (0, 8)
