"""Deterministic fault injection (repro.core.faults): model validation,
the fedavg deadline/quorum policy, same-seed replay determinism, and the
tentpole acceptance criterion — the SAME FaultModel replays the identical
fault event sequence on both execution backends (legacy per-client loop
vs cohort engine at staleness_window=0) with degraded cohorts riding the
existing zero-weight mask slots (no new compiles)."""
from dataclasses import replace

import numpy as np
import pytest

from repro.core.faults import (
    FAULT_STATS_KEYS, FaultInjector, FaultModel, apply_deadline,
    zero_fault_stats)
from repro.core.runlog import ENGINE_STATS_KEYS
from repro.core.testbed import run_experiment

# Probabilities high enough that a short run exercises every fault kind.
CHAOS = FaultModel(seed=7, failure_prob=0.1, upload_loss_prob=0.15,
                   max_retries=1, retry_backoff_s=4.0, duplicate_prob=0.15,
                   late_prob=0.1, leave_prob=0.1, rejoin_delay_s=40.0)
BARRIER = FaultModel(seed=7, failure_prob=0.12, upload_loss_prob=0.1,
                     max_retries=1, retry_backoff_s=4.0, leave_prob=0.1,
                     rejoin_delay_s=40.0, round_deadline_s=300.0,
                     min_quorum=2)


# ---------------------------------------------------------------------------
# FaultModel validation + stats schema
# ---------------------------------------------------------------------------

def test_fault_model_validates_at_construction():
    with pytest.raises(ValueError, match="failure_prob"):
        FaultModel(failure_prob=1.5)
    with pytest.raises(ValueError, match="leave_prob"):
        FaultModel(leave_prob=-0.1)
    with pytest.raises(ValueError, match="seed"):
        FaultModel(seed=-1)
    with pytest.raises(ValueError, match="max_retries"):
        FaultModel(max_retries=-2)
    with pytest.raises(ValueError, match="rejoin_delay_s"):
        FaultModel(rejoin_delay_s=-5.0)
    # zero re-entry delays under a positive probability would freeze
    # virtual time
    with pytest.raises(ValueError, match="retry_backoff_s"):
        FaultModel(upload_loss_prob=0.5, retry_backoff_s=0.0)
    with pytest.raises(ValueError, match="duplicate_delay_s"):
        FaultModel(duplicate_prob=0.5, duplicate_delay_s=0.0)
    with pytest.raises(ValueError, match="round_deadline_s"):
        FaultModel(round_deadline_s=0.0)
    with pytest.raises(ValueError, match="min_quorum"):
        FaultModel(min_quorum=0)
    FaultModel()  # the all-quiet default is valid


def test_fault_stats_schema_is_part_of_engine_stats():
    assert set(FAULT_STATS_KEYS) <= set(ENGINE_STATS_KEYS)
    z = zero_fault_stats()
    assert set(z) == set(FAULT_STATS_KEYS)
    assert all(v == 0 for v in z.values())


# ---------------------------------------------------------------------------
# apply_deadline (fedavg partial aggregation policy)
# ---------------------------------------------------------------------------

def test_apply_deadline_no_deadline_keeps_all_survivors():
    m = FaultModel()
    keep, rt = apply_deadline(m, [10.0, None, 30.0])
    assert keep == [True, False, True]
    assert rt == 30.0


def test_apply_deadline_nothing_survived():
    keep, rt = apply_deadline(FaultModel(), [None, None])
    assert keep == [False, False]
    assert rt is None


def test_apply_deadline_cuts_stragglers():
    m = FaultModel(round_deadline_s=300.0, min_quorum=1)
    keep, rt = apply_deadline(m, [10.0, 50.0, 400.0])
    assert keep == [True, True, False]
    assert rt == 300.0          # the round stopped AT the deadline


def test_apply_deadline_stretches_to_quorum():
    m = FaultModel(round_deadline_s=50.0, min_quorum=2)
    keep, rt = apply_deadline(m, [100.0, 200.0, 400.0])
    # the plain deadline would keep nobody; it stretches to the 2nd
    # smallest surviving delivery
    assert keep == [True, True, False]
    assert rt == 200.0


def test_apply_deadline_nobody_cut_charges_slowest_kept():
    m = FaultModel(round_deadline_s=300.0, min_quorum=1)
    keep, rt = apply_deadline(m, [10.0, 20.0, None])
    assert keep == [True, True, False]
    assert rt == 20.0           # nobody hit the deadline: normal barrier


def test_apply_deadline_quorum_larger_than_survivors_clamps():
    # quorum within the LIVE count but above the SURVIVOR count is the
    # legitimate degraded round: the clamp keeps every survivor
    m = FaultModel(round_deadline_s=1.0, min_quorum=3)
    keep, rt = apply_deadline(m, [10.0, 30.0, None])
    assert keep == [True, True, False]
    assert rt == 30.0


def test_apply_deadline_quorum_larger_than_live_count_raises():
    """A quorum no round can ever assemble is a configuration error, not
    a degraded round — the deadline would stretch unboundedly (PR 9
    bugfix; the old clamp silently aggregated below the quorum)."""
    m = FaultModel(round_deadline_s=1.0, min_quorum=5)
    with pytest.raises(ValueError, match=r"min_quorum=5.*live client count \(2\)"):
        apply_deadline(m, [10.0, 30.0])
    # same guard at injector construction, against the testbed size
    with pytest.raises(ValueError, match=r"min_quorum=5.*live client count \(3\)"):
        FaultInjector(m, 3)
    FaultInjector(m, 5)  # quorum == client count is the boundary: legal


# ---------------------------------------------------------------------------
# injector determinism
# ---------------------------------------------------------------------------

def test_injector_same_seed_replays_identically():
    a, b = FaultInjector(CHAOS, 4), FaultInjector(CHAOS, 4)
    for inj in (a, b):
        for step in range(40):
            cid = step % 4
            inj.on_completion(cid, 10.0 * step)
            inj.redispatch_delay(cid, 10.0 * step + 1.0)
    assert a.events == b.events
    assert a.stats() == b.stats()
    assert a.events                  # the chaos model actually fired


def test_injector_state_dict_roundtrip_resumes_mid_sequence():
    ref = FaultInjector(CHAOS, 3)
    for step in range(30):
        ref.on_completion(step % 3, 7.0 * step)

    half = FaultInjector(CHAOS, 3)
    for step in range(15):
        half.on_completion(step % 3, 7.0 * step)
    resumed = FaultInjector(CHAOS, 3)
    resumed.load_state_dict(half.state_dict())
    for step in range(15, 30):
        resumed.on_completion(step % 3, 7.0 * step)
    assert resumed.events == ref.events
    assert resumed.stats() == ref.stats()


def test_injector_ledger_invariant():
    inj = FaultInjector(CHAOS, 4)
    for step in range(60):
        inj.on_completion(step % 4, 5.0 * step)
    s = inj.stats()
    assert s["fault_upload_losses"] > 0
    assert s["fault_upload_losses"] == (
        s["fault_retries"] + s["fault_lost_updates"])


# ---------------------------------------------------------------------------
# retry-budget edges (PR 8 backoff re-entry, PR 9 regression coverage)
# ---------------------------------------------------------------------------

def _ledger_balances(stats):
    return stats["fault_upload_losses"] == (
        stats["fault_retries"] + stats["fault_lost_updates"])


def test_zero_retry_budget_drops_immediately():
    """max_retries=0: a lost upload never re-enters the heap — the first
    loss IS the lost update, so retry_backoff_s=0.0 is legal (nothing
    re-enters at a frozen virtual time)."""
    m = FaultModel(seed=11, upload_loss_prob=1.0, max_retries=0,
                   retry_backoff_s=0.0)
    inj = FaultInjector(m, 2)
    verdict, reason = inj.on_completion(0, 10.0)
    assert (verdict, reason) == ("drop", "retries_exhausted")
    s = inj.stats()
    assert s["fault_upload_losses"] == s["fault_lost_updates"] == 1
    assert s["fault_retries"] == 0
    assert _ledger_balances(s)
    assert [k for k, _, _ in inj.events] == ["upload_loss", "lost"]


def test_zero_backoff_with_positive_retries_rejected():
    # the carve-out is ONLY for max_retries=0; a retry at +0.0s would
    # re-pop the same virtual instant forever
    with pytest.raises(ValueError, match="retry_backoff_s"):
        FaultModel(upload_loss_prob=0.5, max_retries=1, retry_backoff_s=0.0)
    FaultModel(upload_loss_prob=0.5, max_retries=0, retry_backoff_s=0.0)


def test_retry_exhaustion_exactly_at_round_deadline():
    """A retry chain that exhausts with its final loss timestamped
    exactly AT the deadline: the member drops as a fault (offset None),
    and a surviving member delivered exactly at the deadline is KEPT
    (the deadline boundary is inclusive)."""
    m = FaultModel(seed=0, upload_loss_prob=1.0, max_retries=2,
                   retry_backoff_s=50.0, round_deadline_s=300.0,
                   min_quorum=1)
    inj = FaultInjector(m, 2)
    off, reason = inj.fedavg_fate(0, t0=0.0, duration=200.0)
    assert off is None and reason == "retries_exhausted"
    # losses at 200/250/300, retries into 250/300, lost at 300 == deadline
    assert [(k, t) for k, _, t in inj.events] == [
        ("upload_loss", 200.0), ("retry", 250.0),
        ("upload_loss", 250.0), ("retry", 300.0),
        ("upload_loss", 300.0), ("lost", 300.0)]
    s = inj.stats()
    assert s["fault_upload_losses"] == 3
    assert s["fault_retries"] == 2 and s["fault_lost_updates"] == 1
    assert _ledger_balances(s)
    # the boundary delivery at off == deadline survives the barrier
    keep, rt = apply_deadline(m, [None, 300.0])
    assert keep == [False, True]
    assert rt == 300.0


def test_zero_retry_budget_ledger_holds_end_to_end(micro_cfg):
    m = FaultModel(seed=5, upload_loss_prob=0.4, max_retries=0,
                   retry_backoff_s=0.0)
    _, log = run_experiment("fedasync", _faulty(micro_cfg, m),
                            engine="cohort", max_updates=20, eval_every=10,
                            alpha=0.4)
    s = log.engine_stats
    assert s["fault_upload_losses"] > 0
    assert s["fault_retries"] == 0
    assert _ledger_balances(s)
    kinds = [k for k, _, _ in log.fault_events]
    assert "retry" not in kinds and "lost" in kinds


# ---------------------------------------------------------------------------
# cross-backend fault replay parity (tentpole acceptance)
# ---------------------------------------------------------------------------

def _faulty(cfg, model):
    return replace(cfg, faults=model)


def test_async_fault_events_match_across_backends(micro_cfg):
    cfg = _faulty(micro_cfg, CHAOS)
    kw = dict(max_updates=24, eval_every=6, alpha=0.4)
    _, log_leg = run_experiment("fedasync", cfg, engine="legacy", **kw)
    _, log_eng = run_experiment("fedasync", cfg, engine="cohort", **kw)
    assert log_leg.fault_events, "the chaos model produced no faults"
    assert log_leg.fault_events == log_eng.fault_events
    assert log_leg.update_counts == log_eng.update_counts
    assert log_leg.staleness == log_eng.staleness
    np.testing.assert_allclose(log_leg.global_acc, log_eng.global_acc,
                               atol=1e-5)
    # the engine reports the counters; the legacy loop reports only the
    # event list (engine_stats is the engine's schema)
    s = log_eng.engine_stats
    assert s["fault_upload_losses"] == (
        s["fault_retries"] + s["fault_lost_updates"])
    assert not log_leg.engine_stats


def test_fedavg_fault_events_match_across_backends(micro_cfg):
    cfg = _faulty(micro_cfg, BARRIER)
    kw = dict(rounds=8, eval_every=2)
    _, log_leg = run_experiment("fedavg", cfg, engine="legacy", **kw)
    _, log_eng = run_experiment("fedavg", cfg, engine="cohort", **kw)
    assert log_leg.fault_events, "the barrier model produced no faults"
    assert log_leg.fault_events == log_eng.fault_events
    assert log_leg.times == log_eng.times   # deadline times agree exactly
    assert log_leg.update_counts == log_eng.update_counts
    np.testing.assert_allclose(log_leg.global_acc, log_eng.global_acc,
                               atol=1e-5)
    s = log_eng.engine_stats
    assert s["degraded_cohorts"] > 0
    assert s["deadline_drops"] + s["fault_failures"] + \
        s["fault_lost_updates"] > 0


def test_faultless_run_reports_zero_fault_stats(micro_cfg):
    _, log = run_experiment("fedavg", micro_cfg, rounds=1, engine="cohort")
    assert log.fault_events == []
    for k in FAULT_STATS_KEYS:
        assert log.engine_stats[k] == 0


def test_degraded_cohorts_compile_nothing_new(micro_cfg):
    """A dropped member stays in its compiled cohort as a zero-weight mask
    slot — after the fault-free run has warmed the step cache, a chaotic
    run of the same shape must not build a single new step."""
    from repro.engine.cohort_step import step_builds
    kw = dict(max_updates=16, eval_every=8, alpha=0.4, engine="cohort")
    run_experiment("fedasync", micro_cfg, **kw)            # warm the cache
    before = step_builds()
    chaos = replace(CHAOS, failure_prob=0.4)   # short run, certain drops
    _, log = run_experiment("fedasync", _faulty(micro_cfg, chaos), **kw)
    assert step_builds() == before
    assert log.engine_stats["degraded_cohorts"] > 0        # faults did fire


def test_fault_events_survive_in_runlog_order(micro_cfg):
    """fault_events is the injector's ordered ledger: timestamps are
    non-decreasing per client and every counted kind appears in it."""
    cfg = _faulty(micro_cfg, CHAOS)
    _, log = run_experiment("fedasync", cfg, engine="cohort",
                            max_updates=24, eval_every=8, alpha=0.4)
    per_cid = {}
    for kind, cid, t in log.fault_events:
        assert isinstance(kind, str) and isinstance(cid, int)
        # retries/late/duplicates are recorded at their FUTURE delivery
        # time, so only per-kind streams are monotone per client
        per_cid.setdefault((cid, kind), []).append(t)
    for ts in per_cid.values():
        assert ts == sorted(ts)
    kinds = {k for k, _, _ in log.fault_events}
    s = log.engine_stats
    for kind, counter in (("failure", "fault_failures"),
                          ("upload_loss", "fault_upload_losses"),
                          ("leave", "fault_churn_leaves")):
        assert (kind in kinds) == (s[counter] > 0)
