"""Compiled-program audits + the compile guard.

Device-free logic runs everywhere; the real-cohort-step audits need
multiple host devices and activate in CI's engine-mesh job
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``), where they
check the ACTUAL compiled step — and seeded regressions (replicated
client axis, dropped donation, forbidden collective) must each fail."""
import functools
import warnings
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (
    AuditFailure, CompileBudgetExceeded, audit_collectives, audit_donation,
    audit_engine_stats, audit_sharding, compile_guard, donation_aliases,
    step_signature, sweep_max_builds)
from repro.core.runlog import ENGINE_STATS_KEYS

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs multiple devices (CI: XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")


# ---------------------------------------------------------------------------
# donation audit (single device suffices: CPU materializes aliases)
# ---------------------------------------------------------------------------

def _compiled_text(fn, *avals, donate=()):
    f = jax.jit(fn, donate_argnums=donate) if donate else jax.jit(fn)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")      # "donated buffers not usable"
        return f.lower(*avals).compile().as_text()


def test_donation_alias_table_parses():
    txt = _compiled_text(lambda a, b: (a + b, b * 2),
                         jnp.zeros((8, 4)), jnp.ones((8, 4)), donate=(0,))
    aliases = donation_aliases(txt)
    assert aliases and all(isinstance(p, int) for _, p in aliases)
    assert audit_donation(txt, expect=True) == len(aliases)


def test_donation_audit_catches_dropped_alias():
    # donate requested, but no output matches the input buffer — XLA
    # silently copies; the audit is what makes that loud
    txt = _compiled_text(lambda a: a[0:1], jnp.zeros((8, 4)), donate=(0,))
    assert donation_aliases(txt) == []
    with pytest.raises(AuditFailure, match="aliases materialized"):
        audit_donation(txt, expect=True)


def test_donation_audit_catches_unexpected_alias():
    # the pipelined path REQUIRES donation-free programs
    txt = _compiled_text(lambda a, b: (a + b, b * 2),
                         jnp.zeros((8, 4)), jnp.ones((8, 4)), donate=(0,))
    with pytest.raises(AuditFailure, match="expected OFF"):
        audit_donation(txt, expect=False)
    clean = _compiled_text(lambda a, b: a + b,
                           jnp.zeros((8, 4)), jnp.ones((8, 4)))
    assert audit_donation(clean, expect=False) == 0


# ---------------------------------------------------------------------------
# sharding audit (device-free via stand-in shardings)
# ---------------------------------------------------------------------------

class _FakeSharding:
    def __init__(self, shard_shape):
        self._shard = tuple(shard_shape)

    def shard_shape(self, shape):
        return self._shard


def _fake_compiled(*shardings):
    return SimpleNamespace(output_shardings=list(shardings))


def test_sharding_audit_passes_partitioned():
    compiled = _fake_compiled(_FakeSharding((1, 4)), _FakeSharding(()))
    assert audit_sharding(compiled, [(8, 4), ()], client_dim=8,
                          min_partition=2) == 1


def test_sharding_audit_fails_replicated_client_axis():
    compiled = _fake_compiled(_FakeSharding((8, 4)))
    with pytest.raises(AuditFailure, match="replicated"):
        audit_sharding(compiled, [(8, 4)], client_dim=8)


def test_sharding_audit_fails_when_nothing_matches():
    compiled = _fake_compiled(_FakeSharding((2, 4)))
    with pytest.raises(AuditFailure, match="checked nothing"):
        audit_sharding(compiled, [(16, 4)], client_dim=8)


# ---------------------------------------------------------------------------
# collective audit (synthetic HLO)
# ---------------------------------------------------------------------------

_AG_HLO = """\
HloModule m, entry_computation_layout={(f32[4,8]{1,0})->f32[32,8]{1,0}}

ENTRY %main (p0: f32[4,8]) -> f32[32,8] {
  %p0 = f32[4,8] parameter(0)
  ROOT %ag = f32[32,8] all-gather(%p0), replica_groups=[1,8]<=[8], dimensions={0}
}
"""


def test_collective_audit_forbid_fires():
    with pytest.raises(AuditFailure, match="forbidden collective"):
        audit_collectives(_AG_HLO, forbid=("all-gather",))


def test_collective_audit_budget():
    counts = audit_collectives(_AG_HLO, max_counts={"all-gather": 1})
    assert counts["all-gather"] == 1
    with pytest.raises(AuditFailure, match="exceeds budget"):
        audit_collectives(_AG_HLO, max_counts={"all-gather": 0})


# ---------------------------------------------------------------------------
# engine-stats audit
# ---------------------------------------------------------------------------

def _stats(**over):
    base = {k: 0 for k in ENGINE_STATS_KEYS}
    base.update(data_path="arena", dp_path="jnp", pallas_interpret=None,
                h2d_bytes_per_cohort=0.0, pipeline_depth=1)
    base.update(over)
    return base


def test_engine_stats_audit_roundtrip():
    assert audit_engine_stats(_stats()) == _stats()


def test_engine_stats_audit_catches_drift():
    missing = _stats()
    missing.pop("drain_waits")
    with pytest.raises(AuditFailure, match="drain_waits"):
        audit_engine_stats(missing)
    extra = _stats(new_counter=3)
    with pytest.raises(AuditFailure, match="new_counter"):
        audit_engine_stats(extra)


def test_engine_stats_audit_cross_field_invariants():
    with pytest.raises(AuditFailure, match="submit/drain overlap"):
        audit_engine_stats(_stats(pipeline_depth=2,
                                  host_syncs_between_evals=1))
    with pytest.raises(AuditFailure, match="interpret provenance"):
        audit_engine_stats(_stats(dp_path="pallas"))
    ok = _stats(dp_path="pallas", pallas_interpret={
        "backend": "cpu", "interpret": True, "source": "auto"})
    audit_engine_stats(ok)


# ---------------------------------------------------------------------------
# compile guard
# ---------------------------------------------------------------------------

def test_compile_guard_budget_and_delta():
    from repro.engine import cohort_step
    base = cohort_step._STEP_BUILDS
    try:
        with compile_guard(2, label="test") as g:
            cohort_step._STEP_BUILDS += 1
            assert g.delta == 1
        assert g.delta == 1

        with pytest.raises(CompileBudgetExceeded, match="budgeted for 0"):
            with compile_guard(0, label="test"):
                cohort_step._STEP_BUILDS += 1
    finally:
        cohort_step._STEP_BUILDS = base


def test_compile_guard_never_masks_exceptions():
    from repro.engine import cohort_step
    base = cohort_step._STEP_BUILDS
    try:
        with pytest.raises(RuntimeError, match="boom"):
            with compile_guard(0):
                cohort_step._STEP_BUILDS += 5
                raise RuntimeError("boom")
    finally:
        cohort_step._STEP_BUILDS = base


def test_compile_guard_rejects_negative_budget():
    with pytest.raises(ValueError):
        with compile_guard(-1):
            pass


# ---------------------------------------------------------------------------
# sweep budgets from spec signatures
# ---------------------------------------------------------------------------

def test_sigma_grid_is_one_signature():
    from repro.api.spec import ExperimentSpec, replace_path
    import dataclasses
    spec = ExperimentSpec()
    spec = replace_path(spec, "testbed.use_dp", True)
    grid = [replace_path(spec, "testbed.sigma", s) for s in (0.5, 1.0, 2.0)]
    assert sweep_max_builds(grid) == 1
    assert len({step_signature(s) for s in grid}) == 1
    # noise OFF is a different program (add_noise is static)
    grid.append(replace_path(spec, "testbed.sigma", 0.0))
    assert sweep_max_builds(grid) == 2
    # so is a different DP implementation
    grid.append(replace_path(grid[0], "testbed.dp_path", "pallas"))
    assert sweep_max_builds(grid) == 3
    # legacy backend never touches the step cache
    assert step_signature(
        dataclasses.replace(spec, backend="legacy")) is None
    assert sweep_max_builds([dataclasses.replace(spec, backend="legacy")]) == 0


# ---------------------------------------------------------------------------
# the REAL compiled cohort step (multi-device; CI engine-mesh job)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def compiled_step():
    if len(jax.devices()) < 2:
        pytest.skip("needs multiple devices")
    import jax.random as jr
    from repro.api.workloads import get_workload
    from repro.core.testbed import TestbedConfig, build_clients, \
        build_partitions
    from repro.data.synthetic_ser import SERDataConfig
    from repro.engine import CohortRunner, EngineConfig, cohort_mesh
    from repro.models.ser_cnn import SERConfig

    n_clients = 8
    dims = dict(time_frames=12, n_mels=12)
    mesh = cohort_mesh(max_cohort=n_clients)
    ec = EngineConfig(staleness_window=45.0, max_cohort=8,
                      client_axis="vmap", mesh=mesh)
    tb = TestbedConfig(
        use_dp=True, sigma=0.5, batch_size=16, num_clients=n_clients,
        data=SERDataConfig(n_total=36 * n_clients, **dims),
        model=SERConfig(channels1=8, channels2=16, fc_dim=32, **dims))
    splits, _pooled = build_partitions(tb)
    clients = build_clients(tb, splits)
    runner = CohortRunner(clients, ec)
    wl = get_workload(tb.workload)
    params0 = wl.init(jr.PRNGKey(0), tb.model)
    key = jr.PRNGKey(1)
    plans = []
    for c in clients:
        key, sub = jr.split(key)
        plans.append(runner.dispatch(c, params0, sub, 0))
    staged = runner.stage_cohort(plans)
    runner._ensure_state_arenas(params0)
    args = (runner._arena_params, runner._arena_opt, runner._arena_data,
            staged.slots, staged.data_slots, staged.batch_idx, staged.keys,
            staged.n_steps, runner._noise_std, staged.corrupt)
    compiled = runner.cohort_step.lower(*args).compile()
    shapes = [tuple(s.shape) for s in jax.tree_util.tree_leaves(
        jax.eval_shape(lambda *a: runner.cohort_step(*a), *args))]
    return SimpleNamespace(compiled=compiled, text=compiled.as_text(),
                           shapes=shapes, n_clients=n_clients,
                           n_devices=len(mesh.devices.flatten()))


@multi_device
def test_real_step_client_axis_partitions(compiled_step):
    # every output leaf stacked over the cohort axis must partition —
    # GSPMD replicating it is the PR-2 silent regression
    audited = audit_sharding(
        compiled_step.compiled, compiled_step.shapes,
        client_dim=compiled_step.n_clients,
        min_partition=compiled_step.n_devices)
    assert audited > 0


@multi_device
def test_real_step_is_donation_free(compiled_step):
    # since the PR-9 screen/corrupt epilogue the cohort step never
    # donates its inputs on ANY path (XLA:CPU's thunk runtime recycled
    # the donated opt arena while the epilogue still read pre-scatter
    # state); the alias table must stay empty — the same invariant the
    # pipelined scheduler always required
    assert audit_donation(compiled_step.text, expect=False) == 0


@multi_device
def test_real_step_collective_budget(compiled_step):
    # the sharded-arena gather legitimately all-gathers; pin the budget
    # to its measured footprint (rederive deliberately if the data path
    # changes) rather than pretending the step is collective-free
    counts = audit_collectives(
        compiled_step.text,
        max_counts={"all-gather": 120, "all-reduce": 60, "all-to-all": 8,
                    "reduce-scatter": 8, "collective-permute": 8})
    assert counts.get("all-gather", 0) > 0       # the gather IS there


@multi_device
def test_seeded_replicated_client_axis_fails(compiled_step):
    # regression seed: an unconstrained program leaves the client axis
    # replicated -> the audit must fire
    x = jnp.zeros((compiled_step.n_clients, 4))
    compiled = jax.jit(lambda v: v * 2).lower(x).compile()
    with pytest.raises(AuditFailure, match="replicated"):
        audit_sharding(compiled, [(compiled_step.n_clients, 4)],
                       client_dim=compiled_step.n_clients)


@multi_device
def test_seeded_forced_all_gather_fails():
    from jax.sharding import NamedSharding, PartitionSpec as P
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    sharded = NamedSharding(mesh, P("data"))
    repl = NamedSharding(mesh, P())
    f = jax.jit(lambda v: v + 1.0, in_shardings=(sharded,),
                out_shardings=repl)
    txt = f.lower(
        jax.ShapeDtypeStruct((8 * n, 4), jnp.float32)).compile().as_text()
    with pytest.raises(AuditFailure, match="all-gather"):
        audit_collectives(txt, forbid=("all-gather",))
