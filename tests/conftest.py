"""Shared fixtures and markers for the tier-1 suite.

Session-scoped tiny-model / tiny-data fixtures keep the default run fast:
build the synthetic SER testbed once and share it across test modules.
Long end-to-end FL runs carry ``@pytest.mark.slow`` and are deselected by
default (pytest.ini adds ``-m "not slow"``); run them with ``-m slow``.
"""
import pytest

from repro.core.testbed import TestbedConfig
from repro.data.synthetic_ser import SERDataConfig


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long end-to-end FL system runs; deselected by default "
        "(pytest.ini addopts), select with -m slow")


@pytest.fixture(scope="session")
def tiny_cfg():
    """Reduced-scale testbed config for the end-to-end FL system tests
    (matches the historical test_fl_system module fixture)."""
    return TestbedConfig(
        use_dp=True, sigma=1.0, batch_size=64,
        data=SERDataConfig(n_total=1600), seed=1,
    )


@pytest.fixture(scope="session")
def micro_cfg():
    """Smallest useful testbed: 480 clips / 5 clients / 2 DP-SGD steps per
    round — for parity and engine tests that must run in the default
    (non-slow) suite."""
    return TestbedConfig(
        use_dp=True, sigma=1.0, batch_size=32,
        data=SERDataConfig(n_total=480), seed=3,
    )
