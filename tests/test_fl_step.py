"""fl_step seams: split_batch validation (was a cryptic XLA reshape
error) and the factored local phase the cohort engine drives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dp import DPConfig
from repro.core.fl_step import (
    FLStepConfig, make_fl_train_step, make_local_phase,
    make_server_optimizer, split_batch)

_NOCLIP = DPConfig(clip_norm=1e9, noise_multiplier=0.0,
                   granularity="per_microbatch")


def test_split_batch_layout():
    y = split_batch(jnp.zeros((24, 5)), G=2, n_local=3, n_micro=2)
    assert y.shape == (2, 3, 2, 2, 5)


def test_split_batch_rejects_indivisible_global_batch():
    with pytest.raises(ValueError, match=r"num_clients G=3"):
        split_batch(jnp.zeros((20, 5)), G=3, n_local=1, n_micro=2)


def test_split_batch_rejects_indivisible_per_client_slice():
    with pytest.raises(ValueError, match=r"n_local\*n_micro = 2\*3"):
        split_batch(jnp.zeros((20, 5)), G=2, n_local=2, n_micro=3)


def test_fl_train_step_names_bad_batch_config():
    """The compiled step surfaces the ValueError at trace time, naming
    the offending shape and config values."""
    fl = FLStepConfig(num_clients=2, n_local=2, n_micro=2, dp=_NOCLIP)

    def loss(p, b):
        return jnp.mean((b["x"] @ p["w"]) ** 2)

    params = {"w": jnp.ones((4, 2))}
    step = make_fl_train_step(loss, fl)
    opt_state = make_server_optimizer(fl).init(params)
    batch = {"x": jnp.zeros((12, 4))}    # 12/G=6 not divisible by 2*2
    with pytest.raises(ValueError, match=r"global batch 12 over G=2"):
        step(params, opt_state, batch, jnp.ones((2,)) / 2,
             jax.random.PRNGKey(0))


def test_local_phase_step_count_from_batch_and_mask():
    """make_local_phase takes its step count from the batch's leading dim
    and n_steps masks trailing steps without touching params — the cohort
    engine pads every member to a common step count this way."""
    fl = FLStepConfig(num_clients=1, n_local=3, n_micro=1, local_lr=0.1,
                      dp=_NOCLIP)

    def loss(p, b):
        return jnp.mean((p["w"] - b["x"]) ** 2)

    lp = make_local_phase(loss, fl)
    params = {"w": jnp.zeros((3,))}
    key = jax.random.PRNGKey(0)
    batch3 = {"x": jnp.ones((3, 1, 2, 3))}   # (n_local, n_micro, per, feat)
    full = lp(params, batch3, key)
    masked = lp(params, batch3, key, n_steps=2)
    ref2 = lp(params, {"x": jnp.ones((2, 1, 2, 3))}, key)
    np.testing.assert_allclose(np.asarray(masked["w"]), np.asarray(ref2["w"]),
                               rtol=1e-6)
    assert not np.allclose(np.asarray(full["w"]), np.asarray(masked["w"]))


def test_local_phase_micro_divisor_from_batch():
    """Regression: ``one_local_step`` divided the accumulated microbatch
    grads — and the Eq. 5 noise stddev — by the STATIC ``fl.n_micro``
    while scanning the batch's ACTUAL microbatch dim, silently mis-
    scaling both whenever the batch layout disagreed with the config.
    Both divisors now derive from the batch, so two configs differing
    only in ``n_micro`` must produce identical updates from the same
    batch (noise on: the stddev divisor is exercised too)."""
    def loss(p, b):
        return jnp.mean((p["w"] - b["x"]) ** 2)

    params = {"w": jnp.zeros((3,))}
    key = jax.random.PRNGKey(1)
    # batch laid out with 2 microbatches per local step
    batch = {"x": jnp.linspace(-1.0, 1.0, 1 * 2 * 2 * 3).reshape(1, 2, 2, 3)}
    dp = DPConfig(clip_norm=0.5, noise_multiplier=1.3,
                  granularity="per_microbatch")
    out = {}
    for n_micro in (2, 8):       # 8 disagrees with the batch's 2
        fl = FLStepConfig(num_clients=1, n_local=1, n_micro=n_micro,
                          local_lr=0.1, dp=dp)
        out[n_micro] = make_local_phase(loss, fl)(params, batch, key)
    np.testing.assert_allclose(np.asarray(out[2]["w"]),
                               np.asarray(out[8]["w"]), rtol=1e-6)
    # and the batch-derived scaling is the CORRECT one: with noise off,
    # the update equals local_lr * mean of the 2 clipped microbatch grads
    fl0 = FLStepConfig(num_clients=1, n_local=1, n_micro=8, local_lr=0.1,
                       dp=DPConfig(clip_norm=0.5, noise_multiplier=0.0,
                                   granularity="per_microbatch"))
    got = make_local_phase(loss, fl0)(params, batch, key)
    from repro.core.dp import clip_tree
    acc = jnp.zeros((3,))
    for m in range(2):
        g = jax.grad(lambda p: loss(p, {"x": batch["x"][0, m]}))(params)
        acc = acc + clip_tree(g, 0.5)[0]["w"]
    np.testing.assert_allclose(np.asarray(got["w"]),
                               np.asarray(-0.1 * acc / 2), rtol=1e-5,
                               atol=1e-7)
