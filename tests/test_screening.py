"""Update screening & robust aggregation (PR 9): the compiled
corrupt-update defense.

Covers the tentpole acceptance criteria end to end: in-step rejection
with a ``step_builds`` delta of 0 (thresholds are host-side runtime
scalars), same-seed identical ``fault_events`` on the legacy loop and
the cohort engine, quarantine state carried bit-identically across a
checkpoint/resume boundary, the two robust aggregators, and the
satellite-2 guarantee that screening at infinite thresholds is a
bitwise no-op on a fault-free run (single device here, forced-8-device
mesh in a subprocess)."""
import os
import subprocess
import sys
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.audits import audit_engine_stats
from repro.api.session import Session
from repro.api.spec import ExperimentSpec, RunBudget, StrategySpec
from repro.checkpoint import latest_step
from repro.core.aggregation import (FedAsync, NormBoundedFedAsync,
                                    STRATEGIES, TrimmedMeanFedAvg,
                                    make_strategy)
from repro.core.faults import FaultModel
from repro.core.runlog import ENGINE_STATS_KEYS
from repro.core.screening import (SCREEN_STATS_KEYS, ScreeningConfig,
                                  ScreeningState, corrupt_update,
                                  screen_update, zero_screen_stats)
from repro.core.testbed import run_experiment
from repro.data.synthetic_ser import SERDataConfig
from repro.core.testbed import TestbedConfig
from repro.engine import SimulatedCrash
from repro.engine.cohort_step import step_builds

# The verified corruption drill: half the deliveries corrupted, split
# between all-NaN payloads and 1e6x delta blowups — both far outside
# max_update_norm=1e3, so every corrupt delivery is rejected in-step.
CORRUPT = FaultModel(seed=7, corrupt_prob=0.5)
SCREEN = ScreeningConfig(max_update_norm=1e3, quarantine_after=2,
                         readmit_delay_s=100.0)


def _assert_params_close(a, b, rtol=1e-4, atol=2e-5):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


def _logdict(log, drop_syncs=False):
    es = dict(log.engine_stats)
    if drop_syncs:
        # screening-on runs one sanctioned verdict fetch per cohort; the
        # satellite-2 no-op contract covers everything BUT that counter
        es.pop("screen_verdict_syncs", None)
    return dict(times=log.times, acc=log.global_acc,
                sv=log.server_version, uc=dict(log.update_counts),
                inf=log.influence, st=log.staleness,
                eps={k: list(v) for k, v in log.eps_trajectory.items()},
                fe=list(log.fault_events), es=es,
                cs=list(log.cohort_sizes), dr=dict(log.dropouts))


# ---------------------------------------------------------------------------
# config validation & stats schema
# ---------------------------------------------------------------------------

def test_screening_config_validation():
    ScreeningConfig()                          # all-defaults is legal
    ScreeningConfig(max_update_norm=5.0, quarantine_after=3,
                    readmit_delay_s=10.0)
    with pytest.raises(ValueError, match="max_update_norm"):
        ScreeningConfig(max_update_norm=0.0)
    with pytest.raises(ValueError, match="max_update_norm"):
        ScreeningConfig(max_update_norm=-1.0)
    with pytest.raises(ValueError, match="quarantine_after"):
        ScreeningConfig(quarantine_after=-1)
    with pytest.raises(ValueError, match="readmit_delay_s"):
        ScreeningConfig(quarantine_after=2, readmit_delay_s=0.0)
    # readmit delay is irrelevant while quarantine is off
    ScreeningConfig(quarantine_after=0, readmit_delay_s=0.0)


def test_screen_stats_schema():
    assert set(SCREEN_STATS_KEYS) <= set(ENGINE_STATS_KEYS)
    z = zero_screen_stats()
    assert set(z) == set(SCREEN_STATS_KEYS)
    assert all(v == 0 for v in z.values())


def test_corrupt_fault_model_validation():
    with pytest.raises(ValueError, match="corrupt_scale"):
        FaultModel(corrupt_prob=0.5, corrupt_scale=1.0)
    with pytest.raises(ValueError, match="corrupt_scale"):
        FaultModel(corrupt_scale=float("inf"))
    with pytest.raises(ValueError, match="corrupt_nan_frac"):
        FaultModel(corrupt_nan_frac=1.5)


# ---------------------------------------------------------------------------
# host-side mirrors of the in-step corrupt/screen passes
# ---------------------------------------------------------------------------

def _tree(*vals):
    return {"w": jnp.asarray(vals[0], jnp.float32),
            "b": jnp.asarray(vals[1], jnp.float32)}


def test_corrupt_update_mirror():
    ref = _tree([1.0, 2.0], [0.5])
    new = _tree([1.5, 1.0], [0.5])
    assert corrupt_update(ref, new, 1.0) is new     # clean sentinel
    blown = corrupt_update(ref, new, 3.0)           # p0 + 3 (p - p0)
    np.testing.assert_allclose(blown["w"], [2.5, -1.0])
    np.testing.assert_allclose(blown["b"], [0.5])
    nan = corrupt_update(ref, new, float("nan"))
    assert all(np.isnan(np.asarray(l)).all()
               for l in jax.tree_util.tree_leaves(nan))


def test_screen_update_mirror():
    ref = _tree([1.0, 2.0], [0.0])
    new = _tree([1.0, 5.0], [4.0])
    finite, norm = screen_update(ref, new)
    assert finite and norm == pytest.approx(5.0)    # sqrt(3^2 + 4^2)
    bad = _tree([float("nan"), 5.0], [4.0])
    finite, norm = screen_update(ref, bad)
    assert not finite


# ---------------------------------------------------------------------------
# the deterministic quarantine runtime
# ---------------------------------------------------------------------------

def test_screening_state_strike_quarantine_readmit():
    cfg = ScreeningConfig(max_update_norm=10.0, quarantine_after=2,
                          readmit_delay_s=50.0)
    st = ScreeningState(cfg, num_clients=2)
    assert st.screen(0, 0.0, True, 5.0)             # clean: accepted
    assert not st.screen(0, 1.0, True, 20.0)        # norm reject, strike 1
    assert not st.screen(0, 2.0, False, float("nan"))  # strike 2 -> suspend
    assert not st.screen(0, 3.0, True, 1.0)         # dropped unseen
    assert st.screen(1, 4.0, True, 1.0)             # other client unaffected
    assert st.screen(0, 52.0, True, 1.0)            # served delay -> readmit
    c = st.counters
    assert c["screen_rejections"] == 2
    assert c["screen_rejections"] == (c["screen_nonfinite"]
                                      + c["screen_norm_rejects"])
    assert c["screen_quarantined"] == 1
    assert c["screen_quarantine_drops"] == 1
    assert st.events == [("screen_norm", 0, 1.0),
                         ("screen_nonfinite", 0, 2.0),
                         ("quarantine", 0, 2.0),
                         ("quarantine_drop", 0, 3.0),
                         ("readmit", 0, 52.0)]


def test_screening_state_checkpoint_roundtrip_mid_quarantine():
    """A snapshot taken while a client is suspended must replay the
    remaining drop/readmit sequence identically after restore."""
    cfg = ScreeningConfig(max_update_norm=10.0, quarantine_after=1,
                          readmit_delay_s=30.0)

    def drive(st, steps):
        return [st.screen(*s) for s in steps]

    pre = [(0, 1.0, True, 99.0)]                    # reject -> quarantine
    post = [(0, 5.0, True, 1.0),                    # dropped (suspended)
            (0, 31.0, True, 1.0),                   # readmit + accept
            (0, 40.0, False, 0.0)]                  # reject -> re-quarantine
    a = ScreeningState(cfg, 2)
    drive(a, pre)
    snap = a.state_dict()
    b = ScreeningState(cfg, 2)
    b.load_state_dict(snap)
    assert drive(a, post) == drive(b, post)
    assert a.state_dict() == b.state_dict()
    assert a.events == b.events


# ---------------------------------------------------------------------------
# robust aggregators
# ---------------------------------------------------------------------------

def test_trimmed_mean_math_and_outlier_immunity():
    strat = TrimmedMeanFedAvg(trim_frac=0.2)
    vals = [1.0, 2.0, 3.0, 4.0, 1e9]                # one blown-up payload
    updates = [({"w": jnp.asarray([v], jnp.float32)}, 100 * (i + 1))
               for i, v in enumerate(vals)]         # weights must be ignored
    out = strat.aggregate(None, updates)
    # k=5, cut=1: sort, drop one from each end, mean of [2, 3, 4]
    np.testing.assert_allclose(out["w"], [3.0])
    # two-member cohort: cut clamps to 0, plain unweighted mean survives
    out2 = strat.aggregate(None, updates[:2])
    np.testing.assert_allclose(out2["w"], [1.5])
    with pytest.raises(ValueError, match="trim_frac"):
        TrimmedMeanFedAvg(trim_frac=0.5)


def test_normbound_merge_clamps_and_matches_fedasync_in_bound():
    g = {"w": jnp.asarray([0.0, 0.0], jnp.float32)}
    plain, robust = FedAsync(alpha=0.4), NormBoundedFedAsync(alpha=0.4,
                                                             norm_bound=5.0)
    inb = {"w": jnp.asarray([3.0, 0.0], jnp.float32)}     # norm 3 < 5
    (mp, ap), (mr, ar) = plain.merge(g, inb, 2), robust.merge(g, inb, 2)
    assert ap == ar
    np.testing.assert_array_equal(np.asarray(mp["w"]), np.asarray(mr["w"]))
    # oversized: the merge moves alpha_k * norm_bound, never further
    big = {"w": jnp.asarray([30.0, 40.0], jnp.float32)}   # norm 50
    mb, ab = robust.merge(g, big, 0)
    np.testing.assert_allclose(np.asarray(mb["w"]),
                               0.4 * 5.0 * np.asarray([0.6, 0.8]),
                               rtol=1e-6)
    # nonfinite payload contributes nothing at all
    nan = {"w": jnp.asarray([float("nan"), 1.0], jnp.float32)}
    mn, _ = robust.merge(g, nan, 0)
    np.testing.assert_array_equal(np.asarray(mn["w"]), np.asarray(g["w"]))
    with pytest.raises(ValueError, match="norm_bound"):
        NormBoundedFedAsync(norm_bound=0.0)


def test_robust_strategies_registered_and_spec_validated():
    assert "fedavg_trimmed" in STRATEGIES
    assert "fedasync_normbound" in STRATEGIES
    t = make_strategy("fedavg_trimmed", trim_frac=0.25)
    assert isinstance(t, TrimmedMeanFedAvg) and not t.is_async
    n = make_strategy("fedasync_normbound", alpha=0.5, norm_bound=2.0)
    assert isinstance(n, NormBoundedFedAsync) and n.is_async
    StrategySpec("fedavg_trimmed", trim_frac=0.1)         # registry-legal
    with pytest.raises(ValueError):
        StrategySpec("fedavg_trimmed", trim_frac=0.7)     # validated at spec
    with pytest.raises(ValueError):
        StrategySpec("fedasync_normbound", bogus=1.0)


# ---------------------------------------------------------------------------
# backend parity under corruption (tentpole acceptance)
# ---------------------------------------------------------------------------

def test_corrupt_run_parity_legacy_vs_cohort(micro_cfg):
    """Same seed + same configs replay the identical corruption and
    rejection/quarantine event sequence on both backends, and the
    defended models agree numerically."""
    cfg = replace(micro_cfg, faults=CORRUPT, screening=SCREEN)
    kw = dict(max_updates=12, eval_every=4, alpha=0.4)
    p_leg, log_leg = run_experiment("fedasync", cfg, engine="legacy", **kw)
    p_eng, log_eng = run_experiment("fedasync", cfg, engine="cohort", **kw)
    assert list(log_leg.fault_events) == list(log_eng.fault_events)
    assert log_leg.update_counts == log_eng.update_counts
    assert log_leg.staleness == log_eng.staleness
    _assert_params_close(p_leg, p_eng)
    kinds = [e[0] for e in log_eng.fault_events]
    assert {"corrupt_nan", "corrupt_scale"} & set(kinds)  # faults fired
    assert ("screen_nonfinite" in kinds) or ("screen_norm" in kinds)
    es = log_eng.engine_stats
    audit_engine_stats(es)
    assert es["screen_rejections"] > 0
    assert es["screen_rejections"] == (es["screen_nonfinite"]
                                       + es["screen_norm_rejects"])
    assert es["fault_corruptions"] >= es["screen_rejections"]
    assert es["screen_verdict_syncs"] > 0


def test_pipelined_screening_keeps_sync_free_invariant(micro_cfg):
    """Verdict fetches route through the sanctioned funnel: a PIPELINED
    corrupted run still reports ``host_syncs_between_evals == 0`` while
    the verdict-fetch counter accounts for every device->host read the
    screening oracle needed."""
    from repro.engine import EngineConfig
    cfg = replace(micro_cfg, faults=CORRUPT, screening=SCREEN)
    _, log = run_experiment("fedasync", cfg, max_updates=12, eval_every=4,
                            alpha=0.4, engine="cohort",
                            engine_cfg=EngineConfig(pipeline_depth=2))
    es = log.engine_stats
    audit_engine_stats(es)
    assert es["pipeline_depth"] == 2
    assert es["screen_rejections"] > 0
    assert es["screen_verdict_syncs"] > 0
    assert es["host_syncs_between_evals"] == 0


def test_corrupt_fedavg_parity_legacy_vs_cohort(micro_cfg):
    cfg = replace(micro_cfg, faults=CORRUPT, screening=SCREEN)
    p_leg, log_leg = run_experiment("fedavg", cfg, rounds=2, engine="legacy")
    p_eng, log_eng = run_experiment("fedavg", cfg, rounds=2, engine="cohort")
    assert list(log_leg.fault_events) == list(log_eng.fault_events)
    assert log_leg.update_counts == log_eng.update_counts
    _assert_params_close(p_leg, p_eng)
    assert log_eng.engine_stats["screen_rejections"] > 0


# ---------------------------------------------------------------------------
# satellite 2: screening at infinite thresholds is a bitwise no-op
# ---------------------------------------------------------------------------

def test_screening_off_vs_infinite_thresholds_bitwise(micro_cfg):
    """Fault-free run: screening=None vs a screening pass that can never
    reject (no norm bound, quarantine off) — the RunLog (minus the
    sanctioned verdict-fetch counter) and params are IDENTICAL, because
    the compiled step always computes the verdicts and acceptance routes
    through the same merge coefficients."""
    kw = dict(max_updates=8, eval_every=4, alpha=0.4)
    p_off, log_off = run_experiment("fedasync", micro_cfg, **kw)
    cfg_on = replace(micro_cfg,
                     screening=ScreeningConfig(max_update_norm=None))
    p_on, log_on = run_experiment("fedasync", cfg_on, **kw)
    assert _logdict(log_off, drop_syncs=True) == \
        _logdict(log_on, drop_syncs=True)
    assert log_off.engine_stats["screen_verdict_syncs"] == 0
    assert log_on.engine_stats["screen_verdict_syncs"] > 0
    assert log_on.engine_stats["screen_rejections"] == 0
    for a, b in zip(jax.tree_util.tree_leaves(p_off),
                    jax.tree_util.tree_leaves(p_on)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_screening_off_noop_on_forced_eight_device_mesh():
    """The same bitwise no-op contract on a forced 8-device host mesh
    (own subprocess so the main session keeps its single-device cache)."""
    code = """
from dataclasses import replace
import jax
import numpy as np
assert len(jax.devices()) == 8, len(jax.devices())
from repro.core.screening import ScreeningConfig
from repro.core.testbed import TestbedConfig, run_experiment
from repro.data.synthetic_ser import SERDataConfig
from repro.engine import EngineConfig
from repro.launch.mesh import make_host_mesh

cfg = TestbedConfig(num_clients=4, data=SERDataConfig(n_total=160),
                    batch_size=32, sigma=0.5, seed=3)
kw = dict(max_updates=6, eval_every=6, alpha=0.4,
          engine_cfg=EngineConfig(client_axis="vmap", max_cohort=4))
p_off, log_off = run_experiment("fedasync", cfg, mesh=make_host_mesh(data=4),
                                **kw)
p_on, log_on = run_experiment(
    "fedasync", replace(cfg, screening=ScreeningConfig(max_update_norm=None)),
    mesh=make_host_mesh(data=4), **kw)
assert log_off.times == log_on.times
assert log_off.global_acc == log_on.global_acc
assert log_off.update_counts == log_on.update_counts
assert list(log_off.fault_events) == list(log_on.fault_events)
assert log_on.engine_stats["screen_rejections"] == 0
for a, b in zip(jax.tree_util.tree_leaves(p_off),
                jax.tree_util.tree_leaves(p_on)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("mesh-screen-noop-ok")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "mesh-screen-noop-ok" in out.stdout


# ---------------------------------------------------------------------------
# one-program invariant: screening/corruption never recompile the step
# ---------------------------------------------------------------------------

def test_in_step_rejection_costs_zero_new_builds(micro_cfg):
    """Warm the clean program, then run the corruption drill: rejections
    fire inside the SAME compiled step (corrupt scales are a runtime
    (K,) argument, thresholds compare on the host) — ``step_builds``
    delta 0, the tentpole acceptance criterion."""
    sess = Session()
    base = ExperimentSpec(
        testbed=micro_cfg, strategy=StrategySpec("fedasync", alpha=0.4),
        run=RunBudget(max_updates=10, eval_every=5))
    sess.run(base)                                  # clean warm-up build
    n0 = step_builds()
    _, log = sess.run(replace(
        base, testbed=replace(micro_cfg, faults=CORRUPT, screening=SCREEN)))
    assert step_builds() == n0
    assert log.engine_stats["screen_rejections"] > 0


def test_sweep_strategy_sigma_corruption_shares_one_program(micro_cfg):
    """The (strategy x sigma x corruption) grid runs warm under
    ``compile_guard`` with a budget of ONE build: neither axis reaches
    the compiled program."""
    sess = Session()
    spec = ExperimentSpec(
        testbed=replace(micro_cfg, screening=SCREEN),
        strategy=StrategySpec("fedasync", alpha=0.4),
        run=RunBudget(max_updates=4, eval_every=4))
    res = sess.sweep(spec, axes={
        "strategy": [StrategySpec("fedasync", alpha=0.4),
                     StrategySpec("fedasync_normbound", alpha=0.4,
                                  norm_bound=5.0)],
        "testbed.sigma": [0.5, 1.0],
        "testbed.faults": [None, CORRUPT],
    })
    assert len(res.logs) == 8
    assert sess.events["sweep_step_builds"] <= 1    # guard budget was 1
    for point, log in zip(res.points, res.logs):
        rej = log.engine_stats["screen_rejections"]
        if point["testbed.faults"] is None:
            assert rej == 0
        else:
            assert rej > 0


# ---------------------------------------------------------------------------
# quarantine across a checkpoint/resume boundary
# ---------------------------------------------------------------------------

QUAR_SPEC = ExperimentSpec(
    testbed=TestbedConfig(
        num_clients=4, data=SERDataConfig(n_total=160), batch_size=32,
        sigma=0.5, faults=FaultModel(seed=7, corrupt_prob=0.5),
        screening=ScreeningConfig(max_update_norm=1e3, quarantine_after=1,
                                  readmit_delay_s=60.0)),
    strategy=StrategySpec("fedasync", alpha=0.6),
    run=RunBudget(max_updates=18, eval_every=6))


def test_quarantine_survives_checkpoint_resume(tmp_path):
    plain = Session().run(QUAR_SPEC)
    kinds = [e[0] for e in plain[1].fault_events]
    assert "quarantine" in kinds                    # the drill quarantines
    ckdir = str(tmp_path)
    with pytest.raises(SimulatedCrash):
        Session().run(QUAR_SPEC, checkpoint_every=5, checkpoint_dir=ckdir,
                      crash_after_saves=2)
    assert latest_step(ckdir) is not None
    resumed = Session().run(QUAR_SPEC, checkpoint_every=5,
                            checkpoint_dir=ckdir, resume_from=ckdir)
    assert _logdict(plain[1]) == _logdict(resumed[1])
    for a, b in zip(jax.tree_util.tree_leaves(plain[0]),
                    jax.tree_util.tree_leaves(resumed[0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_refuses_screening_mismatch(tmp_path):
    """The resuming spec must carry the same screening-or-not as the
    checkpointed run — silently dropping quarantine state would fork
    the replay."""
    ckdir = str(tmp_path)
    with pytest.raises(SimulatedCrash):
        Session().run(QUAR_SPEC, checkpoint_every=5, checkpoint_dir=ckdir,
                      crash_after_saves=1)
    stripped = replace(QUAR_SPEC,
                       testbed=replace(QUAR_SPEC.testbed, screening=None))
    with pytest.raises(ValueError, match="[Ss]creening"):
        Session().run(stripped, resume_from=ckdir)
