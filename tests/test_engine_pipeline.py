"""Pipelined cohort scheduler: pipeline_depth >= 2 must reproduce the
serial engine (and the legacy loop) bit-for-bit in RunLog bookkeeping and
params-allclose, while performing ZERO device->host transfers between
eval boundaries (the sync-count test monkeypatches the engine's
_host_fetch funnel to prove every fetch happens inside an eval
boundary), plus unit tests for the scheduler plumbing (EngineConfig
validation, donation-off compiled steps, deterministic pop_cohort
tie-breaking that lookahead planning relies on)."""
import heapq
import random

import jax
import numpy as np
import pytest

import repro.engine.engine as engine_mod
from repro.core.testbed import build_testbed, run_experiment
from repro.engine import EngineConfig
from repro.engine.cohort import pop_cohort


def _assert_params_close(a, b, rtol=1e-4, atol=1e-5):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


def _assert_logs_match(log_a, log_b):
    assert log_a.update_counts == log_b.update_counts
    assert log_a.eps_trajectory == log_b.eps_trajectory
    assert log_a.staleness == log_b.staleness
    assert log_a.times == log_b.times
    assert log_a.cohort_sizes == log_b.cohort_sizes
    np.testing.assert_allclose(log_a.global_acc, log_b.global_acc,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# end-to-end parity: pipelined vs serial vs legacy (the tentpole criterion)
# ---------------------------------------------------------------------------

def test_pipelined_async_matches_serial_and_legacy(micro_cfg):
    kw = dict(max_updates=12, eval_every=4, alpha=0.4)
    p_leg, log_leg = run_experiment("fedasync", micro_cfg, engine="legacy",
                                    **kw)
    p_ser, log_ser = run_experiment("fedasync", micro_cfg, engine="cohort",
                                    engine_cfg=EngineConfig(), **kw)
    p_pipe, log_pipe = run_experiment(
        "fedasync", micro_cfg, engine="cohort",
        engine_cfg=EngineConfig(pipeline_depth=3), **kw)
    _assert_params_close(p_ser, p_pipe)
    _assert_params_close(p_leg, p_pipe)
    _assert_logs_match(log_ser, log_pipe)
    assert log_leg.update_counts == log_pipe.update_counts
    assert log_leg.eps_trajectory == log_pipe.eps_trajectory
    assert log_leg.staleness == log_pipe.staleness
    assert log_pipe.engine_stats["pipeline_depth"] == 3
    assert log_ser.engine_stats["pipeline_depth"] == 1


def test_pipelined_fedavg_matches_serial(micro_cfg):
    kw = dict(rounds=3, eval_every=2)
    p_ser, log_ser = run_experiment("fedavg", micro_cfg, engine="cohort",
                                    engine_cfg=EngineConfig(), **kw)
    p_pipe, log_pipe = run_experiment(
        "fedavg", micro_cfg, engine="cohort",
        engine_cfg=EngineConfig(pipeline_depth=2), **kw)
    _assert_params_close(p_ser, p_pipe)
    _assert_logs_match(log_ser, log_pipe)


def test_pipelined_windowed_cohorts_match_serial(micro_cfg):
    """Multi-member cohorts (the pipelining target) through both drivers:
    identical merge results and bookkeeping."""
    ec_kw = dict(staleness_window=1e9, max_cohort=2)
    kw = dict(max_updates=8, eval_every=4, alpha=0.4, engine="cohort")
    p_ser, log_ser = run_experiment("fedasync", micro_cfg,
                                    engine_cfg=EngineConfig(**ec_kw), **kw)
    p_pipe, log_pipe = run_experiment(
        "fedasync", micro_cfg,
        engine_cfg=EngineConfig(pipeline_depth=2, **ec_kw), **kw)
    _assert_params_close(p_ser, p_pipe)
    _assert_logs_match(log_ser, log_pipe)
    assert max(log_pipe.cohort_sizes) == 2  # the window actually batched


# ---------------------------------------------------------------------------
# sync-count: zero device->host transfers between eval boundaries
# ---------------------------------------------------------------------------

def test_pipelined_zero_host_syncs_between_evals(micro_cfg, monkeypatch):
    """Every device->host fetch in the engine loops goes through the
    _host_fetch funnel; monkeypatch-count it and assert the pipelined
    path only ever fetches INSIDE an eval boundary — while producing the
    exact RunLog the serial path does."""
    fetches = []
    real_fetch = engine_mod._host_fetch

    def counting_fetch(runner, value):
        fetches.append(bool(runner._in_eval))
        return real_fetch(runner, value)

    kw = dict(max_updates=12, eval_every=4, alpha=0.4, engine="cohort")
    p_ser, log_ser = run_experiment("fedasync", micro_cfg,
                                    engine_cfg=EngineConfig(), **kw)
    monkeypatch.setattr(engine_mod, "_host_fetch", counting_fetch)
    p_pipe, log_pipe = run_experiment(
        "fedasync", micro_cfg,
        engine_cfg=EngineConfig(pipeline_depth=2), **kw)
    monkeypatch.undo()

    assert fetches, "the eval boundary must fetch through the funnel"
    assert all(fetches), (
        "a device->host fetch happened OUTSIDE an eval boundary")
    stats = log_pipe.engine_stats
    assert stats["host_syncs_between_evals"] == 0
    assert stats["blocking_submits"] == 0          # no donation syncs
    assert stats["host_syncs_at_eval"] == len(fetches)
    # serial path: every submit is a donation-chained host sync — the
    # per-cohort between-evals count the pipelined path drops to 0
    assert log_ser.engine_stats["blocking_submits"] == \
        log_ser.engine_stats["cohorts"]
    assert log_ser.engine_stats["host_syncs_between_evals"] == \
        log_ser.engine_stats["cohorts"]
    _assert_params_close(p_ser, p_pipe)
    _assert_logs_match(log_ser, log_pipe)


def test_pipelined_run_keeps_callers_params_readable(micro_cfg):
    """Pipelined runners never donate the globals, so the caller's initial
    params must stay readable without the serial path's defensive copy."""
    from repro.core.aggregation import FedAsync
    from repro.engine import CohortRunner, run_async_engine

    clients, params, acc_fn, test = build_testbed(micro_cfg)
    runner = CohortRunner(clients, EngineConfig(pipeline_depth=2))
    assert runner.pipelined and not runner.donates_globals
    clients, params, acc_fn, test = build_testbed(micro_cfg)
    run_async_engine(clients, params, acc_fn, test, FedAsync(alpha=0.4),
                     max_updates=4, eval_every=4, seed=micro_cfg.seed,
                     engine_cfg=EngineConfig(pipeline_depth=2))
    for leaf in jax.tree_util.tree_leaves(params):
        assert np.isfinite(np.asarray(leaf)).all()  # still alive


# ---------------------------------------------------------------------------
# scheduler plumbing
# ---------------------------------------------------------------------------

def test_pipeline_depth_validated():
    assert EngineConfig().pipeline_depth == 1
    EngineConfig(pipeline_depth=2)
    for bad in (0, -1, 1.5):
        with pytest.raises(ValueError, match="pipeline_depth"):
            EngineConfig(pipeline_depth=bad)


def test_stage_then_submit_equals_run_cohort(micro_cfg):
    """The split halves compose to exactly the old run_cohort (the serial
    driver still calls them fused)."""
    from repro.engine import CohortRunner

    clients, params, _, _ = build_testbed(micro_cfg)
    runner = CohortRunner(clients, EngineConfig())
    key = jax.random.PRNGKey(0)
    plans = []
    for c in clients[:2]:
        key, sub = jax.random.split(key)
        plans.append(runner.dispatch(c, params, sub, 0))
    staged = runner.stage_cohort(plans)
    assert staged.k == 2
    out = runner.submit_cohort(staged)
    assert jax.tree_util.tree_leaves(out)[0].shape[0] >= 2


def test_pop_cohort_tie_break_deterministic():
    """Equal completion times pop in ascending cid REGARDLESS of push
    order — pipelined lookahead replans the same cohorts every run."""
    for seed in range(6):
        entries = [(5.0, cid) for cid in range(8)] + [(9.0, 99)]
        random.Random(seed).shuffle(entries)
        heap = []
        for e in entries:
            heapq.heappush(heap, e)
        events = pop_cohort(heap, window=0.0, max_size=8)
        assert events == [(5.0, cid) for cid in range(8)]
        assert heap == [(9.0, 99)]
    # ties interleaved with distinct times keep global (time, cid) order
    heap = [(2.0, 3), (1.0, 7), (1.0, 2), (2.0, 1), (1.0, 5)]
    heapq.heapify(heap)
    events = pop_cohort(heap, window=1.0, max_size=8)
    assert events == [(1.0, 2), (1.0, 5), (1.0, 7), (2.0, 1), (2.0, 3)]


def test_merge_coeffs_built_at_merge_dtype():
    """_pad_coeffs builds float32 directly (no float64 round-trip through
    jnp.asarray's silent downcast) and zero-fills the padded tail."""
    import jax.numpy as jnp

    stacked = {"w": jnp.zeros((4, 3))}
    out = engine_mod._pad_coeffs(np.array([0.5, 0.25], np.float64), stacked)
    assert out.dtype == jnp.float32
    assert out.shape == (4,)
    np.testing.assert_allclose(np.asarray(out), [0.5, 0.25, 0.0, 0.0])
