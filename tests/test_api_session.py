"""Session execution: shim parity (the legacy frontends must be
bit-identical to Session.run), warm-session determinism (client reset),
and cache-reusing sweeps (partitions generated once, compiled steps
shared across a sigma-only grid)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.api import (
    ExperimentSpec, RunBudget, Session, StrategySpec)
from repro.core.testbed import TestbedConfig, build_testbed, run_experiment
from repro.data.synthetic_ser import SERDataConfig
from repro.engine import EngineConfig
from repro.models.ser_cnn import SERConfig


def _assert_bit_identical(p_a, log_a, p_b, log_b):
    la, lb = jax.tree_util.tree_leaves(p_a), jax.tree_util.tree_leaves(p_b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert (np.asarray(x) == np.asarray(y)).all()
    for fld in ("strategy", "times", "global_acc", "server_version",
                "update_counts", "influence", "staleness", "eps_trajectory",
                "local_acc", "cohort_sizes"):
        assert getattr(log_a, fld) == getattr(log_b, fld), fld


@pytest.fixture(scope="module")
def sweep_cfg():
    """Tiny-dims testbed unique to this module so compile-count assertions
    see a cold step cache for this config."""
    dims = dict(time_frames=12, n_mels=12)
    return TestbedConfig(
        use_dp=True, sigma=0.5, batch_size=16, num_clients=4,
        data=SERDataConfig(n_total=144, **dims),
        model=SERConfig(channels1=8, channels2=16, fc_dim=16, **dims),
        seed=7)


# ---------------------------------------------------------------------------
# shim parity (acceptance criterion): legacy signatures == Session.run
# ---------------------------------------------------------------------------

def test_shim_parity_fedavg(micro_cfg):
    p_shim, log_shim = run_experiment("fedavg", micro_cfg, rounds=2)
    p_api, log_api = Session().run(ExperimentSpec(
        testbed=micro_cfg, strategy=StrategySpec("fedavg"),
        run=RunBudget(rounds=2)))
    _assert_bit_identical(p_shim, log_shim, p_api, log_api)


def test_shim_parity_fedasync_window0(micro_cfg):
    p_shim, log_shim = run_experiment("fedasync", micro_cfg, max_updates=8,
                                      eval_every=4, alpha=0.4)
    p_api, log_api = Session().run(ExperimentSpec(
        testbed=micro_cfg,
        strategy=StrategySpec("fedasync", alpha=0.4, staleness_aware=True),
        run=RunBudget(max_updates=8, eval_every=4)))
    _assert_bit_identical(p_shim, log_shim, p_api, log_api)


def test_shim_parity_fedasync_windowed(micro_cfg):
    ec = EngineConfig(staleness_window=1e9, max_cohort=2)
    p_shim, log_shim = run_experiment("fedasync", micro_cfg, max_updates=8,
                                      eval_every=4, alpha=0.4,
                                      engine_cfg=ec)
    p_api, log_api = Session().run(ExperimentSpec(
        testbed=micro_cfg,
        strategy=StrategySpec("fedasync", alpha=0.4, staleness_aware=True),
        run=RunBudget(max_updates=8, eval_every=4), engine=ec))
    _assert_bit_identical(p_shim, log_shim, p_api, log_api)
    assert max(log_api.cohort_sizes) == 2        # the window actually batched


def test_shim_parity_legacy_backend(micro_cfg):
    p_shim, log_shim = run_experiment("fedasync", micro_cfg, max_updates=6,
                                      eval_every=3, alpha=0.4,
                                      engine="legacy")
    p_api, log_api = Session().run(ExperimentSpec(
        testbed=micro_cfg,
        strategy=StrategySpec("fedasync", alpha=0.4, staleness_aware=True),
        run=RunBudget(max_updates=6, eval_every=3), backend="legacy"))
    _assert_bit_identical(p_shim, log_shim, p_api, log_api)


def test_sigma_zero_clipping_only_parity(micro_cfg):
    """use_dp=True with sigma=0 (clip, no noise) selects the statically
    noise-free program variant — it must still match the legacy loop
    exactly (a traced zero scale would have perturbed -0.0 bits and
    burned RNG for nothing)."""
    cfg = dataclasses.replace(micro_cfg, sigma=0.0)
    kw = dict(max_updates=6, eval_every=3, alpha=0.4)
    p_eng, log_eng = run_experiment("fedasync", cfg, **kw)
    p_leg, log_leg = run_experiment("fedasync", cfg, engine="legacy", **kw)
    for x, y in zip(jax.tree_util.tree_leaves(p_eng),
                    jax.tree_util.tree_leaves(p_leg)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-4, atol=1e-5)
    assert log_eng.eps_trajectory == log_leg.eps_trajectory
    assert log_eng.update_counts == log_leg.update_counts


def test_legacy_backend_rejects_mesh(micro_cfg):
    from repro.launch.mesh import make_host_mesh
    spec = ExperimentSpec(testbed=micro_cfg, backend="legacy",
                          engine=EngineConfig(mesh=make_host_mesh(data=1)))
    with pytest.raises(ValueError, match="cohort"):
        Session().run(spec)


# ---------------------------------------------------------------------------
# warm-session determinism: reuse must not leak state between runs
# ---------------------------------------------------------------------------

def test_warm_rerun_is_bit_identical(micro_cfg):
    """Second run of the same spec in one session: clients reset to their
    construction-time RNG/clock/accountant chains, the runner's state
    arenas re-init — the RunLog and params must be bit-identical."""
    spec = ExperimentSpec(
        testbed=micro_cfg,
        strategy=StrategySpec("fedasync", alpha=0.4, staleness_aware=True),
        run=RunBudget(max_updates=8, eval_every=4))
    s = Session()
    p1, log1 = s.run(spec)
    p2, log2 = s.run(spec)
    _assert_bit_identical(p1, log1, p2, log2)
    st = s.stats()
    assert st["testbed_builds"] == 1 and st["testbed_reuses"] == 1
    assert st["runner_builds"] == 1 and st["runner_reuses"] == 1


def test_warm_strategy_switch_matches_fresh(micro_cfg):
    """Strategy-only change reuses testbed AND runner; result must match
    a fresh session's."""
    s = Session()
    base = ExperimentSpec(
        testbed=micro_cfg, strategy=StrategySpec("fedasync", alpha=0.4,
                                                 staleness_aware=True),
        run=RunBudget(max_updates=6, eval_every=3))
    s.run(base)
    spec_b = dataclasses.replace(
        base, strategy=StrategySpec("fedbuff", alpha=0.4, buffer_size=2))
    p_warm, log_warm = s.run(spec_b)
    p_fresh, log_fresh = Session().run(spec_b)
    _assert_bit_identical(p_warm, log_warm, p_fresh, log_fresh)
    assert s.stats()["runner_reuses"] == 1


# ---------------------------------------------------------------------------
# cache-reusing sweeps (the tentpole win)
# ---------------------------------------------------------------------------

def test_sigma_sweep_keeps_step_cache_warm(sweep_cfg, monkeypatch):
    """A sigma-only sweep must NOT invalidate/re-trace the compiled step:
    the noise scale is a runtime argument, so the 4-point grid shares one
    program (monkeypatch-counted make_cohort_step builds), datasets are
    generated once, and every per-scenario RunLog matches a fresh
    session's."""
    from repro.engine import cohort_step

    builds = []
    real = cohort_step.make_cohort_step

    def counting(*a, **kw):
        builds.append((kw.get("client_axis"), kw.get("arena")))
        return real(*a, **kw)

    monkeypatch.setattr(cohort_step, "make_cohort_step", counting)

    sigmas = [0.5, 1.0, 1.5, 2.0]
    spec = ExperimentSpec(
        testbed=sweep_cfg,
        strategy=StrategySpec("fedasync", alpha=0.4, staleness_aware=True),
        run=RunBudget(max_updates=4, eval_every=2))
    s = Session()
    result = s.sweep(spec, axes={"testbed.sigma": sigmas})
    assert len(result) == 4
    assert len(builds) <= 1                      # ONE program for the grid
    assert s.stats()["partition_builds"] == 1    # dataset generated once
    n_after_first = len(builds)
    s.sweep(spec, axes={"testbed.sigma": sigmas})
    assert len(builds) == n_after_first          # repeat sweep: zero builds

    for sg, log in zip(sigmas, result.logs):
        _, fresh = Session().run(
            dataclasses.replace(
                spec, testbed=dataclasses.replace(sweep_cfg, sigma=sg)))
        assert fresh.global_acc == log.global_acc
        assert fresh.eps_trajectory == log.eps_trajectory
        assert fresh.update_counts == log.update_counts


def test_sweep_table_and_points(sweep_cfg):
    spec = ExperimentSpec(
        testbed=sweep_cfg,
        strategy=StrategySpec("fedasync", alpha=0.4, staleness_aware=True),
        run=RunBudget(rounds=1, max_updates=4, eval_every=2))
    res = Session().sweep(spec, axes={
        "strategy": [StrategySpec("fedavg"),
                     StrategySpec("fedasync", alpha=0.4)],
        "testbed.sigma": [0.5, 2.0],
    })
    assert len(res) == 4
    # last axis fastest: fedavg s0.5, fedavg s2, fedasync s0.5, fedasync s2
    # — and the axis column keeps the FULL label (params included), so
    # two points of the same strategy name stay distinguishable
    assert [r["strategy"] for r in res.table()] == [
        "fedavg", "fedavg", "fedasync(alpha=0.4)", "fedasync(alpha=0.4)"]
    assert [r["sigma"] for r in res.table()] == [0.5, 2.0, 0.5, 2.0]
    assert [r["testbed.sigma"] for r in res.table()] == [0.5, 2.0, 0.5, 2.0]
    for row in res.table():
        for key in ("final_acc", "max_eps", "jain_participation",
                    "privacy_disparity", "wall_s", "updates"):
            assert key in row
        assert row["final_acc"] is not None


def test_sweep_validates_axes(sweep_cfg):
    spec = ExperimentSpec(testbed=sweep_cfg)
    s = Session()
    with pytest.raises(ValueError, match="at least one axis"):
        s.sweep(spec, axes={})
    with pytest.raises(ValueError, match="no values"):
        s.sweep(spec, axes={"testbed.sigma": []})
    with pytest.raises(ValueError, match="no field"):
        s.sweep(spec, axes={"testbed.sigmo": [1.0]})
    assert s.stats().get("runs", 0) == 0         # fail fast, nothing ran


# ---------------------------------------------------------------------------
# server-level shims: eval cadence normalized there too
# ---------------------------------------------------------------------------

def test_run_fedavg_run_async_normalize_eval_every(micro_cfg):
    from repro.core.server import run_async, run_fedavg

    clients, params, acc_fn, pooled = build_testbed(micro_cfg)
    _, log = run_fedavg(clients, params, acc_fn, pooled, rounds=1,
                        eval_every=0)
    assert log.global_acc
    for c in clients:
        c.reset()
    _, log = run_async(clients, params, acc_fn, pooled,
                       StrategySpec("fedasync", alpha=0.4).make(),
                       max_updates=4, eval_every=0)
    assert log.global_acc
