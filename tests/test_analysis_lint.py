"""The REP lint rules: every seeded-violation fixture fires its rule,
every clean twin passes, suppression requires a justification, and —
the CI gate itself — ``src/`` lints clean."""
import os

import pytest

from repro.analysis import lint

HERE = os.path.dirname(os.path.abspath(__file__))
FIXTURES = os.path.join(HERE, "analysis_fixtures")
SRC = os.path.join(os.path.dirname(HERE), "src")


def _fixture(name):
    return os.path.join(FIXTURES, name)


def _codes(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------------------------------
# seeded violations fire; clean twins pass
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("code,expected", [
    ("rep001", ["REP001"]),
    ("rep002", ["REP002"]),
    ("rep003", ["REP003"]),
    ("rep004", ["REP004"]),
    ("rep005", ["REP005", "REP005", "REP005"]),
    ("rep006", ["REP006", "REP006", "REP006"]),
])
def test_seeded_violation_fires(code, expected):
    findings = lint.run([_fixture(f"{code}_bad.py")])
    assert _codes(findings) == expected, [f.format() for f in findings]
    # findings carry the fixture path and a real line number
    for f in findings:
        assert f.path.endswith(f"{code}_bad.py") and f.line > 0


@pytest.mark.parametrize(
    "code", ["rep001", "rep002", "rep003", "rep004", "rep005", "rep006"])
def test_clean_twin_passes(code):
    findings = lint.run([_fixture(f"{code}_clean.py")])
    assert findings == [], [f.format() for f in findings]


def test_bare_suppression_is_rep000_and_does_not_suppress():
    findings = lint.run([_fixture("rep000_bad.py")])
    assert _codes(findings) == ["REP000", "REP003"], [
        f.format() for f in findings]


def test_justified_suppression_silences_the_rule():
    # rep003_clean.py contains a REAL violation on its last function,
    # suppressed with `# rep-noqa: REP003 -- ...`; clean-twin test above
    # already asserts zero findings — here pin that the line WOULD flag
    # without the comment (the suppression is doing work, the rule isn't
    # just blind there)
    path = _fixture("rep003_clean.py")
    with open(path, encoding="utf-8") as fh:
        text = fh.read()
    assert "rep-noqa: REP003 --" in text
    stripped = text[:text.index("  # rep-noqa")] + "\n"
    import ast
    f = lint.SourceFile(path, stripped)
    ast.parse(stripped)
    from repro.analysis.rules import RULES
    ctx = lint.ProjectContext([f])
    assert _codes(RULES["REP003"].check(f, ctx)) == ["REP003"]


# ---------------------------------------------------------------------------
# the CI gate: the repo's own source lints clean
# ---------------------------------------------------------------------------

def test_src_lints_clean():
    findings = lint.run([SRC])
    assert findings == [], "\n".join(f.format() for f in findings)


# ---------------------------------------------------------------------------
# engine/CLI behavior
# ---------------------------------------------------------------------------

def test_select_restricts_rules():
    findings = lint.run([_fixture("rep005_bad.py")], select=["REP001"])
    assert findings == []


def test_main_exit_codes(capsys):
    assert lint.main([_fixture("rep001_bad.py")]) == 1
    out = capsys.readouterr().out
    assert "REP001" in out and "rep001_bad.py" in out
    assert lint.main([_fixture("rep001_clean.py")]) == 0
    assert lint.main(["/nonexistent/thing"]) == 2


def test_project_context_registries():
    files = []
    for name in ("rep002_bad.py", "rep004_bad.py"):
        path = _fixture(name)
        with open(path, encoding="utf-8") as fh:
            files.append(lint.SourceFile(path, fh.read()))
    ctx = lint.ProjectContext(files)
    assert {"InnerConfig", "OuterSpec"} <= set(ctx.dataclasses)
    assert ctx.spec_registries[0].names == ["OuterSpec"]
    assert ctx.donators["step"].positions == (0,)


def test_conditional_donation_resolves():
    # the engine's `jit_kw = {...} if flag else {}` and inline
    # `**({"donate_argnums": ...} if ... else {})` idioms both register
    path = _fixture("rep004_clean.py")
    with open(path, encoding="utf-8") as fh:
        ctx = lint.ProjectContext([lint.SourceFile(path, fh.read())])
    assert ctx.donators["write"].positions == (0,)
