"""ExperimentSpec/StrategySpec/RunBudget: construction-time validation,
dict/JSON round-trips, the legacy-signature bridge, and the workload
registry."""
import dataclasses
import json

import pytest

from repro.api import (
    ExperimentSpec, RunBudget, StrategySpec, get_workload, register_workload,
    replace_path, workload_names)
from repro.api.spec import decode, encode
from repro.core.dp import DPConfig
from repro.core.fl_step import FLStepConfig
from repro.core.testbed import TestbedConfig, build_testbed, run_experiment
from repro.data.synthetic_ser import SERDataConfig
from repro.engine import EngineConfig
from repro.models.ser_cnn import SERConfig


# ---------------------------------------------------------------------------
# StrategySpec: registry validation at construction (satellite bugfix —
# bad names/params used to surface deep inside make_strategy mid-run)
# ---------------------------------------------------------------------------

def test_strategy_spec_rejects_unknown_name_listing_options():
    with pytest.raises(ValueError, match="fedasync.*fedavg|fedavg.*fedasync"):
        StrategySpec("fedsync")


def test_strategy_spec_rejects_unknown_param_listing_valid():
    with pytest.raises(ValueError, match="alpha"):
        StrategySpec("fedasync", aplha=0.4)          # the classic typo
    with pytest.raises(ValueError, match="buffer_size"):
        StrategySpec("fedbuff", window=3)


def test_strategy_spec_fedavg_takes_no_params():
    with pytest.raises(ValueError, match="none"):
        StrategySpec("fedavg", alpha=0.4)


def test_strategy_spec_nostale_pins_staleness():
    # the variant exists to pin staleness_aware=False; offering the knob
    # anyway would silently contradict the name
    with pytest.raises(ValueError, match="staleness_aware"):
        StrategySpec("fedasync_nostale", staleness_aware=True)
    strat = StrategySpec("fedasync_nostale", alpha=0.3).make()
    assert strat.staleness_aware is False and strat.alpha == 0.3


def test_strategy_spec_value_semantics():
    a = StrategySpec("fedasync", alpha=0.4, staleness_aware=True)
    b = StrategySpec("fedasync", staleness_aware=True, alpha=0.4)
    assert a == b and hash(a) == hash(b)             # canonical param order
    assert a.replace(alpha=0.2) == StrategySpec(
        "fedasync", alpha=0.2, staleness_aware=True)
    made = a.make()
    assert made.alpha == 0.4 and made.staleness_aware is True


def test_run_experiment_shim_validates_strategy_kwargs_up_front():
    # never reaches the testbed build — no training cost
    with pytest.raises(ValueError, match="eps_target"):
        run_experiment("fedasync", eps_target=8.0)
    with pytest.raises(ValueError, match="unknown aggregation strategy"):
        run_experiment("fedsync")


# ---------------------------------------------------------------------------
# RunBudget: the one eval-cadence validation point (satellite bugfix —
# eval_every=0 used to die on `rnd % 0` in the fedavg loop only)
# ---------------------------------------------------------------------------

def test_run_budget_normalizes_eval_every():
    assert RunBudget(eval_every=0).eval_every == 1
    assert RunBudget(eval_every=-3).eval_every == 1
    assert RunBudget(eval_every=7).eval_every == 7


def test_run_budget_rejects_negative_budgets():
    with pytest.raises(ValueError, match="rounds"):
        RunBudget(rounds=-1)


def test_eval_every_zero_fedavg_regression(micro_cfg):
    """eval_every=0 on the FEDAVG path: ZeroDivisionError before PR 5."""
    _, log = run_experiment("fedavg", micro_cfg, rounds=1, eval_every=0)
    assert log.global_acc                       # evaluated at round 1
    # the legacy engine path flows through the same normalization
    _, log = run_experiment("fedavg", micro_cfg, rounds=1, eval_every=0,
                            engine="legacy")
    assert log.global_acc


# ---------------------------------------------------------------------------
# serialization round-trip
# ---------------------------------------------------------------------------

def test_spec_roundtrip_default():
    spec = ExperimentSpec()
    d = spec.to_dict()
    json.dumps(d)                                # genuinely JSON-able
    assert ExperimentSpec.from_dict(d) == spec


def test_spec_roundtrip_nested_engine_and_dp():
    """The full nesting: custom testbed (data + model sub-configs),
    strategy params, run budget, and an EngineConfig carrying an
    FLStepConfig with its own DPConfig."""
    spec = ExperimentSpec(
        testbed=TestbedConfig(
            num_clients=7, batch_size=32, sigma=1.5, partition="dirichlet",
            dirichlet_alpha=0.3, seed=11,
            data=SERDataConfig(n_total=480, time_frames=32),
            model=SERConfig(channels1=8, fc_dim=32),
            workload="ser_cnn"),
        strategy=StrategySpec("adaptive_async", alpha=0.2, eps_target=4.0),
        run=RunBudget(rounds=3, max_updates=17, max_time=900.0,
                      eval_every=5, target_acc=0.6),
        engine=EngineConfig(
            staleness_window=45.0, max_cohort=4, pipeline_depth=2,
            client_axis="fl_step",
            fl_cfg=FLStepConfig(
                num_clients=4, n_micro=1,
                dp=DPConfig(clip_norm=1.0, noise_multiplier=1.5,
                            granularity="per_microbatch"))),
        backend="cohort")
    d = json.loads(json.dumps(spec.to_dict()))   # through real JSON
    back = ExperimentSpec.from_dict(d)
    assert back == spec
    assert back.engine.fl_cfg.dp == spec.engine.fl_cfg.dp
    assert back.strategy.kwargs == {"alpha": 0.2, "eps_target": 4.0}


def test_spec_roundtrip_mesh_by_axis_shape():
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(data=1)
    spec = ExperimentSpec(engine=EngineConfig(mesh=mesh))
    d = json.loads(json.dumps(spec.to_dict()))
    assert d["engine"]["mesh"] == {"__mesh__": {"data": 1, "model": 1}}
    assert ExperimentSpec.from_dict(d) == spec   # same process: same devices


def test_encode_rejects_unserializable():
    with pytest.raises(ValueError, match="cannot encode"):
        encode(object())
    with pytest.raises(ValueError, match="unknown spec type"):
        decode({"__type__": "NotASpec"})


def test_spec_backend_and_types_validated():
    with pytest.raises(ValueError, match="backend"):
        ExperimentSpec(backend="turbo")
    with pytest.raises(TypeError, match="strategy"):
        ExperimentSpec(strategy="fedasync")


def test_from_legacy_maps_the_old_signature():
    cfg = TestbedConfig(sigma=2.0)
    ec = EngineConfig(staleness_window=9.0)
    spec = ExperimentSpec.from_legacy(
        "fedasync", cfg, rounds=5, max_updates=42, alpha=0.6,
        staleness_aware=False, target_acc=0.7, eval_every=0,
        engine="legacy", engine_cfg=ec)
    assert spec.testbed == cfg and spec.backend == "legacy"
    assert spec.engine == ec
    assert spec.strategy == StrategySpec("fedasync", alpha=0.6,
                                         staleness_aware=False)
    assert spec.run == RunBudget(rounds=5, max_updates=42, eval_every=0,
                                 target_acc=0.7)
    assert spec.run.eval_every == 1
    # fedasync_nostale historical tolerance: staleness_aware dropped
    spec = ExperimentSpec.from_legacy("fedasync_nostale", cfg, alpha=0.3,
                                      staleness_aware=True)
    assert spec.strategy == StrategySpec("fedasync_nostale", alpha=0.3)


def test_replace_path():
    spec = ExperimentSpec()
    assert replace_path(spec, "testbed.sigma", 2.0).testbed.sigma == 2.0
    assert replace_path(spec, "testbed.data.n_total",
                        480).testbed.data.n_total == 480
    s2 = replace_path(spec, "strategy", StrategySpec("fedavg"))
    assert s2.strategy.name == "fedavg"
    assert spec.testbed.sigma == 1.0             # original untouched
    with pytest.raises(ValueError, match="no field"):
        replace_path(spec, "testbed.bogus", 1)


# ---------------------------------------------------------------------------
# workload registry
# ---------------------------------------------------------------------------

def test_workload_registry_lists_names_on_unknown():
    with pytest.raises(ValueError, match="ser_cnn"):
        get_workload("resnet50")
    assert {"ser_cnn", "ser_linear"} <= set(workload_names())


def test_workload_duplicate_registration_rejected():
    wl = get_workload("ser_cnn")
    with pytest.raises(ValueError, match="already registered"):
        register_workload("ser_cnn", init=wl.init, loss=wl.loss,
                          accuracy=wl.accuracy)


def test_workload_shared_closures_are_identity_stable():
    wl = get_workload("ser_cnn")
    cfg = SERConfig(channels1=8)
    assert wl.shared_loss(cfg) is wl.shared_loss(cfg)
    assert wl.shared_accuracy(cfg) is wl.shared_accuracy(cfg)


def test_unknown_workload_fails_at_build(micro_cfg):
    cfg = dataclasses.replace(micro_cfg, workload="nope")
    with pytest.raises(ValueError, match="unknown workload"):
        build_testbed(cfg)


def test_ser_linear_workload_backs_a_testbed(micro_cfg):
    """The registry decouples the testbed from ser_cnn: a different model
    family trains end to end through the same spec machinery."""
    cfg = dataclasses.replace(micro_cfg, workload="ser_linear")
    params, log = run_experiment("fedasync", cfg, max_updates=4,
                                 eval_every=2, alpha=0.4)
    assert set(params) == {"w", "b"}             # the linear model trained
    assert sum(log.update_counts.values()) == 4
    assert log.global_acc
