"""Attention-layer semantics: sliding window, GQA, chunking equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L
from repro.models.base import ArchConfig


def _cfg(**kw):
    base = dict(arch_id="t", family="dense", source="t", n_layers=1,
                d_model=64, n_heads=4, n_kv_heads=2, d_ff=64, vocab=16,
                param_dtype="float32")
    base.update(kw)
    return ArchConfig(**base)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    p = L.init_attention(key, cfg)
    x = jax.random.normal(key, (2, 96, 64), jnp.float32)
    return cfg, p, x


def test_window_ge_seq_equals_full(setup):
    cfg, p, x = setup
    full = L.attention(x, p, cfg, causal=True)
    windowed = L.attention(x, p, cfg, causal=True, window=4096)
    np.testing.assert_allclose(np.asarray(full), np.asarray(windowed),
                               rtol=1e-5, atol=1e-6)


def test_small_window_changes_output(setup):
    cfg, p, x = setup
    full = L.attention(x, p, cfg, causal=True)
    win = L.attention(x, p, cfg, causal=True, window=8)
    assert float(jnp.abs(full - win).max()) > 1e-3


def test_window_locality(setup):
    """With window w, output at position i must not depend on tokens
    older than i-w+1."""
    cfg, p, x = setup
    w = 16
    out = L.attention(x, p, cfg, causal=True, window=w)
    x2 = x.at[:, :40].set(jax.random.normal(jax.random.PRNGKey(9),
                                            (2, 40, 64)))
    out2 = L.attention(x2, p, cfg, causal=True, window=w)
    # positions >= 40 + w see none of the perturbed prefix
    tail = slice(40 + w, None)
    np.testing.assert_allclose(np.asarray(out[:, tail]),
                               np.asarray(out2[:, tail]),
                               rtol=1e-5, atol=1e-6)


def test_q_chunking_invariance(setup):
    cfg, p, x = setup
    a = L.attention(x, p, cfg, causal=True, q_chunk=1024)   # unchunked
    b = L.attention(x, p, cfg, causal=True, q_chunk=32)     # 3 chunks
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)


def test_gqa_equals_repeated_mha():
    """GQA (kv=2, H=4) must equal MHA with explicitly repeated kv heads."""
    cfg = _cfg()
    key = jax.random.PRNGKey(3)
    p = L.init_attention(key, cfg)
    x = jax.random.normal(key, (1, 32, 64), jnp.float32)
    out = L.attention(x, p, cfg, causal=True)

    cfg_mha = _cfg(n_kv_heads=4)
    p_mha = dict(p, wk=jnp.repeat(p["wk"], 2, axis=1),
                 wv=jnp.repeat(p["wv"], 2, axis=1))
    out_mha = L.attention(x, p_mha, cfg_mha, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_mha),
                               rtol=1e-5, atol=1e-6)


def test_softcap_bounds_logits_effect():
    """With a tiny softcap the distribution flattens toward uniform-value
    average; with cap -> inf it matches uncapped."""
    cfg_nc = _cfg()
    cfg_bigcap = _cfg(attn_logit_softcap=1e6)
    key = jax.random.PRNGKey(5)
    p = L.init_attention(key, cfg_nc)
    x = jax.random.normal(key, (1, 24, 64), jnp.float32)
    a = L.attention(x, p, cfg_nc, causal=True)
    b = L.attention(x, p, cfg_bigcap, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-5)
