"""Crash-resilient runs: the durable flat-npz store (atomic publish,
retention, escaped keys) and the engine checkpoint/resume path — an
aborted run resumed from its latest snapshot must reproduce the
uninterrupted run's RunLog bit-identically, fault sequence included."""
import os

import jax
import numpy as np
import pytest

from repro.api.session import Session
from repro.api.spec import ExperimentSpec, RunBudget, StrategySpec
from repro.checkpoint import latest_step, load_flat, restore, save
from repro.checkpoint.checkpoint import _step_files
from repro.core.faults import FaultModel
from repro.core.testbed import SERDataConfig, TestbedConfig
from repro.engine import CheckpointPolicy, SimulatedCrash

FAULTS = FaultModel(seed=7, failure_prob=0.1, upload_loss_prob=0.15,
                    max_retries=1, retry_backoff_s=4.0, duplicate_prob=0.15,
                    late_prob=0.1, leave_prob=0.1, rejoin_delay_s=40.0)
TB = TestbedConfig(num_clients=4, data=SERDataConfig(n_total=160),
                   batch_size=32, sigma=0.5, faults=FAULTS)
ASYNC_SPEC = ExperimentSpec(
    testbed=TB, strategy=StrategySpec("fedasync", alpha=0.6),
    run=RunBudget(max_updates=18, eval_every=6))
FEDAVG_SPEC = ExperimentSpec(
    testbed=TestbedConfig(
        num_clients=4, data=SERDataConfig(n_total=160), batch_size=32,
        sigma=0.5,
        faults=FaultModel(seed=7, failure_prob=0.12, upload_loss_prob=0.1,
                          max_retries=1, retry_backoff_s=4.0, leave_prob=0.1,
                          rejoin_delay_s=40.0, round_deadline_s=300.0,
                          min_quorum=2)),
    strategy=StrategySpec("fedavg"), run=RunBudget(rounds=10, eval_every=2))


def _logdict(log):
    """Every RunLog field the bit-identity contract covers (engine_stats
    carries no wall-time — it is exact across an abort)."""
    return dict(times=log.times, acc=log.global_acc,
                sv=log.server_version, uc=dict(log.update_counts),
                inf=log.influence, st=log.staleness,
                eps={k: list(v) for k, v in log.eps_trajectory.items()},
                fe=list(log.fault_events), es=dict(log.engine_stats),
                cs=list(log.cohort_sizes), dr=dict(log.dropouts))


def _assert_identical(run_a, run_b):
    (p_a, log_a), (p_b, log_b) = run_a, run_b
    a, b = _logdict(log_a), _logdict(log_b)
    assert a == b, [k for k in a if a[k] != b[k]]
    for x, y in zip(jax.tree_util.tree_leaves(p_a),
                    jax.tree_util.tree_leaves(p_b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.fixture(scope="module")
def async_plain():
    return Session().run(ASYNC_SPEC)


@pytest.fixture(scope="module")
def fedavg_plain():
    return Session().run(FEDAVG_SPEC)


# ---------------------------------------------------------------------------
# the durable store
# ---------------------------------------------------------------------------

def test_store_escaped_keys_cannot_collide(tmp_path):
    """{"a": {"b": x}} and {"a/b": y} used to flatten to the SAME npz key;
    the escaped keys keep both leaves (satellite regression)."""
    d = str(tmp_path)
    tree = {"a": {"b": np.full(3, 1.0, np.float32)},
            "a/b": np.full(3, 2.0, np.float32)}
    save(d, 0, tree)
    flat, _ = load_flat(d)
    assert sorted(flat) == ["a/b", "a\\/b"]
    got, _ = restore(d, {"a": {"b": np.zeros(3, np.float32)},
                         "a/b": np.zeros(3, np.float32)})
    np.testing.assert_array_equal(got["a"]["b"], tree["a"]["b"])
    np.testing.assert_array_equal(got["a/b"], tree["a/b"])


def test_store_keep_last_prunes_oldest(tmp_path):
    d = str(tmp_path)
    for step in (1, 2, 3, 4, 5):
        save(d, step, {"x": np.array([step])}, keep_last=3)
    assert _step_files(d) == [f"step_{s:08d}.npz" for s in (3, 4, 5)]
    assert latest_step(d) == 5
    with pytest.raises(ValueError, match="keep_last"):
        save(d, 6, {"x": np.zeros(1)}, keep_last=0)


def test_store_ignores_torn_tmp_files(tmp_path):
    """A crash mid-save leaves a .tmp sibling; readers never see it."""
    d = str(tmp_path)
    save(d, 2, {"x": np.arange(4)})
    with open(os.path.join(d, "step_00000009.npz.tmp"), "wb") as f:
        f.write(b"torn")
    assert latest_step(d) == 2
    assert _step_files(d) == ["step_00000002.npz"]


def test_store_meta_and_dtype_roundtrip(tmp_path):
    d = str(tmp_path)
    t = {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
         "n": np.array(7, dtype=np.int32)}
    save(d, 4, t, meta={"kind": "unit", "t_virtual": 0.1 + 0.2})
    flat, meta = load_flat(d)
    assert meta == {"step": 4, "kind": "unit", "t_virtual": 0.1 + 0.2}
    np.testing.assert_array_equal(flat["w"], t["w"])
    got, _ = restore(d, {"w": np.zeros((2, 3), np.float32),
                         "n": np.array(0, np.int32)})
    assert got["n"].dtype == np.int32 and int(got["n"]) == 7


def test_checkpoint_policy_validation_and_cadence(tmp_path):
    with pytest.raises(ValueError, match="every"):
        CheckpointPolicy(directory=str(tmp_path), every=0)
    with pytest.raises(ValueError, match="keep_last"):
        CheckpointPolicy(directory=str(tmp_path), keep_last=0)
    p = CheckpointPolicy(directory=str(tmp_path), every=5)
    assert not p.due(4) and p.due(5) and p.due(7)
    p.mark(7)                      # resumed at step 7: next snapshot at 10
    assert not p.due(9) and p.due(10)


# ---------------------------------------------------------------------------
# engine abort/resume (tentpole acceptance: bit-identical RunLog)
# ---------------------------------------------------------------------------

def _crash_then_resume(spec, ckdir, every, crash_after):
    with pytest.raises(SimulatedCrash):
        Session().run(spec, checkpoint_every=every, checkpoint_dir=ckdir,
                      crash_after_saves=crash_after)
    assert latest_step(ckdir) is not None
    return Session().run(spec, checkpoint_every=every, checkpoint_dir=ckdir,
                         resume_from=ckdir)


def test_checkpointed_uninterrupted_run_matches_plain(tmp_path, async_plain):
    """Snapshotting is observation-free: a run that checkpoints but never
    crashes equals the plain run bit-for-bit (the early write-flush the
    snapshot forces is a bitwise no-op)."""
    run = Session().run(ASYNC_SPEC, checkpoint_every=5,
                        checkpoint_dir=str(tmp_path))
    _assert_identical(async_plain, run)


def test_async_abort_resume_bit_identical(tmp_path, async_plain):
    resumed = _crash_then_resume(ASYNC_SPEC, str(tmp_path), every=5,
                                 crash_after=2)
    _assert_identical(async_plain, resumed)


def test_fedavg_abort_resume_bit_identical(tmp_path, fedavg_plain):
    resumed = _crash_then_resume(FEDAVG_SPEC, str(tmp_path), every=3,
                                 crash_after=2)
    _assert_identical(fedavg_plain, resumed)


def test_checkpoint_every_requires_directory():
    with pytest.raises(ValueError, match="checkpoint_dir"):
        Session().run(ASYNC_SPEC, checkpoint_every=5)


def test_legacy_backend_refuses_checkpoint(tmp_path):
    from dataclasses import replace
    spec = replace(ASYNC_SPEC, backend="legacy")
    with pytest.raises(ValueError, match="legacy"):
        Session().run(spec, checkpoint_every=5,
                      checkpoint_dir=str(tmp_path))
    with pytest.raises(ValueError, match="legacy"):
        Session().run(spec, resume_from=str(tmp_path))


def test_fedbuff_refuses_checkpoint(tmp_path):
    from dataclasses import replace
    spec = replace(ASYNC_SPEC,
                   strategy=StrategySpec("fedbuff", alpha=0.4,
                                         buffer_size=2))
    with pytest.raises(ValueError, match="FedBuff"):
        Session().run(spec, checkpoint_every=5,
                      checkpoint_dir=str(tmp_path))


def test_resume_refuses_kind_and_fault_mismatch(tmp_path):
    """A fedavg snapshot cannot seed an async loop, and the resuming spec
    must carry the same FaultModel-or-not as the checkpointed run."""
    from dataclasses import replace
    ckdir = str(tmp_path)
    with pytest.raises(SimulatedCrash):
        Session().run(FEDAVG_SPEC, checkpoint_every=3, checkpoint_dir=ckdir,
                      crash_after_saves=1)
    with pytest.raises(ValueError, match="kind"):
        Session().run(ASYNC_SPEC, resume_from=ckdir)
    no_faults = replace(FEDAVG_SPEC,
                        testbed=replace(FEDAVG_SPEC.testbed, faults=None))
    with pytest.raises(ValueError, match="[Ff]ault"):
        Session().run(no_faults, resume_from=ckdir)
