"""The HLO walker must reproduce known FLOP counts: matmuls with and
without scan wrappers (trip-count multiplication is the whole point)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.hlo_analysis import analyze


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 512), jnp.float32)
    txt = _compiled_text(lambda x, y: x @ y, a, b)
    res = analyze(txt)
    expected = 2 * 128 * 512 * 256
    assert res["dot_flops"] == pytest.approx(expected, rel=0.01), res


def test_scan_multiplies_flops():
    """A matmul inside a scan of length N must count N times."""
    N = 7
    w = jnp.zeros((N, 64, 64), jnp.float32)
    x = jnp.zeros((8, 64), jnp.float32)

    def fn(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        out, _ = jax.lax.scan(body, x, w)
        return out

    txt = _compiled_text(fn, x, w)
    res = analyze(txt)
    expected = N * 2 * 8 * 64 * 64
    assert res["dot_flops"] == pytest.approx(expected, rel=0.05), res


def test_nested_scan_multiplies():
    N, M = 3, 5
    w = jnp.zeros((N, M, 32, 32), jnp.float32)
    x = jnp.zeros((4, 32), jnp.float32)

    def fn(x, w):
        def outer(c, wo):
            def inner(c2, wi):
                return c2 @ wi, None
            c, _ = jax.lax.scan(inner, c, wo)
            return c, None
        out, _ = jax.lax.scan(outer, x, w)
        return out

    txt = _compiled_text(fn, x, w)
    res = analyze(txt)
    expected = N * M * 2 * 4 * 32 * 32
    assert res["dot_flops"] == pytest.approx(expected, rel=0.05), res
