"""Per-architecture smoke tests (assignment requirement): a REDUCED
variant of each assigned architecture family (<=2 layers, d_model<=512,
<=4 experts) runs one forward + one FL-DP train step on CPU; output shapes
and finiteness are asserted.  The FULL configs are exercised only by the
dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core.dp import DPConfig
from repro.core.fl_step import FLStepConfig, make_fl_train_step, make_server_optimizer
from repro.models.base import get_family

SEQ = 64
BATCH = 4


def _batch_for(cfg, key):
    toks = jax.random.randint(key, (BATCH, SEQ), 0, cfg.vocab)
    # next-token labels (tokens==labels would let tied-embedding models
    # trivially predict the current token through the residual stream)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (BATCH, cfg.enc_frames, cfg.d_model), cfg.pdtype)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (BATCH, cfg.n_patches, cfg.d_model), cfg.pdtype)
    return batch


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    arch_id = request.param
    cfg = get_config(arch_id).reduced().replace(
        param_dtype="float32", ssm_chunk=min(32, SEQ))
    fam = get_family(cfg.family)
    key = jax.random.PRNGKey(0)
    params = fam.init_params(key, cfg)
    batch = _batch_for(cfg, key)
    return arch_id, cfg, fam, params, batch


def test_forward_shapes_and_finite(arch_setup):
    arch_id, cfg, fam, params, batch = arch_setup
    logits = fam.forward(params, batch, cfg)
    assert logits.shape == (BATCH, SEQ, cfg.vocab), arch_id
    assert bool(jnp.isfinite(logits).all()), f"{arch_id}: non-finite logits"


def test_loss_scalar_reasonable(arch_setup):
    arch_id, cfg, fam, params, batch = arch_setup
    loss = fam.loss(params, batch, cfg)
    assert loss.shape == ()
    # random init => loss near ln(V) (aux losses may add a little)
    assert 0.5 * jnp.log(cfg.vocab) < loss < 3.0 * jnp.log(cfg.vocab), (
        f"{arch_id}: loss {loss} vs ln(V)={jnp.log(cfg.vocab):.2f}")


def test_fl_dp_train_step(arch_setup):
    """One federated round with per-microbatch DP on the reduced arch."""
    arch_id, cfg, fam, params, batch = arch_setup
    G = 2
    fl = FLStepConfig(
        num_clients=G, n_local=1, n_micro=2, local_lr=0.05,
        dp=DPConfig(clip_norm=1.0, noise_multiplier=0.5,
                    granularity="per_microbatch"),
        compute_dtype="float32",
    )
    step = make_fl_train_step(lambda p, b: fam.loss(p, b, cfg), fl)
    sopt = make_server_optimizer(fl)
    master = jax.tree_util.tree_map(lambda l: l.astype(jnp.float32), params)
    opt_state = sopt.init(master)
    weights = jnp.ones((G,)) / G
    new_master, _, metrics = step(master, opt_state, batch, weights,
                                  jax.random.PRNGKey(1))
    # params moved, finitely
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), master, new_master)
    assert max(jax.tree_util.tree_leaves(moved)) > 0, f"{arch_id}: no update"
    for l in jax.tree_util.tree_leaves(new_master):
        assert bool(jnp.isfinite(l).all()), f"{arch_id}: non-finite params"
    assert float(metrics["delta_norm"]) > 0


@pytest.mark.parametrize("arch_id", ["zamba2-1.2b", "xlstm-350m",
                                     "whisper-large-v3", "gemma2-2b"])
def test_bf16_forward_no_dtype_drift(arch_id):
    """bf16 params must flow through scans without f32 carry promotion
    (caught a real bug: SSD/mLSTM decay factors promoted the residual)."""
    cfg = get_config(arch_id).reduced().replace(ssm_chunk=32)  # bf16 default
    fam = get_family(cfg.family)
    key = jax.random.PRNGKey(0)
    params = fam.init_params(key, cfg)
    batch = _batch_for(cfg, key)
    loss = fam.loss(params, batch, cfg)
    assert bool(jnp.isfinite(loss))


def test_decode_step_shapes(arch_setup):
    arch_id, cfg, fam, params, batch = arch_setup
    B = BATCH
    cache = fam.init_cache(cfg, B, SEQ + 8)
    if cfg.family == "audio":
        # decode needs encoder KV: run prefill first
        _, cache = fam.prefill(params, batch, cfg, cache)
    token = batch["tokens"][:, :1]
    pos = jnp.zeros((B,), jnp.int32) + (SEQ if cfg.family == "audio" else 0)
    pos = jnp.minimum(pos, SEQ + 7)
    logits, new_cache = fam.decode_step(params, cache, token, pos, cfg)
    assert logits.shape == (B, cfg.vocab), arch_id
    assert bool(jnp.isfinite(logits).all()), arch_id
