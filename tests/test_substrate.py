"""Substrate unit tests: pytree utilities, token pipeline, data configs."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.pytree import (
    tree_flatten_to_vector, tree_gaussian_like, tree_global_norm, tree_lin,
    tree_size, tree_unflatten_from_vector,
)
from repro.data.tokens import TokenDataConfig, make_batches


def _tree(seed, scale=1.0):
    k = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(k)
    return {"a": jax.random.normal(k1, (3, 5)) * scale,
            "b": [jax.random.normal(k2, (7,)) * scale]}


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31), scale=st.floats(0.01, 100))
def test_flatten_roundtrip(seed, scale):
    t = _tree(seed, scale)
    vec = tree_flatten_to_vector(t)
    assert vec.shape == (tree_size(t),)
    back = tree_unflatten_from_vector(vec, t)
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_global_norm_matches_numpy():
    t = _tree(0, 2.0)
    flat = np.concatenate([np.asarray(l).ravel()
                           for l in jax.tree_util.tree_leaves(t)])
    np.testing.assert_allclose(float(tree_global_norm(t)),
                               np.linalg.norm(flat), rtol=1e-6)


def test_tree_lin_convexity():
    a, b = _tree(1), _tree(2)
    mid = tree_lin(a, b, 0.25, 0.75)
    ref = 0.25 * np.asarray(a["a"]) + 0.75 * np.asarray(b["a"])
    np.testing.assert_allclose(np.asarray(mid["a"]), ref, rtol=1e-6)


def test_gaussian_like_stddev():
    t = {"w": jnp.zeros((50_000,))}
    noise = tree_gaussian_like(jax.random.PRNGKey(0), t, stddev=0.5)
    s = float(jnp.std(noise["w"]))
    assert 0.45 < s < 0.55


def test_token_pipeline_deterministic_and_learnable():
    cfg = TokenDataConfig(vocab=1000, seq_len=32, seed=7)
    b1 = list(make_batches(cfg, 2, 4))
    b2 = list(make_batches(cfg, 2, 4))
    np.testing.assert_array_equal(b1[0]["tokens"], b2[0]["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1[0]["labels"][:, :-1],
                                  b1[0]["tokens"][:, 1:])
    # structure: the affine rule holds for most transitions (noise=0.15)
    t, l = b1[0]["tokens"], b1[0]["labels"]
    V = min(1000, 4096)
    hits = np.mean(l == (31 * t + 17) % V)
    assert hits > 0.7
