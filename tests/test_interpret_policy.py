"""Exhaustive provenance matrix for ``kernels.common.interpret_info``:
override beats env beats backend capability, every accepted env token
resolves, invalid tokens raise (listing the accepted ones), and the
override short-circuits even a malformed environment.  The benches and
``RunLog.engine_stats`` trust this dict's ``source`` field verbatim."""
import pytest

from repro.kernels import common


@pytest.fixture(autouse=True)
def _clean_policy(monkeypatch):
    """Each case starts with no override and no env var, on a fake CPU
    backend unless the test says otherwise."""
    monkeypatch.delenv(common._ENV_VAR, raising=False)
    monkeypatch.setattr(common, "_override", None)
    monkeypatch.setattr(common.jax, "default_backend", lambda: "cpu")
    yield


# ---------------------------------------------------------------------------
# source = auto: backend capability decides
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend,interpret", [
    ("cpu", True), ("METAL", True),            # unknown backends interpret
    ("tpu", False), ("gpu", False), ("cuda", False), ("rocm", False),
])
def test_backend_capability_matrix(monkeypatch, backend, interpret):
    monkeypatch.setattr(common.jax, "default_backend", lambda: backend)
    info = common.interpret_info()
    assert info == {"backend": backend, "interpret": interpret,
                    "source": "auto"}
    assert common.interpret_mode() is interpret


# ---------------------------------------------------------------------------
# source = env: every documented token, case/whitespace-insensitive
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("raw,expected", (
    [(t, True) for t in common._TRUE]
    + [(t, False) for t in common._FALSE]
    + [("  TRUE ", True), ("Off", False), ("YES", True), (" 0", False)]
))
def test_env_tokens(monkeypatch, raw, expected):
    monkeypatch.setenv(common._ENV_VAR, raw)
    info = common.interpret_info()
    assert info["interpret"] is expected
    assert info["source"] == "env"


@pytest.mark.parametrize("backend", ["cpu", "tpu"])
def test_env_beats_backend_both_ways(monkeypatch, backend):
    monkeypatch.setattr(common.jax, "default_backend", lambda: backend)
    monkeypatch.setenv(common._ENV_VAR, "1")
    assert common.interpret_info() == {
        "backend": backend, "interpret": True, "source": "env"}
    monkeypatch.setenv(common._ENV_VAR, "0")
    assert common.interpret_info() == {
        "backend": backend, "interpret": False, "source": "env"}


@pytest.mark.parametrize("raw", ["2", "maybe", "", "truthy", "None"])
def test_invalid_env_raises_listing_tokens(monkeypatch, raw):
    monkeypatch.setenv(common._ENV_VAR, raw)
    with pytest.raises(ValueError) as exc:
        common.interpret_info()
    msg = str(exc.value)
    assert common._ENV_VAR in msg and repr(raw) in msg
    for token in common._TRUE + common._FALSE:
        assert token in msg


# ---------------------------------------------------------------------------
# source = override: beats env (even a malformed one) and backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", [True, False])
@pytest.mark.parametrize("env", [None, "1", "0", "garbage"])
def test_override_beats_everything(monkeypatch, mode, env):
    if env is not None:
        monkeypatch.setenv(common._ENV_VAR, env)
    monkeypatch.setattr(common.jax, "default_backend", lambda: "tpu")
    common.set_interpret_override(mode)
    assert common.interpret_info() == {
        "backend": "tpu", "interpret": mode, "source": "override"}


def test_set_override_returns_previous():
    assert common.set_interpret_override(True) is None
    assert common.set_interpret_override(False) is True
    assert common.set_interpret_override(None) is False
    assert common.interpret_info()["source"] == "auto"
