"""DP-SGD primitives and aggregation strategies: unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.aggregation import AdaptiveAsync, FedAsync, FedAvg, FedBuff, make_strategy
from repro.core.dp import DPConfig, clip_tree, dp_mean_gradient, noise_tree
from repro.pytree import tree_global_norm, tree_lin, tree_sub


def _tree(key, scale=1.0):
    k1, k2 = jax.random.split(key)
    return {
        "a": jax.random.normal(k1, (4, 8)) * scale,
        "b": {"c": jax.random.normal(k2, (16,)) * scale},
    }


# ---------------------------------------------------------------------------
# clipping (Eq. 4)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(scale=st.floats(0.01, 50.0), clip=st.floats(0.1, 5.0), seed=st.integers(0, 2**31))
def test_clip_bounds_global_norm(scale, clip, seed):
    t = _tree(jax.random.PRNGKey(seed), scale)
    clipped, pre = clip_tree(t, clip)
    post = tree_global_norm(clipped)
    assert float(post) <= clip * (1 + 1e-4)
    # no-op when already within the ball
    if float(pre) <= clip:
        np.testing.assert_allclose(
            np.asarray(clipped["a"]), np.asarray(t["a"]), rtol=1e-5)


def test_clip_preserves_direction():
    t = _tree(jax.random.PRNGKey(0), 10.0)
    clipped, _ = clip_tree(t, 1.0)
    ratio = np.asarray(t["a"]) / np.asarray(clipped["a"])
    assert np.allclose(ratio, ratio.flat[0], rtol=1e-4)


# ---------------------------------------------------------------------------
# per-example DP gradient (Eq. 4-6)
# ---------------------------------------------------------------------------

def _quad_loss(params, ex):
    return jnp.sum((params["w"] * ex["x"] - ex["y"]) ** 2)


def test_dp_mean_gradient_noise_scale():
    """With sigma=0 the DP mean equals the clipped-mean; with sigma>0 the
    deviation matches sigma*C/B statistically."""
    key = jax.random.PRNGKey(0)
    params = {"w": jnp.ones((8,))}
    B = 64
    batch = {"x": jax.random.normal(key, (B, 8)), "y": jnp.zeros((B, 8))}
    cfg0 = DPConfig(clip_norm=1.0, noise_multiplier=0.0)
    g0, aux = dp_mean_gradient(_quad_loss, params, batch, key, cfg0)
    assert 0.0 <= float(aux["clip_fraction"]) <= 1.0
    # per-sample clipped norms <= C implies mean norm <= C
    assert float(tree_global_norm(g0)) <= 1.0 + 1e-5

    cfg1 = DPConfig(clip_norm=1.0, noise_multiplier=2.0)
    devs = []
    for s in range(8):
        g1, _ = dp_mean_gradient(_quad_loss, params, batch,
                                 jax.random.PRNGKey(s), cfg1)
        devs.append(float(tree_global_norm(tree_sub(g1, g0))))
    # E||noise|| ~ sigma*C/B * sqrt(dim): dim=8 -> 2/64*2.83 ~ 0.088
    mean_dev = np.mean(devs)
    assert 0.03 < mean_dev < 0.3, mean_dev


def test_dp_kernel_path_matches_jnp_path():
    key = jax.random.PRNGKey(1)
    params = {"w": jnp.ones((16,))}
    batch = {"x": jax.random.normal(key, (32, 16)), "y": jnp.zeros((32, 16))}
    cfg = DPConfig(clip_norm=0.7, noise_multiplier=0.0)
    g_jnp, _ = dp_mean_gradient(_quad_loss, params, batch, key, cfg,
                                dp_path="jnp")
    g_ker, _ = dp_mean_gradient(_quad_loss, params, batch, key, cfg,
                                dp_path="pallas")
    np.testing.assert_allclose(np.asarray(g_jnp["w"]), np.asarray(g_ker["w"]),
                               rtol=1e-5, atol=1e-6)


def test_dp_kernel_path_fused_noise_matches_noise_tree():
    """The pallas path's in-kernel noise epilogue replays noise_tree's
    exact per-leaf draws: with sigma > 0 both paths agree to float
    tolerance (a 2-leaf tree exercises the split order)."""
    key = jax.random.PRNGKey(4)
    params = {"w": jnp.ones((16,)), "b": {"c": jnp.ones((4, 3))}}

    def loss(p, ex):
        return (jnp.sum((p["w"] * ex["x"] - ex["y"]) ** 2)
                + jnp.sum(p["b"]["c"] ** 2) * jnp.sum(ex["x"]))

    batch = {"x": jax.random.normal(key, (32, 16)), "y": jnp.zeros((32, 16))}
    cfg = DPConfig(clip_norm=0.7, noise_multiplier=1.5)
    for nkey in (jax.random.PRNGKey(7), jax.random.PRNGKey(8)):
        g_jnp, _ = dp_mean_gradient(loss, params, batch, nkey, cfg,
                                    dp_path="jnp")
        g_ker, _ = dp_mean_gradient(loss, params, batch, nkey, cfg,
                                    dp_path="pallas")
        for a, b in zip(jax.tree_util.tree_leaves(g_jnp),
                        jax.tree_util.tree_leaves(g_ker)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)


def test_dp_mean_gradient_rejects_unknown_dp_path():
    with pytest.raises(ValueError, match="dp_path"):
        dp_mean_gradient(_quad_loss, {"w": jnp.ones((4,))},
                         {"x": jnp.ones((2, 4)), "y": jnp.zeros((2, 4))},
                         jax.random.PRNGKey(0), DPConfig(), dp_path="tpu")


# ---------------------------------------------------------------------------
# aggregation (Eq. 9-11)
# ---------------------------------------------------------------------------

def test_fedavg_weighted_mean():
    t1 = {"w": jnp.ones((4,))}
    t2 = {"w": 3 * jnp.ones((4,))}
    out = FedAvg().aggregate(None, [(t1, 100), (t2, 300)])
    np.testing.assert_allclose(np.asarray(out["w"]), 2.5)  # (1*1+3*3)/4


@settings(max_examples=40, deadline=None)
@given(alpha=st.floats(0.05, 1.0), tau=st.integers(0, 50))
def test_fedasync_weight_decays_with_staleness(alpha, tau):
    s = FedAsync(alpha=alpha)
    w = s.mixing_weight(tau)
    assert w == pytest.approx(alpha / (1 + tau))
    assert s.mixing_weight(tau + 1) < w


def test_fedasync_merge_convex():
    """Merged params stay on the segment between global and client (Eq 11)."""
    g = {"w": jnp.zeros((4,))}
    c = {"w": jnp.ones((4,))}
    merged, a_k = FedAsync(alpha=0.6).merge(g, c, staleness=2)
    np.testing.assert_allclose(np.asarray(merged["w"]), 0.2)  # 0.6/3
    assert 0 < a_k <= 0.6


def test_fedasync_nostale_constant_weight():
    s = make_strategy("fedasync_nostale", alpha=0.4)
    assert s.mixing_weight(0) == s.mixing_weight(10) == 0.4


def test_fedbuff_applies_every_k():
    s = FedBuff(alpha=0.5, buffer_size=3)
    g = {"w": jnp.zeros((2,))}
    c = {"w": jnp.ones((2,))}
    out1, applied1, _ = s.offer(g, c, 0)
    out2, applied2, _ = s.offer(g, c, 1)
    out3, applied3, _ = s.offer(g, c, 2)
    assert (applied1, applied2, applied3) == (False, False, True)
    assert out3 is not None
    assert 0 < float(out3["w"][0]) < 1


def test_adaptive_async_throttles_by_privacy_spend():
    s = AdaptiveAsync(alpha=0.6, eps_target=8.0)
    fresh = s.mixing_weight(0, eps_spent=0.0)
    spent = s.mixing_weight(0, eps_spent=7.9)
    assert spent < 0.2 * fresh


# ---------------------------------------------------------------------------
# fairness metrics
# ---------------------------------------------------------------------------

def test_fairness_metrics():
    from repro.core.fairness import jain_index, participation_percentages, privacy_disparity
    pp = participation_percentages({"a": 80, "b": 20})
    assert pp["a"] == 80.0
    assert jain_index([1, 1, 1, 1]) == pytest.approx(1.0)
    assert jain_index([1, 0, 0, 0]) == pytest.approx(0.25)
    assert privacy_disparity({"a": 35.0, "b": 7.0}) == pytest.approx(5.0)
