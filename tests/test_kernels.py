"""Per-kernel correctness: sweep shapes/dtypes and assert_allclose against
the pure-jnp oracle (interpret=True executes the Pallas body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dp_clip.ops import dp_clip_mean_flat
from repro.kernels.dp_clip.ref import dp_clip_mean_flat_ref
from repro.kernels.flash_attn.ops import flash_decode
from repro.kernels.flash_attn.ref import flash_decode_ref
from repro.kernels.ssd_scan.ops import ssd_intra_chunk
from repro.kernels.ssd_scan.ref import ssd_intra_chunk_ref


# ---------------------------------------------------------------------------
# dp_clip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,D", [(8, 64), (128, 512), (64, 1000), (33, 257)])
@pytest.mark.parametrize("clip", [0.5, 1.0, 10.0])
def test_dp_clip_matches_ref(B, D, clip):
    key = jax.random.PRNGKey(B * D)
    flat = jax.random.normal(key, (B, D), jnp.float32) * 0.3
    mean, nrm, frac = dp_clip_mean_flat(flat, clip)
    mean_r, nrm_r, frac_r = dp_clip_mean_flat_ref(flat, clip)
    np.testing.assert_allclose(mean, mean_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(nrm, nrm_r, rtol=1e-5)
    np.testing.assert_allclose(frac, frac_r, rtol=1e-6)


def test_dp_clip_bounds_norms():
    """Post-clip per-sample norms never exceed C (Eq. 4 invariant)."""
    key = jax.random.PRNGKey(0)
    flat = jax.random.normal(key, (32, 300), jnp.float32) * 5.0
    C = 1.0
    norms = jnp.sqrt(jnp.sum(flat**2, axis=1))
    scales = 1.0 / jnp.maximum(1.0, norms / C)
    clipped_norms = norms * scales
    assert float(clipped_norms.max()) <= C * (1 + 1e-5)


# ---------------------------------------------------------------------------
# flash decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,Hkv,Dh,window",
    [
        (2, 128, 4, 4, 64, 0),
        (2, 256, 8, 2, 64, 0),     # GQA
        (1, 512, 4, 4, 128, 128),  # sliding window
        (3, 384, 6, 2, 32, 100),   # uneven window, GQA
    ],
)
def test_flash_decode_matches_ref(B, S, H, Hkv, Dh, window, dtype):
    key = jax.random.PRNGKey(S + H)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, H, Dh), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh), dtype)
    pos = jax.random.randint(ks[3], (B,), S // 2, S)
    out = flash_decode(q, k, v, pos, window=window, ts=128)
    ref = flash_decode_ref(q, k, v, pos, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        out.astype(np.float32), ref.astype(np.float32), rtol=tol, atol=tol)


def test_flash_decode_softcap():
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (2, 4, 64), jnp.float32) * 4
    k = jax.random.normal(key, (2, 128, 4, 64), jnp.float32)
    v = jax.random.normal(key, (2, 128, 4, 64), jnp.float32)
    pos = jnp.array([100, 64])
    out = flash_decode(q, k, v, pos, softcap=50.0, ts=64)
    ref = flash_decode_ref(q, k, v, pos, softcap=50.0)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_flash_decode_vs_model_decode_attention():
    """Cross-check against the model-layer reference implementation."""
    from repro.models import layers as L
    from repro.models.base import ArchConfig
    cfg = ArchConfig(arch_id="t", family="dense", source="t", n_layers=1,
                     d_model=64, n_heads=4, n_kv_heads=2, d_ff=64, vocab=16,
                     param_dtype="float32")
    key = jax.random.PRNGKey(3)
    B, S, Dh = 2, 96, cfg.head_dim
    q = jax.random.normal(key, (B, 1, 4, Dh), jnp.float32)
    ck = jax.random.normal(key, (B, S, 2, Dh), jnp.float32)
    cv = jax.random.normal(key, (B, S, 2, Dh), jnp.float32)
    pos = jnp.array([50, 80])
    ref = flash_decode_ref(q[:, 0], ck, cv, pos)
    out = flash_decode(q[:, 0], ck, cv, pos, ts=32)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# ssd intra-chunk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,c,q,h,p,n", [
    (1, 2, 32, 2, 16, 16),
    (2, 4, 64, 4, 64, 64),
    (1, 1, 128, 8, 64, 32),
])
def test_ssd_intra_chunk_matches_ref(b, c, q, h, p, n, dtype):
    key = jax.random.PRNGKey(q * h)
    ks = jax.random.split(key, 4)
    xr = jax.random.normal(ks[0], (b, c, q, h, p), dtype)
    ar = -jnp.abs(jax.random.normal(ks[1], (b, h, c, q), jnp.float32)) * 0.1
    Br = jax.random.normal(ks[2], (b, c, q, n), dtype)
    Cr = jax.random.normal(ks[3], (b, c, q, n), dtype)
    out = ssd_intra_chunk(xr, ar, Br, Cr)
    ref = ssd_intra_chunk_ref(xr, ar, Br, Cr)
    o32, r32 = out.astype(np.float32), ref.astype(np.float32)
    if dtype == jnp.bfloat16:
        # the kernel accumulates fully in f32; the jnp oracle's einsum
        # rounds intermediates to bf16 — tolerance must scale with the
        # output magnitude (bf16 eps ~0.8%)
        atol = 1e-2 * float(np.abs(r32).max())
        np.testing.assert_allclose(o32, r32, rtol=5e-2, atol=atol)
    else:
        np.testing.assert_allclose(o32, r32, rtol=1e-4, atol=1e-4)


def test_ssd_kernel_inside_model():
    """mamba2_forward(use_kernel=True) == pure-jnp path."""
    import jax
    from repro.models.base import ArchConfig
    from repro.models.mamba2 import init_mamba2, mamba2_forward
    cfg = ArchConfig(arch_id="t", family="hybrid", source="t", n_layers=1,
                     d_model=32, n_heads=4, n_kv_heads=4, d_ff=64, vocab=16,
                     ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
                     param_dtype="float32")
    key = jax.random.PRNGKey(0)
    p = init_mamba2(key, cfg)
    x = jax.random.normal(key, (2, 64, 32), jnp.float32)
    y0, st0 = mamba2_forward(x, p, cfg, use_kernel=False)
    y1, st1 = mamba2_forward(x, p, cfg, use_kernel=True)
    np.testing.assert_allclose(y0, y1, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(st0[1], st1[1], rtol=1e-4, atol=1e-4)
