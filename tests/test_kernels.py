"""Per-kernel correctness: sweep shapes/dtypes and assert_allclose against
the pure-jnp oracle (interpret=True executes the Pallas body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.dp_clip.ops import dp_clip_mean_flat, dp_clip_mean_noise_cohort
from repro.kernels.dp_clip.ref import dp_clip_mean_flat_ref
from repro.kernels.flash_attn.ops import flash_decode
from repro.kernels.flash_attn.ref import flash_decode_ref
from repro.kernels.ssd_scan.ops import ssd_intra_chunk
from repro.kernels.ssd_scan.ref import ssd_intra_chunk_ref


# ---------------------------------------------------------------------------
# dp_clip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,D", [(8, 64), (128, 512), (64, 1000), (33, 257)])
@pytest.mark.parametrize("clip", [0.5, 1.0, 10.0])
def test_dp_clip_matches_ref(B, D, clip):
    key = jax.random.PRNGKey(B * D)
    flat = jax.random.normal(key, (B, D), jnp.float32) * 0.3
    mean, nrm, frac = dp_clip_mean_flat(flat, clip)
    mean_r, nrm_r, frac_r = dp_clip_mean_flat_ref(flat, clip)
    np.testing.assert_allclose(mean, mean_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(nrm, nrm_r, rtol=1e-5)
    np.testing.assert_allclose(frac, frac_r, rtol=1e-6)


def test_dp_clip_bounds_norms():
    """Post-clip per-sample norms never exceed C (Eq. 4 invariant)."""
    key = jax.random.PRNGKey(0)
    flat = jax.random.normal(key, (32, 300), jnp.float32) * 5.0
    C = 1.0
    norms = jnp.sqrt(jnp.sum(flat**2, axis=1))
    scales = 1.0 / jnp.maximum(1.0, norms / C)
    clipped_norms = norms * scales
    assert float(clipped_norms.max()) <= C * (1 + 1e-5)


@pytest.mark.parametrize("B,D", [
    (1, 64),       # single example: tb clamps to 1
    (1, 1),        # degenerate both axes
    (13, 257),     # prime B and D — every axis pads
    (8, 100),      # D below the default tile width
    (3, 700),      # B below tile, D above one tile
])
def test_dp_clip_awkward_shapes_match_ref(B, D):
    """The tile-size selection must handle every residue class, not just
    tile-divisible shapes (the old ``min(128, B) if B % ... else 128``
    logic was dead — tb is now clamped then padded unconditionally)."""
    key = jax.random.PRNGKey(B * 1000 + D)
    flat = jax.random.normal(key, (B, D), jnp.float32)
    mean, nrm, frac = dp_clip_mean_flat(flat, 1.0)
    mean_r, nrm_r, frac_r = dp_clip_mean_flat_ref(flat, 1.0)
    np.testing.assert_allclose(mean, mean_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(nrm, nrm_r, rtol=1e-5)
    np.testing.assert_allclose(frac, frac_r, rtol=1e-6)


@settings(max_examples=12, deadline=None)
@given(B=st.integers(1, 48), D=st.integers(1, 200),
       clip=st.floats(0.2, 4.0), seed=st.integers(0, 2**16))
def test_dp_clip_shape_property(B, D, clip, seed):
    key = jax.random.PRNGKey(seed)
    flat = jax.random.normal(key, (B, D), jnp.float32) * 0.8
    mean, nrm, frac = dp_clip_mean_flat(flat, clip)
    mean_r, nrm_r, frac_r = dp_clip_mean_flat_ref(flat, clip)
    np.testing.assert_allclose(mean, mean_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(nrm, nrm_r, rtol=1e-5)
    np.testing.assert_allclose(frac, frac_r, rtol=1e-6)


def test_dp_clip_cohort_matches_per_member_ref():
    """One launch over the stacked (K*B, D) matrix == K independent
    per-member clip+means; an all-zero member (the padded-mask case)
    contributes an exactly-zero mean row."""
    K, B, D = 4, 16, 70
    key = jax.random.PRNGKey(5)
    g = jax.random.normal(key, (K, B, D), jnp.float32)
    g = g.at[2].set(0.0)                       # a padded / masked member
    means, nrm, frac = dp_clip_mean_noise_cohort(g, 1.0)
    assert means.shape == (K, D)
    for m in range(K):
        mean_r, nrm_r, frac_r = dp_clip_mean_flat_ref(g[m], 1.0)
        np.testing.assert_allclose(means[m], mean_r, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(nrm[m], nrm_r, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(frac[m], frac_r, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(means[2]), 0.0)


def test_dp_clip_cohort_fused_noise_epilogue():
    """The fused epilogue adds exactly stddev * z on the final batch tile
    — for a runtime stddev value, so one compiled program serves a whole
    sigma sweep."""
    K, B, D = 3, 8, 130
    key = jax.random.PRNGKey(9)
    g = jax.random.normal(key, (K, B, D), jnp.float32)
    z = jax.random.normal(jax.random.PRNGKey(10), (K, D), jnp.float32)
    base, _, _ = dp_clip_mean_noise_cohort(g, 1.0)
    for std in (0.5, 1.0, 1.5, 2.0):
        noised, _, _ = dp_clip_mean_noise_cohort(g, 1.0, std, z)
        np.testing.assert_allclose(noised, base + std * z,
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# interpret-mode policy (kernels/common)
# ---------------------------------------------------------------------------

def test_interpret_policy_sources(monkeypatch):
    from repro.kernels import common
    prev = common.set_interpret_override(None)
    try:
        monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
        info = common.interpret_info()
        assert info["source"] == "auto"
        assert info["backend"] == jax.default_backend()
        assert info["interpret"] == (
            info["backend"] not in common._COMPILED_BACKENDS)

        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
        assert common.interpret_info() == {
            "backend": info["backend"], "interpret": False, "source": "env"}
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "yes")
        assert common.interpret_mode() is True

        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "junk")
        with pytest.raises(ValueError, match="REPRO_PALLAS_INTERPRET"):
            common.interpret_mode()

        # override wins over env
        common.set_interpret_override(False)
        assert common.interpret_info() == {
            "backend": info["backend"], "interpret": False,
            "source": "override"}
    finally:
        common.set_interpret_override(prev)


def test_interpret_auto_compiles_on_accelerators(monkeypatch):
    from repro.kernels import common
    prev = common.set_interpret_override(None)
    try:
        monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
        for backend, interp in (("tpu", False), ("gpu", False),
                                ("cuda", False), ("rocm", False),
                                ("cpu", True), ("metal", True)):
            monkeypatch.setattr(common.jax, "default_backend",
                                lambda b=backend: b)
            assert common.interpret_info() == {
                "backend": backend, "interpret": interp, "source": "auto"}
    finally:
        common.set_interpret_override(prev)


# ---------------------------------------------------------------------------
# flash decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,Hkv,Dh,window",
    [
        (2, 128, 4, 4, 64, 0),
        (2, 256, 8, 2, 64, 0),     # GQA
        (1, 512, 4, 4, 128, 128),  # sliding window
        (3, 384, 6, 2, 32, 100),   # uneven window, GQA
    ],
)
def test_flash_decode_matches_ref(B, S, H, Hkv, Dh, window, dtype):
    key = jax.random.PRNGKey(S + H)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, H, Dh), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh), dtype)
    pos = jax.random.randint(ks[3], (B,), S // 2, S)
    out = flash_decode(q, k, v, pos, window=window, ts=128)
    ref = flash_decode_ref(q, k, v, pos, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        out.astype(np.float32), ref.astype(np.float32), rtol=tol, atol=tol)


def test_flash_decode_softcap():
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (2, 4, 64), jnp.float32) * 4
    k = jax.random.normal(key, (2, 128, 4, 64), jnp.float32)
    v = jax.random.normal(key, (2, 128, 4, 64), jnp.float32)
    pos = jnp.array([100, 64])
    out = flash_decode(q, k, v, pos, softcap=50.0, ts=64)
    ref = flash_decode_ref(q, k, v, pos, softcap=50.0)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_flash_decode_vs_model_decode_attention():
    """Cross-check against the model-layer reference implementation."""
    from repro.models import layers as L
    from repro.models.base import ArchConfig
    cfg = ArchConfig(arch_id="t", family="dense", source="t", n_layers=1,
                     d_model=64, n_heads=4, n_kv_heads=2, d_ff=64, vocab=16,
                     param_dtype="float32")
    key = jax.random.PRNGKey(3)
    B, S, Dh = 2, 96, cfg.head_dim
    q = jax.random.normal(key, (B, 1, 4, Dh), jnp.float32)
    ck = jax.random.normal(key, (B, S, 2, Dh), jnp.float32)
    cv = jax.random.normal(key, (B, S, 2, Dh), jnp.float32)
    pos = jnp.array([50, 80])
    ref = flash_decode_ref(q[:, 0], ck, cv, pos)
    out = flash_decode(q[:, 0], ck, cv, pos, ts=32)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# ssd intra-chunk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,c,q,h,p,n", [
    (1, 2, 32, 2, 16, 16),
    (2, 4, 64, 4, 64, 64),
    (1, 1, 128, 8, 64, 32),
])
def test_ssd_intra_chunk_matches_ref(b, c, q, h, p, n, dtype):
    key = jax.random.PRNGKey(q * h)
    ks = jax.random.split(key, 4)
    xr = jax.random.normal(ks[0], (b, c, q, h, p), dtype)
    ar = -jnp.abs(jax.random.normal(ks[1], (b, h, c, q), jnp.float32)) * 0.1
    Br = jax.random.normal(ks[2], (b, c, q, n), dtype)
    Cr = jax.random.normal(ks[3], (b, c, q, n), dtype)
    out = ssd_intra_chunk(xr, ar, Br, Cr)
    ref = ssd_intra_chunk_ref(xr, ar, Br, Cr)
    o32, r32 = out.astype(np.float32), ref.astype(np.float32)
    if dtype == jnp.bfloat16:
        # the kernel accumulates fully in f32; the jnp oracle's einsum
        # rounds intermediates to bf16 — tolerance must scale with the
        # output magnitude (bf16 eps ~0.8%)
        atol = 1e-2 * float(np.abs(r32).max())
        np.testing.assert_allclose(o32, r32, rtol=5e-2, atol=atol)
    else:
        np.testing.assert_allclose(o32, r32, rtol=1e-4, atol=1e-4)


def test_ssd_kernel_inside_model():
    """mamba2_forward(use_kernel=True) == pure-jnp path."""
    import jax
    from repro.models.base import ArchConfig
    from repro.models.mamba2 import init_mamba2, mamba2_forward
    cfg = ArchConfig(arch_id="t", family="hybrid", source="t", n_layers=1,
                     d_model=32, n_heads=4, n_kv_heads=4, d_ff=64, vocab=16,
                     ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
                     param_dtype="float32")
    key = jax.random.PRNGKey(0)
    p = init_mamba2(key, cfg)
    x = jax.random.normal(key, (2, 64, 32), jnp.float32)
    y0, st0 = mamba2_forward(x, p, cfg, use_kernel=False)
    y1, st1 = mamba2_forward(x, p, cfg, use_kernel=True)
    np.testing.assert_allclose(y0, y1, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(st0[1], st1[1], rtol=1e-4, atol=1e-4)
