"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

For each (arch x shape) on the single-pod 16x16 mesh:

    compute term    = per_chip_dot_FLOPs / 197 TFLOP/s (bf16)
    memory term     = per_chip_HBM_bytes / 819 GB/s
    collective term = per_chip_link_traffic / 50 GB/s (per-link ICI)

All three numerators come from the trip-count-aware HLO walker
(hlo_analysis.py) over the compiled, partitioned module — i.e. they are
per-chip quantities by construction.  The dominant term is the projected
bottleneck; MODEL_FLOPS/HLO_FLOPs (the 'useful-compute' ratio) uses
6*N*D (train), 2*N*tokens (prefill) or 2*N*B (decode, per step), with
N_active for MoE.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12      # bf16 / chip (TPU v5e)
HBM_BW = 819e9           # B/s / chip
ICI_BW = 50e9            # B/s / link

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "../results/dryrun")
OUT_DIR = os.path.join(os.path.dirname(__file__), "../results/bench")


def _param_counts(arch_id):
    """(N_total, N_active) from the config via eval_shape (no allocation)."""
    import jax
    from repro.configs import get_config
    from repro.models.base import get_family

    cfg = get_config(arch_id)
    fam = get_family(cfg.family)
    key = jax.random.PRNGKey(0)
    sds = jax.eval_shape(lambda: fam.init_params(key, cfg))
    n_total = sum(l.size for l in jax.tree_util.tree_leaves(sds))
    n_active = n_total
    if cfg.n_experts:
        F = cfg.d_expert or cfg.d_ff
        per_layer_experts = cfg.n_experts * 3 * cfg.d_model * F
        inactive = (cfg.n_experts - cfg.top_k) * 3 * cfg.d_model * F
        n_active = n_total - cfg.n_layers * inactive
    return n_total, n_active


def model_flops(arch_id, shape_name, seq_len, batch, kind):
    n_total, n_active = _param_counts(arch_id)
    if kind == "train":
        return 6.0 * n_active * seq_len * batch
    if kind == "prefill":
        return 2.0 * n_active * seq_len * batch
    return 2.0 * n_active * batch          # decode: one token per sequence


SHAPE_KIND = {"train_4k": "train", "prefill_32k": "prefill",
              "decode_32k": "decode", "long_500k": "decode"}
SHAPE_DIMS = {"train_4k": (4096, 256), "prefill_32k": (32768, 32),
              "decode_32k": (32768, 128), "long_500k": (524288, 1)}

CHIPS = 256


def analytic_memory_bytes(arch_id, shape_name):
    """First-order per-chip HBM-traffic floor (what a perfectly fused TPU
    program must move), used alongside the HLO-bytes upper bound:

      train:   client params fwd+bwd+delta (3 x 2N) + master Adam update
               (read p,m,v + write p,m,v in f32 = 24N) + residual
               activations (B*S*d*L*2, read+write)
      prefill: params 2N + KV-cache write + activations
      decode:  params 2N (every weight read once per token) + KV read
    """
    from repro.configs import get_config
    cfg = get_config(arch_id, long_variant=(shape_name == "long_500k"))
    S, B = SHAPE_DIMS[shape_name]
    n_total, _ = _param_counts(arch_id)
    kind = SHAPE_KIND[shape_name]
    L, d = cfg.n_layers, cfg.d_model
    if kind == "train":
        params = 3 * 2 * n_total + 24 * n_total
        acts = 2 * (B * S * d * L * 2)
        total = params + acts
    else:
        # KV bytes (window-bounded for pure sliding-window configs)
        eff_s = min(S, cfg.sliding_window) if (
            cfg.sliding_window and not cfg.local_global_pattern) else S
        kv = 2 * L * B * eff_s * cfg.n_kv_heads * cfg.head_dim * 2
        if cfg.family in ("ssm", "hybrid"):
            kv = L * B * 4 * d * cfg.ssm_state  # recurrent states, f32
        if kind == "prefill":
            total = 2 * n_total + kv + 2 * (B * S * d * L * 2)
        else:
            total = 2 * n_total + kv
    return total / CHIPS


def lever_sentence(dominant, arch, shape):
    return {
        "compute": ("raise MXU utilisation: remove remat waste / pad-free "
                    "head sharding / larger microbatch"),
        "memory": ("cut HBM traffic: fuse noise+clip, keep KV in bf16, "
                   "window-bound the decode cache, reuse gathered params"),
        "collective": ("reduce link traffic: reduce-scatter instead of "
                       "all-reduce+slice, overlap param all-gather with "
                       "compute, shard aggregation tree"),
    }[dominant]


def analyze_all(mesh="single", chips=256, tag=""):
    rows = []
    suffix = f"__{tag}" if tag else ""
    for fn in sorted(glob.glob(os.path.join(
            DRYRUN_DIR, f"*__{mesh}{suffix}.json"))):
        base = os.path.basename(fn)[: -len(".json")]
        parts = base.split("__")
        if tag and (len(parts) < 4 or parts[3] != tag):
            continue
        if not tag and len(parts) != 3:
            continue
        arch, shape = parts[0], parts[1]
        with open(fn) as f:
            d = json.load(f)
        if d.get("status") != "ok" or "walk" not in d:
            rows.append({"arch": arch, "shape": shape, "status":
                         d.get("status", "?"),
                         "error": d.get("error", "")[:100]})
            continue
        walk = d["walk"]
        if "error" in walk:
            rows.append({"arch": arch, "shape": shape,
                         "status": "walk_error", "error": walk["error"]})
            continue
        t_comp = walk["dot_flops"] / PEAK_FLOPS
        t_mem_hlo = walk["hbm_bytes"] / HBM_BW
        t_mem_floor = analytic_memory_bytes(arch, shape) / HBM_BW
        # the CPU-lowered HLO keeps donation copies / unaliased cache
        # updates a TPU elides; classify with the geometric mean of the
        # upper bound and the analytic floor, report both (EXPERIMENTS.md)
        t_mem = (t_mem_hlo * t_mem_floor) ** 0.5
        t_coll = walk["total_collective_bytes"] / ICI_BW
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        dominant = max(terms, key=terms.get)
        S, B = SHAPE_DIMS[shape]
        kind = SHAPE_KIND[shape]
        mf = model_flops(arch, shape, S, B, kind)
        hlo_total = walk["dot_flops"] * chips
        rows.append({
            "arch": arch, "shape": shape, "status": "ok",
            "compute_s": t_comp, "memory_s": t_mem,
            "memory_hlo_s": t_mem_hlo, "memory_floor_s": t_mem_floor,
            "collective_s": t_coll,
            "dominant": dominant,
            "bound_s": max(terms.values()),
            "model_flops": mf,
            "hlo_flops_total": hlo_total,
            "useful_ratio": mf / hlo_total if hlo_total else None,
            "lever": lever_sentence(dominant, arch, shape),
        })
    return rows


def write_table(rows, name="roofline"):
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=1, default=float)
    # markdown for EXPERIMENTS.md
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant "
        "| useful FLOP ratio |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                         f"{r.get('status')} | - |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant']} | "
            f"{(r['useful_ratio'] or 0):.2f} |")
    md = "\n".join(lines)
    with open(os.path.join(OUT_DIR, f"{name}.md"), "w") as f:
        f.write(md + "\n")
    return md


if __name__ == "__main__":
    import sys
    tag = sys.argv[1] if len(sys.argv) > 1 else ""
    rows = analyze_all(tag=tag)
    print(write_table(rows, name=f"roofline{'_' + tag if tag else ''}"))
