"""Render the paper-validation summary from results/bench/*.json
(EXPERIMENTS.md §Paper-validation table).

    PYTHONPATH=src:. python benchmarks/summarize.py
"""
import json
import os

import numpy as np

BENCH = os.path.join(os.path.dirname(__file__), "../results/bench")


def _load(name):
    fn = os.path.join(BENCH, f"{name}.json")
    return json.load(open(fn)) if os.path.exists(fn) else None


def main():
    out = []

    fig4 = _load("fig4_convergence")
    if fig4:
        sp = [r["speedup"] for r in fig4
              if r["speedup"] and r["strategy"] == "fedasync"]
        sp_ns = [r["speedup"] for r in fig4
                 if r["speedup"] and r["strategy"] == "fedasync_nostale"]
        fl = [r["acc_fluctuation"] for r in fig4
              if r["strategy"] == "fedasync"]
        fl_ns = [r["acc_fluctuation"] for r in fig4
                 if r["strategy"] == "fedasync_nostale"]
        out.append(f"fig4: FedAsync speedup to target = "
                   f"{np.mean(sp):.1f}x (paper: 9-10x)"
                   + (f"; no-staleness variant {np.mean(sp_ns):.1f}x with "
                      f"fluctuation {np.mean(fl_ns):.4f} vs "
                      f"{np.mean(fl):.4f} staleness-aware"
                      if sp_ns and fl and fl_ns else ""))

    fig5 = _load("fig5_fairness")
    if fig5:
        for r in fig5:
            out.append(
                f"fig5 alpha={r['alpha']}: high-end PP={r['high_end_pp']}% "
                f"(T1={r.get('pp_HW_T1')}%), Jain={r['jain_participation']}, "
                f"acc gap={r['accuracy_gap']}"
            )

    t3 = _load("table3_privacy")
    if t3:
        for sigma in sorted({r["sigma"] for r in t3}):
            asy = [r for r in t3 if r["sigma"] == sigma
                   and "async" in r["method"]]
            if not asy:
                continue
            hi = [r["epsilon"] for r in asy if r["device"] in
                  ("HW_T4", "HW_T5")]
            lo = [r["epsilon"] for r in asy if r["device"] in
                  ("HW_T1", "HW_T2")]
            acc_hi = [r["acc_loss_pct"] for r in asy if r["device"] in
                      ("HW_T4", "HW_T5")]
            acc_lo = [r["acc_loss_pct"] for r in asy if r["device"] in
                      ("HW_T1", "HW_T2")]
            avg = [r["epsilon"] for r in t3 if r["sigma"] == sigma
                   and r["method"] == "fedavg"]
            out.append(
                f"table3 sigma={sigma}: eps high-end={np.mean(hi):.2f} "
                f"low-end={np.mean(lo):.2f} "
                f"(disparity {np.mean(hi)/max(np.mean(lo),1e-9):.1f}x); "
                f"acc-loss low-end={np.mean(acc_lo):.1f}% "
                f"vs high-end={np.mean(acc_hi):.1f}%; "
                f"fedavg uniform eps={np.mean(avg):.2f}"
            )

    t2 = _load("table2_resources")
    if t2:
        d = {r["hw_type"]: r for r in t2}
        out.append(
            f"table2: cpu_user T1={d['HW_T1']['cpu_user_s']}s vs "
            f"T5={d['HW_T5']['cpu_user_s']}s; RAM% T1="
            f"{d['HW_T1']['ram_pct']} vs T5={d['HW_T5']['ram_pct']}; "
            f"dropouts T1={d['HW_T1']['dropouts']} T2={d['HW_T2']['dropouts']}"
        )

    bp = _load("beyond_paper_tradeoffs")
    if bp:
        for r in bp:
            out.append(
                f"beyond: {r['strategy']}: t_target={r['time_to_target_s']} "
                f"jain={r['jain_participation']} "
                f"eps_disparity={r['privacy_disparity']}x "
                f"max_eps={r['max_eps']}"
            )

    print("\n".join(out))


if __name__ == "__main__":
    main()
