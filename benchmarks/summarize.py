"""Render the paper-validation summary from results/bench/*.json
(EXPERIMENTS.md §Paper-validation table) plus the engine perf trajectory
from BENCH_engine.json at the repo root.

    PYTHONPATH=src:. python benchmarks/summarize.py
    PYTHONPATH=src:. python benchmarks/summarize.py --check-engine
        # validate BENCH_engine.json only; exit 1 when missing/malformed
        # (CI's engine-mesh bench-smoke step)
"""
import json
import os
import sys

import numpy as np

from repro.core.runlog import ENGINE_STATS_KEYS

BENCH = os.path.join(os.path.dirname(__file__), "../results/bench")
BENCH_ENGINE = os.path.join(os.path.dirname(__file__), "../BENCH_engine.json")

# bench-row fields lifted verbatim from RunLog.engine_stats.  The stats
# schema is frozen in repro.core.runlog.ENGINE_STATS_KEYS (the same list
# the engine and repro.analysis.audits validate against); if a key is
# renamed there, --check-engine must fail loudly here instead of letting
# the benches silently emit nulls for the old name.
_STATS_ROW_FIELDS = {
    "data_path", "pipeline_depth", "host_syncs_between_evals",
    "blocking_submits", "drain_waits", "h2d_bytes_per_cohort",
    "degraded_cohorts", "fault_lost_updates", "screen_rejections",
    "screen_verdict_syncs",
}
_stats_drift = _STATS_ROW_FIELDS - set(ENGINE_STATS_KEYS)
if _stats_drift:
    raise RuntimeError(
        f"summarize.py expects bench rows to carry engine-stats fields "
        f"{sorted(_stats_drift)} that no longer exist in "
        "repro.core.runlog.ENGINE_STATS_KEYS — update _STATS_ROW_FIELDS "
        "and the row builders in benchmarks/fl_benchmarks.py together")

# every row bench_engine_throughput emits must carry these keys (values
# may be null for the legacy row).  "spec" is the full
# ExperimentSpec.to_dict() provenance — the row must be reproducible
# from the JSON alone.
_ENGINE_ROW_KEYS = {
    "engine", "executor", "data_path", "mesh", "wall_s", "warm_step_ms",
    "updates_per_s", "speedup_vs_legacy", "h2d_bytes_per_cohort",
    "degraded_cohorts", "fault_lost_updates", "screen_rejections", "spec",
}

# the pipelined-scheduler section (bench_engine_pipeline, multi-device
# runs): serial vs pipelined driver rows
_PIPELINE_ROW_KEYS = {
    "engine", "pipeline_depth", "accounting", "wall_s", "warm_step_ms",
    "updates_per_s", "speedup_vs_serial", "host_syncs_between_evals",
    "blocking_submits", "drain_waits", "spec",
}

# the Session sweep-amortization section (bench_sweep_amortization):
# cold per-run rebuilds vs one warm Session over the sigma grid
_SWEEP_KEYS = {
    "sigmas", "cold_wall_s", "warm_wall_s", "speedup", "cold_step_builds",
    "warm_step_builds", "spec", "axes",
}

# the DP hot-path section (bench_dp_path): jnp reference vs the fused
# Pallas clip+noise kernel, with the interpret-mode provenance that keeps
# a silently-interpreted "kernel" number from passing as a perf row
_DP_ROW_KEYS = {
    "dp_path", "backend", "interpret", "interpret_source", "wall_s",
    "warm_step_ms", "updates_per_s", "speedup_vs_jnp", "spec",
}

# backends whose Pallas lowering compiles for real: a pallas bench row
# reporting interpret=True on one of these is a misconfiguration, not a
# measurement (mirror of kernels/common._COMPILED_BACKENDS)
_COMPILED_BACKENDS = {"tpu", "gpu", "cuda", "rocm"}

# the update-screening overhead section (bench_screening_overhead):
# screening-off vs screening-on on the same clean workload
_SCREEN_ROW_KEYS = {
    "screening", "wall_s", "updates_per_s", "screen_rejections",
    "screen_verdict_syncs", "spec",
}

# the tiered-store scale section (bench_scale): the same FedAsync
# workload over growing shared-row populations through the hot-slot-
# bounded TieredStateStore; the device-arena footprint must stay bounded
# while resident_equiv grows with N, and every row's fetch ledger must
# balance (store_fetches == hot + prefetch + stall)
_SCALE_ROW_KEYS = {
    "n_clients", "hot_slots", "lookahead", "population", "updates",
    "wall_s", "updates_per_s", "peak_device_arena_bytes",
    "resident_equiv_bytes", "store_fetches", "store_hot_hits",
    "store_prefetch_hits", "store_stall_waits", "store_evictions",
    "store_spill_bytes", "store_sync_reads", "spec",
}

# an ExperimentSpec provenance dict must at least nest these sub-configs
_SPEC_KEYS = {"testbed", "strategy", "run", "engine"}


def _check_spec(fn, where, spec):
    if not isinstance(spec, dict) or spec.get("__type__") != "ExperimentSpec":
        raise ValueError(
            f"{fn}: {where} 'spec' is not an ExperimentSpec dict")
    missing = _SPEC_KEYS - set(spec)
    if missing:
        raise ValueError(
            f"{fn}: {where} spec missing sub-configs {sorted(missing)}")


def _load(name):
    fn = os.path.join(BENCH, f"{name}.json")
    return json.load(open(fn)) if os.path.exists(fn) else None


def load_engine_bench(path=None):
    """Load + schema-check BENCH_engine.json.  Returns the parsed dict or
    raises ValueError naming what is wrong (missing file, bad shape)."""
    fn = path or BENCH_ENGINE
    if not os.path.exists(fn):
        raise ValueError(f"{fn} is missing — run "
                         "benchmarks.fl_benchmarks.bench_engine_throughput")
    try:
        data = json.load(open(fn))
    except json.JSONDecodeError as e:
        raise ValueError(f"{fn} is not valid JSON: {e}") from e
    if data.get("benchmark") != "engine_throughput":
        raise ValueError(f"{fn}: benchmark != 'engine_throughput'")
    rows = data.get("rows")
    if not isinstance(rows, list) or not rows:
        raise ValueError(f"{fn}: no rows")
    for i, r in enumerate(rows):
        missing = _ENGINE_ROW_KEYS - set(r)
        if missing:
            raise ValueError(f"{fn}: row {i} missing keys {sorted(missing)}")
        _check_spec(fn, f"row {i}", r["spec"])
        # the throughput scenarios run FAULTLESS with screening off: a
        # nonzero resilience or screening counter means a FaultModel or
        # ScreeningConfig leaked into the perf run and the timing mixes
        # degraded/defended cohorts with healthy ones
        for k in ("degraded_cohorts", "fault_lost_updates",
                  "screen_rejections"):
            if r[k]:
                raise ValueError(
                    f"{fn}: row {i} ({r['engine']}) reports {k}={r[k]} — "
                    "the throughput bench must run without a FaultModel "
                    "or ScreeningConfig")
    pipe = data.get("pipeline")
    if pipe is None:
        if data.get("devices", 1) > 1:
            raise ValueError(
                f"{fn}: multi-device run is missing the 'pipeline' section "
                "(serial vs pipelined scheduler rows — run "
                "benchmarks.fl_benchmarks.bench_engine_pipeline)")
    else:
        prows = pipe.get("rows")
        if not isinstance(prows, list) or not prows:
            raise ValueError(f"{fn}: pipeline section has no rows")
        for i, r in enumerate(prows):
            missing = _PIPELINE_ROW_KEYS - set(r)
            if missing:
                raise ValueError(
                    f"{fn}: pipeline row {i} missing keys {sorted(missing)}")
            _check_spec(fn, f"pipeline row {i}", r["spec"])
        names = {r["engine"] for r in prows}
        if not {"serial", "pipelined"} <= names:
            raise ValueError(
                f"{fn}: pipeline section must compare 'serial' and "
                f"'pipelined' rows (got {sorted(names)})")
        for r in prows:
            if r["engine"] == "pipelined" and r["host_syncs_between_evals"]:
                raise ValueError(
                    f"{fn}: pipelined row reports "
                    f"{r['host_syncs_between_evals']} host syncs between "
                    "eval boundaries (must be 0)")
            if r["engine"] == "serial" and not r["host_syncs_between_evals"]:
                raise ValueError(
                    f"{fn}: serial row reports 0 host syncs between eval "
                    "boundaries — the serial driver's donation-blocked "
                    "submits must be counted (one per cohort), otherwise "
                    "the pipelined row's 0 is vacuous")
    sweep = data.get("sweep")
    if sweep is None:
        raise ValueError(
            f"{fn}: missing the 'sweep' section (cold-per-run vs warm "
            "Session over the sigma grid — run "
            "benchmarks.fl_benchmarks.bench_sweep_amortization)")
    missing = _SWEEP_KEYS - set(sweep)
    if missing:
        raise ValueError(
            f"{fn}: sweep section missing keys {sorted(missing)}")
    _check_spec(fn, "sweep section", sweep["spec"])
    if sweep["warm_step_builds"] >= sweep["cold_step_builds"]:
        raise ValueError(
            f"{fn}: warm Session sweep built {sweep['warm_step_builds']} "
            f"step programs vs {sweep['cold_step_builds']} cold — the "
            "sigma grid must share compiled steps (the runtime noise-"
            "scale argument)")
    if sweep["speedup"] <= 1.0:
        raise ValueError(
            f"{fn}: warm Session sweep is not faster than cold per-run "
            f"rebuilds (speedup {sweep['speedup']}x must be > 1)")
    dp = data.get("dp_path")
    if dp is None:
        raise ValueError(
            f"{fn}: missing the 'dp_path' section (jnp vs fused Pallas "
            "clip+noise kernel on the cohort hot path — run "
            "benchmarks.fl_benchmarks.bench_dp_path)")
    drows = dp.get("rows")
    if not isinstance(drows, list) or not drows:
        raise ValueError(f"{fn}: dp_path section has no rows")
    for i, r in enumerate(drows):
        missing = _DP_ROW_KEYS - set(r)
        if missing:
            raise ValueError(
                f"{fn}: dp_path row {i} missing keys {sorted(missing)}")
        _check_spec(fn, f"dp_path row {i}", r["spec"])
    names = {r["dp_path"] for r in drows}
    if not {"jnp", "pallas"} <= names:
        raise ValueError(
            f"{fn}: dp_path section must compare 'jnp' and 'pallas' rows "
            f"(got {sorted(names)})")
    for r in drows:
        if r["dp_path"] != "pallas":
            continue
        if r["interpret"] is None:
            raise ValueError(
                f"{fn}: pallas dp_path row carries no interpret-mode "
                "provenance (RunLog.engine_stats['pallas_interpret'])")
        if r["interpret"] and r["backend"] in _COMPILED_BACKENDS:
            raise ValueError(
                f"{fn}: pallas dp_path row ran in INTERPRET mode on "
                f"backend {r['backend']!r} (compiled-capable) — the "
                "number is not a kernel measurement; fix the interpret "
                "policy (kernels/common) or unset REPRO_PALLAS_INTERPRET")
    screen = data.get("screening")
    if screen is None:
        raise ValueError(
            f"{fn}: missing the 'screening' section (screening-on vs "
            "screening-off overhead on the clean workload — run "
            "benchmarks.fl_benchmarks.bench_screening_overhead)")
    srows = screen.get("rows")
    if not isinstance(srows, list) or not srows:
        raise ValueError(f"{fn}: screening section has no rows")
    for i, r in enumerate(srows):
        missing = _SCREEN_ROW_KEYS - set(r)
        if missing:
            raise ValueError(
                f"{fn}: screening row {i} missing keys {sorted(missing)}")
        _check_spec(fn, f"screening row {i}", r["spec"])
        # the overhead pair runs CLEAN — rejections firing here mean the
        # off/on comparison is not like-for-like
        if r["screen_rejections"]:
            raise ValueError(
                f"{fn}: screening row {i} ({r['screening']}) reports "
                f"{r['screen_rejections']} rejections — the overhead "
                "pair must run without corruption")
    names = {r["screening"] for r in srows}
    if not {"off", "on"} <= names:
        raise ValueError(
            f"{fn}: screening section must compare 'off' and 'on' rows "
            f"(got {sorted(names)})")
    for r in srows:
        if r["screening"] == "on" and not r["screen_verdict_syncs"]:
            raise ValueError(
                f"{fn}: screening-on row reports 0 verdict syncs — the "
                "sanctioned per-cohort verdict fetch must be counted, "
                "otherwise the measured overhead is vacuous")
        if r["screening"] == "off" and r["screen_verdict_syncs"]:
            raise ValueError(
                f"{fn}: screening-off row reports "
                f"{r['screen_verdict_syncs']} verdict syncs — with "
                "screening disabled nothing may fetch verdicts")
    if "overhead_pct" not in screen:
        raise ValueError(f"{fn}: screening section missing 'overhead_pct'")
    scale = data.get("scale")
    if scale is None:
        raise ValueError(
            f"{fn}: missing the 'scale' section (tiered-store client-count "
            "trajectory — run benchmarks.fl_benchmarks.bench_scale)")
    crows = scale.get("rows")
    if not isinstance(crows, list) or len(crows) < 2:
        raise ValueError(
            f"{fn}: scale section needs >= 2 rows (growing n_clients)")
    for i, r in enumerate(crows):
        missing = _SCALE_ROW_KEYS - set(r)
        if missing:
            raise ValueError(
                f"{fn}: scale row {i} missing keys {sorted(missing)}")
        _check_spec(fn, f"scale row {i}", r["spec"])
        if r["store_fetches"] != (r["store_hot_hits"]
                                  + r["store_prefetch_hits"]
                                  + r["store_stall_waits"]):
            raise ValueError(
                f"{fn}: scale row {i} (n={r['n_clients']}) breaks the "
                "store ledger law store_fetches == hot + prefetch + stall")
        if (r["hot_slots"] < r["n_clients"]
                and r["peak_device_arena_bytes"]
                >= r["resident_equiv_bytes"]):
            raise ValueError(
                f"{fn}: scale row {i} (n={r['n_clients']}, "
                f"hot={r['hot_slots']}) device arena "
                f"{r['peak_device_arena_bytes']}B is not smaller than the "
                f"all-resident equivalent {r['resident_equiv_bytes']}B — "
                "the tiered store is not bounding device memory")
    ns = [r["n_clients"] for r in crows]
    if ns != sorted(set(ns)):
        raise ValueError(
            f"{fn}: scale rows must have strictly increasing n_clients "
            f"(got {ns})")
    return data


def summarize_engine(out):
    try:
        data = load_engine_bench()
    except ValueError:
        return
    for r in data["rows"]:
        h2d = r["h2d_bytes_per_cohort"]
        out.append(
            f"engine[{data['devices']}dev] {r['engine']}: "
            f"{r['speedup_vs_legacy']}x vs legacy, "
            f"warm step {r['warm_step_ms']}ms, "
            f"h2d/cohort {h2d if h2d is not None else '-'}B "
            f"({r['data_path']})")
    for r in data.get("pipeline", {}).get("rows", []):
        out.append(
            f"pipeline[{data['devices']}dev] {r['engine']} "
            f"(depth={r['pipeline_depth']}, {r['accounting']} acct): "
            f"{r['speedup_vs_serial']}x vs serial, "
            f"wall {r['wall_s']}s, warm step {r['warm_step_ms']}ms, "
            f"syncs-between-evals {r['host_syncs_between_evals']}, "
            f"blocking submits {r['blocking_submits']}")
    sw = data.get("sweep")
    if sw:
        out.append(
            f"sweep[{data['devices']}dev] sigma grid {sw['sigmas']}: "
            f"warm Session {sw['warm_wall_s']}s vs cold per-run "
            f"{sw['cold_wall_s']}s ({sw['speedup']}x), step builds "
            f"{sw['warm_step_builds']} vs {sw['cold_step_builds']}")
    for r in data.get("dp_path", {}).get("rows", []):
        mode = ("" if r["dp_path"] != "pallas" else
                (", interpret" if r["interpret"] else ", compiled")
                + f" [{r['interpret_source']}]")
        out.append(
            f"dp_path[{r['backend']}] {r['dp_path']}: "
            f"{r['speedup_vs_jnp']}x vs jnp, "
            f"warm step {r['warm_step_ms']}ms, "
            f"{r['updates_per_s']} updates/s{mode}")
    sc = data.get("screening")
    if sc:
        on = next((r for r in sc["rows"] if r["screening"] == "on"), None)
        out.append(
            f"screening[{data['devices']}dev] on-vs-off overhead "
            f"{sc['overhead_pct']}%"
            + (f", verdict syncs {on['screen_verdict_syncs']}" if on else ""))
    for r in data.get("scale", {}).get("rows", []):
        out.append(
            f"scale[{data['devices']}dev] n={r['n_clients']} "
            f"(hot={r['hot_slots']}, look={r['lookahead']}): "
            f"{r['updates_per_s']} updates/s, wall {r['wall_s']}s, "
            f"device arena {r['peak_device_arena_bytes'] // 1024}KB vs "
            f"resident-equiv {r['resident_equiv_bytes'] // 1024}KB, "
            f"prefetch {r['store_prefetch_hits']}/{r['store_fetches']} "
            f"fetches, {r['store_evictions']} evictions")


def main():
    out = []

    fig4 = _load("fig4_convergence")
    if fig4:
        sp = [r["speedup"] for r in fig4
              if r["speedup"] and r["strategy"] == "fedasync"]
        sp_ns = [r["speedup"] for r in fig4
                 if r["speedup"] and r["strategy"] == "fedasync_nostale"]
        fl = [r["acc_fluctuation"] for r in fig4
              if r["strategy"] == "fedasync"]
        fl_ns = [r["acc_fluctuation"] for r in fig4
                 if r["strategy"] == "fedasync_nostale"]
        out.append(f"fig4: FedAsync speedup to target = "
                   f"{np.mean(sp):.1f}x (paper: 9-10x)"
                   + (f"; no-staleness variant {np.mean(sp_ns):.1f}x with "
                      f"fluctuation {np.mean(fl_ns):.4f} vs "
                      f"{np.mean(fl):.4f} staleness-aware"
                      if sp_ns and fl and fl_ns else ""))

    fig5 = _load("fig5_fairness")
    if fig5:
        for r in fig5:
            out.append(
                f"fig5 alpha={r['alpha']}: high-end PP={r['high_end_pp']}% "
                f"(T1={r.get('pp_HW_T1')}%), Jain={r['jain_participation']}, "
                f"acc gap={r['accuracy_gap']}"
            )

    t3 = _load("table3_privacy")
    if t3:
        for sigma in sorted({r["sigma"] for r in t3}):
            asy = [r for r in t3 if r["sigma"] == sigma
                   and "async" in r["method"]]
            if not asy:
                continue
            hi = [r["epsilon"] for r in asy if r["device"] in
                  ("HW_T4", "HW_T5")]
            lo = [r["epsilon"] for r in asy if r["device"] in
                  ("HW_T1", "HW_T2")]
            acc_hi = [r["acc_loss_pct"] for r in asy if r["device"] in
                      ("HW_T4", "HW_T5")]
            acc_lo = [r["acc_loss_pct"] for r in asy if r["device"] in
                      ("HW_T1", "HW_T2")]
            avg = [r["epsilon"] for r in t3 if r["sigma"] == sigma
                   and r["method"] == "fedavg"]
            out.append(
                f"table3 sigma={sigma}: eps high-end={np.mean(hi):.2f} "
                f"low-end={np.mean(lo):.2f} "
                f"(disparity {np.mean(hi)/max(np.mean(lo),1e-9):.1f}x); "
                f"acc-loss low-end={np.mean(acc_lo):.1f}% "
                f"vs high-end={np.mean(acc_hi):.1f}%; "
                f"fedavg uniform eps={np.mean(avg):.2f}"
            )

    t2 = _load("table2_resources")
    if t2:
        d = {r["hw_type"]: r for r in t2}
        out.append(
            f"table2: cpu_user T1={d['HW_T1']['cpu_user_s']}s vs "
            f"T5={d['HW_T5']['cpu_user_s']}s; RAM% T1="
            f"{d['HW_T1']['ram_pct']} vs T5={d['HW_T5']['ram_pct']}; "
            f"dropouts T1={d['HW_T1']['dropouts']} T2={d['HW_T2']['dropouts']}"
        )

    bp = _load("beyond_paper_tradeoffs")
    if bp:
        for r in bp:
            out.append(
                f"beyond: {r['strategy']}: t_target={r['time_to_target_s']} "
                f"jain={r['jain_participation']} "
                f"eps_disparity={r['privacy_disparity']}x "
                f"max_eps={r['max_eps']}"
            )

    summarize_engine(out)

    print("\n".join(out))


if __name__ == "__main__":
    if "--check-engine" in sys.argv:
        try:
            data = load_engine_bench()
        except ValueError as e:
            print(f"BENCH_engine.json check FAILED: {e}")
            sys.exit(1)
        n_pipe = len(data.get("pipeline", {}).get("rows", []))
        sw = data["sweep"]
        n_dp = len(data["dp_path"]["rows"])
        sc = data["screening"]
        sca = data["scale"]["rows"]
        print(f"BENCH_engine.json ok: {len(data['rows'])} rows, "
              f"{n_pipe} pipeline rows, sweep {sw['speedup']}x "
              f"({sw['warm_step_builds']}/{sw['cold_step_builds']} builds), "
              f"{n_dp} dp_path rows, screening overhead "
              f"{sc['overhead_pct']}%, scale to n={sca[-1]['n_clients']} "
              f"({len(sca)} rows), {data['devices']} device(s)")
        sys.exit(0)
    main()
