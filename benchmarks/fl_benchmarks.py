"""Paper-table/figure benchmarks over the simulated heterogeneous testbed.

One function per paper artifact (Table 2, Fig. 3, Fig. 4, Fig. 5,
Table 3), each returning rows of dicts and writing CSV+JSON under
results/bench/.  Scale note: the default data size is HALF of CREMA-D
(2940 clips, B=64 — preserving the paper's sampling ratio q ~ 0.136) so a
full benchmark pass fits a single CPU core; ratios, not absolute times,
are the reproduction targets (DESIGN.md sec 2).
"""
from __future__ import annotations

import csv
import json
import os
import time
from dataclasses import replace

import numpy as np

from repro.core.accountant import compute_epsilon
from repro.core.testbed import TestbedConfig, run_experiment
from repro.data.synthetic_ser import SERDataConfig

RESULTS = os.path.join(os.path.dirname(__file__), "../results/bench")

HALF = SERDataConfig(n_total=2940)
TARGET_ACC = 0.75


def _cfg(sigma=1.0, use_dp=True, seed=0):
    return TestbedConfig(use_dp=use_dp, sigma=sigma, batch_size=64,
                         data=HALF, seed=seed)


def cached(name):
    """Return previously computed rows if the artifact exists (the harness
    caches results; pass --fresh to recompute)."""
    fn = os.path.join(RESULTS, f"{name}.json")
    if os.path.exists(fn):
        with open(fn) as f:
            return json.load(f)
    return None


def _write(name, rows):
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=1, default=float)
    if rows:
        with open(os.path.join(RESULTS, f"{name}.csv"), "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
    return rows


# ---------------------------------------------------------------------------
# Table 2: resource utilization per hardware type
# ---------------------------------------------------------------------------

def bench_table2_resources(rounds=8, seed=0):
    _, log = run_experiment("fedavg", _cfg(seed=seed), rounds=rounds,
                            eval_every=rounds)
    rows = []
    for tier, res in log.resources.items():
        rows.append({
            "hw_type": tier,
            "cpu_user_s": round(res["cpu_user_s"], 1),
            "cpu_sys_s": round(res["cpu_sys_s"], 1),
            "ram_pct": round(res["ram_pct"], 1),
            "dropouts": log.dropouts[tier],
        })
    return _write("table2_resources", rows)


# ---------------------------------------------------------------------------
# Fig. 3: per-device training time / exchange latency / accuracy variance
# ---------------------------------------------------------------------------

def bench_fig3_per_device(seed=0):
    from repro.core.heterogeneity import PROFILES, TIERS, VirtualClock
    rows = []
    for tier in TIERS:
        clk = VirtualClock(PROFILES[tier], seed=seed)
        times = [clk.round_duration() for _ in range(40)]
        rows.append({
            "hw_type": tier,
            "train_time_mean_s": round(float(np.mean(times)), 1),
            "train_time_std_s": round(float(np.std(times)), 1),
            "exchange_latency_ms": round(
                PROFILES[tier].exchange_latency_s * 1000, 1),
            "rel_vs_T5": round(float(np.mean(times))
                               / PROFILES["HW_T5"].compute_time_s, 2),
        })
    return _write("fig3_per_device", rows)


# ---------------------------------------------------------------------------
# Fig. 4: convergence time by aggregation mode
# ---------------------------------------------------------------------------

def bench_fig4_convergence(seeds=(0, 1), target=TARGET_ACC):
    rows = []
    for seed in seeds:
        _, log_avg = run_experiment("fedavg", _cfg(seed=seed), rounds=40,
                                    target_acc=target)
        t_avg = log_avg.time_to_accuracy(target)
        for name, kw in (
            ("fedasync", dict(alpha=0.4, staleness_aware=True)),
            ("fedasync_nostale", dict(alpha=0.4)),
        ):
            _, log_a = run_experiment(name, _cfg(seed=seed), max_updates=400,
                                      eval_every=5, target_acc=target, **kw)
            t_a = log_a.time_to_accuracy(target)
            rows.append({
                "seed": seed, "strategy": name, "target_acc": target,
                "fedavg_time_s": t_avg, "async_time_s": t_a,
                "speedup": (round(t_avg / t_a, 2)
                            if (t_avg and t_a) else None),
                "final_acc_async": round(log_a.global_acc[-1], 3),
                "acc_fluctuation": round(float(np.std(np.diff(
                    log_a.global_acc))), 4),
            })
    return _write("fig4_convergence", rows)


# ---------------------------------------------------------------------------
# Fig. 5: fairness in client participation vs alpha
# ---------------------------------------------------------------------------

def bench_fig5_fairness(alphas=(0.2, 0.4, 0.6), seed=0, max_updates=300):
    rows = []
    for alpha in alphas:
        _, log = run_experiment("fedasync", _cfg(seed=seed),
                                max_updates=max_updates, alpha=alpha,
                                eval_every=10, target_acc=TARGET_ACC)
        fr = log.fairness()
        pp = fr["participation_pct"]
        high = pp.get("HW_T4", 0) + pp.get("HW_T5", 0)
        row = {"alpha": alpha, "high_end_pp": round(high, 1),
               "jain_participation": round(fr["jain_participation"], 3),
               "accuracy_gap": round(fr["accuracy_gap"], 3),
               "time_to_target_s": log.time_to_accuracy(TARGET_ACC)}
        for tier, v in pp.items():
            row[f"pp_{tier}"] = round(v, 1)
        for tier, accs in log.local_acc.items():
            row[f"acc_{tier}"] = round(accs[-1], 3) if accs else None
        rows.append(row)
    return _write("fig5_fairness", rows)


# ---------------------------------------------------------------------------
# Table 3: privacy loss + accuracy degradation
# ---------------------------------------------------------------------------

def bench_table3_privacy(sigmas=(0.5, 1.0, 2.0), alphas=(0.2, 0.6),
                         seed=0, max_updates=240, rounds=25):
    rows = []
    # non-private baselines for degradation reference (per strategy)
    _, base_avg = run_experiment("fedavg", _cfg(use_dp=False, seed=seed),
                                 rounds=rounds, eval_every=rounds)
    base_acc_avg = {t: a[-1] for t, a in base_avg.local_acc.items()}
    base_async = {}
    for alpha in alphas:
        _, lg = run_experiment("fedasync", _cfg(use_dp=False, seed=seed),
                               max_updates=max_updates, alpha=alpha,
                               eval_every=20)
        base_async[alpha] = {t: a[-1] for t, a in lg.local_acc.items()}

    for sigma in sigmas:
        for alpha in alphas:
            _, log = run_experiment("fedasync", _cfg(sigma=sigma, seed=seed),
                                    max_updates=max_updates, alpha=alpha,
                                    eval_every=20)
            for tier in log.update_counts:
                eps = (log.eps_trajectory[tier][-1]
                       if log.eps_trajectory[tier] else 0.0)
                acc = log.local_acc[tier][-1] if log.local_acc[tier] else 0
                rows.append({
                    "method": f"fedasync_a{alpha}", "sigma": sigma,
                    "device": tier, "epsilon": round(eps, 2),
                    "updates": log.update_counts[tier],
                    "acc_loss_pct": round(
                        100 * (base_async[alpha][tier] - acc), 1),
                })
        _, log = run_experiment("fedavg", _cfg(sigma=sigma, seed=seed),
                                rounds=rounds, eval_every=rounds)
        for tier in log.update_counts:
            eps = log.eps_trajectory[tier][-1]
            acc = log.local_acc[tier][-1]
            rows.append({
                "method": "fedavg", "sigma": sigma, "device": tier,
                "epsilon": round(eps, 2),
                "updates": log.update_counts[tier],
                "acc_loss_pct": round(
                    100 * (base_acc_avg[tier] - acc), 1),
            })
    return _write("table3_privacy", rows)


# ---------------------------------------------------------------------------
# Engine throughput: legacy per-client loop vs cohort-batched engine
# ---------------------------------------------------------------------------

def bench_engine_throughput(num_clients=8, updates=48, seed=0, window=45.0,
                            tiny=False):
    """Wall-clock of the SAME virtual FedAsync workload (>= 8 clients,
    synthetic SER, eval disabled) under the execution paths:

      * legacy   — per-client Python event loop, one jit call per minibatch
      * cohort_w0 — cohort engine, window=0 (size-1 cohorts: measures the
                    whole-local-round fusion alone)
      * cohort_wN — cohort engine with a staleness window (multi-client
                    cohorts through the compiled stacked step)
      * cohort_wN_hostpath — the same workload on the PR-2 host-fed data
                    path (device_arena=False): per-cohort numpy gathers
                    and full batch tensors over H2D
      * cohort_vmap_dD — (multi-device only) the windowed workload with
                    the cohort axis partitioned over a D-way data axis
                    (engine.mesh_backend); spawn host devices with
                    XLA_FLAGS=--xla_force_host_platform_device_count=8
      * cohort_vmap_dD_uneven{_hostpath} — (multi-device only) UNEVEN
                    cohorts (max_cohort that does not divide the data
                    axis): the host path runs them replicated (the PR-2
                    failure mode), the arena path pads them to the bucket
                    size so they always partition — the acceptance pair
                    for the device-resident data path.

    Every row carries ``h2d_bytes_per_cohort`` (RunLog.engine_stats): on
    the arena path this is index-only traffic (a few KB), on the host
    path it is the full stacked batch tensors.

    A warmup pass per engine config is excluded from the timing so the
    numbers compare steady-state execution, not XLA compiles (the engine's
    compiled programs are cached across runs — see repro.engine.cohort_step).

    Writes ``results/bench/engine_throughput.json`` (the usual artifact)
    AND the machine-readable perf trajectory ``BENCH_engine.json`` at the
    repo root (``benchmarks/summarize.py`` reads both; CI's bench-smoke
    step fails when the latter is missing or malformed).  ``tiny`` shrinks
    the workload for that smoke step.
    """
    import time as _time

    import jax

    from repro.engine import EngineConfig

    if tiny:
        num_clients = min(num_clients, 4)
        updates = min(updates, 8)
    cfg = TestbedConfig(use_dp=True, sigma=1.0, batch_size=32,
                        num_clients=num_clients,
                        data=SERDataConfig(
                            n_total=(96 if tiny else 200) * num_clients),
                        seed=seed)

    def spec_of(engine, ec):
        from repro.api import ExperimentSpec
        return ExperimentSpec.from_legacy(
            "fedasync", cfg, max_updates=updates, alpha=0.4,
            eval_every=10 ** 9, engine=engine, engine_cfg=ec)

    def run(engine, ec=None, n=updates):
        t0 = _time.perf_counter()
        _, log = run_experiment("fedasync", cfg, max_updates=n, alpha=0.4,
                                eval_every=10 ** 9, engine=engine,
                                engine_cfg=ec)
        return _time.perf_counter() - t0, log

    ec_w = EngineConfig(staleness_window=window)
    ec_0 = EngineConfig(staleness_window=0.0)
    ec_wh = EngineConfig(staleness_window=window, device_arena=False)
    # warmup: compile every shape the timed runs will hit — the engine's
    # cohort shapes AND the legacy per-step jit (every path pays its XLA
    # compiles here, outside the timed region)
    run("cohort", ec_w, n=max(8, 2 * ec_w.max_cohort))
    run("cohort", ec_0, n=4)
    run("cohort", ec_wh, n=max(8, 2 * ec_wh.max_cohort))
    run("legacy", n=4)

    t_legacy, _ = run("legacy")
    t_w0, log_w0 = run("cohort", ec_0)
    t_wN, log_wN = run("cohort", ec_w)
    t_wh, log_wh = run("cohort", ec_wh)

    timed = [("legacy", t_legacy, None, None),
             ("cohort_w0", t_w0, log_w0, ec_0),
             (f"cohort_w{window:g}", t_wN, log_wN, ec_w),
             (f"cohort_w{window:g}_hostpath", t_wh, log_wh, ec_wh)]

    if len(jax.devices()) > 1:
        # sharded-cohort variants: cohort axis partitioned over the data
        # axes.  The unsharded vmap row is the like-for-like ablation —
        # same executor and cohort sizes, no mesh — so the delta between
        # the two is attributable to the partitioning alone.  The uneven
        # pair (max_cohort = 3/4 of the data axis, pow2 bucketing off)
        # compares the PR-2 replicated execution against padded cohorts.
        from repro.engine import cohort_mesh
        mesh = cohort_mesh(max_cohort=num_clients)
        n_data = mesh.shape["data"]
        ec_vm = EngineConfig(staleness_window=window, max_cohort=n_data,
                             client_axis="vmap")
        ec_sh = EngineConfig(staleness_window=window, max_cohort=n_data,
                             client_axis="vmap", mesh=mesh)
        variants = [(f"cohort_vmap_nomesh_K{n_data}", ec_vm),
                    (f"cohort_vmap_d{n_data}", ec_sh)]
        k_uneven = max(2, (3 * n_data) // 4)
        if k_uneven % n_data:
            ec_un = EngineConfig(staleness_window=window,
                                 max_cohort=k_uneven, client_axis="vmap",
                                 mesh=mesh, pow2_cohorts=False)
            variants += [
                (f"cohort_vmap_d{n_data}_uneven{k_uneven}_hostpath",
                 replace(ec_un, device_arena=False)),
                (f"cohort_vmap_d{n_data}_uneven{k_uneven}", ec_un),
            ]
        for name, ec in variants:
            run("cohort", ec, n=max(8, 2 * n_data))    # warmup compiles
            t_v, log_v = run("cohort", ec)
            timed.append((name, t_v, log_v, ec))

    rows = []
    for name, t, log, ec in timed:
        stats = log.engine_stats if log else {}
        n_cohorts = len(log.cohort_sizes) if log else None
        rows.append({
            "engine": name,
            "executor": ec.client_axis if ec else "legacy",
            "data_path": stats.get("data_path", "legacy"),
            "mesh": (dict(ec.mesh.shape) if ec is not None
                     and ec.mesh is not None else None),
            "num_clients": num_clients,
            "updates": updates,
            "wall_s": round(t, 2),
            "warm_step_ms": (round(1e3 * t / n_cohorts, 2)
                             if n_cohorts else None),
            "updates_per_s": round(updates / t, 2),
            "speedup_vs_legacy": round(t_legacy / t, 2),
            "mean_cohort": (round(float(np.mean(log.cohort_sizes)), 2)
                            if log and log.cohort_sizes else None),
            "h2d_bytes_per_cohort": (
                round(stats["h2d_bytes_per_cohort"])
                if "h2d_bytes_per_cohort" in stats else None),
            # fault-resilience / screening counters (repro.core.faults,
            # repro.core.screening): the bench runs faultless with
            # screening off, so non-null values must be 0 — a nonzero
            # here means a FaultModel or ScreeningConfig leaked into the
            # perf scenario and the timing is not comparable (None on
            # the legacy row, whose loop reports no engine_stats)
            "degraded_cohorts": stats.get(
                "degraded_cohorts", None if log is None else 0),
            "fault_lost_updates": stats.get(
                "fault_lost_updates", None if log is None else 0),
            "screen_rejections": stats.get(
                "screen_rejections", None if log is None else 0),
            # full reproduction provenance: the row's number can be
            # re-measured from this dict alone (ExperimentSpec.from_dict)
            "spec": spec_of("legacy" if ec is None else "cohort",
                            ec).to_dict(),
        })
    pipeline_rows = bench_engine_pipeline(tiny=tiny)
    sweep_section = bench_sweep_amortization(tiny=tiny)
    dp_rows = bench_dp_path(tiny=tiny)
    screening_section = bench_screening_overhead(tiny=tiny)
    scale_section = bench_scale(tiny=tiny)
    _write_bench_engine(rows, pipeline_rows, sweep_section, dp_rows,
                        screening_section, scale_section)
    return _write("engine_throughput", rows)


# ---------------------------------------------------------------------------
# Pipelined cohort scheduler: serial (pre-pipeline) driver vs pipelined
# submit/drain on the forced-8-device mesh
# ---------------------------------------------------------------------------

def bench_engine_pipeline(num_clients=32, updates=96, seed=0, window=120.0,
                          tiny=False):
    """The pipelined-scheduler acceptance pair (multi-device only; spawn
    host devices with XLA_FLAGS=--xla_force_host_platform_device_count=8):
    an identical scheduler-bound async workload — many clients, short
    local rounds, eval disabled, cohorts padded to the data axis — under

      * serial    — pipeline_depth=1 with per-dispatch moments-accountant
                    recomputation: the pre-pipeline driver, whose
                    donation-chained submits block the host for every
                    cohort's full device time (engine_stats counts them
                    as ``blocking_submits``)
      * serial_memo_acct — pipeline_depth=1 with the memoized one-step
                    accountant vector (attribution row: how much of the
                    win is accounting vs overlap)
      * pipelined — pipeline_depth=2 submit/drain: donation-free compiled
                    steps dispatch async, host planning/staging overlaps
                    device compute, zero device->host syncs between eval
                    boundaries (``host_syncs_between_evals`` is asserted
                    in the row)

    The workload is deliberately scheduler-bound (the regime the paper's
    async-speedup argument targets: server-side planning on the critical
    path, not client compute) — small SER model, one/two local steps per
    round, wide cohorts.  Rows land in BENCH_engine.json under the
    ``pipeline`` section (``summarize.py --check-engine`` validates it on
    multi-device runs)."""
    import time as _time

    import jax

    from repro.core.accountant import use_fast_accounting
    from repro.engine import EngineConfig, cohort_mesh
    from repro.models.ser_cnn import SERConfig

    if len(jax.devices()) <= 1:
        return []
    if tiny:
        num_clients = min(num_clients, 16)
        updates = min(updates, 32)
    dims = dict(time_frames=12, n_mels=12)
    cfg = TestbedConfig(
        use_dp=True, sigma=1.0, batch_size=16, num_clients=num_clients,
        data=SERDataConfig(n_total=36 * num_clients, **dims),
        model=SERConfig(channels1=8, channels2=16, fc_dim=32, **dims),
        seed=seed)
    mesh = cohort_mesh(max_cohort=num_clients)
    base = dict(staleness_window=window, max_cohort=mesh.shape["data"],
                client_axis="vmap", mesh=mesh)
    variants = [
        ("serial", EngineConfig(**base), False),
        ("serial_memo_acct", EngineConfig(**base), True),
        ("pipelined", EngineConfig(pipeline_depth=2, **base), True),
    ]

    def run(ec, fast, n=updates):
        prev = use_fast_accounting(fast)
        try:
            t0 = _time.perf_counter()
            _, log = run_experiment("fedasync", cfg, max_updates=n,
                                    alpha=0.4, eval_every=10 ** 9,
                                    engine="cohort", engine_cfg=ec)
            return _time.perf_counter() - t0, log
        finally:
            use_fast_accounting(prev)

    for _, ec, fast in variants:           # warmup: pay the XLA compiles
        run(ec, fast, n=max(8, 2 * mesh.shape["data"]))

    rows = []
    t_serial = None
    for name, ec, fast in variants:
        t, log = run(ec, fast)
        if t_serial is None:
            t_serial = t
        stats = log.engine_stats
        n_cohorts = len(log.cohort_sizes)
        rows.append({
            "engine": name,
            "pipeline_depth": stats["pipeline_depth"],
            "accounting": ("memoized" if fast else
                           "per_dispatch_recompute"),
            "executor": ec.client_axis,
            "data_path": stats["data_path"],
            "mesh": dict(ec.mesh.shape),
            "num_clients": num_clients,
            "updates": updates,
            "wall_s": round(t, 2),
            "warm_step_ms": (round(1e3 * t / n_cohorts, 2)
                             if n_cohorts else None),
            "updates_per_s": round(updates / t, 2),
            "speedup_vs_serial": round(t_serial / t, 2),
            "mean_cohort": (round(float(np.mean(log.cohort_sizes)), 2)
                            if log.cohort_sizes else None),
            "host_syncs_between_evals": stats["host_syncs_between_evals"],
            "blocking_submits": stats["blocking_submits"],
            "drain_waits": stats["drain_waits"],
            "spec": _pipeline_spec(cfg, updates, ec).to_dict(),
        })
    _write("engine_pipeline", rows)
    return rows


def _pipeline_spec(cfg, updates, ec):
    from repro.api import ExperimentSpec
    return ExperimentSpec.from_legacy(
        "fedasync", cfg, max_updates=updates, alpha=0.4,
        eval_every=10 ** 9, engine="cohort", engine_cfg=ec)


# ---------------------------------------------------------------------------
# Session sweep amortization: cold per-run rebuilds vs one warm Session
# over the paper's 4-point sigma grid
# ---------------------------------------------------------------------------

def bench_sweep_amortization(sigmas=(0.5, 1.0, 1.5, 2.0), num_clients=8,
                             updates=24, seed=0, window=45.0, tiny=False):
    """The Session acceptance pair: the paper's sigma grid (Table 3's
    noise axis) run

      * cold — one ``run_experiment`` call per sigma with the compiled-
        step cache invalidated before each point: what a fresh process
        per scenario pays (full testbed rebuild, device re-upload, XLA
        re-trace);
      * warm — ONE ``Session.sweep`` over the same grid: partitions
        generated once, and — because the compiled cohort step takes the
        noise scale as a runtime argument — every sigma replays the same
        compiled program (``cohort_step.step_builds`` counts 1 vs 4).

    Returns the ``sweep`` section for BENCH_engine.json:
    per-point wall clocks, the cold/warm step-build counts, the wall-
    clock speedup, and the base spec + axes as full provenance
    (``summarize.py --check-engine`` requires the section and that the
    warm pass both builds fewer programs and finishes faster)."""
    import time as _time

    from repro.api import ExperimentSpec, RunBudget, Session, StrategySpec
    from repro.engine import EngineConfig, cohort_step, invalidate_step_cache
    from repro.models.ser_cnn import SERConfig

    if tiny:
        num_clients = min(num_clients, 4)
        updates = min(updates, 8)
    dims = dict(time_frames=12, n_mels=12)
    cfg = TestbedConfig(
        use_dp=True, sigma=sigmas[0], batch_size=16,
        num_clients=num_clients,
        data=SERDataConfig(n_total=36 * num_clients, **dims),
        model=SERConfig(channels1=8, channels2=16, fc_dim=32, **dims),
        seed=seed)
    ec = EngineConfig(staleness_window=window)
    base = ExperimentSpec(
        testbed=cfg, strategy=StrategySpec("fedasync", alpha=0.4),
        run=RunBudget(max_updates=updates, eval_every=10 ** 9), engine=ec)
    axes = {"testbed.sigma": list(sigmas)}

    # cold: fresh-process simulation per point — invalidate the compiled
    # programs and rebuild the world through the legacy one-shot frontend
    cold_points, b0 = [], cohort_step.step_builds()
    t_cold = 0.0
    for sg in sigmas:
        invalidate_step_cache()
        t0 = _time.perf_counter()
        run_experiment("fedasync", replace(cfg, sigma=sg),
                       max_updates=updates, alpha=0.4, eval_every=10 ** 9,
                       engine_cfg=ec)
        dt = _time.perf_counter() - t0
        t_cold += dt
        cold_points.append({"sigma": sg, "wall_s": round(dt, 3)})
    cold_builds = cohort_step.step_builds() - b0

    # warm: one Session, same grid
    invalidate_step_cache()
    sess = Session()
    b1 = cohort_step.step_builds()
    t0 = _time.perf_counter()
    result = sess.sweep(base, axes=axes)
    t_warm = _time.perf_counter() - t0
    warm_builds = cohort_step.step_builds() - b1

    section = {
        "sigmas": list(sigmas),
        "num_clients": num_clients,
        "updates": updates,
        "cold_wall_s": round(t_cold, 3),
        "warm_wall_s": round(t_warm, 3),
        "speedup": round(t_cold / t_warm, 2),
        "cold_step_builds": int(cold_builds),
        "warm_step_builds": int(warm_builds),
        "cold_points": cold_points,
        "warm_points": [
            {"sigma": p["testbed.sigma"], "wall_s": round(w, 3)}
            for p, w in zip(result.points, result.wall_s)],
        "session_stats": sess.stats(),
        "spec": base.to_dict(),
        "axes": axes,
    }
    _write("sweep_amortization", [section])
    return section


# ---------------------------------------------------------------------------
# DP hot-path: jnp reference vs the fused Pallas clip+noise kernel
# ---------------------------------------------------------------------------

def bench_dp_path(num_clients=8, updates=24, seed=0, window=45.0, tiny=False):
    """The dp_path acceptance pair: the SAME DP FedAsync workload under

      * jnp    — per-example clip + noise composed from jnp ops (vmap'd
        grads, tree clip, Gaussian tree noise) — the reference path;
      * pallas — ONE fused kernel launch per cohort step over the stacked
        (K*B, D) per-example gradient matrix: two-pass sqnorm/clip-scale
        sweep with the noise add fused into the final-tile epilogue
        (kernels/dp_clip).

    Each row records the backend and — for the pallas row — whether the
    kernel ran compiled or in interpret mode and which policy source
    decided that (``repro.kernels.common.interpret_info``); a pallas row
    silently interpreting on a compiled-capable backend fails
    ``summarize.py --check-engine``.  Rows carry full ExperimentSpec
    provenance like every other BENCH_engine section.

    Returns the ``dp_path`` section rows for BENCH_engine.json."""
    import time as _time

    import jax

    from repro.api import ExperimentSpec
    from repro.engine import EngineConfig
    from repro.models.ser_cnn import SERConfig

    if tiny:
        num_clients = min(num_clients, 4)
        updates = min(updates, 8)
    dims = dict(time_frames=12, n_mels=12)
    base = TestbedConfig(
        use_dp=True, sigma=1.0, batch_size=16, num_clients=num_clients,
        data=SERDataConfig(n_total=36 * num_clients, **dims),
        model=SERConfig(channels1=8, channels2=16, fc_dim=32, **dims),
        seed=seed)
    ec = EngineConfig(staleness_window=window)

    def run(cfg, n=updates):
        t0 = _time.perf_counter()
        _, log = run_experiment("fedasync", cfg, max_updates=n, alpha=0.4,
                                eval_every=10 ** 9, engine="cohort",
                                engine_cfg=ec)
        return _time.perf_counter() - t0, log

    rows, t_jnp = [], None
    for path in ("jnp", "pallas"):
        cfg = replace(base, dp_path=path)
        run(cfg, n=max(8, 2 * ec.max_cohort))       # warmup compiles
        t, log = run(cfg)
        if t_jnp is None:
            t_jnp = t
        stats = log.engine_stats
        info = stats.get("pallas_interpret") or {}
        n_cohorts = len(log.cohort_sizes)
        rows.append({
            "dp_path": path,
            "backend": jax.default_backend(),
            "interpret": info.get("interpret"),       # None on the jnp row
            "interpret_source": info.get("source"),
            "num_clients": num_clients,
            "updates": updates,
            "wall_s": round(t, 3),
            "warm_step_ms": (round(1e3 * t / n_cohorts, 2)
                             if n_cohorts else None),
            "updates_per_s": round(updates / t, 2),
            "speedup_vs_jnp": round(t_jnp / t, 2),
            "spec": ExperimentSpec.from_legacy(
                "fedasync", cfg, max_updates=updates, alpha=0.4,
                eval_every=10 ** 9, engine="cohort",
                engine_cfg=ec).to_dict(),
        })
    _write("dp_path", rows)
    return rows


# ---------------------------------------------------------------------------
# Update-screening overhead: the compiled step ALWAYS computes the
# per-member (finite, norm) verdicts, so turning screening ON costs only
# the per-cohort sanctioned verdict fetch plus the host-side oracle
# ---------------------------------------------------------------------------

def bench_screening_overhead(num_clients=8, updates=48, seed=0, window=45.0,
                             tiny=False):
    """Screening-on vs screening-off on the SAME clean windowed FedAsync
    workload (eval disabled).  Verdict computation is baked into every
    compiled step, so the measurable cost of enabling screening is the
    per-cohort device->host verdict fetch (``screen_verdict_syncs``) and
    the host-side quarantine oracle — this section records that overhead
    in BENCH_engine.json so a regression (e.g. the fetch becoming a full
    blocking sync per member) shows up in the perf trajectory.  Both rows
    run clean: a nonzero ``screen_rejections`` here means corruption
    leaked into the perf scenario (``summarize.py --check-engine``
    enforces it)."""
    import time as _time

    from repro.api import ExperimentSpec
    from repro.core.screening import ScreeningConfig
    from repro.engine import EngineConfig

    if tiny:
        num_clients = min(num_clients, 4)
        updates = min(updates, 8)
    scr = ScreeningConfig(max_update_norm=1e3, quarantine_after=2,
                          readmit_delay_s=100.0)
    ec = EngineConfig(staleness_window=window)

    def cfg_of(screening):
        return TestbedConfig(use_dp=True, sigma=1.0, batch_size=32,
                             num_clients=num_clients,
                             data=SERDataConfig(
                                 n_total=(96 if tiny else 200) * num_clients),
                             seed=seed, screening=screening)

    def run(screening, n=updates):
        t0 = _time.perf_counter()
        _, log = run_experiment("fedasync", cfg_of(screening), max_updates=n,
                                alpha=0.4, eval_every=10 ** 9,
                                engine="cohort", engine_cfg=ec)
        return _time.perf_counter() - t0, log

    run(None, n=max(8, 2 * ec.max_cohort))        # warm the compiled step
    t_off, log_off = run(None)
    t_on, log_on = run(scr)
    rows = []
    for name, t, log, screening in (("off", t_off, log_off, None),
                                    ("on", t_on, log_on, scr)):
        s = log.engine_stats
        rows.append({
            "screening": name,
            "num_clients": num_clients,
            "updates": updates,
            "wall_s": round(t, 3),
            "updates_per_s": round(updates / t, 2),
            "screen_rejections": s["screen_rejections"],
            "screen_verdict_syncs": s["screen_verdict_syncs"],
            "spec": ExperimentSpec.from_legacy(
                "fedasync", cfg_of(screening), max_updates=updates,
                alpha=0.4, eval_every=10 ** 9, engine="cohort",
                engine_cfg=ec).to_dict(),
        })
    return {"rows": rows,
            "overhead_pct": round(100.0 * (t_on / t_off - 1.0), 1)}


def bench_scale(n_values=(1_000, 10_000, 100_000), hot_slots=128,
                lookahead=16, updates=32, seed=0, tiny=False):
    """Million-client-track scale trajectory: the SAME FedAsync workload
    over growing shared-row synthetic populations, executed through the
    tiered client-state store (``StoreConfig.hot_slots`` bounds the
    device arena; :mod:`repro.engine.statestore`).  Every client
    references ONE dataset dict, so the identity-deduped ``DataArena``
    uploads one device row regardless of N — population size stresses
    exactly what the store manages (startup dispatch, the event heap,
    residency churn, prefetch), not host RAM.

    Each row records updates/s and wall seconds (startup included — the
    O(N) part IS the scale story), the measured device-arena footprint
    (live hot params + opt + data leaf bytes, BOUNDED by ``hot_slots``
    while N grows 100x), the all-resident arithmetic equivalent
    (per-slot state bytes x (N + pad) + data), and the store's ledger
    counters.  ``summarize.py --check-engine`` requires the section and
    validates growing N, bounded-vs-resident bytes and the fetch ledger
    per row — the 100k row is the acceptance run: it must complete with
    the same hot-arena bytes as the 1k row.  ``tiny`` shrinks the
    populations to (64, 256) for the CI smoke; the compiled programs
    depend on ``hot_slots``, never N, so one warm pass covers every row.
    """
    import time as _time

    import jax
    import jax.random as jr

    from repro.api import ExperimentSpec
    from repro.api.workloads import get_workload
    from repro.core.aggregation import FedAsync
    from repro.core.runlog import STORE_STATS_KEYS
    from repro.core.testbed import build_clients, build_partitions
    from repro.engine import (CohortRunner, EngineConfig, StoreConfig,
                              run_async_engine)
    from repro.models.ser_cnn import SERConfig

    if tiny:
        n_values, hot_slots, lookahead, updates = (64, 256), 24, 8, 12
    dims = dict(time_frames=12, n_mels=12)
    base = TestbedConfig(
        use_dp=True, sigma=1.0, batch_size=16, num_clients=4,
        data=SERDataConfig(n_total=160, **dims),
        model=SERConfig(channels1=8, channels2=16, fc_dim=32, **dims),
        seed=seed)
    splits, pooled = build_partitions(base)
    tmpl = splits[0]                 # every scale client shares this row
    wl = get_workload(base.workload)
    params0 = wl.init(jr.PRNGKey(seed), base.model)
    acc_fn = wl.shared_accuracy(base.model)

    mesh, max_cohort = None, 8
    if len(jax.devices()) > 1:
        from repro.engine import cohort_mesh
        mesh = cohort_mesh(max_cohort=max_cohort)
    ec = EngineConfig(staleness_window=60.0, max_cohort=max_cohort,
                      pipeline_depth=2, mesh=mesh,
                      store=StoreConfig(hot_slots=hot_slots,
                                        lookahead=lookahead))

    def tree_bytes(t):
        return int(sum(l.nbytes for l in jax.tree_util.tree_leaves(t)))

    def go(n):
        clients = build_clients(base, [tmpl] * n)
        runner = CohortRunner(clients, ec)
        _, log = run_async_engine(
            clients, params0, acc_fn, pooled, FedAsync(alpha=0.4),
            max_updates=updates, seed=seed, eval_every=10 ** 9,
            runner=runner)
        return runner, log

    go(max(hot_slots + 8, 2 * max_cohort))   # warm the compiled buckets

    rows = []
    for n in n_values:
        t0 = _time.perf_counter()
        runner, log = go(n)
        wall = _time.perf_counter() - t0
        state_bytes = (tree_bytes(runner._arena_params)
                       + tree_bytes(runner._arena_opt))
        data_bytes = tree_bytes(runner._arena_data)
        stats = log.engine_stats
        row = {
            "n_clients": n,
            "hot_slots": hot_slots,
            "lookahead": lookahead,
            "population": "shared-row",
            "updates": updates,
            "wall_s": round(wall, 2),
            "updates_per_s": round(updates / wall, 2),
            "peak_device_arena_bytes": state_bytes + data_bytes,
            "resident_equiv_bytes": int(
                state_bytes / runner.arena_slots * (n + 1)) + data_bytes,
            "spec": ExperimentSpec.from_legacy(
                "fedasync", replace(base, num_clients=n),
                max_updates=updates, alpha=0.4, eval_every=10 ** 9,
                engine="cohort", engine_cfg=ec).to_dict(),
        }
        row.update({k: int(stats[k]) for k in STORE_STATS_KEYS})
        rows.append(row)
        del runner
    return {"rows": rows}


def _write_bench_engine(rows, pipeline_rows=None, sweep_section=None,
                        dp_rows=None, screening_section=None,
                        scale_section=None):
    """The machine-readable perf trajectory: BENCH_engine.json at the repo
    root (schema checked by ``benchmarks/summarize.py --check-engine``).
    ``pipeline_rows`` (multi-device runs) land under the ``pipeline``
    section — the serial-vs-pipelined scheduler comparison —
    ``sweep_section`` (bench_sweep_amortization) under ``sweep`` — the
    cold-per-run vs warm-Session comparison — ``dp_rows`` (bench_dp_path)
    under ``dp_path`` — the jnp-vs-fused-kernel DP hot-path comparison —
    ``screening_section`` (bench_screening_overhead) under ``screening``
    — the screening-on vs screening-off overhead pair — and
    ``scale_section`` (bench_scale) under ``scale`` — the tiered-store
    client-count trajectory with its bounded device-arena footprint."""
    import jax

    out = {
        "benchmark": "engine_throughput",
        "devices": len(jax.devices()),
        "rows": rows,
    }
    if pipeline_rows:
        out["pipeline"] = {"rows": pipeline_rows}
    if sweep_section:
        out["sweep"] = sweep_section
    if dp_rows:
        out["dp_path"] = {"rows": dp_rows}
    if screening_section:
        out["screening"] = screening_section
    if scale_section:
        out["scale"] = scale_section
    fn = os.path.join(os.path.dirname(__file__), "..", "BENCH_engine.json")
    with open(fn, "w") as f:
        json.dump(out, f, indent=1, default=float)
    return fn


# ---------------------------------------------------------------------------
# Beyond-paper: non-IID ablation (the paper is IID-only; label skew makes
# low-end marginalization strictly worse because their rare updates are
# also the only carriers of their label distribution)
# ---------------------------------------------------------------------------

def bench_noniid_ablation(seed=0, sigma=1.0, max_updates=240,
                          dirichlet_alpha=0.3):
    rows = []
    for part in ("iid", "dirichlet"):
        cfg = TestbedConfig(use_dp=True, sigma=sigma, batch_size=64,
                            data=HALF, seed=seed, partition=part,
                            dirichlet_alpha=dirichlet_alpha)
        _, log = run_experiment("fedasync", cfg, max_updates=max_updates,
                                alpha=0.4, eval_every=10,
                                target_acc=TARGET_ACC)
        fr = log.fairness()
        rows.append({
            "partition": part,
            "global_acc": round(log.global_acc[-1], 3),
            "time_to_target_s": log.time_to_accuracy(TARGET_ACC),
            "accuracy_gap": round(fr["accuracy_gap"], 3),
            "jain_accuracy": round(fr["jain_accuracy"], 3),
            "acc_HW_T1": round(log.local_acc["HW_T1"][-1], 3),
            "acc_HW_T5": round(log.local_acc["HW_T5"][-1], 3),
        })
    return _write("noniid_ablation", rows)


# ---------------------------------------------------------------------------
# Beyond-paper: adaptive strategies trade-off table (paper Sec. 5)
# ---------------------------------------------------------------------------

def bench_beyond_paper(seed=0, sigma=1.0, max_updates=240):
    rows = []
    for name, kw in (
        ("fedasync", dict(alpha=0.4)),
        ("fedbuff", dict(alpha=0.4, buffer_size=3)),
        ("adaptive_async", dict(alpha=0.4, eps_target=8.0)),
    ):
        _, log = run_experiment(name, _cfg(sigma=sigma, seed=seed),
                                max_updates=max_updates, eval_every=10,
                                target_acc=TARGET_ACC, **kw)
        fr = log.fairness()
        rows.append({
            "strategy": name,
            "time_to_target_s": log.time_to_accuracy(TARGET_ACC),
            "final_acc": round(log.global_acc[-1], 3),
            "jain_participation": round(fr["jain_participation"], 3),
            "privacy_disparity": round(fr["privacy_disparity"], 2),
            "max_eps": round(max(v[-1] for v in
                                 log.eps_trajectory.values() if v), 2),
        })
    return _write("beyond_paper_tradeoffs", rows)
