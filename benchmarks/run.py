"""Benchmark harness entry point — one benchmark per paper table/figure
plus kernel microbenchmarks and the roofline digest.

    PYTHONPATH=src python -m benchmarks.run            # full pass
    PYTHONPATH=src python -m benchmarks.run --quick    # CI-sized

Prints ``name,us_per_call,derived`` CSV lines; full artifacts land in
results/bench/.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def _line(name, us, derived=""):
    print(f"{name},{us if us is not None else ''},{derived}", flush=True)


def kernel_microbench():
    """us_per_call for the three Pallas kernels (interpret mode on CPU —
    correctness-path timing, not TPU perf; the roofline table carries the
    TPU projection)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.dp_clip.ops import dp_clip_mean_flat
    from repro.kernels.flash_attn.ops import flash_decode
    from repro.kernels.ssd_scan.ops import ssd_intra_chunk

    key = jax.random.PRNGKey(0)

    def timeit(f, *args, reps=3):
        jax.block_until_ready(f(*args))  # compile
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(f(*args))
        return (time.perf_counter() - t0) / reps * 1e6

    flat = jax.random.normal(key, (128, 4096), jnp.float32)
    us = timeit(lambda x: dp_clip_mean_flat(x, 1.0), flat)
    _line("kernel.dp_clip.128x4096", round(us), "interpret")

    q = jax.random.normal(key, (2, 8, 64), jnp.float32)
    k = jax.random.normal(key, (2, 1024, 2, 64), jnp.float32)
    v = jax.random.normal(key, (2, 1024, 2, 64), jnp.float32)
    pos = jnp.array([900, 1000])
    us = timeit(lambda a, b, c, d: flash_decode(a, b, c, d, window=512),
                q, k, v, pos)
    _line("kernel.flash_decode.S1024", round(us), "interpret")

    xr = jax.random.normal(key, (1, 4, 64, 4, 32), jnp.float32)
    ar = -jnp.abs(jax.random.normal(key, (1, 4, 4, 64))) * 0.1
    Br = jax.random.normal(key, (1, 4, 64, 32), jnp.float32)
    Cr = jax.random.normal(key, (1, 4, 64, 32), jnp.float32)
    us = timeit(ssd_intra_chunk, xr, ar, Br, Cr)
    _line("kernel.ssd_intra.c4q64", round(us), "interpret")


def sweep_smoke() -> None:
    """A tiny 2x2 ``Session.sweep`` — {fedavg, fedasync} x two sigmas —
    through the declarative API, sharded over the mesh when more than one
    device exists (CI's engine-mesh job forces 8 host devices).  Prints
    one CSV line per scenario plus the session's cache telemetry; any
    scenario failing to train is a hard error."""
    import jax

    from repro.api import ExperimentSpec, RunBudget, Session, StrategySpec
    from repro.core.testbed import TestbedConfig
    from repro.data.synthetic_ser import SERDataConfig
    from repro.engine import EngineConfig, cohort_mesh
    from repro.models.ser_cnn import SERConfig

    n_clients = 8
    dims = dict(time_frames=12, n_mels=12)
    multi = len(jax.devices()) > 1
    if multi:
        mesh = cohort_mesh(max_cohort=n_clients)
        ec = EngineConfig(staleness_window=45.0,
                          max_cohort=mesh.shape["data"],
                          client_axis="vmap", mesh=mesh)
    else:
        ec = EngineConfig(staleness_window=45.0)
    spec = ExperimentSpec(
        testbed=TestbedConfig(
            use_dp=True, sigma=0.5, batch_size=16, num_clients=n_clients,
            data=SERDataConfig(n_total=36 * n_clients, **dims),
            model=SERConfig(channels1=8, channels2=16, fc_dim=32, **dims)),
        strategy=StrategySpec("fedasync", alpha=0.4),
        run=RunBudget(rounds=2, max_updates=8, eval_every=4),
        engine=ec)
    t0 = time.time()
    result = Session().sweep(spec, axes={
        "strategy": [StrategySpec("fedavg"),
                     StrategySpec("fedasync", alpha=0.4)],
        "testbed.sigma": [0.5, 2.0],
    })
    for row in result.table():
        if row["final_acc"] is None:
            raise SystemExit(f"sweep-smoke scenario produced no eval: {row}")
        _line(f"sweep.smoke.{row['strategy']}.s{row['sigma']:g}",
              round(row["wall_s"] * 1e6),
              f"acc={row['final_acc']};eps={row['max_eps']}"
              + (";mesh" if multi else ""))
    _line("sweep.smoke", round((time.time() - t0) * 1e6),
          f"points={len(result)};mesh={multi}")


def fault_smoke() -> None:
    """Kill/resume drill for the resilience layer (RESILIENCE.md): a tiny
    chaotic run (failures, upload loss + retries, duplicates, late
    deliveries, churn), then the same run killed in-process by
    ``SimulatedCrash`` at its second published checkpoint and resumed from
    disk — the resumed RunLog must equal the uninterrupted one BIT FOR
    BIT (params, times, epsilon trajectories, fault events, engine
    stats).  Runs sharded when more than one device exists (CI's
    engine-mesh job forces 8 host devices)."""
    import shutil
    import tempfile

    import jax

    from repro.api import ExperimentSpec, RunBudget, Session, StrategySpec
    from repro.core.faults import FaultModel
    from repro.core.testbed import TestbedConfig
    from repro.data.synthetic_ser import SERDataConfig
    from repro.engine import EngineConfig, SimulatedCrash, cohort_mesh
    from repro.models.ser_cnn import SERConfig

    n_clients = 8
    dims = dict(time_frames=12, n_mels=12)
    multi = len(jax.devices()) > 1
    if multi:
        mesh = cohort_mesh(max_cohort=n_clients)
        ec = EngineConfig(staleness_window=45.0,
                          max_cohort=mesh.shape["data"],
                          client_axis="vmap", mesh=mesh)
    else:
        ec = EngineConfig(staleness_window=45.0)
    spec = ExperimentSpec(
        testbed=TestbedConfig(
            use_dp=True, sigma=0.5, batch_size=16, num_clients=n_clients,
            data=SERDataConfig(n_total=36 * n_clients, **dims),
            model=SERConfig(channels1=8, channels2=16, fc_dim=32, **dims),
            faults=FaultModel(
                seed=7, failure_prob=0.1, upload_loss_prob=0.15,
                max_retries=1, retry_backoff_s=4.0, duplicate_prob=0.15,
                late_prob=0.1, leave_prob=0.1, rejoin_delay_s=40.0)),
        strategy=StrategySpec("fedasync", alpha=0.4),
        run=RunBudget(max_updates=24, eval_every=6),
        engine=ec)

    def logdict(log):
        return dict(times=log.times, acc=log.global_acc,
                    sv=log.server_version, uc=dict(log.update_counts),
                    st=log.staleness, fe=list(log.fault_events),
                    es=dict(log.engine_stats),
                    eps={k: list(v) for k, v in log.eps_trajectory.items()})

    t0 = time.time()
    p_plain, log_plain = Session().run(spec)
    if not log_plain.fault_events:
        raise SystemExit("fault-smoke chaos model produced no faults")
    ckdir = tempfile.mkdtemp(prefix="fault_smoke_ck_")
    try:
        try:
            Session().run(spec, checkpoint_every=7, checkpoint_dir=ckdir,
                          crash_after_saves=2)
            raise SystemExit("fault-smoke run survived crash_after_saves=2")
        except SimulatedCrash:
            pass
        p_res, log_res = Session().run(spec, checkpoint_every=7,
                                       checkpoint_dir=ckdir,
                                       resume_from=ckdir)
    finally:
        shutil.rmtree(ckdir, ignore_errors=True)
    a, b = logdict(log_plain), logdict(log_res)
    bad = [k for k in a if a[k] != b[k]]
    bad += ["params"] if any(
        not np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree_util.tree_leaves(p_plain),
                        jax.tree_util.tree_leaves(p_res))) else []
    if bad:
        raise SystemExit(
            f"fault-smoke resume is NOT bit-identical; diverged: {bad}")
    s = log_res.engine_stats
    _line("fault.smoke", round((time.time() - t0) * 1e6),
          f"events={len(log_res.fault_events)}"
          f";lost={s['fault_failures'] + s['fault_lost_updates']}"
          f";retries={s['fault_retries']}"
          f";degraded={s['degraded_cohorts']}"
          f";mesh={multi};resume=bit-identical")


def screen_smoke() -> None:
    """Corrupt-update defense drill (RESILIENCE.md): the SAME tiny async
    workload run clean, corrupted-and-undefended, and corrupted-defended
    (in-step screening + quarantine + norm-bounded merge) through one
    warm Session.  The defended run must fire in-step rejections with a
    consistent counter ledger and beat the undefended run's final
    accuracy — corruption defense as an acceptance check, sharded when
    more than one device exists (CI's engine-mesh job forces 8 host
    devices)."""
    import math
    from dataclasses import replace

    import jax

    from repro.api import ExperimentSpec, RunBudget, Session, StrategySpec
    from repro.core.faults import FaultModel
    from repro.core.screening import ScreeningConfig
    from repro.core.testbed import TestbedConfig
    from repro.data.synthetic_ser import SERDataConfig
    from repro.engine import EngineConfig, cohort_mesh
    from repro.models.ser_cnn import SERConfig

    n_clients = 8
    dims = dict(time_frames=12, n_mels=12)
    multi = len(jax.devices()) > 1
    if multi:
        mesh = cohort_mesh(max_cohort=n_clients)
        ec = EngineConfig(staleness_window=45.0,
                          max_cohort=mesh.shape["data"],
                          client_axis="vmap", mesh=mesh)
    else:
        ec = EngineConfig(staleness_window=45.0)
    tb = TestbedConfig(
        use_dp=True, sigma=0.5, batch_size=16, num_clients=n_clients,
        data=SERDataConfig(n_total=36 * n_clients, **dims),
        model=SERConfig(channels1=8, channels2=16, fc_dim=32, **dims))
    faults = FaultModel(seed=7, corrupt_prob=0.5)
    screen = ScreeningConfig(max_update_norm=1e3, quarantine_after=2,
                             readmit_delay_s=100.0)

    def spec(tb_, strat):
        return ExperimentSpec(testbed=tb_, strategy=strat,
                              run=RunBudget(max_updates=24, eval_every=8),
                              engine=ec)

    plain = StrategySpec("fedasync", alpha=0.4)
    robust = StrategySpec("fedasync_normbound", alpha=0.4, norm_bound=10.0)
    sess = Session()
    t0 = time.time()
    _, log_clean = sess.run(spec(tb, plain))
    _, log_open = sess.run(spec(replace(tb, faults=faults), plain))
    _, log_def = sess.run(
        spec(replace(tb, faults=faults, screening=screen), robust))

    s = log_def.engine_stats
    if not s["screen_rejections"]:
        raise SystemExit("screen-smoke defended run rejected nothing — "
                         "the corruption drill is not exercising screening")
    if s["screen_rejections"] != s["screen_nonfinite"] + s["screen_norm_rejects"]:
        raise SystemExit(f"screen-smoke rejection ledger broken: {s}")
    if not any(e[0].startswith("corrupt_") for e in log_open.fault_events):
        raise SystemExit("screen-smoke fault model produced no corruption")
    a_clean, a_open, a_def = (log.global_acc[-1] for log in
                              (log_clean, log_open, log_def))
    if not math.isfinite(a_def):
        raise SystemExit(f"screen-smoke defended accuracy is {a_def}")
    # the acceptance comparison: defense must beat the undefended run,
    # whose merges ingest the NaN/blown-up payloads unchecked
    a_open_eff = a_open if math.isfinite(a_open) else -1.0
    if a_def <= a_open_eff:
        raise SystemExit(
            f"screen-smoke defense did not help: defended acc {a_def} "
            f"<= undefended {a_open} (clean {a_clean})")
    _line("screen.smoke", round((time.time() - t0) * 1e6),
          f"rej={s['screen_rejections']}"
          f";nonfinite={s['screen_nonfinite']}"
          f";norm={s['screen_norm_rejects']}"
          f";quar={s['screen_quarantined']}"
          f";acc_clean={a_clean};acc_open={a_open};acc_def={a_def}"
          f";mesh={multi}")


def scale_smoke() -> None:
    """Tiered client-state store drill (STORE.md): the SAME tiny async
    workload run all-resident and through a hot-slot-bounded
    TieredStateStore with lookahead prefetch — the two runs must produce
    BIT-IDENTICAL params and trajectories, the tiered run must actually
    churn (prefetch hits AND evictions both nonzero), its fetch ledger
    must balance, and the pipelined scheduler must stay sync-free
    between eval boundaries.  Runs sharded when more than one device
    exists (CI's engine-mesh job forces 8 host devices)."""
    import jax
    import jax.random as jr

    from repro.api.workloads import get_workload
    from repro.core.aggregation import FedAsync
    from repro.core.runlog import STORE_STATS_KEYS
    from repro.core.testbed import TestbedConfig, build_clients, \
        build_partitions
    from repro.data.synthetic_ser import SERDataConfig
    from repro.engine import (CohortRunner, EngineConfig, StoreConfig,
                              cohort_mesh, run_async_engine)
    from repro.models.ser_cnn import SERConfig

    n_clients = 16
    dims = dict(time_frames=12, n_mels=12)
    tb = TestbedConfig(
        use_dp=True, sigma=0.5, batch_size=16, num_clients=n_clients,
        data=SERDataConfig(n_total=36 * n_clients, **dims),
        model=SERConfig(channels1=8, channels2=16, fc_dim=32, **dims))
    splits, pooled = build_partitions(tb)
    wl = get_workload(tb.workload)
    params0 = wl.init(jr.PRNGKey(0), tb.model)
    acc_fn = wl.shared_accuracy(tb.model)
    multi = len(jax.devices()) > 1
    if multi:
        mesh, max_cohort, updates = cohort_mesh(max_cohort=8), 8, 24
        store = StoreConfig(hot_slots=8, lookahead=6)
    else:
        mesh, max_cohort, updates = None, 4, 40
        store = StoreConfig(hot_slots=6, lookahead=4)

    def go(store_cfg):
        clients = build_clients(tb, splits)
        ec = EngineConfig(staleness_window=30.0, max_cohort=max_cohort,
                          pipeline_depth=2, mesh=mesh, store=store_cfg)
        return run_async_engine(
            clients, params0, acc_fn, pooled, FedAsync(alpha=0.5),
            max_updates=updates, seed=0, eval_every=10,
            runner=CohortRunner(clients, ec))

    t0 = time.time()
    p_res, log_res = go(StoreConfig())
    p_tier, log_tier = go(store)
    bad = [k for k in ("times", "global_acc", "staleness", "update_counts",
                       "cohort_sizes")
           if getattr(log_res, k) != getattr(log_tier, k)]
    bad += ["params"] if any(
        not np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree_util.tree_leaves(p_res),
                        jax.tree_util.tree_leaves(p_tier))) else []
    if bad:
        raise SystemExit(
            f"scale-smoke tiered run is NOT bit-identical; diverged: {bad}")
    s = {k: log_tier.engine_stats[k] for k in STORE_STATS_KEYS}
    if s["store_fetches"] != (s["store_hot_hits"] + s["store_prefetch_hits"]
                              + s["store_stall_waits"]):
        raise SystemExit(f"scale-smoke store ledger broken: {s}")
    if not s["store_prefetch_hits"]:
        raise SystemExit(f"scale-smoke lookahead prefetcher never hit: {s}")
    if not s["store_evictions"]:
        raise SystemExit(f"scale-smoke store never evicted "
                         f"(hot_slots={store.hot_slots} of {n_clients}): {s}")
    if log_tier.engine_stats["host_syncs_between_evals"]:
        raise SystemExit(
            "scale-smoke tiered run blocked between eval boundaries: "
            f"{log_tier.engine_stats['host_syncs_between_evals']} syncs")
    _line("scale.smoke", round((time.time() - t0) * 1e6),
          f"hot={store.hot_slots}/{n_clients}"
          f";prefetch={s['store_prefetch_hits']}"
          f";evictions={s['store_evictions']}"
          f";stalls={s['store_stall_waits']}"
          f";spill_kb={s['store_spill_bytes'] // 1024}"
          f";mesh={multi};parity=bit-identical")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-fl", action="store_true")
    ap.add_argument("--fresh", action="store_true",
                    help="recompute even when a cached artifact exists")
    ap.add_argument("--engine-smoke", action="store_true",
                    help="tiny bench_engine_throughput pass only: emits "
                         "BENCH_engine.json for summarize.py --check-engine "
                         "(CI's engine-mesh bench-smoke step)")
    ap.add_argument("--dp-smoke", action="store_true",
                    help="tiny bench_dp_path pass only: jnp vs the fused "
                         "Pallas clip+noise kernel on the cohort hot path "
                         "(CI's engine-mesh dp-smoke step; does NOT rewrite "
                         "BENCH_engine.json)")
    ap.add_argument("--sweep-smoke", action="store_true",
                    help="tiny 2x2 Session.sweep (strategy x sigma) — "
                         "exercises the declarative API end to end on "
                         "whatever devices exist (CI's engine-mesh "
                         "sweep-smoke step runs it on the forced-8-device "
                         "mesh)")
    ap.add_argument("--fault-smoke", action="store_true",
                    help="tiny chaotic run + in-process SimulatedCrash at "
                         "a published checkpoint + resume; the resumed "
                         "RunLog must be bit-identical (CI's engine-mesh "
                         "fault-smoke step runs it on the forced-8-device "
                         "mesh)")
    ap.add_argument("--scale-smoke", action="store_true",
                    help="tiny resident-vs-tiered client-state-store pair: "
                         "bit-identical params/trajectories with live "
                         "prefetch hits, evictions and a balanced fetch "
                         "ledger (CI's engine-mesh scale-smoke step runs "
                         "it on the forced-8-device mesh)")
    ap.add_argument("--screen-smoke", action="store_true",
                    help="tiny corrupted run with in-step screening + "
                         "robust aggregation: rejections must fire and "
                         "the defended accuracy must beat the undefended "
                         "run (CI's engine-mesh screen-smoke step runs it "
                         "on the forced-8-device mesh)")
    args = ap.parse_args()

    from benchmarks import fl_benchmarks as flb

    if args.scale_smoke:
        scale_smoke()
        return

    if args.screen_smoke:
        screen_smoke()
        return

    if args.fault_smoke:
        fault_smoke()
        return

    if args.sweep_smoke:
        sweep_smoke()
        return

    if args.dp_smoke:
        t0 = time.time()
        rows = flb.bench_dp_path(tiny=True)
        pallas = next(r for r in rows if r["dp_path"] == "pallas")
        _line("engine.dp.smoke", round((time.time() - t0) * 1e6),
              ";".join(f"{r['dp_path']}:{r['speedup_vs_jnp']}x"
                       for r in rows)
              + f";interpret={pallas['interpret']}"
              + f"({pallas['interpret_source']})")
        return

    if args.engine_smoke:
        t0 = time.time()
        rows = flb.bench_engine_throughput(tiny=True)
        _line("engine.smoke", round((time.time() - t0) * 1e6),
              ";".join(f"{r['engine']}:{r['speedup_vs_legacy']}x"
                       for r in rows))
        # the pipelined-scheduler pair (multi-device only): tiny
        # pipeline_depth=2 run vs the serial driver, validated by
        # summarize.py --check-engine against the BENCH pipeline section.
        # Read THIS run's BENCH_engine.json (bench_engine_throughput just
        # rewrote it) — the results/bench cache may hold a stale
        # multi-device artifact from an earlier invocation.
        import json
        import os
        bench_fn = os.path.join(os.path.dirname(flb.__file__), "..",
                                "BENCH_engine.json")
        with open(bench_fn) as f:
            bench = json.load(f)
        pipe = bench.get("pipeline", {}).get("rows", [])
        if pipe:
            _line("engine.pipeline.smoke", None,
                  ";".join(f"{r['engine']}:{r['speedup_vs_serial']}x"
                           for r in pipe))
        sw = bench.get("sweep")
        if sw:
            _line("engine.sweep.smoke", None,
                  f"warm:{sw['speedup']}x;builds:{sw['warm_step_builds']}"
                  f"/{sw['cold_step_builds']}")
        dp = bench.get("dp_path", {}).get("rows", [])
        if dp:
            _line("engine.dp.smoke", None,
                  ";".join(f"{r['dp_path']}:{r['speedup_vs_jnp']}x"
                           for r in dp))
        return

    def run_or_cache(name, fn):
        if not args.fresh:
            rows = flb.cached(name)
            if rows is not None:
                return rows, True
        return fn(), False

    t0 = time.time()
    kernel_microbench()

    if not args.skip_fl:
        rounds = 4 if args.quick else 8
        rows, hit = run_or_cache(
            "table2_resources", lambda: flb.bench_table2_resources(rounds=rounds))
        _line("table2.resources", round((time.time() - t0) * 1e6),
              f"tiers={len(rows)}{';cached' if hit else ''}")

        rows, hit = run_or_cache("fig3_per_device", flb.bench_fig3_per_device)
        rel = {r["hw_type"]: r["rel_vs_T5"] for r in rows}
        _line("fig3.per_device", None,
              f"T1_rel={rel.get('HW_T1')}x{';cached' if hit else ''}")

        t = time.time()
        rows, hit = run_or_cache(
            "fig4_convergence",
            lambda: flb.bench_fig4_convergence(seeds=(0,) if args.quick else (0, 1)))
        sp = [r["speedup"] for r in rows if r["speedup"]]
        _line("fig4.convergence", round((time.time() - t) * 1e6),
              (f"speedup={np.mean(sp):.1f}x" if sp else "no-target")
              + (";cached" if hit else ""))

        t = time.time()
        rows, hit = run_or_cache(
            "fig5_fairness",
            lambda: flb.bench_fig5_fairness(
                alphas=(0.2, 0.6) if args.quick else (0.2, 0.4, 0.6),
                max_updates=150 if args.quick else 300))
        _line("fig5.fairness", round((time.time() - t) * 1e6),
              ";".join(f"a{r['alpha']}:high={r['high_end_pp']}%"
                       for r in rows) + (";cached" if hit else ""))

        t = time.time()
        rows, hit = run_or_cache(
            "table3_privacy",
            lambda: flb.bench_table3_privacy(
                sigmas=(0.5, 2.0) if args.quick else (0.5, 1.0, 2.0),
                alphas=(0.2,) if args.quick else (0.2, 0.6),
                max_updates=120 if args.quick else 240,
                rounds=12 if args.quick else 25))
        hi = [r for r in rows if r["device"] == "HW_T5"
              and "async" in r["method"]]
        lo = [r for r in rows if r["device"] == "HW_T1"
              and "async" in r["method"]]
        if hi and lo:
            disp = np.mean([h["epsilon"] / max(l["epsilon"], 1e-9)
                            for h, l in zip(hi, lo)])
            _line("table3.privacy", round((time.time() - t) * 1e6),
                  f"eps_disparity={disp:.1f}x" + (";cached" if hit else ""))

        t = time.time()
        rows, hit = run_or_cache(
            "noniid_ablation",
            lambda: flb.bench_noniid_ablation(
                max_updates=120 if args.quick else 240))
        _line("beyond.noniid", round((time.time() - t) * 1e6),
              ";".join(f"{r['partition']}:gap={r['accuracy_gap']}"
                       for r in rows) + (";cached" if hit else ""))

        t = time.time()
        rows, hit = run_or_cache(
            "engine_throughput",
            lambda: flb.bench_engine_throughput(
                num_clients=8, updates=24 if args.quick else 48))
        sp = {r["engine"]: r["speedup_vs_legacy"] for r in rows}
        _line("engine.throughput", round((time.time() - t) * 1e6),
              ";".join(f"{k}:{v}x" for k, v in sp.items())
              + (";cached" if hit else ""))

        t = time.time()
        rows, hit = run_or_cache(
            "beyond_paper_tradeoffs",
            lambda: flb.bench_beyond_paper(
                max_updates=100 if args.quick else 240))
        _line("beyond.tradeoffs", round((time.time() - t) * 1e6),
              ";".join(f"{r['strategy']}:eps={r['max_eps']}"
                       for r in rows) + (";cached" if hit else ""))

    # roofline digest from whatever dry-run artifacts exist
    try:
        from benchmarks.roofline import analyze_all, write_table
        rows = analyze_all()
        ok = [r for r in rows if r.get("status") == "ok"]
        if ok:
            write_table(rows)
            doms = {}
            for r in ok:
                doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
            _line("roofline.single_pod", None,
                  f"pairs={len(ok)};dominant={doms}")
    except Exception as e:  # noqa: BLE001
        _line("roofline.single_pod", None, f"unavailable:{e}")

    _line("total", round((time.time() - t0) * 1e6), "bench pass complete")


if __name__ == "__main__":
    main()
