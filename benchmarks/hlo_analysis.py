"""Thin re-export shim: the HLO walker moved to ``repro.analysis.hlo``
(the static-analysis & program-audit subsystem) so the compiled-program
audits can live next to it.  Import sites that predate the move —
``tests/test_hlo_analysis.py``, ``tests/test_hlo_collectives.py``,
``benchmarks/roofline.py`` — keep working unchanged through this module.
"""
from repro.analysis.hlo import (  # noqa: F401
    COLLECTIVE_KINDS,
    Computation,
    analyze,
    donation_aliases,
    parse_module,
)

__all__ = ["COLLECTIVE_KINDS", "Computation", "analyze",
           "donation_aliases", "parse_module"]
